"""launch.hlo_analysis shape parsing + collective accounting unit tests.

Regression coverage for the shape-regex fixes: tuple-result async
collectives (``all-gather-start`` returning ``(inputs..., outputs...)``),
bounded-dynamic dims (``f32[<=16,8]``), and unranked/scalar ``f32[]`` — the
old ``[\\d,]*`` regex silently dropped all three to zero bytes.
"""
from repro.launch import hlo_analysis as ha


class TestShapeBytes:
    def test_plain_shape(self):
        assert ha._shape_bytes("f32", "32,128") == 32 * 128 * 4

    def test_scalar_empty_dims(self):
        assert ha._shape_bytes("f32", "") == 4

    def test_bounded_dynamic_dim_charges_the_bound(self):
        assert ha._shape_bytes("f32", "<=16,8") == 16 * 8 * 4

    def test_unknown_dtype_is_zero(self):
        assert ha._shape_bytes("token", "") == 0

    def test_dtype_widths(self):
        assert ha._shape_bytes("bf16", "4,4") == 32
        assert ha._shape_bytes("u8", "4,4") == 16


class TestResultBytes:
    def test_sync_collective_result(self):
        line = ("%ag = f32[32,128]{1,0} all-gather(f32[8,128]{1,0} %p), "
                "replica_groups={{0,1,2,3}}, dimensions={0}")
        assert ha._result_bytes(line) == 32 * 128 * 4

    def test_async_tuple_start_counts_output_half_only(self):
        # (input, output) tuple: summing both halves double-counts
        line = ("%ags = (f32[8,128]{1,0}, f32[32,128]{1,0}) "
                "all-gather-start(f32[8,128]{1,0} %p), "
                "replica_groups={{0,1,2,3}}, dimensions={0}")
        assert ha._result_bytes(line) == 32 * 128 * 4

    def test_bounded_dynamic_result_is_nonzero(self):
        line = "%r = f32[<=16,8] all-reduce(f32[<=16,8] %p), to_apply=%add"
        assert ha._result_bytes(line) == 16 * 8 * 4

    def test_scalar_result(self):
        line = "%r = f32[] all-reduce(f32[] %p), to_apply=%add"
        assert ha._result_bytes(line) == 4


class TestCollectiveStats:
    HLO = "\n".join([
        "ENTRY %main {",
        "  %p = f32[8,128]{1,0} parameter(0)",
        "  %ag = f32[32,128]{1,0} all-gather(%p), "
        "replica_groups={{0,1,2,3}}, dimensions={0}",
        "  %ars = (f32[8,128]{1,0}, f32[8,128]{1,0}) "
        "all-reduce-start(f32[8,128]{1,0} %p), replica_groups={{0,1,2,3}}, "
        "to_apply=%add",
        "  ROOT %t = f32[32,128]{1,0} copy(%ag)",
        "}",
    ])

    def test_counts_and_bytes(self):
        st = ha.collective_stats(self.HLO, total_devices=4)
        assert st.ops == {"all-gather": 1, "all-reduce": 1}
        ag = 32 * 128 * 4
        ar = 8 * 128 * 4            # output half of the start tuple
        assert st.result_bytes["all-gather"] == ag
        assert st.result_bytes["all-reduce"] == ar
        # ring factors: AG (g-1)/g, AR 2(g-1)/g over g=4
        assert st.wire_bytes == ag * 3 / 4 + 2 * ar * 3 / 4
        assert st.total_result_bytes() == ag + ar

    def test_non_collective_lines_ignored(self):
        st = ha.collective_stats("  %c = f32[4,4] copy(%p)\n", 4)
        assert st.ops == {} and st.wire_bytes == 0.0
