"""bench_diff self-tests: the CI perf gate must catch what it claims to.

Three layers:

  * pure ``diff()`` semantics on hand-built documents — directionality
    (occupancy drops vs VMEM growth), tolerance, coverage (missing rows),
    verifier findings;
  * the seeded-regression fixture: take the committed
    ``BENCH_baseline.json``, degrade one MXU-occupancy figure and grow one
    VMEM working set, and require the CLI to exit 1 naming both — this is
    the acceptance proof that the gate is live, not decorative;
  * schema discipline: mismatched/missing ``meta.schema_version`` is exit
    2 (refused), and ``--update-baseline`` rewrites the baseline file.

The committed baseline must also diff cleanly against itself (exit 0), so
a stale baseline or schema drift fails here before it fails in CI.
"""
import copy
import json
import pathlib
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "tools"))
sys.path.insert(0, str(REPO))          # for `import benchmarks.run`
import bench_diff  # noqa: E402
BASELINE = REPO / "BENCH_baseline.json"


def _doc(**over):
    base = {
        "meta": {"schema_version": bench_diff_schema()},
        "modules": {
            "kernel": {"structured": [
                {"name": "conv_tile", "kind": "conv_tile",
                 "mxu_row_occupancy": 0.9, "vmem_bytes": 100_000},
                {"name": "dw_tile", "kind": "dw_tile",
                 "vmem_bytes": 50_000},
            ]},
            "serve": {"structured": [
                {"name": "admit_len8", "device_calls_per_admit": 2.0},
            ]},
        },
        "program": {"cnn_a": {
            "totals": {"max_vmem_bytes": 200_000, "weight_bytes": 9_000},
            "layers": [{"name": "conv1", "vmem_bytes": 80_000,
                        "mxu_row_occupancy": 0.8}],
        }},
        "verify": {"cnn_a": {"errors": 0, "warnings": 1,
                             "by_rule": {}}},
    }
    base.update(over)
    return base


def bench_diff_schema():
    import importlib

    run = importlib.import_module("benchmarks.run")
    return run.SCHEMA_VERSION


def test_identical_docs_no_regressions():
    d = _doc()
    assert bench_diff.diff(d, copy.deepcopy(d)) == []


def test_occupancy_drop_is_regression_and_gain_is_not():
    base = _doc()
    worse = copy.deepcopy(base)
    worse["modules"]["kernel"]["structured"][0]["mxu_row_occupancy"] = 0.7
    regs = [d for d in bench_diff.diff(base, worse) if d.regression]
    assert [d.path for d in regs] == ["kernel/conv_tile/mxu_row_occupancy"]
    better = copy.deepcopy(base)
    better["modules"]["kernel"]["structured"][0]["mxu_row_occupancy"] = 0.95
    deltas = bench_diff.diff(base, better)
    assert deltas and not any(d.regression for d in deltas)  # benign drift


def test_vmem_growth_device_calls_and_totals():
    base = _doc()
    worse = copy.deepcopy(base)
    worse["modules"]["kernel"]["structured"][1]["vmem_bytes"] = 80_000
    worse["modules"]["serve"]["structured"][0]["device_calls_per_admit"] = 3.0
    worse["program"]["cnn_a"]["totals"]["max_vmem_bytes"] = 400_000
    paths = {d.path for d in bench_diff.diff(base, worse) if d.regression}
    assert paths == {"kernel/dw_tile/vmem_bytes",
                     "serve/admit_len8/device_calls_per_admit",
                     "program/cnn_a/totals/max_vmem_bytes"}


def test_small_drift_within_tolerance_is_not_regression():
    base = _doc()
    close = copy.deepcopy(base)
    close["modules"]["kernel"]["structured"][0]["vmem_bytes"] = 100_500
    assert not any(d.regression
                   for d in bench_diff.diff(base, close, rel_tol=0.01))
    assert any(d.regression
               for d in bench_diff.diff(base, close, rel_tol=0.001))


def test_missing_row_and_new_verifier_findings():
    base = _doc()
    worse = copy.deepcopy(base)
    del worse["modules"]["kernel"]["structured"][1]          # dropped bench
    worse["verify"]["cnn_a"]["errors"] = 2                   # new ERRORs
    regs = {d.path: d for d in bench_diff.diff(base, worse) if d.regression}
    assert "kernel/dw_tile" in regs
    assert regs["kernel/dw_tile"].metric == "coverage"
    assert "verify/cnn_a/errors" in regs
    # warnings above baseline regress too; at-or-below does not
    warn = copy.deepcopy(base)
    warn["verify"]["cnn_a"]["warnings"] = 2
    assert any(d.path == "verify/cnn_a/warnings" and d.regression
               for d in bench_diff.diff(base, warn))
    assert not any(d.regression for d in bench_diff.diff(
        base, copy.deepcopy(base) | {}))


def test_schema_mismatch_refused():
    base, cand = _doc(), _doc()
    cand["meta"]["schema_version"] = bench_diff_schema() + 1
    with pytest.raises(bench_diff.SchemaMismatch):
        bench_diff.check_schemas(base, cand)
    cand2 = _doc()
    del cand2["meta"]["schema_version"]
    with pytest.raises(bench_diff.SchemaMismatch):
        bench_diff.check_schemas(base, cand2)


# ---------------------------------------------------------------------------
# CLI against the committed baseline
# ---------------------------------------------------------------------------

def _committed():
    if not BASELINE.exists():
        pytest.skip("BENCH_baseline.json not generated yet")
    return json.loads(BASELINE.read_text())


def test_committed_baseline_passes_against_itself(tmp_path):
    doc = _committed()
    assert doc["meta"]["schema_version"] == bench_diff_schema()
    cand = tmp_path / "cand.json"
    cand.write_text(json.dumps(doc))
    assert bench_diff.main([str(BASELINE), str(cand)]) == 0


def _first_row_with(doc, module, field):
    for row in doc["modules"][module]["structured"]:
        if isinstance(row.get(field), (int, float)) and row[field]:
            return row
    raise AssertionError(
        f"committed baseline has no {module} row with {field!r} — the "
        "seeded-regression fixture lost its target")


def test_seeded_regression_fixture_fails_cli(tmp_path, capsys):
    """Acceptance check: degrade the committed baseline and the gate fires."""
    doc = _committed()
    _first_row_with(doc, "kernel", "mxu_row_occupancy")[
        "mxu_row_occupancy"] *= 0.5                     # occupancy drop
    _first_row_with(doc, "kernel", "vmem_bytes")["vmem_bytes"] *= 4  # growth
    cand = tmp_path / "seeded.json"
    cand.write_text(json.dumps(doc))
    rc = bench_diff.main([str(BASELINE), str(cand),
                          "--json", str(tmp_path / "deltas.json")])
    assert rc == 1
    out = capsys.readouterr().out
    assert "mxu_row_occupancy" in out and "vmem_bytes" in out
    dumped = json.loads((tmp_path / "deltas.json").read_text())
    assert len(dumped["regressions"]) >= 2


def test_schema_mismatch_exits_2_and_update_baseline(tmp_path, capsys):
    doc = _committed()
    doc["meta"]["schema_version"] = 999
    cand = tmp_path / "newschema.json"
    cand.write_text(json.dumps(doc))
    moving_base = tmp_path / "base.json"
    moving_base.write_text(BASELINE.read_text())
    assert bench_diff.main([str(moving_base), str(cand)]) == 2
    assert "refusing to compare" in capsys.readouterr().err
    # the explicit human path: --update-baseline rewrites and exits 0
    assert bench_diff.main([str(moving_base), str(cand),
                            "--update-baseline"]) == 0
    assert json.loads(moving_base.read_text())[
        "meta"]["schema_version"] == 999
