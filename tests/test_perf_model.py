"""Tests for the paper's analytical performance model (Eq. 14-18)."""
from repro.core import perf_model as pm


class TestEquations:
    def test_eq14_output_dims(self):
        l = pm.ConvLayer(48, 48, 3, 7, 7, 5)
        assert l.out_dims == (42, 42, 5)
        l2 = pm.ConvLayer(224, 224, 3, 3, 3, 32, stride=2, padding=1)
        assert l2.out_dims == (112, 112, 32)

    def test_eq15_lsa_folding(self):
        cfg = pm.BinArrayConfig(4, 32, 2)
        assert pm.n_lsa(cfg, M=2) == 4        # high-throughput mode
        assert pm.n_lsa(cfg, M=4) == 2        # high-accuracy mode: 2 passes

    def test_eq17_passes(self):
        cfg = pm.BinArrayConfig(1, 32, 2)
        assert pm.n_pass(cfg, D=5, M=2) == 1
        assert pm.n_pass(cfg, D=150, M=2) == 5
        assert pm.n_pass(cfg, D=150, M=2, depthwise=True) == 150  # §V-A3

    def test_cnn_a_macs_match_paper(self):
        """Paper: CNN-A has ~9M MACs.  VALID-conv accounting gives 5.8M;
        SAME-padding accounting gives ~9.5M — the paper's figure is
        consistent with the latter.  We assert the same order of magnitude
        and that the composition (conv >> dense) matches."""
        layers = pm.cnn_a_layers()
        macs = pm.total_macs(layers)
        assert 5e6 < macs < 10e6, macs
        dense = sum(l.macs for l in layers if isinstance(l, pm.DenseLayer))
        assert dense / macs < 0.15

    def test_mobilenet_macs_match_paper(self):
        """CNN-B1 ~49M MACs (alpha=.5 @128); CNN-B2 ~569M (alpha=1 @224)."""
        b1 = pm.total_macs(pm.mobilenet_layers(alpha=0.5, resolution=128))
        b2 = pm.total_macs(pm.mobilenet_layers(alpha=1.0, resolution=224))
        assert 35e6 < b1 < 65e6, b1
        assert 450e6 < b2 < 700e6, b2

    def test_cpu_baseline_table3(self):
        """Paper Table III CPU column: CNN-A 111.8 fps, B2 1.8 fps @1 GOPS.
        (CNN-A within the VALID/SAME conv-accounting gap — see above.)"""
        fps_a = pm.cpu_fps(pm.cnn_a_layers())
        assert 0.6 < fps_a / 111.8 < 1.7, fps_a
        fps_b2 = pm.cpu_fps(pm.mobilenet_layers(alpha=1.0, resolution=224))
        assert abs(fps_b2 - 1.8) / 1.8 < 0.35, fps_b2


class TestThroughputScaling:
    """Table III structure: fps scales with N_SA / D_arch and drops with M."""

    def test_scales_with_nsa(self):
        layers = pm.mobilenet_layers(alpha=0.5, resolution=128)
        f1 = pm.fps(pm.BinArrayConfig(1, 32, 4), layers, M=4,
                    exclude_final_dense=True)
        f4 = pm.fps(pm.BinArrayConfig(4, 32, 4), layers, M=4,
                    exclude_final_dense=True)
        f16 = pm.fps(pm.BinArrayConfig(16, 32, 4), layers, M=4,
                     exclude_final_dense=True)
        assert f4 > 2.5 * f1 and f16 > 2.5 * f4

    def test_darch_sublinear_when_channels_small(self):
        """Paper §V-B3: 4x D_arch -> only ~2x on CNN-A (first layer has 5
        channels -> 15% PE utilization at D_arch=32)."""
        layers = pm.cnn_a_layers()
        f8 = pm.fps(pm.BinArrayConfig(1, 8, 2), layers, M=2)
        f32 = pm.fps(pm.BinArrayConfig(1, 32, 2), layers, M=2)
        ratio = f32 / f8
        assert 1.5 < ratio < 3.0, ratio

    def test_high_accuracy_mode_halves_throughput(self):
        """M = 2*M_arch costs ~2x cycles (Eq. 15)."""
        layers = pm.mobilenet_layers(alpha=0.5, resolution=128)
        cfg = pm.BinArrayConfig(4, 32, 4)
        f_fast = pm.fps(cfg, layers, M=4, exclude_final_dense=True)
        f_acc = pm.fps(cfg, layers, M=8, exclude_final_dense=True)
        assert abs(f_fast / f_acc - 2.0) < 0.2

    def test_table3_magnitudes(self):
        """Our MAC-exact model lands near the paper's Table III BinArray
        numbers (same order, within ~35% — the paper's Eq. 18 is internally
        inconsistent; see perf_model docstring)."""
        expect = {  # (cfg, layers, M) -> paper fps
            (pm.BinArrayConfig(1, 8, 2), "a", 2): 354.2,
            (pm.BinArrayConfig(1, 32, 2), "a", 2): 819.8,
            (pm.BinArrayConfig(4, 32, 4), "b1", 4): 728.4,
            (pm.BinArrayConfig(16, 32, 4), "b2", 4): 350.0,
        }
        nets = {"a": pm.cnn_a_layers(),
                "b1": pm.mobilenet_layers(alpha=0.5, resolution=128),
                "b2": pm.mobilenet_layers(alpha=1.0, resolution=224)}
        for (cfg, net, M), paper_fps in expect.items():
            ours = pm.fps(cfg, nets[net], M=M,
                          exclude_final_dense=(net != "a"))
            assert 0.4 < ours / paper_fps < 2.5, (str(cfg), net, ours, paper_fps)

    def test_dsp_count_model(self):
        """Paper §V-B4: DSP blocks == N_SA * M_arch always."""
        for nsa, march in [(1, 2), (4, 4), (16, 4)]:
            assert nsa * march == pm.BinArrayConfig(nsa, 32, march).N_SA * march
