"""SLO-governed CNN serving: ladder, admission control, SLO feedback.

The degradation-ladder contract (ISSUE 8): every rung is strictly cheaper
than the one above it, every rung's outputs are bit-exact against
``deploy.execute`` at the same schedule on the same padded batch, lower
level counts run measurably faster, and the controller degrades under
latency pressure and recovers to full-M when it clears — while admission
sheds explicitly (named reasons, counted) instead of queueing unboundedly.

Everything here is deterministic: the service runs on a
``testing.faults.ManualClock`` and latency pressure is synthesized by
advancing that clock from inside a stub ``execute_fn`` — no wall-clock
sleeps, no flaky thresholds (the one real-time check, conv kernel latency
vs level count, compares medians of repeated jitted calls).
"""
import time

import jax
import numpy as np
import pytest

from repro import deploy
from repro.serve_cnn import (CNNService, SLOConfig, default_ladder,
                             schedule_cost)
from repro.testing.faults import ManualClock
from repro.testing.scenarios import tiny_cnn_program

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def program():
    return tiny_cnn_program(batch=4)


def _images(n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal((8, 8, 3), dtype=np.float32)
            for _ in range(n)]


# ---------------------------------------------------------------------------
# the §IV-D degradation ladder
# ---------------------------------------------------------------------------

class TestLadder:
    def test_strictly_decreasing_cost_full_m_first(self, program):
        ladder = default_ladder(program)
        assert ladder[0] == program.resolve_schedule(None)
        costs = [schedule_cost(program, s) for s in ladder]
        assert all(a > b for a, b in zip(costs, costs[1:])), costs
        assert len(ladder) >= 2     # M=2 program must have a reduced rung

    def test_single_level_program_gets_one_rung(self):
        prog = tiny_cnn_program(batch=2, m=1)
        assert default_ladder(prog) == (prog.resolve_schedule(None),)

    def test_every_rung_bit_exact_vs_execute(self, program):
        """A request served at rung k returns exactly what deploy.execute
        produces at that rung's schedule on the same padded batch — the
        ladder changes cost, never numerics."""
        for rung, sched in enumerate(default_ladder(program)):
            svc = CNNService(program, initial_rung=rung, batch_size=4)
            reqs = [svc.submit(im) for im in _images(3, seed=rung)]
            done = svc.drain()
            assert [r.status for r in done] == ["done"] * 3
            ref = np.asarray(deploy.execute(
                program, svc.last_batch, sched))
            for r in done:
                assert r.m_schedule == sched and r.rung == rung
                assert np.array_equal(r.logits, ref[r.batch_index]), rung
            assert reqs[0] is done[0]

    def test_lower_m_active_lower_latency(self, program):
        """§IV-D's point: fewer levels, fewer MXU passes, faster batch.
        Median of repeated steady-state jitted calls, full-M vs the bottom
        rung (every layer at 1 of 2 levels — half the matmul work)."""
        ladder = default_ladder(program)
        x = np.stack(_images(4))

        def median_t(sched, n=7):
            deploy.execute(program, x, sched).block_until_ready()  # compile
            ts = []
            for _ in range(n):
                t0 = time.perf_counter()
                deploy.execute(program, x, sched).block_until_ready()
                ts.append(time.perf_counter() - t0)
            return sorted(ts)[n // 2]

        t_full, t_low = median_t(ladder[0]), median_t(ladder[-1])
        # direction only, with headroom for CPU-interpret noise: the cost
        # model says 2x — flag only a real inversion
        assert t_low < t_full * 1.25, (t_low, t_full)


# ---------------------------------------------------------------------------
# admission control: explicit sheds, bounded queue
# ---------------------------------------------------------------------------

class TestAdmission:
    def test_rejects_wrong_image_shape(self, program):
        svc = CNNService(program)
        with pytest.raises(ValueError, match=r"\(9, 8, 3\).*\(8, 8, 3\)"):
            svc.submit(np.zeros((9, 8, 3), np.float32))

    def test_expired_deadline_shed_at_admit(self, program):
        clock = ManualClock(100.0)
        svc = CNNService(program, clock=clock)
        r = svc.submit(_images(1)[0], deadline_s=99.0)
        assert r.status == "shed" and r.shed_reason == "deadline_expired"
        assert svc.stats["shed"]["deadline_expired"] == 1
        assert svc.stats["shed_count"] == 1
        assert not svc.queue

    def test_deadline_expiring_in_queue_shed_at_dispatch(self, program):
        clock = ManualClock()
        svc = CNNService(program, clock=clock, batch_size=2)
        ok = svc.submit(_images(1)[0])
        tight = svc.submit(_images(1)[0], deadline_s=clock() + 0.5)
        clock.advance(1.0)          # deadline passes while queued
        finished = svc.step()
        assert tight in finished
        assert tight.status == "shed"
        assert tight.shed_reason == "deadline_expired"
        assert ok.status == "done"  # the live request still served

    def test_queue_full_backpressure(self, program):
        svc = CNNService(program, max_queue=3)
        results = [svc.submit(im) for im in _images(5)]
        assert [r.status for r in results[:3]] == ["queued"] * 3
        assert all(r.status == "shed" and r.shed_reason == "queue_full"
                   for r in results[3:])
        assert svc.stats["shed"]["queue_full"] == 2
        svc.drain()
        assert svc.stats["completed"] == 3

    def test_drain_raises_instead_of_spinning(self, program):
        svc = CNNService(program, batch_size=1, max_queue=8)
        for im in _images(3):
            svc.submit(im)
        with pytest.raises(RuntimeError, match="failed to drain"):
            svc.drain(max_steps=1)


# ---------------------------------------------------------------------------
# SLO feedback: degrade under pressure, recover when it clears
# ---------------------------------------------------------------------------

def _pressured_service(program, slow_s, *, target_ms=10.0, clock=None):
    """Service whose executor advances the shared virtual clock by
    ``slow_s[i]`` on call i — deterministic latency pressure."""
    clock = clock or ManualClock()
    calls = [0]

    def execute_fn(prog, x, sched, *, interpret=None):
        dt = slow_s[min(calls[0], len(slow_s) - 1)]
        calls[0] += 1
        clock.advance(dt)
        return deploy.execute(prog, x, sched, interpret=interpret)

    svc = CNNService(
        program,
        slo=SLOConfig(target_ms=target_ms, window=16, min_samples=4,
                      recover_at=0.5, recover_after=2),
        batch_size=4, clock=clock, sleep=clock.sleep,
        execute_fn=execute_fn)
    return svc, clock


class TestSLOController:
    def test_degrades_under_pressure_then_recovers(self, program):
        ladder = default_ladder(program)
        # 6 slow batches (5x target), then fast forever
        svc, clock = _pressured_service(program, [0.05] * 6 + [0.0])
        rungs = []
        for i in range(16):
            for im in _images(4, seed=i):
                svc.submit(im)
            svc.step()
            rungs.append(svc.controller.rung)
        assert max(rungs) > 0, rungs                      # degraded
        assert rungs[-1] == 0, rungs                      # fully recovered
        hist = svc.stats["rung_hist"]
        assert set(hist) == set(range(len(ladder))), hist  # walked the ladder
        # degraded batches still served (degrade-before-shed)
        assert svc.stats["completed"] == svc.stats["admitted"]

    def test_static_service_never_moves(self, program):
        svc, _ = _pressured_service(program, [0.05], target_ms=None)
        for i in range(6):
            for im in _images(4, seed=i):
                svc.submit(im)
            svc.step()
        assert svc.stats["rung_hist"] == {0: 6}
        assert not svc.controller.shedding

    def test_shedding_is_backpressure_not_outage(self, program):
        """Past the last rung the service sheds load that would *queue*,
        but keeps serving a batch's worth — otherwise no latency samples
        ever arrive and shedding latches forever (the stuck-queue bug this
        tier exists to prevent)."""
        svc, clock = _pressured_service(program, [0.05] * 10 + [0.0])
        shed_seen = recovered = False
        for i in range(40):
            for im in _images(8, seed=i):  # 2x service rate: overload
                svc.submit(im)
            svc.step()
            shed_seen = shed_seen or svc.controller.shedding
            if (shed_seen and not svc.controller.shedding
                    and svc.controller.rung == 0):
                recovered = True
                break
        assert shed_seen
        assert recovered                           # shedding never latched
        assert svc.stats["shed"]["slo_shed"] > 0
        assert svc.stats["completed"] > 0          # kept serving throughout
        svc.drain()
        assert not svc.queue

    def test_rung_change_clears_the_window(self, program):
        """Decisions at a new rung must be based on latencies measured at
        that rung — stale pre-degradation samples would cascade the
        controller straight to shed."""
        svc, clock = _pressured_service(program, [0.05] + [0.0])
        for i in range(2):
            for im in _images(4, seed=i):
                svc.submit(im)
            svc.step()
        assert svc.controller.rung == 1            # one decision, one rung
        # only the post-change step's 4 samples remain
        assert len(svc.controller._window) == 4


# ---------------------------------------------------------------------------
# LM server: the same admission contract (satellite)
# ---------------------------------------------------------------------------

class TestLMServerDeadline:
    @pytest.fixture(scope="class")
    def server(self):
        from repro.configs import base as cb
        from repro.launch.serve import Server
        from repro.models import api

        cfg = cb.reduced(cb.get_config("gemma_2b")).replace(dtype="float32")
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        return Server(cfg, params, max_batch=2, max_len=32)

    def test_expired_deadline_rejected_and_counted(self, server):
        from repro.launch.serve import Request

        req = Request(prompt=np.array([3, 7], np.int32), max_new_tokens=1,
                      deadline_s=time.monotonic() - 1.0)
        before = server.stats["shed_count"]
        assert server.admit(req) is False
        assert server.stats["shed_count"] == before + 1
        assert all(s is None for s in server.slots)  # no slot consumed

    def test_live_deadline_admitted(self, server):
        from repro.launch.serve import Request

        req = Request(prompt=np.array([3, 7], np.int32), max_new_tokens=1,
                      deadline_s=time.monotonic() + 60.0)
        assert server.admit(req) is True
        server.run_until_done()
        assert server.stats["shed_count"] == 1      # unchanged by success
