"""SSD (Mamba2) numerical correctness: chunked parallel form vs the
sequential recurrence ground truth, + chunk-size invariance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.ssm import ssd_chunked

jax.config.update("jax_platform_name", "cpu")


def ssd_sequential(xh, dt, A, Bm, Cm, D):
    b, l, h, p = xh.shape
    g = Bm.shape[2]
    rep = h // g
    n = Bm.shape[-1]
    state = np.zeros((b, h, p, n), np.float64)
    ys = []
    xh, dt, Bm, Cm = map(lambda t: np.asarray(t, np.float64),
                         (xh, dt, Bm, Cm))
    A = np.asarray(A, np.float64)
    Dv = np.asarray(D, np.float64)
    Bh = np.repeat(Bm, rep, axis=2)
    Ch = np.repeat(Cm, rep, axis=2)
    for t in range(l):
        dA = np.exp(dt[:, t] * A[None])
        state = state * dA[..., None, None] + np.einsum(
            "bh,bhp,bhn->bhpn", dt[:, t], xh[:, t], Bh[:, t])
        y = np.einsum("bhpn,bhn->bhp", state, Ch[:, t]) \
            + Dv[None, :, None] * xh[:, t]
        ys.append(y)
    return np.stack(ys, 1)


def _inputs(seed, b=2, l=32, h=6, p=4, g=2, n=8):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    xh = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    Bm = jax.random.normal(ks[3], (b, l, g, n)) * 0.5
    Cm = jax.random.normal(ks[4], (b, l, g, n)) * 0.5
    D = jnp.ones((h,))
    return xh, dt, A, Bm, Cm, D


@pytest.mark.parametrize("chunk", [4, 8, 16, 32])
def test_chunked_matches_sequential(chunk):
    xh, dt, A, Bm, Cm, D = _inputs(0)
    y = np.asarray(ssd_chunked(xh, dt, A, Bm, Cm, D, chunk))
    ref = ssd_sequential(xh, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(y, ref, rtol=2e-4, atol=2e-4)


def test_chunk_size_invariance():
    xh, dt, A, Bm, Cm, D = _inputs(1)
    y8 = np.asarray(ssd_chunked(xh, dt, A, Bm, Cm, D, 8))
    y16 = np.asarray(ssd_chunked(xh, dt, A, Bm, Cm, D, 16))
    np.testing.assert_allclose(y8, y16, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), g=st.sampled_from([1, 2, 3]),
       chunk=st.sampled_from([4, 8]))
def test_property_group_broadcast_correct(seed, g, chunk):
    """h = g x e factoring must equal the explicit head-broadcast semantics
    for any group count."""
    xh, dt, A, Bm, Cm, D = _inputs(seed, h=6, g=g)
    y = np.asarray(ssd_chunked(xh, dt, A, Bm, Cm, D, chunk))
    ref = ssd_sequential(xh, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(y, ref, rtol=5e-4, atol=5e-4)
