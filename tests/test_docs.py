"""Docs stay runnable: execute every fenced ```python block in README.md
and docs/*.md (the CI docs job runs the same checker stand-alone)."""
import importlib.util
import pathlib

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent

_spec = importlib.util.spec_from_file_location(
    "check_docs", ROOT / "tools" / "check_docs.py")
check_docs = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_docs)

SNIPPETS = list(check_docs.iter_snippets(ROOT))


def test_docs_exist_with_snippets():
    assert (ROOT / "README.md").exists()
    assert (ROOT / "docs" / "serving.md").exists()
    assert SNIPPETS, "no executable python snippets found in the docs"


@pytest.mark.parametrize(
    "path,lineno,code",
    SNIPPETS,
    ids=[f"{p.name}:{ln}" for p, ln, _ in SNIPPETS],
)
def test_snippet_runs(path, lineno, code):
    check_docs.run_snippet(path, lineno, code)
