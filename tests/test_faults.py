"""Fault-injection matrix: every injected fault class has a disposition.

One test per fault class (ISSUE 8), each asserting (a) the service's
disposition — retried, shed, degraded, or failed-loudly — matches the
documented matrix in docs/serving_cnn.md, (b) the queue drains afterward
(no fault wedges the service), and (c) the fault is *accounted*: the
service's counters reconcile against the injector's ledger, so nothing is
silently swallowed.  Plus the harness's own contracts: seeded determinism,
context-manager patch/unpatch hygiene, and the checkpoint-truncation path
through ``deploy.load_program``'s integrity gate.

The ``sleep`` injectable doubles as the phase switch: the service's retry
backoff calls it between attempts, so ``_clear_on_sleep`` flips the
injector to a clean plan exactly at the first retry — fault on attempt 0,
success on attempt 1, fully deterministic.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro import deploy
from repro.deploy import executor
from repro.serve_cnn import CNNService, SLOConfig
from repro.testing.faults import (FaultInjector, FaultPlan, InjectedFault,
                                  ManualClock, inject_faults)
from repro.testing.scenarios import tiny_cnn_program

jax.config.update("jax_platform_name", "cpu")

CLEAN = FaultPlan()


@pytest.fixture(scope="module")
def program():
    return tiny_cnn_program(batch=4)


def _service(program, inj, clock, **kw):
    kw.setdefault("max_retries", 2)
    kw.setdefault("backoff_s", 0.001)
    return CNNService(program, clock=clock, sleep=clock.sleep,
                      execute_fn=inj.wrap_execute(executor.execute), **kw)


def _clear_on_sleep(inj, clock):
    """sleep injectable that advances the virtual clock AND clears the
    fault plan — the retry backoff is the first sleep, so attempt 0 faults
    and attempt 1 runs clean."""
    def sleep(dt):
        clock.advance(dt)
        inj.plan = CLEAN
    return sleep


def _imgs(n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal((8, 8, 3), dtype=np.float32)
            for _ in range(n)]


# ---------------------------------------------------------------------------
# the fault matrix, class by class
# ---------------------------------------------------------------------------

class TestFaultDispositions:
    def test_executor_exception_is_retried(self, program):
        clock = ManualClock()
        inj = FaultInjector(FaultPlan(error_rate=1.0))
        svc = _service(program, inj, clock)
        inj.sleep = clock.sleep
        svc.sleep = _clear_on_sleep(inj, clock)
        reqs = [svc.submit(im) for im in _imgs(2)]
        done = svc.drain()
        # disposition: retried once, then served — and bit-exact
        assert [r.status for r in done] == ["done"] * 2
        s = svc.stats
        assert s["retries"] == 1 and s["exec_exceptions"] == 1, s
        assert s["fault_types"] == {"InjectedFault": 1}, s
        assert s["exec_exceptions"] == inj.counts["error"]  # reconciled
        ref = np.asarray(deploy.execute(program, svc.last_batch,
                                        svc.last_schedule))
        assert np.array_equal(done[0].logits, ref[0])
        assert not svc.queue

    @pytest.mark.parametrize("field", ["nan_rate", "inf_rate"])
    def test_nonfinite_output_is_screened_and_retried(self, program, field):
        """NaN/Inf logits must never reach a client: the finite screen
        raises, the batch retries clean, and the detection is counted."""
        clock = ManualClock()
        inj = FaultInjector(FaultPlan(**{field: 1.0}))
        svc = _service(program, inj, clock)
        svc.sleep = _clear_on_sleep(inj, clock)
        svc.submit(_imgs(1)[0])
        (req,) = svc.drain()
        assert req.status == "done"
        assert np.all(np.isfinite(req.logits))
        s = svc.stats
        assert s["nonfinite_detected"] == 1 and s["retries"] == 1, s
        assert s["exec_exceptions"] == 0, s     # screened, not an exec raise
        injected = inj.counts["nan"] + inj.counts["inf"]
        assert s["nonfinite_detected"] == injected  # reconciled
        ref = np.asarray(deploy.execute(program, svc.last_batch,
                                        svc.last_schedule))
        assert np.array_equal(req.logits, ref[0])

    def test_latency_spike_degrades_the_ladder(self, program):
        """Latency faults don't raise — their disposition is *degradation*:
        the SLO controller sees the spiked completions and walks down the
        ladder, and climbs back once the spikes stop."""
        clock = ManualClock()
        inj = FaultInjector(
            FaultPlan(latency_rate=1.0, latency_s=0.05), sleep=clock.sleep)
        svc = _service(
            program, inj, clock,
            slo=SLOConfig(target_ms=10.0, window=16, min_samples=4,
                          recover_at=0.5, recover_after=2))
        for i in range(4):                      # spiked traffic
            for im in _imgs(4, seed=i):
                svc.submit(im)
            svc.step()
        assert svc.controller.rung > 0, svc.stats   # degraded
        inj.plan = CLEAN
        for i in range(10):                     # pressure cleared
            for im in _imgs(4, seed=10 + i):
                svc.submit(im)
            svc.step()
        assert svc.controller.rung == 0, svc.stats  # recovered to full-M
        s = svc.stats
        assert inj.counts["latency"] > 0
        assert len(s["rung_hist"]) > 1, s           # histogram shows both
        assert s["completed"] == s["admitted"], s   # degraded, shed nothing

    def test_exhausted_retries_fail_loudly_and_queue_drains(self, program):
        """A persistent fault must not wedge the queue OR produce a silent
        answer: after max_retries the batch's requests come back
        status=failed with the error attached, and later clean traffic is
        served normally."""
        clock = ManualClock()
        inj = FaultInjector(FaultPlan(error_rate=1.0))
        svc = _service(program, inj, clock, max_retries=2)
        svc.submit(_imgs(1)[0])
        (req,) = svc.step()
        assert req.status == "failed"
        assert req.logits is None
        assert "InjectedFault" in req.error
        s = svc.stats
        assert s["exec_failed_batches"] == 1, s
        assert s["retries"] == 2, s                  # bounded, not infinite
        assert s["exec_exceptions"] == inj.counts["error"] == 3, s
        inj.plan = CLEAN                             # fault clears ->
        after = svc.submit(_imgs(1, seed=9)[0])      # service recovers
        assert svc.drain() and after.status == "done"
        assert not svc.queue

    def test_truncated_checkpoint_fails_integrity_gate(self, program,
                                                       tmp_path):
        """A torn checkpoint read (one leaf loses a leading-axis slice —
        here a whole binary level) must fail at load_program with a typed
        error naming the findings, not as garbage logits later.  The fuzz
        tier's opt-out returns the corrupt program unverified."""
        from repro.checkpoint.manager import CheckpointManager

        mgr = CheckpointManager(str(tmp_path / "ckpt"))
        deploy.save_program(mgr, 1, program)
        with inject_faults(FaultPlan(truncate_rate=1.0)) as inj:
            with pytest.raises(deploy.ProgramIntegrityError) as ei:
                deploy.load_program(mgr, 1, program)
            assert inj.counts["truncate"] == 1
            assert ei.value.findings          # carries the ERROR findings
            corrupt = deploy.load_program(mgr, 1, program, verify=False)
        # opt-out really skipped the gate: the damage is present
        assert (corrupt.instrs[0].B_tap_packed.shape
                != program.instrs[0].B_tap_packed.shape)
        # clean restore passes the gate
        back = deploy.load_program(mgr, 1, program)
        np.testing.assert_array_equal(back.instrs[0].B_tap_packed,
                                      program.instrs[0].B_tap_packed)


# ---------------------------------------------------------------------------
# harness contracts
# ---------------------------------------------------------------------------

class TestHarness:
    def test_inject_faults_patches_and_restores(self, program):
        """The context manager patches the executor module attribute (the
        service's default late-bound path) and restores it on exit even
        when the body raises; deploy.execute stays the clean reference."""
        from repro.checkpoint.manager import CheckpointManager

        real_exec = executor.execute
        real_restore = CheckpointManager.restore
        x = np.stack(_imgs(4))
        with inject_faults(FaultPlan(error_rate=1.0)) as inj:
            assert executor.execute is not real_exec
            with pytest.raises(InjectedFault):
                executor.execute(program, x)
            # the package-level binding is untouched: reference outputs
            # stay computable inside the block
            ref = deploy.execute(program, x)
            assert np.all(np.isfinite(np.asarray(ref)))
        assert executor.execute is real_exec
        assert CheckpointManager.restore is real_restore
        assert inj.counts["error"] == 1
        with pytest.raises(RuntimeError, match="boom"):
            with inject_faults(FaultPlan()):
                raise RuntimeError("boom")
        assert executor.execute is real_exec       # finally ran

    def test_service_default_path_sees_global_patch(self, program):
        """A CNNService built with NO execute_fn still gets faults from
        inject_faults — the default path resolves executor.execute at call
        time, by design."""
        clock = ManualClock()
        svc = CNNService(program, clock=clock, sleep=clock.sleep,
                         max_retries=3, backoff_s=0.001)
        with inject_faults(FaultPlan(error_rate=0.5, seed=3)) as inj:
            for im in _imgs(8):
                svc.submit(im)
            svc.drain()
        assert inj.counts["error"] > 0
        assert svc.stats["exec_exceptions"] == inj.counts["error"]

    def test_seeded_determinism(self, program):
        x = np.stack(_imgs(4))
        ledgers = []
        for _ in range(2):
            inj = FaultInjector(FaultPlan(error_rate=0.4, nan_rate=0.4,
                                          seed=7), sleep=lambda s: None)
            fn = inj.wrap_execute(executor.execute)
            for _call in range(12):
                try:
                    fn(program, x)
                except InjectedFault:
                    pass
            ledgers.append(dict(inj.counts))
        assert ledgers[0] == ledgers[1]
        assert ledgers[0]["error"] > 0 and ledgers[0]["nan"] > 0

    def test_manual_clock(self):
        clock = ManualClock(5.0)
        assert clock() == 5.0
        clock.sleep(0.25)
        clock.advance(0.75)
        assert clock() == 6.0

    def test_zero_rate_plan_is_transparent(self, program):
        inj = FaultInjector(FaultPlan())
        fn = inj.wrap_execute(executor.execute)
        x = np.stack(_imgs(4))
        out = np.asarray(fn(program, x))
        ref = np.asarray(deploy.execute(program, x))
        np.testing.assert_array_equal(out, ref)
        assert inj.counts["calls"] == 1
        assert sum(v for k, v in inj.counts.items()
                   if k not in ("calls", "restores")) == 0

    def test_plan_fields_cover_the_matrix(self):
        names = {f.name for f in dataclasses.fields(FaultPlan)}
        assert {"latency_rate", "error_rate", "nan_rate", "inf_rate",
                "truncate_rate", "seed"} <= names
