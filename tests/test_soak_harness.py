"""Fast unit tests for the soak driver itself (repro.testing.soak).

Synthetic step closures — no jax, no server — prove the trend machinery:
flat workloads pass, each leak class (python heap, gauge, latency) raises
a TrendViolation naming the guilty series, warmup samples are excluded,
and the CSV artifact round-trips.  The real scenarios run in the ``soak``
tier (tests/test_soak.py); these tests are what lets the fast tier trust
that a green soak run actually asserted something.
"""
import csv

import numpy as np
import pytest

from repro.testing import soak


def _result(*, steps=1000, rss=None, traced=None, latency=None, gauges=None):
    """Hand-built SoakResult over 50 sample points."""
    xs = np.linspace(20, steps, 50).astype(np.int64)
    z = np.zeros(50)
    return soak.SoakResult(
        name="synthetic", total_steps=steps, steps=xs,
        rss=np.asarray(rss if rss is not None else z, np.float64),
        traced=np.asarray(traced if traced is not None else z, np.float64),
        latency=np.asarray(latency if latency is not None else z + 1e-3,
                           np.float64),
        gauges={n: np.asarray(v, np.float64)
                for n, v in (gauges or {}).items()})


def test_flat_run_passes():
    rng = np.random.default_rng(0)
    _result(rss=1e8 + rng.normal(0, 1e4, 50),
            traced=5e6 + rng.normal(0, 1e3, 50),
            latency=1e-3 + rng.normal(0, 1e-6, 50),
            gauges={"cache": np.full(50, 4.0)}).assert_flat()


def test_python_heap_leak_raises():
    leak = 5e6 + np.linspace(0, 64e6, 50)          # ~64 MiB over the run
    with pytest.raises(soak.TrendViolation, match="traced python heap"):
        _result(traced=leak).assert_flat()


def test_gauge_leak_raises_even_by_one_entry():
    g = np.full(50, 4.0)
    g[-5:] = 5.0                                   # one late extra entry
    with pytest.raises(soak.TrendViolation, match="cache leak"):
        _result(gauges={"decode_fns": g}).assert_flat()


def test_latency_creep_raises():
    lat = 1e-3 * (1 + np.linspace(0, 2.0, 50))     # 3x slowdown
    with pytest.raises(soak.TrendViolation, match="step latency"):
        _result(latency=lat).assert_flat()


def test_warmup_window_is_excluded():
    # big ramp confined to the first 20% of samples, flat afterwards:
    # must pass, because warmup compiles/arena growth look exactly like this
    traced = np.full(50, 30e6)
    traced[:10] = np.linspace(1e6, 30e6, 10)
    _result(traced=traced).assert_flat()


def test_run_soak_samples_and_detects_real_leak():
    sink = []

    def leaky(i):
        sink.append(bytearray(64 * 1024))          # 64 KiB per step

    res = soak.run_soak(leaky, steps=300, name="leaky", sample_every=10)
    assert len(res.steps) == 30
    with pytest.raises(soak.TrendViolation):
        res.assert_flat(traced_tol_bytes=1e6)
    # and a no-op workload is flat under the same tolerances
    soak.run_soak(lambda i: None, steps=300, name="idle",
                  sample_every=10).assert_flat(traced_tol_bytes=1e6)


def test_write_csv_roundtrip(tmp_path):
    res = soak.run_soak(lambda i: None, steps=64, name="csv",
                        sample_every=8,
                        gauges={"g": lambda: 3.0})
    path = tmp_path / "trend.csv"
    res.write_csv(str(path))
    rows = list(csv.DictReader(path.open()))
    assert len(rows) == len(res.steps)
    assert set(rows[0]) == {"step", "rss_bytes", "traced_bytes",
                            "latency_s", "g"}
    assert all(float(r["g"]) == 3.0 for r in rows)
    assert int(rows[-1]["step"]) == 64


def test_rss_bytes_reads_something():
    # on linux this is /proc/self/statm; anywhere else psutil or 0 — the
    # contract is "non-negative int, stable within a few pages across calls"
    a, b = soak.rss_bytes(), soak.rss_bytes()
    assert a >= 0 and b >= 0
    if a:
        assert abs(a - b) < 64 * 2**20
