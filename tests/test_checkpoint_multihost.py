"""Multi-host checkpointing: per-host manifests + cross-host digest exchange.

With ``n_hosts > 1`` the step dir is SHARED: each host merge-commits its own
``host_<id>.npz`` + ``manifest_host_<id>.json`` with per-file atomic
replaces (the single-host rename-aside protocol would displace the other
hosts' files).  ``cross_host_digests`` is the all-gather-style audit over
that layout: every host's leaves are re-hashed and leaves recorded by more
than one host must hash identically (replicated state that diverges across
hosts is a silent training bug checksums alone cannot see — each host's
local file is self-consistent).

All tests fake the multi-host fleet with two managers sharing one directory
under different ``host_id``s — the same process-index trick jax distributed
tests use, no actual multi-process setup required.
"""
import os

import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree(host: int, shared_val: float = 1.0):
    """A host-local leaf plus a 'shared' leaf every host replicates."""
    local = {0: {"w": np.arange(6, dtype=np.float32).reshape(2, 3)},
             1: {"b": np.arange(3, dtype=np.float32)}}[host]
    return {**local, "shared": np.full((4,), shared_val, np.float32)}


@pytest.fixture
def fleet(tmp_path):
    d = str(tmp_path)
    m0 = CheckpointManager(d, host_id=0, n_hosts=2)
    m1 = CheckpointManager(d, host_id=1, n_hosts=2)
    return d, m0, m1


class TestMultiHostLayout:
    def test_hosts_share_one_step_dir(self, fleet):
        _, m0, m1 = fleet
        m0.save(1, _tree(0))
        m1.save(1, _tree(1))
        names = sorted(os.listdir(m0._step_dir(1)))
        assert names == ["host_0.npz", "host_1.npz",
                         "manifest_host_0.json", "manifest_host_1.json"]
        assert m0.all_steps() == [1]
        assert m1.all_steps() == [1]

    def test_second_host_commit_keeps_first_hosts_files(self, fleet):
        """The merge commit must never displace a sibling's files — saving
        host 1 after host 0 leaves host 0's step restorable bit-exact."""
        _, m0, m1 = fleet
        m0.save(1, _tree(0))
        m1.save(1, _tree(1))
        back, _ = m0.restore(1, {"w": np.zeros((2, 3), np.float32),
                                 "shared": np.zeros((4,), np.float32)})
        np.testing.assert_array_equal(back["w"], _tree(0)["w"])

    def test_each_host_restores_its_own_leaves(self, fleet):
        _, m0, m1 = fleet
        m0.save(1, _tree(0))
        m1.save(1, _tree(1))
        back, _ = m1.restore(1, {"b": np.zeros((3,), np.float32),
                                 "shared": np.zeros((4,), np.float32)})
        np.testing.assert_array_equal(back["b"], _tree(1)["b"])
        np.testing.assert_array_equal(back["shared"], _tree(1)["shared"])

    def test_only_host_zero_garbage_collects(self, tmp_path):
        """Racing gc from every host would delete steps a slower host is
        still committing into — gc is host 0's job alone."""
        d = str(tmp_path)
        m0 = CheckpointManager(d, keep=1, host_id=0, n_hosts=2)
        m1 = CheckpointManager(d, keep=1, host_id=1, n_hosts=2)
        m1.save(1, _tree(1))
        m1.save(2, _tree(1))
        assert m1.all_steps() == [1, 2]      # host 1 never gc'd
        m0.save(2, _tree(0))
        assert m0.all_steps() == [2]         # host 0 enforces keep=1


class TestCrossHostDigests:
    def test_clean_fleet_reports_ok(self, fleet):
        _, m0, m1 = fleet
        m0.save(1, _tree(0))
        m1.save(1, _tree(1))
        rep = m0.cross_host_digests(1)
        assert rep["ok"] and rep["mismatches"] == []
        assert sorted(rep["hosts"]) == [0, 1]
        for info in rep["hosts"].values():
            assert info["problems"] == []
        # the replicated leaf was gathered from BOTH hosts and agreed
        assert rep["hosts"][0]["leaves"]["shared"] \
            == rep["hosts"][1]["leaves"]["shared"]

    def test_diverged_replicated_leaf_is_a_mismatch(self, fleet):
        """Each host's file is locally self-consistent (digests pass), but
        the replicated leaf differs between hosts — exactly the failure
        class only the cross-host exchange can catch."""
        _, m0, m1 = fleet
        m0.save(1, _tree(0, shared_val=1.0))
        m1.save(1, _tree(1, shared_val=2.0))
        rep = m0.cross_host_digests(1)
        assert not rep["ok"]
        assert [m["leaf"] for m in rep["mismatches"]] == ["shared"]
        assert sorted(rep["mismatches"][0]["digests"]) == [0, 1]
        # local verification stays clean on both sides
        for info in rep["hosts"].values():
            assert info["problems"] == []

    def test_corrupt_host_file_is_that_hosts_problem(self, fleet):
        _, m0, m1 = fleet
        m0.save(1, _tree(0))
        m1.save(1, _tree(1))
        npz = os.path.join(m0._step_dir(1), "host_0.npz")
        blob = bytearray(open(npz, "rb").read())
        blob[len(blob) // 2] ^= 0xFF
        open(npz, "wb").write(bytes(blob))
        rep = m1.cross_host_digests(1)
        assert not rep["ok"]
        assert rep["hosts"][0]["problems"]
        assert rep["hosts"][1]["problems"] == []
        # the unaffected host still restores cleanly
        back, _ = m1.restore(1, {"b": np.zeros((3,), np.float32),
                                 "shared": np.zeros((4,), np.float32)})
        np.testing.assert_array_equal(back["b"], _tree(1)["b"])

    def test_missing_host_file_is_reported(self, fleet):
        _, m0, m1 = fleet
        m0.save(1, _tree(0))
        m1.save(1, _tree(1))
        os.remove(os.path.join(m0._step_dir(1), "host_1.npz"))
        rep = m0.cross_host_digests(1)
        assert not rep["ok"]
        assert any("host_1.npz missing" in p
                   for p in rep["hosts"][1]["problems"])

    def test_single_host_step_audits_as_host_zero(self, tmp_path):
        """Legacy single-host steps (plain manifest.json) still audit: the
        manifest counts as host 0's contribution."""
        m = CheckpointManager(str(tmp_path))
        m.save(1, {"w": np.ones((2,), np.float32)})
        rep = m.cross_host_digests(1)
        assert rep["ok"] and list(rep["hosts"]) == [0]
        assert rep["hosts"][0]["problems"] == []

    def test_missing_step_raises(self, fleet):
        _, m0, _ = fleet
        from repro.checkpoint.manager import CheckpointCorruption
        with pytest.raises(CheckpointCorruption, match="no step dir"):
            m0.cross_host_digests(99)
