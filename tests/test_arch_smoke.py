"""Per-architecture smoke tests (assignment requirement).

For each assigned arch: instantiate a REDUCED same-family config, run one
forward pass AND one train step on CPU, assert output shapes + no NaNs.
Decode smoke: one decode step against a small cache.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cb
from repro.models import api

jax.config.update("jax_platform_name", "cpu")

# model-wide sweep over every assigned arch: ~4 min on CPU — nightly tier
pytestmark = pytest.mark.slow


def _batch(cfg, B=2, S=16, key=0):
    k = jax.random.PRNGKey(key)
    tokens = jax.random.randint(k, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            k, (B, cfg.n_image_tokens, cfg.d_model), jnp.float32
        ).astype(cfg.jnp_dtype)
    if cfg.family == "encdec":
        batch["frame_embeds"] = jax.random.normal(
            k, (B, cfg.encoder_len, cfg.d_model), jnp.float32
        ).astype(cfg.jnp_dtype)
    return batch


@pytest.fixture(scope="module")
def reduced_cfgs():
    return {name: cb.reduced(cb.get_config(name)) for name in cb.ARCH_IDS}


@pytest.mark.parametrize("arch", cb.ARCH_IDS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch, reduced_cfgs):
        cfg = reduced_cfgs[arch]
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        batch = _batch(cfg)
        logits, _ = api.forward(cfg, params, batch)
        B, S = batch["tokens"].shape
        assert logits.shape == (B, S, cfg.vocab), logits.shape
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    def test_train_step(self, arch, reduced_cfgs):
        cfg = reduced_cfgs[arch]
        params = api.init_params(cfg, jax.random.PRNGKey(1))
        batch = _batch(cfg)

        def loss(p):
            l, _ = api.loss_fn(cfg, p, batch)
            return l

        l0, grads = jax.value_and_grad(loss)(params)
        assert np.isfinite(float(l0))
        gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                             for g in jax.tree.leaves(grads)))
        assert np.isfinite(float(gnorm)) and float(gnorm) > 0
        # one SGD step lowers loss on the same batch
        lr = 0.05
        new_params = jax.tree.map(
            lambda p, g: p - (lr * g).astype(p.dtype), params, grads)
        l1 = loss(new_params)
        assert float(l1) < float(l0), (float(l0), float(l1))

    def test_decode_step(self, arch, reduced_cfgs):
        cfg = reduced_cfgs[arch]
        params = api.init_params(cfg, jax.random.PRNGKey(2))
        B, max_len = 2, 32
        cache = api.init_cache(cfg, B, max_len)
        batch = {
            "tokens": jnp.zeros((B, 1), jnp.int32),
            "pos": jnp.zeros((B,), jnp.int32),
            "cache": cache,
        }
        logits, new_cache = api.decode_step(cfg, params, batch)
        assert logits.shape == (B, 1, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
        # cache structure preserved
        assert jax.tree.structure(new_cache) == jax.tree.structure(cache)

    def test_quantized_forward_fake_quant(self, arch, reduced_cfgs):
        """The paper's technique is applicable to every assigned arch
        (DESIGN.md §5): fake-quant forward must stay finite."""
        cfg = reduced_cfgs[arch].replace(
            quant=reduced_cfgs[arch].quant.replace(mode="fake_quant", M=2,
                                                   K_iters=2))
        params = api.init_params(cfg, jax.random.PRNGKey(3))
        logits, _ = api.forward(cfg, params, _batch(cfg))
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_full_configs_have_exact_assigned_dims():
    """The FULL configs carry the exact dims from the assignment table."""
    expect = {
        "gemma_2b": dict(n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
                         d_ff=16384, vocab=256000, head_dim=256),
        "qwen3_14b": dict(n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
                          d_ff=17408, vocab=151936, qk_norm=True),
        "h2o_danube_1_8b": dict(n_layers=24, d_model=2560, n_heads=32,
                                n_kv_heads=8, d_ff=6912, vocab=32000),
        "codeqwen15_7b": dict(n_layers=32, d_model=4096, n_heads=32,
                              n_kv_heads=32, d_ff=13440, vocab=92416),
        "internvl2_2b": dict(n_layers=24, d_model=2048, n_heads=16,
                             n_kv_heads=8, d_ff=8192, vocab=92553),
        "zamba2_7b": dict(n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
                          d_ff=14336, vocab=32000, ssm_state=64),
        "whisper_medium": dict(n_layers=24, d_model=1024, n_heads=16,
                               n_kv_heads=16, d_ff=4096, vocab=51865),
        "mamba2_2_7b": dict(n_layers=64, d_model=2560, d_ff=0, vocab=50280,
                            ssm_state=128),
        "grok_1_314b": dict(n_layers=64, d_model=6144, n_heads=48,
                            n_kv_heads=8, d_ff=32768, vocab=131072,
                            n_experts=8, top_k=2),
        "deepseek_v3_671b": dict(n_layers=61, d_model=7168, n_heads=128,
                                 n_kv_heads=128, d_ff_expert=2048,
                                 vocab=129280, n_experts=256, top_k=8,
                                 use_mla=True),
    }
    for name, fields in expect.items():
        cfg = cb.get_config(name)
        for f, v in fields.items():
            assert getattr(cfg, f) == v, (name, f, getattr(cfg, f), v)


def test_param_counts_near_nameplate():
    """Sanity: full-config param counts are in the right ballpark."""
    targets = {  # (arch, billions, rel tolerance)
        "gemma_2b": (2.5, 0.25),
        "qwen3_14b": (14.8, 0.25),
        "h2o_danube_1_8b": (1.8, 0.3),
        "mamba2_2_7b": (2.7, 0.3),
        "grok_1_314b": (314, 0.15),
        "deepseek_v3_671b": (671, 0.15),
        "zamba2_7b": (7.0, 0.35),
    }
    for name, (bn, tol) in targets.items():
        cfg = cb.get_config(name)
        n = api.count_params(cfg)
        assert abs(n / 1e9 - bn) / bn < tol, (name, n / 1e9, bn)


def test_moe_active_params():
    cfg = cb.get_config("deepseek_v3_671b")
    total = api.count_params(cfg)
    active = api.count_params(cfg, active_only=True)
    assert active < total * 0.12, (active / 1e9, total / 1e9)  # ~37B/671B
