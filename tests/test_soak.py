"""Soak tier (``-m soak``): thousands of steps, asserted-flat trends.

Each test drives one long-lived serving surface (repro.testing.scenarios)
through ``repro.testing.soak.run_soak`` and calls ``assert_flat()``: after
the warmup window, RSS, tracemalloc heap, and per-step latency must fit a
near-zero linear slope, and every compile-cache gauge must end exactly
where it started.  A leak in the jitted-closure caches, the executor's
trace-key derivation, or the checkpoint manager shows up here as a
TrendViolation naming the metric and its projected growth.

Deliberately excluded from the fast tier (see pyproject markers): minutes
of wall clock.  CI runs these nightly (tools/soak.py writes the trend CSVs
the workflow uploads); this pytest form is the local/acceptance entry.
"""
import pytest

from repro.testing import scenarios as sc
from repro.testing.soak import run_soak

pytestmark = pytest.mark.soak


def test_server_soak_mixed_traffic():
    scen = sc.server_scenario()
    # each soak step = one decode round serving TWO m_active groups
    # (None + 1), so 1100 steps ~= 2200 decode_steps — clears the >=2000
    # acceptance floor with margin
    result = run_soak(scen.step, steps=1100, name=scen.name,
                      gauges=scen.gauges)
    stats = scen.progress()
    assert stats["decode_steps"] >= 2000, stats
    assert stats["bulk_prefills"] > 100, stats
    # bounded compile caches: 2 m_active variants x decode/prefill, and
    # the pow2 bucket map stays at the handful of lengths the traffic uses
    result.assert_flat()


def test_executor_soak_rotating_schedules():
    scen = sc.executor_scenario()
    result = run_soak(scen.step, steps=520, name=scen.name,
                      gauges=scen.gauges)
    stats = scen.progress()
    assert stats["execute_calls"] >= 500, stats
    # the schedule rotation re-visits a fixed set of resolved schedules:
    # every variant traced during warmup, then the counter froze
    result.assert_flat()


def test_cnn_server_soak_faulty_traffic():
    """Acceptance soak for the SLO-governed CNN service (ISSUE 8 + 9): under
    cyclic fault storms (latency spikes + executor exceptions + NaN outputs
    at seeded rates, plus one disk and one in-memory bit flip per cycle)
    every non-shed request finishes bit-exact vs the clean
    ``deploy.execute``, every injected fault reconciles against a disposition
    counter (zero silently swallowed), every bit flip is detected and healed
    (quarantine + hot-reload), the degradation histogram shows reduced-M
    activity during pressure and full-M recovery after, and the trend gauges
    stay flat."""
    scen = sc.cnn_server_scenario()
    # 324 steps = 6 whole 54-step clean/storm/clean cycles; whole cycles
    # keep the (deliberately spiky) latency series trend-free
    result = run_soak(scen.step, steps=324, name=scen.name,
                      gauges=scen.gauges)
    p = scen.progress()
    stats = p["stats"]
    # --- every completed answer verified bit-exact vs deploy.execute ---
    assert p["verified"] > 100, p
    assert p["mismatches"] == 0, p
    # --- zero faults silently swallowed: injected == observed, per class
    inj = p["injected"]
    assert stats["exec_exceptions"] == inj["error"], (stats, inj)
    assert stats["nonfinite_detected"] == inj["nan"] + inj["inf"], (
        stats, inj)
    assert inj["error"] > 0 and inj["nan"] > 0 and inj["latency"] > 0, inj
    # every observed fault was retried; with the seeded rates and
    # max_retries=4 no batch exhausts its retries, so nothing failed
    assert stats["retries"] > 0, stats
    assert stats["exec_failed_batches"] == 0 and p["failed"] == 0, (stats, p)
    # --- degradation histogram: reduced-M during storms, back to full-M
    hist = stats["rung_hist"]
    assert hist.get(0, 0) > 0 and sum(
        v for k, v in hist.items() if k > 0) > 0, hist
    assert stats["rung"] == 0 and not stats["shedding"], stats  # recovered
    # --- explicit sheds, drained queue, nothing stuck ---
    assert stats["shed"]["deadline_expired"] > 0, stats
    assert stats["shed"]["slo_shed"] > 0, stats
    assert stats["queue_depth"] <= 2 * 4, stats
    # --- integrity storms (ISSUE 9): every in-memory flip caught by the
    # golden self-test and healed by a hot-reload; every disk flip caught
    # at restore and quarantined (renamed aside, never deleted) ---
    assert inj["bitflip_mem"] > 0 and inj["bitflip_disk"] > 0, inj
    assert stats["reloads"] == inj["bitflip_mem"], (stats, inj)
    assert stats["quarantined_steps"] == inj["bitflip_disk"], (stats, inj)
    assert p["ckpt_quarantined"] == inj["bitflip_disk"], (p, inj)
    assert stats["selftest_failures"] == inj["bitflip_mem"], (stats, inj)
    assert stats["selftest_runs"] > stats["selftest_failures"], stats
    # --- flat trends; gauges exactly flat (all rungs traced in cycle 1,
    # inside the 20% warmup window) ---
    result.assert_flat()


def test_checkpoint_soak_save_load_cycle(tmp_path):
    scen = sc.checkpoint_scenario(str(tmp_path / "ckpt"))
    result = run_soak(scen.step, steps=120, name=scen.name,
                      gauges=scen.gauges)
    stats = scen.progress()
    assert stats["cycles"] >= 120, stats
    assert stats["ckpt_dirs"] <= 2, stats       # keep=2 GC held
    result.assert_flat()
