"""Soak tier (``-m soak``): thousands of steps, asserted-flat trends.

Each test drives one long-lived serving surface (repro.testing.scenarios)
through ``repro.testing.soak.run_soak`` and calls ``assert_flat()``: after
the warmup window, RSS, tracemalloc heap, and per-step latency must fit a
near-zero linear slope, and every compile-cache gauge must end exactly
where it started.  A leak in the jitted-closure caches, the executor's
trace-key derivation, or the checkpoint manager shows up here as a
TrendViolation naming the metric and its projected growth.

Deliberately excluded from the fast tier (see pyproject markers): minutes
of wall clock.  CI runs these nightly (tools/soak.py writes the trend CSVs
the workflow uploads); this pytest form is the local/acceptance entry.
"""
import pytest

from repro.testing import scenarios as sc
from repro.testing.soak import run_soak

pytestmark = pytest.mark.soak


def test_server_soak_mixed_traffic():
    scen = sc.server_scenario()
    # each soak step = one decode round serving TWO m_active groups
    # (None + 1), so 1100 steps ~= 2200 decode_steps — clears the >=2000
    # acceptance floor with margin
    result = run_soak(scen.step, steps=1100, name=scen.name,
                      gauges=scen.gauges)
    stats = scen.progress()
    assert stats["decode_steps"] >= 2000, stats
    assert stats["bulk_prefills"] > 100, stats
    # bounded compile caches: 2 m_active variants x decode/prefill, and
    # the pow2 bucket map stays at the handful of lengths the traffic uses
    result.assert_flat()


def test_executor_soak_rotating_schedules():
    scen = sc.executor_scenario()
    result = run_soak(scen.step, steps=520, name=scen.name,
                      gauges=scen.gauges)
    stats = scen.progress()
    assert stats["execute_calls"] >= 500, stats
    # the schedule rotation re-visits a fixed set of resolved schedules:
    # every variant traced during warmup, then the counter froze
    result.assert_flat()


def test_checkpoint_soak_save_load_cycle(tmp_path):
    scen = sc.checkpoint_scenario(str(tmp_path / "ckpt"))
    result = run_soak(scen.step, steps=120, name=scen.name,
                      gauges=scen.gauges)
    stats = scen.progress()
    assert stats["cycles"] >= 120, stats
    assert stats["ckpt_dirs"] <= 2, stats       # keep=2 GC held
    result.assert_flat()
