"""Shared pytest config.

Two session-level concerns:

* ``hypothesis`` fallback — the property tests import hypothesis, which the
  dev extra provides (``pip install -e .[dev]``) but an offline container
  may lack.  When the real package is missing we register the deterministic
  stub in ``tests/_hypothesis_stub.py`` under the same name before any test
  module is collected, so collection never errors on the import.

* jax.clear_caches() between test modules: the XLA CPU JIT accumulates one
  dylib per compiled executable and a multi-hundred-compile session can hit
  "Failed to materialize symbols" — clearing the compile cache per module
  keeps the long full-suite run healthy (observed on jax 0.8.2 cpu).
"""
import importlib.util
import os
import sys

try:
    import hypothesis  # noqa: F401  (real package wins when installed)
except ImportError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        os.path.join(os.path.dirname(__file__), "_hypothesis_stub.py"))
    _stub = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_stub)
    sys.modules["hypothesis"] = _stub

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    yield
    jax.clear_caches()
