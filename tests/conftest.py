"""Shared pytest config.

jax.clear_caches() between test modules: the XLA CPU JIT accumulates one
dylib per compiled executable and a multi-hundred-compile session can hit
"Failed to materialize symbols" — clearing the compile cache per module
keeps the long full-suite run healthy (observed on jax 0.8.2 cpu).
"""
import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    yield
    jax.clear_caches()
