"""Sharding-rule unit tests (fast — pattern/spec logic, no big compiles)."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import base as cb
from repro.models import api
from repro.sharding import rules as shr

jax.config.update("jax_platform_name", "cpu")


def _mesh(multi=False):
    # abstract mesh over fake devices is not needed — rules only read
    # mesh.shape / axis_names; build the smallest real mesh and patch shape
    class FakeMesh:
        def __init__(self, shape_map):
            self._s = shape_map

        @property
        def shape(self):
            return self._s

        @property
        def axis_names(self):
            return tuple(self._s.keys())

    if multi:
        return FakeMesh({"pod": 2, "data": 16, "model": 16})
    return FakeMesh({"data": 16, "model": 16})


class TestParamRules:
    def test_dense_arch_specs(self):
        cfg = cb.get_config("qwen3_14b")
        shapes = jax.eval_shape(lambda k: api.init_params(cfg, k),
                                jax.ShapeDtypeStruct((2,), jnp.uint32))
        specs = shr.param_pspecs(cfg, shapes, _mesh())
        # attention q: stacked [L, D, H*hd] -> (None, dp, model)
        assert specs["layers"]["attn"]["wq"]["w"] == P(None, ("data",), "model")
        assert specs["layers"]["attn"]["wo"]["w"] == P(None, "model", ("data",))
        # embeddings: vocab on model
        assert specs["embed"]["table"] == P("model", ("data",))
        # norms replicated
        assert specs["final_norm"]["scale"] == P()

    def test_moe_fallback_when_experts_dont_divide(self):
        """grok: 8 experts < 16-way model axis -> TP-inside-expert fallback."""
        cfg = cb.get_config("grok_1_314b")
        shapes = jax.eval_shape(lambda k: api.init_params(cfg, k),
                                jax.ShapeDtypeStruct((2,), jnp.uint32))
        specs = shr.param_pspecs(cfg, shapes, _mesh())
        wg = specs["layers"]["moe"]["w_gate"]
        assert wg == P(None, None, ("data",), "model"), wg

    def test_moe_ep_when_experts_divide(self):
        cfg = cb.get_config("deepseek_v3_671b")
        shapes = jax.eval_shape(lambda k: api.init_params(cfg, k),
                                jax.ShapeDtypeStruct((2,), jnp.uint32))
        specs = shr.param_pspecs(cfg, shapes, _mesh())
        assert specs["layers"]["moe"]["w_gate"] == P(None, "model", ("data",), None)

    def test_multipod_dp_domain(self):
        cfg = cb.get_config("gemma_2b")
        shapes = jax.eval_shape(lambda k: api.init_params(cfg, k),
                                jax.ShapeDtypeStruct((2,), jnp.uint32))
        specs = shr.param_pspecs(cfg, shapes, _mesh(multi=True))
        assert specs["layers"]["attn"]["wq"]["w"] == P(
            None, ("pod", "data"), "model")

    def test_packed_binary_specs(self):
        cfg = cb.get_config("qwen3_14b")
        from repro.core.binlinear import QuantConfig

        qc = QuantConfig(mode="binary", M=2, K_iters=2)
        shapes = jax.eval_shape(
            lambda k: api.binarize_model_params(
                cfg, api.init_params(cfg, k), qc=qc),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        specs = shr.param_pspecs(cfg.replace(quant=qc), shapes, _mesh())
        bp = specs["layers"]["attn"]["wq"]["B_packed"]
        # [L, M, K/8, N]: packed-K FSDP, out-dim TP
        assert bp == P(None, None, ("data",), "model"), bp


class TestCacheSpecs:
    def test_decode_batch_and_heads(self):
        cfg = cb.get_config("codeqwen15_7b")  # kv=32 divides 16
        batch = cb.input_specs(cfg, "decode_32k")
        specs = shr.batch_pspecs(cfg, batch, _mesh())
        k_spec = specs["cache"]["layers"]["k"]
        # [L, B, S, kv, hd]: batch on dp, kv heads on model
        assert k_spec == P(None, ("data",), None, "model", None), k_spec

    def test_kv_seq_shard_for_mqa(self):
        cfg = cb.get_config("gemma_2b").replace(kv_seq_shard=True)
        batch = cb.input_specs(cfg, "decode_32k")
        specs = shr.batch_pspecs(cfg, batch, _mesh())
        k_spec = specs["cache"]["layers"]["k"]
        # [L, B, S, kv=1, hd]: seq (largest) dim on model
        assert k_spec[2] == "model", k_spec

    def test_head_dim_fallback_without_seq_shard(self):
        cfg = cb.get_config("gemma_2b")
        batch = cb.input_specs(cfg, "decode_32k")
        specs = shr.batch_pspecs(cfg, batch, _mesh())
        k_spec = specs["cache"]["layers"]["k"]
        assert k_spec[-1] == "model", k_spec


class TestActivationRules:
    def test_divisibility_guard(self):
        from repro.models import common as cm

        cm.set_axis_rules({"heads": "model", "batch": ("data",)},
                          {"data": 16, "model": 16})
        try:
            # 8 heads % 16 != 0 -> constraint silently dropped (no error)
            x = jnp.zeros((16, 4, 8, 32))
            # note: outside jit/mesh this is a no-op path check only
            spec_ok = True
        finally:
            cm.set_axis_rules(None)
        assert spec_ok
