"""Retrace detection: repeated identical traffic must not grow compile
caches.

Two surfaces hold per-variant compiled functions:
  * ``deploy.executor`` — one jitted execute per distinct resolved
    ``m_active`` schedule (the trace-entry counter is the proof hook);
  * ``launch.serve.Server`` — per-``m_active`` decode/prefill closures plus
    the bucketed-prefill length cache.

Each test runs the same traffic three times and asserts the variant count
after round one never grows again.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import deploy
from repro.analysis import trace_lint
from repro.configs import base as cb
from repro.core.binlinear import QuantConfig
from repro.deploy import executor
from repro.launch.serve import Request, Server
from repro.models import api, cnn

jax.config.update("jax_platform_name", "cpu")

QC = QuantConfig(mode="binary", M=2, K_iters=2, interpret=True)


class TestExecutorRetrace:
    def test_repeated_schedules_hold_bounded_variants(self):
        params = cnn.init_cnn_a(jax.random.PRNGKey(0))
        # B=4 is unique to this test -> the first round really traces
        prog = deploy.compile(cnn.binarize_cnn_a(params, QC), "cnn_a", QC,
                              (4, 48, 48, 3))
        x = jnp.ones((4, 48, 48, 3), jnp.float32)
        schedules = (None, 1, (1, 2, 1, 2, 1))
        distinct = len({prog.resolve_schedule(m) for m in schedules})
        assert distinct == 3
        c0 = executor.trace_entry_count()
        for m in schedules:   # warm round: one trace per distinct schedule
            jax.block_until_ready(deploy.execute(prog, x, m))
        warm = executor.trace_entry_count() - c0
        assert 1 <= warm <= distinct
        for _ in range(3):    # identical traffic: zero new traces
            for m in schedules:
                jax.block_until_ready(deploy.execute(prog, x, m))
        assert executor.trace_entry_count() - c0 == warm

    def test_retrace_findings_clean_on_repeated_traffic(self):
        params = cnn.init_cnn_a(jax.random.PRNGKey(1))
        prog = deploy.compile(cnn.binarize_cnn_a(params, QC), "cnn_a", QC,
                              (2, 48, 48, 3))
        x = jnp.ones((2, 48, 48, 3), jnp.float32)
        assert trace_lint.retrace_findings(
            prog, x, schedules=(None, 1), repeats=3, interpret=True) == []

    def test_clamped_schedules_share_one_variant(self):
        """m_active=2 and m_active=5 both clamp to every layer's M=2 — same
        resolved schedule, so the second must reuse the first's trace."""
        params = cnn.init_cnn_a(jax.random.PRNGKey(2))
        prog = deploy.compile(cnn.binarize_cnn_a(params, QC), "cnn_a", QC,
                              (2, 48, 48, 3))
        assert prog.resolve_schedule(2) == prog.resolve_schedule(5)
        x = jnp.ones((2, 48, 48, 3), jnp.float32)
        jax.block_until_ready(deploy.execute(prog, x, 2))
        c0 = executor.trace_entry_count()
        jax.block_until_ready(deploy.execute(prog, x, 5))
        assert executor.trace_entry_count() == c0


class TestServerRetrace:
    def _traffic(self, srv):
        # mixed lengths (prefix lens 2, 4, 6 -> pow2 buckets 2, 4, 8) x
        # mixed per-request m_active
        for n, m in ((3, None), (5, 1), (7, None), (5, 1)):
            req = Request(prompt=np.arange(1, n + 1, dtype=np.int32),
                          max_new_tokens=2, m_active=m)
            assert srv.admit(req)
            srv.run_until_done()

    def test_repeated_traffic_holds_bounded_compiled_variants(self):
        cfg = cb.reduced(cb.get_config("gemma_2b")).replace(dtype="float32")
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        srv = Server(cfg, params, max_batch=2, max_len=32)
        self._traffic(srv)
        decode_v = len(srv._decode_fns)
        prefill_v = len(srv._prefill_fns)
        lens_v = srv.stats["prefill_unique_lens"]
        assert decode_v <= 2          # m_active in {None, 1}
        assert prefill_v <= 2
        assert lens_v <= 3 * 2        # <= distinct (bucket, m) pairs
        for _ in range(3):            # 3x the same traffic: no growth
            self._traffic(srv)
        assert len(srv._decode_fns) == decode_v
        assert len(srv._prefill_fns) == prefill_v
        assert srv.stats["prefill_unique_lens"] == lens_v
        assert srv.stats["prefill_bucket_hits"] > 0

    def test_bucketed_prefill_reuses_lengths_across_rounds(self):
        cfg = cb.reduced(cb.get_config("gemma_2b")).replace(dtype="float32")
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        srv = Server(cfg, params, max_batch=2, max_len=32,
                     prefill_buckets="pow2")
        for _ in range(3):
            for n in (3, 5, 6):   # prefix lens 2, 4, 5 -> buckets 2, 4, 8
                req = Request(prompt=np.arange(1, n + 1, dtype=np.int32),
                              max_new_tokens=1)
                assert srv.admit(req)
                srv.run_until_done()
        assert srv.stats["prefill_unique_lens"] == 3
        assert srv.stats["prefill_bucket_hits"] == 3 * 3 - 3


class TestProgramScheduleStatic:
    def test_schedule_is_aux_data_not_a_leaf(self):
        """The plan/schedule must live in the treedef: two programs that
        differ only in a plan field get different treedefs (so jit keys on
        them), while reshaping weights alone keeps the treedef."""
        params = cnn.init_cnn_a(jax.random.PRNGKey(3))
        prog = deploy.compile(cnn.binarize_cnn_a(params, QC), "cnn_a", QC,
                              (2, 48, 48, 3))
        _, td1 = jax.tree_util.tree_flatten(prog)
        instrs = list(prog.instrs)
        instrs[0] = dataclasses.replace(
            instrs[0], plan=dataclasses.replace(instrs[0].plan, bu=1))
        prog2 = dataclasses.replace(prog, instrs=tuple(instrs))
        _, td2 = jax.tree_util.tree_flatten(prog2)
        assert td1 != td2
