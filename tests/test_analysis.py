"""Program verifier + trace lint (repro.analysis) — ISSUE 6 acceptance bar.

  * every shipped program (compiled CNN-A / small MobileNet, plus the three
    abstract benchmark programs) verifies with zero ERROR findings;
  * each seeded-illegal fixture — misaligned conv ``bd``, out-of-range
    ``bu``/``nb``, truncated packed weights, wrong level count — yields
    exactly its expected rule id;
  * hand-built (legal but non-canonical) TilePlans are detected: mutating a
    compiled plan raises the ``plan-noncanonical`` WARN (mutation check);
  * the trace lint proves the jitted execute trace has zero fp
    ``conv_general_dilated`` and zero trace-time plan picks, and its
    positive paths fire on the dense and legacy per-call forwards;
  * ``deploy.compile(..., verify=True)`` / ``assert_verified`` gate on
    ERRORs only.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import deploy
from repro.analysis import (ProgramVerificationError, assert_verified,
                            mosaic_rules, summarize, trace_lint,
                            verify_program)
from repro.core.binlinear import QuantConfig
from repro.kernels import binary_conv as bck
from repro.models import cnn

jax.config.update("jax_platform_name", "cpu")

QC = QuantConfig(mode="binary", M=2, K_iters=4, interpret=True)
FUSED = QC.replace(fuse_conv=True, use_pallas=True)


@pytest.fixture(scope="module")
def cnn_a():
    params = cnn.init_cnn_a(jax.random.PRNGKey(0))
    bp = cnn.binarize_cnn_a(params, QC)
    prog = deploy.compile(bp, "cnn_a", QC, (3, 48, 48, 3))
    return bp, prog


@pytest.fixture(scope="module")
def mobilenet_small():
    params = cnn.init_mobilenet(jax.random.PRNGKey(2), width_mult=0.25,
                                n_classes=10)
    qc = QC.replace(K_iters=2)
    bp = cnn.binarize_mobilenet(params, qc)
    prog = deploy.compile(bp, "mobilenet", qc, (2, 32, 32, 3))
    return bp, prog


def _errors(findings):
    return [f for f in findings if f.severity == mosaic_rules.ERROR]


def _tamper(prog, idx, **instr_changes):
    """Rebuild the program with one instruction's fields replaced."""
    instrs = list(prog.instrs)
    instrs[idx] = dataclasses.replace(instrs[idx], **instr_changes)
    return dataclasses.replace(prog, instrs=tuple(instrs))


class TestShippedProgramsClean:
    def test_compiled_cnn_a_zero_errors(self, cnn_a):
        _, prog = cnn_a
        findings = verify_program(prog)
        assert not _errors(findings), [str(f) for f in findings]

    def test_compiled_mobilenet_zero_errors(self, mobilenet_small):
        _, prog = mobilenet_small
        findings = verify_program(prog)
        assert not _errors(findings), [str(f) for f in findings]

    @pytest.mark.parametrize("arch,shape,kw", [
        ("cnn_a", (8, 48, 48, 3), {}),
        ("mobilenet", (8, 128, 128, 3), {"width_mult": 0.5}),
        ("mobilenet", (8, 224, 224, 3), {}),
    ])
    def test_abstract_benchmark_programs_zero_errors(self, arch, shape, kw):
        qc = QuantConfig(mode="binary", M=2, K_iters=1)
        prog = deploy.abstract_program(arch, qc, shape, **kw)
        findings = verify_program(prog)
        assert not _errors(findings), [str(f) for f in findings]

    def test_compile_verify_true_passes(self):
        params = cnn.init_cnn_a(jax.random.PRNGKey(0))
        prog = deploy.compile(params, "cnn_a", QC, (3, 48, 48, 3),
                              verify=True)
        assert len(prog) == 5


class TestSeededIllegalFixtures:
    """Each deliberately-illegal plan yields exactly its expected rule."""

    def test_misaligned_bd_fires_mosaic_lane(self, cnn_a):
        # conv2: D=150 -> Dp=192 under bd=96; 96 % 128 != 0 and 96 != 192,
        # so every D-blocked operand violates the lane rule
        _, prog = cnn_a
        plan = dataclasses.replace(prog.instrs[1].plan, bd=96)
        bad = _tamper(prog, 1, plan=plan)
        errs = _errors(verify_program(bad))
        assert errs and {f.rule for f in errs} == {"mosaic-lane"}, \
            [str(f) for f in errs]
        assert all(f.index == 1 for f in errs)

    def test_oversized_bu_fires_plan_range(self, cnn_a):
        _, prog = cnn_a
        plan = dataclasses.replace(prog.instrs[0].plan, bu=999)
        bad = _tamper(prog, 0, plan=plan)
        errs = _errors(verify_program(bad))
        assert {f.rule for f in errs} == {"plan-range"}, [str(f) for f in errs]

    def test_nb_beyond_batch_fires_plan_range(self, cnn_a):
        # conv2: clamped nb_e equals the compiled nb, so the range violation
        # is the only ERROR (conv1 at nb_e=3 would also blow the budget)
        _, prog = cnn_a
        plan = dataclasses.replace(prog.instrs[1].plan, nb=99)
        bad = _tamper(prog, 1, plan=plan)
        errs = _errors(verify_program(bad))
        assert {f.rule for f in errs} == {"plan-range"}, [str(f) for f in errs]

    def test_truncated_packed_weights_fire_pack_width(self, cnn_a):
        # fc1: K=1350 -> K8=169; chopping one packed row breaks ceil(K/8)
        _, prog = cnn_a
        fc = next(i for i, ins in enumerate(prog.instrs)
                  if ins.kind == "linear")
        bad = _tamper(prog, fc,
                      B_packed=prog.instrs[fc].B_packed[:, :-1, :])
        errs = _errors(verify_program(bad))
        assert any(f.rule == "pack-width" for f in errs), \
            [str(f) for f in errs]

    def test_wrong_level_count_fires_levels_mismatch(self, cnn_a):
        _, prog = cnn_a
        bad = _tamper(prog, 0, M=3)  # arrays still carry M=2
        errs = _errors(verify_program(bad))
        assert {f.rule for f in errs} == {"levels-mismatch"}, \
            [str(f) for f in errs]

    def test_tiny_budget_fires_vmem_budget(self, cnn_a):
        _, prog = cnn_a
        findings = verify_program(prog, vmem_budget=1000)
        assert any(f.rule == "vmem-budget" for f in findings)
        # matmul working sets get no pick-floor exemption -> ERROR
        assert any(f.rule == "vmem-budget" for f in _errors(findings))

    def test_assert_verified_raises_on_error(self, cnn_a):
        _, prog = cnn_a
        plan = dataclasses.replace(prog.instrs[1].plan, bd=96)
        with pytest.raises(ProgramVerificationError, match="mosaic-lane"):
            assert_verified(_tamper(prog, 1, plan=plan))

    def test_warn_only_findings_do_not_raise(self, mobilenet_small):
        _, prog = mobilenet_small
        findings = assert_verified(prog)   # returns WARNs, raises on ERRORs
        assert not _errors(findings)


class TestHandBuiltPlanMutation:
    def test_mutated_bu_detected_as_noncanonical(self, cnn_a):
        """Mutation check: the compiled plan verifies clean; sweeping bu over
        its legal range must flag at least one hand-built variant (and the
        canonical pick itself never flags)."""
        _, prog = cnn_a
        conv = prog.instrs[0]
        base_rules = {f.rule for f in verify_program(prog)}
        assert "plan-noncanonical" not in base_rules
        flagged = 0
        # sweep below the compiled bu: same nb, smaller working set, so
        # every variant stays budget- and Mosaic-legal
        for bu in range(1, conv.plan.bu + 1):
            plan = dataclasses.replace(conv.plan, bu=bu)
            findings = verify_program(_tamper(prog, 0, plan=plan))
            assert not _errors(findings), [str(f) for f in findings]
            if any(f.rule == "plan-noncanonical" and f.index == 0
                   for f in findings):
                flagged += 1
            elif bu != conv.plan.bu:
                # a non-compiled bu may legitimately match another pick
                # variant (m- or nb-biased); the canonical one never flags
                pass
        assert flagged > 0, \
            f"no bu in 1..{conv.plan.bu} flagged as hand-built"

    def test_verification_never_counts_as_plan_pick(self, cnn_a):
        _, prog = cnn_a
        before = bck.plan_pick_count()
        verify_program(prog)
        assert bck.plan_pick_count() == before


class TestTraceLint:
    def test_execute_trace_is_clean(self, cnn_a):
        _, prog = cnn_a
        assert trace_lint.lint_execute(prog, interpret=True) == []

    def test_abstract_program_lints_without_executing(self):
        qc = QuantConfig(mode="binary", M=2, K_iters=1)
        prog = deploy.abstract_program("cnn_a", qc, (8, 48, 48, 3))
        assert trace_lint.lint_execute(prog, interpret=True) == []

    def test_fp_conv_reference_fires_trace_fp_conv(self, mobilenet_small):
        """The dw reference kernel lowers through lax.conv_general_dilated —
        a full-binary trace containing it must be flagged."""
        from repro.kernels import ref
        _, prog = mobilenet_small
        dw = next(i for i in prog.instrs if i.kind == "dwconv")
        x = jax.ShapeDtypeStruct((2,) + tuple(dw.stats.in_shape[1:]),
                                 "float32")
        findings = trace_lint.lint_fn(
            lambda xx: ref.binary_dwconv_relu_ref(
                xx, dw.B_tap_packed, dw.alpha, bias=dw.bias, kh=dw.kh,
                kw=dw.kw, stride=dw.stride, padding="SAME"), (x,),
            label="ref-dw")
        assert any(f.rule == "trace-fp-conv" for f in findings), \
            [str(f) for f in findings]

    def test_legacy_fused_forward_fires_trace_plan_pick(self, cnn_a):
        bp, _ = cnn_a
        x = jax.ShapeDtypeStruct((3, 48, 48, 3), "float32")
        before = bck.plan_pick_count()
        # close over the params: the legacy tree mixes static ints (kh, kw)
        # with array leaves and cannot be traced as an argument
        findings = trace_lint.lint_fn(
            lambda xx: cnn.cnn_a_forward(bp, xx, FUSED), (x,),
            label="legacy")
        assert any(f.rule == "trace-plan-pick" for f in findings), \
            [str(f) for f in findings]
        # the lint snapshots/restores the counter: no gate poisoning
        assert bck.plan_pick_count() == before

    def test_summarize_rolls_up_by_rule(self, cnn_a):
        _, prog = cnn_a
        plan = dataclasses.replace(prog.instrs[0].plan, nb=99)
        findings = verify_program(_tamper(prog, 0, plan=plan))
        summ = summarize(findings)
        assert summ["errors"] >= 1
        assert summ["by_rule"].get("plan-range", 0) >= 1


class TestExecuteStillBitExact:
    def test_legalized_matmul_plans_keep_logits_exact(self, cnn_a):
        """pick_matmul_plan's lane legalization (bn/bk snapped to single
        lane-legal blocks) must not change numerics vs the legacy path."""
        bp, prog = cnn_a
        import numpy as np
        x = jax.random.normal(jax.random.PRNGKey(1), (3, 48, 48, 3),
                              jnp.float32)
        want = cnn.cnn_a_forward(bp, x, FUSED)
        got = deploy.execute(prog, x)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
