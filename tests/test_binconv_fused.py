"""Fused implicit-GEMM binary-conv kernel vs the jnp conv oracle, plus the
conv-path bugfix regressions (im2col SAME parity, odd-group-size blocks)
and the spatial row-tiling tier (halo slabs, pick_bu, tiled bit-exactness).

Mirrors the paper's §V-A2 verification style: the Pallas kernel (interpret
mode on CPU) must match kernels/ref.py to fp32-accumulation tolerance across
a shape sweep covering K % 8 != 0, m_active < M, stride 2, SAME/VALID, and
pool ∈ {1, 2}; row-tiled blocking must additionally be *bit-exact* against
whole-image blocking across stride/pool/ragged-tile combinations.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import binarize as bz
from repro.core import binconv
from repro.core.binlinear import QuantConfig
from repro.kernels import binary_conv as bck
from repro.kernels import ops as kops
from repro.kernels import ref as kref

jax.config.update("jax_platform_name", "cpu")


def _conv_case(seed, kh, kw, C, D, M, group_size=None):
    kx, kw_key, kb = jax.random.split(jax.random.PRNGKey(seed), 3)
    w = jax.random.normal(kw_key, (kh, kw, C, D), jnp.float32) * 0.2
    b = jax.random.normal(kb, (D,), jnp.float32)
    qc = QuantConfig(mode="binary", M=M, K_iters=6, group_size=group_size)
    return binconv.binarize_conv_params({"w": w, "b": b}, qc), kx


# kh, kw, C, D, H, W, M, stride, padding, pool, m_active
SWEEP = [
    (3, 3, 3, 16, 10, 10, 2, 1, "VALID", 1, None),   # C%8!=0: K=27 % 8 != 0
    (7, 7, 3, 5, 48, 48, 2, 1, "VALID", 2, None),    # CNN-A conv1 + pool
    (4, 4, 5, 24, 21, 21, 2, 1, "VALID", 2, None),   # even kernel, K=80
    (3, 3, 8, 12, 9, 9, 3, 1, "SAME", 1, 2),         # SAME + m_active < M
    (4, 4, 3, 10, 12, 12, 2, 2, "SAME", 1, None),    # even kernel SAME stride 2
    (5, 5, 4, 9, 11, 11, 3, 2, "VALID", 1, 1),       # stride 2 + m_active=1
    (1, 1, 16, 24, 8, 8, 2, 1, "VALID", 1, None),    # pointwise (MobileNet pw)
    (2, 2, 4, 7, 9, 9, 4, 2, "VALID", 2, None),      # stride 2 + pool 2
]


class TestFusedBinaryConvKernel:
    @pytest.mark.parametrize("kh,kw,C,D,H,W,M,stride,padding,pool,m_active",
                             SWEEP)
    def test_matches_conv_oracle(self, kh, kw, C, D, H, W, M, stride, padding,
                                 pool, m_active):
        p, kx = _conv_case(kh * 100 + kw * 10 + C, kh, kw, C, D, M)
        x = jax.random.normal(kx, (2, H, W, C), jnp.float32)
        got = kops.binary_conv2d(
            x, p["B_tap_packed"], p["alpha"], p["b"], kh=kh, kw=kw,
            stride=stride, padding=padding, pool=pool, m_active=m_active,
            interpret=True)
        want = kref.fused_binary_conv_relu_pool_ref(
            x, p["B_packed"], p["alpha"], kh=kh, kw=kw, stride=stride,
            padding=padding, pool=pool, m_active=m_active, bias=p["b"])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_grouped_alpha_odd_group_size(self):
        """Grouped alpha whose group size is not a multiple of 8."""
        p, kx = _conv_case(42, 3, 3, 6, 8, 2, group_size=27)  # K=54, G=2
        x = jax.random.normal(kx, (2, 8, 8, 6), jnp.float32)
        got = kops.binary_conv2d(x, p["B_tap_packed"], p["alpha"], p["b"],
                                 kh=3, kw=3, interpret=True)
        want = kref.fused_binary_conv_relu_pool_ref(
            x, p["B_packed"], p["alpha"], kh=3, kw=3, bias=p["b"])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_repack_taps_matches_direct_packing(self):
        """repack_taps (flat -> per-tap) agrees with binarize_conv_params."""
        p, _ = _conv_case(7, 3, 3, 5, 12, 2)
        via_repack = bck.repack_taps(p["B_packed"], 3, 3, 5)
        np.testing.assert_array_equal(np.asarray(via_repack),
                                      np.asarray(p["B_tap_packed"]))

    def test_legacy_packed_tree_warns_once_and_matches(self):
        """A tree without B_tap_packed still runs fused (warn-once repack);
        ensure_tap_packed upgrades it to the silent fast path."""
        p, kx = _conv_case(13, 3, 3, 5, 12, 2)
        legacy = {k: v for k, v in p.items() if k != "B_tap_packed"}
        x = jax.random.normal(kx, (1, 8, 8, 5), jnp.float32)
        qc = QuantConfig(mode="binary", M=2, fuse_conv=True, use_pallas=True,
                         interpret=True)
        binconv._warned_legacy_repack = False
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            y_legacy = binconv.conv2d_relu_pool(legacy, x, quant=qc)
            binconv.conv2d_relu_pool(legacy, x, quant=qc)  # second: silent
        runtime = [r for r in rec if issubclass(r.category, RuntimeWarning)
                   and "ensure_tap_packed" in str(r.message)]
        assert len(runtime) == 1, [str(r.message) for r in rec]
        upgraded = binconv.ensure_tap_packed(legacy, C=5)
        np.testing.assert_array_equal(np.asarray(upgraded["B_tap_packed"]),
                                      np.asarray(p["B_tap_packed"]))
        with warnings.catch_warnings():
            warnings.simplefilter("error")  # no warning on the upgraded tree
            y_up = binconv.conv2d_relu_pool(upgraded, x, quant=qc)
        np.testing.assert_array_equal(np.asarray(y_legacy), np.asarray(y_up))

    def test_conv2d_relu_pool_routes_fused(self):
        """Model-layer routing: fused flag on == fused flag off (unfused)."""
        p, kx = _conv_case(11, 4, 4, 5, 20, 2)
        x = jax.random.normal(kx, (2, 12, 12, 5), jnp.float32)
        qc = QuantConfig(mode="binary", M=2)
        unfused = binconv.conv2d_relu_pool(p, x, pool=3, quant=qc)
        fused = binconv.conv2d_relu_pool(
            p, x, pool=3,
            quant=qc.replace(fuse_conv=True, use_pallas=True, interpret=True))
        np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                                   rtol=1e-4, atol=1e-4)

    def test_cnn_a_fused_end_to_end(self):
        """Whole CNN-A deployment forward: fused conv path == im2col path."""
        from repro.models import cnn

        params = cnn.init_cnn_a(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 48, 48, 3),
                              jnp.float32)
        qc = QuantConfig(mode="binary", M=2, K_iters=4)
        bp = cnn.binarize_cnn_a(params, qc)
        ref_logits = cnn.cnn_a_forward(bp, x, qc)
        fused_logits = cnn.cnn_a_forward(
            bp, x, qc.replace(fuse_conv=True, use_pallas=True, interpret=True))
        np.testing.assert_allclose(np.asarray(fused_logits),
                                   np.asarray(ref_logits),
                                   rtol=2e-3, atol=2e-3)


class TestIm2colSamePadding:
    """im2col's SAME padding must match jax.lax.conv (asymmetric for even
    kernels — the seed padded kh//2 on both sides, shifting even-kernel
    convs like CNN-A's 4x4 conv2 by half a pixel and changing the shape)."""

    @pytest.mark.parametrize("kh,kw,stride", [
        (3, 3, 1), (4, 4, 1), (4, 4, 2), (2, 2, 2), (5, 5, 2), (7, 7, 1),
        (2, 3, 1),
    ])
    def test_same_parity_vs_lax_conv(self, kh, kw, stride):
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 9, 11, 3),
                              jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(3), (kh, kw, 3, 5),
                              jnp.float32)
        patches = binconv.im2col(x, kh, kw, stride, "SAME")
        got = patches.reshape(*patches.shape[:3], -1) @ w.reshape(-1, 5)
        want = jax.lax.conv_general_dilated(
            x, w, (stride, stride), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        assert got.shape == want.shape
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("kh,kw,stride", [(3, 3, 1), (4, 4, 2)])
    def test_valid_parity_vs_lax_conv(self, kh, kw, stride):
        x = jax.random.normal(jax.random.PRNGKey(4), (1, 10, 10, 2),
                              jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(5), (kh, kw, 2, 4),
                              jnp.float32)
        patches = binconv.im2col(x, kh, kw, stride, "VALID")
        got = patches.reshape(*patches.shape[:3], -1) @ w.reshape(-1, 4)
        want = jax.lax.conv_general_dilated(
            x, w, (stride, stride), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


class TestOddGroupSizeMatmul:
    """_pick_block regression: group sizes with no multiple-of-8 divisor used
    to trip the kernel's ``group_size % bk`` assert; now they take the
    single-K-block grouped-alpha path."""

    @pytest.mark.parametrize("K,group_size,M", [(48, 12, 2), (30, 15, 3),
                                                (72, 36, 2)])
    def test_pallas_matches_ref(self, K, group_size, M):
        kx, kw = jax.random.split(jax.random.PRNGKey(K + group_size))
        x = jax.random.normal(kx, (16, K), jnp.float32)
        W = jax.random.normal(kw, (K, 24), jnp.float32)
        approx = bz.algorithm2(W, M=M, K_iters=8, group_size=group_size)
        if K % 8:
            pad = (-K) % 8
            B = jnp.concatenate(
                [approx.B, jnp.ones((M, pad, 24), jnp.int8)], axis=1)
        else:
            B = approx.B
        packed = bz.pack_bits(B)
        got = kops.binary_matmul(x, packed, approx.alpha, K=K,
                                 group_size=group_size, interpret=True)
        want = kref.binary_matmul_ref(x, packed, approx.alpha, K=K,
                                      group_size=group_size)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)

    def test_odd_group_with_m_active(self):
        kx, kw = jax.random.split(jax.random.PRNGKey(9))
        x = jax.random.normal(kx, (8, 48), jnp.float32)
        W = jax.random.normal(kw, (48, 16), jnp.float32)
        approx = bz.algorithm2(W, M=3, K_iters=8, group_size=12)
        packed = bz.pack_bits(approx.B)
        got = kops.binary_matmul(x, packed, approx.alpha, K=48, group_size=12,
                                 m_active=2, interpret=True)
        want = kref.binary_matmul_ref(x, packed, approx.alpha, K=48,
                                      group_size=12, m_active=2)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)


class TestRowTiledBlocking:
    """Spatial row tiling of the fused conv kernel: BU-row output tiles with
    halo input slabs must be *bit-exact* against whole-image blocking (the
    BU = Uo special case) — each output element runs the identical K
    reduction and level order in every tiling."""

    # kh, kw, C, D, H, W, stride, pool, bu  (bu chosen to force ragged tiles
    # for most cases: Uo % bu != 0)
    TILED = [
        (3, 3, 3, 16, 13, 11, 1, 1, 3),    # C%8!=0, ragged: Uo=11, 4 tiles
        (7, 7, 3, 5, 48, 48, 1, 2, 4),     # CNN-A conv1, pool 2, Uo=21 ragged
        (4, 4, 5, 24, 21, 21, 1, 6, 1),    # pool 6, one pooled row per tile
        (4, 4, 5, 24, 21, 21, 2, 1, 2),    # stride 2, Uo=9 ragged
        (2, 2, 4, 7, 9, 9, 2, 2, 1),       # stride 2 + pool 2
        (3, 3, 8, 12, 9, 9, 1, 1, 5),      # odd U=7 not divisible by bu
        (1, 1, 16, 24, 8, 8, 1, 1, 3),     # point-wise, ragged
    ]

    @pytest.mark.parametrize("kh,kw,C,D,H,W,stride,pool,bu", TILED)
    def test_tiled_bit_exact_vs_whole_image(self, kh, kw, C, D, H, W, stride,
                                            pool, bu):
        p, kx = _conv_case(kh + kw + C + bu, kh, kw, C, D, 2)
        x = jax.random.normal(kx, (2, H, W, C), jnp.float32)
        gs = kh * kw * C // p["alpha"].shape[1]
        kw_args = dict(kh=kh, kw=kw, stride=stride, pool=pool, group_size=gs,
                       interpret=True)
        whole = bck.binary_conv2d_pallas(
            x, p["B_tap_packed"], p["alpha"], p["b"], bu=10**6, **kw_args)
        tiled = bck.binary_conv2d_pallas(
            x, p["B_tap_packed"], p["alpha"], p["b"], bu=bu, **kw_args)
        np.testing.assert_array_equal(np.asarray(whole), np.asarray(tiled))

    @pytest.mark.parametrize("kh,kw,C,D,H,W,stride,pool,bu", TILED[:3])
    def test_tiled_matches_oracle(self, kh, kw, C, D, H, W, stride, pool, bu):
        """Tiled blocking through the public wrapper still matches the
        HBM-materialized im2col oracle."""
        p, kx = _conv_case(kh * 10 + bu, kh, kw, C, D, 2)
        x = jax.random.normal(kx, (2, H, W, C), jnp.float32)
        got = kops.binary_conv2d(
            x, p["B_tap_packed"], p["alpha"], p["b"], kh=kh, kw=kw,
            stride=stride, pool=pool, bu=bu, interpret=True)
        want = kref.fused_binary_conv_relu_pool_ref(
            x, p["B_packed"], p["alpha"], kh=kh, kw=kw, stride=stride,
            pool=pool, bias=p["b"])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_pick_bu_respects_budget_and_recovers_whole_image(self):
        # small map: whole image fits the default budget
        assert bck.pick_bu(48, 48, 3, 7, 7, 8) == 42  # CNN-A conv1, Uo=U=42
        # MobileNet-224 early point-wise: whole image exceeds 8 MiB, the
        # picked tile fits, and the floor is 1
        bu = bck.pick_bu(112, 112, 32, 1, 1, 64, 1, m=2)
        uo = 112
        whole = bck.tile_vmem_bytes(112, 32, 1, 1, 64, bu=uo, m=2)
        tiled = bck.tile_vmem_bytes(112, 32, 1, 1, 64, bu=bu, m=2)
        assert whole > bck.DEFAULT_VMEM_BUDGET
        assert tiled <= bck.DEFAULT_VMEM_BUDGET
        assert 1 <= bu < uo
        # tiny budget degrades to a single pooled row, never 0
        assert bck.pick_bu(112, 112, 32, 1, 1, 64, 1, 1024, m=2) == 1

    def test_auto_bu_engages_on_large_maps(self):
        """The wrapper's auto pick tiles a map that exceeds the budget and
        still matches a forced whole-image run (tolerance-free)."""
        p, kx = _conv_case(99, 1, 1, 16, 32, 2)
        x = jax.random.normal(kx, (1, 40, 40, 16), jnp.float32)
        gs = 16 // p["alpha"].shape[1]
        kw_args = dict(kh=1, kw=1, group_size=gs, interpret=True)
        auto = bck.binary_conv2d_pallas(
            x, p["B_tap_packed"], p["alpha"], p["b"],
            vmem_budget=64 * 1024, **kw_args)  # force tiling via tiny budget
        whole = bck.binary_conv2d_pallas(
            x, p["B_tap_packed"], p["alpha"], p["b"], bu=10**6, **kw_args)
        np.testing.assert_array_equal(np.asarray(auto), np.asarray(whole))
