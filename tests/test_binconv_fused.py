"""Fused implicit-GEMM binary-conv kernel vs the jnp conv oracle, plus the
conv-path bugfix regressions (im2col SAME parity, odd-group-size blocks),
the spatial row-tiling tier (halo slabs, pick_bu, tiled bit-exactness), and
the batch-tiling tier (NB images folded into the GEMM row dim, pick_tile).

Mirrors the paper's §V-A2 verification style: the Pallas kernel (interpret
mode on CPU) must match kernels/ref.py to fp32-accumulation tolerance across
a shape sweep covering K % 8 != 0, m_active < M, stride 2, SAME/VALID, and
pool ∈ {1, 2}; row-tiled and batch-tiled blocking must additionally be
*bit-exact* against per-image whole-image blocking across
stride/pool/ragged-tile/ragged-batch combinations (the kernel issues its
contraction in fixed MXU-row-sized passes precisely so that holds).
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import binarize as bz
from repro.core import binconv
from repro.core.binlinear import QuantConfig
from repro.kernels import binary_conv as bck
from repro.kernels import ops as kops
from repro.kernels import ref as kref

jax.config.update("jax_platform_name", "cpu")


def _conv_case(seed, kh, kw, C, D, M, group_size=None):
    kx, kw_key, kb = jax.random.split(jax.random.PRNGKey(seed), 3)
    w = jax.random.normal(kw_key, (kh, kw, C, D), jnp.float32) * 0.2
    b = jax.random.normal(kb, (D,), jnp.float32)
    qc = QuantConfig(mode="binary", M=M, K_iters=6, group_size=group_size)
    return binconv.binarize_conv_params({"w": w, "b": b}, qc), kx


# kh, kw, C, D, H, W, M, stride, padding, pool, m_active
SWEEP = [
    (3, 3, 3, 16, 10, 10, 2, 1, "VALID", 1, None),   # C%8!=0: K=27 % 8 != 0
    (7, 7, 3, 5, 48, 48, 2, 1, "VALID", 2, None),    # CNN-A conv1 + pool
    (4, 4, 5, 24, 21, 21, 2, 1, "VALID", 2, None),   # even kernel, K=80
    (3, 3, 8, 12, 9, 9, 3, 1, "SAME", 1, 2),         # SAME + m_active < M
    (4, 4, 3, 10, 12, 12, 2, 2, "SAME", 1, None),    # even kernel SAME stride 2
    (5, 5, 4, 9, 11, 11, 3, 2, "VALID", 1, 1),       # stride 2 + m_active=1
    (1, 1, 16, 24, 8, 8, 2, 1, "VALID", 1, None),    # pointwise (MobileNet pw)
    (2, 2, 4, 7, 9, 9, 4, 2, "VALID", 2, None),      # stride 2 + pool 2
]


class TestFusedBinaryConvKernel:
    @pytest.mark.parametrize("kh,kw,C,D,H,W,M,stride,padding,pool,m_active",
                             SWEEP)
    def test_matches_conv_oracle(self, kh, kw, C, D, H, W, M, stride, padding,
                                 pool, m_active):
        p, kx = _conv_case(kh * 100 + kw * 10 + C, kh, kw, C, D, M)
        x = jax.random.normal(kx, (2, H, W, C), jnp.float32)
        got = kops.binary_conv2d(
            x, p["B_tap_packed"], p["alpha"], p["b"], kh=kh, kw=kw,
            stride=stride, padding=padding, pool=pool, m_active=m_active,
            interpret=True)
        want = kref.fused_binary_conv_relu_pool_ref(
            x, p["B_packed"], p["alpha"], kh=kh, kw=kw, stride=stride,
            padding=padding, pool=pool, m_active=m_active, bias=p["b"])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_grouped_alpha_odd_group_size(self):
        """Grouped alpha whose group size is not a multiple of 8."""
        p, kx = _conv_case(42, 3, 3, 6, 8, 2, group_size=27)  # K=54, G=2
        x = jax.random.normal(kx, (2, 8, 8, 6), jnp.float32)
        got = kops.binary_conv2d(x, p["B_tap_packed"], p["alpha"], p["b"],
                                 kh=3, kw=3, interpret=True)
        want = kref.fused_binary_conv_relu_pool_ref(
            x, p["B_packed"], p["alpha"], kh=3, kw=3, bias=p["b"])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_repack_taps_matches_direct_packing(self):
        """repack_taps (flat -> per-tap) agrees with binarize_conv_params."""
        p, _ = _conv_case(7, 3, 3, 5, 12, 2)
        via_repack = bck.repack_taps(p["B_packed"], 3, 3, 5)
        np.testing.assert_array_equal(np.asarray(via_repack),
                                      np.asarray(p["B_tap_packed"]))

    def test_legacy_packed_tree_deprecation_warns_every_call(self):
        """The retired per-call repack path: a tree without B_tap_packed
        still runs fused but raises a hard DeprecationWarning on EVERY call;
        ensure_tap_packed upgrades it to the silent fast path (the deploy
        compiler does the same, so compiled programs never hit this)."""
        p, kx = _conv_case(13, 3, 3, 5, 12, 2)
        legacy = {k: v for k, v in p.items() if k != "B_tap_packed"}
        x = jax.random.normal(kx, (1, 8, 8, 5), jnp.float32)
        qc = QuantConfig(mode="binary", M=2, fuse_conv=True, use_pallas=True,
                         interpret=True)
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            y_legacy = binconv.conv2d_relu_pool(legacy, x, quant=qc)
            binconv.conv2d_relu_pool(legacy, x, quant=qc)
        dep = [r for r in rec if issubclass(r.category, DeprecationWarning)
               and "ensure_tap_packed" in str(r.message)]
        assert len(dep) == 2, [str(r.message) for r in rec]  # not warn-once
        upgraded = binconv.ensure_tap_packed(legacy, C=5)
        np.testing.assert_array_equal(np.asarray(upgraded["B_tap_packed"]),
                                      np.asarray(p["B_tap_packed"]))
        with warnings.catch_warnings():
            warnings.simplefilter("error")  # no warning on the upgraded tree
            y_up = binconv.conv2d_relu_pool(upgraded, x, quant=qc)
        np.testing.assert_array_equal(np.asarray(y_legacy), np.asarray(y_up))

    def test_conv2d_relu_pool_routes_fused(self):
        """Model-layer routing: fused flag on == fused flag off (unfused)."""
        p, kx = _conv_case(11, 4, 4, 5, 20, 2)
        x = jax.random.normal(kx, (2, 12, 12, 5), jnp.float32)
        qc = QuantConfig(mode="binary", M=2)
        unfused = binconv.conv2d_relu_pool(p, x, pool=3, quant=qc)
        fused = binconv.conv2d_relu_pool(
            p, x, pool=3,
            quant=qc.replace(fuse_conv=True, use_pallas=True, interpret=True))
        np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                                   rtol=1e-4, atol=1e-4)

    def test_cnn_a_fused_end_to_end(self):
        """Whole CNN-A deployment forward: fused conv path == im2col path."""
        from repro.models import cnn

        params = cnn.init_cnn_a(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 48, 48, 3),
                              jnp.float32)
        qc = QuantConfig(mode="binary", M=2, K_iters=4)
        bp = cnn.binarize_cnn_a(params, qc)
        ref_logits = cnn.cnn_a_forward(bp, x, qc)
        fused_logits = cnn.cnn_a_forward(
            bp, x, qc.replace(fuse_conv=True, use_pallas=True, interpret=True))
        np.testing.assert_allclose(np.asarray(fused_logits),
                                   np.asarray(ref_logits),
                                   rtol=2e-3, atol=2e-3)


class TestIm2colSamePadding:
    """im2col's SAME padding must match jax.lax.conv (asymmetric for even
    kernels — the seed padded kh//2 on both sides, shifting even-kernel
    convs like CNN-A's 4x4 conv2 by half a pixel and changing the shape)."""

    @pytest.mark.parametrize("kh,kw,stride", [
        (3, 3, 1), (4, 4, 1), (4, 4, 2), (2, 2, 2), (5, 5, 2), (7, 7, 1),
        (2, 3, 1),
    ])
    def test_same_parity_vs_lax_conv(self, kh, kw, stride):
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 9, 11, 3),
                              jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(3), (kh, kw, 3, 5),
                              jnp.float32)
        patches = binconv.im2col(x, kh, kw, stride, "SAME")
        got = patches.reshape(*patches.shape[:3], -1) @ w.reshape(-1, 5)
        want = jax.lax.conv_general_dilated(
            x, w, (stride, stride), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        assert got.shape == want.shape
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("kh,kw,stride", [(3, 3, 1), (4, 4, 2)])
    def test_valid_parity_vs_lax_conv(self, kh, kw, stride):
        x = jax.random.normal(jax.random.PRNGKey(4), (1, 10, 10, 2),
                              jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(5), (kh, kw, 2, 4),
                              jnp.float32)
        patches = binconv.im2col(x, kh, kw, stride, "VALID")
        got = patches.reshape(*patches.shape[:3], -1) @ w.reshape(-1, 4)
        want = jax.lax.conv_general_dilated(
            x, w, (stride, stride), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


class TestOddGroupSizeMatmul:
    """_pick_block regression: group sizes with no multiple-of-8 divisor used
    to trip the kernel's ``group_size % bk`` assert; now they take the
    single-K-block grouped-alpha path."""

    @pytest.mark.parametrize("K,group_size,M", [(48, 12, 2), (30, 15, 3),
                                                (72, 36, 2)])
    def test_pallas_matches_ref(self, K, group_size, M):
        kx, kw = jax.random.split(jax.random.PRNGKey(K + group_size))
        x = jax.random.normal(kx, (16, K), jnp.float32)
        W = jax.random.normal(kw, (K, 24), jnp.float32)
        approx = bz.algorithm2(W, M=M, K_iters=8, group_size=group_size)
        if K % 8:
            pad = (-K) % 8
            B = jnp.concatenate(
                [approx.B, jnp.ones((M, pad, 24), jnp.int8)], axis=1)
        else:
            B = approx.B
        packed = bz.pack_bits(B)
        got = kops.binary_matmul(x, packed, approx.alpha, K=K,
                                 group_size=group_size, interpret=True)
        want = kref.binary_matmul_ref(x, packed, approx.alpha, K=K,
                                      group_size=group_size)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)

    def test_odd_group_with_m_active(self):
        kx, kw = jax.random.split(jax.random.PRNGKey(9))
        x = jax.random.normal(kx, (8, 48), jnp.float32)
        W = jax.random.normal(kw, (48, 16), jnp.float32)
        approx = bz.algorithm2(W, M=3, K_iters=8, group_size=12)
        packed = bz.pack_bits(approx.B)
        got = kops.binary_matmul(x, packed, approx.alpha, K=48, group_size=12,
                                 m_active=2, interpret=True)
        want = kref.binary_matmul_ref(x, packed, approx.alpha, K=48,
                                      group_size=12, m_active=2)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)


class TestRowTiledBlocking:
    """Spatial row tiling of the fused conv kernel: BU-row output tiles with
    halo input slabs must be *bit-exact* against whole-image blocking (the
    BU = Uo special case) — each output element runs the identical K
    reduction and level order in every tiling."""

    # kh, kw, C, D, H, W, stride, pool, bu  (bu chosen to force ragged tiles
    # for most cases: Uo % bu != 0)
    TILED = [
        (3, 3, 3, 16, 13, 11, 1, 1, 3),    # C%8!=0, ragged: Uo=11, 4 tiles
        (7, 7, 3, 5, 48, 48, 1, 2, 4),     # CNN-A conv1, pool 2, Uo=21 ragged
        (4, 4, 5, 24, 21, 21, 1, 6, 1),    # pool 6, one pooled row per tile
        (4, 4, 5, 24, 21, 21, 2, 1, 2),    # stride 2, Uo=9 ragged
        (2, 2, 4, 7, 9, 9, 2, 2, 1),       # stride 2 + pool 2
        (3, 3, 8, 12, 9, 9, 1, 1, 5),      # odd U=7 not divisible by bu
        (1, 1, 16, 24, 8, 8, 1, 1, 3),     # point-wise, ragged
    ]

    @pytest.mark.parametrize("kh,kw,C,D,H,W,stride,pool,bu", TILED)
    def test_tiled_bit_exact_vs_whole_image(self, kh, kw, C, D, H, W, stride,
                                            pool, bu):
        p, kx = _conv_case(kh + kw + C + bu, kh, kw, C, D, 2)
        x = jax.random.normal(kx, (2, H, W, C), jnp.float32)
        gs = kh * kw * C // p["alpha"].shape[1]
        kw_args = dict(kh=kh, kw=kw, stride=stride, pool=pool, group_size=gs,
                       interpret=True)
        whole = bck.binary_conv2d_pallas(
            x, p["B_tap_packed"], p["alpha"], p["b"], bu=10**6, **kw_args)
        tiled = bck.binary_conv2d_pallas(
            x, p["B_tap_packed"], p["alpha"], p["b"], bu=bu, **kw_args)
        np.testing.assert_array_equal(np.asarray(whole), np.asarray(tiled))

    @pytest.mark.parametrize("kh,kw,C,D,H,W,stride,pool,bu", TILED[:3])
    def test_tiled_matches_oracle(self, kh, kw, C, D, H, W, stride, pool, bu):
        """Tiled blocking through the public wrapper still matches the
        HBM-materialized im2col oracle."""
        p, kx = _conv_case(kh * 10 + bu, kh, kw, C, D, 2)
        x = jax.random.normal(kx, (2, H, W, C), jnp.float32)
        got = kops.binary_conv2d(
            x, p["B_tap_packed"], p["alpha"], p["b"], kh=kh, kw=kw,
            stride=stride, pool=pool, bu=bu, interpret=True)
        want = kref.fused_binary_conv_relu_pool_ref(
            x, p["B_packed"], p["alpha"], kh=kh, kw=kw, stride=stride,
            pool=pool, bias=p["b"])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_pick_bu_respects_budget_and_recovers_whole_image(self):
        # small map: whole image fits the default budget
        assert bck.pick_bu(48, 48, 3, 7, 7, 8) == 42  # CNN-A conv1, Uo=U=42
        # MobileNet-224 early point-wise: whole image exceeds 8 MiB, the
        # picked tile fits, and the floor is 1
        bu = bck.pick_bu(112, 112, 32, 1, 1, 64, 1, m=2)
        uo = 112
        whole = bck.tile_vmem_bytes(112, 32, 1, 1, 64, bu=uo, m=2)
        tiled = bck.tile_vmem_bytes(112, 32, 1, 1, 64, bu=bu, m=2)
        assert whole > bck.DEFAULT_VMEM_BUDGET
        assert tiled <= bck.DEFAULT_VMEM_BUDGET
        assert 1 <= bu < uo
        # tiny budget degrades to a single pooled row, never 0
        assert bck.pick_bu(112, 112, 32, 1, 1, 64, 1, 1024, m=2) == 1

    def test_auto_nb_bu_engage_on_small_maps(self):
        """With neither nb nor bu forced, pick_tile folds several images of
        a small map into one program — and the result is bit-exact vs the
        forced per-image whole-image run."""
        p, kx = _conv_case(77, 1, 1, 32, 48, 2)
        x = jax.random.normal(kx, (6, 7, 7, 32), jnp.float32)
        gs = 32 // p["alpha"].shape[1]
        kw_args = dict(kh=1, kw=1, group_size=gs, interpret=True)
        nb, bu = bck.pick_tile(6, 7, 7, 32, 1, 1, 48, m=2)
        assert nb > 1 and bu == 7, (nb, bu)
        auto = bck.binary_conv2d_pallas(
            x, p["B_tap_packed"], p["alpha"], p["b"], **kw_args)
        per_image = bck.binary_conv2d_pallas(
            x, p["B_tap_packed"], p["alpha"], p["b"], nb=1, bu=10**6,
            **kw_args)
        np.testing.assert_array_equal(np.asarray(auto), np.asarray(per_image))

    def test_auto_bu_engages_on_large_maps(self):
        """The wrapper's auto pick tiles a map that exceeds the budget and
        still matches a forced whole-image run (tolerance-free)."""
        p, kx = _conv_case(99, 1, 1, 16, 32, 2)
        x = jax.random.normal(kx, (1, 40, 40, 16), jnp.float32)
        gs = 16 // p["alpha"].shape[1]
        kw_args = dict(kh=1, kw=1, group_size=gs, interpret=True)
        auto = bck.binary_conv2d_pallas(
            x, p["B_tap_packed"], p["alpha"], p["b"],
            vmem_budget=64 * 1024, **kw_args)  # force tiling via tiny budget
        whole = bck.binary_conv2d_pallas(
            x, p["B_tap_packed"], p["alpha"], p["b"], bu=10**6, **kw_args)
        np.testing.assert_array_equal(np.asarray(auto), np.asarray(whole))


class TestBatchTiledBlocking:
    """Batch tiling (NB images folded into the implicit-GEMM row dim) must be
    *bit-exact* against the per-image kernel (nb=1, whole-image BU) for every
    (NB, BU) — the kernel issues its contraction in fixed MXU-row-sized
    passes so each output row's reduction is tiling-invariant — including
    ragged batches (B % NB != 0) padded with zero images."""

    # name -> (kh, kw, C, D, H, W, stride, pool, B)
    CASES = {
        "cnn_a_conv2": (4, 4, 5, 24, 21, 21, 1, 6, 3),
        "mnet_pw_7": (1, 1, 64, 32, 7, 7, 1, 1, 5),
        "mnet_pw_7_stride2": (1, 1, 16, 24, 7, 7, 2, 1, 3),
    }

    @pytest.mark.parametrize("case", sorted(CASES))
    @pytest.mark.parametrize("nb", [1, 2, "B"])
    def test_batched_bit_exact_vs_per_image(self, case, nb):
        kh, kw, C, D, H, W, stride, pool, B = self.CASES[case]
        nb = B if nb == "B" else nb  # nb=2 leaves every case's batch ragged
        p, kx = _conv_case(sum(self.CASES[case]), kh, kw, C, D, 2)
        x = jax.random.normal(kx, (B, H, W, C), jnp.float32)
        gs = kh * kw * C // p["alpha"].shape[1]
        kw_args = dict(kh=kh, kw=kw, stride=stride, pool=pool, group_size=gs,
                       interpret=True)
        per_image = bck.binary_conv2d_pallas(
            x, p["B_tap_packed"], p["alpha"], p["b"], nb=1, bu=10**6,
            **kw_args)
        batched = bck.binary_conv2d_pallas(
            x, p["B_tap_packed"], p["alpha"], p["b"], nb=nb, **kw_args)
        np.testing.assert_array_equal(np.asarray(per_image),
                                      np.asarray(batched))

    @pytest.mark.parametrize("nb,bu", [(2, 1), (3, 2), (5, 3)])
    def test_joint_nb_bu_bit_exact(self, nb, bu):
        """Batch and row tiling compose: ragged batch × ragged row tiles."""
        p, kx = _conv_case(nb * 10 + bu, 3, 3, 6, 16, 2)
        x = jax.random.normal(kx, (7, 9, 9, 6), jnp.float32)  # U=7, Uo=7
        gs = 54 // p["alpha"].shape[1]
        kw_args = dict(kh=3, kw=3, group_size=gs, interpret=True)
        per_image = bck.binary_conv2d_pallas(
            x, p["B_tap_packed"], p["alpha"], p["b"], nb=1, bu=10**6,
            **kw_args)
        tiled = bck.binary_conv2d_pallas(
            x, p["B_tap_packed"], p["alpha"], p["b"], nb=nb, bu=bu, **kw_args)
        np.testing.assert_array_equal(np.asarray(per_image),
                                      np.asarray(tiled))

    def test_batched_matches_oracle(self):
        """Batch tiling through the public wrapper still matches the
        HBM-materialized im2col oracle (ragged B=5, nb=2)."""
        p, kx = _conv_case(321, 1, 1, 24, 40, 2)
        x = jax.random.normal(kx, (5, 7, 7, 24), jnp.float32)
        got = kops.binary_conv2d(
            x, p["B_tap_packed"], p["alpha"], p["b"], kh=1, kw=1, nb=2,
            interpret=True)
        want = kref.fused_binary_conv_relu_pool_ref(
            x, p["B_packed"], p["alpha"], kh=1, kw=1, bias=p["b"])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_quant_config_threads_batch_tile(self):
        """conv_batch_tile/conv_vmem_budget reach the kernel through the
        model-layer routing and stay numerically equal to the default."""
        p, kx = _conv_case(11, 4, 4, 5, 20, 2)
        x = jax.random.normal(kx, (3, 12, 12, 5), jnp.float32)
        qc = QuantConfig(mode="binary", M=2, fuse_conv=True, use_pallas=True,
                         interpret=True)
        base = binconv.conv2d_relu_pool(p, x, pool=3, quant=qc)
        forced = binconv.conv2d_relu_pool(
            p, x, pool=3, quant=qc.replace(conv_batch_tile=2,
                                           conv_vmem_budget=2 * 2**20))
        np.testing.assert_array_equal(np.asarray(base), np.asarray(forced))

    def test_pick_tile_grows_nb_on_small_maps(self):
        """pw@7²: one image is 49 GEMM rows (38% of the 128-row MXU); the
        pick minimizes the batch's total padded rows — at B=128 that lands
        on NB=13 (637/640 rows per program)."""
        nb, bu = bck.pick_tile(128, 7, 7, 512, 1, 1, 128, m=2)
        assert (nb, bu) == (13, 7), (nb, bu)
        occ = bck.mxu_row_occupancy(bck.gemm_rows(nb, bu, 7))
        assert occ >= 0.95, occ
        assert bck.batch_row_utilization(128, nb, 49) >= 0.95
        assert bck.mxu_row_occupancy(bck.gemm_rows(1, 7, 7)) < 0.39
        # per-output weight-unpack work drops ~NB x
        gain = (bck.unpack_work_per_output(1, 7, 7, 512, m=2)
                / bck.unpack_work_per_output(nb, 7, 7, 512, m=2))
        assert gain == pytest.approx(nb)

    def test_pick_tile_charges_ragged_batch_padding(self):
        """The pick optimizes the whole batch, not one program: a batch of
        6 folds into a single 294-row program rather than NB=5 + a ragged
        program of 4 zero images, and a batch of exactly 16 becomes one
        784-row program."""
        assert bck.pick_tile(6, 7, 7, 512, 1, 1, 128, m=2) == (6, 7)
        assert bck.pick_tile(16, 7, 7, 512, 1, 1, 128, m=2) == (16, 7)
        assert (bck.batch_padded_rows(6, 6, 49)
                < bck.batch_padded_rows(6, 5, 49))

    def test_pick_tile_keeps_nb1_on_large_maps(self):
        """112² stem-scale maps: the row slab already fills the MXU and VMEM
        binds, so the pick row-tiles with NB=1."""
        nb, bu = bck.pick_tile(8, 112, 112, 32, 1, 1, 64, m=2)
        assert nb == 1 and 1 <= bu < 112, (nb, bu)
        # batch cap: never folds more images than the batch holds
        nb, bu = bck.pick_tile(2, 7, 7, 512, 1, 1, 128, m=2)
        assert nb <= 2 and bu == 7, (nb, bu)
        # B=1 short-circuits to per-image
        assert bck.pick_tile(1, 7, 7, 512, 1, 1, 128, m=2) == (1, 7)

    def test_pick_tile_budget_binds_nb(self):
        """A tiny budget stops NB growth before occupancy saturates."""
        budget = bck.tile_vmem_bytes(7, 512, 1, 1, 128, bu=7, m=2, nb=2)
        nb, bu = bck.pick_tile(16, 7, 7, 512, 1, 1, 128, 1, budget, m=2)
        assert nb == 2 and bu == 7, (nb, bu)
