"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle.

Mirrors the paper's §V-A2 verification: VHDL (here: Pallas kernel) against a
bit-accurate Python model (here: kernels/ref.py), over shape/dtype sweeps.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import binarize as bz
from repro.kernels import ops as kops
from repro.kernels import ref as kref

jax.config.update("jax_platform_name", "cpu")


def _make_case(key, T, K, N, M, group_size=None):
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (T, K), jnp.float32)
    W = jax.random.normal(kw, (K, N), jnp.float32)
    approx = bz.algorithm2(W, M=M, K_iters=10, group_size=group_size)
    packed = bz.pack(approx)
    return x, W, packed


SHAPES = [
    # T, K, N, M      — deliberately non-multiples of the 128 blocks
    (4, 8, 8, 1),
    (16, 32, 24, 2),
    (128, 128, 128, 2),
    (64, 200, 96, 3),     # K not multiple of 8 -> padding path
    (1, 512, 256, 4),     # decode-like GEMV row
    (256, 64, 16, 2),
]


class TestBinaryMatmulKernel:
    @pytest.mark.parametrize("T,K,N,M", SHAPES)
    def test_matches_ref(self, T, K, N, M):
        x, W, packed = _make_case(jax.random.PRNGKey(T * K + N + M), T, K, N, M)
        got = kops.binary_matmul(
            x, packed.B_packed, packed.alpha, K=K,
            group_size=packed.group_size, interpret=True,
        )
        want = kref.binary_matmul_ref(
            x, packed.B_packed, packed.alpha, K=K, group_size=packed.group_size
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        x, W, packed = _make_case(jax.random.PRNGKey(0), 32, 64, 48, 2)
        x = x.astype(dtype)
        got = kops.binary_matmul(
            x, packed.B_packed, packed.alpha, K=64,
            group_size=packed.group_size, interpret=True,
        )
        want = kref.binary_matmul_ref(
            x, packed.B_packed, packed.alpha, K=64, group_size=packed.group_size
        )
        tol = 1e-4 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want), rtol=tol, atol=tol
        )

    def test_groupwise_alpha(self):
        """Group-aligned K tiling: group_size 64, bk forced to divide it."""
        T, K, N, M = 16, 256, 32, 2
        x, W, packed = _make_case(jax.random.PRNGKey(5), T, K, N, M, group_size=64)
        got = kops.binary_matmul(
            x, packed.B_packed, packed.alpha, K=K, group_size=64, interpret=True,
        )
        want = kref.binary_matmul_ref(
            x, packed.B_packed, packed.alpha, K=K, group_size=64
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)

    @pytest.mark.parametrize("m_active", [1, 2, 3])
    def test_m_active_runtime_switch(self, m_active):
        """Paper §IV-D: throughput mode uses fewer levels on same buffers."""
        x, W, packed = _make_case(jax.random.PRNGKey(9), 8, 64, 16, 3)
        got = kops.binary_matmul(
            x, packed.B_packed, packed.alpha, K=64,
            group_size=packed.group_size, m_active=m_active, interpret=True,
        )
        want = kref.binary_matmul_ref(
            x, packed.B_packed, packed.alpha, K=64,
            group_size=packed.group_size, m_active=m_active,
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)

    def test_accuracy_improves_with_m_active(self):
        """More levels -> closer to the dense matmul (paper Table II trend)."""
        x, W, packed = _make_case(jax.random.PRNGKey(11), 32, 128, 32, 4)
        dense = np.asarray(x @ W)
        errs = []
        for m in (1, 2, 3, 4):
            y = np.asarray(kref.binary_matmul_ref(
                x, packed.B_packed, packed.alpha, K=128,
                group_size=packed.group_size, m_active=m))
            errs.append(float(np.mean((y - dense) ** 2)))
        assert all(errs[i + 1] <= errs[i] + 1e-6 for i in range(3)), errs

    def test_ref_equals_dense_reconstruction(self):
        """Oracle self-consistency: Eq. 8 factored form == x @ W_hat."""
        x, W, packed = _make_case(jax.random.PRNGKey(13), 16, 64, 8, 3)
        approx = bz.unpack(packed)
        via_ref = kref.binary_matmul_ref(
            x, packed.B_packed, packed.alpha, K=64, group_size=packed.group_size
        )
        via_dense = kref.binary_matmul_dense_equiv(x, approx)
        np.testing.assert_allclose(np.asarray(via_ref), np.asarray(via_dense),
                                   rtol=1e-5, atol=1e-5)


class TestFusedEpilogue:
    def test_relu_pool_commutativity(self):
        """AMU claim (paper Eq. 13): max-pool then ReLU == ReLU then max-pool."""
        x, W, packed = _make_case(jax.random.PRNGKey(17), 32, 64, 16, 2)
        y = kref.binary_matmul_ref(x, packed.B_packed, packed.alpha, K=64,
                                   group_size=packed.group_size)
        fused = kref.fused_binary_matmul_relu_pool_ref(
            x, packed.B_packed, packed.alpha, K=64,
            group_size=packed.group_size, pool=4)
        manual = np.maximum(np.asarray(y), 0).reshape(8, 4, 16).max(axis=1)
        np.testing.assert_allclose(np.asarray(fused), manual, rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    T=st.integers(1, 40),
    K=st.sampled_from([8, 16, 40, 72]),
    N=st.integers(1, 40),
    M=st.integers(1, 3),
    seed=st.integers(0, 1000),
)
def test_property_kernel_matches_ref(T, K, N, M, seed):
    x, W, packed = _make_case(jax.random.PRNGKey(seed), T, K, N, M)
    got = kops.binary_matmul(x, packed.B_packed, packed.alpha, K=K,
                             group_size=packed.group_size, interpret=True)
    want = kref.binary_matmul_ref(x, packed.B_packed, packed.alpha, K=K,
                                  group_size=packed.group_size)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)
