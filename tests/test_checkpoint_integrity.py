"""End-to-end program integrity: checksummed checkpoints, last-known-good
recovery, golden self-test (BIST), and service hot-reload.

Fault matrix (docs/checkpointing.md): every corruption class the stack
defends against is seeded here by ``repro.testing.faults`` and asserted to
be (a) detected with a typed error naming the damage and (b) recovered
from via quarantine + latest-good fallback or service hot-reload — with
bit-exact answers afterwards and every fault accounted for in a ledger.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import deploy
from repro.checkpoint.manager import (CheckpointCorruption, CheckpointManager,
                                      ChecksumMismatch, LeafMismatch,
                                      ManifestMismatch, NoGoodCheckpoint,
                                      crc32_hex)
from repro.testing.faults import FaultInjector, FaultPlan, ManualClock
from repro.testing.scenarios import tiny_cnn_program

jax.config.update("jax_platform_name", "cpu")


def _state():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones(4, jnp.float32)},
            "step": jnp.int32(3)}


def _injector():
    return FaultInjector(FaultPlan(seed=11))


@pytest.fixture(scope="module")
def program():
    return tiny_cnn_program(batch=2)


# ---------------------------------------------------------------------------
# checkpoint layer: digests, typed detection, quarantine, latest-good walk
# ---------------------------------------------------------------------------

class TestChecksums:
    def test_manifest_records_digests(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, _state())
        with open(tmp_path / "step_0000000001" / "manifest.json") as f:
            meta = json.load(f)
        assert meta["manifest_crc32"]
        for key, info in meta["leaves"].items():
            assert set(info) == {"shape", "dtype", "crc32"}, key
            assert len(info["crc32"]) == 8
        # digest is of the bytes actually on disk (jnp default is float32)
        w = np.arange(12, dtype=np.float32).reshape(3, 4)
        assert meta["leaves"]["params/w"]["crc32"] == crc32_hex(w.tobytes())

    def test_scalar_leaf_shape_roundtrip(self, tmp_path):
        """0-d leaves must stay 0-d (np.ascontiguousarray promotes to (1,),
        which the strict shape check would then reject)."""
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, _state())
        restored, _ = mgr.restore(1, _state())
        assert np.shape(restored["step"]) == ()
        assert int(restored["step"]) == 3

    def test_disk_bitflip_detected_and_named(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, _state())
        leaf = _injector().flip_bit_on_disk(mgr._step_dir(1))
        with pytest.raises(ChecksumMismatch) as ei:
            mgr.restore(1, _state())
        err = ei.value
        assert err.leaf.replace("/", "__") == leaf
        assert err.step == 1 and err.expected != err.actual
        assert err.leaf in str(err)

    def test_manifest_tamper_detected(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, _state())
        _injector().tamper_manifest(mgr._step_dir(1))
        with pytest.raises(ManifestMismatch):
            mgr.restore(1, _state())

    def test_missing_npz_detected(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, _state())
        _injector().remove_npz(mgr._step_dir(1))
        with pytest.raises(CheckpointCorruption, match="npz missing"):
            mgr.restore(1, _state())

    def test_shape_mismatch_is_loud(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"w": jnp.ones((3, 4))})
        with pytest.raises(LeafMismatch, match="'w'.*shape"):
            mgr.restore(1, {"w": jnp.ones((4, 3))})

    def test_verify_step_reports_problems(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, _state())
        assert mgr.verify_step(1) == []
        _injector().flip_bit_on_disk(mgr._step_dir(1))
        problems = mgr.verify_step(1)
        assert len(problems) == 1 and "digest" in problems[0]


class TestLatestGood:
    def test_falls_back_and_quarantines(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, _state())
        mgr.save(2, _state())
        _injector().flip_bit_on_disk(mgr._step_dir(2))
        step, restored, _ = mgr.restore_latest_good(_state())
        assert step == 1
        np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                      np.asarray(_state()["params"]["w"]))
        # the bad step is renamed aside (never deleted) with its reason
        assert mgr.all_steps() == [1]
        (qdir,) = mgr.quarantine_dirs()
        with open(tmp_path / qdir / "quarantine.json") as f:
            ledger = json.load(f)
        assert ledger["step"] == 2 and "digest" in ledger["reason"]
        assert mgr.quarantined == [(2, ledger["reason"])]

    def test_validate_hook_rejections_quarantine_too(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, _state(), extra={"tag": "good"})
        mgr.save(2, _state(), extra={"tag": "bad"})

        def validate(restored, extra):
            if extra.get("tag") == "bad":
                raise ValueError("rejected by policy")

        step, _, extra = mgr.restore_latest_good(_state(), validate=validate)
        assert step == 1 and extra["tag"] == "good"
        assert mgr.quarantined[0][0] == 2
        assert "rejected by policy" in mgr.quarantined[0][1]

    def test_exhausted_walk_is_loud(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        inj = _injector()
        for s in (1, 2):
            mgr.save(s, _state())
            inj.flip_bit_on_disk(mgr._step_dir(s))
        with pytest.raises(NoGoodCheckpoint, match="step 1.*digest"):
            mgr.restore_latest_good(_state())
        assert mgr.all_steps() == [] and len(mgr.quarantine_dirs()) == 2

    def test_empty_directory_is_loud(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        with pytest.raises(NoGoodCheckpoint, match="no checkpoints"):
            mgr.restore_latest_good(_state())


class TestCrashWindows:
    def test_commit_crash_rolls_displaced_back(self, tmp_path, monkeypatch):
        """A crash at the commit rename must not lose the OLD copy of the
        step being overwritten — the except path renames it back."""
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, _state())

        def boom(tmp, step_dir):
            raise OSError("simulated crash at commit")

        monkeypatch.setattr(CheckpointManager, "_commit", staticmethod(boom))
        with pytest.raises(OSError, match="simulated crash"):
            mgr.save(1, {"params": {"w": jnp.zeros((3, 4)),
                                    "b": jnp.zeros(4)},
                         "step": jnp.int32(9)})
        monkeypatch.undo()
        # old copy intact, restorable, no litter
        restored, _ = mgr.restore(1, _state())
        assert int(restored["step"]) == 3
        litter = [d for d in os.listdir(tmp_path) if d.startswith(".")]
        assert litter == []

    def test_hard_crash_between_renames_recovered_at_init(self, tmp_path):
        """Simulate dying AFTER the old step was renamed aside but BEFORE
        the new dir committed: a fresh manager restores the displaced copy."""
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, _state())
        os.rename(tmp_path / "step_0000000001",
                  tmp_path / ".displaced_step_0000000001_0")
        mgr2 = CheckpointManager(str(tmp_path))
        assert mgr2.all_steps() == [1]
        restored, _ = mgr2.restore(1, _state())
        assert int(restored["step"]) == 3

    def test_orphaned_tmp_dirs_scrubbed_at_init(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, _state())
        os.makedirs(tmp_path / ".tmp_ckpt_dead")
        (tmp_path / ".tmp_ckpt_dead" / "host_0.npz").write_bytes(b"partial")
        mgr2 = CheckpointManager(str(tmp_path))
        assert not (tmp_path / ".tmp_ckpt_dead").exists()
        assert mgr2.all_steps() == [1]

    def test_all_steps_skips_quarantine_dirs(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, _state())
        mgr.save(2, _state())
        mgr.quarantine_step(2, reason="test")
        assert mgr.all_steps() == [1]
        assert mgr.latest_step() == 1


# ---------------------------------------------------------------------------
# program layer: GoldenRecord + runtime self-test (BIST)
# ---------------------------------------------------------------------------

class TestGolden:
    def test_compile_records_golden(self, program):
        g = program.golden
        assert g is not None and g.seed == 0
        # the probe is batch-1 regardless of the compiled batch
        assert tuple(g.input_shape) == (1,) + tuple(program.input_shape[1:])
        assert len(g.digests) >= 1
        # full-M schedule is always recorded
        assert g.digest_for(program.resolve_schedule(None)) is not None

    def test_golden_json_roundtrip_exact(self, program):
        from repro.deploy import GoldenRecord

        g = program.golden
        assert GoldenRecord.from_json(g.to_json()) == g
        # equal records hash equal — aux-data equality is what keeps the
        # jit cache warm across hot-reloads
        assert hash(GoldenRecord.from_json(g.to_json())) == hash(g)

    def test_golden_covers_ladder(self, program):
        from repro.serve_cnn.slo import default_ladder

        recorded = set(program.golden.schedules())
        for rung in default_ladder(program):
            assert program.resolve_schedule(rung) in recorded

    def test_compile_golden_off_and_seeded(self):
        # golden=False skips the record; golden=<int> changes the probe
        from repro.core.binlinear import QuantConfig
        from repro.models.cnn import LayerSpec, spec_binarize

        specs = (LayerSpec("fc", "linear", pre="flatten", relu=False),)
        params = {"fc": {"w": jax.random.normal(
            jax.random.PRNGKey(0), (12, 4)) * 0.1}}
        qc = QuantConfig(mode="binary", M=2, K_iters=4, interpret=True)
        packed = spec_binarize(specs, params, qc)
        off = deploy.compile(packed, specs, qc, (2, 2, 2, 3), golden=False)
        assert off.golden is None
        seeded = deploy.compile(packed, specs, qc, (2, 2, 2, 3), golden=7)
        base = deploy.compile(packed, specs, qc, (2, 2, 2, 3))
        assert seeded.golden.seed == 7 and base.golden.seed == 0
        assert seeded.golden.digests != base.golden.digests

    def test_self_test_passes_clean(self, program):
        assert deploy.self_test(program) >= 1

    def test_self_test_catches_memory_bitflip(self, program):
        from repro.deploy import SelfTestFailure

        bad = _injector().flip_bit_in_program(program)
        with pytest.raises(SelfTestFailure) as ei:
            deploy.self_test(bad)
        assert ei.value.rung is not None
        assert ei.value.expected != ei.value.actual

    def test_golden_survives_save_load(self, program, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        deploy.save_program(mgr, 0, program)
        like = dataclasses.replace(program, golden=None)
        loaded = deploy.load_program(mgr, 0, like)
        assert loaded.golden == program.golden
        # identical treedef -> no jit retrace after a hot-reload
        assert (jax.tree_util.tree_structure(loaded)
                == jax.tree_util.tree_structure(program))
        assert deploy.self_test(loaded) >= 1

    def test_load_latest_good_skips_corrupt_program(self, program, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        deploy.save_program(mgr, 1, program)
        deploy.save_program(mgr, 2, program)
        _injector().flip_bit_on_disk(mgr._step_dir(2))
        step, loaded = deploy.load_latest_good(
            mgr, dataclasses.replace(program, golden=None))
        assert step == 1
        x = np.zeros(tuple(program.input_shape), np.float32)
        np.testing.assert_array_equal(
            np.asarray(deploy.execute(loaded, x)),
            np.asarray(deploy.execute(program, x)))


# ---------------------------------------------------------------------------
# service layer: watchdog + hot-reload, end to end under ManualClock
# ---------------------------------------------------------------------------

def _service(program, mgr, clock, *, selftest_every=2):
    from repro.serve_cnn import CNNService, SLOConfig

    return CNNService(
        program,
        slo=SLOConfig(target_ms=50.0, window=8, min_samples=4,
                      recover_after=2),
        batch_size=2, max_queue=8, clock=clock, sleep=clock.sleep,
        selftest_every=selftest_every, checkpoint_manager=mgr,
        restore_like=dataclasses.replace(program, golden=None))


class TestServiceHotReload:
    def test_watchdog_detects_and_hot_reloads(self, program, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        deploy.save_program(mgr, 0, program)
        clock = ManualClock()
        svc = _service(program, mgr, clock)
        img = np.zeros(tuple(program.input_shape[1:]), np.float32)

        def step():
            clock.advance(0.002)
            for _ in range(2):
                svc.submit(img)
            return svc.step()

        for _ in range(3):  # clean phase: BIST runs, nothing trips
            step()
        assert svc.stats["selftest_runs"] >= 1
        assert svc.stats["selftest_failures"] == 0

        svc.program = _injector().flip_bit_in_program(svc.program)
        done = []
        for _ in range(4):
            done.extend(step())
        s = svc.stats
        assert s["selftest_failures"] == 1 and s["reloads"] == 1
        assert svc.last_reload_step == 0
        assert svc.quarantined_program is not None
        # recovered program serves bit-exact answers vs the clean executor
        x = np.stack([img, img])
        ref = np.asarray(deploy.execute(program, x))
        np.testing.assert_array_equal(
            np.asarray(deploy.execute(svc.program, x)), ref)
        assert any(np.array_equal(np.asarray(r.logits), ref[0])
                   for r in done if r.status == "done")

    def test_watchdog_without_manager_reraises(self, program):
        from repro.deploy import SelfTestFailure
        from repro.serve_cnn import CNNService, SLOConfig

        clock = ManualClock()
        svc = CNNService(
            program, slo=SLOConfig(target_ms=50.0),
            batch_size=2, max_queue=8, clock=clock, sleep=clock.sleep,
            selftest_every=1)
        svc.program = _injector().flip_bit_in_program(svc.program)
        svc.submit(np.zeros(tuple(program.input_shape[1:]), np.float32))
        with pytest.raises(SelfTestFailure):
            svc.step()
        assert svc.stats["selftest_failures"] == 1
        assert svc.stats["reloads"] == 0

    def test_selftest_requires_golden(self, program):
        from repro.serve_cnn import CNNService, SLOConfig

        with pytest.raises(ValueError, match="GoldenRecord"):
            CNNService(dataclasses.replace(program, golden=None),
                       slo=SLOConfig(target_ms=50.0), selftest_every=2)


# ---------------------------------------------------------------------------
# fsck CLI
# ---------------------------------------------------------------------------

class TestFsckCLI:
    def _main(self):
        import tools.fsck_ckpt as fsck

        return fsck.main

    def test_clean_exit_0(self, tmp_path, capsys):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, _state())
        assert self._main()([str(tmp_path)]) == 0
        assert "CLEAN" in capsys.readouterr().out

    def test_corrupt_exit_1_and_read_only(self, tmp_path, capsys):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, _state())
        mgr.save(2, _state())
        _injector().flip_bit_on_disk(mgr._step_dir(2))
        report = tmp_path / "report.json"
        assert self._main()([str(tmp_path), "--json", str(report)]) == 1
        assert "CORRUPT" in capsys.readouterr().out
        doc = json.loads(report.read_text())
        assert doc["corrupt_steps"] == 1 and doc["total_steps"] == 2
        # read-only: the corrupt step is still there, NOT quarantined
        assert mgr.all_steps() == [1, 2] and mgr.quarantine_dirs() == []

    def test_no_steps_exit_2(self, tmp_path):
        assert self._main()([str(tmp_path / "empty")]) == 2
