"""Bulk prefill + per-slot state masking in the serving engine.

Covers the serving overhaul's two correctness claims:
  * parity — bulk prefill (one forward + cache scatter) leaves a slot in the
    same state token-wise decode warmup would, for every served family;
  * isolation — admitting/stepping one slot never perturbs concurrent
    slots' recurrent state, so mixed per-request ``m_active`` (§IV-D) now
    serves for ssm/hybrid too.
"""
import jax
import numpy as np
import pytest

from repro.configs import base as cb
from repro.core.binlinear import QuantConfig
from repro.launch.serve import Request, Server
from repro.models import api

jax.config.update("jax_platform_name", "cpu")

FAMILIES = {
    "transformer": "gemma_2b",
    "ssm": "mamba2_2_7b",
    "hybrid": "zamba2_7b",
    # cache-layout variants of the transformer path:
    "moe_mla": "deepseek_v3_671b",     # MoE stack + latent (absorbed) cache
    "swa": "h2o_danube_1_8b",          # rolling sliding-window cache
}


def _cfg(family: str):
    cfg = cb.reduced(cb.get_config(FAMILIES[family])).replace(dtype="float32")
    if family == "swa":
        # shrink the window so a 6-token prompt wraps the rolling cache
        cfg = cfg.replace(sliding_window=4)
    return cfg


def _slot_rows(cfg, cache, slot):
    """Batch row ``slot`` of every cache leaf (leaves are [L, B, ...])."""
    return [np.asarray(l)[:, slot] for l in jax.tree.leaves(cache)]


class TestPrefillParity:
    @pytest.mark.parametrize("family", list(FAMILIES))
    def test_bulk_matches_tokenwise(self, family):
        """Bulk prefill leaves the slot's cache rows and the subsequent
        greedy decode (tokens + logits) matching the token-wise reference.

        The transformer path is bit-identical; recurrent state tolerates
        float op-order differences (chunked SSD vs sequential recurrence)
        at the 1e-5 level.  Length bucketing is disabled here: it writes
        pad KV into the *transient* rows >= prompt_len that token-wise
        warmup leaves zeroed — dead state by the overwrite-before-attend
        invariant, but not bitwise comparable; TestPrefillBucketing checks
        the bucketed path's parity on live outputs instead."""
        cfg = _cfg(family)
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        prompt = np.array([3, 7, 11, 2, 9, 4], np.int32)
        results = {}
        for mode in ("bulk", "tokenwise"):
            srv = Server(cfg, params, max_batch=2, max_len=32, prefill=mode,
                         prefill_buckets=None)
            req = Request(prompt=prompt.copy(), max_new_tokens=3)
            assert srv.admit(req)
            rows = _slot_rows(cfg, srv.cache, 0)
            srv.run_until_done()
            results[mode] = (rows, req.out_tokens, req.last_logits)
        for rb, rt in zip(results["bulk"][0], results["tokenwise"][0]):
            np.testing.assert_allclose(rb, rt, rtol=1e-5, atol=1e-5)
        assert results["bulk"][1] == results["tokenwise"][1]
        np.testing.assert_allclose(results["bulk"][2], results["tokenwise"][2],
                                   rtol=2e-5, atol=5e-5)

    def test_bulk_prefill_is_one_device_program(self):
        """Admission cost: one forward pass, not O(prompt_len) decode steps."""
        cfg = _cfg("transformer")
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        prompt = np.arange(1, 12, dtype=np.int32)
        srv = Server(cfg, params, max_batch=2, max_len=32)
        assert srv.admit(Request(prompt=prompt, max_new_tokens=1))
        assert srv.stats["bulk_prefills"] == 1
        assert srv.stats["tokenwise_prefill_steps"] == 0


class TestPrefillBucketing:
    def test_bucketing_bounds_compiles_and_preserves_outputs(self):
        """Mixed-length traffic: padded lengths collapse onto pow2 buckets
        (bounded compile count) while token streams and logits match the
        exact-length server."""
        cfg = _cfg("transformer")
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        prompts = [np.arange(1, n + 1, dtype=np.int32)
                   for n in (3, 5, 6, 7, 10)]  # prefix lens 2,4,5,6,9
        results = {}
        for buckets in ("pow2", None):
            srv = Server(cfg, params, max_batch=2, max_len=32,
                         prefill_buckets=buckets)
            toks, logits = [], []
            for p in prompts:
                req = Request(prompt=p.copy(), max_new_tokens=3)
                assert srv.admit(req)
                srv.run_until_done()
                toks.append(req.out_tokens)
                logits.append(req.last_logits)
            results[buckets] = (toks, logits, dict(srv.stats))
        assert results["pow2"][0] == results[None][0]
        for a, b in zip(results["pow2"][1], results[None][1]):
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
        # lens 2,4,5,6,9 -> buckets 2,4,8,8,16: 4 unique vs 5 exact
        assert results["pow2"][2]["prefill_unique_lens"] == 4
        assert results["pow2"][2]["prefill_bucket_hits"] == 1
        assert results[None][2]["prefill_unique_lens"] == 5
        assert results[None][2]["prefill_bucket_hits"] == 0

    def test_explicit_bucket_list(self):
        cfg = _cfg("transformer")
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        srv = Server(cfg, params, max_batch=2, max_len=32,
                     prefill_buckets=[8, 16])
        for n in (3, 6, 9):  # prefix lens 2, 5, 8 -> all bucket to 8
            assert srv.admit(Request(prompt=np.arange(1, n + 1,
                                                      dtype=np.int32),
                                     max_new_tokens=1))
            srv.run_until_done()
        assert srv.stats["prefill_unique_lens"] == 1
        assert srv.stats["prefill_bucket_hits"] == 2

    @pytest.mark.parametrize("family", ["ssm", "hybrid", "swa"])
    def test_recurrent_and_swa_families_stay_exact(self, family):
        """Padding is not exact for recurrent final states or rolling SWA
        rings — those families must prefill at the true length even with
        bucketing enabled (the default)."""
        cfg = _cfg(family)
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        srv = Server(cfg, params, max_batch=2, max_len=32)
        assert not srv._pad_safe
        for n in (3, 5):  # distinct prefix lens stay distinct
            assert srv.admit(Request(prompt=np.arange(1, n + 1,
                                                      dtype=np.int32),
                                     max_new_tokens=1))
            srv.run_until_done()
        assert srv.stats["prefill_unique_lens"] == 2
        assert srv.stats["prefill_bucket_hits"] == 0


class TestSlotIsolation:
    @pytest.mark.parametrize("mode", ["bulk", "tokenwise"])
    def test_prefill_leaves_concurrent_ssm_state_untouched(self, mode):
        """Regression: warming a new slot must not nudge other active slots'
        recurrent state.  Bulk prefill runs on a separate B=1 batch; the
        token-wise fallback is saved by the per-slot update mask.  Either
        way slot 0's state must be *bit-exact* across slot 1's admission."""
        cfg = _cfg("ssm")
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        srv = Server(cfg, params, max_batch=2, max_len=32, prefill=mode)
        assert srv.admit(Request(prompt=np.array([5, 6, 7], np.int32),
                                 max_new_tokens=4))
        before = _slot_rows(cfg, srv.cache, 0)
        assert srv.admit(Request(prompt=np.array([9, 8, 7, 6], np.int32),
                                 max_new_tokens=4))
        after = _slot_rows(cfg, srv.cache, 0)
        for b, a in zip(before, after):
            np.testing.assert_array_equal(b, a)

    @pytest.mark.parametrize("family", ["ssm", "hybrid"])
    def test_mixed_m_active_serves_like_isolated(self, family):
        """§IV-D end-to-end for recurrent families: a request in a mixed
        m_active batch produces the exact token stream it gets when served
        alone — grouped decode with update masks corrupts nothing."""
        cfg = _cfg(family)
        qc = QuantConfig(mode="binary", M=2, K_iters=2)
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        bp = api.binarize_model_params(cfg, params, qc=qc)
        scfg = cfg.replace(quant=qc)
        prompt = np.array([1, 2, 3, 4], np.int32)

        srv = Server(scfg, bp, max_batch=3, max_len=32)
        r_full = Request(prompt=prompt.copy(), max_new_tokens=4)
        r_fast = Request(prompt=prompt.copy(), max_new_tokens=4, m_active=1)
        assert srv.admit(r_full)
        assert srv.admit(r_fast)
        srv.run_until_done()

        for m, mixed in ((None, r_full), (1, r_fast)):
            solo_srv = Server(scfg, bp, max_batch=1, max_len=32)
            solo = Request(prompt=prompt.copy(), max_new_tokens=4, m_active=m)
            assert solo_srv.admit(solo)
            solo_srv.run_until_done()
            assert mixed.out_tokens == solo.out_tokens
            np.testing.assert_allclose(mixed.last_logits, solo.last_logits,
                                       rtol=1e-5, atol=1e-5)
        # the runtime switch stays observable inside the mixed batch
        assert not np.allclose(r_fast.last_logits, r_full.last_logits)
