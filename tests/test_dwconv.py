"""Fused binary depth-wise kernel vs the ±1 oracle, and the full-binary
MobileNet deployment path (paper §V-A3: channel-wise dw approximation,
D_arch = 1).

The end-to-end claims under test:
  * the Pallas dw kernel (interpret mode) matches kernels/ref.py's
    reconstruction-through-``lax.conv`` oracle across C % 8 != 0, stride 2,
    m_active < M, and forced ragged row tiles;
  * ``mobilenet_forward`` over a ``binarize_mobilenet`` tree with
    ``fuse_conv`` executes **zero** fp ``lax.conv`` calls (dw included) and
    matches the fake-quant retraining reference within tolerance;
  * row-tiled dw blocking is bit-exact against whole-image blocking.

The 224²/112² MobileNet-B2-scale cases are ``slow`` (nightly tier).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import binconv
from repro.core.binlinear import QuantConfig
from repro.kernels import binary_dwconv as bdw
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.models import cnn

jax.config.update("jax_platform_name", "cpu")


def _dw_case(seed, C, M, K_iters=4):
    kx, kw_key, kb = jax.random.split(jax.random.PRNGKey(seed), 3)
    w = jax.random.normal(kw_key, (3, 3, 1, C), jnp.float32) * 0.3
    b = jax.random.normal(kb, (C,), jnp.float32)
    qc = QuantConfig(mode="binary", M=M, K_iters=K_iters)
    return binconv.binarize_dwconv_params({"w": w, "b": b}, qc), kx


class TestBinaryDwConvKernel:
    # C, H, W, stride, M, m_active, bu
    SWEEP = [
        (6, 10, 10, 1, 2, None, None),   # C%8!=0
        (8, 9, 11, 2, 3, 2, None),       # stride 2 + m_active < M
        (16, 12, 12, 1, 2, None, 5),     # ragged tiles: U=12, bu=5
        (32, 7, 7, 2, 1, None, 1),       # M=1, one row per tile
        (13, 8, 8, 1, 4, 3, 3),          # odd C, m_active < M, ragged
    ]

    @pytest.mark.parametrize("C,H,W,stride,M,m_active,bu", SWEEP)
    def test_matches_oracle(self, C, H, W, stride, M, m_active, bu):
        p, kx = _dw_case(C * 10 + (bu or 0), C, M)
        x = jax.random.normal(kx, (2, H, W, C), jnp.float32)
        got = kops.binary_dwconv2d(
            x, p["B_tap_packed"], p["alpha"], p["b"], kh=3, kw=3,
            stride=stride, m_active=m_active, bu=bu, interpret=True)
        want = kref.binary_dwconv_relu_ref(
            x, p["B_tap_packed"], p["alpha"], kh=3, kw=3, stride=stride,
            m_active=m_active, bias=p["b"])
        assert got.shape == want.shape
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_tiled_bit_exact_vs_whole_image(self):
        p, kx = _dw_case(7, 16, 2)
        x = jax.random.normal(kx, (2, 13, 9, 16), jnp.float32)
        args = (x, p["B_tap_packed"], p["alpha"], p["b"])
        kw_args = dict(kh=3, kw=3, stride=1, interpret=True)
        whole = bdw.binary_dwconv2d_pallas(*args, bu=10**6, **kw_args)
        for bu in (1, 4, 5):  # 5 leaves a ragged last tile (U=11)
            tiled = bdw.binary_dwconv2d_pallas(*args, bu=bu, **kw_args)
            np.testing.assert_array_equal(np.asarray(whole),
                                          np.asarray(tiled))

    @pytest.mark.parametrize("nb", [1, 2, 3])
    def test_batch_tiled_bit_exact_dw14(self, nb):
        """dw@14² (MobileNet back half): NB images per program — including
        the ragged B=3, nb=2 split — bit-exact vs per-image blocking."""
        p, kx = _dw_case(33, 32, 2)
        x = jax.random.normal(kx, (3, 16, 16, 32), jnp.float32)  # SAME 14²+2
        args = (x, p["B_tap_packed"], p["alpha"], p["b"])
        kw_args = dict(kh=3, kw=3, stride=1, interpret=True)
        per_image = bdw.binary_dwconv2d_pallas(*args, nb=1, bu=10**6,
                                               **kw_args)
        batched = bdw.binary_dwconv2d_pallas(*args, nb=nb, **kw_args)
        np.testing.assert_array_equal(np.asarray(per_image),
                                      np.asarray(batched))

    def test_batch_and_row_tiles_compose(self):
        p, kx = _dw_case(44, 8, 2)
        x = jax.random.normal(kx, (5, 12, 10, 8), jnp.float32)
        args = (x, p["B_tap_packed"], p["alpha"], p["b"])
        kw_args = dict(kh=3, kw=3, stride=1, interpret=True)
        per_image = bdw.binary_dwconv2d_pallas(*args, nb=1, bu=10**6,
                                               **kw_args)
        tiled = bdw.binary_dwconv2d_pallas(*args, nb=2, bu=4, **kw_args)
        np.testing.assert_array_equal(np.asarray(per_image),
                                      np.asarray(tiled))

    def test_pick_tile_dw_regimes(self):
        """Whole-image dw maps grow NB until the budget or cap binds;
        row-tiled (112²-scale) maps keep NB=1."""
        nb, bu = bdw.pick_tile_dw(8, 16, 16, 32, 3, 3, m=2)
        assert bu == 14 and nb > 1, (nb, bu)
        nb112, bu112 = bdw.pick_tile_dw(8, 114, 114, 32, 3, 3,
                                        2 * 1024 * 1024, m=2)
        assert nb112 == 1 and bu112 < 112, (nb112, bu112)
        assert bdw.pick_tile_dw(1, 16, 16, 32, 3, 3, m=2)[0] == 1

    def test_pack_unpack_roundtrip(self):
        key = jax.random.PRNGKey(3)
        B = jnp.where(jax.random.bernoulli(key, shape=(2, 9, 13)), 1,
                      -1).astype(jnp.int8)
        packed = bdw.pack_dw_taps(B)
        assert packed.shape == (2, 9, 2)  # ceil(13/8) == 2
        np.testing.assert_array_equal(np.asarray(bdw.unpack_dw_taps(packed, 13)),
                                      np.asarray(B))

    def test_m_active_truncates_levels(self):
        """§IV-D on the dw path: fewer levels -> different (coarser) output,
        and m_active=M == all levels."""
        p, kx = _dw_case(21, 8, 3)
        x = jax.random.normal(kx, (1, 8, 8, 8), jnp.float32)
        args = (x, p["B_tap_packed"], p["alpha"], p["b"])
        kw_args = dict(kh=3, kw=3, interpret=True)
        full = kops.binary_dwconv2d(*args, **kw_args)
        m3 = kops.binary_dwconv2d(*args, m_active=3, **kw_args)
        m1 = kops.binary_dwconv2d(*args, m_active=1, **kw_args)
        np.testing.assert_array_equal(np.asarray(full), np.asarray(m3))
        assert not np.allclose(np.asarray(full), np.asarray(m1))


def _boosted_mobilenet(width_mult=0.25, n_classes=10):
    """Init whose activations survive 13 ReLU blocks (the 0.1-scale random
    init collapses logits to ~1e-13, which would make parity vacuous)."""
    params = cnn.init_mobilenet(jax.random.PRNGKey(0), width_mult=width_mult,
                                n_classes=n_classes)
    for i, (k, v) in enumerate(sorted(params.items())):
        if "w" in v:
            v["w"] = v["w"] * 3.0
        if "b" in v:
            v["b"] = jax.random.normal(jax.random.PRNGKey(100 + i),
                                       v["b"].shape) * 0.1
    return params


class TestFullBinaryMobileNet:
    def test_fused_matches_fake_quant_reference(self):
        """Packed + fuse_conv forward tracks the fake-quant retraining
        reference (same Algorithm-2 reconstruction) within fp tolerance."""
        params = _boosted_mobilenet()
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3),
                              jnp.float32)
        qc = QuantConfig(mode="binary", M=2, K_iters=3)
        bp = cnn.binarize_mobilenet(params, qc)
        fq = cnn.mobilenet_forward(params, x, qc.replace(mode="fake_quant"))
        fused = cnn.mobilenet_forward(
            bp, x, qc.replace(fuse_conv=True, use_pallas=True, interpret=True))
        assert float(jnp.max(jnp.abs(fq))) > 0.1  # non-vacuous comparison
        np.testing.assert_allclose(np.asarray(fused), np.asarray(fq),
                                   rtol=2e-3, atol=2e-3)

    def test_fused_forward_has_zero_fp_conv_calls(self):
        """The acceptance bar: with packed params + fuse_conv, no
        ``conv_general_dilated`` appears anywhere in the traced forward —
        the dw layers run the binary kernel, not fp ``lax.conv``."""
        params = _boosted_mobilenet(width_mult=0.125)
        qc = QuantConfig(mode="binary", M=2, K_iters=2)
        bp = cnn.binarize_mobilenet(params, qc)
        x = jnp.zeros((1, 32, 32, 3), jnp.float32)
        fused_qc = qc.replace(fuse_conv=True, use_pallas=True, interpret=True)
        jaxpr = jax.make_jaxpr(
            lambda x: cnn.mobilenet_forward(bp, x, fused_qc))(x)
        assert "conv_general_dilated" not in str(jaxpr)
        # sanity: the dense fp baseline *does* use it (dw layers)
        dense_jaxpr = jax.make_jaxpr(
            lambda x: cnn.mobilenet_forward(params, x))(x)
        assert "conv_general_dilated" in str(dense_jaxpr)

    def test_unfused_binary_matches_fused(self):
        """Packed tree without fuse_conv (oracle dw + im2col pw) agrees with
        the fused kernels — two execution strategies, one computation."""
        params = _boosted_mobilenet(width_mult=0.125)
        x = jax.random.normal(jax.random.PRNGKey(2), (1, 32, 32, 3),
                              jnp.float32)
        qc = QuantConfig(mode="binary", M=2, K_iters=2)
        bp = cnn.binarize_mobilenet(params, qc)
        unfused = cnn.mobilenet_forward(bp, x, qc)
        fused = cnn.mobilenet_forward(
            bp, x, qc.replace(fuse_conv=True, use_pallas=True, interpret=True))
        np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                                   rtol=2e-3, atol=2e-3)


@pytest.mark.slow
class TestMobileNet224Scale:
    """MobileNet-B2 (224²) layer shapes through the tiled kernels — the
    feature maps where whole-image blocking exceeds the VMEM budget and the
    row tiling has to engage (nightly tier; interpret mode is slow)."""

    def test_stem_224_tiles_and_matches_oracle(self):
        kx, kw_key = jax.random.split(jax.random.PRNGKey(5))
        w = jax.random.normal(kw_key, (3, 3, 3, 32), jnp.float32) * 0.2
        b = jnp.zeros((32,), jnp.float32)
        p = binconv.binarize_conv_params(
            {"w": w, "b": b}, QuantConfig(mode="binary", M=2, K_iters=2))
        x = jax.random.normal(kx, (1, 224, 224, 3), jnp.float32)
        got = kops.binary_conv2d(
            x, p["B_tap_packed"], p["alpha"], p["b"], kh=3, kw=3, stride=2,
            padding="SAME", vmem_budget=2 * 1024 * 1024, interpret=True)
        want = kref.fused_binary_conv_relu_pool_ref(
            x, p["B_packed"], p["alpha"], kh=3, kw=3, stride=2,
            padding="SAME", bias=p["b"])
        assert got.shape == (1, 112, 112, 32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_dw0_112_tiles_and_matches_oracle(self):
        p, kx = _dw_case(51, 32, 2, K_iters=2)
        x = jax.random.normal(kx, (1, 112, 112, 32), jnp.float32)
        got = kops.binary_dwconv2d(
            x, p["B_tap_packed"], p["alpha"], p["b"], kh=3, kw=3,
            vmem_budget=2 * 1024 * 1024, interpret=True)
        want = kref.binary_dwconv_relu_ref(
            x, p["B_tap_packed"], p["alpha"], kh=3, kw=3, bias=p["b"])
        assert got.shape == (1, 112, 112, 32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_early_pw_112_auto_tiles_under_budget(self):
        """pw0 at 112²: whole-image blocking exceeds the default budget, so
        the auto pick must tile — and still match the oracle."""
        from repro.kernels import binary_conv as bck

        kx, kw_key = jax.random.split(jax.random.PRNGKey(7))
        w = jax.random.normal(kw_key, (1, 1, 32, 64), jnp.float32) * 0.2
        b = jnp.zeros((64,), jnp.float32)
        p = binconv.binarize_conv_params(
            {"w": w, "b": b}, QuantConfig(mode="binary", M=2, K_iters=2))
        assert bck.tile_vmem_bytes(112, 32, 1, 1, 64, bu=112,
                                   m=2) > bck.DEFAULT_VMEM_BUDGET
        x = jax.random.normal(kx, (1, 112, 112, 32), jnp.float32)
        got = kops.binary_conv2d(x, p["B_tap_packed"], p["alpha"], p["b"],
                                 kh=1, kw=1, interpret=True)
        want = kref.fused_binary_conv_relu_pool_ref(
            x, p["B_packed"], p["alpha"], kh=1, kw=1, bias=p["b"])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
