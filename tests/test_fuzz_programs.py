"""Differential fuzz tier: random topologies through the whole deploy stack.

Property (repro.testing.fuzz generates legal-by-construction networks):

    random LayerSpec list -> deploy.compile -> verify_program: zero ERRORs
        -> deploy.execute == models.cnn.spec_forward(..., fused)  BIT-EXACT
        -> allclose vs the unfused fake-quant reconstruction (the jnp
           oracle path — same math, different kernel)

for shapes/strides/pools/paddings/M-levels/ragged batches the unit tests
never hand-picked.  Everything keys off one integer seed so a failure
replays with ``fuzz.random_network(seed)``.

Tiers: a pinned fast subset always runs; the wide sweep is ``slow``.  The
sweep draws seeds via hypothesis (real or the deterministic stub in
tests/_hypothesis_stub.py — conftest registers whichever is available).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import deploy
from repro.analysis import verify_program
from repro.core.binlinear import QuantConfig
from repro.models import cnn
from repro.testing import fuzz


def _check_seed(seed: int) -> None:
    net = fuzz.random_network(seed)
    qc = QuantConfig(mode="binary", M=net.M, K_iters=2, interpret=True)
    fused = qc.replace(fuse_conv=True, use_pallas=True)
    params = net.init_params(jax.random.PRNGKey(seed))
    packed = cnn.spec_binarize(net.specs, params, qc)

    prog = deploy.compile(packed, net.specs, qc, net.input_shape)
    errors = [f for f in verify_program(prog) if f.severity == "ERROR"]
    assert not errors, (
        f"seed {seed}: verifier ERRORs on a legal-by-construction program "
        f"({[s.name for s in net.specs]} @ {net.input_shape}): {errors[:3]}")

    x = jax.random.normal(jax.random.PRNGKey(seed + 99),
                          (net.exec_batch,) + net.input_shape[1:],
                          jnp.float32)
    got = np.asarray(deploy.execute(prog, x))
    want = np.asarray(cnn.spec_forward(net.specs, packed, x, fused))
    np.testing.assert_array_equal(
        got, want,
        err_msg=f"seed {seed}: execute diverged bit-wise from the per-call "
                f"fused forward ({[s.name for s in net.specs]})")
    # same math via the unfused jnp reconstruction — catches a kernel and
    # the executor agreeing on a shared wrong answer
    oracle = np.asarray(cnn.spec_forward(net.specs, packed, x, qc))
    np.testing.assert_allclose(
        got, oracle, rtol=1e-3, atol=1e-3,
        err_msg=f"seed {seed}: fused path diverged from the jnp oracle")


# pinned fast subset: covers conv VALID+SAME, stride 2, pooling, a dwconv
# layer, gap + flatten tails, M=1 and M=2, ragged exec batches — picked by
# inspecting fuzz.random_network draws so the fast tier touches every
# generator branch without the sweep's cost.
@pytest.mark.parametrize("seed", [0, 3, 6, 11])
def test_fuzz_pinned(seed):
    _check_seed(seed)


@pytest.mark.slow
@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 500))
def test_fuzz_sweep(seed):
    _check_seed(seed)


def test_generator_is_deterministic_and_legal():
    a, b = fuzz.random_network(5), fuzz.random_network(5)
    assert a == b
    for seed in range(8):
        net = fuzz.random_network(seed)
        assert net.specs and net.specs[-1].kind == "linear"
        assert not net.specs[-1].relu            # logits layer
        kinds = {s.kind for s in net.specs}
        assert kinds <= {"conv", "dwconv", "linear"}
        assert 1 <= net.exec_batch <= 5
        assert net.M in (1, 2)
