"""MeshPlan planning, verification, and per-device accounting (always-run).

The multi-device executor's correctness tier needs 8 virtual devices
(test_distributed_exec.py); everything HERE is static — ``plan_mesh``,
``analysis.verify_mesh_plan``, ``distributed.stats`` read shapes and frozen
aux only, so the planning policy and every seeded-illegal verifier rule run
on any single-device CPU.  Abstract programs (``deploy.abstract_program``)
keep it weight-free and fast.

Seeded-illegal fixtures follow the verifier suite's pattern: take a clean
planner output, break exactly one invariant with ``dataclasses.replace``,
and assert the intended rule id fires (and only then).
"""
import dataclasses

import jax
import pytest

from repro import deploy
from repro.analysis import verify_mesh_plan
from repro.core.binlinear import QuantConfig
from repro.deploy.program import TilePlan
from repro.distributed import (DEFAULT_MIN_SHARD_BYTES, LayerShard, MeshPlan,
                               mesh_totals, plan_mesh, shard_layer_stats)
from repro.kernels import binary_conv as bck

jax.config.update("jax_platform_name", "cpu")

QC = QuantConfig(mode="binary", M=2, K_iters=4, interpret=True)


@pytest.fixture(scope="module")
def cnn_a():
    return deploy.abstract_program("cnn_a", QC, (8, 48, 48, 3))


@pytest.fixture(scope="module")
def mobilenet():
    return deploy.abstract_program("mobilenet", QC.replace(K_iters=2),
                                   (8, 32, 32, 3), width_mult=0.25,
                                   n_classes=10)


def _rules(findings):
    return sorted({f.rule for f in findings})


class TestPlanPolicy:
    def test_cnn_a_is_pure_data_parallel(self, cnn_a):
        """CNN-A has no bd-shardable layer (conv1 D=5 < 8 channels/device,
        conv2 D=150 leaves a non-8-divisible slice) — any mesh degenerates
        to replicated-weights data parallelism, the paper's plain
        Processing-Array replication."""
        plan = plan_mesh(cnn_a, n_data=4, n_model=2, min_shard_bytes=0,
                         pointwise_only=False)
        assert all(s.kind == "replicated" for s in plan.shards)
        assert len(plan.shards) == len(cnn_a.instrs)
        assert plan.global_batch == 8 and plan.devices == 8

    def test_mobilenet_pointwise_layers_shard(self, mobilenet):
        plan = plan_mesh(mobilenet, n_data=4, n_model=2, min_shard_bytes=0)
        bd = [(i, s) for i, s in enumerate(plan.shards) if s.kind == "bd"]
        assert bd, "expected bd-sharded point-wise layers at n_model=2"
        for i, s in bd:
            instr = mobilenet.instrs[i]
            assert instr.kh == 1 and instr.kw == 1      # point-wise only
            assert s.d_local * 2 == int(instr.alpha.shape[-1])
            assert s.plan is not None and s.plan.bd is not None
            assert s.per_device_weight_bytes \
                == int(instr.stats.weight_bytes) // 2

    def test_min_shard_bytes_gates_small_layers(self, mobilenet):
        """Below the byte floor the all_gather is not worth it: a huge floor
        must plan everything replicated, the default floor strictly fewer
        shards than floor-zero."""
        all_in = plan_mesh(mobilenet, n_data=4, n_model=2, min_shard_bytes=0)
        floored = plan_mesh(mobilenet, n_data=4, n_model=2,
                            min_shard_bytes=DEFAULT_MIN_SHARD_BYTES)
        none = plan_mesh(mobilenet, n_data=4, n_model=2,
                         min_shard_bytes=1 << 40)
        n = [sum(1 for s in p.shards if s.kind == "bd")
             for p in (all_in, floored, none)]
        assert n[0] >= n[1] >= n[2] == 0

    def test_planning_counts_zero_plan_picks(self, mobilenet):
        """Device-local tile plans are co-picked with the compiler's own
        exported machinery — wrapped so planning never shows up on the
        trace-time pick counter the lint gate reads."""
        bck.reset_plan_pick_count()
        plan_mesh(mobilenet, n_data=4, n_model=2, min_shard_bytes=0)
        assert bck.plan_pick_count() == 0

    def test_plan_validation(self, cnn_a):
        with pytest.raises(ValueError, match="mesh axes"):
            plan_mesh(cnn_a, n_data=0)
        with pytest.raises(ValueError, match="global_batch"):
            plan_mesh(cnn_a, n_data=2, global_batch=0)

    def test_mesh_plan_properties(self, cnn_a):
        plan = plan_mesh(cnn_a, n_data=3, global_batch=8)
        assert plan.devices == 3
        assert plan.local_batch == 3          # ceil(8 / 3)
        lines = plan.describe()
        assert "mesh 3x1" in lines[0]
        assert len(lines) == 1 + len(plan.shards)


class TestVerifierCleanOnPlannerOutput:
    @pytest.mark.parametrize("n_model", [1, 2])
    def test_planner_output_is_clean(self, mobilenet, n_model):
        plan = plan_mesh(mobilenet, n_data=4, n_model=n_model,
                         min_shard_bytes=0)
        assert verify_mesh_plan(mobilenet, plan) == []

    def test_cnn_a_clean(self, cnn_a):
        plan = plan_mesh(cnn_a, n_data=8)
        assert verify_mesh_plan(cnn_a, plan) == []


class TestSeededIllegalPlans:
    """Each fixture breaks ONE invariant; the named rule must fire."""

    @pytest.fixture()
    def mn_plan(self, mobilenet):
        return plan_mesh(mobilenet, n_data=4, n_model=2, min_shard_bytes=0)

    def _bd_idx(self, plan):
        return next(i for i, s in enumerate(plan.shards) if s.kind == "bd")

    def _swap(self, plan, idx, shard):
        shards = list(plan.shards)
        shards[idx] = shard
        return dataclasses.replace(plan, shards=tuple(shards))

    def test_wrong_arity_fires_shard_plan(self, mobilenet, mn_plan):
        bad = dataclasses.replace(mn_plan, shards=mn_plan.shards[:-1])
        assert _rules(verify_mesh_plan(mobilenet, bad)) == ["shard-plan"]

    def test_bad_axis_size_fires_shard_plan(self, mobilenet, mn_plan):
        bad = dataclasses.replace(mn_plan, n_data=0)
        assert _rules(verify_mesh_plan(mobilenet, bad)) == ["shard-plan"]

    def test_unknown_kind_fires_shard_plan(self, mobilenet, mn_plan):
        bad = self._swap(mn_plan, 0, LayerShard(kind="columnwise"))
        assert "shard-plan" in _rules(verify_mesh_plan(mobilenet, bad))

    def test_bd_on_non_conv_fires_shard_plan(self, cnn_a):
        plan = plan_mesh(cnn_a, n_data=4, n_model=2)
        fc = next(i for i, ins in enumerate(cnn_a.instrs)
                  if ins.kind != "conv")
        bad = self._swap(plan, fc, LayerShard(
            kind="bd", d_local=8, plan=TilePlan(nb=1, bu=1, bd=128)))
        fs = verify_mesh_plan(cnn_a, bad)
        assert any(f.rule == "shard-plan" and f.index == fc for f in fs)

    def test_unfrozen_local_plan_fires_shard_plan(self, mobilenet, mn_plan):
        """A bd shard without a frozen device-local plan would re-pick
        inside the sharded trace — the exact sin the compiler exists to
        prevent."""
        i = self._bd_idx(mn_plan)
        bad = self._swap(mn_plan, i,
                         dataclasses.replace(mn_plan.shards[i], plan=None))
        fs = verify_mesh_plan(mobilenet, bad)
        assert any(f.rule == "shard-plan" and f.index == i for f in fs)

    def test_non_dividing_channels_fire_shard_divisibility(self, mobilenet,
                                                           mn_plan):
        bad = dataclasses.replace(mn_plan, n_model=3)
        fs = verify_mesh_plan(mobilenet, bad)
        assert "shard-divisibility" in _rules(fs)

    def test_wrong_d_local_fires_shard_divisibility(self, mobilenet, mn_plan):
        i = self._bd_idx(mn_plan)
        s = mn_plan.shards[i]
        bad = self._swap(mn_plan, i,
                         dataclasses.replace(s, d_local=s.d_local + 8))
        fs = verify_mesh_plan(mobilenet, bad)
        assert any(f.rule == "shard-divisibility" and f.index == i
                   for f in fs)

    def test_illegal_lane_tile_fires_shard_lane(self, mobilenet, mn_plan):
        i = self._bd_idx(mn_plan)
        s = mn_plan.shards[i]
        bad = self._swap(mn_plan, i, dataclasses.replace(
            s, plan=dataclasses.replace(s.plan, bd=24)))
        fs = verify_mesh_plan(mobilenet, bad)
        assert any(f.rule == "shard-lane" and f.index == i for f in fs)

    def test_bad_byte_split_fires_shard_accounting(self, mobilenet, mn_plan):
        bad = self._swap(mn_plan, 0, dataclasses.replace(
            mn_plan.shards[0], per_device_weight_bytes=12345))
        fs = verify_mesh_plan(mobilenet, bad)
        assert any(f.rule == "shard-accounting" and f.severity == "WARN"
                   for f in fs)

    def test_ragged_global_batch_fires_shard_batch(self, mobilenet, mn_plan):
        bad = dataclasses.replace(mn_plan, global_batch=7)
        fs = verify_mesh_plan(mobilenet, bad)
        assert any(f.rule == "shard-batch" and f.severity == "WARN"
                   for f in fs)


class TestShardStats:
    def test_arity_mismatch_raises(self, cnn_a, mobilenet):
        plan = plan_mesh(cnn_a, n_data=2)
        with pytest.raises(ValueError, match="instruction"):
            shard_layer_stats(mobilenet, plan)

    def test_pure_dp_totals(self, cnn_a):
        plan = plan_mesh(cnn_a, n_data=4)
        tot = mesh_totals(cnn_a, plan)
        assert tot["devices_per_forward"] == 4
        assert tot["sharded_layers"] == 0
        assert tot["gather_bytes"] == 0
        # everything replicated: fleet bytes = devices x one copy
        assert tot["replication_overhead"] == pytest.approx(4.0)
        assert tot["per_device_weight_bytes"] \
            == tot["replicated_weight_bytes"]

    def test_bd_sharding_cuts_replication_and_bytes(self, mobilenet):
        dp = plan_mesh(mobilenet, n_data=8, n_model=1)
        mp = plan_mesh(mobilenet, n_data=4, n_model=2, min_shard_bytes=0)
        t_dp, t_mp = mesh_totals(mobilenet, dp), mesh_totals(mobilenet, mp)
        assert t_dp["devices_per_forward"] == t_mp["devices_per_forward"] == 8
        # sharding weights over the model axis must strictly beat pure DP
        # on both per-device bytes and fleet replication
        assert t_mp["per_device_weight_bytes"] \
            < t_dp["per_device_weight_bytes"]
        assert t_mp["replication_overhead"] < t_dp["replication_overhead"]
        assert t_mp["gather_bytes"] > 0
        assert t_mp["sharded_layers"] > 0

    def test_rows_are_json_shaped(self, mobilenet):
        plan = plan_mesh(mobilenet, n_data=4, n_model=2, min_shard_bytes=0)
        rows = shard_layer_stats(mobilenet, plan)
        assert len(rows) == len(mobilenet.instrs)
        for r in rows:
            assert r["shard"] in ("replicated", "bd")
            assert r["per_device_vmem_bytes"] > 0
            if r["shard"] == "bd":
                assert set(r["local_plan"]) == {"nb", "bu", "bd"}
