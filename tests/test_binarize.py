"""Unit + property tests for the paper's §II approximation procedures."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import binarize as bz

jax.config.update("jax_platform_name", "cpu")


def _rand_w(key, K, N, scale=1.0):
    return jax.random.normal(key, (K, N)) * scale


class TestAlgorithm1:
    def test_first_tensor_is_sign(self):
        """B_1 = sign(W) — the paper's rationale for Algorithm 1 step 3."""
        W = _rand_w(jax.random.PRNGKey(0), 32, 8)
        a = bz.algorithm1(W, M=3)
        np.testing.assert_array_equal(
            np.asarray(a.B[0]), np.where(np.asarray(W) >= 0, 1, -1)
        )

    def test_residual_decreases_with_M(self):
        """More binary tensors -> better approximation (paper §II-A)."""
        W = _rand_w(jax.random.PRNGKey(1), 64, 16)
        errs = [float(bz.residual_error(W, bz.algorithm1(W, M=m))) for m in (1, 2, 3, 4)]
        assert all(errs[i + 1] < errs[i] for i in range(len(errs) - 1)), errs

    def test_alpha_is_least_squares_optimal(self):
        """Paper Eq. 5: alpha from solve() beats the greedy estimates."""
        W = _rand_w(jax.random.PRNGKey(2), 48, 4)
        B, alpha_hat = bz._greedy_binarize(W, 3, 48)
        greedy = bz.BinApprox(B=B, alpha=alpha_hat[:, None, :] if alpha_hat.ndim == 2 else alpha_hat, group_size=48)
        # reshape greedy alphas [M, G=1, N]
        greedy = bz.BinApprox(B=B, alpha=alpha_hat.reshape(3, 1, 4), group_size=48)
        ls = bz.algorithm1(W, M=3)
        assert float(bz.residual_error(W, ls)) <= float(bz.residual_error(W, greedy)) + 1e-5

    def test_exact_recovery_when_W_is_binary_combination(self):
        """If W = a1*B1 + a2*B2 exactly, M=2 recovers it to fp precision."""
        key = jax.random.PRNGKey(3)
        k1, k2 = jax.random.split(key)
        B1 = jnp.where(jax.random.bernoulli(k1, 0.5, (40, 8)), 1.0, -1.0)
        B2 = jnp.where(jax.random.bernoulli(k2, 0.5, (40, 8)), 1.0, -1.0)
        W = 0.7 * B1 + 0.2 * B2
        a = bz.algorithm2(W, M=2, K_iters=50)
        assert float(bz.residual_error(W, a)) < 1e-8


class TestAlgorithm2:
    @pytest.mark.parametrize("M", [1, 2, 3, 4])
    def test_alg2_never_worse_than_alg1(self, M):
        """The paper's central §II claim."""
        for seed in range(5):
            W = _rand_w(jax.random.PRNGKey(seed), 72, 12)
            e1 = float(bz.residual_error(W, bz.algorithm1(W, M=M)))
            e2 = float(bz.residual_error(W, bz.algorithm2(W, M=M, K_iters=100)))
            assert e2 <= e1 + 1e-5, (seed, M, e1, e2)

    def test_alg2_monotone_in_M(self):
        """Monotone accuracy increase with M — what Alg-1 lacks (Table II)."""
        W = _rand_w(jax.random.PRNGKey(7), 96, 16)
        errs = [
            float(bz.residual_error(W, bz.algorithm2(W, M=m, K_iters=100)))
            for m in (1, 2, 3, 4, 5)
        ]
        assert all(errs[i + 1] <= errs[i] + 1e-6 for i in range(len(errs) - 1)), errs

    def test_alg2_jits(self):
        W = _rand_w(jax.random.PRNGKey(8), 32, 8)
        f = jax.jit(lambda w: bz.reconstruct(bz.algorithm2(w, M=2, K_iters=10)))
        out = f(W)
        assert out.shape == W.shape and bool(jnp.all(jnp.isfinite(out)))

    def test_groupwise_alpha_improves_residual(self):
        """Beyond-paper: finer alpha groups fit at least as well."""
        W = _rand_w(jax.random.PRNGKey(9), 64, 8)
        e_filter = float(bz.residual_error(W, bz.algorithm2(W, M=2, K_iters=30)))
        e_group = float(
            bz.residual_error(W, bz.algorithm2(W, M=2, K_iters=30, group_size=16))
        )
        assert e_group <= e_filter + 1e-5


class TestPacking:
    @pytest.mark.parametrize("K,N,M", [(8, 4, 1), (64, 16, 3), (128, 8, 4)])
    def test_pack_unpack_roundtrip(self, K, N, M):
        key = jax.random.PRNGKey(K + N + M)
        B = jnp.where(jax.random.bernoulli(key, 0.5, (M, K, N)), 1, -1).astype(jnp.int8)
        np.testing.assert_array_equal(
            np.asarray(bz.unpack_bits(bz.pack_bits(B), K)), np.asarray(B)
        )

    def test_packed_size_is_one_sixteenth_of_bf16(self):
        B = jnp.ones((2, 128, 64), jnp.int8)
        packed = bz.pack_bits(B)
        assert packed.size == B.size // 8  # 1 byte per 8 weights
        # vs bf16 dense: 2 bytes/weight for M=2 levels -> 16x per level pair
        assert (128 * 64 * 2) / (packed.size / 2) == 16.0


class TestCompressionFactor:
    def test_eq6_examples_from_paper(self):
        """Paper: cf -> 16, 10.7, 8 for M = 2, 3, 4 at bits_w=32."""
        for M, expect in [(2, 16.0), (3, 32 / 3), (4, 8.0)]:
            cf = bz.compression_factor(100000, M)
            assert abs(cf - expect) < 0.05, (M, cf, expect)

    def test_table2_cnn_a_values(self):
        """Table II CNN-A: cf = 15.8, 10.6, 7.9 for M = 2, 3, 4.

        CNN-A's mean filter size gives cf slightly under the asymptote; with
        a representative N_c (the 4x4x5 conv filter = 80 coeffs, plus bias)
        Eq. 6 lands in the Table II ballpark.
        """
        cf2 = bz.compression_factor(80, 2, bits_w=32, bits_alpha=8)
        assert 14.5 < cf2 < 16.0, cf2


class TestSTE:
    def test_fake_quant_gradient_is_straight_through(self):
        W = _rand_w(jax.random.PRNGKey(11), 24, 8)
        x = jax.random.normal(jax.random.PRNGKey(12), (4, 24))

        def loss(w):
            return jnp.sum(x @ bz.fake_quant(w, M=2, K_iters=5))

        g = jax.grad(loss)(W)
        # STE: dL/dW == x^T @ ones — as if binarization were identity
        expect = x.T @ jnp.ones((4, 8))
        np.testing.assert_allclose(np.asarray(g), np.asarray(expect), rtol=1e-5)

    def test_fake_quant_forward_is_reconstruction(self):
        W = _rand_w(jax.random.PRNGKey(13), 24, 8)
        got = bz.fake_quant(W, M=3, K_iters=20)
        expect = bz.reconstruct(bz.algorithm2(W, M=3, K_iters=20))
        np.testing.assert_allclose(np.asarray(got), np.asarray(expect), rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    K=st.sampled_from([8, 16, 32, 64]),
    N=st.integers(1, 12),
    M=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_residual_bounded_by_alg1(K, N, M, seed):
    """Property: Alg-2 residual <= Alg-1 residual for any shape/seed."""
    W = jax.random.normal(jax.random.PRNGKey(seed), (K, N))
    e1 = float(bz.residual_error(W, bz.algorithm1(W, M=M)))
    e2 = float(bz.residual_error(W, bz.algorithm2(W, M=M, K_iters=25)))
    assert e2 <= e1 + 1e-4 * max(e1, 1.0)


@settings(max_examples=20, deadline=None)
@given(
    K=st.sampled_from([8, 24, 40]),
    N=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_reconstruction_values_in_omega(K, N, seed):
    """Every reconstructed weight lies in the 2^M-element set omega (Eq. 3)."""
    M = 3
    W = jax.random.normal(jax.random.PRNGKey(seed), (K, N))
    a = bz.algorithm2(W, M=M, K_iters=25)
    W_hat = np.asarray(bz.reconstruct(a))
    alpha = np.asarray(a.alpha)[:, 0, :]  # [M, N]
    for n in range(N):
        omega = set()
        for signs in np.ndindex(*([2] * M)):
            s = sum((1 if b else -1) * alpha[m, n] for m, b in enumerate(signs))
            omega.add(round(float(s), 4))
        col = {round(float(v), 4) for v in W_hat[:, n]}
        assert col <= omega, (n, col - omega)
