"""Parity tests for the offline hypothesis stub (tests/_hypothesis_stub.py).

Two concerns:

* the stub itself (always imported directly by path, regardless of whether
  the real hypothesis is installed) must keep its contract — deterministic
  draws, honest domains, falsifying-example reporting — because the fuzz
  tier (tests/test_fuzz_programs.py) leans on exactly that surface when the
  container has no real hypothesis;
* a domain property runs under *whichever* implementation conftest.py
  registered, proving the ``@given``/``st.*`` subset the suite uses behaves
  identically under both (same decorator shape, same pass/fail semantics).
"""
import importlib.util
import os
import random

import pytest


def _load_stub():
    spec = importlib.util.spec_from_file_location(
        "_hypothesis_stub_under_test",
        os.path.join(os.path.dirname(__file__), "_hypothesis_stub.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


stub = _load_stub()


# ---------------------------------------------------------------------------
# stub strategy domains
# ---------------------------------------------------------------------------

def test_integers_within_bounds_and_deterministic():
    s = stub.strategies.integers(3, 9)

    def draws(seed):
        rng = random.Random(seed)
        return [s.example(rng) for _ in range(50)]

    a, b = draws(42), draws(42)
    assert a == b                      # same seed -> same draws
    assert all(3 <= v <= 9 for v in a)
    assert len(set(a)) > 1             # actually samples the range


def test_sampled_from_only_yields_members():
    s = stub.strategies.sampled_from(("a", "b", "c"))
    rng = random.Random(0)
    draws = {s.example(rng) for _ in range(60)}
    assert draws == {"a", "b", "c"}


def test_floats_and_booleans_domains():
    rng = random.Random(7)
    f = stub.strategies.floats(-1.0, 1.0)
    assert all(-1.0 <= f.example(rng) <= 1.0 for _ in range(40))
    b = stub.strategies.booleans()
    assert {b.example(rng) for _ in range(40)} == {True, False}


def test_just_lists_tuples_one_of():
    rng = random.Random(3)
    assert stub.strategies.just(17).example(rng) == 17
    ls = stub.strategies.lists(stub.strategies.integers(0, 5),
                               min_size=1, max_size=4)
    for _ in range(30):
        v = ls.example(rng)
        assert 1 <= len(v) <= 4 and all(0 <= x <= 5 for x in v)
    tp = stub.strategies.tuples(stub.strategies.integers(0, 1),
                                stub.strategies.just("x"))
    assert tp.example(rng)[1] == "x"
    oo = stub.strategies.one_of(stub.strategies.just(1),
                                stub.strategies.just(2))
    assert {oo.example(rng) for _ in range(30)} == {1, 2}


def test_map_transforms_draws():
    s = stub.strategies.integers(1, 3).map(lambda v: v * 10)
    rng = random.Random(1)
    assert all(s.example(rng) in (10, 20, 30) for _ in range(20))


# ---------------------------------------------------------------------------
# stub @given/@settings semantics
# ---------------------------------------------------------------------------

def test_given_runs_max_examples_and_reports_falsifying():
    calls = []

    @stub.settings(max_examples=7)
    @stub.given(x=stub.strategies.integers(0, 100))
    def prop(x):
        calls.append(x)

    prop()
    assert len(calls) == 7

    @stub.settings(max_examples=50)
    @stub.given(x=stub.strategies.integers(0, 100))
    def failing(x):
        assert x < 30

    with pytest.raises(AssertionError, match="falsifying example"):
        failing()


def test_given_wrapper_has_zero_arg_signature():
    # pytest must see a no-arg callable, or it hunts for fixtures named
    # like the strategy kwargs (why the stub avoids functools.wraps)
    @stub.given(x=stub.strategies.integers(0, 1))
    def prop(x):
        pass

    assert not hasattr(prop, "__wrapped__")
    prop()   # callable with no args


# ---------------------------------------------------------------------------
# same property under whichever implementation conftest registered
# ---------------------------------------------------------------------------

from hypothesis import given, settings, strategies as st  # noqa: E402


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 64), m=st.sampled_from((1, 2, 4)))
def test_active_implementation_runs_domain_property(n, m):
    # trivially-true arithmetic property — the point is the decorator
    # plumbing: kwargs arrive inside the declared domains under both the
    # stub and real hypothesis
    assert 1 <= n <= 64
    assert m in (1, 2, 4)
    assert (n * m) % m == 0
