"""Compile-once deployment API (repro.deploy): BinArrayProgram tests.

The claims under test (ISSUE 5 acceptance bar):
  * ``compile`` + ``execute`` of packed CNN-A and MobileNet are *bit-exact*
    against the legacy per-call ``QuantConfig.fuse_conv`` forwards;
  * ``pick_tile``/packing run only at compile time — the plan-pick counter
    proves zero scheduling decisions inside the jitted execute trace (and
    that the legacy path does keep re-picking per trace);
  * per-layer ``m_active`` schedules (§IV-D generalized): a schedule equals
    the per-layer reference composition, a global int equals the old
    ``QuantConfig(m_active=k)`` path, entries clamp to each layer's M;
  * programs round-trip through checkpoint/manager.py bit-exact, with an
    abstract (eval_shape) program as the restore target;
  * ``layer_stats()`` is a faithful static description (shape chaining,
    exact MAC accounting vs models/cnn.cnn_a_macs).

MobileNet-B2 proper (224², width 1.0) runs in the slow tier; the fast tier
covers the same code paths at reduced width/resolution.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import deploy
from repro.checkpoint.manager import CheckpointManager
from repro.core import binconv
from repro.core import binlinear as bl
from repro.core.binlinear import QuantConfig
from repro.kernels import binary_conv as bck
from repro.models import cnn

jax.config.update("jax_platform_name", "cpu")

QC = QuantConfig(mode="binary", M=2, K_iters=4, interpret=True)
FUSED = QC.replace(fuse_conv=True, use_pallas=True)


@pytest.fixture(scope="module")
def cnn_a():
    params = cnn.init_cnn_a(jax.random.PRNGKey(0))
    bp = cnn.binarize_cnn_a(params, QC)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 48, 48, 3), jnp.float32)
    prog = deploy.compile(bp, "cnn_a", QC, (3, 48, 48, 3))
    return bp, x, prog


@pytest.fixture(scope="module")
def mobilenet_small():
    params = cnn.init_mobilenet(jax.random.PRNGKey(2), width_mult=0.25,
                                n_classes=10)
    qc = QC.replace(K_iters=2)
    bp = cnn.binarize_mobilenet(params, qc)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, 32, 3), jnp.float32)
    prog = deploy.compile(bp, "mobilenet", qc, (2, 32, 32, 3))
    return bp, x, prog


class TestCompileExecuteBitExact:
    def test_cnn_a_matches_legacy_fused_forward(self, cnn_a):
        bp, x, prog = cnn_a
        want = cnn.cnn_a_forward(bp, x, FUSED)
        got = deploy.execute(prog, x)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_mobilenet_matches_legacy_fused_forward(self, mobilenet_small):
        bp, x, prog = mobilenet_small
        want = cnn.mobilenet_forward(bp, x,
                                     FUSED.replace(K_iters=2))
        got = deploy.execute(prog, x)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_compile_from_fp_tree_equals_compile_from_packed(self, cnn_a):
        """compile() binarizes fp trees with the same offline packing the
        binarize_* helpers use -> identical programs, identical logits."""
        bp, x, prog = cnn_a
        params = cnn.init_cnn_a(jax.random.PRNGKey(0))
        prog_fp = deploy.compile(params, "cnn_a", QC, (3, 48, 48, 3))
        for a, b in zip(jax.tree_util.tree_leaves(prog),
                        jax.tree_util.tree_leaves(prog_fp)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(
            np.asarray(deploy.execute(prog_fp, x)),
            np.asarray(deploy.execute(prog, x)))

    def test_compile_upgrades_legacy_flat_trees_silently(self):
        """Conv params carrying only B_packed compile fine (ensure_tap_packed
        runs at compile time) and never hit the deprecated per-call repack."""
        params = cnn.init_cnn_a(jax.random.PRNGKey(4))
        bp = cnn.binarize_cnn_a(params, QC)
        legacy = {name: {k: v for k, v in layer.items()
                         if k != "B_tap_packed"}
                  for name, layer in bp.items()}
        x = jax.random.normal(jax.random.PRNGKey(5), (2, 48, 48, 3),
                              jnp.float32)
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            prog = deploy.compile(legacy, "cnn_a", QC, (2, 48, 48, 3))
            got = deploy.execute(prog, x)
        for i in prog.instrs:
            if i.kind == "conv":
                assert i.B_tap_packed is not None
        want = cnn.cnn_a_forward(bp, x, FUSED)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_other_batch_sizes_stay_correct(self, cnn_a):
        """Plans are optimized for the compiled batch but valid for any:
        the kernels' tiling bit-exactness covers the clamped plans."""
        bp, _, prog = cnn_a  # compiled for B=3
        x = jax.random.normal(jax.random.PRNGKey(6), (5, 48, 48, 3),
                              jnp.float32)
        want = cnn.cnn_a_forward(bp, x, FUSED)
        got = deploy.execute(prog, x)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestMActiveSchedules:
    def test_global_int_matches_quantconfig_path(self, cnn_a):
        bp, x, prog = cnn_a
        for k in (1, 2):
            want = cnn.cnn_a_forward(bp, x, FUSED.replace(m_active=k))
            got = deploy.execute(prog, x, m_active=k)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_schedule_matches_per_layer_composition(self, cnn_a):
        """[M, M-1, ...]-style schedule == composing the legacy per-layer
        calls with each layer's own m_active, bit-exact."""
        bp, x, prog = cnn_a
        sched = (2, 1, 2, 1, 1)
        got = deploy.execute(prog, x, m_active=sched)
        y = binconv.conv2d_relu_pool(bp["conv1"], x, pool=2,
                                     quant=FUSED.replace(m_active=sched[0]))
        y = binconv.conv2d_relu_pool(bp["conv2"], y, pool=6,
                                     quant=FUSED.replace(m_active=sched[1]))
        y = y.reshape(y.shape[0], -1)
        y = jax.nn.relu(bl.apply_linear(
            bp["fc1"], y, FUSED.replace(m_active=sched[2])))
        y = jax.nn.relu(bl.apply_linear(
            bp["fc2"], y, FUSED.replace(m_active=sched[3])))
        want = bl.apply_linear(bp["fc3"], y, FUSED.replace(m_active=sched[4]))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_schedule_clamps_to_packed_levels(self, cnn_a):
        bp, x, prog = cnn_a
        full = deploy.execute(prog, x)
        over = deploy.execute(prog, x, m_active=[7] * len(prog))
        np.testing.assert_array_equal(np.asarray(full), np.asarray(over))
        assert prog.resolve_schedule(7) == tuple(i.M for i in prog.instrs)

    def test_schedule_validation(self, cnn_a):
        _, _, prog = cnn_a
        with pytest.raises(ValueError, match="entries"):
            prog.resolve_schedule([2, 2])
        with pytest.raises(ValueError, match=">= 1"):
            prog.resolve_schedule([0] * len(prog))
        with pytest.raises(ValueError, match=">= 1"):
            prog.resolve_schedule(0)

    def test_fewer_levels_change_logits(self, cnn_a):
        _, x, prog = cnn_a
        full = deploy.execute(prog, x)
        m1 = deploy.execute(prog, x, m_active=1)
        assert not np.allclose(np.asarray(full), np.asarray(m1))


class TestZeroPlanPicksInTrace:
    def test_execute_trace_runs_zero_plan_picks(self, cnn_a):
        """The acceptance counter: tracing execute() performs no pick_tile /
        pick_bu / pick_matmul_plan calls — plans are frozen in the program —
        while tracing the legacy per-call forward re-picks every time."""
        bp, x, prog = cnn_a
        jax.clear_caches()
        bck.reset_plan_pick_count()
        jax.make_jaxpr(
            lambda p, x: deploy.execute(p, x, m_active=2))(prog, x)
        assert bck.plan_pick_count() == 0
        jax.make_jaxpr(
            lambda x: cnn.cnn_a_forward(bp, x, FUSED))(x)
        assert bck.plan_pick_count() > 0

    def test_compile_is_where_the_picks_happen(self):
        params = cnn.init_cnn_a(jax.random.PRNGKey(7))
        bp = cnn.binarize_cnn_a(params, QC)
        bck.reset_plan_pick_count()
        deploy.compile(bp, "cnn_a", QC, (2, 48, 48, 3))
        assert bck.plan_pick_count() > 0


class TestProgramStructure:
    def test_layer_stats_chain_and_macs(self, cnn_a):
        _, _, prog = cnn_a
        stats = prog.layer_stats()
        assert [s["name"] for s in stats] == ["conv1", "conv2", "fc1", "fc2",
                                              "fc3"]
        # shapes chain: each layer's input is the previous output (modulo
        # the declared pre-op)
        assert stats[0]["out_shape"] == [3, 21, 21, 5]
        assert stats[1]["out_shape"] == [3, 3, 3, 150]
        assert stats[2]["in_shape"] == [3, 1350]          # flatten pre-op
        assert stats[-1]["out_shape"] == [3, 43]
        # MAC accounting is exact vs the hand-derived count
        assert sum(s["macs"] for s in stats) == cnn.cnn_a_macs()

    def test_plans_respect_vmem_budget_default(self, mobilenet_small):
        _, _, prog = mobilenet_small
        for s in prog.layer_stats():
            if s["kind"] in ("conv", "dwconv"):
                assert s["vmem_bytes"] <= bck.DEFAULT_VMEM_BUDGET, s

    def test_quant_overrides_freeze_into_plan(self):
        params = cnn.init_cnn_a(jax.random.PRNGKey(8))
        qc = QC.replace(conv_batch_tile=2, conv_vmem_budget=2 * 2**20)
        prog = deploy.compile(params, "cnn_a", qc, (4, 48, 48, 3))
        assert prog.instrs[1].plan.nb == 2  # conv2: forced batch tile
        x = jax.random.normal(jax.random.PRNGKey(9), (4, 48, 48, 3),
                              jnp.float32)
        base = deploy.compile(params, "cnn_a", QC, (4, 48, 48, 3))
        np.testing.assert_array_equal(
            np.asarray(deploy.execute(prog, x)),
            np.asarray(deploy.execute(base, x)))  # tiling never changes math

    def test_abstract_program_matches_concrete_structure(self, cnn_a):
        _, _, prog = cnn_a
        ab = deploy.abstract_program("cnn_a", QC, (3, 48, 48, 3))
        # eval_shape cannot execute the golden probe, so abstract programs
        # carry golden=None (load_program re-attaches the record from the
        # checkpoint manifest); structure matches modulo that field
        assert ab.golden is None and prog.golden is not None
        assert (jax.tree_util.tree_structure(ab)
                == jax.tree_util.tree_structure(
                    dataclasses.replace(prog, golden=None)))
        assert ab.layer_stats() == prog.layer_stats()
        for got, want in zip(jax.tree_util.tree_leaves(ab),
                             jax.tree_util.tree_leaves(prog)):
            assert got.shape == want.shape and got.dtype == want.dtype

    def test_program_is_jit_transparent(self, cnn_a):
        """The program pytree crosses jit boundaries: plans ride in the
        treedef, weights are leaves."""
        _, x, prog = cnn_a
        leaves, treedef = jax.tree_util.tree_flatten(prog)
        rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
        np.testing.assert_array_equal(
            np.asarray(deploy.execute(rebuilt, x)),
            np.asarray(deploy.execute(prog, x)))


class TestCheckpointRoundTrip:
    def test_program_roundtrip_bit_exact(self, mobilenet_small, tmp_path):
        """save_program -> load_program (abstract target) is bit-exact, both
        in the packed buffers and in the executed logits."""
        _, x, prog = mobilenet_small
        mgr = CheckpointManager(str(tmp_path))
        deploy.save_program(mgr, 0, prog)
        like = deploy.abstract_program(
            "mobilenet", QC.replace(K_iters=2), (2, 32, 32, 3),
            width_mult=0.25, n_classes=10)
        back = deploy.load_program(mgr, 0, like)
        for a, b in zip(jax.tree_util.tree_leaves(prog),
                        jax.tree_util.tree_leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(
            np.asarray(deploy.execute(back, x)),
            np.asarray(deploy.execute(prog, x)))

    def test_roundtrip_preserves_plans_and_stats(self, cnn_a, tmp_path):
        _, _, prog = cnn_a
        mgr = CheckpointManager(str(tmp_path))
        deploy.save_program(mgr, 3, prog, extra={"note": "cnn-a"})
        back = deploy.load_program(
            mgr, 3, deploy.abstract_program("cnn_a", QC, (3, 48, 48, 3)))
        assert back.layer_stats() == prog.layer_stats()
        assert [i.plan for i in back.instrs] == [i.plan for i in prog.instrs]


@pytest.mark.slow
class TestMobileNetB2:
    """The real CNN-B2 (width 1.0, 224²) through compile/execute — nightly
    tier (interpret-mode kernels at 224² are minutes-scale on CPU)."""

    def test_b2_compile_execute_matches_legacy_and_roundtrips(self, tmp_path):
        params = cnn.init_mobilenet(jax.random.PRNGKey(0), width_mult=1.0,
                                    n_classes=1000)
        qc = QuantConfig(mode="binary", M=2, K_iters=1, interpret=True)
        bp = cnn.binarize_mobilenet(params, qc)
        # golden=False: each golden rung is another minutes-scale 224²
        # interpret execute, and this test never self-tests
        prog = deploy.compile(bp, "mobilenet", qc, (1, 224, 224, 3),
                              golden=False)
        # the early maps must be row-tiled (VMEM) and the 7² back half
        # batch-planned — the compile decisions the paper's §IV-E predicts
        stats = {s["name"]: s for s in prog.layer_stats()}
        assert stats["pw0"]["plan"]["bu"] < stats["pw0"]["out_shape"][1]
        assert stats["pw11"]["plan"]["bu"] == 7
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 224, 224, 3),
                              jnp.float32)
        want = cnn.mobilenet_forward(
            bp, x, qc.replace(fuse_conv=True, use_pallas=True))
        got = deploy.execute(prog, x)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        # serialization round-trip of the full B2 program
        mgr = CheckpointManager(str(tmp_path))
        deploy.save_program(mgr, 0, prog)
        back = deploy.load_program(
            mgr, 0, deploy.abstract_program("mobilenet", qc,
                                            (1, 224, 224, 3)))
        for a, b in zip(jax.tree_util.tree_leaves(prog),
                        jax.tree_util.tree_leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
