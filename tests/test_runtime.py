"""Integration tests: fault-tolerant trainer, resume, elastic restore, serve."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cb
from repro.core.binlinear import QuantConfig
from repro.data.tokens import SyntheticTokens
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import Request, Server
from repro.models import api
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig

jax.config.update("jax_platform_name", "cpu")


def _tiny_cfg():
    return cb.reduced(cb.get_config("gemma_2b")).replace(
        n_layers=2, d_model=32, d_ff=64, vocab=64, head_dim=8, dtype="float32")


def _setup(tmp_path, total_steps=12, ckpt_every=5):
    cfg = _tiny_cfg()
    mesh = make_host_mesh()
    opt = adamw(1e-2)
    state = steps_mod.init_train_state(cfg, mesh, opt)
    step_fn, _ = steps_mod.build_train_step(cfg, mesh, opt, donate=False)
    data = SyntheticTokens(cfg.vocab, 16, 4, seed=0)
    tcfg = TrainerConfig(total_steps=total_steps, checkpoint_every=ckpt_every,
                         checkpoint_dir=str(tmp_path), log_every=100)
    return cfg, mesh, Trainer(step_fn, state, data, tcfg), opt


class TestTrainerFaultTolerance:
    def test_loss_decreases(self, tmp_path):
        cfg, mesh, trainer, _ = _setup(tmp_path, total_steps=30)
        with mesh:
            report = trainer.run()
        assert report.steps_run == 30
        assert np.mean(report.losses[-5:]) < np.mean(report.losses[:5])

    def test_kill_and_resume_bit_exact(self, tmp_path):
        """Checkpoint/restart: a job killed at step 10 resumes and produces
        the same final params as an uninterrupted run."""
        # uninterrupted run
        cfg, mesh, t_full, _ = _setup(tmp_path / "a", total_steps=10,
                                      ckpt_every=5)
        with mesh:
            t_full.run()
        w_full = jax.device_get(t_full.state["params"]["embed"]["table"])

        # interrupted: run to 5 (checkpoint), "crash", new trainer resumes
        cfg, mesh, t1, _ = _setup(tmp_path / "b", total_steps=5, ckpt_every=5)
        with mesh:
            t1.run()
        cfg, mesh, t2, _ = _setup(tmp_path / "b", total_steps=10, ckpt_every=5)
        assert t2.maybe_resume()
        assert t2.report.resumed_from == 5
        with mesh:
            t2.run()
        w_resumed = jax.device_get(t2.state["params"]["embed"]["table"])
        np.testing.assert_allclose(w_full, w_resumed, rtol=1e-6)

    def test_straggler_watchdog_fires(self, tmp_path):
        cfg, mesh, trainer, _ = _setup(tmp_path, total_steps=6, ckpt_every=10)
        orig = trainer.step_fn
        calls = {"n": 0}

        def slow_step(state, batch):
            calls["n"] += 1
            if calls["n"] == 4:
                import time

                time.sleep(1.0)  # induced straggler
            return orig(state, batch)

        trainer.step_fn = slow_step
        with mesh:
            report = trainer.run()
        assert any(e["step"] == 3 for e in report.straggler_events), \
            report.straggler_events

    def test_nan_guard_skips_update(self, tmp_path):
        cfg, mesh, trainer, _ = _setup(tmp_path, total_steps=3, ckpt_every=10)
        orig = trainer.step_fn
        calls = {"n": 0}

        def nan_step(state, batch):
            new_state, metrics = orig(state, batch)
            calls["n"] += 1
            if calls["n"] == 2:
                metrics = dict(metrics, loss=jnp.float32(np.nan))
            return new_state, metrics

        trainer.step_fn = nan_step
        with mesh:
            report = trainer.run()
        assert report.nan_skips == 1
        assert report.steps_run == 3

    def test_elastic_restore_different_data_layout(self, tmp_path):
        """Checkpoint written under one device layout restores under another
        (reshard-on-restore): emulated by restoring into a target tree with
        different sharding request (host mesh here is 1 device; the manager
        API path is identical at fleet scale)."""
        from repro.checkpoint.manager import CheckpointManager
        from jax.sharding import NamedSharding, PartitionSpec as P

        cfg, mesh, trainer, opt = _setup(tmp_path, total_steps=5, ckpt_every=5)
        with mesh:
            trainer.run()
        mgr = CheckpointManager(str(tmp_path))
        mesh2 = make_host_mesh()  # "new" mesh after elastic event
        shardings = jax.tree.map(
            lambda x: NamedSharding(mesh2, P()), trainer.state)
        restored, _ = mgr.restore(5, trainer.state, shardings=shardings)
        np.testing.assert_allclose(
            jax.device_get(restored["params"]["final_norm"]["scale"]),
            jax.device_get(trainer.state["params"]["final_norm"]["scale"]),
            rtol=1e-6)


class TestGradCompressionTraining:
    def test_compressed_training_converges(self, tmp_path):
        cfg = _tiny_cfg()
        mesh = make_host_mesh()
        opt = adamw(1e-2)
        state = steps_mod.init_train_state(cfg, mesh, opt)
        from repro.core import compress as gcomp

        state["grad_comp"] = gcomp.init_state(state["params"])
        step_fn, _ = steps_mod.build_train_step(
            cfg, mesh, opt, grad_compress_M=2, donate=False)
        data = SyntheticTokens(cfg.vocab, 16, 4, seed=0)
        losses = []
        with mesh:
            for _ in range(25):
                state, metrics = step_fn(state, data.next_batch())
                losses.append(float(metrics["loss"]))
        assert np.mean(losses[-5:]) < np.mean(losses[:5])


class TestMicrobatching:
    def test_microbatch_matches_full_batch_grads(self):
        cfg = _tiny_cfg()
        mesh = make_host_mesh()
        opt = adamw(1e-2)
        state = steps_mod.init_train_state(cfg, mesh, opt)
        data = SyntheticTokens(cfg.vocab, 16, 8, seed=0)
        batch = data.next_batch()
        full, _ = steps_mod.build_train_step(cfg, mesh, opt, donate=False)
        micro, _ = steps_mod.build_train_step(cfg, mesh, opt, microbatch=4,
                                              donate=False)
        with mesh:
            s1, m1 = full(state, batch)
            s2, m2 = micro(state, batch)
        w1 = jax.device_get(s1["params"]["embed"]["table"])
        w2 = jax.device_get(s2["params"]["embed"]["table"])
        np.testing.assert_allclose(w1, w2, rtol=2e-4, atol=2e-5)


class TestServer:
    def test_batched_serving_completes(self):
        cfg = _tiny_cfg()
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        srv = Server(cfg, params, max_batch=4, max_len=64)
        reqs = [Request(prompt=np.array([1, 2, 3], np.int32),
                        max_new_tokens=5) for _ in range(3)]
        for r in reqs:
            assert srv.admit(r)
        srv.run_until_done()
        for r in reqs:
            assert len(r.out_tokens) == 5
            assert all(0 <= t < cfg.vocab for t in r.out_tokens)

    def test_m_active_per_request_reaches_decode(self):
        """Paper §IV-D through the Server: Request.m_active must actually
        reach the jitted decode step — serving the same prompt with 1 level
        vs all levels yields different logits off the same packed buffers."""
        cfg = _tiny_cfg()
        qc = QuantConfig(mode="binary", M=2, K_iters=4)
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        bp = api.binarize_model_params(cfg, params, qc=qc)
        srv = Server(cfg.replace(quant=qc), bp, max_batch=4, max_len=32)
        prompt = np.array([1, 2, 3], np.int32)
        r_full = Request(prompt=prompt.copy(), max_new_tokens=1)  # all levels
        r_fast = Request(prompt=prompt.copy(), max_new_tokens=1, m_active=1)
        r_expl = Request(prompt=prompt.copy(), max_new_tokens=1, m_active=2)
        for r in (r_full, r_fast, r_expl):
            assert srv.admit(r)
        srv.run_until_done()
        assert r_full.last_logits is not None
        assert r_fast.last_logits is not None
        # fewer levels -> different logits (the switch is observable)
        assert not np.allclose(r_fast.last_logits, r_full.last_logits)
        # explicit m_active == M is the same computation as the default —
        # and shares the default's compiled decode (group-key normalization)
        np.testing.assert_allclose(r_expl.last_logits, r_full.last_logits,
                                   rtol=1e-5, atol=1e-5)
        assert set(srv._decode_fns) == {None, 1}

    def test_mixed_m_active_accepted_for_recurrent_families(self):
        """Per-slot update masks keep non-group slots' SSM/conv state
        bit-exact under grouped decode, so mixed per-request level counts
        now serve for ssm/hybrid too (the PR-1 admit-time rejection is
        gone; correctness is covered by test_serve_prefill.py)."""
        cfg = cb.reduced(cb.get_config("mamba2_2_7b")).replace(dtype="float32")
        qc = QuantConfig(mode="binary", M=2, K_iters=2)
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        bp = api.binarize_model_params(cfg, params, qc=qc)
        srv = Server(cfg.replace(quant=qc), bp, max_batch=2, max_len=16)
        r_full = Request(prompt=np.array([1, 2], np.int32), max_new_tokens=1)
        r_fast = Request(prompt=np.array([1, 2], np.int32), max_new_tokens=1,
                         m_active=1)
        assert srv.admit(r_full)
        assert srv.admit(r_fast)
        srv.run_until_done()
        assert len(r_full.out_tokens) == 1 and len(r_fast.out_tokens) == 1
        # the level switch stays observable inside the mixed batch
        assert not np.allclose(r_fast.last_logits, r_full.last_logits)

    def test_admit_validates_m_active_and_prompt(self):
        """m_active=0 used to be silently clamped by the kernel path —
        admission must surface a clear error instead (m_active > M stays a
        documented serve-full-accuracy clamp)."""
        cfg = _tiny_cfg()
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        srv = Server(cfg, params, max_batch=2, max_len=16)
        with pytest.raises(ValueError, match="m_active"):
            srv.admit(Request(prompt=np.array([1, 2], np.int32), m_active=0))
        with pytest.raises(ValueError, match="m_active"):
            srv.admit(Request(prompt=np.array([1, 2], np.int32), m_active=-3))
        with pytest.raises(ValueError, match="at least one token"):
            srv.admit(Request(prompt=np.array([], np.int32)))
        with pytest.raises(ValueError, match="max_len"):
            srv.admit(Request(prompt=np.array([1, 2], np.int32),
                              max_new_tokens=64))

    def test_decode_matches_forward(self):
        """Step-wise decode with cache reproduces teacher-forced logits."""
        cfg = _tiny_cfg()
        params = api.init_params(cfg, jax.random.PRNGKey(1))
        toks = np.array([[3, 7, 11, 2, 9, 4]], np.int32)
        logits_full, _ = api.forward(cfg, params, {"tokens": jnp.asarray(toks)})
        cache = api.init_cache(cfg, 1, 16)
        outs = []
        for t in range(toks.shape[1]):
            batch = {"tokens": jnp.asarray(toks[:, t: t + 1]),
                     "pos": jnp.asarray([t], jnp.int32), "cache": cache}
            lg, cache = api.decode_step(cfg, params, batch)
            outs.append(np.asarray(lg[:, 0]))
        dec = np.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(logits_full), dec,
                                   rtol=2e-3, atol=2e-3)
