"""Per-layer §IV-D m_active schedules through the LM stack and server.

``deploy.execute`` has taken per-layer schedules since PR 5; this tier
extends the same runtime knob to the *language-model* families:
``QuantConfig.m_schedule`` installs a per-decoder-layer level count
(resolved by ``models.common.layer_quant_cfg`` inside the unrolled layer
walks), and ``launch.serve.Request.m_active`` accepts a sequence so a
single served request can run its early layers fast and late layers
accurate off one set of packed buffers.

Claims under test:
  * a uniform schedule is the SAME trace as the global int — bitwise;
  * a non-uniform schedule differs from every uniform level count (the
    knob is observable) and schedules reach decode AND prefill;
  * the server normalizes: uniform tuples collapse onto the int/None
    compiled variant (bounded compile cache), non-uniform tuples get their
    own variant; admission validates entries;
  * all three edited layer walks (dense scan stack, ssm, hybrid) resolve
    schedules.
"""
import jax
import numpy as np
import pytest

from repro.configs import base as cb
from repro.core.binlinear import QuantConfig
from repro.launch.serve import Request, Server
from repro.models import api

jax.config.update("jax_platform_name", "cpu")

FAMILIES = {
    "transformer": "gemma_2b",
    "ssm": "mamba2_2_7b",
    "hybrid": "zamba2_7b",
}
QC = QuantConfig(mode="binary", M=2, K_iters=2)


def _setup(family):
    cfg = (cb.reduced(cb.get_config(FAMILIES[family]))
           .replace(dtype="float32", quant=QC))
    params = api.binarize_model_params(
        cfg, api.init_params(cfg, jax.random.PRNGKey(0)), qc=QC)
    return cfg, params


def _sched_cfg(cfg, sched):
    return cfg.replace(quant=cfg.quant.replace(m_schedule=tuple(sched)))


def _fwd(cfg, params, toks):
    logits, _ = api.forward(cfg, params, {"tokens": jax.numpy.asarray(toks)})
    return np.asarray(logits)


class TestForwardSchedules:
    @pytest.mark.parametrize("family", list(FAMILIES))
    def test_uniform_schedule_equals_global_int(self, family):
        """(1, 1) is the same per-layer resolution as m_active=1 — the
        schedule walk must produce the identical computation, bitwise."""
        cfg, params = _setup(family)
        toks = [[3, 7, 11, 2]]
        want = _fwd(cfg.replace(quant=cfg.quant.replace(m_active=1)),
                    params, toks)
        got = _fwd(_sched_cfg(cfg, (1, 1)), params, toks)
        np.testing.assert_array_equal(got, want)

    @pytest.mark.parametrize("family", list(FAMILIES))
    def test_mixed_schedule_is_observable(self, family):
        """(1, 2) differs from both uniform settings — each layer really
        gets its own level count."""
        cfg, params = _setup(family)
        toks = [[3, 7, 11, 2]]
        mixed = _fwd(_sched_cfg(cfg, (1, 2)), params, toks)
        for m in (1, 2):
            uni = _fwd(cfg.replace(quant=cfg.quant.replace(m_active=m)),
                       params, toks)
            assert not np.array_equal(mixed, uni)

    def test_short_schedule_extends_last_entry(self):
        """Like deploy.execute's resolve_schedule: a 1-entry schedule
        covers every layer with that entry."""
        cfg, params = _setup("transformer")
        toks = [[3, 7, 11, 2]]
        short = _fwd(_sched_cfg(cfg, (1,)), params, toks)
        full = _fwd(_sched_cfg(cfg, (1, 1)), params, toks)
        np.testing.assert_array_equal(short, full)

    def test_schedule_forces_unrolled_walk_matching_scan(self):
        """scan_layers configs fall back to the unrolled walk under a
        schedule (a scan body cannot vary per layer); the fallback itself
        is numerically faithful: uniform-schedule-under-scan-config equals
        the scanned global-int forward to fp32 round-off."""
        cfg, params = _setup("transformer")
        cfg_scan = cfg.replace(scan_layers=True)
        toks = [[5, 9, 1, 4]]
        want = _fwd(cfg_scan.replace(quant=cfg.quant.replace(m_active=1)),
                    params, toks)
        got = _fwd(_sched_cfg(cfg_scan, (1, 1)), params, toks)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


class TestServedSchedules:
    @pytest.mark.parametrize("family", list(FAMILIES))
    def test_request_schedule_matches_baked_in_config(self, family):
        """A request carrying m_active=[1, 2] must serve exactly like a
        server whose config bakes m_schedule=(1, 2) in — prefill and
        decode both route through the schedule-specialized variants."""
        cfg, params = _setup(family)
        prompt = np.array([3, 7, 11, 2], np.int32)
        srv_req = Server(cfg, params, max_batch=2, max_len=32)
        r_sched = Request(prompt=prompt.copy(), max_new_tokens=3,
                          m_active=[1, 2])
        assert srv_req.admit(r_sched)
        srv_req.run_until_done()

        srv_baked = Server(_sched_cfg(cfg, (1, 2)), params, max_batch=2,
                           max_len=32)
        r_plain = Request(prompt=prompt.copy(), max_new_tokens=3)
        assert srv_baked.admit(r_plain)
        srv_baked.run_until_done()

        assert r_sched.out_tokens == r_plain.out_tokens
        np.testing.assert_array_equal(r_sched.last_logits,
                                      r_plain.last_logits)

    def test_schedule_differs_from_uniform_serving(self):
        cfg, params = _setup("transformer")
        prompt = np.array([3, 7, 11, 2], np.int32)
        srv = Server(cfg, params, max_batch=2, max_len=32)
        r_sched = Request(prompt=prompt.copy(), max_new_tokens=1,
                          m_active=[1, 2])
        r_full = Request(prompt=prompt.copy(), max_new_tokens=1)
        assert srv.admit(r_sched) and srv.admit(r_full)
        srv.run_until_done()
        assert not np.array_equal(r_sched.last_logits, r_full.last_logits)

    def test_uniform_tuple_collapses_onto_int_variant(self):
        """(1, 1), 1, and (2, 2) (== M == default) must not each compile
        their own decode: the normalizer collapses uniform schedules, so
        the compile-cache bound stays M+1 plus the distinct non-uniform
        schedules actually served."""
        cfg, params = _setup("transformer")
        srv = Server(cfg, params, max_batch=4, max_len=32)
        assert srv._norm_m((1, 1)) == 1
        assert srv._norm_m([2, 2]) is None     # uniform M == default
        assert srv._norm_m((1, 2)) == (1, 2)
        assert srv._norm_m([7, 7]) is None     # clamps to M, then default
        prompt = np.array([3, 7], np.int32)
        for m in ((1, 1), 1):
            assert srv.admit(Request(prompt=prompt.copy(), max_new_tokens=1,
                                     m_active=m))
        srv.run_until_done()
        assert srv.cache_sizes()["decode_fns"] == 1
        assert set(srv._decode_fns) == {1}

    def test_distinct_schedules_get_distinct_variants(self):
        cfg, params = _setup("transformer")
        srv = Server(cfg, params, max_batch=4, max_len=32)
        prompt = np.array([3, 7], np.int32)
        for m in ((1, 2), (2, 1), None):
            assert srv.admit(Request(prompt=prompt.copy(), max_new_tokens=1,
                                     m_active=m))
        srv.run_until_done()
        assert set(srv._decode_fns) == {(1, 2), (2, 1), None}

    def test_admit_validates_schedule_entries(self):
        cfg, params = _setup("transformer")
        srv = Server(cfg, params, max_batch=2, max_len=16)
        with pytest.raises(ValueError, match="m_active"):
            srv.admit(Request(prompt=np.array([1, 2], np.int32),
                              m_active=[1, 0]))
        with pytest.raises(ValueError, match="m_active"):
            srv.admit(Request(prompt=np.array([1, 2], np.int32),
                              m_active=[]))
