"""Deployment-path tests: model-wide binarization (the paper's technique as
a serving feature) + runtime m_active switch + dry-run lowering."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cb
from repro.core.binlinear import QuantConfig
from repro.models import api

jax.config.update("jax_platform_name", "cpu")

# model-wide binarize+forward sweeps: ~3.5 min on CPU — nightly tier
pytestmark = pytest.mark.slow


def _cfg(arch="qwen3_14b", **kw):
    cfg = cb.reduced(cb.get_config(arch)).replace(dtype="float32", **kw)
    return cfg


class TestModelBinarization:
    def test_binary_forward_approximates_dense(self):
        cfg = _cfg()
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8),
                                              0, cfg.vocab)}
        dense_logits, _ = api.forward(cfg, params, batch)
        errs = []
        for M in (1, 2, 4):
            qc = QuantConfig(mode="binary", M=M, K_iters=10)
            bp = api.binarize_model_params(cfg, params, qc=qc)
            bcfg = cfg.replace(quant=qc)
            blogits, _ = api.forward(bcfg, bp, batch)
            assert blogits.shape == dense_logits.shape
            errs.append(float(jnp.mean(
                (blogits - dense_logits).astype(jnp.float32) ** 2)))
        # Table II trend: error decreases monotonically with M
        assert errs[0] > errs[1] > errs[2], errs
        assert np.isfinite(errs[-1])

    def test_m_active_runtime_switch_on_model(self):
        """Paper §IV-D: same packed buffers, fewer levels -> larger error."""
        cfg = _cfg()
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8),
                                              0, cfg.vocab)}
        dense_logits, _ = api.forward(cfg, params, batch)
        qc4 = QuantConfig(mode="binary", M=4, K_iters=10)
        bp = api.binarize_model_params(cfg, params, qc=qc4)
        errs = {}
        for m_active in (1, 2, 4):
            bcfg = cfg.replace(quant=qc4.replace(m_active=m_active))
            lg, _ = api.forward(bcfg, bp, batch)
            errs[m_active] = float(jnp.mean(
                (lg - dense_logits).astype(jnp.float32) ** 2))
        assert errs[1] > errs[2] > errs[4], errs

    def test_excluded_leaves_stay_fp(self):
        cfg = _cfg("deepseek_v3_671b")
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        bp = api.binarize_model_params(
            cfg, params, qc=QuantConfig(mode="binary", M=2, K_iters=2))
        assert "table" in bp["embed"]                      # embeddings fp
        assert "w" in bp["layers"]["moe"]["router"]        # router fp
        assert "w" in bp["layers"]["attn"]["wuk"]          # MLA factor fp
        assert "B_packed" in bp["layers"]["attn"]["wdkv"]  # projections packed

    def test_packed_bytes_are_sixteenth_of_bf16_at_M2(self):
        cfg = _cfg()
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        bp = api.binarize_model_params(
            cfg, params, qc=QuantConfig(mode="binary", M=2, K_iters=2))

        def linear_bytes(tree, key):
            tot = 0
            for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
                pstr = "/".join(str(getattr(p, "key", p)) for p in path)
                if key in pstr and "attn" in pstr:
                    tot += leaf.size * leaf.dtype.itemsize
            return tot

        dense_b = sum(
            l.size * 2  # as-if bf16
            for p, l in jax.tree_util.tree_flatten_with_path(params)[0]
            if "attn" in "/".join(str(getattr(x, "key", x)) for x in p)
            and "/w" in "/".join(str(getattr(x, "key", x)) for x in p))
        packed_b = linear_bytes(bp, "B_packed")
        assert dense_b / packed_b > 7, (dense_b, packed_b)  # ~8x at M=2

    def test_binary_decode_step(self):
        cfg = _cfg()
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        qc = QuantConfig(mode="binary", M=2, K_iters=4)
        bp = api.binarize_model_params(cfg, params, qc=qc)
        bcfg = cfg.replace(quant=qc)
        cache = api.init_cache(bcfg, 2, 16)
        batch = {"tokens": jnp.zeros((2, 1), jnp.int32),
                 "pos": jnp.zeros((2,), jnp.int32), "cache": cache}
        logits, _ = api.decode_step(bcfg, bp, batch)
        assert logits.shape == (2, 1, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    def test_eval_shape_lowering_compatible(self):
        """The packed tree must be buildable abstractly (dry-run path)."""
        cfg = _cfg()
        qc = QuantConfig(mode="binary", M=2, K_iters=2)
        shapes = jax.eval_shape(
            lambda k: api.binarize_model_params(
                cfg, api.init_params(cfg, k), qc=qc),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        leaves = jax.tree.leaves(shapes)
        assert all(hasattr(l, "shape") for l in leaves)
        assert any(l.dtype == jnp.uint8 for l in leaves)  # packed buffers
