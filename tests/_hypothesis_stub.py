"""Minimal in-repo stand-in for ``hypothesis`` (used when it isn't installed).

The container this suite runs in has no network access, so the dev extra
(``pip install -e .[dev]``) may not be installable.  conftest.py registers
this module as ``hypothesis`` in that case, covering exactly the surface the
tests use: ``@settings(max_examples=..., deadline=...)``, ``@given(**kw)``,
``st.integers(lo, hi)``, ``st.sampled_from(seq)``, ``st.booleans()``,
``st.floats(lo, hi)``, ``st.just(v)``, ``st.lists(elem, ...)``,
``st.tuples(*elems)``, ``st.one_of(*strats)``, and ``.map(f)``.

Sampling is deterministic (seeded per test name) so runs are reproducible;
with the real hypothesis installed this module is never imported.
tests/test_hypothesis_stub.py pins the stub's behavior (determinism, draw
domains, falsifying-example reporting) so the offline tier and the
CI-with-real-hypothesis tier exercise the same property surface.
"""
from __future__ import annotations

import random


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)

    def map(self, f) -> "_Strategy":
        return _Strategy(lambda rng: f(self._draw(rng)))


class strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def sampled_from(seq) -> _Strategy:
        seq = list(seq)
        return _Strategy(lambda rng: rng.choice(seq))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def just(value) -> _Strategy:
        return _Strategy(lambda rng: value)

    @staticmethod
    def lists(elements: _Strategy, *, min_size: int = 0,
              max_size: int = 5) -> _Strategy:
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.example(rng) for _ in range(n)]

        return _Strategy(draw)

    @staticmethod
    def tuples(*elems: _Strategy) -> _Strategy:
        return _Strategy(lambda rng: tuple(e.example(rng) for e in elems))

    @staticmethod
    def one_of(*strats: _Strategy) -> _Strategy:
        return _Strategy(lambda rng: rng.choice(strats).example(rng))


_DEFAULT_MAX_EXAMPLES = 20


def given(**strategies_kw):
    def deco(fn):
        def wrapper():
            n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(fn.__qualname__)
            for _ in range(n):
                kw = {k: s.example(rng) for k, s in strategies_kw.items()}
                try:
                    fn(**kw)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (hypothesis stub): {kw}") from e

        # NOT functools.wraps: that sets __wrapped__, which pytest unwraps to
        # the original signature and then hunts for fixtures named like the
        # strategy kwargs.  The zero-arg signature must stay visible.
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._hypothesis_stub = True
        return wrapper

    return deco


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco
