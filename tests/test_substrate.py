"""Substrate tests: optimizers, data pipelines, checkpointing, compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core import compress as gcomp
from repro.data.images import SyntheticGTSRB
from repro.data.tokens import SyntheticTokens
from repro.optim import adamw, sgd, warmup_cosine, exponential_decay

jax.config.update("jax_platform_name", "cpu")


class TestOptimizers:
    def _quadratic(self):
        target = jnp.asarray([1.0, -2.0, 3.0])
        params = {"w": jnp.zeros(3)}

        def loss(p):
            return jnp.sum((p["w"] - target) ** 2)

        return params, loss, target

    @pytest.mark.parametrize("make", [
        lambda: adamw(0.1), lambda: sgd(0.05, momentum=0.9)])
    def test_converges_on_quadratic(self, make):
        opt = make()
        params, loss, target = self._quadratic()
        state = opt.init(params)
        for step in range(200):
            g = jax.grad(loss)(params)
            params, state = opt.update(g, state, params, jnp.int32(step))
        np.testing.assert_allclose(np.asarray(params["w"]),
                                   np.asarray(target), atol=1e-2)

    def test_grad_clip(self):
        from repro.optim import clip_by_global_norm

        g = {"a": jnp.ones(4) * 100.0}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(200.0)
        total = jnp.sqrt(jnp.sum(clipped["a"] ** 2))
        assert float(total) == pytest.approx(1.0, rel=1e-5)

    def test_schedules(self):
        s = warmup_cosine(1.0, 10, 100)
        assert float(s(jnp.int32(0))) == 0.0
        assert float(s(jnp.int32(10))) == pytest.approx(1.0, rel=1e-3)
        assert float(s(jnp.int32(100))) < 0.2
        e = exponential_decay(5e-4, 0.5, 10)
        assert float(e(jnp.int32(10))) == pytest.approx(2.5e-4)


class TestDataPipelines:
    def test_tokens_deterministic_resume(self):
        """Fault tolerance: a pipeline restored from state replays batches."""
        p1 = SyntheticTokens(100, 16, 4, seed=7)
        _ = p1.next_batch()
        saved = p1.state_dict()
        b2 = p1.next_batch()
        p2 = SyntheticTokens(100, 16, 4, seed=7)
        p2.load_state_dict(saved)
        b2_replay = p2.next_batch()
        np.testing.assert_array_equal(np.asarray(b2["tokens"]),
                                      np.asarray(b2_replay["tokens"]))

    def test_tokens_host_sharding_disjoint(self):
        a = SyntheticTokens(100, 8, 8, seed=1, host_id=0, n_hosts=2)
        b = SyntheticTokens(100, 8, 8, seed=1, host_id=1, n_hosts=2)
        ba, bb = a.next_batch(), b.next_batch()
        assert ba["tokens"].shape == (4, 8)
        assert not np.array_equal(np.asarray(ba["tokens"]),
                                  np.asarray(bb["tokens"]))

    def test_labels_are_next_tokens(self):
        p = SyntheticTokens(50, 12, 2, seed=3)
        b = p.next_batch()
        # labels[t] is the token following tokens[t] in the raw stream
        assert b["tokens"].shape == b["labels"].shape
        np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                      np.asarray(b["labels"][:, :-1]))

    def test_images_learnable(self):
        ds = SyntheticGTSRB(n_classes=5, seed=0)
        x, y = ds.batch(32, rng=np.random.default_rng(0))
        assert x.shape == (32, 48, 48, 3) and y.shape == (32,)
        # same class → template correlation higher than cross-class
        x0 = np.asarray(x)
        same = [np.corrcoef(x0[i].ravel(),
                            np.asarray(ds.templates[int(y[i])]).ravel())[0, 1]
                for i in range(8)]
        other = [np.corrcoef(
            x0[i].ravel(),
            np.asarray(ds.templates[(int(y[i]) + 1) % 5]).ravel())[0, 1]
            for i in range(8)]
        # noisy by design (~90% trained accuracy target) — raw-pixel
        # correlation is weak under ±5px shifts; the class signal just has
        # to dominate cross-class correlation (conv layers are shift-robust)
        assert np.mean(same) > np.mean(other) + 0.05
        assert np.mean(same) > 0.05


class TestCheckpointing:
    def test_atomic_save_restore_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
                 "step": jnp.int32(5)}
        mgr.save(5, state, extra={"data_state": {"seed": 1, "step": 9}})
        assert mgr.latest_step() == 5
        restored, extra = mgr.restore(5, state)
        np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                      np.asarray(state["params"]["w"]))
        assert extra["data_state"]["step"] == 9

    def test_gc_keeps_latest(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        state = {"w": jnp.zeros(2)}
        for s in (1, 2, 3, 4):
            mgr.save(s, state)
        assert mgr.all_steps() == [3, 4]

    def test_interrupted_write_invisible(self, tmp_path):
        """A partial (non-manifest) dir is never listed as a checkpoint."""
        mgr = CheckpointManager(str(tmp_path))
        os.makedirs(tmp_path / "step_0000000007")
        # no manifest.json inside
        assert mgr.latest_step() is None

    def test_restore_dtype_mismatch_is_loud(self, tmp_path):
        """A dtype mismatch between checkpoint and target is an error that
        names the leaf and both dtypes — silent coercion once masked a
        float leaf landing in a packed uint8 slot.  allow_cast=True makes
        the conversion explicit for intentional precision changes."""
        from repro.checkpoint.manager import LeafMismatch
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"w": jnp.ones((3,), jnp.float32)})
        target = {"w": jnp.zeros((3,), jnp.bfloat16)}
        with pytest.raises(LeafMismatch, match="'w'.*float32.*bfloat16"):
            mgr.restore(1, target)
        restored, _ = mgr.restore(1, target, allow_cast=True)
        assert restored["w"].dtype == jnp.bfloat16


class TestGradientCompression:
    def test_error_feedback_unbiased_over_time(self):
        """Compressed-SGD with error feedback tracks exact SGD on a convex
        problem (the residual memory absorbs the per-step bias)."""
        target = jnp.asarray(np.random.default_rng(0).normal(size=16))
        w_exact = jnp.zeros(16)
        w_comp = jnp.zeros(16)
        state = gcomp.init_state({"w": w_comp})
        lr = 0.05
        for _ in range(300):
            g_exact = 2 * (w_exact - target)
            w_exact = w_exact - lr * g_exact
            g = {"w": 2 * (w_comp - target)}
            cg, state = gcomp.compress_grads(g, state, M=1)
            w_comp = w_comp - lr * cg["w"]
        # both converge to the target
        assert float(jnp.max(jnp.abs(w_comp - target))) < 0.05

    def test_higher_M_smaller_error(self):
        g = {"w": jnp.asarray(np.random.default_rng(1).normal(size=256))}
        errs = []
        for M in (1, 2, 4):
            state = gcomp.init_state(g)
            cg, _ = gcomp.compress_grads(g, state, M=M)
            errs.append(float(jnp.mean((cg["w"] - g["w"]) ** 2)))
        assert errs[0] > errs[1] > errs[2]

    def test_wire_bytes_ratio(self):
        g = {"w": jnp.zeros((1024, 1024))}
        comp, unc = gcomp.wire_bytes(g, M=2)
        assert unc / comp > 15  # ~16x for M=2 vs fp32
