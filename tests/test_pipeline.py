"""Pipeline-parallelism validation.

The numeric check needs >= 4 devices, so it runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main test process
must keep seeing 1 device — assignment rule)."""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.pipeline import (make_pipeline_mesh, pipeline_apply,
                                       reference_apply)

    def stage_fn(p, h):
        return jnp.tanh(h @ p["w"] + p["b"])

    n_stages, D, B = 4, 16, 24
    key = jax.random.PRNGKey(0)
    kw, kb, kx = jax.random.split(key, 3)
    params = {
        "w": jax.random.normal(kw, (n_stages, D, D)) * 0.5,
        "b": jax.random.normal(kb, (n_stages, D)) * 0.1,
    }
    x = jax.random.normal(kx, (B, D))
    mesh = make_pipeline_mesh(n_pipe=n_stages)
    with mesh:
        y = pipeline_apply(stage_fn, params, x, mesh=mesh, n_micro=6)
    ref = reference_apply(stage_fn, params, x)
    err = float(jnp.max(jnp.abs(y - ref)))
    assert err < 1e-5, err
    # ppermute schedule present in the lowered HLO
    with mesh:
        txt = jax.jit(lambda p, x: pipeline_apply(
            stage_fn, p, x, mesh=mesh, n_micro=6)).lower(params, x).as_text()
    assert ("collective_permute" in txt) or ("collective-permute" in txt)
    print("PIPELINE_OK", err)
""")


def test_gpipe_schedule_matches_reference():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "PIPELINE_OK" in out.stdout, (out.stdout[-2000:], out.stderr[-2000:])
