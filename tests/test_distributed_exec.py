"""Multi-device execute_sharded: bit-exactness on an 8-virtual-device mesh.

The acceptance bar for the distributed runtime: ``execute_sharded`` under
any :class:`MeshPlan` returns *bitwise* the arrays ``deploy.execute``
returns — for CNN-A (pure data parallelism: no layer is bd-shardable) and
reduced MobileNet (data x model 4x2: the point-wise layers split their
output channels), across global / per-layer §IV-D schedules and ragged
batches, with zero trace-time plan picks and no retraces on repeat calls.

This module needs 8 devices.  CPU provides them virtually::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m pytest tests/test_distributed_exec.py

(the CI fast tier runs exactly that); under a plain single-device run the
whole module skips.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import deploy, distributed
from repro.analysis import verify_mesh_plan
from repro.core.binlinear import QuantConfig
from repro.kernels import binary_conv as bck
from repro.models import cnn

jax.config.update("jax_platform_name", "cpu")

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")

QC = QuantConfig(mode="binary", M=2, K_iters=4, interpret=True)


@pytest.fixture(scope="module")
def cnn_a():
    params = cnn.init_cnn_a(jax.random.PRNGKey(0))
    bp = cnn.binarize_cnn_a(params, QC)
    prog = deploy.compile(bp, "cnn_a", QC, (8, 48, 48, 3))
    plan = distributed.plan_mesh(prog, n_data=8)
    return prog, plan


@pytest.fixture(scope="module")
def mobilenet():
    params = cnn.init_mobilenet(jax.random.PRNGKey(2), width_mult=0.25,
                                n_classes=10)
    qc = QC.replace(K_iters=2)
    bp = cnn.binarize_mobilenet(params, qc)
    prog = deploy.compile(bp, "mobilenet", qc, (8, 32, 32, 3))
    plan = distributed.plan_mesh(prog, n_data=4, n_model=2,
                                 min_shard_bytes=0)
    return prog, plan


def _x(key, b, hw):
    return jax.random.normal(jax.random.PRNGKey(key), (b, hw, hw, 3),
                             jnp.float32)


def _assert_parity(prog, plan, x, m_active):
    want = deploy.execute(prog, x, m_active=m_active)
    got = distributed.execute_sharded(prog, plan, x, m_active=m_active)
    assert got.shape == want.shape
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestPureDataParallel:
    """CNN-A: every layer replicated, the batch splits 8 ways."""

    def test_plan_shape(self, cnn_a):
        prog, plan = cnn_a
        assert plan.devices == 8
        assert all(s.kind == "replicated" for s in plan.shards)
        assert verify_mesh_plan(prog, plan) == []

    @pytest.mark.parametrize("m_active", [None, 1, 2, (2, 1, 2, 1, 1)])
    def test_bit_exact_full_batch(self, cnn_a, m_active):
        prog, plan = cnn_a
        _assert_parity(prog, plan, _x(1, 8, 48), m_active)

    @pytest.mark.parametrize("batch", [5, 11])
    def test_bit_exact_ragged_batch(self, cnn_a, batch):
        """B % n_data != 0: zero images pad the last column(s) and the
        output slices back to B — still bitwise."""
        prog, plan = cnn_a
        _assert_parity(prog, plan, _x(2, batch, 48), (2, 1, 2, 1, 1))


class TestModelParallel:
    """MobileNet 4x2: point-wise layers split output channels over the
    model axis; channel slices are gathered without any fp reduction."""

    def test_plan_shards_pointwise_layers(self, mobilenet):
        prog, plan = mobilenet
        assert plan.devices == 8 and plan.n_model == 2
        assert sum(1 for s in plan.shards if s.kind == "bd") > 0
        assert verify_mesh_plan(prog, plan) == []

    @pytest.mark.parametrize("m_active", [None, 1, "mix"])
    def test_bit_exact_full_batch(self, mobilenet, m_active):
        prog, plan = mobilenet
        if m_active == "mix":
            m_active = tuple((i % 2) + 1 for i in range(len(prog.instrs)))
        _assert_parity(prog, plan, _x(3, 8, 32), m_active)

    def test_bit_exact_ragged_batch(self, mobilenet):
        prog, plan = mobilenet
        sched = tuple((i % 2) + 1 for i in range(len(prog.instrs)))
        _assert_parity(prog, plan, _x(4, 5, 32), sched)


class TestNoPicksNoRetraces:
    def test_sharded_execution_runs_zero_plan_picks(self, mobilenet):
        """The distributed tier inherits the compiler's contract: every
        tile decision (including the device-local bd plans) froze at
        plan_mesh time — tracing the sharded forward picks nothing."""
        prog, plan = mobilenet
        bck.reset_plan_pick_count()
        distributed.execute_sharded(prog, plan, _x(5, 8, 32), m_active=2)
        assert bck.plan_pick_count() == 0

    def test_repeat_calls_do_not_retrace(self, cnn_a):
        prog, plan = cnn_a
        x = _x(6, 8, 48)
        distributed.execute_sharded(prog, plan, x, m_active=1)
        distributed.reset_trace_entry_count()
        distributed.execute_sharded(prog, plan, x, m_active=1)
        assert distributed.trace_entry_count() == 0
        assert distributed.cache_stats()["sharded_fns"] > 0


class TestValidation:
    def test_shard_arity_mismatch_raises(self, cnn_a, mobilenet):
        prog, _ = cnn_a
        _, wrong_plan = mobilenet
        with pytest.raises(ValueError, match="shard"):
            distributed.execute_sharded(prog, wrong_plan, _x(7, 8, 48))


class TestCNNServiceOnMesh:
    def test_service_with_mesh_plan_serves_bit_exact(self, cnn_a):
        """CNNService(mesh_plan=...) routes batches through
        execute_sharded — answers stay bit-exact vs the single-device
        service, so every SLO/fault contract carries over."""
        from repro.serve_cnn import CNNService

        prog, plan = cnn_a
        imgs = np.asarray(_x(8, 4, 48))
        answers = {}
        for mp in (None, plan):
            svc = CNNService(prog, batch_size=8, mesh_plan=mp)
            reqs = [svc.submit(img) for img in imgs]
            svc.drain()
            assert all(r.status == "done" for r in reqs)
            answers[mp is None] = np.stack([r.logits for r in reqs])
        np.testing.assert_array_equal(answers[True], answers[False])

    def test_service_validates_mesh_plan(self, cnn_a, mobilenet):
        from repro.serve_cnn import CNNService

        prog, plan = cnn_a
        with pytest.raises(ValueError, match="divide"):
            CNNService(prog, batch_size=4, mesh_plan=plan)  # 4 % 8 != 0
        _, wrong = mobilenet
        with pytest.raises(ValueError, match="shard"):
            CNNService(prog, batch_size=8, mesh_plan=wrong)
