"""Benchmark driver: one module per paper table + kernel + roofline.

Prints ``name,us_per_call,derived`` CSV rows (scaffold contract).
Usage:  PYTHONPATH=src python -m benchmarks.run [--quick] [--only table2,...]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    ("table2", "benchmarks.table2_accuracy"),
    ("table3", "benchmarks.table3_throughput"),
    ("table4", "benchmarks.table4_resources"),
    ("kernel", "benchmarks.kernel_bench"),
    ("roofline", "benchmarks.roofline_bench"),
    ("serve", "benchmarks.serve_bench"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failed = 0
    for key, modname in MODULES:
        if only and key not in only:
            continue
        t0 = time.time()
        try:
            import importlib

            mod = importlib.import_module(modname)
            for name, secs, derived in mod.run(quick=args.quick):
                print(f"{name},{secs * 1e6:.0f},{derived}")
        except Exception as e:  # noqa: BLE001
            failed += 1
            print(f"{key}_FAILED,0,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
        print(f"{key}_total,{(time.time() - t0) * 1e6:.0f},", flush=True)
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
