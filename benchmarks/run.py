"""Benchmark driver: one module per paper table + kernel + roofline.

Prints ``name,us_per_call,derived`` CSV rows (scaffold contract).  With
``--json PATH`` it additionally writes the machine-readable perf trajectory:
every selected module that exports ``run_structured(quick)`` contributes
JSON-ready dicts of its *derived* metrics (VMEM/HBM bytes, MXU occupancy,
tile picks, device-call counts — no CPU wall times, which are noise), plus
the CSV rows themselves, plus a ``program`` section with the deploy
compiler's per-layer tile plans and MAC/byte stats
(``BinArrayProgram.layer_stats()`` for CNN-A and MobileNet-B1/B2) and a
``verify`` section (repro.analysis finding counts + rule coverage per
program), so future PRs can diff runtime perf, compile-time decisions, and
static-analysis cleanliness without parsing the human-oriented derived
strings.  A ``meta`` block (schema version, git sha, jax version, platform)
makes artifacts pairable: ``tools/bench_diff.py`` diffs two such documents
and fails CI on occupancy/VMEM/device-call regressions vs the committed
``BENCH_baseline.json``.  CI uploads ``BENCH_kernel.json`` next to the CSV
artifact (.github/workflows/ci.yml).

Usage:  PYTHONPATH=src python -m benchmarks.run [--quick] [--only table2,...]
                                                [--json BENCH_kernel.json]
"""
from __future__ import annotations

import argparse
import json
import platform
import subprocess
import sys
import time
import traceback

# Version of the --json document layout.  Bump on any structural change to
# the emitted sections (modules/structured row schemas, program, verify,
# distributed) — tools/bench_diff.py refuses to compare documents whose
# schema differs, so a layout change can never masquerade as a perf change.
# v2: added the ``distributed`` section (per-mesh MeshPlan byte splits).
SCHEMA_VERSION = 2

MODULES = [
    ("table2", "benchmarks.table2_accuracy"),
    ("table3", "benchmarks.table3_throughput"),
    ("table4", "benchmarks.table4_resources"),
    ("kernel", "benchmarks.kernel_bench"),
    ("roofline", "benchmarks.roofline_bench"),
    ("serve", "benchmarks.serve_bench"),
]

# compile-time sections of the JSON artifact: per-layer tile plans, VMEM/HBM
# bytes, and MAC counts straight from BinArrayProgram.layer_stats() (abstract
# compile — jax.eval_shape, no weights computed), so BENCH_kernel.json tracks
# the deploy compiler's decisions PR over PR.
PROGRAMS = {
    "cnn_a": ("cnn_a", (8, 48, 48, 3), {}),
    "mobilenet_b1": ("mobilenet", (8, 128, 128, 3), {"width_mult": 0.5}),
    "mobilenet_b2": ("mobilenet", (8, 224, 224, 3), {}),
}


def program_section() -> dict:
    from repro import deploy
    from repro.core.binlinear import QuantConfig

    qc = QuantConfig(mode="binary", M=2, K_iters=1)
    out = {}
    for key, (arch, shape, kw) in PROGRAMS.items():
        prog = deploy.abstract_program(arch, qc, shape, **kw)
        out[key] = {"totals": prog.totals(), "layers": prog.layer_stats()}
    return out


# meshes the distributed section plans every program onto: pure data
# parallelism and the data x model split (both 8 devices, so the per-device
# numbers are directly comparable across rows)
MESHES = {
    "dp8": (8, 1),
    "dp4_mp2": (4, 2),
}


def distributed_section() -> dict:
    """Per-mesh MeshPlan accounting for every tracked program: how many
    bytes of packed weights / VMEM working set / gather traffic one device
    carries under ``plan_mesh`` (abstract compile — no weights, no devices).
    ``tools/bench_diff.py`` gates these: a planner change that grows a
    per-device working set, re-replicates previously sharded weights, or
    inflates gather traffic is a regression."""
    from repro import deploy, distributed
    from repro.core.binlinear import QuantConfig

    qc = QuantConfig(mode="binary", M=2, K_iters=1)
    out = {}
    for key, (arch, shape, kw) in PROGRAMS.items():
        prog = deploy.abstract_program(arch, qc, shape, **kw)
        out[key] = {
            mesh: distributed.mesh_totals(
                prog, distributed.plan_mesh(prog, n_data=nd, n_model=nm))
            for mesh, (nd, nm) in MESHES.items()
        }
    return out


def verify_section() -> dict:
    """Static-analysis roll-up for the JSON artifact: per-program finding
    counts from ``repro.analysis.verify_program`` + the execute-trace lint,
    plus which rules exist/fired — so BENCH_kernel.json records that the
    shipped plans are clean (tools/verify_program.py is the failing gate;
    this is the trajectory record)."""
    from repro import deploy
    from repro.analysis import mosaic_rules, summarize, trace_lint
    from repro.analysis import verify_program as _verify
    from repro.core.binlinear import QuantConfig

    qc = QuantConfig(mode="binary", M=2, K_iters=1)
    out: dict = {"rules": sorted(mosaic_rules.RULES)}
    fired: set[str] = set()
    for key, (arch, shape, kw) in PROGRAMS.items():
        prog = deploy.abstract_program(arch, qc, shape, **kw)
        findings = _verify(prog) + trace_lint.lint_execute(prog,
                                                           interpret=True)
        out[key] = summarize(findings)
        fired.update(out[key]["by_rule"])
    out["rules_fired"] = sorted(fired)
    return out


def meta_section(quick: bool, only: str) -> dict:
    """Provenance block so artifacts pair: two BENCH_*.json files are
    comparable iff their schema matches (bench_diff enforces it), and the
    git sha / jax version / platform say *what* produced each side."""
    import jax

    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, timeout=10, check=True).stdout.strip()
    except Exception:  # noqa: BLE001 — not a checkout / git missing
        sha = "unknown"
    return {
        "schema_version": SCHEMA_VERSION,
        "git_sha": sha,
        "jax_version": jax.__version__,
        "platform": platform.platform(),
        "python_version": platform.python_version(),
        "quick": quick,
        "only": only,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="also write derived metrics as JSON "
                         "(e.g. BENCH_kernel.json)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failed = 0
    doc: dict = {"quick": args.quick, "modules": {},
                 "meta": meta_section(args.quick, args.only)}
    for key, modname in MODULES:
        if only and key not in only:
            continue
        t0 = time.time()
        try:
            import importlib

            mod = importlib.import_module(modname)
            csv_rows = []
            for name, secs, derived in mod.run(quick=args.quick):
                print(f"{name},{secs * 1e6:.0f},{derived}")
                csv_rows.append({"name": name, "us_per_call": secs * 1e6,
                                 "derived": derived})
            entry: dict = {"csv_rows": csv_rows}
            doc["modules"][key] = entry  # csv_rows survive a structured fail
            if hasattr(mod, "run_structured"):
                try:
                    entry["structured"] = mod.run_structured(quick=args.quick)
                except Exception as e:  # noqa: BLE001
                    failed += 1
                    entry["structured_error"] = f"{type(e).__name__}: {e}"
                    print(f"{key}_structured_FAILED,0,{type(e).__name__}: {e}")
                    traceback.print_exc(file=sys.stderr)
        except Exception as e:  # noqa: BLE001
            failed += 1
            print(f"{key}_FAILED,0,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
            doc["modules"][key] = {"error": f"{type(e).__name__}: {e}"}
        print(f"{key}_total,{(time.time() - t0) * 1e6:.0f},", flush=True)
    if args.json:
        try:
            doc["program"] = program_section()
        except Exception as e:  # noqa: BLE001
            failed += 1
            doc["program"] = {"error": f"{type(e).__name__}: {e}"}
            print(f"program_section_FAILED,0,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
        try:
            doc["verify"] = verify_section()
        except Exception as e:  # noqa: BLE001
            failed += 1
            doc["verify"] = {"error": f"{type(e).__name__}: {e}"}
            print(f"verify_section_FAILED,0,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
        try:
            doc["distributed"] = distributed_section()
        except Exception as e:  # noqa: BLE001
            failed += 1
            doc["distributed"] = {"error": f"{type(e).__name__}: {e}"}
            print(f"distributed_section_FAILED,0,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        print(f"json_written,0,{args.json}", file=sys.stderr)
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
