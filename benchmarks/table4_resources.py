"""Table IV reproduction: resource-usage model for BinArray configs, plus the
TPU translation (HBM bytes for packed vs dense weights).

FPGA side (paper §V-B4):
  * DSP = N_SA * M_arch (exactly — one MAC per PA);
  * weight BRAM = N_c*D_arch bits per PA + alpha distributed RAM;
  * CNN-A fits in BRAM; CNN-B adds a 4 Mb global weight buffer.

TPU side (the adaptation's equivalent claim): binary-packed weights divide
HBM weight bytes by 16/M vs bf16 — reported per assigned-arch config.
"""
from __future__ import annotations

import time

from repro.core import perf_model as pm

PAPER_DSP = {(1, 8, 2): 2, (1, 32, 2): 2, (4, 32, 4): 16, (16, 32, 4): 64}
DSP_TOTAL = 900  # XC7Z045


def weight_bits(layers, M: int, *, bits_alpha: int = 8) -> int:
    total = 0
    for lyr in layers:
        if isinstance(lyr, pm.DenseLayer):
            n_c, d = lyr.N_in, lyr.N_out
        else:
            n_c = lyr.W_B * lyr.H_B * (1 if lyr.depthwise else lyr.C_I)
            d = lyr.D
        total += M * (n_c + bits_alpha) * d
    return total


def run(quick: bool = False):
    rows = []
    for cfg_t, dsp_expect in PAPER_DSP.items():
        nsa, d, march = cfg_t
        cfg = pm.BinArrayConfig(nsa, d, march)
        dsp = nsa * march
        t0 = time.time()
        rows.append((f"table4_dsp_{cfg}", time.time() - t0,
                     f"dsp={dsp} paper_dsp={dsp_expect} "
                     f"util_pct={100 * dsp / DSP_TOTAL:.2f} match={dsp == dsp_expect}"))
    # BRAM model: CNN-A binary weights fit on-chip (paper: 1.15% of 19.2Mb)
    a_bits = weight_bits(pm.cnn_a_layers(), M=4)
    rows.append(("table4_bram_cnn_a", 0.0,
                 f"weight_Mb={a_bits / 1e6:.2f} fits_19.2Mb={a_bits < 19.2e6}"))
    b2_bits = weight_bits(pm.mobilenet_layers(alpha=1.0, resolution=224), M=4)
    rows.append(("table4_bram_cnn_b2", 0.0,
                 f"weight_Mb={b2_bits / 1e6:.1f} needs_global_buffer="
                 f"{b2_bits > 19.2e6 * 0.5}"))
    # TPU translation: packed-vs-bf16 weight bytes for assigned archs
    from repro.configs import base as cb
    from repro.models import api

    for arch in ("gemma_2b", "qwen3_14b", "deepseek_v3_671b"):
        cfg = cb.get_config(arch)
        n = api.count_params(cfg)
        for M in (2, 4):
            dense_gb = n * 2 / 1e9
            packed_gb = n * M / 8 / 1e9
            rows.append((
                f"table4_tpu_{arch}_M{M}", 0.0,
                f"bf16_GB={dense_gb:.1f} packed_GB={packed_gb:.1f} "
                f"ratio={dense_gb / packed_gb:.1f}"))
    return rows


if __name__ == "__main__":
    for name, secs, derived in run():
        print(f"{name},{secs * 1e6:.0f},{derived}")
