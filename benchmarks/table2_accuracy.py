"""Table II reproduction: compression factor + accuracy, Alg-1 vs Alg-2,
no-retrain vs retrain, as a function of M.

CNN-A on synthetic GTSRB-43 (data/images.py).  The paper's claims under
test:
  (1) compression factor tracks Eq. 6 (bits_w/M asymptote);
  (2) Algorithm 2 >= Algorithm 1 accuracy (both regimes);
  (3) accuracy is monotone in M for Algorithm 2 (Alg-1 is not guaranteed);
  (4) retraining (STE, low lr) recovers most of the fp baseline.

Runs in ~3-4 min on CPU with a reduced training budget; the structure (not
ImageNet-scale wall time) is the reproduction target.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binarize as bz
from repro.core.binlinear import QuantConfig
from repro.data.images import SyntheticGTSRB
from repro.models import cnn
from repro.optim import adamw


def _accuracy(params, x, y, quant=QuantConfig(mode="dense")):
    logits = cnn.cnn_a_forward(params, x, quant)
    return float(jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32)))


def _train(params, ds, *, steps, lr, quant=QuantConfig(mode="dense"),
           batch=64, seed=0):
    opt = adamw(lr)
    state = opt.init(params)
    rng = np.random.default_rng(seed)

    @jax.jit
    def step(params, state, opt_step, x, y):
        def loss(p):
            lg = cnn.cnn_a_forward(p, x, quant)
            logp = jax.nn.log_softmax(lg)
            return -jnp.mean(jnp.take_along_axis(logp, y[:, None], 1))

        g = jax.grad(loss)(params)
        return opt.update(g, state, params, opt_step)

    for i in range(steps):
        x, y = ds.batch(batch, rng=rng)
        params, state = step(params, state, jnp.int32(i), x, y)
    return params


def run(quick: bool = False):
    rows = []
    ds = SyntheticGTSRB(n_classes=43, seed=0)
    x_eval, y_eval = ds.eval_set(128 if quick else 384)
    t0 = time.time()
    params = cnn.init_cnn_a(jax.random.PRNGKey(0))
    params = _train(params, ds, steps=40 if quick else 150, lr=1e-3,
                    batch=32 if quick else 64)
    base_acc = _accuracy(params, x_eval, y_eval)
    rows.append(("cnn_a_fp_baseline", time.time() - t0, f"acc={base_acc:.4f}"))

    Ms = (2, 4) if quick else (2, 3, 4)
    for M in Ms:
        # compression factor (Eq. 6) for the big conv layer (N_c = 4*4*5)
        cf = bz.compression_factor(4 * 4 * 5, M, bits_w=32, bits_alpha=8)
        for algo in (1, 2):
            qc = QuantConfig(mode="fake_quant", M=M, algorithm=algo,
                             K_iters=8 if algo == 2 else 0)
            t1 = time.time()
            acc_no_rt = _accuracy(params, x_eval, y_eval, qc)
            # retrain: paper uses 1 epoch, low lr (1e-4, Adam) with STE
            rt = _train(jax.tree.map(jnp.copy, params), ds,
                        steps=10 if quick else 60, lr=1e-4, quant=qc, seed=M,
                        batch=32 if quick else 64)
            acc_rt = _accuracy(rt, x_eval, y_eval, qc)
            rows.append((
                f"table2_M{M}_alg{algo}", time.time() - t1,
                f"cf={cf:.1f} acc_no_retrain={acc_no_rt:.4f} "
                f"acc_retrain={acc_rt:.4f} baseline={base_acc:.4f}"))
    return rows


if __name__ == "__main__":
    for name, secs, derived in run():
        print(f"{name},{secs * 1e6:.0f},{derived}")
