"""Serving benches: LM admission latency + CNN SLO degradation under load.

**Admission** — admission used to cost O(prompt_len) jitted decode steps
per request (token-wise cache warmup); bulk prefill replaces that with ONE
forward pass plus a cache scatter (launch/serve.py).  CPU wall times are
not TPU-indicative; the structural column is ``device_calls`` — the number
of device programs an admission dispatches, recorded from ``Server.stats``
(1 bulk prefill vs prompt_len-1 token-wise steps).

**CNN SLO** — p50/p99 latency vs offered load for the SLO-governed CNN
service (repro.serve_cnn), plus its degradation histogram and shed
fraction.  The simulation runs entirely on a virtual clock with a §IV-E
cost-model executor (batch service time proportional to
``slo.schedule_cost`` of the served rung), so every number is a
deterministic function of the policy — machine-independent, which is what
lets ``tools/bench_diff.py`` gate on them: a controller change that raises
p99 under overload, sheds more, or completes less is a policy regression
CI catches.

**CNN recovery** — watchdog self-test cadence and hot-reload latency after
a seeded in-memory bit flip (docs/checkpointing.md).  Same virtual-clock
determinism: detect latency in batches/virtual ms, BIST runs per 100
batches, and a bit-exactness flag on the recovered program are gated by
bench_diff so the recovery path cannot silently slow down or stop working.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import base as cb
from repro.launch.serve import Request, Server
from repro.models import api


def _admit_time(srv: Server, prompt: np.ndarray, iters: int) -> float:
    # warm the jit caches with one throwaway admission, then time re-admits
    srv.admit(Request(prompt=prompt.copy(), max_new_tokens=1))
    srv.slots = [None] * srv.max_batch
    t0 = time.time()
    for _ in range(iters):
        srv.admit(Request(prompt=prompt.copy(), max_new_tokens=1))
        srv.slots = [None] * srv.max_batch
    return (time.time() - t0) / iters


_CACHE: dict = {}

# virtual-time constants for the CNN SLO simulation: one ingest frame per
# step plus a batch service time that scales with the served rung's
# schedule_cost.  EXEC_FULL_S sits just under the 10 ms target so the
# low-load case is calm at full-M and the overload case (queue wait added)
# is decisively over it.
_CNN_FRAME_S = 0.002
_CNN_EXEC_FULL_S = 0.009
_CNN_TARGET_MS = 10.0
_CNN_STEPS = 120


def _cnn_slo_rows():
    """CNN SLO bench: p50/p99 virtual latency + shed/degraded fractions at
    two offered loads.  Entirely deterministic — ManualClock, cost-model
    executor, zero images — so the numbers are a pure function of the
    ladder + controller policy and bench_diff can gate on them."""
    from repro.serve_cnn import CNNService, SLOConfig, schedule_cost
    from repro.testing.faults import ManualClock
    from repro.testing.scenarios import tiny_cnn_program

    program = tiny_cnn_program(batch=4)
    full_cost = schedule_cost(program, None)
    img = np.zeros(tuple(program.input_shape[1:]), np.float32)
    # logits shape from one real (clean) execute; the simulation itself
    # never touches the device — its executor only advances the clock
    from repro import deploy

    probe = np.asarray(deploy.execute(
        program, np.zeros(tuple(program.input_shape), np.float32)))
    out_tail = probe.shape[1:]

    rows, structured = [], []
    # low: under capacity (batch_size=4/step) -> calm at full-M.
    # high: 2.5x capacity -> queue wait blows the target, the controller
    # walks the ladder and sheds; the histogram shows the whole response.
    for label, offered in (("low", 2), ("high", 10)):
        clock = ManualClock()

        def execute_fn(prog, x, m_active=None, *, interpret=None,
                       _clock=clock):
            cost = schedule_cost(prog, m_active)
            _clock.advance(_CNN_EXEC_FULL_S * cost / full_cost)
            return np.zeros((x.shape[0],) + out_tail, np.float32)

        svc = CNNService(
            program,
            slo=SLOConfig(target_ms=_CNN_TARGET_MS, window=16,
                          min_samples=4, recover_after=2),
            batch_size=4, max_queue=16,
            clock=clock, sleep=clock.sleep, execute_fn=execute_fn)
        for _ in range(_CNN_STEPS):
            clock.advance(_CNN_FRAME_S)
            for _r in range(offered):
                svc.submit(img)
            svc.step()
        svc.drain()
        s = svc.stats
        submitted = s["admitted"] + s["shed_count"]
        degraded = sum(v for k, v in s["rung_hist"].items() if k > 0)
        shed_fraction = round(s["shed_count"] / submitted, 4)
        degraded_fraction = round(degraded / s["batches"], 4)
        p50_ms = round(s["p50_latency_s"] * 1e3, 3)
        p99_ms = round(s["p99_latency_s"] * 1e3, 3)
        rows.append((
            f"serve_cnn_slo_{label}", s["p99_latency_s"],
            f"offered={offered}/step p50={p50_ms}ms "
            f"shed={shed_fraction:.0%} degraded={degraded_fraction:.0%} "
            f"rungs={sorted(s['rung_hist'])}",
        ))
        structured.append({
            "name": f"serve_cnn_slo_{label}", "kind": "cnn_slo",
            "offered_per_step": offered, "steps": _CNN_STEPS,
            "target_ms": _CNN_TARGET_MS,
            "p50_virtual_ms": p50_ms, "p99_virtual_ms": p99_ms,
            "shed_fraction": shed_fraction,
            "degraded_fraction": degraded_fraction,
            "completed": s["completed"],
            "rung_hist": {str(k): v
                          for k, v in sorted(s["rung_hist"].items())},
        })
    return rows, structured


_CNN_SELFTEST_EVERY = 3


def _cnn_recovery_rows():
    """CNN recovery bench: golden self-test cadence + hot-reload latency.

    Seeds one in-memory bit flip into the live program's packed weights and
    measures the watchdog's response on the virtual clock: how many batches
    (and virtual ms) pass before the flip is detected and the service has
    hot-reloaded from the checkpoint, how often the BIST runs per 100
    batches, and whether the recovered program is bit-exact against the
    pre-fault reference.  ManualClock + seeded injector + cost-model
    executor — the self-test itself runs the real (clean) execute path, so
    the numbers are a pure function of the watchdog policy and bench_diff
    can gate on them: a watchdog change that detects later, self-tests
    more per batch, or recovers inexactly is a regression CI catches."""
    import dataclasses
    import tempfile

    from repro import deploy
    from repro.checkpoint.manager import CheckpointManager
    from repro.serve_cnn import CNNService, SLOConfig, schedule_cost
    from repro.testing.faults import FaultInjector, FaultPlan, ManualClock
    from repro.testing.scenarios import tiny_cnn_program

    program = tiny_cnn_program(batch=4)
    full_cost = schedule_cost(program, None)
    img = np.zeros(tuple(program.input_shape[1:]), np.float32)
    x_ref = np.zeros(tuple(program.input_shape), np.float32)
    ref = np.asarray(deploy.execute(program, x_ref))
    out_tail = ref.shape[1:]

    mgr = CheckpointManager(tempfile.mkdtemp(prefix="bench_ckpt_"), keep=2)
    deploy.save_program(mgr, 0, program)
    clock = ManualClock()

    def execute_fn(prog, x, m_active=None, *, interpret=None):
        cost = schedule_cost(prog, m_active)
        clock.advance(_CNN_EXEC_FULL_S * cost / full_cost)
        return np.zeros((x.shape[0],) + out_tail, np.float32)

    svc = CNNService(
        program,
        slo=SLOConfig(target_ms=_CNN_TARGET_MS, window=16,
                      min_samples=4, recover_after=2),
        batch_size=4, max_queue=16,
        clock=clock, sleep=clock.sleep, execute_fn=execute_fn,
        selftest_every=_CNN_SELFTEST_EVERY,
        checkpoint_manager=mgr,
        restore_like=dataclasses.replace(program, golden=None))

    def step_once():
        clock.advance(_CNN_FRAME_S)
        for _ in range(2):
            svc.submit(img)
        svc.step()

    warm_steps = 12
    for _ in range(warm_steps):
        step_once()
    assert svc.stats["reloads"] == 0 and svc.stats["selftest_failures"] == 0

    inj = FaultInjector(FaultPlan(seed=5), sleep=clock.sleep)
    svc.program = inj.flip_bit_in_program(svc.program)
    flip_batch, flip_t = svc.stats["batches"], clock()
    for _ in range(2 * _CNN_SELFTEST_EVERY + 2):
        if svc.stats["reloads"]:
            break
        step_once()
    assert svc.stats["reloads"] == 1, "watchdog never detected the flip"
    detect_batches = svc.stats["batches"] - flip_batch
    detect_virtual_ms = round((clock() - flip_t) * 1e3, 3)

    for _ in range(warm_steps):  # post-recovery steady state
        step_once()
    svc.drain()
    s = svc.stats
    recovered = np.asarray(deploy.execute(svc.program, x_ref))
    bit_exact = int(np.array_equal(recovered, ref))
    per_100 = round(100.0 * s["selftest_runs"] / max(s["batches"], 1), 3)
    rows = [(
        "serve_cnn_recovery", detect_virtual_ms / 1e3,
        f"detect={detect_batches}batches selftest/100batches={per_100} "
        f"reloads={s['reloads']} bit_exact={bit_exact}",
    )]
    structured = [{
        "name": "serve_cnn_recovery", "kind": "cnn_recovery",
        "selftest_every": _CNN_SELFTEST_EVERY,
        "selftest_per_100_batches": per_100,
        "reload_detect_batches": detect_batches,
        "reload_detect_virtual_ms": detect_virtual_ms,
        "reloads": s["reloads"],
        "selftest_failures": s["selftest_failures"],
        "recovered_bit_exact": bit_exact,
        "completed": s["completed"],
    }]
    return rows, structured


def _bench(quick: bool):
    """Shared body for ``run``/``run_structured`` — cached per quick flag so
    the driver's CSV + JSON passes dispatch the admissions only once."""
    if quick in _CACHE:
        return _CACHE[quick]
    rows, structured = [], []
    iters = 2 if quick else 3
    prompt_len = 12 if quick else 24
    cases = [("gemma_2b", "dense"), ("mamba2_2_7b", "ssm")]
    if quick:
        cases = cases[:1]
    for arch, fam in cases:
        cfg = cb.reduced(cb.get_config(arch)).replace(dtype="float32")
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        prompt = (np.arange(prompt_len, dtype=np.int32) % cfg.vocab) + 1
        for mode in ("bulk", "tokenwise"):
            srv = Server(cfg, params, max_batch=2, max_len=2 * prompt_len,
                         prefill=mode)
            secs = _admit_time(srv, prompt, iters)
            per_admit = (1 if mode == "bulk"
                         else srv.stats["tokenwise_prefill_steps"] // (iters + 1))
            rows.append((
                f"serve_admit_{mode}_{fam}", secs,
                f"prompt_len={prompt_len} device_calls_per_admit={per_admit}",
            ))
            structured.append({
                "name": f"serve_admit_{mode}_{fam}", "kind": "admission",
                "prompt_len": prompt_len,
                "device_calls_per_admit": per_admit})
    # CNN SLO section: deterministic regardless of quick (virtual clock),
    # so the quick-generated committed baseline gates full runs too.
    # Its secs column is the *virtual* p99 — policy output, not wall time.
    cnn_rows, cnn_structured = _cnn_slo_rows()
    rows.extend(cnn_rows)
    structured.extend(cnn_structured)
    # CNN recovery section: watchdog detect latency + BIST cadence, same
    # virtual-clock determinism (its secs column is virtual detect latency)
    rec_rows, rec_structured = _cnn_recovery_rows()
    rows.extend(rec_rows)
    structured.extend(rec_structured)
    _CACHE[quick] = (rows, structured)
    return _CACHE[quick]


def run(quick: bool = False):
    return _bench(quick)[0]


def run_structured(quick: bool = False):
    """Machine-readable admission metrics for ``benchmarks/run.py --json``."""
    return _bench(quick)[1]


if __name__ == "__main__":
    for name, secs, derived in run():
        print(f"{name},{secs * 1e6:.0f},{derived}")
