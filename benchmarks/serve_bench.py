"""Serving admission-latency bench: bulk prefill vs token-wise warmup.

Admission used to cost O(prompt_len) jitted decode steps per request
(token-wise cache warmup); bulk prefill replaces that with ONE forward pass
plus a cache scatter (launch/serve.py).  CPU wall times are not
TPU-indicative; the structural column is ``device_calls`` — the number of
device programs an admission dispatches, recorded from ``Server.stats``
(1 bulk prefill vs prompt_len-1 token-wise steps).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import base as cb
from repro.launch.serve import Request, Server
from repro.models import api


def _admit_time(srv: Server, prompt: np.ndarray, iters: int) -> float:
    # warm the jit caches with one throwaway admission, then time re-admits
    srv.admit(Request(prompt=prompt.copy(), max_new_tokens=1))
    srv.slots = [None] * srv.max_batch
    t0 = time.time()
    for _ in range(iters):
        srv.admit(Request(prompt=prompt.copy(), max_new_tokens=1))
        srv.slots = [None] * srv.max_batch
    return (time.time() - t0) / iters


_CACHE: dict = {}


def _bench(quick: bool):
    """Shared body for ``run``/``run_structured`` — cached per quick flag so
    the driver's CSV + JSON passes dispatch the admissions only once."""
    if quick in _CACHE:
        return _CACHE[quick]
    rows, structured = [], []
    iters = 2 if quick else 3
    prompt_len = 12 if quick else 24
    cases = [("gemma_2b", "dense"), ("mamba2_2_7b", "ssm")]
    if quick:
        cases = cases[:1]
    for arch, fam in cases:
        cfg = cb.reduced(cb.get_config(arch)).replace(dtype="float32")
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        prompt = (np.arange(prompt_len, dtype=np.int32) % cfg.vocab) + 1
        for mode in ("bulk", "tokenwise"):
            srv = Server(cfg, params, max_batch=2, max_len=2 * prompt_len,
                         prefill=mode)
            secs = _admit_time(srv, prompt, iters)
            per_admit = (1 if mode == "bulk"
                         else srv.stats["tokenwise_prefill_steps"] // (iters + 1))
            rows.append((
                f"serve_admit_{mode}_{fam}", secs,
                f"prompt_len={prompt_len} device_calls_per_admit={per_admit}",
            ))
            structured.append({
                "name": f"serve_admit_{mode}_{fam}", "kind": "admission",
                "prompt_len": prompt_len,
                "device_calls_per_admit": per_admit})
    _CACHE[quick] = (rows, structured)
    return _CACHE[quick]


def run(quick: bool = False):
    return _bench(quick)[0]


def run_structured(quick: bool = False):
    """Machine-readable admission metrics for ``benchmarks/run.py --json``."""
    return _bench(quick)[1]


if __name__ == "__main__":
    for name, secs, derived in run():
        print(f"{name},{secs * 1e6:.0f},{derived}")
