"""Roofline table from the dry-run result cache (deliverable g).

Reads experiments/dryrun/*.json and prints one row per (arch, shape, mesh):
three roofline terms, the dominant bound, and MODEL_FLOPS/HLO_FLOPS.
"""
from __future__ import annotations

import glob
import json
import os

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "dryrun")


def load_records(tag: str = ""):
    recs = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if tag and r.get("tag") != tag:
            continue
        if not tag and r.get("tag"):
            continue
        recs.append(r)
    return recs


def run(quick: bool = False):
    rows = []
    for r in load_records():
        name = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
        if r["status"] == "skipped":
            rows.append((name, 0.0, f"SKIP: {r['reason'][:60]}"))
            continue
        if r["status"] != "ok":
            rows.append((name, 0.0, f"ERROR: {r.get('error', '?')[:80]}"))
            continue
        step_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / step_s if step_s else 0.0
        rows.append((
            name, r.get("total_s", 0.0),
            f"compute_s={r['compute_s']:.3f} memory_s={r['memory_s']:.3f} "
            f"collective_s={r['collective_s']:.3f} bound={r['bound']} "
            f"roofline_frac={frac:.3f} "
            f"model_flops_ratio={r.get('model_flops_ratio', 0):.2f}"))
    if not rows:
        rows.append(("roofline_no_results", 0.0,
                     "run: python -m repro.launch.dryrun --all --mesh both"))
    return rows


if __name__ == "__main__":
    for name, secs, derived in run():
        print(f"{name},{secs * 1e6:.0f},{derived}")
