"""Table III reproduction: throughput (fps) of BinArray configs vs the
hypothetical 1-GOPS CPU, via the analytical performance model (Eq. 14-18).

Prints our MAC-exact model's fps next to the paper's numbers with ratios.
"""
from __future__ import annotations

import time

from repro.core import perf_model as pm

PAPER = [
    # (net, M, cfg, paper_fps)
    ("cnn_a", 2, (1, 8, 2), 354.2),
    ("cnn_a", 2, (1, 32, 2), 819.8),
    ("cnn_b1", 4, (1, 8, 2), 46.7),
    ("cnn_b1", 4, (1, 32, 2), 92.5),
    ("cnn_b1", 4, (4, 32, 4), 728.4),
    ("cnn_b1", 4, (16, 32, 4), 3845.5),
    ("cnn_b2", 4, (1, 8, 2), 2.6),
    ("cnn_b2", 4, (1, 32, 2), 7.7),
    ("cnn_b2", 4, (4, 32, 4), 74.3),
    ("cnn_b2", 4, (16, 32, 4), 350.0),
    ("cnn_b1", 6, (16, 32, 4), 1036.0),
    ("cnn_b2", 6, (16, 32, 4), 175.0),
]

PAPER_CPU = {"cnn_a": 111.8, "cnn_b1": 20.6, "cnn_b2": 1.8}


def _net(name):
    if name == "cnn_a":
        return pm.cnn_a_layers(), False
    if name == "cnn_b1":
        return pm.mobilenet_layers(alpha=0.5, resolution=128), True
    return pm.mobilenet_layers(alpha=1.0, resolution=224), True


def run(quick: bool = False):
    rows = []
    for net, M, (nsa, d, march), paper_fps in PAPER:
        t0 = time.time()
        layers, excl = _net(net)
        cfg = pm.BinArrayConfig(nsa, d, march)
        ours = pm.fps(cfg, layers, M=M, exclude_final_dense=excl)
        rows.append((
            f"table3_{net}_M{M}_{cfg}", time.time() - t0,
            f"model_fps={ours:.1f} paper_fps={paper_fps} "
            f"ratio={ours / paper_fps:.2f}"))
    for net, paper_fps in PAPER_CPU.items():
        layers, _ = _net(net)
        ours = pm.cpu_fps(layers)
        rows.append((f"table3_cpu_{net}", 0.0,
                     f"model_fps={ours:.1f} paper_fps={paper_fps} "
                     f"ratio={ours / paper_fps:.2f}"))
    return rows


if __name__ == "__main__":
    for name, secs, derived in run():
        print(f"{name},{secs * 1e6:.0f},{derived}")
