"""Table III reproduction: throughput (fps) of BinArray configs vs the
hypothetical 1-GOPS CPU, via the analytical performance model (Eq. 14-18).

Prints our MAC-exact model's fps next to the paper's numbers with ratios,
plus a per-layer utilization cross-reference: the paper's Table III scaling
holds only while every PA stays busy (D fills the D_arch·N_LSA lanes each
pass), and our Pallas port's analog is the MXU row occupancy the (NB, BU)
batch tile buys.  Every layer list here is program-derived: pm.cnn_a_layers
/ pm.mobilenet_layers re-derive from an abstract BinArrayProgram compile,
and the xref rows read program.layer_stats() directly.  The
``table3_util_xref_*`` rows put both numbers side by side for the
MobileNet-B2 layers so Table III rows and kernel_bench rows cross-reference:
layers where the paper's PA utilization is high but our per-image row
occupancy was low (the 7² back half) are exactly where the batch tile must
fold images to reach the paper's utilization story.
"""
from __future__ import annotations

import time

from repro.core import perf_model as pm

PAPER = [
    # (net, M, cfg, paper_fps)
    ("cnn_a", 2, (1, 8, 2), 354.2),
    ("cnn_a", 2, (1, 32, 2), 819.8),
    ("cnn_b1", 4, (1, 8, 2), 46.7),
    ("cnn_b1", 4, (1, 32, 2), 92.5),
    ("cnn_b1", 4, (4, 32, 4), 728.4),
    ("cnn_b1", 4, (16, 32, 4), 3845.5),
    ("cnn_b2", 4, (1, 8, 2), 2.6),
    ("cnn_b2", 4, (1, 32, 2), 7.7),
    ("cnn_b2", 4, (4, 32, 4), 74.3),
    ("cnn_b2", 4, (16, 32, 4), 350.0),
    ("cnn_b1", 6, (16, 32, 4), 1036.0),
    ("cnn_b2", 6, (16, 32, 4), 175.0),
]

PAPER_CPU = {"cnn_a": 111.8, "cnn_b1": 20.6, "cnn_b2": 1.8}


def _net(name):
    if name == "cnn_a":
        return pm.cnn_a_layers(), False
    if name == "cnn_b1":
        return pm.mobilenet_layers(alpha=0.5, resolution=128), True
    return pm.mobilenet_layers(alpha=1.0, resolution=224), True


def pa_utilization(cfg: pm.BinArrayConfig, layer: pm.ConvLayer,
                   M: int) -> float:
    """Fraction of the D_arch·N_LSA PA lanes carrying real filters each
    pass: D / (n_pass · D_arch · N_LSA), capped at 1 — the hardware-side
    utilization behind the paper's Table III scaling."""
    d_arch = 1 if layer.depthwise else cfg.D_arch
    lanes = d_arch * pm.n_lsa(cfg, M)
    return min(layer.D / (pm.n_pass(cfg, layer.D, M, layer.depthwise)
                          * lanes), 1.0)


# MobileNet-B2 layers to cross-reference, by layer name in the compiled
# program (models/cnn.py MOBILENET_SPECS — the same names kernel_bench uses)
XREF_LAYERS = ("stem", "pw0", "pw5", "pw11", "pw12")


def utilization_xref_rows(B: int = 128):
    """Per-layer (paper PA utilization) × (our MXU row occupancy) rows for
    the Table III headline config BinArray[16, 32, 4] at M=4 (B = a bulk
    serving batch — the pick minimizes the batch's total padded rows).

    Both columns read the same compiled program: the tile plans and
    occupancies come straight from ``program.layer_stats()`` of an abstract
    M=4 compile at batch B, and the paper-side ConvLayers are
    ``pm.layers_from_program`` over the very same program."""
    from repro import deploy
    from repro.core.binlinear import QuantConfig
    from repro.kernels import binary_conv as bck

    cfg = pm.BinArrayConfig(16, 32, 4)
    # m=4 matches the paper side: both columns describe the M=4 config
    prog = deploy.abstract_program(
        "mobilenet", QuantConfig(mode="binary", M=4, K_iters=1),
        (B, 224, 224, 3))
    stats = prog.layer_stats()
    layers = pm.layers_from_stats(stats)
    rows = []
    for s, lyr in zip(stats, layers):
        if s["name"] not in XREF_LAYERS:
            continue
        plan = s["plan"]
        V = s["out_shape"][2] * s["pool"]
        occ1 = bck.mxu_row_occupancy(bck.gemm_rows(1, plan["bu"], V))
        rows.append((
            f"table3_util_xref_{s['name']}_{s['in_shape'][1]}", 0.0,
            f"pa_util_paper={pa_utilization(cfg, lyr, 4):.2f} "
            f"mxu_row_occ_per_image={occ1:.2f} "
            f"mxu_row_occ_batched={s['mxu_row_occupancy']:.2f} "
            f"nb={plan['nb']} bu={plan['bu']}"))
    return rows


def run(quick: bool = False):
    rows = []
    for net, M, (nsa, d, march), paper_fps in PAPER:
        t0 = time.time()
        layers, excl = _net(net)
        cfg = pm.BinArrayConfig(nsa, d, march)
        ours = pm.fps(cfg, layers, M=M, exclude_final_dense=excl)
        rows.append((
            f"table3_{net}_M{M}_{cfg}", time.time() - t0,
            f"model_fps={ours:.1f} paper_fps={paper_fps} "
            f"ratio={ours / paper_fps:.2f}"))
    for net, paper_fps in PAPER_CPU.items():
        layers, _ = _net(net)
        ours = pm.cpu_fps(layers)
        rows.append((f"table3_cpu_{net}", 0.0,
                     f"model_fps={ours:.1f} paper_fps={paper_fps} "
                     f"ratio={ours / paper_fps:.2f}"))
    rows.extend(utilization_xref_rows())
    return rows


if __name__ == "__main__":
    for name, secs, derived in run():
        print(f"{name},{secs * 1e6:.0f},{derived}")
