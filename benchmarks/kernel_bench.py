"""Kernel micro-bench: binary matmul vs dense reference, and the fused
implicit-GEMM conv kernel vs the HBM-materialized im2col path.

CPU wall times (interpret-mode Pallas) are NOT TPU-indicative; the derived
columns that matter are the analytic VMEM working set, HBM bytes per tile,
MXU row occupancy, per-output weight-unpack work, and arithmetic intensity —
the quantities the BlockSpec design controls (see kernels/binary_matmul.py
and kernels/binary_conv.py docstrings).

Layer shapes are NOT hand-maintained: every conv/dw row comes from
``program.layer_stats()`` of an abstractly-compiled BinArrayProgram
(repro.deploy.abstract_program — jax.eval_shape, so no weights are ever
computed), which in turn derives every slab/VMEM/occupancy number through
the kernel modules' own exported analytics (``slab_rows``,
``tile_vmem_bytes``, ``tile_hbm_bytes``, ``pick_tile``, ...).  The bench
therefore cannot drift from either the network topology (models/cnn.py
LayerSpec lists) or the BlockSpec reality.

``run_structured`` returns the same derived metrics as JSON-ready dicts —
``benchmarks/run.py --json BENCH_kernel.json`` writes them next to the CSV
(plus a whole-program section) so future PRs can diff perf machine-readably.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from repro.core import binarize as bz
from repro.core import binconv
from repro.core.binlinear import QuantConfig
from repro.kernels import binary_conv as bck
from repro.kernels import binary_dwconv as bdw
from repro.kernels import ops as kops
from repro.kernels import ref as kref


def _time(fn, *args, iters=3):
    fn(*args)  # warmup/compile
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters


def tile_stats(bt, bn, bk, M):
    """Analytic per-tile VMEM bytes + arithmetic intensity for the kernel."""
    x_b = bt * bk * 4
    w_packed = M * (bk // 8) * bn
    w_bf16 = bk * bn * 2
    acc = bt * bn * 4
    flops = 2 * bt * bn * bk * M
    vmem = x_b + w_packed + acc
    ai_packed = flops / (x_b + w_packed)
    ai_dense = (2 * bt * bn * bk) / (x_b + w_bf16)
    return vmem, ai_packed, ai_dense


# ---------------------------------------------------------------------------
# Program-derived layer cases (the compile-once API is the source of truth)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _layer_stats(arch: str, B: int, M: int = 2):
    """name -> layer_stats() row of an abstractly-compiled program (frozen
    plans + stats only; the weights are ShapeDtypeStructs)."""
    from repro import deploy

    qc = QuantConfig(mode="binary", M=M, K_iters=1)
    shape = (B, 48, 48, 3) if arch == "cnn_a" else (B, 224, 224, 3)
    prog = deploy.abstract_program(arch, qc, shape)
    return {s["name"]: s for s in prog.layer_stats()}


def _conv_whole_image_vmem(s: dict, M: int) -> int:
    """Whole-image (NB=1, BU=Uo) working set for a conv layer-stat row."""
    Wp = s["padded_in"][1]
    C = s["in_shape"][-1]
    uo = s["out_shape"][1]
    return bck.tile_vmem_bytes(Wp, C, s["kh"], s["kw"], s["plan"]["bd"],
                               bu=uo, pool=s["pool"], stride=s["stride"],
                               m=M)


def _dw_whole_image_vmem(s: dict, M: int) -> int:
    Wp = s["padded_in"][1]
    C = s["in_shape"][-1]
    U = s["out_shape"][1]
    return bdw.tile_vmem_bytes_dw(Wp, C, s["kh"], s["kw"], bu=U,
                                  stride=s["stride"], m=M)


# conv layers whose per-tile VMEM/HBM trajectory the bench tracks: CNN-A's
# two convs + the MobileNet-B2 (224²) tier where row tiling must engage on
# the early maps and the batch tile on the 7² back half.
B2_CONV_ROWS = ("stem", "pw0", "pw1", "pw3", "pw5", "pw11")
B2_DW_ROWS = ("dw0", "dw1", "dw5")
# small late-layer maps where one image underfills the 128-row MXU: the
# batch-tile tier (B = a bulk serving batch the pick may fold from)
MXU_OCCUPANCY_ROWS = (("cnn_a", "conv2"), ("mobilenet", "pw11"),
                      ("mobilenet", "pw12"))


def conv_rows(quick: bool = False):
    """Fused-conv section: wall time (interpret vs jnp oracle) + HBM bytes
    per tile for the program-picked plans."""
    rows = []
    kh, kw, C, D, M, H, W, pool = (4, 4, 5, 32, 2, 21, 21, 2)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2 if quick else 8, H, W, C), jnp.float32)
    w = jax.random.normal(key, (kh, kw, C, D), jnp.float32) * 0.2
    b = jnp.zeros((D,), jnp.float32)
    p = binconv.binarize_conv_params(
        {"w": w, "b": b}, QuantConfig(mode="binary", M=M, K_iters=4))

    t_ref = _time(jax.jit(lambda x: kref.fused_binary_conv_relu_pool_ref(
        x, p["B_packed"], p["alpha"], kh=kh, kw=kw, pool=pool, bias=b)), x)
    rows.append(("kernel_binary_conv_ref_im2col_jnp", t_ref,
                 f"shape=({x.shape[0]},{H},{W},{C})->D{D} pool{pool} M{M}"))
    t_pal = _time(lambda x: kops.binary_conv2d(
        x, p["B_tap_packed"], p["alpha"], b, kh=kh, kw=kw, pool=pool,
        interpret=True), x)
    rows.append(("kernel_binary_conv_fused_pallas_interpret", t_pal,
                 "interpret-mode (CPU correctness path, not TPU wall time)"))

    stats = {**_layer_stats("cnn_a", 8), "pw5": _layer_stats("mobilenet", 8)["pw5"]}
    for name in ("conv1", "conv2", "pw5"):
        s = stats[name]
        rows.append((
            f"conv_hbm_bytes_per_tile_{name}_{s['in_shape'][1]}", 0.0,
            f"fused_KB={s['hbm_fused_bytes'] / 1024:.1f} "
            f"im2col_KB={s['hbm_im2col_bytes'] / 1024:.1f} "
            f"reduction={s['hbm_im2col_bytes'] / s['hbm_fused_bytes']:.1f}x"))
    return rows


def mobilenet_b2_rows():
    """MobileNet-B2 (224²) tier: per-tile VMEM working set for whole-image
    vs the program's frozen (NB, BU) plan, plus fused-vs-im2col HBM bytes
    under that plan — the quantities behind the §V Table III scaling claim,
    straight from program.layer_stats()."""
    budget = bck.DEFAULT_VMEM_BUDGET
    M = 2
    stats = _layer_stats("mobilenet", 8, M)
    rows = []
    for name in B2_CONV_ROWS:
        s = stats[name]
        plan = s["plan"]
        rows.append((
            f"conv_vmem_per_tile_mnet_b2_{name}_{s['in_shape'][1]}", 0.0,
            f"nb={plan['nb']} bu={plan['bu']}/{s['out_shape'][1]} "
            f"vmem_whole_MB={_conv_whole_image_vmem(s, M) / 2**20:.2f} "
            f"vmem_tiled_MB={s['vmem_bytes'] / 2**20:.2f} "
            f"budget_MB={budget / 2**20:.0f} "
            f"fused_KB={s['hbm_fused_bytes'] / 1024:.1f} "
            f"im2col_KB={s['hbm_im2col_bytes'] / 1024:.1f} "
            f"hbm_reduction={s['hbm_im2col_bytes'] / s['hbm_fused_bytes']:.1f}x"))
    for name in B2_DW_ROWS:
        s = stats[name]
        C = s["in_shape"][-1]
        c8 = -(-C // 8)
        # binary vs fp32 dw weight stream per image (the dw memory-bound win)
        w_bits = M * 9 * c8 + M * C * 4
        w_fp = 9 * C * 4
        rows.append((
            f"dwconv_vmem_per_tile_mnet_b2_{name}_{s['in_shape'][1]}", 0.0,
            f"nb={s['plan']['nb']} bu={s['plan']['bu']}/{s['out_shape'][1]} "
            f"vmem_whole_MB={_dw_whole_image_vmem(s, M) / 2**20:.2f} "
            f"vmem_tiled_MB={s['vmem_bytes'] / 2**20:.2f} "
            f"budget_MB={budget / 2**20:.0f} "
            f"w_packed_B={w_bits} w_fp32_B={w_fp}"))
    return rows


def mxu_occupancy_rows(B: int = 128):
    """Whole-image-per-program vs the program's batch-tiled plan for the
    small back-half maps: MXU row occupancy and per-output weight-unpack
    work, the two quantities the (NB, BU) batch tile exists to fix."""
    rows = []
    M = 2
    for arch, name in MXU_OCCUPANCY_ROWS:
        s = _layer_stats(arch, B, M)[name]
        plan = s["plan"]
        uo, vo = s["out_shape"][1], s["out_shape"][2]
        V = vo * s["pool"]
        K = s["kh"] * s["kw"] * s["in_shape"][-1]
        occ_whole = bck.mxu_row_occupancy(
            bck.gemm_rows(1, uo, V, pool=s["pool"]))
        rows.append((
            f"conv_mxu_occupancy_{arch}_{name}", 0.0,
            f"nb={plan['nb']} bu={plan['bu']}/{uo} B={B} "
            f"occ_whole={occ_whole:.2f} "
            f"occ_batched={s['mxu_row_occupancy']:.2f} "
            f"util_batch={s['batch_row_utilization']:.2f} "
            f"unpack_per_out_whole="
            f"{bck.unpack_work_per_output(1, uo, vo, K, m=M):.1f} "
            f"unpack_per_out_batched="
            f"{bck.unpack_work_per_output(plan['nb'], plan['bu'], vo, K, m=M):.1f} "
            f"vmem_tiled_MB={s['vmem_bytes'] / 2**20:.2f}"))
    return rows


def run_structured(quick: bool = False):
    """Machine-readable derived metrics (no wall times — those are CPU
    interpret-mode noise), straight from program.layer_stats().  Consumed by
    ``benchmarks/run.py --json``."""
    out = []
    b2 = _layer_stats("mobilenet", 8)
    for name in B2_CONV_ROWS:
        out.append({"name": f"conv_mnet_b2_{name}", "kind": "conv_tile",
                    "vmem_whole_bytes": _conv_whole_image_vmem(b2[name], 2),
                    "vmem_budget_bytes": bck.DEFAULT_VMEM_BUDGET,
                    **b2[name]})
    for name in B2_DW_ROWS:
        out.append({"name": f"dwconv_mnet_b2_{name}", "kind": "dw_tile",
                    "vmem_whole_bytes": _dw_whole_image_vmem(b2[name], 2),
                    **b2[name]})
    for arch, name in MXU_OCCUPANCY_ROWS:
        out.append({"name": f"conv_mxu_occupancy_{arch}_{name}",
                    "kind": "mxu_occupancy", "B": 128,
                    **_layer_stats(arch, 128)[name]})
    for name in ("conv1", "conv2"):
        out.append({"name": f"conv_hbm_cnn_a_{name}", "kind": "hbm_per_tile",
                    **_layer_stats("cnn_a", 8)[name]})
    return out


def run(quick: bool = False):
    rows = []
    T, K, N, M = (64, 256, 128, 2) if quick else (128, 512, 256, 2)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (T, K), jnp.float32)
    W = jax.random.normal(key, (K, N), jnp.float32)
    approx = bz.algorithm2(W, M=M, K_iters=8)
    packed = bz.pack(approx)

    t_ref = _time(jax.jit(lambda x: kref.binary_matmul_ref(
        x, packed.B_packed, packed.alpha, K=K,
        group_size=packed.group_size)), x)
    rows.append(("kernel_binary_matmul_ref_jnp", t_ref,
                 f"shape=({T},{K},{N})xM{M}"))
    t_pal = _time(lambda x: kops.binary_matmul(
        x, packed.B_packed, packed.alpha, K=K, group_size=packed.group_size,
        interpret=True), x)
    rows.append(("kernel_binary_matmul_pallas_interpret", t_pal,
                 "interpret-mode (CPU correctness path, not TPU wall time)"))
    t_dense = _time(jax.jit(lambda x: x @ W), x)
    rows.append(("kernel_dense_matmul_xla", t_dense, "fp32 baseline"))

    for bt, bn, bk in [(128, 128, 256), (256, 256, 512), (128, 256, 1024)]:
        vmem, ai_p, ai_d = tile_stats(bt, bn, bk, M)
        rows.append((
            f"kernel_tilestats_bt{bt}_bn{bn}_bk{bk}", 0.0,
            f"vmem_KB={vmem / 1024:.0f} AI_packed={ai_p:.0f} "
            f"AI_bf16={ai_d:.0f} gain={ai_p / ai_d:.1f}x"))
    rows.extend(conv_rows(quick))
    rows.extend(mobilenet_b2_rows())
    rows.extend(mxu_occupancy_rows())
    return rows


if __name__ == "__main__":
    for name, secs, derived in run():
        print(f"{name},{secs * 1e6:.0f},{derived}")
