"""Kernel micro-bench: binary matmul vs dense reference.

CPU wall times (interpret-mode Pallas) are NOT TPU-indicative; the derived
columns that matter are the analytic VMEM working set, HBM bytes per tile,
and arithmetic intensity — the quantities the BlockSpec design controls
(see kernels/binary_matmul.py docstring).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import binarize as bz
from repro.kernels import ops as kops
from repro.kernels import ref as kref


def _time(fn, *args, iters=3):
    fn(*args)  # warmup/compile
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters


def tile_stats(bt, bn, bk, M):
    """Analytic per-tile VMEM bytes + arithmetic intensity for the kernel."""
    x_b = bt * bk * 4
    w_packed = M * (bk // 8) * bn
    w_bf16 = bk * bn * 2
    acc = bt * bn * 4
    flops = 2 * bt * bn * bk * M
    vmem = x_b + w_packed + acc
    ai_packed = flops / (x_b + w_packed)
    ai_dense = (2 * bt * bn * bk) / (x_b + w_bf16)
    return vmem, ai_packed, ai_dense


def run(quick: bool = False):
    rows = []
    T, K, N, M = (64, 256, 128, 2) if quick else (128, 512, 256, 2)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (T, K), jnp.float32)
    W = jax.random.normal(key, (K, N), jnp.float32)
    approx = bz.algorithm2(W, M=M, K_iters=8)
    packed = bz.pack(approx)

    t_ref = _time(jax.jit(lambda x: kref.binary_matmul_ref(
        x, packed.B_packed, packed.alpha, K=K,
        group_size=packed.group_size)), x)
    rows.append(("kernel_binary_matmul_ref_jnp", t_ref,
                 f"shape=({T},{K},{N})xM{M}"))
    t_pal = _time(lambda x: kops.binary_matmul(
        x, packed.B_packed, packed.alpha, K=K, group_size=packed.group_size,
        interpret=True), x)
    rows.append(("kernel_binary_matmul_pallas_interpret", t_pal,
                 "interpret-mode (CPU correctness path, not TPU wall time)"))
    t_dense = _time(jax.jit(lambda x: x @ W), x)
    rows.append(("kernel_dense_matmul_xla", t_dense, "fp32 baseline"))

    for bt, bn, bk in [(128, 128, 256), (256, 256, 512), (128, 256, 1024)]:
        vmem, ai_p, ai_d = tile_stats(bt, bn, bk, M)
        rows.append((
            f"kernel_tilestats_bt{bt}_bn{bn}_bk{bk}", 0.0,
            f"vmem_KB={vmem / 1024:.0f} AI_packed={ai_p:.0f} "
            f"AI_bf16={ai_d:.0f} gain={ai_p / ai_d:.1f}x"))
    return rows


if __name__ == "__main__":
    for name, secs, derived in run():
        print(f"{name},{secs * 1e6:.0f},{derived}")
