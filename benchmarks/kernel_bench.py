"""Kernel micro-bench: binary matmul vs dense reference, and the fused
implicit-GEMM conv kernel vs the HBM-materialized im2col path.

CPU wall times (interpret-mode Pallas) are NOT TPU-indicative; the derived
columns that matter are the analytic VMEM working set, HBM bytes per tile,
MXU row occupancy, per-output weight-unpack work, and arithmetic intensity —
the quantities the BlockSpec design controls (see kernels/binary_matmul.py
and kernels/binary_conv.py docstrings).  Every slab/VMEM/occupancy number is
computed by the kernel module's own exported functions (``slab_rows``,
``tile_vmem_bytes``, ``pick_tile``, ``mxu_row_occupancy``, ...), so this
bench cannot drift from the BlockSpec reality.

``run_structured`` returns the same derived metrics as JSON-ready dicts —
``benchmarks/run.py --json BENCH_kernel.json`` writes them next to the CSV
so future PRs can diff perf machine-readably.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import binarize as bz
from repro.core import binconv
from repro.core.binlinear import QuantConfig
from repro.kernels import binary_conv as bck
from repro.kernels import binary_dwconv as bdw
from repro.kernels import ops as kops
from repro.kernels import ref as kref


def _time(fn, *args, iters=3):
    fn(*args)  # warmup/compile
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters


def tile_stats(bt, bn, bk, M):
    """Analytic per-tile VMEM bytes + arithmetic intensity for the kernel."""
    x_b = bt * bk * 4
    w_packed = M * (bk // 8) * bn
    w_bf16 = bk * bn * 2
    acc = bt * bn * 4
    flops = 2 * bt * bn * bk * M
    vmem = x_b + w_packed + acc
    ai_packed = flops / (x_b + w_packed)
    ai_dense = (2 * bt * bn * bk) / (x_b + w_bf16)
    return vmem, ai_packed, ai_dense


def conv_tile_stats(H, W, C, kh, kw, D, M, *, stride=1, pool=1, bd=128,
                    bu=None, nb=1):
    """Analytic HBM bytes moved per (batch-tile, D-tile, row-tile) kernel
    program: fused implicit GEMM vs the explicit-im2col path, fp32
    activations.  Slab geometry comes from ``kernels/binary_conv.slab_rows``
    — the same function the kernel's BlockSpec uses.

    fused (kernels/binary_conv.py): read NB images' input row-slabs (halo
    rows included) + the bit-packed per-tap weight tile, write the *pooled*
    output tile.  The patch tensor lives only in VMEM.  ``bu`` is the row
    tile in pooled output rows; None = whole-image blocking (the BU = Uo
    special case).  ``nb`` is the batch tile (images folded into the GEMM
    row dim).

    im2col (core/binconv.py conv2d + relu_maxpool): additionally writes the
    tile's [nb·u·V, kh·kw·C] patch slice to HBM and reads it back for the
    matmul, then writes the unpooled conv output and re-reads it for
    pooling.
    """
    U = (H - kh) // stride + 1
    V = (W - kw) // stride + 1
    bd = min(bd, D)
    uo = max(U // pool, 1)
    bu = uo if bu is None else min(bu, uo)
    u_tile = bu * pool
    slab = bck.slab_rows(bu, kh, stride=stride, pool=pool)
    x_b = nb * min(slab, H) * W * C * 4
    w_packed = M * kh * kw * ((C + 7) // 8) * bd
    out_pooled = nb * bu * (V // pool) * bd * 4
    out_unpooled = nb * u_tile * V * bd * 4
    patches = nb * u_tile * V * kh * kw * C * 4
    fused = x_b + w_packed + out_pooled
    im2col_path = (x_b + 2 * patches + w_packed
                   + out_unpooled * 2 + out_pooled)
    return fused, im2col_path, im2col_path / fused


# the paper's conv layers (CNN-A §V-A1) + a mid-net MobileNet point-wise conv
CONV_CASES = [
    ("cnn_a_conv1", dict(H=48, W=48, C=3, kh=7, kw=7, D=5, M=2, pool=2)),
    ("cnn_a_conv2", dict(H=21, W=21, C=5, kh=4, kw=4, D=150, M=2, pool=6)),
    ("mobilenet_pw", dict(H=14, W=14, C=256, kh=1, kw=1, D=256, M=2)),
]


def conv_rows(quick: bool = False):
    """Fused-conv section: wall time (interpret vs jnp oracle) + HBM bytes."""
    rows = []
    kh, kw, C, D, M, H, W, pool = (4, 4, 5, 32, 2, 21, 21, 2)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2 if quick else 8, H, W, C), jnp.float32)
    w = jax.random.normal(key, (kh, kw, C, D), jnp.float32) * 0.2
    b = jnp.zeros((D,), jnp.float32)
    p = binconv.binarize_conv_params(
        {"w": w, "b": b}, QuantConfig(mode="binary", M=M, K_iters=4))

    t_ref = _time(jax.jit(lambda x: kref.fused_binary_conv_relu_pool_ref(
        x, p["B_packed"], p["alpha"], kh=kh, kw=kw, pool=pool, bias=b)), x)
    rows.append(("kernel_binary_conv_ref_im2col_jnp", t_ref,
                 f"shape=({x.shape[0]},{H},{W},{C})->D{D} pool{pool} M{M}"))
    t_pal = _time(lambda x: kops.binary_conv2d(
        x, p["B_tap_packed"], p["alpha"], b, kh=kh, kw=kw, pool=pool,
        interpret=True), x)
    rows.append(("kernel_binary_conv_fused_pallas_interpret", t_pal,
                 "interpret-mode (CPU correctness path, not TPU wall time)"))

    for name, case in CONV_CASES:
        fused, im2col_b, gain = conv_tile_stats(**case)
        rows.append((
            f"conv_hbm_bytes_per_tile_{name}", 0.0,
            f"fused_KB={fused / 1024:.1f} im2col_KB={im2col_b / 1024:.1f} "
            f"reduction={gain:.1f}x"))
    return rows


# MobileNet-B2 (alpha=1, rho=1, 224² — the paper's Table III headline row).
# H/W are the SAME-padded input dims of each layer; stem + the early
# point-wise layers are exactly where whole-image blocking blows the VMEM
# budget and the row tiling (kernels/binary_conv.py pick_tile) must engage
# with NB=1, while the 7² back half is where the batch tile must grow.
MOBILENET_B2_CASES = [
    ("stem_224", dict(H=225, W=225, C=3, kh=3, kw=3, D=32, M=2, stride=2)),
    ("pw0_112", dict(H=112, W=112, C=32, kh=1, kw=1, D=64, M=2)),
    ("pw1_56", dict(H=56, W=56, C=64, kh=1, kw=1, D=128, M=2)),
    ("pw3_28", dict(H=28, W=28, C=128, kh=1, kw=1, D=256, M=2)),
    ("pw5_14", dict(H=14, W=14, C=256, kh=1, kw=1, D=512, M=2)),
    ("pw11_7", dict(H=7, W=7, C=512, kh=1, kw=1, D=1024, M=2)),
]

# depth-wise layers (binary_dwconv.py): SAME-padded dims, channel-wise
MOBILENET_B2_DW_CASES = [
    ("dw0_112", dict(H=114, W=114, C=32, stride=1)),
    ("dw1_112s2", dict(H=113, W=113, C=64, stride=2)),
    ("dw5_28s2", dict(H=29, W=29, C=256, stride=2)),
]

# The MXU-row-occupancy tier: small late-layer maps where one image feeds
# the 128-row MXU far under capacity, whole-image-per-program vs the
# batch-tiled pick.  B is the serving batch the pick may fold from (a bulk
# batch: the pick minimizes the batch's total padded rows, so B matters).
MXU_OCCUPANCY_CASES = [
    ("cnn_a_conv2", dict(H=21, W=21, C=5, kh=4, kw=4, D=150, M=2, pool=6,
                         B=128)),
    ("mnet_pw11_7", dict(H=7, W=7, C=512, kh=1, kw=1, D=1024, M=2, B=128)),
    ("mnet_pw12_7", dict(H=7, W=7, C=1024, kh=1, kw=1, D=1024, M=2, B=128)),
]


def conv_case_stats(H, W, C, kh, kw, D, M, *, stride=1, pool=1, B=1,
                    budget=None):
    """Everything the bench (and the JSON artifact) reports for one conv
    layer shape, derived exclusively through the kernel module's exported
    analytics: the (NB, BU) pick, per-program VMEM bytes, fused vs im2col
    HBM bytes, MXU row occupancy, and per-output weight-unpack work."""
    budget = budget or bck.DEFAULT_VMEM_BUDGET
    bd = min(128, D)
    U = (H - kh) // stride + 1
    V = (W - kw) // stride + 1
    uo = max(U // pool, 1)
    K = kh * kw * C
    nb, bu = bck.pick_tile(B, H, W, C, kh, kw, bd, pool, budget,
                           stride=stride, m=M)
    vmem_whole = bck.tile_vmem_bytes(W, C, kh, kw, bd, bu=uo, stride=stride,
                                     pool=pool, m=M)
    vmem_tiled = bck.tile_vmem_bytes(W, C, kh, kw, bd, bu=bu, stride=stride,
                                     pool=pool, m=M, nb=nb)
    fused, im2col_b, hbm_gain = conv_tile_stats(
        H, W, C, kh, kw, D, M, stride=stride, pool=pool, bd=bd, bu=bu, nb=nb)
    occ_whole = bck.mxu_row_occupancy(bck.gemm_rows(1, uo, V, pool=pool))
    occ_picked = bck.mxu_row_occupancy(bck.gemm_rows(nb, bu, V, pool=pool))
    rows_img = bck.gemm_rows(1, bu, V, pool=pool)
    util_batch = (bck.batch_row_utilization(B, nb, rows_img)
                  if bu == uo else occ_picked)
    return {
        "B": B, "nb": nb, "bu": bu, "uo": uo, "bd": bd, "K": K,
        "batch_row_utilization": util_batch,
        "vmem_whole_bytes": vmem_whole, "vmem_tiled_bytes": vmem_tiled,
        "vmem_budget_bytes": budget,
        "hbm_fused_bytes": fused, "hbm_im2col_bytes": im2col_b,
        "hbm_reduction": hbm_gain,
        "mxu_row_occupancy_whole": occ_whole,
        "mxu_row_occupancy_picked": occ_picked,
        "unpack_per_output_whole": bck.unpack_work_per_output(
            1, uo, max(V // pool, 1), K, m=M),
        "unpack_per_output_picked": bck.unpack_work_per_output(
            nb, bu, max(V // pool, 1), K, m=M),
    }


def mobilenet_b2_rows():
    """MobileNet-B2 (224²) tier: per-tile VMEM working set for whole-image
    vs picked (NB, BU) blocking, plus fused-vs-im2col HBM bytes under the
    picked blocking — the quantities behind the §V Table III scaling claim."""
    budget = bck.DEFAULT_VMEM_BUDGET
    rows = []
    for name, case in MOBILENET_B2_CASES:
        s = conv_case_stats(B=8, **case)
        rows.append((
            f"conv_vmem_per_tile_mnet_b2_{name}", 0.0,
            f"nb={s['nb']} bu={s['bu']}/{s['uo']} "
            f"vmem_whole_MB={s['vmem_whole_bytes'] / 2**20:.2f} "
            f"vmem_tiled_MB={s['vmem_tiled_bytes'] / 2**20:.2f} "
            f"budget_MB={budget / 2**20:.0f} "
            f"fused_KB={s['hbm_fused_bytes'] / 1024:.1f} "
            f"im2col_KB={s['hbm_im2col_bytes'] / 1024:.1f} "
            f"hbm_reduction={s['hbm_reduction']:.1f}x"))
    for name, case in MOBILENET_B2_DW_CASES:
        H, W, C, stride = case["H"], case["W"], case["C"], case["stride"]
        M = 2
        U = (H - 3) // stride + 1
        whole = bdw.tile_vmem_bytes_dw(W, C, 3, 3, bu=U, stride=stride, m=M)
        nb, bu = bdw.pick_tile_dw(8, H, W, C, 3, 3, budget, stride=stride,
                                  m=M)
        tiled = bdw.tile_vmem_bytes_dw(W, C, 3, 3, bu=bu, stride=stride, m=M,
                                       nb=nb)
        c8 = -(-C // 8)
        # binary vs fp32 dw weight stream per image (the dw memory-bound win)
        w_bits = M * 9 * c8 + M * C * 4
        w_fp = 9 * C * 4
        rows.append((
            f"dwconv_vmem_per_tile_mnet_b2_{name}", 0.0,
            f"nb={nb} bu={bu}/{U} vmem_whole_MB={whole / 2**20:.2f} "
            f"vmem_tiled_MB={tiled / 2**20:.2f} "
            f"budget_MB={budget / 2**20:.0f} "
            f"w_packed_B={w_bits} w_fp32_B={w_fp}"))
    return rows


def mxu_occupancy_rows():
    """Whole-image-per-program vs batch-tiled rows for the small back-half
    maps: MXU row occupancy and per-output weight-unpack work, the two
    quantities the (NB, BU) batch tile exists to fix."""
    rows = []
    for name, case in MXU_OCCUPANCY_CASES:
        s = conv_case_stats(**case)
        rows.append((
            f"conv_mxu_occupancy_{name}", 0.0,
            f"nb={s['nb']} bu={s['bu']}/{s['uo']} B={s['B']} "
            f"occ_whole={s['mxu_row_occupancy_whole']:.2f} "
            f"occ_batched={s['mxu_row_occupancy_picked']:.2f} "
            f"util_batch={s['batch_row_utilization']:.2f} "
            f"unpack_per_out_whole={s['unpack_per_output_whole']:.1f} "
            f"unpack_per_out_batched={s['unpack_per_output_picked']:.1f} "
            f"vmem_tiled_MB={s['vmem_tiled_bytes'] / 2**20:.2f}"))
    return rows


def run_structured(quick: bool = False):
    """Machine-readable derived metrics (no wall times — those are CPU
    interpret-mode noise).  Consumed by ``benchmarks/run.py --json``."""
    out = []
    for name, case in MOBILENET_B2_CASES:
        out.append({"name": f"conv_mnet_b2_{name}", "kind": "conv_tile",
                    **conv_case_stats(B=8, **case)})
    for name, case in MXU_OCCUPANCY_CASES:
        out.append({"name": f"conv_mxu_occupancy_{name}",
                    "kind": "mxu_occupancy", **conv_case_stats(**case)})
    for name, case in CONV_CASES:
        fused, im2col_b, gain = conv_tile_stats(**case)
        out.append({"name": f"conv_hbm_{name}", "kind": "hbm_per_tile",
                    "hbm_fused_bytes": fused, "hbm_im2col_bytes": im2col_b,
                    "hbm_reduction": gain})
    for name, case in MOBILENET_B2_DW_CASES:
        H, W, C, stride = case["H"], case["W"], case["C"], case["stride"]
        nb, bu = bdw.pick_tile_dw(8, H, W, C, 3, 3, stride=stride, m=2)
        out.append({
            "name": f"dwconv_mnet_b2_{name}", "kind": "dw_tile",
            "nb": nb, "bu": bu,
            "vmem_tiled_bytes": bdw.tile_vmem_bytes_dw(
                W, C, 3, 3, bu=bu, stride=stride, m=2, nb=nb)})
    return out


def run(quick: bool = False):
    rows = []
    T, K, N, M = (64, 256, 128, 2) if quick else (128, 512, 256, 2)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (T, K), jnp.float32)
    W = jax.random.normal(key, (K, N), jnp.float32)
    approx = bz.algorithm2(W, M=M, K_iters=8)
    packed = bz.pack(approx)

    t_ref = _time(jax.jit(lambda x: kref.binary_matmul_ref(
        x, packed.B_packed, packed.alpha, K=K,
        group_size=packed.group_size)), x)
    rows.append(("kernel_binary_matmul_ref_jnp", t_ref,
                 f"shape=({T},{K},{N})xM{M}"))
    t_pal = _time(lambda x: kops.binary_matmul(
        x, packed.B_packed, packed.alpha, K=K, group_size=packed.group_size,
        interpret=True), x)
    rows.append(("kernel_binary_matmul_pallas_interpret", t_pal,
                 "interpret-mode (CPU correctness path, not TPU wall time)"))
    t_dense = _time(jax.jit(lambda x: x @ W), x)
    rows.append(("kernel_dense_matmul_xla", t_dense, "fp32 baseline"))

    for bt, bn, bk in [(128, 128, 256), (256, 256, 512), (128, 256, 1024)]:
        vmem, ai_p, ai_d = tile_stats(bt, bn, bk, M)
        rows.append((
            f"kernel_tilestats_bt{bt}_bn{bn}_bk{bk}", 0.0,
            f"vmem_KB={vmem / 1024:.0f} AI_packed={ai_p:.0f} "
            f"AI_bf16={ai_d:.0f} gain={ai_p / ai_d:.1f}x"))
    rows.extend(conv_rows(quick))
    rows.extend(mobilenet_b2_rows())
    rows.extend(mxu_occupancy_rows())
    return rows


if __name__ == "__main__":
    for name, secs, derived in run():
        print(f"{name},{secs * 1e6:.0f},{derived}")
