"""End-to-end driver: the paper's CNN-A workflow on synthetic GTSRB.

    PYTHONPATH=src python examples/train_cnn_a.py [--steps 300]

Reproduces the Table II pipeline: train fp32 baseline -> binary-approximate
(Algorithm 2) -> measure accuracy drop -> retrain with STE at low lr ->
convert to packed deployment form -> verify bit-equivalence of the fused
AMU (ReLU+maxpool) path.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.binlinear import QuantConfig
from repro.data.images import SyntheticGTSRB
from repro.models import cnn
from repro.optim import adamw


def accuracy(params, x, y, quant=QuantConfig(mode="dense")):
    logits = cnn.cnn_a_forward(params, x, quant)
    return float(jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32)))


def train(params, ds, *, steps, lr, quant, batch=64, seed=0, log_every=50):
    opt = adamw(lr)
    state = opt.init(params)
    rng = np.random.default_rng(seed)

    @jax.jit
    def step(params, state, i, x, y):
        def loss(p):
            logp = jax.nn.log_softmax(cnn.cnn_a_forward(p, x, quant))
            return -jnp.mean(jnp.take_along_axis(logp, y[:, None], 1))

        l, g = jax.value_and_grad(loss)(params)
        params, state = opt.update(g, state, params, i)
        return params, state, l

    for i in range(steps):
        x, y = ds.batch(batch, rng=rng)
        params, state, l = step(params, state, jnp.int32(i), x, y)
        if i % log_every == 0:
            print(f"  step {i:4d} loss {float(l):.4f}")
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--M", type=int, default=2)
    args = ap.parse_args()

    ds = SyntheticGTSRB(n_classes=43, seed=0)
    x_eval, y_eval = ds.eval_set(512)

    print("1) training fp32 CNN-A baseline...")
    params = cnn.init_cnn_a(jax.random.PRNGKey(0))
    params = train(params, ds, steps=args.steps, lr=1e-3,
                   quant=QuantConfig(mode="dense"))
    acc_fp = accuracy(params, x_eval, y_eval)
    print(f"   baseline accuracy: {acc_fp:.4f}")

    qc = QuantConfig(mode="fake_quant", M=args.M, algorithm=2, K_iters=25)
    acc_bin = accuracy(params, x_eval, y_eval, qc)
    print(f"2) binary-approximated (Alg-2, M={args.M}) without retraining: "
          f"{acc_bin:.4f}")

    print("3) retraining with straight-through estimator (paper §V-B1, "
          "Adam 1e-4)...")
    params_rt = train(jax.tree.map(jnp.copy, params), ds,
                      steps=max(args.steps // 2, 50), lr=1e-4, quant=qc,
                      seed=1)
    acc_rt = accuracy(params_rt, x_eval, y_eval, qc)
    print(f"   retrained accuracy: {acc_rt:.4f}  (fp baseline {acc_fp:.4f})")

    print("4) converting to packed deployment form...")
    t0 = time.time()
    deploy = cnn.binarize_cnn_a(params_rt, qc.replace(mode="binary"))
    acc_deploy = accuracy(deploy, x_eval, y_eval,
                          QuantConfig(mode="binary", M=args.M))
    print(f"   packed-binary accuracy: {acc_deploy:.4f} "
          f"({time.time() - t0:.1f}s) — matches fake-quant: "
          f"{abs(acc_deploy - acc_rt) < 0.02}")

    # compile the packed tree into a BinArrayProgram (paper §IV: tile plans
    # frozen offline, zero per-call scheduling) and spot-check the fused
    # kernels against the im2col reference path, interpret mode
    from repro import deploy as dpl

    program = dpl.compile(deploy, "cnn_a",
                          QuantConfig(mode="binary", M=args.M, interpret=True),
                          input_shape=(16, 48, 48, 3))
    lg_ref = cnn.cnn_a_forward(deploy, x_eval[:16],
                               QuantConfig(mode="binary", M=args.M))
    lg_fused = dpl.execute(program, x_eval[:16])
    drift = float(jnp.max(jnp.abs(lg_fused - lg_ref)))
    print(f"   compiled program (fused kernels) == im2col path: "
          f"max |Δlogit| = {drift:.2e}")

    arrays = lambda tree: (l for l in jax.tree.leaves(tree)
                           if hasattr(l, "size"))
    n_bits_fp = sum(l.size * 32 for l in arrays(params))
    # deploy trees carry BOTH conv packings (flat for im2col, per-tap for the
    # fused kernel) — a shipped artifact needs only one, so count one
    n_bits_bin = sum(
        l.size * l.dtype.itemsize * 8
        for path, l in jax.tree_util.tree_flatten_with_path(deploy)[0]
        if hasattr(l, "size") and "B_tap_packed" not in
        "/".join(str(getattr(p, "key", p)) for p in path))
    print(f"5) weight compression: {n_bits_fp / n_bits_bin:.1f}x "
          f"(Eq. 6 asymptote {32 / args.M:.1f}x)")


if __name__ == "__main__":
    main()
