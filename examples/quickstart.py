"""Quickstart: the paper's technique in five steps.

    PYTHONPATH=src python examples/quickstart.py

1. binarize a weight matrix with Algorithm 1 and the improved Algorithm 2;
2. compare their residuals (the paper's central §II claim);
3. run the binary dot product through the Pallas kernel vs the jnp oracle;
4. compile CNN-A into a BinArrayProgram (paper §IV: one macro-instruction
   per layer, tile plans frozen offline) and execute it;
5. flip the runtime accuracy<->throughput switch (m_active, §IV-D) — global
   and per-layer — on the same compiled program.
"""
import jax
import jax.numpy as jnp

from repro import deploy
from repro.core import binarize as bz
from repro.core.binlinear import QuantConfig
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.models import cnn


def main():
    key = jax.random.PRNGKey(0)

    # -- 1+2: Algorithm 1 vs Algorithm 2 ------------------------------------
    W = jax.random.normal(key, (256, 64))
    for M in (1, 2, 3, 4):
        e1 = float(bz.residual_error(W, bz.algorithm1(W, M=M)))
        e2 = float(bz.residual_error(W, bz.algorithm2(W, M=M, K_iters=50)))
        cf = bz.compression_factor(256, M)
        print(f"M={M}: ||W-What||^2  Alg1={e1:8.2f}  Alg2={e2:8.2f} "
              f"(improvement {100 * (e1 - e2) / e1:5.1f}%)  cf={cf:.1f}x")

    # -- 3: kernel vs oracle -------------------------------------------------
    x = jax.random.normal(key, (8, 256))
    packed = bz.pack(bz.algorithm2(W, M=2, K_iters=20))
    y_kernel = kops.binary_matmul(x, packed.B_packed, packed.alpha, K=256,
                                  group_size=packed.group_size, interpret=True)
    y_oracle = kref.binary_matmul_ref(x, packed.B_packed, packed.alpha,
                                      K=256, group_size=packed.group_size)
    print(f"\nPallas kernel vs oracle max |err|: "
          f"{float(jnp.max(jnp.abs(y_kernel - y_oracle))):.2e}")
    print(f"binary vs dense matmul MSE (M=2): "
          f"{float(jnp.mean((y_oracle - x @ W) ** 2)):.4f}")

    # -- 4: compile once, execute many (paper §IV) ---------------------------
    params = cnn.init_cnn_a(key)
    qc = QuantConfig(mode="binary", M=2, K_iters=8, interpret=True)
    program = deploy.compile(params, "cnn_a", qc, input_shape=(4, 48, 48, 3))
    print("\ncompiled CNN-A instruction stream (frozen tile plans):")
    for s in program.layer_stats():
        plan = " ".join(f"{k}={v}" for k, v in s["plan"].items())
        print(f"  {s['name']:<5} {s['kind']:<6} {plan:<22} "
              f"macs={s['macs']:>9,} vmem_KB={s['vmem_bytes'] / 1024:>7.0f}")
    print(f"  total: {program.totals()['macs']:,} MACs, "
          f"{program.totals()['weight_bytes']:,} packed weight bytes")

    xb = jax.random.normal(jax.random.PRNGKey(1), (4, 48, 48, 3), jnp.float32)
    dense_logits = cnn.cnn_a_forward(params, xb)          # fp baseline
    full = deploy.execute(program, xb)

    # -- 5: runtime accuracy<->throughput switch on the compiled program -----
    print("\nruntime m_active switch (same program, no recompilation):")
    for m in (1, 2):
        lg = deploy.execute(program, xb, m_active=m)
        mse = float(jnp.mean((lg - dense_logits) ** 2))
        print(f"  m_active={m} (global):     logits MSE vs dense = {mse:.5f} "
              f"({'high-throughput' if m < 2 else 'high-accuracy'} mode)")
    sched = [1, 2, 2, 2, 2]   # cheap first conv, full levels elsewhere
    lg = deploy.execute(program, xb, m_active=sched)
    print(f"  schedule {sched}: logits MSE vs dense = "
          f"{float(jnp.mean((lg - dense_logits) ** 2)):.5f} "
          f"(per-layer §IV-D)")
    print(f"  full-level program vs dense MSE = "
          f"{float(jnp.mean((full - dense_logits) ** 2)):.5f}")


if __name__ == "__main__":
    main()
