"""Quickstart: the paper's technique in five steps.

    PYTHONPATH=src python examples/quickstart.py

1. binarize a weight matrix with Algorithm 1 and the improved Algorithm 2;
2. compare their residuals (the paper's central §II claim);
3. run the binary dot product through the Pallas kernel vs the jnp oracle;
4. binarize a whole (reduced) qwen3 model and serve one decode step;
5. flip the runtime accuracy<->throughput switch (m_active, paper §IV-D).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cb
from repro.core import binarize as bz
from repro.core.binlinear import QuantConfig
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.models import api


def main():
    key = jax.random.PRNGKey(0)

    # -- 1+2: Algorithm 1 vs Algorithm 2 ------------------------------------
    W = jax.random.normal(key, (256, 64))
    for M in (1, 2, 3, 4):
        e1 = float(bz.residual_error(W, bz.algorithm1(W, M=M)))
        e2 = float(bz.residual_error(W, bz.algorithm2(W, M=M, K_iters=50)))
        cf = bz.compression_factor(256, M)
        print(f"M={M}: ||W-What||^2  Alg1={e1:8.2f}  Alg2={e2:8.2f} "
              f"(improvement {100 * (e1 - e2) / e1:5.1f}%)  cf={cf:.1f}x")

    # -- 3: kernel vs oracle -------------------------------------------------
    x = jax.random.normal(key, (8, 256))
    packed = bz.pack(bz.algorithm2(W, M=2, K_iters=20))
    y_kernel = kops.binary_matmul(x, packed.B_packed, packed.alpha, K=256,
                                  group_size=packed.group_size, interpret=True)
    y_oracle = kref.binary_matmul_ref(x, packed.B_packed, packed.alpha,
                                      K=256, group_size=packed.group_size)
    print(f"\nPallas kernel vs oracle max |err|: "
          f"{float(jnp.max(jnp.abs(y_kernel - y_oracle))):.2e}")
    print(f"binary vs dense matmul MSE (M=2): "
          f"{float(jnp.mean((y_oracle - x @ W) ** 2)):.4f}")

    # -- 4: whole-model deployment binarization ------------------------------
    cfg = cb.reduced(cb.get_config("qwen3_14b")).replace(dtype="float32")
    params = api.init_params(cfg, key)
    qc = QuantConfig(mode="binary", M=4, K_iters=8)
    bparams = api.binarize_model_params(cfg, params, qc=qc)
    batch = {"tokens": jnp.array([[1, 2, 3, 4]], jnp.int32)}
    dense_logits, _ = api.forward(cfg, params, batch)

    # -- 5: runtime accuracy<->throughput switch -----------------------------
    print("\nruntime m_active switch (same packed buffers):")
    for m in (1, 2, 4):
        bcfg = cfg.replace(quant=qc.replace(m_active=m))
        lg, _ = api.forward(bcfg, bparams, batch)
        mse = float(jnp.mean((lg - dense_logits) ** 2))
        print(f"  m_active={m}: logits MSE vs dense = {mse:.5f} "
              f"({'high-throughput' if m < 4 else 'high-accuracy'} mode)")


if __name__ == "__main__":
    main()
