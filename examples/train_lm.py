"""End-to-end LM training driver (~100M-class model, few hundred steps).

    PYTHONPATH=src python examples/train_lm.py --steps 200

Trains a scaled-down qwen3-family decoder on the synthetic token pipeline
with the full production stack: sharded train step, checkpointing, straggler
watchdog, optional QAT (--quant fake_quant) and binary gradient compression
(--grad-compress-M 2).  This is the same code path the dry-run lowers at
(16,16) / (2,16,16) scale.
"""
import argparse
import logging

import jax

from repro.configs import base as cb
from repro.data.tokens import SyntheticTokens
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw, warmup_cosine
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--quant", default="dense", choices=["dense", "fake_quant"])
    ap.add_argument("--grad-compress-M", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    # ~100M-class config: qwen3 family, 8 layers, d=512
    cfg = cb.get_config("qwen3_14b").replace(
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=1536, vocab=8192, scan_layers=False, remat=False)
    if args.quant != "dense":
        cfg = cfg.replace(quant=cfg.quant.replace(mode=args.quant, M=2,
                                                  K_iters=4))
    n_params = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(lambda k: __import__('repro.models.api', fromlist=['x'])
                       .init_params(cfg, k),
                       jax.ShapeDtypeStruct((2,), jax.numpy.uint32))))
    print(f"model: {n_params / 1e6:.1f}M params, quant={args.quant}")

    mesh = make_host_mesh()
    opt = adamw(warmup_cosine(3e-4, 20, args.steps))
    state = steps_mod.init_train_state(cfg, mesh, opt)
    if args.grad_compress_M:
        from repro.core import compress as gcomp

        state["grad_comp"] = gcomp.init_state(state["params"])
    step_fn, _ = steps_mod.build_train_step(
        cfg, mesh, opt, grad_compress_M=args.grad_compress_M, donate=False)
    data = SyntheticTokens(cfg.vocab, args.seq, args.batch)
    trainer = Trainer(step_fn, state, data, TrainerConfig(
        total_steps=args.steps, checkpoint_every=max(args.steps // 4, 10),
        checkpoint_dir=args.checkpoint_dir, log_every=10))
    trainer.maybe_resume()
    with mesh:
        report = trainer.run()
    print(f"\nfirst-10 mean loss {sum(report.losses[:10]) / 10:.4f} -> "
          f"last-10 mean loss {sum(report.losses[-10:]) / 10:.4f}")
    print(f"stragglers={len(report.straggler_events)} "
          f"nan_skips={report.nan_skips} resumed={report.resumed_from}")


if __name__ == "__main__":
    main()
