"""Serving example: batched requests against a binary-approximated LM.

    PYTHONPATH=src python examples/serve_lm.py

Binarizes a reduced model into packed deployment form and serves a mixed
batch of requests with continuous batching: high-accuracy requests (all M
levels) and high-throughput requests (m_active=1) side by side in the same
server, off the same packed buffers — the paper's §IV-D runtime switch,
selected per request via ``Request.m_active``.

Admission uses bulk prefill (one forward pass + cache scatter per request —
see ``Server.stats``), and per-slot state masking lets recurrent-state
families (here: mamba2) serve mixed level counts too, which PR 1 had to
reject at admit time.
"""
import numpy as np
import jax

from repro.configs import base as cb
from repro.core.binlinear import QuantConfig
from repro.launch.serve import Request, Server
from repro.models import api


def serve_one(arch: str, label: str):
    cfg = cb.reduced(cb.get_config(arch)).replace(dtype="float32")
    params = api.init_params(cfg, jax.random.PRNGKey(0))

    qc = QuantConfig(mode="binary", M=2, K_iters=8)
    bparams = api.binarize_model_params(cfg, params, qc=qc)

    prompts = [np.array([5, 9, 2], np.int32),
               np.array([17, 3, 3, 8], np.int32),
               np.array([1, 1, 2, 3, 5], np.int32)]

    srv = Server(cfg.replace(quant=qc), bparams, max_batch=4, max_len=64)
    modes = (None, 1, None)  # per-request §IV-D level count (None = all M)
    reqs = [Request(prompt=p, max_new_tokens=8, m_active=m)
            for p, m in zip(prompts, modes)]
    for r in reqs:
        assert srv.admit(r)
    srv.run_until_done()
    print(f"--- {label} ({arch}, family={cfg.family}) ---")
    for i, r in enumerate(reqs):
        mode = ("high-throughput (m=1)" if r.m_active == 1
                else "high-accuracy (all levels)")
        print(f"req{i} [{mode}] prompt={list(map(int, prompts[i]))} "
              f"-> {r.out_tokens}")
    print(f"admission: {srv.stats['bulk_prefills']} bulk prefill passes, "
          f"{srv.stats['tokenwise_prefill_steps']} token-wise steps")


def main():
    serve_one("gemma_2b", "transformer, positional KV cache")
    # recurrent state + mixed m_active: needs the per-slot update mask
    serve_one("mamba2_2_7b", "ssm, masked recurrent state")


if __name__ == "__main__":
    main()
