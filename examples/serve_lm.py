"""Serving example: batched requests against a binary-approximated LM.

    PYTHONPATH=src python examples/serve_lm.py

Binarizes a reduced gemma model into packed deployment form and serves a
mixed batch of requests with continuous batching: high-accuracy requests
(all M levels) and high-throughput requests (m_active=1) side by side in the
same server, off the same packed buffers — the paper's §IV-D runtime switch,
selected per request via ``Request.m_active``.
"""
import numpy as np
import jax

from repro.configs import base as cb
from repro.core.binlinear import QuantConfig
from repro.launch.serve import Request, Server
from repro.models import api


def main():
    cfg = cb.reduced(cb.get_config("gemma_2b")).replace(dtype="float32")
    params = api.init_params(cfg, jax.random.PRNGKey(0))

    qc = QuantConfig(mode="binary", M=2, K_iters=8)
    bparams = api.binarize_model_params(cfg, params, qc=qc)

    prompts = [np.array([5, 9, 2], np.int32),
               np.array([17, 3, 3, 8], np.int32),
               np.array([1, 1, 2, 3, 5], np.int32)]

    srv = Server(cfg.replace(quant=qc), bparams, max_batch=4, max_len=64)
    modes = (None, 1, None)  # per-request §IV-D level count (None = all M)
    reqs = [Request(prompt=p, max_new_tokens=8, m_active=m)
            for p, m in zip(prompts, modes)]
    for r in reqs:
        assert srv.admit(r)
    srv.run_until_done()
    for i, r in enumerate(reqs):
        label = ("high-throughput (m=1)" if r.m_active == 1
                 else "high-accuracy (all levels)")
        print(f"req{i} [{label}] prompt={list(map(int, prompts[i]))} "
              f"-> {r.out_tokens}")


if __name__ == "__main__":
    main()
