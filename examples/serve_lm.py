"""Serving example: batched requests against a binary-approximated LM.

    PYTHONPATH=src python examples/serve_lm.py

Binarizes a reduced gemma model into packed deployment form and serves a
small batch of requests with continuous batching, once in high-accuracy mode
(all M levels) and once in high-throughput mode (m_active=1) — the paper's
§IV-D runtime switch.
"""
import numpy as np
import jax

from repro.configs import base as cb
from repro.core.binlinear import QuantConfig
from repro.launch.serve import Request, Server
from repro.models import api


def main():
    cfg = cb.reduced(cb.get_config("gemma_2b")).replace(dtype="float32")
    params = api.init_params(cfg, jax.random.PRNGKey(0))

    qc = QuantConfig(mode="binary", M=2, K_iters=8)
    bparams = api.binarize_model_params(cfg, params, qc=qc)

    prompts = [np.array([5, 9, 2], np.int32),
               np.array([17, 3, 3, 8], np.int32),
               np.array([1, 1, 2, 3, 5], np.int32)]

    for label, m_active in (("high-accuracy (m=2)", None),
                            ("high-throughput (m=1)", 1)):
        scfg = cfg.replace(quant=qc.replace(m_active=m_active))
        srv = Server(scfg, bparams, max_batch=4, max_len=64)
        reqs = [Request(prompt=p, max_new_tokens=8) for p in prompts]
        for r in reqs:
            assert srv.admit(r)
        srv.run_until_done()
        print(f"{label}:")
        for i, r in enumerate(reqs):
            print(f"  req{i} prompt={list(map(int, prompts[i]))} "
                  f"-> {r.out_tokens}")


if __name__ == "__main__":
    main()
