"""Keep the docs runnable: execute fenced ``python`` blocks in README.md
and docs/*.md.

    python tools/check_docs.py [repo_root]

Every block fenced as ```` ```python ```` is executed in a fresh namespace
with ``src/`` on sys.path (the fast-tier environment — CPU, no TPU).  Blocks
that are illustrative API sketches rather than runnable programs should be
fenced as ```` ```python no-exec ```` (the first info-string word keeps
markdown highlighting working).  CI runs this as the docs job; the pytest
wrapper is tests/test_docs.py.
"""
from __future__ import annotations

import pathlib
import sys


def iter_snippets(root: pathlib.Path):
    """Yield (path, first_line_no, code) for every executable python block."""
    docs = [root / "README.md", *sorted((root / "docs").glob("*.md"))]
    for path in docs:
        if not path.exists():
            continue
        in_block, info, buf, start = False, "", [], 0
        for lineno, line in enumerate(path.read_text().splitlines(), 1):
            stripped = line.strip()
            if not in_block and stripped.startswith("```"):
                in_block, info, buf, start = True, stripped[3:].strip(), [], lineno + 1
            elif in_block and stripped == "```":
                in_block = False
                words = info.split()
                if words[:1] == ["python"] and "no-exec" not in words:
                    yield path, start, "\n".join(buf)
            elif in_block:
                buf.append(line)


def run_snippet(path: pathlib.Path, lineno: int, code: str) -> None:
    ns = {"__name__": "__docsnippet__"}
    exec(compile(code, f"{path}:{lineno}", "exec"), ns)  # noqa: S102


def main(root: str | None = None) -> int:
    rootp = pathlib.Path(root or ".").resolve()
    src = str(rootp / "src")
    if src not in sys.path:
        sys.path.insert(0, src)
    count = 0
    for path, lineno, code in iter_snippets(rootp):
        rel = path.relative_to(rootp)
        print(f"[check_docs] exec {rel}:{lineno}", flush=True)
        run_snippet(path, lineno, code)
        count += 1
    if count == 0:
        print("[check_docs] ERROR: no executable python snippets found")
        return 1
    print(f"[check_docs] {count} snippet(s) executed OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1] if len(sys.argv) > 1 else None))
