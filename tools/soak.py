"""Nightly soak CLI: run long-lived-surface scenarios, assert flat trends.

    PYTHONPATH=src python tools/soak.py [server executor checkpoint ...]
        [--steps N] [--csv-dir DIR] [--ckpt-dir DIR] [--mobilenet-b2]
        [--list]

Each scenario (repro.testing.scenarios.SCENARIOS) wraps one long-lived
serving surface — the launch server under mixed m_active/prefill traffic,
``deploy.execute`` over rotating §IV-D schedules, the checkpoint
save/load cycle — as a step closure plus cache-size gauges.  This driver
runs each through ``repro.testing.soak.run_soak`` and calls
``SoakResult.assert_flat()``: RSS, traced-heap, and latency must fit a
flat linear trend after warmup, and every cache gauge must end exactly
where it started (a growing jit cache IS the leak we're hunting).

``--csv-dir`` writes one ``<scenario>_trend.csv`` per run for CI artifact
upload (step, rss_bytes, traced_bytes, latency_s + gauge columns), so a
slow creep that stays inside one night's tolerance is still visible
across nights.  ``--mobilenet-b2`` swaps the executor scenario's reduced
MobileNet for the full B2 @224² — minutes per call under CPU interpret
mode, meant for real accelerator hardware only.

Exit codes: 0 all flat, 1 any TrendViolation (message names the metric,
slope, and projected growth).
"""
from __future__ import annotations

import argparse
import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parent.parent
for _p in (str(_ROOT / "src"), str(_ROOT)):
    if _p not in sys.path:
        sys.path.insert(0, _p)

# default step counts per scenario: sized so the full run is minutes on
# CPU interpret mode while still clearing the acceptance floors
# (>= 2000 server decode steps, >= 500 executor calls).  One server soak
# step admits/retires a whole request group, so 1100 steps ~= 2200 decodes.
# cnn_server runs whole 54-step fault cycles (clean/storm/clean) so the
# latency trend sees complete cycles, not a half-storm tail.
DEFAULT_STEPS = {"server": 1100, "executor": 260, "checkpoint": 120,
                 "cnn_server": 324}


def main(argv=None) -> int:
    from repro.testing import scenarios as sc
    from repro.testing.soak import TrendViolation, run_soak

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("scenarios", nargs="*", default=[],
                    metavar="SCENARIO",
                    help=f"which to run (default: all of "
                         f"{sorted(sc.SCENARIOS)})")
    ap.add_argument("--steps", type=int, default=0,
                    help="override step count for every selected scenario")
    ap.add_argument("--csv-dir", default="", metavar="DIR",
                    help="write <scenario>_trend.csv files here")
    ap.add_argument("--ckpt-dir", default="", metavar="DIR",
                    help="checkpoint directory for the cnn_server scenario "
                         "(default: a fresh tempdir); point tools/fsck_ckpt.py "
                         "at it afterwards to audit the recovery path")
    ap.add_argument("--mobilenet-b2", action="store_true",
                    help="executor scenario uses full MobileNet-B2 @224^2 "
                         "(hardware only; minutes/call under interpret)")
    ap.add_argument("--list", action="store_true",
                    help="list scenarios and exit")
    args = ap.parse_args(argv)

    if args.list:
        for name in sorted(sc.SCENARIOS):
            print(name)
        return 0
    names = args.scenarios or sorted(sc.SCENARIOS)
    unknown = [n for n in names if n not in sc.SCENARIOS]
    if unknown:
        ap.error(f"unknown scenario(s) {unknown}; choose from "
                 f"{sorted(sc.SCENARIOS)}")

    csv_dir = pathlib.Path(args.csv_dir) if args.csv_dir else None
    if csv_dir:
        csv_dir.mkdir(parents=True, exist_ok=True)
    failures = []
    for name in names:
        steps = args.steps or DEFAULT_STEPS.get(name, 200)
        print(f"== soak: {name} ({steps} steps) ==", flush=True)
        if name == "executor" and args.mobilenet_b2:
            scen = sc.executor_scenario(
                mobilenet_kw={"width_mult": 1.0, "n_classes": 1000,
                              "resolution": 224})
        elif name == "checkpoint":
            import tempfile

            tmp = tempfile.mkdtemp(prefix="soak_ckpt_")
            scen = sc.SCENARIOS[name](directory=tmp)
        elif name == "cnn_server" and args.ckpt_dir:
            scen = sc.SCENARIOS[name](directory=args.ckpt_dir)
        else:
            scen = sc.SCENARIOS[name]()
        result = run_soak(scen.step, steps=steps, name=name,
                          gauges=scen.gauges)
        if csv_dir:
            result.write_csv(csv_dir / f"{name}_trend.csv")
        print(result.summary(), flush=True)
        if scen.progress is not None:
            print(f"   progress: {scen.progress()}", flush=True)
        try:
            result.assert_flat()
            print(f"   {name}: FLAT", flush=True)
        except TrendViolation as e:
            failures.append((name, str(e)))
            print(f"   {name}: VIOLATION — {e}", flush=True)
    if failures:
        print(f"soak: {len(failures)} scenario(s) violated flat-trend "
              "tolerances", file=sys.stderr)
        return 1
    print("soak: all trends flat")
    return 0


if __name__ == "__main__":
    sys.exit(main())
