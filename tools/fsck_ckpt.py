"""Checkpoint fsck: verify every digest in a checkpoint directory.

    python tools/fsck_ckpt.py DIR [DIR ...] [--json PATH] [--quiet]

For each directory (a checkpoint dir holding ``step_*`` subdirs, or a
parent whose children are such dirs), re-hash every leaf of every step
against its manifest CRC32, re-hash the manifest against its own recorded
digest, and cross-check recorded shapes/dtypes — exactly the checks
``CheckpointManager.restore`` runs, but read-only: nothing is quarantined,
renamed, or deleted (``scrub=False``), so fsck is safe to point at a live
serving directory.

Prints one verdict line per step (``ok`` or the first problem found),
plus any quarantine dirs already present (informational — they are prior
recoveries' evidence, not new corruption).  Exit codes: 0 all steps clean,
1 any corruption found, 2 usage error (no checkpoint steps found).

Wired into the nightly CI soak job against the soak run's checkpoint
directory — a recovery path that quietly stops detecting corruption is
itself a regression.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parent.parent
for p in (str(_ROOT / "src"), str(_ROOT)):
    if p not in sys.path:
        sys.path.insert(0, p)

from repro.checkpoint.manager import CheckpointManager


def _is_ckpt_dir(path: str) -> bool:
    try:
        entries = os.listdir(path)
    except OSError:
        return False
    return any(e.startswith("step_") or e.startswith("quarantine_")
               for e in entries)


def _expand(paths: list[str]) -> list[str]:
    """Accept checkpoint dirs directly, or parents of checkpoint dirs."""
    out = []
    for p in paths:
        if _is_ckpt_dir(p):
            out.append(p)
            continue
        try:
            children = sorted(os.listdir(p))
        except OSError:
            continue
        out.extend(c for c in (os.path.join(p, child) for child in children)
                   if os.path.isdir(c) and _is_ckpt_dir(c))
    return out


def fsck(directory: str) -> dict:
    """Verify one checkpoint directory; returns a JSON-able report."""
    mgr = CheckpointManager(directory, scrub=False)
    steps = {}
    bad = 0
    for step in mgr.all_steps():
        problems = mgr.verify_step(step)
        steps[step] = problems
        bad += bool(problems)
    return {
        "directory": directory,
        "steps": steps,
        "corrupt_steps": bad,
        "quarantined": mgr.quarantine_dirs(),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("dirs", nargs="+",
                    help="checkpoint dir(s), or parent(s) of checkpoint dirs")
    ap.add_argument("--json", metavar="PATH",
                    help="also write the full report as JSON")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress per-step verdict lines")
    args = ap.parse_args(argv)

    dirs = _expand(args.dirs)
    reports = [fsck(d) for d in dirs]
    total_steps = sum(len(r["steps"]) for r in reports)
    corrupt = sum(r["corrupt_steps"] for r in reports)

    for r in reports:
        if not args.quiet:
            print(f"{r['directory']}:")
            for step, problems in sorted(r["steps"].items()):
                verdict = "ok" if not problems else problems[0]
                print(f"  step {step}: {verdict}")
            for q in r["quarantined"]:
                print(f"  {q}: quarantined (prior recovery, not re-checked)")

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"reports": reports, "total_steps": total_steps,
                       "corrupt_steps": corrupt}, f, indent=1)

    if total_steps == 0:
        print(f"fsck_ckpt: no checkpoint steps found under {args.dirs}",
              file=sys.stderr)
        return 2
    status = "CLEAN" if corrupt == 0 else "CORRUPT"
    print(f"fsck_ckpt: {total_steps} step(s) across {len(reports)} dir(s), "
          f"{corrupt} corrupt — {status}")
    return 0 if corrupt == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
