"""CI perf gate: diff two ``benchmarks/run.py --json`` documents.

    python tools/bench_diff.py BASELINE.json CANDIDATE.json
                               [--rel-tol 0.01] [--update-baseline]
                               [--json PATH]

Compares only the *structural* metrics — analytic VMEM working sets, HBM
bytes, MXU occupancy/utilization, device-call counts, compiler tile plans,
verifier findings.  Wall-clock columns (``us_per_call``) are CPU
interpret-mode noise and are never compared.  The comparison is
directional, encoded as data in :data:`METRIC_DIRECTIONS`:

  * ``higher``-is-better metrics (occupancy, utilization) regress when the
    candidate drops more than ``--rel-tol`` below the baseline;
  * ``lower``-is-better metrics (VMEM/HBM bytes, device calls, error
    counts) regress when the candidate grows more than ``--rel-tol``;
  * rows present in the baseline but missing from the candidate are
    coverage regressions (a silently-dropped bench can hide anything);
  * any ERROR finding in the candidate's verify section, or a WARN count
    above baseline, is a regression (new verifier findings).

Schema discipline: both documents must carry ``meta.schema_version`` and
they must match — otherwise exit 2 (*refused*, not compared).  The
explicit ``--update-baseline`` path copies the candidate over the baseline
after a human decided the change is intended (docs/testing.md documents
the workflow).

Exit codes: 0 clean, 1 regression(s), 2 schema mismatch / unusable input.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import shutil
import sys

# metric name -> which direction is GOOD.  Anything not listed is
# informational only (plans, shapes, counts that have no better/worse).
METRIC_DIRECTIONS = {
    # kernel structured rows + program layer stats
    "mxu_row_occupancy": "higher",
    "batch_row_utilization": "higher",
    "vmem_bytes": "lower",
    "vmem_whole_bytes": "lower",
    "hbm_fused_bytes": "lower",
    "hbm_im2col_bytes": "lower",
    "weight_bytes": "lower",
    # serve structured rows
    "device_calls_per_admit": "lower",
    # cnn_slo rows (virtual-clock policy outputs, benchmarks/serve_bench):
    # a controller/ladder change that lifts tail latency, sheds more, or
    # completes fewer requests at the same offered load is a regression
    "p50_virtual_ms": "lower",
    "p99_virtual_ms": "lower",
    "shed_fraction": "lower",
    "completed": "higher",
    # cnn_recovery rows (watchdog + hot-reload path, benchmarks/serve_bench):
    # detecting a seeded bit flip later, running the BIST more often per
    # batch, or recovering to a non-bit-exact program is a regression
    "reload_detect_batches": "lower",
    "reload_detect_virtual_ms": "lower",
    "selftest_per_100_batches": "lower",
    "recovered_bit_exact": "higher",
    # program totals
    "max_vmem_bytes": "lower",
    # distributed section (mesh_totals per program x mesh): a planner
    # change that puts more bytes on one device, re-replicates previously
    # sharded weights, inflates gather traffic, or needs more devices per
    # forward is a regression
    "per_device_weight_bytes": "lower",
    "per_device_vmem_bytes": "lower",
    "max_per_device_vmem_bytes": "lower",
    "replication_overhead": "lower",
    "replicated_weight_bytes": "lower",
    "gather_bytes": "lower",
    "devices_per_forward": "lower",
    # verify summaries
    "errors": "lower",
    "warnings": "lower",
}


@dataclasses.dataclass(frozen=True)
class Delta:
    """One compared metric: where, what, and whether it regressed."""

    path: str          # e.g. "kernel/conv_mnet_b2_pw0/vmem_bytes"
    metric: str
    base: float
    cand: float
    regression: bool
    note: str = ""

    def __str__(self) -> str:
        tag = "REGRESSION" if self.regression else "ok"
        extra = f" ({self.note})" if self.note else ""
        return f"{tag:10s} {self.path}: {self.base:g} -> {self.cand:g}{extra}"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def _regressed(metric: str, base: float, cand: float, rel_tol: float) -> bool:
    direction = METRIC_DIRECTIONS.get(metric)
    if direction is None or base is None or cand is None:
        return False
    scale = max(abs(base), 1e-12)
    if direction == "higher":
        return cand < base - rel_tol * scale
    return cand > base + rel_tol * scale


def _walk_numeric(prefix: str, base: dict, cand: dict, rel_tol: float,
                  out: list[Delta]) -> None:
    """Compare every direction-listed numeric key present in both dicts."""
    for key, bval in base.items():
        if key not in METRIC_DIRECTIONS:
            continue
        cval = cand.get(key)
        if not isinstance(bval, (int, float)) or not isinstance(
                cval, (int, float)):
            continue
        reg = _regressed(key, float(bval), float(cval), rel_tol)
        if reg or bval != cval:
            out.append(Delta(f"{prefix}/{key}", key, float(bval),
                             float(cval), reg))


def _rows_by_name(doc: dict, module: str) -> dict:
    rows = (doc.get("modules", {}).get(module, {}) or {}).get(
        "structured") or []
    return {r.get("name", f"row{i}"): r for i, r in enumerate(rows)}


def diff(base: dict, cand: dict, *, rel_tol: float = 0.01) -> list[Delta]:
    """All deltas between two bench documents (schema already validated)."""
    out: list[Delta] = []
    # --- structured module rows (kernel, serve, ...) ---
    for module in sorted(set(base.get("modules", {}))
                         | set(cand.get("modules", {}))):
        b_rows, c_rows = _rows_by_name(base, module), _rows_by_name(
            cand, module)
        for name, b_row in b_rows.items():
            c_row = c_rows.get(name)
            if c_row is None:
                out.append(Delta(f"{module}/{name}", "coverage", 1.0, 0.0,
                                 True, "row missing from candidate"))
                continue
            _walk_numeric(f"{module}/{name}", b_row, c_row, rel_tol, out)
            # nested plan dicts etc. are informational; layer rows inline
            # their stats so _walk_numeric covers them
    # --- program section: totals + per-layer stats ---
    b_prog, c_prog = base.get("program", {}), cand.get("program", {})
    for prog in sorted(set(b_prog) & set(c_prog)):
        if "totals" not in b_prog[prog] or "totals" not in c_prog[prog]:
            continue
        _walk_numeric(f"program/{prog}/totals", b_prog[prog]["totals"],
                      c_prog[prog]["totals"], rel_tol, out)
        b_layers = {s["name"]: s for s in b_prog[prog].get("layers", [])}
        c_layers = {s["name"]: s for s in c_prog[prog].get("layers", [])}
        for lname, b_layer in b_layers.items():
            c_layer = c_layers.get(lname)
            if c_layer is None:
                out.append(Delta(f"program/{prog}/{lname}", "coverage",
                                 1.0, 0.0, True,
                                 "layer missing from candidate"))
                continue
            _walk_numeric(f"program/{prog}/{lname}", b_layer, c_layer,
                          rel_tol, out)
    # --- distributed section: per-device byte splits per program x mesh ---
    b_dist, c_dist = base.get("distributed", {}), cand.get("distributed", {})
    for prog in sorted(k for k in b_dist if isinstance(b_dist[k], dict)):
        c_meshes = c_dist.get(prog)
        if not isinstance(c_meshes, dict):
            out.append(Delta(f"distributed/{prog}", "coverage", 1.0, 0.0,
                             True, "program missing from candidate"))
            continue
        for mesh, b_tot in b_dist[prog].items():
            c_tot = c_meshes.get(mesh)
            if not isinstance(c_tot, dict):
                out.append(Delta(f"distributed/{prog}/{mesh}", "coverage",
                                 1.0, 0.0, True,
                                 "mesh missing from candidate"))
                continue
            _walk_numeric(f"distributed/{prog}/{mesh}", b_tot, c_tot,
                          rel_tol, out)
    # --- verify section: no new findings, ever ---
    b_ver, c_ver = base.get("verify", {}), cand.get("verify", {})
    for prog in sorted(set(k for k in c_ver
                           if isinstance(c_ver[k], dict)
                           and "errors" in c_ver[k])):
        c_sum = c_ver[prog]
        b_sum = b_ver.get(prog, {"errors": 0, "warnings": 0})
        if c_sum.get("errors", 0) > 0:
            out.append(Delta(f"verify/{prog}/errors", "errors",
                             float(b_sum.get("errors", 0)),
                             float(c_sum["errors"]), True,
                             "candidate has ERROR findings"))
        elif c_sum.get("warnings", 0) > b_sum.get("warnings", 0):
            out.append(Delta(f"verify/{prog}/warnings", "warnings",
                             float(b_sum.get("warnings", 0)),
                             float(c_sum["warnings"]), True,
                             "new verifier WARN findings"))
    return out


class SchemaMismatch(ValueError):
    """The two documents cannot be compared (refuse, don't guess)."""


def check_schemas(base: dict, cand: dict) -> None:
    b = (base.get("meta") or {}).get("schema_version")
    c = (cand.get("meta") or {}).get("schema_version")
    if b is None or c is None:
        raise SchemaMismatch(
            "missing meta.schema_version "
            f"(baseline={b!r}, candidate={c!r}); regenerate with the "
            "current benchmarks/run.py --json")
    if b != c:
        raise SchemaMismatch(
            f"schema_version mismatch: baseline={b!r} candidate={c!r}; "
            "refusing to compare — update the baseline with "
            "tools/bench_diff.py --update-baseline")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="committed BENCH_baseline.json")
    ap.add_argument("candidate", help="freshly produced BENCH_*.json")
    ap.add_argument("--rel-tol", type=float, default=0.01,
                    help="relative tolerance per metric (default 1%%)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="copy candidate over baseline and exit 0 "
                         "(the intended-change path)")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="also dump all deltas as JSON")
    args = ap.parse_args(argv)

    base_path = pathlib.Path(args.baseline)
    cand_path = pathlib.Path(args.candidate)
    try:
        cand = json.loads(cand_path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_diff: cannot read candidate {cand_path}: {e}",
              file=sys.stderr)
        return 2
    if args.update_baseline:
        shutil.copyfile(cand_path, base_path)
        print(f"bench_diff: baseline updated from {cand_path} "
              f"(sha {(cand.get('meta') or {}).get('git_sha', '?')})")
        return 0
    try:
        base = json.loads(base_path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_diff: cannot read baseline {base_path}: {e}",
              file=sys.stderr)
        return 2
    try:
        check_schemas(base, cand)
    except SchemaMismatch as e:
        print(f"bench_diff: {e}", file=sys.stderr)
        return 2

    deltas = diff(base, cand, rel_tol=args.rel_tol)
    regressions = [d for d in deltas if d.regression]
    drifts = [d for d in deltas if not d.regression]
    for d in regressions + drifts:
        print(d)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"regressions": [d.as_dict() for d in regressions],
                       "drift": [d.as_dict() for d in drifts]},
                      f, indent=1, sort_keys=True)
    print(f"bench_diff: {'FAIL' if regressions else 'OK'} "
          f"({len(regressions)} regression(s), {len(drifts)} benign "
          f"drift(s); rel_tol={args.rel_tol})")
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main())
