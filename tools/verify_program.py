"""CI gate: statically verify + trace-lint the shipped BinArrayPrograms.

    python tools/verify_program.py [--json PATH] [--skip-retrace]
                                   [--mesh devices=N[,model=K]]

Runs, for each program in ``benchmarks.run.PROGRAMS`` (CNN-A,
MobileNet-B1, MobileNet-B2):

  1. ``repro.analysis.verify_program`` on the abstract compile — Mosaic
     block legality, packed widths, plan ranges, VMEM budget, stats drift;
  2. ``repro.analysis.trace_lint.lint_execute`` on the jitted execute jaxpr
     — zero fp conv primitives, zero trace-time plan picks, no f64
     (abstract tracing: nothing executes, so MobileNet-B2 @ 224² is cheap);
  3. for CNN-A only (small enough to actually run on CPU interpret mode),
     the retrace detector across 3x repeated mixed-``m_active`` traffic;
  4. with ``--mesh devices=N[,model=K]``: plan every program onto the
     N-device mesh (K-way model parallelism, data parallelism fills the
     rest) and run ``repro.analysis.verify_mesh_plan`` over the result —
     shard structure, channel divisibility, device-local lane legality,
     per-device VMEM budgets, byte accounting.  Static only: no devices
     are touched, so an 8-device plan audits fine on a 1-CPU runner.

Prints every finding and exits 1 if any ERROR surfaced.  CI runs this in
the fast tier (.github/workflows/ci.yml).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parent.parent
for p in (str(_ROOT / "src"), str(_ROOT)):
    if p not in sys.path:
        sys.path.insert(0, p)

import jax
import jax.numpy as jnp

from benchmarks.run import PROGRAMS
from repro import deploy, distributed
from repro.analysis import (mosaic_rules, summarize, trace_lint,
                            verify_mesh_plan, verify_program)
from repro.core.binlinear import QuantConfig
from repro.models import cnn


def _parse_mesh(spec: str) -> tuple[int, int]:
    """``devices=N[,model=K]`` -> (n_data, n_model); K must divide N."""
    fields = dict(part.split("=", 1) for part in spec.split(",") if part)
    unknown = set(fields) - {"devices", "model"}
    if unknown or "devices" not in fields:
        raise SystemExit(
            f"--mesh expects devices=N[,model=K], got {spec!r}")
    devices = int(fields["devices"])
    n_model = int(fields.get("model", 1))
    if devices < 1 or n_model < 1 or devices % n_model:
        raise SystemExit(
            f"--mesh: model={n_model} must divide devices={devices}")
    return devices // n_model, n_model


def _retrace_check(findings: dict) -> None:
    """Compile a real (small) CNN-A program and prove repeated traffic does
    not grow the executor's compiled-variant count."""
    qc = QuantConfig(mode="binary", M=2, K_iters=2, interpret=True)
    params = cnn.init_cnn_a(jax.random.PRNGKey(0))
    program = deploy.compile(cnn.binarize_cnn_a(params, qc), "cnn_a", qc,
                             (2, 48, 48, 3), verify=True)
    x = jnp.ones((2, 48, 48, 3), jnp.float32)
    fs = trace_lint.retrace_findings(
        program, x, schedules=(None, 1), repeats=3, interpret=True)
    findings["cnn_a_retrace"] = [f.as_dict() for f in fs]
    for f in fs:
        print(f"  {f}")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", default="", metavar="PATH",
                    help="also dump all findings as JSON")
    ap.add_argument("--skip-retrace", action="store_true",
                    help="skip the (executing) CNN-A retrace check")
    ap.add_argument("--mesh", default="", metavar="devices=N[,model=K]",
                    help="also plan each program onto this mesh and audit "
                         "the MeshPlan (verify_mesh_plan)")
    args = ap.parse_args()
    mesh = _parse_mesh(args.mesh) if args.mesh else None

    qc = QuantConfig(mode="binary", M=2, K_iters=1)
    doc: dict = {"rules": sorted(mosaic_rules.RULES)}
    n_errors = 0
    for key, (arch, shape, kw) in PROGRAMS.items():
        prog = deploy.abstract_program(arch, qc, shape, **kw)
        static = verify_program(prog)
        traced = trace_lint.lint_execute(prog, interpret=True)
        fs = static + traced
        summ = summarize(fs)
        n_errors += summ["errors"]
        doc[key] = {"summary": summ, "findings": [f.as_dict() for f in fs]}
        print(f"{key}: {summ['errors']} error(s), "
              f"{summ['warnings']} warning(s)")
        for f in fs:
            print(f"  {f}")
        if mesh is not None:
            n_data, n_model = mesh
            plan = distributed.plan_mesh(prog, n_data=n_data,
                                         n_model=n_model)
            mfs = verify_mesh_plan(prog, plan)
            msumm = summarize(mfs)
            n_errors += msumm["errors"]
            doc[key]["mesh"] = {
                "n_data": n_data, "n_model": n_model,
                "summary": msumm,
                "findings": [f.as_dict() for f in mfs],
                "totals": distributed.mesh_totals(prog, plan),
            }
            print(f"{key} @ mesh {n_data}x{n_model}: "
                  f"{msumm['errors']} error(s), "
                  f"{msumm['warnings']} warning(s), "
                  f"{sum(1 for s in plan.shards if s.kind == 'bd')} "
                  f"bd-sharded layer(s)")
            for f in mfs:
                print(f"  {f}")

    if not args.skip_retrace:
        print("cnn_a retrace check (3x repeated mixed-m_active traffic)")
        _retrace_check(doc)
        n_errors += len(doc["cnn_a_retrace"])

    if args.json:
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        print(f"findings written to {args.json}")
    print(f"verify_program: {'FAIL' if n_errors else 'OK'} "
          f"({n_errors} ERROR finding(s))")
    return 1 if n_errors else 0


if __name__ == "__main__":
    sys.exit(main())
