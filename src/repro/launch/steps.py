"""jit-compiled train / serve step builders with full sharding wiring.

These are the functions the dry-run lowers and the trainer executes:

  build_train_step(cfg, mesh, optimizer, ...)   -> (step_fn, state_specs)
  build_serve_step(cfg, mesh)                   -> step_fn

The train step consumes {params, opt_state, step} + batch and returns the
updated state + metrics; supports microbatch gradient accumulation and
optional binary gradient compression (core/compress.py).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import api
from repro.models import common as cm
from repro.optim import Optimizer
from repro.sharding import rules as shr


def install_rules(cfg: ArchConfig, mesh: Mesh, *, seq_sharded: bool = False):
    cm.set_axis_rules(
        shr.activation_rules(mesh, seq_sharded=seq_sharded),
        dict(mesh.shape),
    )


def train_state_specs(cfg: ArchConfig, mesh: Mesh, optimizer: Optimizer):
    """PartitionSpec pytree for {params, opt_state, step} (FSDP+TP)."""
    param_shapes = jax.eval_shape(
        lambda k: api.init_params(cfg, k), jax.ShapeDtypeStruct((2,), jnp.uint32))
    pspecs = shr.param_pspecs(cfg, param_shapes, mesh)
    opt_shapes = jax.eval_shape(optimizer.init, param_shapes)
    # optimizer state mirrors the param tree per moment buffer
    ospecs = {key: pspecs for key in opt_shapes.keys()}
    return {"params": pspecs, "opt_state": ospecs, "step": P()}


def init_train_state(cfg: ArchConfig, mesh: Mesh, optimizer: Optimizer,
                     seed: int = 0):
    """Initialize sharded state ON the mesh (params materialize sharded)."""
    specs = train_state_specs(cfg, mesh, optimizer)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))

    def init_fn(key):
        params = api.init_params(cfg, key)
        return {"params": params, "opt_state": optimizer.init(params),
                "step": jnp.zeros((), jnp.int32)}

    with mesh:
        return jax.jit(init_fn, out_shardings=shardings)(jax.random.PRNGKey(seed))


def build_train_step(cfg: ArchConfig, mesh: Mesh, optimizer: Optimizer, *,
                     microbatch: int | None = None,
                     grad_compress_M: int = 0,
                     donate: bool = True,
                     seq_sharded: bool = False):
    """Returns jit'd step(state, batch) -> (state, metrics)."""
    install_rules(cfg, mesh, seq_sharded=seq_sharded)
    state_specs = train_state_specs(cfg, mesh, optimizer)

    def loss_for(params, batch):
        loss, metrics = api.loss_fn(cfg, params, batch)
        return loss, metrics

    def grads_of(params, batch):
        if microbatch and microbatch > 1:
            B = batch["tokens"].shape[0]
            assert B % microbatch == 0
            mb = B // microbatch
            mb_batches = jax.tree.map(
                lambda t: t.reshape(microbatch, mb, *t.shape[1:]), batch)

            def body(carry, mb_batch):
                acc, met_acc = carry
                (_, met), g = jax.value_and_grad(loss_for, has_aux=True)(
                    params, mb_batch)
                acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32), acc, g)
                return (acc, jax.tree.map(jnp.add, met_acc, met)), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            met_shapes = jax.eval_shape(
                lambda b: loss_for(params, b)[1],
                jax.tree.map(lambda t: t[0], mb_batches))
            zero_m = jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype), met_shapes)
            (grads, met_sum), _ = jax.lax.scan(
                body, (zero_g, zero_m), mb_batches)
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            metrics = jax.tree.map(lambda m: m / microbatch, met_sum)
            return grads, metrics
        (_, metrics), grads = jax.value_and_grad(loss_for, has_aux=True)(
            params, batch)
        return grads, metrics

    def step_fn(state, batch):
        grads, metrics = grads_of(state["params"], batch)
        if grad_compress_M:
            from repro.core import compress as gc

            grads, comp_state = gc.compress_grads(
                grads, state["grad_comp"], M=grad_compress_M)
        new_params, new_opt = optimizer.update(
            grads, state["opt_state"], state["params"], state["step"])
        new_state = dict(state, params=new_params, opt_state=new_opt,
                         step=state["step"] + 1)
        if grad_compress_M:
            new_state["grad_comp"] = comp_state
        return new_state, metrics

    batch_shapes = None  # resolved per-call by jit
    state_shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), state_specs,
        is_leaf=lambda x: isinstance(x, P))

    jit_kwargs: dict[str, Any] = dict(
        # batch shardings resolved via with_sharding_constraint + defaults
        donate_argnums=(0,) if donate else (),
    )
    return jax.jit(step_fn, **jit_kwargs), state_specs


def lower_train_step(cfg: ArchConfig, mesh: Mesh, optimizer: Optimizer,
                     batch_specs, *, microbatch: int | None = None,
                     seq_sharded: bool = False):
    """Dry-run entry: .lower() the train step with explicit in/out shardings
    over ShapeDtypeStructs (no allocation).  microbatch > 1 scans gradient
    accumulation over batch slices (activation memory / microbatch)."""
    install_rules(cfg, mesh, seq_sharded=seq_sharded)
    state_specs = train_state_specs(cfg, mesh, optimizer)
    state_shapes = _train_state_shapes(cfg, optimizer)
    bspecs = shr.batch_pspecs(cfg, batch_specs, mesh, seq_sharded=seq_sharded)

    def loss_for(params, b):
        return api.loss_fn(cfg, params, b)

    def step_fn(state, batch):
        params = state["params"]
        if microbatch and microbatch > 1:
            B = batch["tokens"].shape[0]
            assert B % microbatch == 0, (B, microbatch)
            mb = B // microbatch
            mb_batches = jax.tree.map(
                lambda t: t.reshape(microbatch, mb, *t.shape[1:]), batch)

            def body(carry, mb_batch):
                acc, met_acc = carry
                (_, met), g = jax.value_and_grad(loss_for, has_aux=True)(
                    params, mb_batch)
                acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32), acc, g)
                return (acc, jax.tree.map(jnp.add, met_acc, met)), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            met_shapes = jax.eval_shape(
                lambda b: loss_for(params, b)[1],
                jax.tree.map(lambda t: t[0], mb_batches))
            zero_m = jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype), met_shapes)
            (grads, met_sum), _ = jax.lax.scan(body, (zero_g, zero_m), mb_batches)
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            metrics = jax.tree.map(lambda m: m / microbatch, met_sum)
        else:
            (_, metrics), grads = jax.value_and_grad(
                loss_for, has_aux=True)(params, batch)
        new_params, new_opt = optimizer.update(
            grads, state["opt_state"], state["params"], state["step"])
        return dict(state, params=new_params, opt_state=new_opt,
                    step=state["step"] + 1), metrics

    in_shardings = (
        jax.tree.map(lambda s: NamedSharding(mesh, s), state_specs,
                     is_leaf=lambda x: isinstance(x, P)),
        jax.tree.map(lambda s: NamedSharding(mesh, s), bspecs,
                     is_leaf=lambda x: isinstance(x, P)),
    )
    out_shardings = (in_shardings[0], None)
    with mesh:
        return jax.jit(
            step_fn, in_shardings=in_shardings, out_shardings=out_shardings,
            donate_argnums=(0,),
        ).lower(state_shapes, batch_specs)


def _train_state_shapes(cfg: ArchConfig, optimizer: Optimizer):
    param_shapes = jax.eval_shape(
        lambda k: api.init_params(cfg, k), jax.ShapeDtypeStruct((2,), jnp.uint32))
    opt_shapes = jax.eval_shape(optimizer.init, param_shapes)
    return {"params": param_shapes, "opt_state": opt_shapes,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


# ---------------------------------------------------------------------------
# serve
# ---------------------------------------------------------------------------

def lower_serve_step(cfg: ArchConfig, mesh: Mesh, batch_specs, *,
                     kind: str = "decode", seq_sharded: bool = False,
                     fsdp_params: bool = True):
    """Dry-run entry for decode/prefill steps.

    cfg.quant.mode == 'binary' lowers over the PACKED parameter tree (the
    paper's deployment form).  fsdp_params=False shards params TP-only
    (replicated over the DP axes) — the serving-appropriate layout that
    removes per-step FSDP all-gathers (see EXPERIMENTS.md §Perf).
    """
    install_rules(cfg, mesh, seq_sharded=seq_sharded)
    if cfg.quant.mode == "binary":
        param_shapes = jax.eval_shape(
            lambda k: api.binarize_model_params(
                cfg, api.init_params(cfg, k), qc=cfg.quant),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
    else:
        param_shapes = jax.eval_shape(
            lambda k: api.init_params(cfg, k),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
    pspecs = shr.param_pspecs(cfg, param_shapes, mesh, fsdp=fsdp_params)
    bspecs = shr.batch_pspecs(cfg, batch_specs, mesh, seq_sharded=seq_sharded)

    if kind == "decode":
        def step_fn(params, batch):
            logits, new_cache = api.decode_step(cfg, params, batch)
            return logits, new_cache

        out_shardings = (None, jax.tree.map(
            lambda s: NamedSharding(mesh, s), bspecs["cache"],
            is_leaf=lambda x: isinstance(x, P)))
    else:  # prefill: forward only
        def step_fn(params, batch):
            logits, _ = api.forward(cfg, params, batch)
            return logits

        out_shardings = None

    in_shardings = (
        jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                     is_leaf=lambda x: isinstance(x, P)),
        jax.tree.map(lambda s: NamedSharding(mesh, s), bspecs,
                     is_leaf=lambda x: isinstance(x, P)),
    )
    with mesh:
        return jax.jit(step_fn, in_shardings=in_shardings,
                       out_shardings=out_shardings).lower(
            param_shapes, batch_specs)
