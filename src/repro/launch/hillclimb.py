import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Perf hillclimb driver (EXPERIMENTS.md §Perf).

Three cells (picked per the assignment rubric from the baseline roofline
table):
  A qwen3_14b/decode_32k   — worst roofline fraction AND the cell most
                             representative of the paper's technique
                             (binary weights attack decode's memory wall);
  B gemma_2b/decode_32k    — most collective-bound baseline;
  C codeqwen15_7b/train_4k — memory-bound training (attention S^2 traffic).

Each iteration is (tag, cfg overrides); results land in
experiments/dryrun/<arch>__<shape>__<mesh>__<tag>.json next to the
baselines.  Every record with a binary quant config also carries
``adjusted_bytes_per_device``: the XLA CPU lowering of the reference binary
path materializes the dequantized fp32 weights (an artifact the Pallas
kernel avoids by unpacking in VMEM — kernels/binary_matmul.py, validated in
interpret mode); the adjustment subtracts that analytic artifact:
    artifact ~= 8 bytes * M * (binarized params per device)
(4B convert-write + 4B dot-read of the dequantized tensor).

Usage:
    python -m repro.launch.hillclimb --cell A          # all iterations
    python -m repro.launch.hillclimb --cell A --iter bin_M4
"""
import argparse
import json

import jax
import jax.numpy as jnp

from repro.core.binlinear import QuantConfig
from repro.launch import dryrun
from repro.launch import hlo_analysis as ha


def _bin(M, m_active=None):
    return QuantConfig(mode="binary", M=M, K_iters=2, m_active=m_active)


CELLS = {
    # cell: (arch, shape, mesh, [(tag, overrides), ...])
    "A": ("qwen3_14b", "decode_32k", "single", [
        # paper-faithful deployment: M=4 binary weights, same sharding
        ("bin_M4", {"quant": _bin(4)}),
        # + serving-appropriate params (TP-only, no FSDP all-gathers)
        ("bin_M4_tponly", {"quant": _bin(4), "serve_fsdp": False}),
        # beyond-paper: runtime throughput mode on the same buffers
        ("bin_M4_m2_tponly", {"quant": _bin(4, m_active=2),
                              "serve_fsdp": False}),
        # ablation: dense weights, TP-only (isolates the sharding fix)
        ("dense_tponly", {"serve_fsdp": False}),
        # seq-sharded KV cache: kills the per-layer fp32 logits all-reduce
        # (kv=8 heads don't divide the 16-way model axis)
        ("dense_seqshard", {"serve_fsdp": False, "kv_seq_shard": True}),
        ("bin_M4_seqshard", {"quant": _bin(4), "serve_fsdp": False,
                             "kv_seq_shard": True}),
    ]),
    "B": ("grok_1_314b", "train_4k", "single", [
        # hypothesis: collective term is FSDP expert-weight all-gathers x3
        # (fwd + remat-bwd re-gather) + grad reduce-scatter.  remat=False
        # removes the re-gather (microbatching keeps activations bounded).
        ("remat_off", {"remat": False}),
        # hypothesis: combine/dispatch collectives scale with capacity_factor
        ("cf10_remat_off", {"remat": False, "capacity_factor": 1.0}),
    ]),
    "C": ("codeqwen15_7b", "train_4k", "single", [
        ("chunk512", {"attn_chunk": 512}),
        ("chunk512_onehot", {"attn_chunk": 512, "onehot_loss": True}),
        ("chunk1024_onehot", {"attn_chunk": 1024, "onehot_loss": True}),
        # mixed-precision attention (bf16 operands, fp32 MXU accumulation):
        # the HLO op-bytes profile showed fp32 dX partial-sum all-reduces +
        # ~1 TB of convert traffic from fp32-cast attention inputs
        # (same overrides as chunk1024_onehot; the iteration is the code
        # change in attention.py — run AFTER it lands)
        ("mixedprec_chunk_onehot", {"attn_chunk": 1024, "onehot_loss": True}),
    ]),
    # serving-sharding study on the most collective-bound DECODE cell
    "D": ("gemma_2b", "decode_32k", "single", [
        ("tponly", {"serve_fsdp": False}),
        ("tponly_binM2", {"quant": _bin(2), "serve_fsdp": False}),
        ("seqshard_binM2", {"quant": _bin(2), "serve_fsdp": False,
                            "kv_seq_shard": True}),
    ]),
}


def _binarized_param_bytes_per_device(cfg, n_model_shards: int) -> float:
    """Analytic: fp32-dequant artifact bytes per device for the ref path."""
    from repro.models import api

    shapes = jax.eval_shape(
        lambda k: api.binarize_model_params(cfg, api.init_params(cfg, k),
                                            qc=cfg.quant),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    packed_elems = sum(
        t.size for t in jax.tree.leaves(shapes) if t.dtype == jnp.uint8)
    m = cfg.quant.m_active or cfg.quant.M
    # packed_elems = M * ceil(K/8) * N summed -> P_bin = packed_elems*8/M
    p_bin = packed_elems * 8 / cfg.quant.M
    return 8.0 * m * p_bin / n_model_shards


def run_iteration(cell: str, tag: str, overrides: dict):
    arch, shape, mesh_kind, iters = CELLS[cell]
    rec = dryrun.run_and_save(arch, shape, mesh_kind, tag=tag,
                              overrides=overrides)
    if rec["status"] == "ok" and overrides.get("quant") is not None:
        from repro.configs import base as cb

        cfg = cb.get_config(arch).replace(**overrides)
        artifact = _binarized_param_bytes_per_device(cfg, 16)
        adj = max(rec["bytes_per_device"] - artifact, 0.0)
        rec["dequant_artifact_bytes"] = artifact
        rec["adjusted_bytes_per_device"] = adj
        rec["adjusted_memory_s"] = adj / ha.HBM_BW
        path = dryrun._result_path(arch, shape, mesh_kind, tag)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=list(CELLS))
    ap.add_argument("--iter", default=None)
    args = ap.parse_args()
    arch, shape, mesh_kind, iters = CELLS[args.cell]
    for tag, overrides in iters:
        if args.iter and tag != args.iter:
            continue
        rec = run_iteration(args.cell, tag, overrides)
        keys = ("status", "compute_s", "memory_s", "adjusted_memory_s",
                "collective_s", "bound")
        print(f"[{args.cell}:{tag}]",
              {k: rec.get(k) for k in keys if rec.get(k) is not None})


if __name__ == "__main__":
    main()
