"""Production mesh construction (assignment-mandated shapes).

A FUNCTION, not a module constant, so importing never touches jax device
state.  Single-pod: 16x16 = 256 chips (data, model).  Multi-pod: 2x16x16 =
512 chips (pod, data, model) — the 'pod' axis is the slow inter-pod (DCN)
domain; sharding rules place only DP/FSDP traffic on it.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Tiny mesh over the real local devices (tests / CPU examples)."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
