import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first lines: jax locks the device count on first init.
"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape) cell, lower + compile the train or
serve step on the production meshes:

    single-pod : (16, 16)      ("data", "model")     = 256 chips
    multi-pod  : (2, 16, 16)   ("pod","data","model") = 512 chips

and record memory_analysis / cost_analysis / collective schedule + the
three-term roofline (launch/hlo_analysis.py) into
experiments/dryrun/<arch>__<shape>__<mesh>.json.

Usage:
    python -m repro.launch.dryrun --arch gemma_2b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both          # every cell
    python -m repro.launch.dryrun --all --subprocess         # isolate compiles
"""
import argparse
import json
import subprocess
import sys
import time
import traceback

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")


def _result_path(arch: str, shape: str, mesh_kind: str, tag: str = "") -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    return os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mesh_kind}{suffix}.json")


def _lower_for(cfg, mesh, shape_name, specs, *, microbatch=None):
    from repro.configs import base as cb
    from repro.launch import steps
    from repro.optim import adamw

    kind = cb.SHAPES[shape_name]["kind"]
    if kind == "train":
        return steps.lower_train_step(cfg, mesh, adamw(1e-4), specs,
                                      microbatch=microbatch)
    return steps.lower_serve_step(
        cfg, mesh, specs, kind="prefill" if kind == "prefill" else "decode",
        fsdp_params=cfg.serve_fsdp)


def _depth_pair(cfg):
    """Two reduced depths for the affine per-layer cost fit.

    XLA's cost analysis counts a scan body ONCE regardless of trip count, so
    full-depth compiled FLOPs/bytes under-report by ~n_layers.  Costs are
    affine in depth: cost(n) = intercept(embed/unembed/head) + n * per_layer.
    We compile two shallow variants and extrapolate to the full depth.
    Depths respect structural constraints (leading dense layers, hybrid
    attention period).
    """
    if cfg.n_dense_layers:                       # deepseek: 3 dense + moe
        return cfg.n_dense_layers + 1, cfg.n_dense_layers + 2
    if cfg.family == "hybrid":                   # zamba2: shared attn every 6
        return cfg.hybrid_attn_every, 2 * cfg.hybrid_attn_every
    return 2, 4


def _with_depth(cfg, n):
    # scan_layers=False: the shallow variants must be UNROLLED — XLA cost
    # analysis sees a scan body exactly once whatever the trip count, so a
    # scanned shallow model measures the same as a scanned deep one.
    kw = {"n_layers": n, "scan_layers": False}
    if cfg.n_encoder_layers:
        kw["n_encoder_layers"] = n               # whisper scales both stacks
    return cfg.replace(**kw)


def _measured_costs(compiled, n_dev):
    from repro.launch import hlo_analysis as ha

    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    text = compiled.as_text()
    coll = ha.collective_stats(text, n_dev)
    return (float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0)),
            coll.wire_bytes)


def extrapolated_costs(cfg, mesh, shape_name, *, n_dev) -> dict:
    """Affine-in-depth extrapolation of (flops, bytes, wire_bytes)."""
    from repro.configs import base as cb

    n_full = cfg.n_layers
    d1, d2 = _depth_pair(cfg)
    vals = {}
    for d in (d1, d2):
        c = _with_depth(cfg, d)
        specs = cb.input_specs(c, shape_name)
        compiled = _lower_for(c, mesh, shape_name, specs).compile()
        vals[d] = _measured_costs(compiled, n_dev)
    slope = [(b - a) / (d2 - d1) for a, b in zip(vals[d1], vals[d2])]
    full = [v + s * (n_full - d1) for v, s in zip(vals[d1], slope)]
    return {
        "flops": full[0], "bytes": full[1], "wire_bytes": full[2],
        "per_layer": {"flops": slope[0], "bytes": slope[1],
                      "wire_bytes": slope[2]},
        "depths_used": [d1, d2],
    }


def run_cell(arch: str, shape: str, mesh_kind: str, *, tag: str = "",
             overrides: dict | None = None) -> dict:
    """Lower + compile one cell; returns the result record."""

    from repro.configs import base as cb
    from repro.launch import hlo_analysis as ha
    from repro.launch.mesh import make_production_mesh
    from repro.launch import steps
    from repro.models import api
    from repro.optim import adamw

    t0 = time.time()
    cfg = cb.get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    sh = cb.SHAPES[shape]
    record: dict = {
        "arch": arch, "shape": shape, "mesh": mesh_kind, "tag": tag,
        "kind": sh["kind"], "seq_len": sh["seq_len"],
        "global_batch": sh["global_batch"], "status": "pending",
    }
    if shape == "long_500k" and not cfg.sub_quadratic:
        record["status"] = "skipped"
        record["reason"] = ("full-attention arch: long_500k requires "
                            "sub-quadratic attention (DESIGN.md §5)")
        return record
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.devices.size
    specs = cb.input_specs(cfg, shape)
    tokens = sh["global_batch"] * (sh["seq_len"] if sh["kind"] != "decode" else 1)
    n_active = api.count_params(cfg, active_only=True)
    model_flops = (6 if sh["kind"] == "train" else 2) * n_active * tokens

    # full compile: microbatched grad accumulation (deployable memory config);
    # cost extrapolation below runs un-microbatched (flops/bytes identical,
    # see _depth_pair) so the two concerns stay separable.
    microbatch = 8 if sh["kind"] == "train" else None
    record["microbatch"] = microbatch
    lowered = _lower_for(cfg, mesh, shape, specs, microbatch=microbatch)
    record["lower_s"] = round(time.time() - t0, 1)
    t1 = time.time()
    compiled = lowered.compile()
    record["compile_s"] = round(time.time() - t1, 1)
    mem = compiled.memory_analysis()
    print(f"[{arch}/{shape}/{mesh_kind}] memory_analysis:", mem)
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    print(f"[{arch}/{shape}/{mesh_kind}] cost_analysis: flops={ca.get('flops', 0):.3e}"
          f" bytes={ca.get('bytes accessed', 0):.3e}")
    terms = ha.roofline(compiled, total_devices=n_dev, model_flops=model_flops)
    record.update(terms.as_dict())
    record["raw_compiled"] = {  # full-depth module (scan bodies counted once)
        "flops_per_device": terms.flops_per_device,
        "bytes_per_device": terms.bytes_per_device,
        "wire_bytes_per_device": terms.wire_bytes_per_device,
    }
    # depth-extrapolated terms (see _depth_pair docstring)
    ext = extrapolated_costs(cfg, mesh, shape, n_dev=n_dev)
    record["extrapolation"] = ext
    record["flops_per_device"] = ext["flops"]
    record["bytes_per_device"] = ext["bytes"]
    record["wire_bytes_per_device"] = ext["wire_bytes"]
    record["compute_s"] = ext["flops"] / ha.PEAK_FLOPS
    record["memory_s"] = ext["bytes"] / ha.HBM_BW
    record["collective_s"] = ext["wire_bytes"] / ha.ICI_BW
    terms3 = {"compute": record["compute_s"], "memory": record["memory_s"],
              "collective": record["collective_s"]}
    record["bound"] = max(terms3, key=terms3.get)
    if record["flops_per_device"]:
        record["model_flops_ratio"] = model_flops / (
            record["flops_per_device"] * n_dev)
    record["n_devices"] = n_dev
    record["n_params"] = api.count_params(cfg)
    record["n_active_params"] = n_active
    record["status"] = "ok"
    record["total_s"] = round(time.time() - t0, 1)
    return record


def run_and_save(arch: str, shape: str, mesh_kind: str, *, tag: str = "",
                 overrides: dict | None = None) -> dict:
    try:
        record = run_cell(arch, shape, mesh_kind, tag=tag, overrides=overrides)
    except Exception as e:  # noqa: BLE001 — failures are recorded, not raised
        record = {"arch": arch, "shape": shape, "mesh": mesh_kind, "tag": tag,
                  "status": "error", "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-3000:]}
    path = _result_path(arch, shape, mesh_kind, tag)
    with open(path, "w") as f:
        json.dump(record, f, indent=1, default=str)
    print(f"[{arch}/{shape}/{mesh_kind}] -> {record['status']} ({path})")
    return record


def main() -> None:
    from repro.configs import base as cb

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--subprocess", action="store_true",
                    help="isolate each compile in a fresh process")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, s) for a in cb.ARCH_IDS
                 for s in cb.cells(cb.get_config(a))]
        # also record the documented skips
        skips = [(a, "long_500k") for a in cb.ARCH_IDS
                 if "long_500k" not in cb.cells(cb.get_config(a))]
        cells += skips
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in cells:
        for mesh_kind in meshes:
            path = _result_path(arch, shape, mesh_kind, args.tag)
            if os.path.exists(path) and not args.force:
                with open(path) as f:
                    if json.load(f).get("status") in ("ok", "skipped"):
                        print(f"[{arch}/{shape}/{mesh_kind}] cached — skip")
                        continue
            if args.subprocess:
                rc = subprocess.call(
                    [sys.executable, "-m", "repro.launch.dryrun",
                     "--arch", arch, "--shape", shape, "--mesh", mesh_kind]
                    + (["--force"] if args.force else [])
                    + (["--tag", args.tag] if args.tag else []),
                    env=dict(os.environ),
                )
                if rc:
                    failures += 1
            else:
                rec = run_and_save(arch, shape, mesh_kind, tag=args.tag)
                if rec["status"] == "error":
                    failures += 1
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
