"""Optional pipeline parallelism: GPipe-style microbatch pipeline on a
'pipe' mesh axis via shard_map + collective_permute (DESIGN.md §4).

Composable with the (data, model) mesh: stages hold contiguous layer blocks;
microbatches stream through stages with one collective_permute per tick
(fill + steady-state + drain = n_micro + n_stages - 1 ticks).

This module is self-contained (toy per-stage fn or a layer-stack closure) so
the mainline FSDP/TP path stays pipeline-free; it exists to prove the
communication schedule lowers and computes correctly (tests/test_pipeline.py
validates numerically against the unpipelined reference on 8 host devices).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def make_pipeline_mesh(n_pipe: int, n_data: int = 1):
    devs = jax.devices()
    assert len(devs) >= n_pipe * n_data, (len(devs), n_pipe, n_data)
    return jax.make_mesh((n_pipe, n_data), ("pipe", "data"))


def pipeline_apply(stage_fn, params_stacked, x, *, mesh: Mesh,
                   n_micro: int):
    """y = stage_{S-1}(...stage_0(x)) with stages sharded over 'pipe'.

    stage_fn(stage_params, h) -> h'
    params_stacked: pytree with leading dim n_stages (sharded over 'pipe').
    x: [B, ...] with B % n_micro == 0; batch microbatched and streamed.
    """
    n_stages = mesh.shape["pipe"]
    B = x.shape[0]
    assert B % n_micro == 0
    mb = B // n_micro

    def per_device(params_local, x_local):
        # params_local: stage slice [1, ...] -> this device's stage params
        stage_params = jax.tree.map(lambda t: t[0], params_local)
        stage_idx = jax.lax.axis_index("pipe")
        mbs = x_local.reshape(n_micro, mb, *x_local.shape[1:])
        n_ticks = n_micro + n_stages - 1
        buf = jnp.zeros((mb, *x_local.shape[1:]), x_local.dtype)
        outs = jnp.zeros_like(mbs)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (if in range)
            inject = jnp.where(t < n_micro, 1, 0)
            incoming = jnp.where(
                (stage_idx == 0) & (inject == 1),
                mbs[jnp.clip(t, 0, n_micro - 1)], buf)
            h = stage_fn(stage_params, incoming)
            # last stage emits microbatch (t - (n_stages-1))
            emit_idx = t - (n_stages - 1)
            outs = jax.lax.cond(
                (stage_idx == n_stages - 1) & (emit_idx >= 0),
                lambda o: o.at[jnp.clip(emit_idx, 0, n_micro - 1)].set(h),
                lambda o: o, outs)
            # rotate activations downstream: stage i -> stage i+1
            nxt = jax.lax.ppermute(
                h, "pipe",
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (nxt, outs), None

        (_, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(n_ticks))
        # only the last stage holds real outputs: zero elsewhere + psum
        outs = jnp.where(stage_idx == n_stages - 1, outs, 0)
        outs = jax.lax.psum(outs, "pipe")
        return outs.reshape(B, *x_local.shape[1:])

    fn = shard_map(
        per_device, mesh=mesh,
        in_specs=(P("pipe"), P()),
        out_specs=P(),
        check_rep=False,
    )
    return fn(params_stacked, x)


def reference_apply(stage_fn, params_stacked, x):
    """Unpipelined ground truth: apply stages sequentially."""
    n_stages = jax.tree.leaves(params_stacked)[0].shape[0]
    h = x
    for i in range(n_stages):
        h = stage_fn(jax.tree.map(lambda t: t[i], params_stacked), h)
    return h
