"""HLO analysis: collective-bytes extraction + roofline terms from a
compiled dry-run artifact.

cost_analysis() gives FLOPs / bytes-accessed for the *per-device* partitioned
module; collective bytes are NOT in cost_analysis, so we parse the optimized
HLO text and sum operand sizes of every communication op, converting to
effective wire bytes with ring-algorithm factors over the parsed
replica_groups size.

Hardware model (TPU v5e-like, per assignment):
    peak bf16 compute : 197 TFLOP/s / chip
    HBM bandwidth     : 819 GB/s / chip
    ICI link bandwidth: ~50 GB/s / link
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# Tolerant of non-numeric dim tokens: bounded-dynamic dims ("<=16" — use
# the bound) and unranked/scalar "[]" must not make the whole shape silently
# vanish (the old `[\d,]*` regex returned 0 bytes for both).
_SHAPE_RE = re.compile(r"(\w+)\[([^\]]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for tok in dims.split(","):
        tok = tok.strip()
        if not tok:
            continue          # "[]": scalar / unranked — one element
        if tok.startswith("<="):
            tok = tok[2:]     # bounded-dynamic dim: charge the bound
        if tok.isdigit():
            n *= int(tok)
    return n * _DTYPE_BYTES[dtype]


def _result_bytes(line: str) -> int:
    """Sum byte sizes of the result shape(s) on an HLO op line (LHS of =)."""
    lhs = line.split(" = ", 1)
    if len(lhs) != 2:
        return 0
    # result shapes appear at the start of the RHS
    rhs = lhs[1]
    op_pos = min((rhs.find(c) for c in _COLLECTIVES if rhs.find(c) >= 0),
                 default=-1)
    head = rhs[:op_pos] if op_pos > 0 else rhs.split("(")[0]
    shapes = _SHAPE_RE.findall(head)
    # Async "-start" collectives return a tuple aliasing their operands,
    # (in_0..in_{k-1}, out_0..out_{k-1}); only the output half is the
    # collective's result — summing the whole tuple double-counts.
    if ("-start(" in rhs and head.lstrip().startswith("(")
            and len(shapes) >= 2 and len(shapes) % 2 == 0):
        shapes = shapes[len(shapes) // 2:]
    return sum(_shape_bytes(d, dims) for d, dims in shapes)


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return total_devices


@dataclasses.dataclass
class CollectiveStats:
    ops: dict            # op kind -> count
    result_bytes: dict   # op kind -> sum of result-shape bytes (per device)
    wire_bytes: float    # ring-effective bytes through each device's links

    def total_result_bytes(self) -> float:
        return float(sum(self.result_bytes.values()))


def collective_stats(hlo_text: str, total_devices: int) -> CollectiveStats:
    ops: dict[str, int] = {}
    rbytes: dict[str, float] = {}
    wire = 0.0
    for line in hlo_text.splitlines():
        s = line.strip()
        if " = " not in s:
            continue
        kind = None
        for c in _COLLECTIVES:
            # match the op name, e.g. "all-gather(", "all-gather-start("
            if re.search(rf"\b{c}(-start)?\(", s):
                kind = c
                break
        if kind is None:
            continue
        b = _result_bytes(s)
        g = max(_group_size(s, total_devices), 1)
        ops[kind] = ops.get(kind, 0) + 1
        rbytes[kind] = rbytes.get(kind, 0.0) + b
        # ring-algorithm effective wire bytes per device
        if kind == "all-gather":
            wire += b * (g - 1) / g
        elif kind == "reduce-scatter":
            wire += b * (g - 1)            # result is the scattered shard
        elif kind == "all-reduce":
            wire += 2 * b * (g - 1) / g
        elif kind == "all-to-all":
            wire += b * (g - 1) / g
        elif kind == "collective-permute":
            wire += b
    return CollectiveStats(ops=ops, result_bytes=rbytes, wire_bytes=wire)


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{opname}\(", hlo_text))


_OP_RE = re.compile(r"=\s*((?:\(?[\w\[\],\s]+\)?)?)\s*([\w-]+)\(")


def op_bytes_profile(hlo_text: str, top: int = 20):
    """Sum result-shape bytes per op kind + the largest single ops.

    A coarse where-do-the-bytes-go profile for the §Perf hypothesis loop
    (cost_analysis gives only module totals).
    """
    by_kind: dict[str, float] = {}
    biggest: list[tuple[float, str]] = []
    for line in hlo_text.splitlines():
        s = line.strip()
        if " = " not in s or s.startswith("ROOT"):
            s = s[5:].strip() if s.startswith("ROOT ") else s
        if " = " not in s:
            continue
        _, rhs = s.split(" = ", 1)
        # op name = first identifier after the shape spec
        om = re.search(r"\)?\s*([a-z][\w-]*)\(", rhs)
        if not om:
            continue
        kind = om.group(1)
        head = rhs[: om.start()]
        b = sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(head))
        if not b:
            continue
        by_kind[kind] = by_kind.get(kind, 0.0) + b
        biggest.append((b, f"{kind} {head.strip()[:80]}"))
    biggest.sort(reverse=True)
    return (sorted(by_kind.items(), key=lambda kv: -kv[1])[:top],
            biggest[:top])


@dataclasses.dataclass
class RooflineTerms:
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    bound: str
    collectives: CollectiveStats
    memory_stats: dict
    model_flops: float = 0.0
    model_flops_ratio: float = 0.0

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "wire_bytes_per_device": self.wire_bytes_per_device,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bound": self.bound,
            "collective_ops": self.collectives.ops,
            "collective_result_bytes": self.collectives.result_bytes,
            "memory_stats": self.memory_stats,
            "model_flops": self.model_flops,
            "model_flops_ratio": self.model_flops_ratio,
        }


def roofline(compiled, *, total_devices: int, model_flops: float = 0.0,
             hlo_text: str | None = None) -> RooflineTerms:
    """Three-term roofline from a compiled artifact (per-device module)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older API returns [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_stats(text, total_devices)
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    collective_s = coll.wire_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bound = max(terms, key=terms.get)
    try:
        mem = compiled.memory_analysis()
        memory_stats = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        }
    except Exception:  # pragma: no cover - backend-dependent
        memory_stats = {}
    mf_ratio = (model_flops / (flops * total_devices)
                if flops and model_flops else 0.0)
    return RooflineTerms(
        flops_per_device=flops, bytes_per_device=bytes_accessed,
        wire_bytes_per_device=coll.wire_bytes, compute_s=compute_s,
        memory_s=memory_s, collective_s=collective_s, bound=bound,
        collectives=coll, memory_stats=memory_stats,
        model_flops=model_flops, model_flops_ratio=mf_ratio)
