"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the result cache.

    PYTHONPATH=src python -m repro.launch.report > experiments/tables.md
"""
from __future__ import annotations

import glob
import json
import os

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")


def load(tag_filter=None):
    recs = []
    for p in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        r = json.load(open(p))
        tag = r.get("tag", "")
        if tag_filter is None and tag:
            continue
        if tag_filter is not None and tag != tag_filter:
            continue
        recs.append(r)
    return recs


def _fmt_gb(x):
    return f"{x / 1e9:.2f}"


def dryrun_table():
    print("| arch | shape | mesh | status | args GB/dev | temp GB/dev "
          "| HLO GFLOP/dev | HLO GB/dev | wire GB/dev | collectives |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in load():
        if r["status"] == "skipped":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP"
                  f" (full attention, sub-quadratic required) | | | | | | |")
            continue
        mem = r.get("memory_stats", {})
        coll = r.get("collective_ops", {})
        coll_s = " ".join(f"{k}:{v}" for k, v in sorted(coll.items()))
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']} "
              f"| {_fmt_gb(mem.get('argument_bytes', 0))} "
              f"| {_fmt_gb(mem.get('temp_bytes', 0))} "
              f"| {r['flops_per_device'] / 1e9:.0f} "
              f"| {_fmt_gb(r['bytes_per_device'])} "
              f"| {_fmt_gb(r['wire_bytes_per_device'])} "
              f"| {coll_s} |")


def roofline_table():
    print("| arch | shape | mesh | compute s | memory s | collective s "
          "| bound | roofline frac | 6ND/HLO |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in load():
        if r["status"] != "ok":
            continue
        step = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / step if step else 0.0
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
              f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
              f"| {r['collective_s']:.3f} | {r['bound']} | {frac:.3f} "
              f"| {r.get('model_flops_ratio', 0):.2f} |")


def perf_table():
    tagged = [r for r in
              (json.load(open(p)) for p in
               sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))))
              if r.get("tag")]
    base = {(r["arch"], r["shape"], r["mesh"]): r for r in load()}
    print("| cell | iteration | compute s | memory s | adj. memory s "
          "| collective s | bound | Δ dominant |")
    print("|---|---|---|---|---|---|---|---|")
    for r in tagged:
        key = (r["arch"], r["shape"], r["mesh"])
        b = base.get(key)
        if r["status"] != "ok":
            print(f"| {key[0]}/{key[1]} | {r['tag']} | ERROR: "
                  f"{r.get('error', '')[:60]} | | | | | |")
            continue
        dom = b["bound"] if b else "?"
        before = b[f"{dom}_s"] if b else 0
        after_key = ("adjusted_memory_s"
                     if dom == "memory" and "adjusted_memory_s" in r
                     else f"{dom}_s")
        after = r.get(after_key, r.get(f"{dom}_s", 0))
        delta = (1 - after / before) * 100 if before else 0
        adj = r.get("adjusted_memory_s")
        print(f"| {key[0]}/{key[1]} | {r['tag']} | {r['compute_s']:.3f} "
              f"| {r['memory_s']:.3f} | "
              f"{'' if adj is None else f'{adj:.3f}'} "
              f"| {r['collective_s']:.3f} | {r['bound']} "
              f"| {delta:+.0f}% on {dom} |")


if __name__ == "__main__":
    print("## Dry-run (generated)\n")
    dryrun_table()
    print("\n## Roofline (generated)\n")
    roofline_table()
    print("\n## Perf iterations (generated)\n")
    perf_table()
