"""Serving runtime: batched decoding with KV caches + the paper's runtime
accuracy<->throughput switch.

The BinArray §IV-D feature — hardware built for M_arch levels can serve in
high-accuracy mode (M = 2·M_arch, two passes) or high-throughput mode
(M = M_arch, one pass) *at runtime* — maps to the ``m_active`` knob of the
binary-linear path: the packed buffers hold M levels; each request batch
chooses how many to apply.

`Server` implements continuous batching over a request queue: prefill on
arrival (teacher-forced forward to warm the cache), then step-wise batched
decode; slots free as sequences finish.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import api


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int = 16
    m_active: int | None = None   # paper §IV-D runtime mode (None = all levels)
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    """Single-host batched decode server (greedy sampling)."""

    def __init__(self, cfg: ArchConfig, params, *, max_batch: int = 8,
                 max_len: int = 256):
        from repro.models import common as cm

        cm.set_axis_rules(None)  # single-host serve: no mesh constraints
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.cache = api.init_cache(cfg, max_batch, max_len)
        self.pos = np.zeros((max_batch,), np.int32)
        self.slots: list[Request | None] = [None] * max_batch
        self._decode = jax.jit(
            lambda p, b: api.decode_step(cfg, p, b))

    # ------------------------------------------------------------ admit ---
    def admit(self, req: Request) -> bool:
        for i, slot in enumerate(self.slots):
            if slot is None:
                self.slots[i] = req
                self._prefill(i, req)
                return True
        return False

    def _prefill(self, slot: int, req: Request):
        """Feed the prompt token-by-token through decode_step (cache warmup).

        (Bulk prefill via forward() + cache scatter is the optimized path —
        see EXPERIMENTS.md §Perf; token-wise warmup keeps the reference
        implementation simple and bit-identical.)
        """
        self.pos[slot] = 0
        # feed all but the last prompt token; step() feeds the last one and
        # collects the first prediction (no double-insert into the cache)
        for t in req.prompt[:-1]:
            self._step_one(slot, int(t))

    def _step_one(self, slot: int, token: int) -> int:
        B = self.max_batch
        tokens = np.zeros((B, 1), np.int32)
        tokens[slot, 0] = token
        batch = {"tokens": jnp.asarray(tokens),
                 "pos": jnp.asarray(self.pos.copy()),
                 "cache": self.cache}
        logits, self.cache = self._decode(self.params, batch)
        self.pos[slot] += 1
        return int(jnp.argmax(logits[slot, 0]))

    # ------------------------------------------------------------- step ---
    def step(self):
        """One batched decode step for every active slot."""
        active = [i for i, r in enumerate(self.slots) if r and not r.done]
        if not active:
            return
        B = self.max_batch
        tokens = np.zeros((B, 1), np.int32)
        for i in active:
            r = self.slots[i]
            tokens[i, 0] = (r.out_tokens[-1] if r.out_tokens
                            else int(r.prompt[-1]))
        batch = {"tokens": jnp.asarray(tokens),
                 "pos": jnp.asarray(self.pos.copy()),
                 "cache": self.cache}
        logits, self.cache = self._decode(self.params, batch)
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
        for i in active:
            r = self.slots[i]
            r.out_tokens.append(int(nxt[i]))
            self.pos[i] += 1
            if (len(r.out_tokens) >= r.max_new_tokens
                    or self.pos[i] >= self.max_len - 1):
                r.done = True
                self.slots[i] = None if r.done else r

    def run_until_done(self, max_steps: int = 10_000):
        for _ in range(max_steps):
            if not any(r and not r.done for r in self.slots):
                break
            self.step()
