"""Serving runtime: batched decoding with KV caches + the paper's runtime
accuracy<->throughput switch.

The BinArray §IV-D feature — hardware built for M_arch levels can serve in
high-accuracy mode (M = 2·M_arch, two passes) or high-throughput mode
(M = M_arch, one pass) *at runtime* — maps to the ``m_active`` knob of the
binary-linear path: the packed buffers hold M levels; each **request**
chooses how many to apply via ``Request.m_active``.

Because m_active selects how many statically-unrolled level matmuls run, it
is a compile-time constant of the decode step: the server keeps one jitted
decode function per distinct m_active it has seen (at most M+1 of them) and,
each step, groups the active slots by their requested level count and runs
one batched decode per group.  Slots outside the running group see a zero
token; the cache rows that writes are transient — they always land at a
position the owning slot has not yet attended past, and that slot's next
real decode overwrites the row before attending to it (the same mechanism
token-wise prefill relies on).  This invariant holds for positional KV
caches only; recurrent-state families are rejected at admit time.

`Server` implements continuous batching over a request queue: prefill on
arrival (teacher-forced forward to warm the cache), then step-wise batched
decode; slots free as sequences finish.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import api


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int = 16
    m_active: int | None = None   # paper §IV-D runtime mode (None = all levels)
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    last_logits: np.ndarray | None = None   # [V] logits of the newest token


class Server:
    """Single-host batched decode server (greedy sampling)."""

    def __init__(self, cfg: ArchConfig, params, *, max_batch: int = 8,
                 max_len: int = 256):
        from repro.models import common as cm

        cm.set_axis_rules(None)  # single-host serve: no mesh constraints
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.cache = api.init_cache(cfg, max_batch, max_len)
        self.pos = np.zeros((max_batch,), np.int32)
        self.slots: list[Request | None] = [None] * max_batch
        # one jitted decode per distinct m_active (§IV-D: the level count is
        # static — it sets how many unrolled level matmuls the step runs)
        self._decode_fns: dict[int | None, Callable] = {}

    def _norm_m(self, m_active: int | None) -> int | None:
        """Canonical per-request level count: clamp to [1, M] (a request
        asking for more levels than the buffers hold serves full-accuracy),
        and collapse an explicit request for the server's default count onto
        the ``None`` key — same computation, one shared jitted decode and
        one shared batch group per step."""
        if m_active is None:
            return None
        m_active = max(1, min(m_active, self.cfg.quant.M))
        default = self.cfg.quant.m_active or self.cfg.quant.M
        return None if m_active == default else m_active

    def _decode_for(self, m_active: int | None) -> Callable:
        m_active = self._norm_m(m_active)
        fn = self._decode_fns.get(m_active)
        if fn is None:
            cfg = self.cfg
            if m_active is not None:
                cfg = cfg.replace(quant=cfg.quant.replace(m_active=m_active))
            fn = jax.jit(functools.partial(api.decode_step, cfg))
            self._decode_fns[m_active] = fn
        return fn

    # ------------------------------------------------------------ admit ---
    def admit(self, req: Request) -> bool:
        if self.cfg.family in ("ssm", "hybrid"):
            # Recurrent-state families update ssm/conv state unconditionally
            # for every batch row, so the transient-cache-row argument above
            # does not apply: a grouped decode would advance non-group
            # slots' recurrent state with pad tokens.  One level count per
            # Server until masked state updates land (ROADMAP).
            keys = {self._norm_m(r.m_active)
                    for r in self.slots if r and not r.done}
            if keys and self._norm_m(req.m_active) not in keys:
                raise ValueError(
                    "mixed per-request m_active is not supported for "
                    f"family={self.cfg.family!r} (recurrent state); serve "
                    "one level count per Server instance")
        for i, slot in enumerate(self.slots):
            if slot is None:
                self.slots[i] = req
                self._prefill(i, req)
                return True
        return False

    def _prefill(self, slot: int, req: Request):
        """Feed the prompt token-by-token through decode_step (cache warmup).

        (Bulk prefill via forward() + cache scatter is the optimized path —
        see EXPERIMENTS.md §Perf; token-wise warmup keeps the reference
        implementation simple and bit-identical.)
        """
        self.pos[slot] = 0
        # feed all but the last prompt token; step() feeds the last one and
        # collects the first prediction (no double-insert into the cache)
        for t in req.prompt[:-1]:
            self._step_one(slot, int(t), req.m_active)

    def _step_one(self, slot: int, token: int,
                  m_active: int | None = None) -> int:
        B = self.max_batch
        tokens = np.zeros((B, 1), np.int32)
        tokens[slot, 0] = token
        batch = {"tokens": jnp.asarray(tokens),
                 "pos": jnp.asarray(self.pos.copy()),
                 "cache": self.cache}
        logits, self.cache = self._decode_for(m_active)(self.params, batch)
        self.pos[slot] += 1
        return int(jnp.argmax(logits[slot, 0]))

    # ------------------------------------------------------------- step ---
    def step(self):
        """One batched decode step for every active slot.

        Slots are grouped by their request's ``m_active`` (§IV-D level
        count); each group runs one batched decode compiled for that count,
        so a single server round serves high-accuracy and high-throughput
        requests side by side off the same packed buffers.
        """
        active = [i for i, r in enumerate(self.slots) if r and not r.done]
        if not active:
            return
        B = self.max_batch
        groups: dict[int | None, list[int]] = {}
        for i in active:
            groups.setdefault(self._norm_m(self.slots[i].m_active), []).append(i)
        for m_active, idxs in groups.items():
            tokens = np.zeros((B, 1), np.int32)
            for i in idxs:
                r = self.slots[i]
                tokens[i, 0] = (r.out_tokens[-1] if r.out_tokens
                                else int(r.prompt[-1]))
            batch = {"tokens": jnp.asarray(tokens),
                     "pos": jnp.asarray(self.pos.copy()),
                     "cache": self.cache}
            logits, self.cache = self._decode_for(m_active)(self.params, batch)
            nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
            for i in idxs:
                r = self.slots[i]
                r.out_tokens.append(int(nxt[i]))
                r.last_logits = np.asarray(logits[i, 0])
                self.pos[i] += 1
                if (len(r.out_tokens) >= r.max_new_tokens
                        or self.pos[i] >= self.max_len - 1):
                    r.done = True
                    self.slots[i] = None

    def run_until_done(self, max_steps: int = 10_000):
        for _ in range(max_steps):
            if not any(r and not r.done for r in self.slots):
                break
            self.step()
