"""Serving runtime: batched decoding with KV caches + the paper's runtime
accuracy<->throughput switch.

The BinArray §IV-D feature — hardware built for M_arch levels can serve in
high-accuracy mode (M = 2·M_arch, two passes) or high-throughput mode
(M = M_arch, one pass) *at runtime* — maps to the ``m_active`` knob of the
binary-linear path: the packed buffers hold M levels; each **request**
chooses how many to apply via ``Request.m_active``.

Because m_active selects how many statically-unrolled level matmuls run, it
is a compile-time constant of the decode step: the server keeps one jitted
decode function per distinct m_active it has seen (at most M+1 of them) and,
each step, groups the active slots by their requested level count and runs
one batched decode per group.  Two mechanisms keep non-group slots' cached
state intact while a group runs:

* positional KV caches (transformer/hybrid attention): the zero-token rows a
  grouped decode writes for non-group slots are *transient* — they always
  land at a position the owning slot has not yet attended past, and that
  slot's next real decode overwrites the row before attending to it.
* recurrent state (ssm/hybrid mamba): the decode step takes a per-slot
  ``update_mask`` ([B] bool) and keeps masked rows' ssm/conv state
  bit-exact, so mixed per-request level counts serve for every family
  (docs/serving.md §masking).

Admission runs **bulk prefill**: one ``api.prefill`` forward over the prompt
(B=1) emits logits *and* the decode cache, which ``api.scatter_cache``
writes into the slot's row of the serving arrays — one device program
instead of O(prompt_len) decode steps, and by construction it cannot touch
concurrent slots' state.  Families without a prefill path (encdec/vlm) fall
back to masked token-wise warmup (``prefill="tokenwise"`` forces the
fallback everywhere; the parity tests and the admission-latency benchmark
compare both).

Because the prefill forward is jitted per prompt length, mixed-length
traffic would compile one program per distinct length.  ``Server`` therefore
**buckets** prompt lengths (``prefill_buckets``: powers of two by default,
or an explicit bucket list): the prompt is right-padded to the bucket
boundary before the forward, so the compile count is bounded by the number
of buckets.  Right-padding is exact for *positional-KV-only* caches (dense /
moe without a sliding window): causal attention makes rows < L independent
of the pad tokens, and the pad KV rows written at positions >= L are
transient — the slot's own decode overwrites each row before attending past
it, the same invariant grouped decode relies on.  Recurrent-state families
(ssm/hybrid) and rolling SWA caches are served with exact lengths instead:
an ssm final state would absorb the pad tokens, and a ring cache would let
pad rows wrap onto live positions.  ``stats`` exposes the bucket behavior
(``prefill_bucket_hits`` / ``prefill_unique_lens``).

`Server` implements continuous batching over a request queue: prefill on
arrival, then step-wise batched decode; slots free as sequences finish.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import api


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int = 16
    m_active: int | tuple | list | None = None
    #   paper §IV-D runtime mode: None = all levels, int = uniform level
    #   count, sequence = per-decoder-layer schedule (entry i applies to
    #   layer i, last entry extends; same shape deploy.execute takes)
    deadline_s: float | None = None  # absolute time.monotonic() deadline;
    #                                  expired-on-arrival requests are shed
    #                                  at admit (same contract as serve_cnn)
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    last_logits: np.ndarray | None = None   # [V] logits of the newest token


class Server:
    """Single-host batched decode server (greedy sampling).

    ``prefill`` selects the admission path: ``"auto"`` (default) uses bulk
    prefill when the family supports it, ``"bulk"`` requires it,
    ``"tokenwise"`` forces the step-wise reference path (used by the parity
    tests and the admission-latency benchmark).

    ``prefill_buckets`` bounds the bulk-prefill compile count under
    mixed-length traffic: ``"pow2"`` (default) right-pads each prompt to the
    next power of two, an explicit sorted list pads to the smallest bucket
    that fits (lengths beyond the last bucket run exact), ``None`` disables
    padding.  Padding only applies where it is provably exact — positional-
    KV-only caches (dense/moe, no sliding window); recurrent/rolling caches
    always prefill at the exact length (module docstring).

    ``stats`` counts device programs per path: ``bulk_prefills`` (one per
    bulk admission), ``tokenwise_prefill_steps`` (one per warmed prompt
    token), ``decode_steps`` (one per served group per round); for the
    bucketing: ``prefill_bucket_hits`` (bulk prefills that reused an
    already-compiled padded length) and ``prefill_unique_lens`` (distinct
    (m_active, padded length) pairs seen — each pair is one compiled
    prefill executable, since the per-m jitted functions each specialize
    per length).
    """

    def __init__(self, cfg: ArchConfig, params, *, max_batch: int = 8,
                 max_len: int = 256, prefill: str = "auto",
                 prefill_buckets: str | list[int] | None = "pow2"):
        from repro.models import common as cm

        cm.set_axis_rules(None)  # single-host serve: no mesh constraints
        if prefill not in ("auto", "bulk", "tokenwise"):
            raise ValueError(f"unknown prefill mode {prefill!r}")
        if prefill == "bulk" and cfg.family not in api.BULK_PREFILL_FAMILIES:
            raise ValueError(
                f"bulk prefill is not implemented for family={cfg.family!r}")
        if not (prefill_buckets is None or prefill_buckets == "pow2"
                or isinstance(prefill_buckets, (list, tuple))):
            raise ValueError(f"unknown prefill_buckets {prefill_buckets!r}")
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.prefill_mode = prefill
        self.prefill_buckets = (sorted(prefill_buckets)
                                if isinstance(prefill_buckets, (list, tuple))
                                else prefill_buckets)
        self.cache = api.init_cache(cfg, max_batch, max_len)
        self.pos = np.zeros((max_batch,), np.int32)
        self.slots: list[Request | None] = [None] * max_batch
        # one jitted decode per distinct m_active (§IV-D: the level count is
        # static — it sets how many unrolled level matmuls the step runs);
        # ditto for the prefill pass, which runs the same binary linears
        self._decode_fns: dict[int | tuple | None, Callable] = {}
        self._prefill_fns: dict[int | tuple | None, Callable] = {}
        self._scatter_fn = jax.jit(functools.partial(api.scatter_cache, cfg))
        self._prefill_lens_seen: set[tuple[int | None, int]] = set()
        self.stats = {"bulk_prefills": 0, "tokenwise_prefill_steps": 0,
                      "decode_steps": 0, "prefill_bucket_hits": 0,
                      "prefill_unique_lens": 0, "shed_count": 0}

    def cache_sizes(self) -> dict:
        """Entry counts of every unbounded-dict-shaped cache the server
        holds — the quantities a soak run must prove flat under repeated
        traffic (repro.testing.soak).  ``decode_fns``/``prefill_fns`` are
        the per-``m_active`` jitted closures (bounded by M+1 by
        construction); ``prefill_lens`` is the bucketed-prefill compile map
        (bounded by buckets x level counts)."""
        return {"decode_fns": len(self._decode_fns),
                "prefill_fns": len(self._prefill_fns),
                "prefill_lens": len(self._prefill_lens_seen)}

    def cache_gauges(self) -> dict:
        """``name -> callable`` gauge closures for ``repro.testing.soak``."""
        return {name: (lambda n=name: float(self.cache_sizes()[n]))
                for name in self.cache_sizes()}

    @property
    def _bulk(self) -> bool:
        return (self.prefill_mode != "tokenwise"
                and self.cfg.family in api.BULK_PREFILL_FAMILIES)

    @property
    def _pad_safe(self) -> bool:
        """Right-padding the prefill is exact only for positional-KV-only
        caches: causal attention keeps rows < L pad-independent and the pad
        rows at positions >= L are transient (overwritten before attended).
        Recurrent state (ssm/hybrid) would absorb the pads into the final
        state; a rolling SWA ring would let pad rows wrap onto live ones."""
        return (self.cfg.family in ("dense", "moe")
                and self.cfg.sliding_window is None)

    def _padded_len(self, L: int) -> int:
        """Bucketed prefill length for a true prompt-prefix length ``L``."""
        if self.prefill_buckets is None or not self._pad_safe or L < 1:
            return L
        if self.prefill_buckets == "pow2":
            b = 1
            while b < L:
                b *= 2
        else:
            b = next((x for x in self.prefill_buckets if x >= L), L)
        return max(min(b, self.max_len - 1), L)

    def _norm_m(self, m_active) -> int | tuple | None:
        """Canonical per-request level count: clamp to [1, M] (a request
        asking for more levels than the buffers hold serves full-accuracy),
        and collapse an explicit request for the server's default count onto
        the ``None`` key — same computation, one shared jitted decode and
        one shared batch group per step.  A per-layer schedule normalizes
        to a clamped tuple; a uniform tuple collapses onto its single level
        (so ``(2, 2)`` and ``2`` share one compiled variant and one batch
        group)."""
        if m_active is None:
            return None
        if isinstance(m_active, (tuple, list)):
            sched = tuple(max(1, min(int(m), self.cfg.quant.M))
                          for m in m_active)
            if len(set(sched)) > 1:
                return sched
            m_active = sched[0]     # uniform schedule == one level count
        m_active = max(1, min(int(m_active), self.cfg.quant.M))
        default = self.cfg.quant.m_active or self.cfg.quant.M
        return None if m_active == default else m_active

    def _cfg_for(self, m_active: int | tuple | None) -> ArchConfig:
        """Specialize the arch config to a normalized §IV-D mode: an int
        sets the uniform level count, a tuple installs the per-layer
        schedule (``quant.m_schedule``, resolved by the layer walks)."""
        if m_active is None:
            return self.cfg
        if isinstance(m_active, tuple):
            return self.cfg.replace(quant=self.cfg.quant.replace(
                m_active=None, m_schedule=m_active))
        return self.cfg.replace(
            quant=self.cfg.quant.replace(m_active=m_active))

    def _decode_for(self, m_active) -> Callable:
        m_active = self._norm_m(m_active)
        fn = self._decode_fns.get(m_active)
        if fn is None:
            fn = jax.jit(functools.partial(api.decode_step,
                                           self._cfg_for(m_active)))
            self._decode_fns[m_active] = fn
        return fn

    def _prefill_for(self, m_active) -> Callable:
        m_active = self._norm_m(m_active)
        fn = self._prefill_fns.get(m_active)
        if fn is None:
            fn = jax.jit(functools.partial(api.prefill,
                                           self._cfg_for(m_active),
                                           max_len=self.max_len))
            self._prefill_fns[m_active] = fn
        return fn

    # ------------------------------------------------------------ admit ---
    def admit(self, req: Request) -> bool:
        """Place ``req`` in a free slot and prefill it; False when full.

        Admission control mirrors the CNN tier (repro.serve_cnn): a request
        whose ``deadline_s`` (absolute ``time.monotonic()``) has already
        expired is *shed* — rejected up front, counted in
        ``stats["shed_count"]`` — instead of burning a prefill it can never
        repay.  Both serving tiers report shedding through the same key.

        Raises ValueError on malformed requests (empty/oversized prompt, or
        ``m_active < 1`` — the kernel path would silently clamp a 0 to one
        level, which is never what the caller meant; values *above* the
        packed level count M serve full accuracy, documented clamp).
        """
        if req.deadline_s is not None and req.deadline_s <= time.monotonic():
            self.stats["shed_count"] += 1
            return False
        if req.m_active is not None:
            ms = (req.m_active if isinstance(req.m_active, (tuple, list))
                  else [req.m_active])
            if len(ms) == 0 or any(int(m) < 1 for m in ms):
                raise ValueError(
                    f"Request.m_active entries must be >= 1 (got "
                    f"{req.m_active}); use None to serve all packed levels")
        n_prompt = int(np.asarray(req.prompt).size)
        if n_prompt < 1:
            raise ValueError("Request.prompt must hold at least one token")
        if n_prompt + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt ({n_prompt}) + max_new_tokens ({req.max_new_tokens})"
                f" exceeds max_len={self.max_len}")
        for i, slot in enumerate(self.slots):
            if slot is None:
                self.slots[i] = req
                self._prefill(i, req)
                return True
        return False

    def _prefill(self, slot: int, req: Request):
        """Warm slot ``slot``'s cache over the prompt.

        Bulk path: one ``api.prefill`` forward over ``prompt[:-1]`` (B=1) —
        right-padded to the length bucket where exact (``_padded_len``) —
        then scatter the returned cache into the slot's row: admission is
        O(1) device programs instead of O(prompt_len), and the compile
        count is bounded by the bucket count instead of the distinct-length
        count.  step() feeds the last prompt token and collects the first
        prediction (no double-insert into the cache).  Token-wise fallback
        feeds the same tokens through the masked decode step.
        """
        self.pos[slot] = 0
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        if prompt.size <= 1:
            return
        if self._bulk:
            L = prompt.size - 1
            Lb = self._padded_len(L)
            toks = prompt[:-1]
            if Lb > L:  # pad KV rows >= L are transient (see _pad_safe)
                toks = np.concatenate(
                    [toks, np.zeros((Lb - L,), np.int32)])
            key = (self._norm_m(req.m_active), Lb)
            if key in self._prefill_lens_seen:
                self.stats["prefill_bucket_hits"] += 1
            else:
                self._prefill_lens_seen.add(key)
                self.stats["prefill_unique_lens"] = len(
                    self._prefill_lens_seen)
            fn = self._prefill_for(req.m_active)
            _, part = fn(self.params, jnp.asarray(toks[None]))
            self.cache = self._scatter_fn(self.cache, slot, part)
            self.pos[slot] = prompt.size - 1
            self.stats["bulk_prefills"] += 1
        else:
            for t in prompt[:-1]:
                self._step_one(slot, int(t), req.m_active)

    def _step_one(self, slot: int, token: int,
                  m_active: int | None = None) -> int:
        B = self.max_batch
        tokens = np.zeros((B, 1), np.int32)
        tokens[slot, 0] = token
        mask = np.zeros((B,), bool)
        mask[slot] = True
        batch = {"tokens": jnp.asarray(tokens),
                 "pos": jnp.asarray(self.pos.copy()),
                 "cache": self.cache,
                 "update_mask": jnp.asarray(mask)}
        logits, self.cache = self._decode_for(m_active)(self.params, batch)
        self.pos[slot] += 1
        self.stats["tokenwise_prefill_steps"] += 1
        return int(jnp.argmax(logits[slot, 0]))

    # ------------------------------------------------------------- step ---
    def step(self):
        """One batched decode step for every active slot.

        Slots are grouped by their request's ``m_active`` (§IV-D level
        count); each group runs one batched decode compiled for that count,
        so a single server round serves high-accuracy and high-throughput
        requests side by side off the same packed buffers.  The group's
        ``update_mask`` keeps recurrent state of non-group slots bit-exact
        (positional KV rows rely on the transient-row invariant instead).
        """
        active = [i for i, r in enumerate(self.slots) if r and not r.done]
        if not active:
            return
        B = self.max_batch
        groups: dict[int | None, list[int]] = {}
        for i in active:
            groups.setdefault(self._norm_m(self.slots[i].m_active), []).append(i)
        for m_active, idxs in groups.items():
            tokens = np.zeros((B, 1), np.int32)
            mask = np.zeros((B,), bool)
            for i in idxs:
                r = self.slots[i]
                tokens[i, 0] = (r.out_tokens[-1] if r.out_tokens
                                else int(r.prompt[-1]))
                mask[i] = True
            batch = {"tokens": jnp.asarray(tokens),
                     "pos": jnp.asarray(self.pos.copy()),
                     "cache": self.cache,
                     "update_mask": jnp.asarray(mask)}
            logits, self.cache = self._decode_for(m_active)(self.params, batch)
            self.stats["decode_steps"] += 1
            nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
            for i in idxs:
                r = self.slots[i]
                r.out_tokens.append(int(nxt[i]))
                r.last_logits = np.asarray(logits[i, 0])
                self.pos[i] += 1
                if (len(r.out_tokens) >= r.max_new_tokens
                        or self.pos[i] >= self.max_len - 1):
                    r.done = True
                    self.slots[i] = None

    def run_until_done(self, max_steps: int = 10_000):
        for _ in range(max_steps):
            if not any(r and not r.done for r in self.slots):
                break
            self.step()
