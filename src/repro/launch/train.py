"""End-to-end training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch gemma_2b --reduced \
        --steps 50 --checkpoint-dir /tmp/ckpt

On the CPU container this trains reduced configs on the synthetic pipeline;
the same entry point drives full configs on real meshes (the mesh geometry
and sharding rules are identical — see launch/dryrun.py for the compile-time
proof at production scale).
"""
from __future__ import annotations

import argparse
import logging


from repro.configs import base as cb
from repro.data.tokens import SyntheticTokens
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw, warmup_cosine
from repro.runtime.trainer import Trainer, TrainerConfig


def main() -> None:
    logging.basicConfig(level=logging.INFO)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--quant-mode", default="dense",
                    choices=["dense", "fake_quant"])
    ap.add_argument("--quant-M", type=int, default=2)
    ap.add_argument("--grad-compress-M", type=int, default=0)
    args = ap.parse_args()

    cfg = cb.get_config(args.arch)
    if args.reduced:
        cfg = cb.reduced(cfg)
    if args.quant_mode != "dense":
        cfg = cfg.replace(quant=cfg.quant.replace(
            mode=args.quant_mode, M=args.quant_M))

    mesh = make_host_mesh()
    optimizer = adamw(warmup_cosine(args.lr, 10, args.steps))
    state = steps_mod.init_train_state(cfg, mesh, optimizer)
    if args.grad_compress_M:
        from repro.core import compress as gcomp

        grads_like = state["params"]
        state["grad_comp"] = gcomp.init_state(grads_like)
    step_fn, _ = steps_mod.build_train_step(
        cfg, mesh, optimizer, grad_compress_M=args.grad_compress_M,
        donate=False)
    data = SyntheticTokens(cfg.vocab, args.seq, args.batch)
    trainer = Trainer(step_fn, state, data, TrainerConfig(
        total_steps=args.steps, checkpoint_every=args.checkpoint_every,
        checkpoint_dir=args.checkpoint_dir))
    trainer.maybe_resume()
    with mesh:
        report = trainer.run()
    print(f"done: {report.steps_run} steps, "
          f"final loss {report.losses[-1]:.4f}, "
          f"resumed_from={report.resumed_from}, "
          f"stragglers={len(report.straggler_events)}, "
          f"nan_skips={report.nan_skips}")


if __name__ == "__main__":
    main()
