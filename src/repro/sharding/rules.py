"""Sharding rules: parameter + activation partitioning for every arch family.

Mesh axes:
  * single-pod:  ("data", "model")          = 16 x 16  (256 chips)
  * multi-pod:   ("pod", "data", "model")   = 2 x 16 x 16 (512 chips)

Strategy (DESIGN.md §4):
  * TP   — attention heads / FFN hidden / experts / vocab on "model".
  * FSDP — every parameter's largest non-TP dim additionally sharded over
           the DP domain ("pod"+"data") — ZeRO-3; optimizer state likewise.
  * DP   — batch over ("pod", "data"); SP — sequence over "data" for the
           batch=1 long-context cells.

Rules are *pattern -> PartitionSpec* over parameter tree paths; first match
wins; unmatched leaves are replicated (biases, norms, scalars).
"""
from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _fsdp(*axes):
    """Helper marker: replaced by the DP domain at resolution time."""
    return axes


# Each entry: (regex over 'path', [candidate specs — first that divides the
# leaf's dims wins]).  Weight matrices are [in, out].
def _param_rules(cfg: ArchConfig, mesh: Mesh, fsdp: bool = True):
    dp = dp_axes(mesh) if fsdp else None
    rules: list[tuple[str, list[P]]] = [
        # embeddings / unembeddings: vocab on model, d_model FSDP
        (r"(embed|unembed)/table", [P("model", dp), P(dp, "model"), P(dp, None)]),
        # MoE experts: expert dim on model (EP); fallback = TP over hidden
        # (grok: 8 experts < 16-way model axis -> TP inside experts)
        (r"moe/w_(gate|up)$", [P("model", dp, None), P(None, dp, "model")]),
        (r"moe/w_down$", [P("model", None, dp), P(None, "model", dp)]),
        (r"moe/router/w", [P()]),
        # attention projections: fused head dim on model, d_model FSDP
        (r"attn/w(q|k|v)/w", [P(dp, "model"), P(dp, None)]),
        (r"attn/wo/w", [P("model", dp), P(None, dp)]),
        (r"attn/w(q|k|v)/b", [P("model"), P()]),
        # MLA factors
        (r"attn/wdq/w", [P(dp, "model")]),
        (r"attn/wuq/w", [P(dp, "model")]),
        (r"attn/wdkv/w", [P(dp, None)]),
        (r"attn/wu(k|v)/w", [P(dp, "model")]),
        # FFN: hidden on model, d_model FSDP
        (r"(ffn|shared)/w_(gate|up)/w", [P(dp, "model")]),
        (r"(ffn|shared)/w_down/w", [P("model", dp)]),
        # Mamba2 projections: d_inner on model
        (r"block/in_proj/w", [P(dp, "model")]),
        (r"block/out_proj/w", [P("model", dp)]),
        (r"block/conv_w", [P(None, "model"), P()]),
        (r"block/conv_b", [P("model"), P()]),
        # hybrid shared block input projection
        (r"shared/in_proj/w", [P(dp, "model")]),
        # MTP projection
        (r"mtp/proj/w", [P(dp, "model")]),
        # packed-binary deployment weights: [M, K/8, N] (+ leading stack dim)
        # out-dim on model (TP), packed-K FSDP; alphas [M, G, N] follow N
        (r"/B_packed$", [P(None, dp, "model"), P(None, None, "model"),
                         P(None, dp, None), P()]),
        (r"/alpha$", [P(None, None, "model"), P()]),
    ]
    return rules


def _spec_divides(spec: P, shape, mesh: Mesh) -> bool:
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for dim, axes in zip(shape, entries):
        if axes is None:
            continue
        names = (axes,) if isinstance(axes, str) else tuple(axes)
        n = int(np.prod([mesh.shape[a] for a in names]))
        if dim % n != 0:
            return False
    return True


def _fit_spec(spec: P, ndim: int) -> P:
    specs = list(spec)
    while len(specs) < ndim:          # stacked-layer leading axes -> None
        specs.insert(0, None)
    if len(specs) > ndim:
        specs = specs[len(specs) - ndim:]
    return P(*specs)


def _leaf_path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def param_pspecs(cfg: ArchConfig, params_tree, mesh: Mesh, *,
                 fsdp: bool = True):
    """PartitionSpec pytree for a parameter tree (stacked layer dims get a
    leading None automatically — detected by rank vs rule arity)."""
    rules = _param_rules(cfg, mesh, fsdp)

    def spec_for(path, leaf):
        pstr = _leaf_path_str(path)
        ndim = getattr(leaf, "ndim", len(leaf.shape))
        for pat, candidates in rules:
            if re.search(pat, pstr):
                for cand in candidates:
                    fitted = _fit_spec(cand, ndim)
                    if _spec_divides(fitted, leaf.shape, mesh):
                        return fitted
                return P()  # nothing divides -> replicate
        return P()  # replicate (biases, norms, scalars)

    return jax.tree_util.tree_map_with_path(spec_for, params_tree)


def param_shardings(cfg: ArchConfig, params_tree, mesh: Mesh, *,
                    fsdp: bool = True):
    specs = param_pspecs(cfg, params_tree, mesh, fsdp=fsdp)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


# ---------------------------------------------------------------------------
# Batch / cache shardings
# ---------------------------------------------------------------------------

def batch_pspecs(cfg: ArchConfig, batch_tree, mesh: Mesh, *,
                 seq_sharded: bool = False):
    """tokens/labels: batch over DP axes (seq over 'data' when batch==1 SP);
    cache: batch over DP, heads over model."""
    dp = dp_axes(mesh)

    # actual batch size, to disambiguate the stacked-layer dim in caches
    tokens = batch_tree.get("tokens") if isinstance(batch_tree, dict) else None
    global_batch = tokens.shape[0] if tokens is not None else None

    def spec_for(path, leaf):
        pstr = _leaf_path_str(path)
        shape = leaf.shape
        ndim = len(shape)
        dp_size = int(np.prod([mesh.shape[a] for a in dp]))
        if "cache" in pstr:
            return _cache_spec(cfg, pstr, shape, mesh, global_batch)
        if pstr.endswith("pos"):
            return P(dp) if shape and shape[0] % dp_size == 0 else P()
        if "tokens" in pstr or "labels" in pstr:
            if shape[0] % dp_size == 0:
                return P(dp, *([None] * (ndim - 1)))
            if seq_sharded and ndim >= 2:
                return P(None, "data", *([None] * (ndim - 2)))
            return P()
        if "embeds" in pstr:  # patch/frame stubs: [B, S, D]
            if shape[0] % dp_size == 0:
                return P(dp, None, None)
            return P()
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, batch_tree)


def _cache_spec(cfg: ArchConfig, pstr: str, shape, mesh: Mesh,
                global_batch: int | None = None):
    """KV / SSM cache sharding: leading stacked-layer dim unsharded; batch on
    DP when divisible; kv-head dim on model when divisible."""
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    model_size = mesh.shape["model"]
    spec: list = [None] * len(shape)
    # the batch dim: matched by size when known (disambiguates the stacked
    # layer dim), else the first plausible leading dim
    for i, d in enumerate(shape[:2]):
        if global_batch is not None and d != global_batch:
            continue
        if d % dp_size == 0 and d >= dp_size:
            spec[i] = dp
            break
    # head dim: size == n_kv_heads or n_heads and divisible by model axis
    # (index 0 excluded — it's the stacked-layer dim, which can collide by
    # value, e.g. codeqwen's 32 layers == 32 kv heads)
    for i, d in enumerate(shape):
        if i == 0:
            continue
        if spec[i] is None and d in (cfg.n_kv_heads, cfg.n_heads) and d and \
                d % model_size == 0:
            spec[i] = "model"
            break
    else:
        # SSM state: shard the (large) d_inner-derived head dim on model
        matched = False
        if cfg.ssm_state and len(shape) >= 3:
            d_inner = cfg.ssm_expand * cfg.d_model
            H = d_inner // cfg.ssm_head_dim
            for i, d in enumerate(shape):
                if i == 0:
                    continue
                if spec[i] is None and d == H and d % model_size == 0:
                    spec[i] = "model"
                    matched = True
                    break
        if not matched and len(shape) >= 3 and cfg.kv_seq_shard:
            # sequence-sharded KV cache: shard the largest (seq) dim over
            # 'model' — scores partition over keys; only the softmax
            # normalizer + weighted-V partials cross shards (tiny
            # all-reduces) instead of per-layer logits partial sums.
            cands = [(d, i) for i, d in enumerate(shape)
                     if spec[i] is None and d >= 1024 and d % model_size == 0]
            if cands:
                matched = True
                spec[max(cands)[1]] = "model"
        if not matched and len(shape) >= 3:
            # kv-head count not divisible by the model axis (MQA/GQA<16) or
            # latent cache (MLA): shard the trailing feature dim on 'model'
            # instead — storage-sharded KV; attention contracts it with a
            # partial-sum all-reduce.
            d = shape[-1]
            if d % model_size == 0 and d >= model_size:
                spec[-1] = "model"
    # huge sequence dim (long-context cache, batch==1): shard over 'data'
    used = {a for s in spec if s for a in ((s,) if isinstance(s, str) else s)}
    if "data" not in used:
        for i, d in enumerate(shape):
            if spec[i] is None and d >= 8192 and d % mesh.shape["data"] == 0:
                spec[i] = "data"
                break
    return P(*spec)


def activation_rules(mesh: Mesh, *, seq_sharded: bool = False):
    """Logical-axis rules installed via models.common.set_axis_rules."""
    dp = dp_axes(mesh)
    return {
        "batch": dp,
        "seq": "data" if seq_sharded else None,
        "heads": "model",
        "kv_heads": "model",
        "ff": "model",
        "experts": "model",
        "vocab": "model",
    }
