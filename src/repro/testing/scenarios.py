"""Soak scenarios for the three long-lived serving surfaces.

Each builder returns a :class:`Scenario` — a step closure plus the gauges
that must stay flat — consumed by ``repro.testing.soak.run_soak`` from both
the ``soak``-marked pytest tier (tests/test_soak.py) and the nightly CLI
(tools/soak.py).  The scenarios are the repo's production surfaces, not
synthetic loops:

  * ``server_scenario`` — ``launch.serve.Server`` under continuous mixed
    traffic: rotating prompt lengths (exercising the bucketed-prefill map)
    and rotating per-request ``m_active`` (exercising the per-level-count
    jitted decode/prefill caches).  Every soak step is one batched decode
    round; freed slots are immediately re-admitted so the server never
    idles.  The gauges are the compiled-variant counters — bounded by
    construction, and a key-derivation bug here is a compile leak.
  * ``executor_scenario`` — ``deploy.execute`` over compiled CNN-A and
    MobileNet programs with a *fixed rotation* of §IV-D schedules (global
    ints + per-layer lists).  Distinct schedules each compile once; the
    rotation re-visits them so the trace-entry counter must freeze after
    the first lap.
  * ``checkpoint_scenario`` — the ``save_program``/``load_program`` cycle
    through ``checkpoint/manager.py``: repeated checkpointing must neither
    grow the python heap (manifest/array copies) nor the on-disk step count
    (the manager's ``keep`` GC is the gauge).
  * ``cnn_server_scenario`` — ``serve_cnn.CNNService`` under *faulty*
    cyclic traffic: a seeded ``testing.faults`` injector alternates clean /
    fault-storm / clean phases (latency spikes, raised exceptions, NaN
    outputs, plus one disk + one in-memory bit-flip per storm) on a
    virtual clock, so the SLO controller demonstrably walks down the
    §IV-D ladder under pressure and back to full-M after, and the golden
    watchdog demonstrably hot-reloads through the last-known-good
    checkpoint walk — while every completed answer is verified bit-exact
    against the *unfaulted* ``deploy.execute`` on the same padded batch,
    and every injected fault reconciles against the service's disposition
    counters (zero silently swallowed).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable

import numpy as np

_DEFAULT = object()


@dataclasses.dataclass
class Scenario:
    """A soak workload: ``step(i)`` plus flat-by-contract gauges."""

    name: str
    step: Callable[[int], None]
    gauges: dict[str, Callable[[], float]]
    # scenario-specific counters for acceptance asserts (e.g. decode steps)
    progress: Callable[[], dict]


# ---------------------------------------------------------------------------
# launch/serve.py under mixed traffic
# ---------------------------------------------------------------------------

def server_scenario(*, family: str = "gemma_2b", max_batch: int = 4,
                    max_len: int = 64, seed: int = 0) -> Scenario:
    """Continuous mixed per-request ``m_active`` + bucketed-prefill traffic.

    The admission pattern cycles prompt lengths {3, 5, 7, 9} (pow2 buckets
    2/4/8) x ``m_active`` {None, 1} so every soak step exercises grouped
    decode with two level-count groups and the prefill-length bucket map.
    """
    import jax

    from repro.configs import base as cb
    from repro.launch.serve import Request, Server
    from repro.models import api

    cfg = cb.reduced(cb.get_config(family)).replace(dtype="float32")
    params = api.init_params(cfg, jax.random.PRNGKey(seed))
    srv = Server(cfg, params, max_batch=max_batch, max_len=max_len)
    pattern = [(3, None), (5, 1), (7, None), (9, 1)]
    counter = [0]

    def admit_to_full():
        while any(s is None for s in srv.slots):
            n, m = pattern[counter[0] % len(pattern)]
            counter[0] += 1
            ok = srv.admit(Request(
                prompt=np.arange(1, n + 1, dtype=np.int32),
                max_new_tokens=4, m_active=m))
            if not ok:
                break

    def step(i: int) -> None:
        admit_to_full()
        srv.step()

    return Scenario(
        name=f"server_{family}",
        step=step,
        gauges=dict(srv.cache_gauges()),
        progress=lambda: dict(srv.stats))


# ---------------------------------------------------------------------------
# deploy.execute over compiled programs
# ---------------------------------------------------------------------------

def _rotating_schedules(program) -> list:
    """A fixed schedule rotation for one program: all levels, the global
    §IV-D throughput switch, and two per-layer schedules (front-half vs
    back-half reduced) — four distinct resolved schedules, re-visited
    forever, so the executor must stop tracing after one lap."""
    n = len(program)
    half = n // 2
    front = tuple([1] * half + [2] * (n - half))
    back = tuple([2] * half + [1] * (n - half))
    scheds = [None, 1, front, back]
    # dedupe resolved forms (tiny programs may collapse some)
    seen, out = set(), []
    for s in scheds:
        r = program.resolve_schedule(s)
        if r not in seen:
            seen.add(r)
            out.append(s)
    return out


def executor_scenario(*, archs=("cnn_a", "mobilenet"), batch: int = 2,
                      mobilenet_kw: dict = _DEFAULT,
                      seed: int = 0) -> Scenario:
    """Rotate compiled programs x §IV-D schedules through ``deploy.execute``.

    ``archs`` defaults to CNN-A plus the MobileNet (CNN-B) topology; the
    pytest soak tier runs MobileNet at reduced width/resolution (the same
    code paths as B2 — dw/pw stacks, gap head — at CPU-interpret-feasible
    cost) while ``tools/soak.py --mobilenet-b2`` runs the real 224²
    program on hardware.
    """
    import jax
    import jax.numpy as jnp

    from repro import deploy
    from repro.core.binlinear import QuantConfig
    from repro.deploy import executor
    from repro.models import cnn

    if mobilenet_kw is _DEFAULT:
        mobilenet_kw = {"width_mult": 0.25, "n_classes": 10,
                        "resolution": 32}
    qc = QuantConfig(mode="binary", M=2, K_iters=2, interpret=True)
    work = []
    key = jax.random.PRNGKey(seed)
    for arch in archs:
        key, k1, k2 = jax.random.split(key, 3)
        if arch == "cnn_a":
            params = cnn.init_cnn_a(k1)
            shape = (batch, 48, 48, 3)
            prog = deploy.compile(cnn.binarize_cnn_a(params, qc), "cnn_a",
                                  qc, shape)
        else:
            res = mobilenet_kw.get("resolution", 32)
            init_kw = {k: v for k, v in mobilenet_kw.items()
                       if k != "resolution"}
            params = cnn.init_mobilenet(k1, **init_kw)
            shape = (batch, res, res, 3)
            prog = deploy.compile(cnn.binarize_mobilenet(params, qc),
                                  "mobilenet", qc, shape)
        x = jax.random.normal(k2, shape, jnp.float32)
        for sched in _rotating_schedules(prog):
            work.append((prog, x, sched))
    calls = [0]

    def step(i: int) -> None:
        prog, x, sched = work[(i - 1) % len(work)]
        jax.block_until_ready(deploy.execute(prog, x, sched))
        calls[0] += 1

    return Scenario(
        name="executor_" + "_".join(archs),
        step=step,
        gauges=dict(executor.cache_gauges()),
        progress=lambda: {"execute_calls": calls[0],
                          **executor.cache_stats()})


# ---------------------------------------------------------------------------
# serve_cnn.CNNService under faulty traffic
# ---------------------------------------------------------------------------

def tiny_cnn_program(*, batch: int = 4, m: int = 2, seed: int = 0):
    """A small custom-topology program for serving tests/soaks: 3x3 SAME
    conv (D=8, AMU pool 2) on 8x8x3 images into a flatten->10 linear head.
    Cheap enough for thousands of interpret-mode calls, deep enough that the
    degradation ladder has distinct front-half/global rungs."""
    import jax

    from repro import deploy
    from repro.core.binlinear import QuantConfig
    from repro.models.cnn import LayerSpec, spec_binarize

    specs = (
        LayerSpec("c0", "conv", kh=3, kw=3, padding="SAME", pool=2),
        LayerSpec("fc", "linear", pre="flatten", relu=False),
    )
    k0, k1 = jax.random.split(jax.random.PRNGKey(seed))
    params = {
        "c0": {"w": jax.random.normal(k0, (3, 3, 3, 8)) / 9.0,
               "b": None},
        "fc": {"w": jax.random.normal(k1, (4 * 4 * 8, 10)) * 0.1,
               "b": None},
    }
    params = {n: {k: v for k, v in p.items() if v is not None}
              for n, p in params.items()}
    qc = QuantConfig(mode="binary", M=m, K_iters=4, interpret=True)
    packed = spec_binarize(specs, params, qc)
    return deploy.compile(packed, specs, qc, (batch, 8, 8, 3))


def cnn_server_scenario(*, seed: int = 0, cycle: int = 54,
                        batch_size: int = 4, verify_every: int = 3,
                        directory: str | None = None,
                        selftest_every: int = 3) -> Scenario:
    """Faulty cyclic traffic against :class:`repro.serve_cnn.CNNService`.

    Each ``cycle`` (default 54 steps — phases long enough that the full
    recover-to-rung-0 walk lands inside the cycle's own clean tail) is
    three equal phases on a shared
    :class:`~repro.testing.faults.ManualClock` (1 ms virtual frame/step):

      1. **clean** — zero fault rates; request latency ~0 vs the 10 ms
         target, so the controller sits (or recovers to) rung 0 (full-M);
      2. **storm** — the injector raises its rates: every call eats a 50 ms
         virtual latency spike, plus seeded executor exceptions and NaN
         outputs.  p99 blows through the target and the controller walks
         down the ladder (the first storm visits every rung, inside the
         soak warmup window, so the compiled-variant gauges are flat after);
      3. **clean again** — pressure clears and the controller climbs back.

    On top of the rate faults, every storm carries one **bit-flip pair**:
    the latest on-disk checkpoint step takes a seeded flip in a packed
    leaf at the storm's first step, and the *live program* takes an
    in-memory packed-buffer flip six steps later.  The service's golden
    watchdog (``selftest_every``) detects the memory flip within its
    budget, quarantines the program, and hot-reloads through
    ``restore_latest_good`` — which hits the disk-flipped step first,
    quarantines it (``ChecksumMismatch``), and falls back to the previous
    good step.  One fresh step is saved at the start of every cycle, so
    the walk always has a fallback; the count of live (non-quarantined)
    step dirs stays bounded by ``keep`` while the quarantine ledger grows
    by exactly one per storm — both reconciled in ``progress()``.

    Traffic: ``batch_size`` requests per step (no backlog growth), plus a
    request with a too-tight virtual deadline every 6th step (shed at
    *dispatch*) and an already-expired one every 13th (shed at *admit*).
    Every ``verify_every``-th step the completed logits are compared
    **bit-exact** against the clean ``deploy.execute`` on the service's own
    padded batch at the served schedule (including right after every
    recovery); ``progress()`` exposes the verified/mismatch counters, the
    service's disposition stats, and the injector ledger so the soak test
    can reconcile injected == observed.
    """
    import tempfile

    from repro import deploy
    from repro.checkpoint.manager import CheckpointManager
    from repro.deploy import executor
    from repro.serve_cnn import CNNService, SLOConfig
    from repro.testing.faults import FaultInjector, FaultPlan, ManualClock

    assert cycle % 3 == 0, cycle
    program = tiny_cnn_program(batch=batch_size, seed=seed)
    clock = ManualClock()
    inj = FaultInjector(FaultPlan(seed=seed), sleep=clock.sleep)
    clean = FaultPlan(seed=seed)
    storm = FaultPlan(latency_rate=0.9, latency_s=0.05, error_rate=0.15,
                      nan_rate=0.10, seed=seed)
    if directory is None:
        directory = tempfile.mkdtemp(prefix="soak_ckpt_")
    mgr = CheckpointManager(directory, keep=4)
    next_step = [0]

    def save_step():
        next_step[0] += 1
        deploy.save_program(mgr, next_step[0], program)

    save_step()
    save_step()     # two good steps before any corruption
    svc = CNNService(
        program,
        slo=SLOConfig(target_ms=10.0, window=16, min_samples=8,
                      recover_at=0.6, recover_after=2),
        batch_size=batch_size, max_queue=4 * batch_size,
        max_retries=4, backoff_s=0.001,
        clock=clock, sleep=clock.sleep,
        execute_fn=inj.wrap_execute(executor.execute),
        selftest_every=selftest_every, checkpoint_manager=mgr,
        restore_like=dataclasses.replace(program, golden=None))
    rng = np.random.default_rng(seed + 1)
    counters = {"verified": 0, "mismatches": 0, "submitted": 0,
                "done": 0, "failed": 0}

    def step(i: int) -> None:
        offset = (i - 1) % cycle
        phase = offset // (cycle // 3)
        inj.plan = storm if phase == 1 else clean
        if offset == 0:
            save_step()                       # fresh fallback every cycle
        if offset == cycle // 3:              # storm opens: rot the newest
            inj.flip_bit_on_disk(mgr._step_dir(mgr.latest_step()))
        if offset == cycle // 3 + 6:          # mid-storm: corrupt the live
            svc.program = inj.flip_bit_in_program(svc.program)
        clock.advance(0.001)
        for _ in range(batch_size):
            img = rng.standard_normal(program.input_shape[1:],
                                      dtype=np.float32)
            svc.submit(img)
            counters["submitted"] += 1
        if i % 6 == 0:      # expires while queued -> shed at dispatch
            svc.submit(np.zeros(program.input_shape[1:], np.float32),
                       deadline_s=clock() + 5e-4)
            counters["submitted"] += 1
        if i % 13 == 0:     # dead on arrival -> shed at admit
            svc.submit(np.zeros(program.input_shape[1:], np.float32),
                       deadline_s=clock() - 1.0)
            counters["submitted"] += 1
        finished = svc.step()
        done = [r for r in finished if r.status == "done"]
        counters["done"] += len(done)
        counters["failed"] += sum(r.status == "failed" for r in finished)
        if done and i % verify_every == 0:
            # clean reference on the exact padded batch + schedule served
            ref = np.asarray(deploy.execute(
                svc.program, svc.last_batch, svc.last_schedule))
            for r in done:
                counters["verified"] += 1
                if not np.array_equal(r.logits, ref[r.batch_index]):
                    counters["mismatches"] += 1

    def progress() -> dict:
        return {**counters, "stats": svc.stats,
                "injected": dict(inj.counts),
                "ckpt_live_steps": len(mgr.all_steps()),
                "ckpt_quarantined": len(mgr.quarantine_dirs())}

    return Scenario(
        name="cnn_server_faulty",
        step=step,
        gauges=svc.cache_gauges(),
        progress=progress)


# ---------------------------------------------------------------------------
# checkpoint save/load cycle
# ---------------------------------------------------------------------------

def checkpoint_scenario(directory: str, *, keep: int = 2,
                        seed: int = 0) -> Scenario:
    """Repeated ``save_program`` -> ``load_program`` -> execute cycles.

    Gauges: live checkpoint step-dirs on disk (the manager's ``keep`` GC
    contract) — plus the driver's heap/RSS sampling catches manifest or
    array-copy leaks in the save path.
    """
    import jax
    import jax.numpy as jnp

    from repro import deploy
    from repro.checkpoint.manager import CheckpointManager
    from repro.core.binlinear import QuantConfig
    from repro.models import cnn

    qc = QuantConfig(mode="binary", M=2, K_iters=2, interpret=True)
    params = cnn.init_cnn_a(jax.random.PRNGKey(seed))
    prog = deploy.compile(cnn.binarize_cnn_a(params, qc), "cnn_a", qc,
                          (1, 48, 48, 3))
    like = deploy.abstract_program("cnn_a", qc, (1, 48, 48, 3))
    mgr = CheckpointManager(directory, keep=keep)
    x = jnp.ones((1, 48, 48, 3), jnp.float32)
    cycles = [0]

    def live_dirs() -> int:
        return sum(1 for d in os.listdir(directory) if d.startswith("step_"))

    def step(i: int) -> None:
        deploy.save_program(mgr, i, prog)
        back = deploy.load_program(mgr, i, like)
        jax.block_until_ready(deploy.execute(back, x))
        cycles[0] += 1

    return Scenario(
        name="checkpoint_cycle",
        step=step,
        gauges={"ckpt_dirs": live_dirs},
        progress=lambda: {"cycles": cycles[0], "ckpt_dirs": live_dirs()})


SCENARIOS = {
    "server": server_scenario,
    "executor": executor_scenario,
    "checkpoint": checkpoint_scenario,
    "cnn_server": cnn_server_scenario,
}
