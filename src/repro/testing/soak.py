"""Soak driver: run a workload for thousands of steps and defend flat trends.

The paper's claim is *sustained* real-time inference (§V reports steady-state
throughput), and the repo's long-lived surfaces — the per-``m_active`` jitted
variant caches in ``launch/serve.py``, the compiled-program executor's
per-schedule cache (``deploy/executor.py``), the bucketed-prefill length
map — are all unbounded-dictionary-shaped: a key-derivation bug turns each
into a compile leak that only shows up under continuous load.  This module
is the harness that makes such bugs fail a test instead of an on-call shift.

``run_soak`` drives a step closure ``steps`` times and samples, every
``sample_every`` steps:

  * **RSS** (``/proc/self/statm``, psutil fallback) — catches native leaks:
    compiled executables, device buffers, XLA autotuning caches;
  * **tracemalloc** current traced bytes — catches Python-level leaks
    (request lists, stats dicts, closure captures);
  * **per-step wall latency** (mean over the sample window) — catches
    steady-state slowdowns (cache-miss churn, growing scans);
  * **gauges** — caller-supplied ``name -> callable`` integer counters
    (cache entry counts, live checkpoint dirs).  These are the sharp end:
    a compile cache that grows by even ONE entry after warmup is a leak
    long before RSS shows it.

Trend semantics (documented contract, see docs/testing.md):

  * the first ``warmup_frac`` of samples is discarded (jit compiles, arena
    growth, tracemalloc ramp all happen there);
  * a least-squares line is fit over the post-warmup samples;
  * the *projected growth over the whole run* (slope x total steps) must
    stay within an absolute byte tolerance for memory series and within a
    fraction of the median for latency;
  * gauges must be exactly flat post-warmup (tolerance 0 by default).

``SoakResult.write_csv`` emits the sample table (one row per sample point)
so the nightly CI job can upload trend artifacts for eyeballing.
"""
from __future__ import annotations

import dataclasses
import os
import time
import tracemalloc
from typing import Callable

import numpy as np

_PAGE = os.sysconf("SC_PAGE_SIZE") if hasattr(os, "sysconf") else 4096


def rss_bytes() -> int:
    """Resident set size of this process, in bytes.

    Reads ``/proc/self/statm`` (second field, pages) on Linux; falls back to
    psutil, then to 0 (trend asserts then only cover tracemalloc/gauges).
    """
    try:
        with open("/proc/self/statm") as f:
            return int(f.read().split()[1]) * _PAGE
    except (OSError, ValueError, IndexError):
        pass
    try:
        import psutil

        return int(psutil.Process().memory_info().rss)
    except Exception:  # noqa: BLE001 — psutil missing or restricted
        return 0


@dataclasses.dataclass(frozen=True)
class TrendFit:
    """Least-squares line over the post-warmup samples of one series."""

    slope_per_step: float   # fitted units per workload step
    intercept: float
    n_samples: int
    span_steps: int         # steps covered by the post-warmup window

    @property
    def projected_growth(self) -> float:
        """Growth the fitted line predicts over the post-warmup window —
        the quantity the tolerances bound (slope alone is scale-free)."""
        return self.slope_per_step * self.span_steps


class TrendViolation(AssertionError):
    """A soak series grew beyond its documented tolerance."""


@dataclasses.dataclass
class SoakResult:
    """Samples + trend fits of one soak run."""

    name: str
    total_steps: int
    steps: np.ndarray                 # [S] sample step indices (1-based)
    rss: np.ndarray                   # [S] bytes
    traced: np.ndarray                # [S] tracemalloc current bytes
    latency: np.ndarray               # [S] mean seconds/step in the window
    gauges: dict[str, np.ndarray]     # name -> [S]
    warmup_frac: float = 0.2

    # ------------------------------------------------------------ trends ---
    def _post_warmup(self) -> slice:
        k = int(len(self.steps) * self.warmup_frac)
        # always leave >= 2 samples so a line is fittable
        return slice(min(k, max(len(self.steps) - 2, 0)), None)

    def fit(self, series: np.ndarray) -> TrendFit:
        sl = self._post_warmup()
        xs = self.steps[sl].astype(np.float64)
        ys = np.asarray(series, np.float64)[sl]
        if len(xs) < 2:
            return TrendFit(0.0, float(ys[-1]) if len(ys) else 0.0,
                            len(xs), 0)
        slope, intercept = np.polyfit(xs, ys, 1)
        return TrendFit(float(slope), float(intercept), len(xs),
                        int(xs[-1] - xs[0]))

    def rss_trend(self) -> TrendFit:
        return self.fit(self.rss)

    def traced_trend(self) -> TrendFit:
        return self.fit(self.traced)

    def latency_trend(self) -> TrendFit:
        return self.fit(self.latency)

    def gauge_growth(self, name: str) -> float:
        """Max - min of a gauge over the post-warmup window (0 == flat)."""
        sl = self._post_warmup()
        ys = self.gauges[name][sl]
        return float(ys.max() - ys.min()) if len(ys) else 0.0

    # ----------------------------------------------------------- asserts ---
    def assert_flat(self, *, rss_tol_bytes: float = 32 * 2**20,
                    traced_tol_bytes: float = 4 * 2**20,
                    latency_tol_frac: float = 0.5,
                    latency_floor_s: float = 1e-3,
                    gauge_tol: float = 0.0) -> None:
        """Raise :class:`TrendViolation` unless every trend is flat.

        Tolerances bound the *projected growth over the post-warmup window*:

          * ``rss_tol_bytes`` (default 32 MiB): RSS under a CPU jax runtime
            is allocator-noisy, so the bound is deliberately coarse — the
            gauges catch cache leaks far earlier;
          * ``traced_tol_bytes`` (default 4 MiB): Python-heap growth;
          * ``latency_tol_frac`` (default 0.5): projected latency growth as
            a fraction of the median post-warmup step latency, with an
            absolute floor of ``latency_floor_s`` (sub-millisecond steps
            are pure scheduler jitter — relative bounds mean nothing there);
          * ``gauge_tol`` (default 0): cache/entry counters must be exactly
            flat after warmup.
        """
        problems: list[str] = []
        r = self.rss_trend()
        if r.projected_growth > rss_tol_bytes:
            problems.append(
                f"rss grows {r.projected_growth / 2**20:.1f} MiB over "
                f"{r.span_steps} steps (tol {rss_tol_bytes / 2**20:.1f} MiB)")
        t = self.traced_trend()
        if t.projected_growth > traced_tol_bytes:
            problems.append(
                f"traced python heap grows {t.projected_growth / 2**20:.2f} "
                f"MiB over {t.span_steps} steps "
                f"(tol {traced_tol_bytes / 2**20:.2f} MiB)")
        lat = self.latency_trend()
        sl = self._post_warmup()
        med = float(np.median(self.latency[sl])) if len(
            self.latency[sl]) else 0.0
        if med > 0 and lat.projected_growth > max(latency_tol_frac * med,
                                                  latency_floor_s):
            problems.append(
                f"step latency grows {lat.projected_growth * 1e3:.2f} ms "
                f"over {lat.span_steps} steps "
                f"(median {med * 1e3:.2f} ms, tol {latency_tol_frac:.0%})")
        for name in self.gauges:
            g = self.gauge_growth(name)
            if g > gauge_tol:
                problems.append(
                    f"gauge {name!r} grew by {g:g} post-warmup "
                    f"(tol {gauge_tol:g}) — cache leak")
        if problems:
            raise TrendViolation(
                f"soak {self.name!r} ({self.total_steps} steps):\n  "
                + "\n  ".join(problems))

    # --------------------------------------------------------------- io ---
    def write_csv(self, path: str) -> None:
        """One row per sample: step, rss, traced, latency, gauges."""
        names = sorted(self.gauges)
        with open(path, "w") as f:
            f.write("step,rss_bytes,traced_bytes,latency_s"
                    + "".join(f",{n}" for n in names) + "\n")
            for i in range(len(self.steps)):
                f.write(f"{int(self.steps[i])},{int(self.rss[i])},"
                        f"{int(self.traced[i])},{self.latency[i]:.6g}")
                for n in names:
                    f.write(f",{self.gauges[n][i]:g}")
                f.write("\n")

    def summary(self) -> str:
        r, t, lat = self.rss_trend(), self.traced_trend(), self.latency_trend()
        g = {n: self.gauge_growth(n) for n in sorted(self.gauges)}
        return (f"{self.name}: {self.total_steps} steps, "
                f"rss {r.projected_growth / 2**20:+.2f} MiB, "
                f"pyheap {t.projected_growth / 2**20:+.3f} MiB, "
                f"latency {lat.projected_growth * 1e3:+.3f} ms, "
                f"gauge growth {g}")


def run_soak(step_fn: Callable[[int], None], *, steps: int, name: str,
             sample_every: int | None = None,
             gauges: dict[str, Callable[[], float]] | None = None,
             warmup_frac: float = 0.2,
             trace_python_heap: bool = True) -> SoakResult:
    """Drive ``step_fn(i)`` for ``steps`` steps, sampling trends.

    ``sample_every`` defaults to ``max(1, steps // 64)`` (about 64 sample
    points regardless of run length).  ``gauges`` are read at every sample
    point; they should be cheap (len() of a dict, a counter read).

    tracemalloc is started/stopped here unless it is already tracing (so a
    caller-level tracemalloc session is left untouched); pass
    ``trace_python_heap=False`` to skip it entirely (it adds per-alloc
    overhead — latency-sensitive hardware runs may want it off).
    """
    if steps < 1:
        raise ValueError(f"steps must be >= 1, got {steps}")
    every = sample_every or max(1, steps // 64)
    gauges = gauges or {}
    own_trace = trace_python_heap and not tracemalloc.is_tracing()
    if own_trace:
        tracemalloc.start()
    xs, rss_s, traced_s, lat_s = [], [], [], []
    gauge_s: dict[str, list[float]] = {n: [] for n in gauges}
    try:
        window_t0 = time.perf_counter()
        window_n = 0
        for i in range(1, steps + 1):
            step_fn(i)
            window_n += 1
            if i % every == 0 or i == steps:
                now = time.perf_counter()
                xs.append(i)
                rss_s.append(rss_bytes())
                traced_s.append(tracemalloc.get_traced_memory()[0]
                                if tracemalloc.is_tracing() else 0)
                lat_s.append((now - window_t0) / max(window_n, 1))
                for n, fn in gauges.items():
                    gauge_s[n].append(float(fn()))
                window_t0 = time.perf_counter()
                window_n = 0
    finally:
        if own_trace:
            tracemalloc.stop()
    return SoakResult(
        name=name, total_steps=steps,
        steps=np.asarray(xs, np.int64),
        rss=np.asarray(rss_s, np.float64),
        traced=np.asarray(traced_s, np.float64),
        latency=np.asarray(lat_s, np.float64),
        gauges={n: np.asarray(v, np.float64) for n, v in gauge_s.items()},
        warmup_frac=warmup_frac)
