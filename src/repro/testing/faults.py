"""Deterministic fault injection for the serving/deployment surfaces.

The robustness contract of the CNN service (``repro.serve_cnn``) is that
every fault is **retried, shed, or degraded — never a silent wrong answer,
never a stuck queue**.  A contract like that is only testable if faults can
be produced on demand, at seeded rates, with exact bookkeeping of what was
injected — which is this module:

  * :class:`FaultPlan` — per-call probabilities for each fault class
    (latency spike, raised exception, NaN/Inf-corrupted outputs) plus the
    checkpoint-read truncation rate, all driven by one seeded
    ``numpy.random.Generator`` so a run replays exactly;
  * :class:`FaultInjector` — wraps callables: ``wrap_execute`` around the
    program executor (``repro.deploy.executor.execute`` or any same-shaped
    function) and ``wrap_restore`` around ``CheckpointManager.restore``.
    Every injected fault is counted in ``counts`` so tests can reconcile
    *injected* against *observed* — a fault the service did not account for
    is a silent swallow and fails the suite;
  * :func:`inject_faults` — context-manager scoping: patches the executor
    and checkpoint surfaces module-wide for the duration of the block and
    restores them on exit (exception-safe), for code paths that cannot take
    an ``execute_fn`` parameter;
  * :class:`ManualClock` — a virtual time source (``clock()``/``advance``/
    ``sleep``) so SLO-controller behavior is testable deterministically:
    the service takes ``clock=``/``sleep=`` injectables and the bench drives
    latency with a cost model instead of wall time.

The injector mutates no numerics silently: NaN/Inf corruption touches the
*returned* array (one poisoned element is enough for ``isfinite`` screens),
never the packed weights, and the truncation fault shears a leading axis off
one restored leaf — exactly the damage a torn checkpoint read produces,
which ``deploy.load_program``'s integrity verification must catch.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time

import numpy as np


class InjectedFault(RuntimeError):
    """A deterministic, injected executor failure (transient by contract:
    the next attempt re-draws, so bounded retry is the correct response)."""


@dataclasses.dataclass
class FaultPlan:
    """Per-call fault probabilities.  All rates are independent draws from
    the injector's seeded stream; a plan with every rate 0 is a no-op wrap
    (useful for phase-switching soaks: swap plans, keep the stream)."""

    latency_rate: float = 0.0   # sleep latency_s before executing
    latency_s: float = 0.02
    error_rate: float = 0.0     # raise InjectedFault instead of executing
    nan_rate: float = 0.0       # poison one output element with NaN
    inf_rate: float = 0.0       # poison one output element with +Inf
    truncate_rate: float = 0.0  # shear a leading axis off one restored leaf
    seed: int = 0


class FaultInjector:
    """Wrap executor/checkpoint callables with seeded fault draws.

    ``counts`` ledger: ``calls``/``restores`` are attempts seen;
    ``latency``/``error``/``nan``/``inf``/``truncate`` are faults actually
    injected.  ``plan`` is read per call, so a soak can switch phases by
    assigning a new :class:`FaultPlan` mid-run — the random stream carries
    across phases, keeping the whole run a function of the initial seed.
    """

    def __init__(self, plan: FaultPlan, *, sleep=time.sleep):
        self.plan = plan
        self.sleep = sleep
        self.rng = np.random.default_rng(plan.seed)
        self.counts = {"calls": 0, "latency": 0, "error": 0, "nan": 0,
                       "inf": 0, "restores": 0, "truncate": 0,
                       "bitflip_disk": 0, "bitflip_mem": 0,
                       "manifest_tamper": 0, "missing_npz": 0}

    # ---------------------------------------------------------- executor ---
    def wrap_execute(self, fn):
        """``fn(program, x, m_active=None, **kw)`` -> same signature, with
        per-call fault draws.  Draw order is fixed (latency, error, nan,
        inf) so counts replay for a given seed regardless of outcomes."""

        def wrapped(program, x, m_active=None, **kw):
            plan = self.plan
            self.counts["calls"] += 1
            u = self.rng.random(4)
            if u[0] < plan.latency_rate:
                self.counts["latency"] += 1
                self.sleep(plan.latency_s)
            if u[1] < plan.error_rate:
                self.counts["error"] += 1
                raise InjectedFault(
                    f"injected executor fault (call {self.counts['calls']})")
            out = fn(program, x, m_active, **kw)
            if u[2] < plan.nan_rate:
                self.counts["nan"] += 1
                out = out.at[(0,) * out.ndim].set(float("nan"))
            elif u[3] < plan.inf_rate:
                self.counts["inf"] += 1
                out = out.at[(0,) * out.ndim].set(float("inf"))
            return out

        # deploy.selftest unwraps this marker so the golden BIST always
        # measures the clean execute path, even when the injector's patch
        # is live module-wide
        wrapped._clean_execute = fn
        return wrapped

    # -------------------------------------------------------- checkpoint ---
    def wrap_restore(self, fn):
        """Wrap ``CheckpointManager.restore`` (bound or unbound): with
        probability ``truncate_rate`` the restored tree comes back with one
        leaf's leading axis sheared off — a torn/truncated read.  The
        manifest ``extra`` passes through untouched."""
        import jax

        def wrapped(*args, **kw):
            self.counts["restores"] += 1
            restored, extra = fn(*args, **kw)
            if self.rng.random() < self.plan.truncate_rate:
                leaves, treedef = jax.tree_util.tree_flatten(restored)
                idx = next((i for i, leaf in enumerate(leaves)
                            if getattr(leaf, "ndim", 0) >= 1
                            and leaf.shape[0] > 1), None)
                if idx is not None:
                    self.counts["truncate"] += 1
                    leaves[idx] = leaves[idx][:-1]
                    restored = jax.tree_util.tree_unflatten(treedef, leaves)
            return restored, extra

        return wrapped

    # ------------------------------------------ one-shot integrity faults ---
    # Deliberate, ledgered damage to checkpoint/program state — the inputs
    # of the integrity subsystem (checkpoint digests, golden self-test,
    # service hot-reload).  Each is a single seeded act, not a rate: tests
    # reconcile recovery counters against these ledger entries exactly.

    def flip_bit_on_disk(self, step_dir: str, *, leaf: str | None = None,
                         prefer: str = "packed") -> str:
        """Flip one seeded bit inside one leaf of a saved ``host_*.npz``.

        ``prefer="packed"`` targets a bit-packed weight leaf
        (``B_tap_packed``/``B_packed``) when one exists — the exact damage
        class the paper's weight memory is exposed to.  Any flipped bit
        changes the leaf's CRC32, so restore must raise
        ``ChecksumMismatch`` naming the leaf.  Returns the npz key flipped.
        """
        import glob
        import os

        path = sorted(glob.glob(os.path.join(step_dir, "host_*.npz")))[0]
        data = dict(np.load(path))
        keys = sorted(data)
        if leaf is None:
            packed = [k for k in keys
                      if "B_tap_packed" in k or "B_packed" in k]
            pool = packed if (prefer == "packed" and packed) else keys
            leaf = pool[int(self.rng.integers(len(pool)))]
        arr = np.ascontiguousarray(data[leaf]).copy()
        flat = arr.view(np.uint8).reshape(-1)
        flat[int(self.rng.integers(flat.size))] ^= np.uint8(
            1 << int(self.rng.integers(8)))
        data[leaf] = arr
        np.savez(path, **data)
        self.counts["bitflip_disk"] += 1
        return leaf

    def flip_bit_in_program(self, program, *, instr: int = 0):
        """Return a copy of ``program`` with one bit flipped in the packed
        weight buffer of instruction ``instr`` — in-memory corruption that
        every static check passes and only the golden self-test catches.

        The flip lands in *level 0* (every §IV-D rung applies level 0, so
        every rung's digest changes) at a byte whose packed-axis index is 0
        with bit 0 set — packing is LSB-first (``core.binarize.pack_bits``),
        so that bit is always a real channel/input, never byte padding.
        """
        import dataclasses as dc

        import jax.numpy as jnp

        ins = program.instrs[instr]
        field = "B_tap_packed" if hasattr(ins, "B_tap_packed") else "B_packed"
        arr = np.asarray(getattr(ins, field)).copy()
        # arr[0] is level 0.  Conv [T, C8, D] / linear [K8, N] carry the
        # packed axis second-to-last — pin it to byte 0, draw the trailing
        # lane; depth-wise [T, C8] packs along the *trailing* axis — pin it
        # to byte 0, draw the tap.  Bit 0 of byte 0 is channel/input 0.
        if getattr(ins, "kind", "") == "dwconv":
            pos = (0, int(self.rng.integers(arr.shape[1])), 0)
        else:
            lane = int(self.rng.integers(arr.shape[-1]))
            pos = (0,) * (arr.ndim - 2) + (0, lane)
        arr[pos] ^= np.uint8(1)
        flipped = dc.replace(ins, **{field: jnp.asarray(arr)})
        instrs = (program.instrs[:instr] + (flipped,)
                  + program.instrs[instr + 1:])
        self.counts["bitflip_mem"] += 1
        return dc.replace(program, instrs=instrs)

    def tamper_manifest(self, step_dir: str, *, key: str = "step") -> None:
        """Rewrite one manifest field without updating the manifest digest —
        the stale/tampered-metadata class ``ManifestMismatch`` must catch."""
        import json
        import os

        path = os.path.join(step_dir, "manifest.json")
        with open(path) as f:
            meta = json.load(f)
        meta[key] = (meta.get(key, 0) + 1 if isinstance(meta.get(key), int)
                     else "tampered")
        with open(path, "w") as f:
            json.dump(meta, f)
        self.counts["manifest_tamper"] += 1

    def remove_npz(self, step_dir: str) -> str:
        """Delete the step's array payload, leaving the manifest — a partial
        directory that restore must reject and the latest-good walk must
        skip.  Returns the removed path."""
        import glob
        import os

        path = sorted(glob.glob(os.path.join(step_dir, "host_*.npz")))[0]
        os.remove(path)
        self.counts["missing_npz"] += 1
        return path


@contextlib.contextmanager
def inject_faults(plan: FaultPlan, *, sleep=time.sleep):
    """Patch the module-level executor + checkpoint surfaces for the scope
    of the block; yields the :class:`FaultInjector` for count reconciliation.

    Patches ``repro.deploy.executor.execute`` (the attribute the CNN
    service's default path resolves at call time) and
    ``CheckpointManager.restore``.  ``repro.deploy.execute`` — the name
    bound at import into the package namespace — intentionally stays the
    *clean* function, so reference outputs for bit-exactness checks remain
    computable inside the block.
    """
    from repro.checkpoint import manager as ckpt_manager
    from repro.deploy import executor

    inj = FaultInjector(plan, sleep=sleep)
    real_execute = executor.execute
    real_restore = ckpt_manager.CheckpointManager.restore
    inj.real_execute = real_execute
    executor.execute = inj.wrap_execute(real_execute)
    ckpt_manager.CheckpointManager.restore = inj.wrap_restore(real_restore)
    try:
        yield inj
    finally:
        executor.execute = real_execute
        ckpt_manager.CheckpointManager.restore = real_restore


class ManualClock:
    """Deterministic time source for SLO tests and the serving bench.

    ``clock()`` semantics of ``time.monotonic`` with explicit advancement;
    ``sleep`` advances instead of blocking, so it doubles as the injector's
    and the service's sleep injectable — latency spikes and retry backoff
    become exact, replayable quantities.
    """

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> None:
        self.now += float(dt)

    def sleep(self, dt: float) -> None:
        self.advance(dt)
