"""Deterministic fault injection for the serving/deployment surfaces.

The robustness contract of the CNN service (``repro.serve_cnn``) is that
every fault is **retried, shed, or degraded — never a silent wrong answer,
never a stuck queue**.  A contract like that is only testable if faults can
be produced on demand, at seeded rates, with exact bookkeeping of what was
injected — which is this module:

  * :class:`FaultPlan` — per-call probabilities for each fault class
    (latency spike, raised exception, NaN/Inf-corrupted outputs) plus the
    checkpoint-read truncation rate, all driven by one seeded
    ``numpy.random.Generator`` so a run replays exactly;
  * :class:`FaultInjector` — wraps callables: ``wrap_execute`` around the
    program executor (``repro.deploy.executor.execute`` or any same-shaped
    function) and ``wrap_restore`` around ``CheckpointManager.restore``.
    Every injected fault is counted in ``counts`` so tests can reconcile
    *injected* against *observed* — a fault the service did not account for
    is a silent swallow and fails the suite;
  * :func:`inject_faults` — context-manager scoping: patches the executor
    and checkpoint surfaces module-wide for the duration of the block and
    restores them on exit (exception-safe), for code paths that cannot take
    an ``execute_fn`` parameter;
  * :class:`ManualClock` — a virtual time source (``clock()``/``advance``/
    ``sleep``) so SLO-controller behavior is testable deterministically:
    the service takes ``clock=``/``sleep=`` injectables and the bench drives
    latency with a cost model instead of wall time.

The injector mutates no numerics silently: NaN/Inf corruption touches the
*returned* array (one poisoned element is enough for ``isfinite`` screens),
never the packed weights, and the truncation fault shears a leading axis off
one restored leaf — exactly the damage a torn checkpoint read produces,
which ``deploy.load_program``'s integrity verification must catch.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time

import numpy as np


class InjectedFault(RuntimeError):
    """A deterministic, injected executor failure (transient by contract:
    the next attempt re-draws, so bounded retry is the correct response)."""


@dataclasses.dataclass
class FaultPlan:
    """Per-call fault probabilities.  All rates are independent draws from
    the injector's seeded stream; a plan with every rate 0 is a no-op wrap
    (useful for phase-switching soaks: swap plans, keep the stream)."""

    latency_rate: float = 0.0   # sleep latency_s before executing
    latency_s: float = 0.02
    error_rate: float = 0.0     # raise InjectedFault instead of executing
    nan_rate: float = 0.0       # poison one output element with NaN
    inf_rate: float = 0.0       # poison one output element with +Inf
    truncate_rate: float = 0.0  # shear a leading axis off one restored leaf
    seed: int = 0


class FaultInjector:
    """Wrap executor/checkpoint callables with seeded fault draws.

    ``counts`` ledger: ``calls``/``restores`` are attempts seen;
    ``latency``/``error``/``nan``/``inf``/``truncate`` are faults actually
    injected.  ``plan`` is read per call, so a soak can switch phases by
    assigning a new :class:`FaultPlan` mid-run — the random stream carries
    across phases, keeping the whole run a function of the initial seed.
    """

    def __init__(self, plan: FaultPlan, *, sleep=time.sleep):
        self.plan = plan
        self.sleep = sleep
        self.rng = np.random.default_rng(plan.seed)
        self.counts = {"calls": 0, "latency": 0, "error": 0, "nan": 0,
                       "inf": 0, "restores": 0, "truncate": 0}

    # ---------------------------------------------------------- executor ---
    def wrap_execute(self, fn):
        """``fn(program, x, m_active=None, **kw)`` -> same signature, with
        per-call fault draws.  Draw order is fixed (latency, error, nan,
        inf) so counts replay for a given seed regardless of outcomes."""

        def wrapped(program, x, m_active=None, **kw):
            plan = self.plan
            self.counts["calls"] += 1
            u = self.rng.random(4)
            if u[0] < plan.latency_rate:
                self.counts["latency"] += 1
                self.sleep(plan.latency_s)
            if u[1] < plan.error_rate:
                self.counts["error"] += 1
                raise InjectedFault(
                    f"injected executor fault (call {self.counts['calls']})")
            out = fn(program, x, m_active, **kw)
            if u[2] < plan.nan_rate:
                self.counts["nan"] += 1
                out = out.at[(0,) * out.ndim].set(float("nan"))
            elif u[3] < plan.inf_rate:
                self.counts["inf"] += 1
                out = out.at[(0,) * out.ndim].set(float("inf"))
            return out

        return wrapped

    # -------------------------------------------------------- checkpoint ---
    def wrap_restore(self, fn):
        """Wrap ``CheckpointManager.restore`` (bound or unbound): with
        probability ``truncate_rate`` the restored tree comes back with one
        leaf's leading axis sheared off — a torn/truncated read.  The
        manifest ``extra`` passes through untouched."""
        import jax

        def wrapped(*args, **kw):
            self.counts["restores"] += 1
            restored, extra = fn(*args, **kw)
            if self.rng.random() < self.plan.truncate_rate:
                leaves, treedef = jax.tree_util.tree_flatten(restored)
                idx = next((i for i, leaf in enumerate(leaves)
                            if getattr(leaf, "ndim", 0) >= 1
                            and leaf.shape[0] > 1), None)
                if idx is not None:
                    self.counts["truncate"] += 1
                    leaves[idx] = leaves[idx][:-1]
                    restored = jax.tree_util.tree_unflatten(treedef, leaves)
            return restored, extra

        return wrapped


@contextlib.contextmanager
def inject_faults(plan: FaultPlan, *, sleep=time.sleep):
    """Patch the module-level executor + checkpoint surfaces for the scope
    of the block; yields the :class:`FaultInjector` for count reconciliation.

    Patches ``repro.deploy.executor.execute`` (the attribute the CNN
    service's default path resolves at call time) and
    ``CheckpointManager.restore``.  ``repro.deploy.execute`` — the name
    bound at import into the package namespace — intentionally stays the
    *clean* function, so reference outputs for bit-exactness checks remain
    computable inside the block.
    """
    from repro.checkpoint import manager as ckpt_manager
    from repro.deploy import executor

    inj = FaultInjector(plan, sleep=sleep)
    real_execute = executor.execute
    real_restore = ckpt_manager.CheckpointManager.restore
    inj.real_execute = real_execute
    executor.execute = inj.wrap_execute(real_execute)
    ckpt_manager.CheckpointManager.restore = inj.wrap_restore(real_restore)
    try:
        yield inj
    finally:
        executor.execute = real_execute
        ckpt_manager.CheckpointManager.restore = real_restore


class ManualClock:
    """Deterministic time source for SLO tests and the serving bench.

    ``clock()`` semantics of ``time.monotonic`` with explicit advancement;
    ``sleep`` advances instead of blocking, so it doubles as the injector's
    and the service's sleep injectable — latency spikes and retry backoff
    become exact, replayable quantities.
    """

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> None:
        self.now += float(dt)

    def sleep(self, dt: float) -> None:
        self.advance(dt)
