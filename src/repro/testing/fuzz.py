"""Differential-fuzz network generator: random LayerSpec lists + params.

The deploy compiler (``repro.deploy.compile``) accepts any LayerSpec
sequence, and the executor's contract is bit-exactness against the per-call
spec forward (``models.cnn.spec_forward``) for every legal topology — not
just the two hand-built ones.  This module generates *legal-by-construction*
random networks so the fuzz tier (tests/test_fuzz_programs.py) can drive

    random specs -> compile -> verify_program (zero ERRORs) -> execute
                 -> bit-exact vs the per-call fused forward
                 -> allclose vs the unfused fake-quant reconstruction

over shapes/strides/pooling/M-levels/ragged batches the unit tests never
hand-picked.  Everything is derived from one integer seed
(``random.Random(seed)``), so failures replay exactly.

Legality constraints encoded here (mirrors the compiler's own checks):
  * conv kernels fit the current map (kh <= Hp, kw <= Wp for VALID);
  * the AMU pool window divides the conv output (paper §III-B:
    downsampling only — the compiler raises otherwise);
  * depth-wise layers are 3x3 SAME (MobileNet's only variant);
  * a ``flatten``/``gap`` pre-op transitions to the linear tail, and the
    last layer drops ReLU (logits).
"""
from __future__ import annotations

import dataclasses
import random

import jax
import jax.numpy as jnp

from repro.models.cnn import LayerSpec


@dataclasses.dataclass(frozen=True)
class FuzzNet:
    """One generated network: topology + matching fp params + geometry."""

    specs: tuple[LayerSpec, ...]
    input_shape: tuple[int, int, int, int]   # (B, H, W, C) compile target
    exec_batch: int                          # ragged-batch execute size
    M: int                                   # packed level count

    def init_params(self, key) -> dict:
        """fp parameter tree matching ``specs`` (shapes re-derived by the
        same walk the generator ran)."""
        params = {}
        _, H, W, C = self.input_shape
        shapes = _shape_walk(self.specs, (H, W, C))
        ks = jax.random.split(key, len(self.specs))
        for (spec, (shp_in, shp_out)), k in zip(shapes, ks):
            if spec.kind == "conv":
                cin, cout = shp_in[2], shp_out[2]
                w = jax.random.normal(
                    k, (spec.kh, spec.kw, cin, cout)) / (spec.kh * spec.kw)
                params[spec.name] = {"w": w.astype(jnp.float32),
                                     "b": jnp.zeros((cout,), jnp.float32)}
            elif spec.kind == "dwconv":
                cin = shp_in[2]
                w = jax.random.normal(k, (spec.kh, spec.kw, 1, cin)) * 0.3
                params[spec.name] = {"w": w.astype(jnp.float32),
                                     "b": jnp.zeros((cin,), jnp.float32)}
            else:
                kin, nout = shp_in
                w = jax.random.normal(k, (kin, nout)) / jnp.sqrt(kin)
                params[spec.name] = {"w": w.astype(jnp.float32),
                                     "b": jnp.zeros((nout,), jnp.float32)}
        return params


def _shape_walk(specs, hwc):
    """[(spec, ((in-geom), (out-geom)))] — conv/dw geoms are (H, W, C),
    linear geoms are (K, N), re-derived from the names' embedded dims."""
    out = []
    cur = hwc
    for spec in specs:
        dims = [int(d) for d in spec.name.split("_")[-1].split("x")]
        if spec.kind == "conv":
            D = dims[0]
            H, W, C = cur
            Hp, Wp = _padded(H, W, spec)
            U = (Hp - spec.kh) // spec.stride + 1
            V = (Wp - spec.kw) // spec.stride + 1
            nxt = (U // spec.pool, V // spec.pool, D)
            out.append((spec, ((H, W, C), nxt)))
            cur = nxt
        elif spec.kind == "dwconv":
            H, W, C = cur
            # dw layers are ALWAYS SAME (the compiler ignores spec.padding)
            Hp, Wp = _padded(H, W, dataclasses.replace(spec, padding="SAME"))
            U = (Hp - spec.kh) // spec.stride + 1
            V = (Wp - spec.kw) // spec.stride + 1
            nxt = (U, V, C)
            out.append((spec, ((H, W, C), nxt)))
            cur = nxt
        else:
            N = dims[0]
            if spec.pre == "flatten":
                K = cur[0] * cur[1] * cur[2] if len(cur) == 3 else cur[0]
            elif spec.pre == "gap":
                K = cur[2]
            else:
                K = cur[0]
            out.append((spec, ((K, N), (N,))))
            cur = (N,)
    return out


def _padded(H, W, spec):
    if spec.padding != "SAME":
        return H, W
    from repro.core.binconv import same_pads

    (pt, pb) = same_pads(H, spec.kh, spec.stride)
    (pl, pr) = same_pads(W, spec.kw, spec.stride)
    return H + pt + pb, W + pl + pr


def random_network(seed: int, *, max_layers: int = 5) -> FuzzNet:
    """Generate one legal network from ``seed``.

    Spatial section: 1-3 conv/dwconv layers over small maps (H, W in
    [8, 20], C in {3, 4, 8}, D in {8, 16, 32}, strides {1, 2}, pools
    {1, 2, 3} restricted to divisors of the conv output).  Tail: a
    flatten/gap transition linear plus 0-2 more, last one without ReLU.
    """
    rng = random.Random(seed)
    H = rng.randint(8, 20)
    W = rng.randint(8, 20)
    C = rng.choice((3, 4, 8))
    M = rng.choice((1, 2, 2))            # bias toward the paper's M=2
    specs: list[LayerSpec] = []
    cur = (H, W, C)
    n_spatial = rng.randint(1, max(1, max_layers - 2))
    for li in range(n_spatial):
        h, w, c = cur
        use_dw = c % 8 == 0 and min(h, w) >= 3 and rng.random() < 0.4
        if use_dw:
            stride = rng.choice((1, 2)) if min(h, w) >= 6 else 1
            spec = LayerSpec(f"dw{li}_{c}x{c}", "dwconv", kh=3, kw=3,
                             stride=stride)
            specs.append(spec)
            cur = _shape_walk((spec,), cur)[0][1][1]
            continue
        padding = rng.choice(("VALID", "SAME"))
        kmax = min(5, h, w)
        kh = rng.randint(1, kmax)
        kw = rng.randint(1, kmax)
        stride = rng.choice((1, 2)) if min(h, w) > 6 else 1
        # lane-legal output-channel counts only: the conv bd pick snaps to
        # a divisor of 128, so D must pad to a legal block (the verifier
        # ERRORs on e.g. D=24 -> bd 16 over padded 32 — by design)
        D = rng.choice((8, 16, 32))
        hp, wp = (h, w) if padding == "VALID" else _padded(
            h, w, LayerSpec("t", "conv", kh=kh, kw=kw, stride=stride,
                            padding="SAME"))
        U = (hp - kh) // stride + 1
        V = (wp - kw) // stride + 1
        if U < 1 or V < 1:
            continue
        pools = [p for p in (1, 2, 3) if U % p == 0 and V % p == 0]
        pool = rng.choice(pools)
        spec = LayerSpec(f"conv{li}_{D}", "conv", kh=kh, kw=kw,
                         stride=stride, padding=padding, pool=pool)
        specs.append(spec)
        cur = _shape_walk((spec,), cur)[0][1][1]
        if min(cur[0], cur[1]) < 2:
            break
    # linear tail: flatten or gap transition, then 0-2 plain linears
    pre = rng.choice(("flatten", "flatten", "gap")) if specs else "flatten"
    if not specs:  # degenerate: all-spatial generation failed -> pure MLP
        cur = (H, W, C)
    n_tail = rng.randint(1, 3)
    for ti in range(n_tail):
        N = rng.choice((8, 16, 32))
        last = ti == n_tail - 1
        specs.append(LayerSpec(f"fc{ti}_{N}", "linear",
                               pre=pre if ti == 0 else "none",
                               relu=not last))
    B = rng.randint(1, 3)
    exec_b = rng.randint(1, 5)
    return FuzzNet(specs=tuple(specs), input_shape=(B, H, W, C),
                   exec_batch=exec_b, M=M)
