"""Reusable test/soak infrastructure (importable, not test-collected).

  * :mod:`repro.testing.soak` — the soak driver: run a workload closure for
    thousands of steps, sample RSS/tracemalloc/latency/cache gauges, fit
    linear trends, assert them flat (``run_soak`` / ``SoakResult``).
  * :mod:`repro.testing.scenarios` — the three long-lived-surface soak
    scenarios (server traffic, executor schedule rotation, checkpoint
    cycle) shared by the ``soak`` pytest tier and ``tools/soak.py``.
  * :mod:`repro.testing.fuzz` — legal-by-construction random network
    generator for the differential fuzz tier.

Lives under ``src/repro`` (not ``tests/``) because tools/ and CI consume
it too; heavyweight imports (jax, server, compiler) stay inside the
scenario builders so ``import repro.testing.soak`` is cheap.
"""
