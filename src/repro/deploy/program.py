"""BinArrayProgram: the compiled deployment form of a binary-approximated CNN.

The paper's BinArray is an *instruction-set processor* (§IV): an offline
compiler turns each network layer into one macro-instruction — weights,
addresses, and the whole schedule decided ahead of time — and the accelerator
merely executes the stream.  This module is that instruction set for the
Pallas port:

    ============  ====================================  =====================
    instruction   paper §IV macro-instruction           kernel it drives
    ============  ====================================  =====================
    ConvInstr     CONV (AGU patch walk + PA levels +    kernels/binary_conv
                  AMU bias/pool/ReLU)
    DWConvInstr   CONV, channel-wise D_arch=1 (§V-A3)   kernels/binary_dwconv
    LinearInstr   FC (PE accumulate over N_in)          kernels/binary_matmul
    ============  ====================================  =====================

Each instruction carries its packed weights (array leaves), the *frozen* tile
plan the compiler picked — ``(NB, BU, bd)`` for convs, ``(bt, bn, bk)`` for
matmuls — and the static per-layer facts (VMEM/HBM byte estimates, MAC
counts, MXU row occupancy) as :class:`LayerStats`.  Pre-layer epilogue fields
(``pre``: flatten / global-average-pool) and post-layer AMU fields (``pool``,
``relu``) make the instruction list a complete forward description: the
executor (deploy/executor.py) is a dumb loop.

Instructions are registered as JAX pytrees with the static fields as aux
data, so a whole :class:`BinArrayProgram` can be passed straight through
``jax.jit`` (plans ride in the treedef, weights are leaves), through
``jax.eval_shape`` (abstract programs: real plans + stats, ShapeDtypeStruct
weights — what the benchmarks introspect), and through
``checkpoint/manager.py`` (serialization round-trip).
"""
from __future__ import annotations

import dataclasses

import jax


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """A frozen kernel schedule.  Convs use (nb, bu, bd); matmuls use
    (bt, bn, bk); the depth-wise kernel uses (nb, bu).  Unused fields stay
    None.  Every field is static — the plan lives in the pytree aux data, so
    two programs with different plans compile to different executables."""

    nb: int | None = None   # conv/dw: images folded per program
    bu: int | None = None   # conv/dw: pooled output rows per program
    bd: int | None = None   # conv: output-channel (MXU lane) tile
    bt: int | None = None   # matmul: row block
    bn: int | None = None   # matmul: output-column block
    bk: int | None = None   # matmul: reduction block


@dataclasses.dataclass(frozen=True)
class LayerStats:
    """Static per-layer facts the compiler derives once (paper §IV-E inputs).

    All plain ints/floats/tuples — hashable (pytree aux data) and trivially
    JSON-able (``BinArrayProgram.layer_stats``)."""

    in_shape: tuple[int, ...]       # activation entering the layer (post-pre)
    out_shape: tuple[int, ...]      # activation leaving it (post-pool/relu)
    padded_in: tuple[int, ...] = () # (Hp, Wp) after SAME resolution, convs
    macs: int = 0                   # fp-equivalent multiply-accumulates
    weight_bytes: int = 0           # packed deployment weight stream (HBM)
    vmem_bytes: int = 0             # per-program working set under the plan
    hbm_fused_bytes: int = 0        # per-program HBM traffic, fused kernel
    hbm_im2col_bytes: int = 0       # same tile via the explicit-im2col path
    mxu_row_occupancy: float = 1.0  # GEMM rows / padded MXU rows (convs)
    batch_row_utilization: float = 1.0  # whole-batch row utilization

    def device_view(self, *, n_model: int = 1, sharded: bool = False) -> dict:
        """Per-device byte split of this layer under a mesh: an output-
        channel (bd) shard divides the packed weight stream and its HBM
        traffic evenly over the ``n_model`` axis (channel slices are
        independent); a replicated layer carries the full copy on every
        device.  VMEM for sharded layers depends on the device-local tile
        plan, so ``repro.distributed.stats`` recomputes it from the kernel
        formula instead of splitting this estimate."""
        share = n_model if sharded else 1
        return {
            "per_device_weight_bytes": self.weight_bytes // share,
            "per_device_hbm_fused_bytes": self.hbm_fused_bytes // share,
        }


def _register(cls, array_fields: tuple[str, ...]) -> None:
    """Register a dataclass as a pytree: ``array_fields`` are children, every
    other field is aux data (static, hashable)."""
    static_fields = tuple(f.name for f in dataclasses.fields(cls)
                          if f.name not in array_fields)

    def flatten_with_keys(obj):
        children = [(jax.tree_util.GetAttrKey(f), getattr(obj, f))
                    for f in array_fields]
        aux = tuple(getattr(obj, f) for f in static_fields)
        return children, aux

    def flatten(obj):
        return tuple(getattr(obj, f) for f in array_fields), tuple(
            getattr(obj, f) for f in static_fields)

    def unflatten(aux, children):
        kw = dict(zip(array_fields, children))
        kw.update(zip(static_fields, aux))
        return cls(**kw)

    jax.tree_util.register_pytree_with_keys(
        cls, flatten_with_keys, unflatten, flatten_func=flatten)


@dataclasses.dataclass(frozen=True)
class ConvInstr:
    """Fused conv + bias + max-pool + ReLU (PE→PA→AMU, paper Eq. 8 + 13)."""

    # array leaves
    B_tap_packed: jax.Array   # [M, kh*kw, ceil(C/8), D] uint8 (pack_taps)
    alpha: jax.Array          # [M, G, D]
    bias: jax.Array           # [D] (zeros when the layer has none)
    # static
    name: str = ""
    kh: int = 1
    kw: int = 1
    stride: int = 1
    padding: str = "VALID"
    pool: int = 1
    relu: bool = True
    pre: str = "none"
    M: int = 1
    group_size: int = 1
    plan: TilePlan = TilePlan()
    stats: LayerStats = LayerStats((), ())

    kind = "conv"


@dataclasses.dataclass(frozen=True)
class DWConvInstr:
    """Fused channel-wise depth-wise conv + bias + ReLU (paper §V-A3)."""

    B_tap_packed: jax.Array   # [M, kh*kw, ceil(C/8)] uint8 (pack_dw_taps)
    alpha: jax.Array          # [M, C]
    bias: jax.Array           # [C]
    name: str = ""
    kh: int = 3
    kw: int = 3
    stride: int = 1
    relu: bool = True
    pre: str = "none"
    M: int = 1
    plan: TilePlan = TilePlan()
    stats: LayerStats = LayerStats((), ())

    kind = "dwconv"


@dataclasses.dataclass(frozen=True)
class LinearInstr:
    """Binary matmul + bias (+ ReLU) — the paper's FC macro-instruction."""

    B_packed: jax.Array       # [M, ceil(K/8), N] uint8 (flat packing)
    alpha: jax.Array          # [M, G, N]
    bias: jax.Array           # [N]
    name: str = ""
    K: int = 1                # logical reduction dim (pre-padding)
    relu: bool = False
    pre: str = "none"
    M: int = 1
    group_size: int = 1
    plan: TilePlan = TilePlan()
    stats: LayerStats = LayerStats((), ())

    kind = "linear"


Instr = ConvInstr | DWConvInstr | LinearInstr

_register(ConvInstr, ("B_tap_packed", "alpha", "bias"))
_register(DWConvInstr, ("B_tap_packed", "alpha", "bias"))
_register(LinearInstr, ("B_packed", "alpha", "bias"))


@dataclasses.dataclass(frozen=True)
class GoldenRecord:
    """Compile-time BIST reference: seeded input spec + output digests.

    ``deploy.compile`` runs a canonical probe input (batch 1, drawn from
    ``jax.random.normal(PRNGKey(seed), input_shape)``) through every §IV-D
    rung once and records the CRC32 of each output — the expected answers a
    deployed program must still produce.  ``deploy.self_test`` replays the
    probe and compares digests: the dynamic check that catches in-memory /
    packed-buffer corruption static verification cannot.

    Frozen + all-tuple, so it is hashable and rides in the pytree aux data
    (a golden change is a retrace, like any other static field), and
    trivially JSON-able for the checkpoint manifest.
    """

    seed: int
    input_shape: tuple[int, ...]                       # probe shape, batch 1
    digests: tuple[tuple[tuple[int, ...], str], ...]   # (schedule, crc32 hex)

    def schedules(self) -> tuple[tuple[int, ...], ...]:
        return tuple(s for s, _ in self.digests)

    def digest_for(self, schedule: tuple[int, ...]) -> str | None:
        for s, d in self.digests:
            if s == tuple(schedule):
                return d
        return None

    def to_json(self) -> dict:
        return {"seed": self.seed, "input_shape": list(self.input_shape),
                "digests": [[list(s), d] for s, d in self.digests]}

    @classmethod
    def from_json(cls, doc: dict) -> "GoldenRecord":
        return cls(seed=int(doc["seed"]),
                   input_shape=tuple(int(v) for v in doc["input_shape"]),
                   digests=tuple((tuple(int(m) for m in s), str(d))
                                 for s, d in doc["digests"]))


@dataclasses.dataclass(frozen=True)
class BinArrayProgram:
    """A compiled network: a macro-instruction stream plus program facts.

    ``input_shape`` is the (B, H, W, C) the tile plans were optimized for —
    executing other batch sizes stays *correct* (the kernels clamp and
    remain bit-exact across tilings), just not necessarily optimal.
    ``interpret`` records the compile-time default for the Pallas interpret
    flag (CPU validation); ``execute`` can override it.  ``golden`` is the
    compile-time :class:`GoldenRecord` (None for abstract programs and
    ``compile(..., golden=False)``).
    """

    instrs: tuple[Instr, ...]
    arch: str = ""
    input_shape: tuple[int, ...] = ()
    interpret: bool = False
    golden: GoldenRecord | None = None

    def __len__(self) -> int:
        return len(self.instrs)

    @property
    def m_max(self) -> int:
        return max(i.M for i in self.instrs)

    def resolve_schedule(self, m_active) -> tuple[int, ...]:
        """Normalize ``m_active`` into one static level count per
        instruction: None -> all packed levels; an int -> global, clamped to
        each instruction's M (§IV-D); a sequence -> per-layer schedule
        (length must match), each entry clamped to [1, M_layer]."""
        if m_active is None:
            return tuple(i.M for i in self.instrs)
        if isinstance(m_active, int):
            if m_active < 1:
                raise ValueError(f"m_active must be >= 1, got {m_active}")
            return tuple(min(m_active, i.M) for i in self.instrs)
        sched = tuple(int(m) for m in m_active)
        if len(sched) != len(self.instrs):
            raise ValueError(
                f"m_active schedule has {len(sched)} entries for "
                f"{len(self.instrs)} instructions "
                f"({[i.name for i in self.instrs]})")
        if any(m < 1 for m in sched):
            raise ValueError(f"schedule entries must be >= 1: {sched}")
        return tuple(min(m, i.M) for m, i in zip(sched, self.instrs))

    def layer_stats(self) -> list[dict]:
        """One JSON-able dict per instruction: geometry, frozen tile plan,
        VMEM/HBM byte estimates, MAC counts — the single source the
        benchmarks (kernel_bench, table3, run.py --json) read instead of
        hand-maintained layer lists."""
        out = []
        for idx, i in enumerate(self.instrs):
            d = {
                "index": idx, "name": i.name, "kind": i.kind,
                "pre": i.pre, "relu": bool(i.relu), "M": int(i.M),
                "in_shape": list(i.stats.in_shape),
                "out_shape": list(i.stats.out_shape),
                "macs": int(i.stats.macs),
                "weight_bytes": int(i.stats.weight_bytes),
                "vmem_bytes": int(i.stats.vmem_bytes),
                "plan": {k: v for k, v in dataclasses.asdict(i.plan).items()
                         if v is not None},
            }
            if i.kind in ("conv", "dwconv"):
                d.update(kh=i.kh, kw=i.kw, stride=i.stride,
                         padded_in=list(i.stats.padded_in))
            if i.kind == "conv":
                d.update(
                    padding=i.padding, pool=i.pool,
                    group_size=int(i.group_size),
                    hbm_fused_bytes=int(i.stats.hbm_fused_bytes),
                    hbm_im2col_bytes=int(i.stats.hbm_im2col_bytes),
                    mxu_row_occupancy=float(i.stats.mxu_row_occupancy),
                    batch_row_utilization=float(
                        i.stats.batch_row_utilization))
            if i.kind == "linear":
                d.update(K=int(i.K), group_size=int(i.group_size))
            out.append(d)
        return out

    def totals(self) -> dict:
        """Whole-program roll-up of the per-layer stats."""
        return {
            "arch": self.arch,
            "input_shape": list(self.input_shape),
            "n_instructions": len(self.instrs),
            "macs": int(sum(i.stats.macs for i in self.instrs)),
            "weight_bytes": int(sum(i.stats.weight_bytes
                                    for i in self.instrs)),
            "max_vmem_bytes": int(max(i.stats.vmem_bytes
                                      for i in self.instrs)),
        }


jax.tree_util.register_pytree_with_keys(
    BinArrayProgram,
    lambda p: ([(jax.tree_util.GetAttrKey("instrs"), p.instrs)],
               (p.arch, p.input_shape, p.interpret, p.golden)),
    lambda aux, children: BinArrayProgram(
        instrs=tuple(children[0]), arch=aux[0], input_shape=aux[1],
        interpret=aux[2], golden=aux[3]),
    flatten_func=lambda p: ((p.instrs,),
                            (p.arch, p.input_shape, p.interpret, p.golden)),
)
