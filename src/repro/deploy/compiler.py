"""compile(): layer params + arch spec -> BinArrayProgram (paper §IV).

The compiler does everything that is static, ONCE, ahead of deployment:

  1. **Pack** — fp trees are binarized (Algorithm 2) into the kernels'
     packed layouts; already-packed trees are reused as-is, and legacy
     trees that predate the fused conv kernel are upgraded through
     ``binconv.ensure_tap_packed`` so every emitted ``ConvInstr`` carries
     ``B_tap_packed`` (the per-call ``repack_taps`` path is retired).
  2. **Plan** — the exact tile auto-picks the per-call paths run on every
     trace (``pick_tile`` / ``pick_tile_dw`` / ``pick_matmul_plan``) run
     here instead, against the compile-time ``input_shape``, and freeze
     into each instruction's :class:`~repro.deploy.program.TilePlan`.
     Using the same pick functions is what makes ``execute`` bit-exact
     against the legacy ``QuantConfig.fuse_conv`` forwards.
  3. **Account** — per-layer VMEM working sets, fused-vs-im2col HBM bytes,
     MAC counts, and MXU row occupancy land in :class:`LayerStats`, so the
     benchmarks read ``program.layer_stats()`` instead of hand-maintained
     layer lists.

``compile`` is pure JAX: run it under ``jax.eval_shape`` (see
:func:`abstract_program`) and you get the full program — real plans, real
stats — with ShapeDtypeStruct weights, for free.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import binconv
from repro.core import binlinear as bl
from repro.core.binlinear import QuantConfig
from repro.deploy.program import (BinArrayProgram, ConvInstr, DWConvInstr,
                                  GoldenRecord, LayerStats, LinearInstr,
                                  TilePlan)
from repro.kernels import binary_conv as bck
from repro.kernels import binary_dwconv as bdw
from repro.kernels import binary_matmul as bmk
from repro.kernels import ops as kops
from repro.models import cnn

ARCHS = ("cnn_a", "mobilenet")


def _specs(arch):
    if isinstance(arch, (tuple, list)):   # explicit LayerSpec list (fuzz /
        return tuple(arch)                # custom topologies)
    if arch == "cnn_a":
        return cnn.cnn_a_specs()
    if arch == "mobilenet":
        return cnn.mobilenet_specs()
    raise ValueError(f"unknown arch {arch!r}; expected one of {ARCHS} "
                     "or an explicit LayerSpec sequence")


def _bias(p: dict, n: int) -> jax.Array:
    b = p.get("b")
    if b is None:
        return jnp.zeros((n,), jnp.float32)
    return b.astype(jnp.float32)


def _compile_conv(spec, p, shape, quant):
    """One conv spec -> (ConvInstr, out_shape)."""
    if "B_packed" not in p and "B_tap_packed" not in p:
        p = binconv.binarize_conv_params(p, quant)
    B, H, W, C = shape
    p = binconv.ensure_tap_packed(p, C)      # legacy flat-only trees upgrade
    tap = p["B_tap_packed"]
    M, T, C8, D = tap.shape
    kh, kw = spec.kh, spec.kw
    assert T == kh * kw, (spec.name, T, kh, kw)
    if spec.padding == "SAME":
        (pt, pb) = binconv.same_pads(H, kh, spec.stride)
        (pl, pr) = binconv.same_pads(W, kw, spec.stride)
        Hp, Wp = H + pt + pb, W + pl + pr
    else:
        Hp, Wp = H, W
    U = (Hp - kh) // spec.stride + 1
    V = (Wp - kw) // spec.stride + 1
    if U % spec.pool or V % spec.pool:
        raise ValueError(
            f"{spec.name}: conv output {U}x{V} not divisible by AMU pool "
            f"{spec.pool} (paper §III-B: downsampling only)")
    G = p["alpha"].shape[1]
    group_size = kh * kw * C // G
    m_plan = min(quant.m_active or M, M)
    budget = quant.conv_vmem_budget or bck.DEFAULT_VMEM_BUDGET

    bd = kops._pick_block(D, 128)
    if quant.conv_batch_tile is not None:
        nb = max(1, min(quant.conv_batch_tile, B))
        bu = bck.pick_bu(Hp, Wp, C, kh, kw, bd, spec.pool, budget,
                         stride=spec.stride, m=m_plan, nb=nb)
    else:
        nb, bu = bck.pick_tile(B, Hp, Wp, C, kh, kw, bd, spec.pool, budget,
                               stride=spec.stride, m=m_plan)

    uo = U // spec.pool
    fused, im2col = bck.tile_hbm_bytes(
        Wp, C, kh, kw, min(bd, D), bu=bu, pool=spec.pool, stride=spec.stride,
        m=M, nb=nb, H=Hp)
    rows_img = bck.gemm_rows(1, bu, V, pool=spec.pool)
    stats = LayerStats(
        in_shape=(B, H, W, C),
        out_shape=(B, uo, V // spec.pool, D),
        padded_in=(Hp, Wp),
        macs=U * V * D * kh * kw * C,
        weight_bytes=int(tap.size) + int(p["alpha"].size) * 4,
        vmem_bytes=bck.tile_vmem_bytes(
            Wp, C, kh, kw, bd, bu=bu, pool=spec.pool, stride=spec.stride,
            m=m_plan, nb=nb),
        hbm_fused_bytes=fused, hbm_im2col_bytes=im2col,
        mxu_row_occupancy=bck.mxu_row_occupancy(
            bck.gemm_rows(nb, bu, V, pool=spec.pool)),
        batch_row_utilization=(bck.batch_row_utilization(B, nb, rows_img)
                               if bu == uo else bck.mxu_row_occupancy(
                                   bck.gemm_rows(nb, bu, V, pool=spec.pool))),
    )
    instr = ConvInstr(
        B_tap_packed=tap, alpha=p["alpha"], bias=_bias(p, D),
        name=spec.name, kh=kh, kw=kw, stride=spec.stride,
        padding=spec.padding, pool=spec.pool, relu=spec.relu, pre=spec.pre,
        M=M, group_size=group_size,
        plan=TilePlan(nb=nb, bu=bu, bd=bd), stats=stats)
    return instr, stats.out_shape


def _compile_dwconv(spec, p, shape, quant):
    """One depth-wise spec -> (DWConvInstr, out_shape).  Always SAME."""
    if "B_tap_packed" not in p:
        p = binconv.binarize_dwconv_params(p, quant)
    B, H, W, C = shape
    tap = p["B_tap_packed"]
    M, T, c8 = tap.shape
    kh, kw = spec.kh, spec.kw
    assert T == kh * kw and c8 * 8 >= C, (spec.name, tap.shape, C)
    (pt, pb) = binconv.same_pads(H, kh, spec.stride)
    (pl, pr) = binconv.same_pads(W, kw, spec.stride)
    Hp, Wp = H + pt + pb, W + pl + pr
    U = (Hp - kh) // spec.stride + 1
    V = (Wp - kw) // spec.stride + 1
    m_plan = min(quant.m_active or M, M)
    budget = quant.conv_vmem_budget or bck.DEFAULT_VMEM_BUDGET
    if quant.conv_batch_tile is not None:
        nb = max(1, min(quant.conv_batch_tile, B))
        bu = bdw.pick_bu_dw(Hp, Wp, C, kh, kw, budget, stride=spec.stride,
                            m=m_plan, nb=nb)
    else:
        nb, bu = bdw.pick_tile_dw(B, Hp, Wp, C, kh, kw, budget,
                                  stride=spec.stride, m=m_plan)
    stats = LayerStats(
        in_shape=(B, H, W, C), out_shape=(B, U, V, C), padded_in=(Hp, Wp),
        macs=U * V * C * kh * kw,
        weight_bytes=int(tap.size) + int(p["alpha"].size) * 4,
        vmem_bytes=bdw.tile_vmem_bytes_dw(
            Wp, C, kh, kw, bu=bu, stride=spec.stride, m=m_plan, nb=nb),
    )
    instr = DWConvInstr(
        B_tap_packed=tap, alpha=p["alpha"], bias=_bias(p, C),
        name=spec.name, kh=kh, kw=kw, stride=spec.stride, relu=spec.relu,
        pre=spec.pre, M=M, plan=TilePlan(nb=nb, bu=bu), stats=stats)
    return instr, stats.out_shape


def _compile_linear(spec, p, shape, quant):
    """One linear spec -> (LinearInstr, out_shape)."""
    if "B_packed" not in p:
        p = bl.binarize_params(p, quant)
    B = shape[0]
    if spec.pre == "flatten":
        K = 1
        for d in shape[1:]:
            K *= d
    else:  # "gap" (channels survive the mean) or "none" (already [B, K])
        K = shape[-1]
    M, K8, N = p["B_packed"].shape
    G = p["alpha"].shape[1]
    group_size = K // G
    bt, bn, bk = kops.pick_matmul_plan(B, K, N, G=G, group_size=group_size)
    vmem = bmk.tile_vmem_bytes_mm(bt, bn, bk, m=M)
    stats = LayerStats(
        in_shape=(B, K), out_shape=(B, N),
        macs=K * N,
        weight_bytes=int(p["B_packed"].size) + int(p["alpha"].size) * 4,
        vmem_bytes=vmem,
    )
    instr = LinearInstr(
        B_packed=p["B_packed"], alpha=p["alpha"], bias=_bias(p, N),
        name=spec.name, K=K, relu=spec.relu, pre=spec.pre, M=M,
        group_size=group_size, plan=TilePlan(bt=bt, bn=bn, bk=bk),
        stats=stats)
    return instr, stats.out_shape


def compile(params: dict, arch: str, quant: QuantConfig,
            input_shape: tuple[int, ...], *,
            verify: bool = False,
            golden: bool | int = True) -> BinArrayProgram:
    """Compile a network into a :class:`BinArrayProgram`.

    params:      fp tree (binarized here with ``quant``), a packed tree from
                 ``binarize_cnn_a`` / ``binarize_mobilenet`` (reused as-is),
                 or a legacy packed tree without ``B_tap_packed`` (upgraded).
    arch:        "cnn_a" | "mobilenet" — selects the LayerSpec list in
                 models/cnn.py (the single topology source of truth) — or
                 an explicit LayerSpec sequence (custom/fuzzed topologies;
                 the program's ``arch`` records "custom").
    quant:       packing config (M, algorithm, K_iters, group_size) plus the
                 compile-time knobs: ``m_active`` biases the VMEM plan,
                 ``conv_batch_tile`` / ``conv_vmem_budget`` override the
                 auto pick, ``interpret`` sets the program's default Pallas
                 interpret flag.
    input_shape: (B, H, W, C) the tile plans are optimized for.
    verify:      run ``repro.analysis.verify_program`` on the result and
                 raise :class:`~repro.analysis.ProgramVerificationError` on
                 any ERROR finding (Mosaic-illegal blocks, out-of-range
                 plans, VMEM overruns) before the program ever reaches a
                 TPU.  Off by default — the CLI gate
                 (``tools/verify_program.py``) covers the shipped programs.
    golden:      record a :class:`~repro.deploy.program.GoldenRecord` (the
                 compile-time BIST: seeded probe input executed once per
                 §IV-D rung, output digests frozen — see deploy/selftest.py).
                 True (default) uses seed 0; an int supplies the probe seed;
                 False skips it (e.g. multi-minute 224² compiles whose
                 callers never self-test).  Automatically skipped under
                 ``jax.eval_shape`` — abstract programs carry ``golden=None``.

    All scheduling (``pick_tile`` / ``pick_tile_dw`` / ``pick_matmul_plan``)
    happens HERE — ``execute`` runs zero plan picks inside its trace
    (``kernels.binary_conv.plan_pick_count`` proves it).
    """
    if len(input_shape) != 4:
        raise ValueError(f"input_shape must be (B, H, W, C): {input_shape}")
    specs = _specs(arch)
    shape: tuple[int, ...] = tuple(int(d) for d in input_shape)
    instrs = []
    for spec in specs:
        p = params[spec.name]
        if spec.kind == "conv":
            instr, shape = _compile_conv(spec, p, shape, quant)
        elif spec.kind == "dwconv":
            instr, shape = _compile_dwconv(spec, p, shape, quant)
        else:
            instr, shape = _compile_linear(spec, p, shape, quant)
        instrs.append(instr)
    program = BinArrayProgram(
        instrs=tuple(instrs),
        arch=arch if isinstance(arch, str) else "custom",
        input_shape=tuple(int(d) for d in input_shape),
        interpret=quant.interpret)
    if golden is not False and not any(
            isinstance(leaf, jax.core.Tracer)
            for leaf in jax.tree_util.tree_leaves(program)):
        # deferred import: selftest pulls in the executor
        from repro.deploy.selftest import compute_golden

        seed = 0 if golden is True else int(golden)
        program = dataclasses.replace(
            program, golden=compute_golden(program, seed=seed))
    if verify:
        # deferred import: analysis depends on deploy.program, and pulling
        # the verifier in only when asked keeps plain compiles light
        from repro.analysis.verify import assert_verified

        assert_verified(program)
    return program


def abstract_program(arch: str, quant: QuantConfig,
                     input_shape: tuple[int, ...], *,
                     width_mult: float = 1.0,
                     n_classes: int = 1000) -> BinArrayProgram:
    """Compile without computing: init + binarize + plan under
    ``jax.eval_shape``.  The returned program carries the *real* frozen tile
    plans and LayerStats (they are static aux data) with ShapeDtypeStruct
    weight leaves — this is what the benchmarks and ``run.py --json``
    introspect, and the restore target for checkpoint round-trips."""

    def build(key):
        if arch == "cnn_a":
            p = cnn.init_cnn_a(key)
        else:
            p = cnn.init_mobilenet(key, width_mult=width_mult,
                                   n_classes=n_classes)
        return compile(p, arch, quant, input_shape)

    return jax.eval_shape(build, jax.ShapeDtypeStruct((2,), jnp.uint32))


# ---------------------------------------------------------------------------
# Checkpoint round-trip (checkpoint/manager.py)
# ---------------------------------------------------------------------------

def save_program(manager, step: int, program: BinArrayProgram, *,
                 extra: dict | None = None) -> str:
    """Persist a compiled program (packed weights; plans/stats ride in the
    pytree structure, which the restore target re-supplies).  The program's
    :class:`GoldenRecord` is serialized into the (digest-protected)
    manifest so :func:`load_program` can re-attach it even when the restore
    target is an abstract program with ``golden=None``."""
    meta = {"deploy": program.totals()}
    if program.golden is not None:
        meta["golden"] = program.golden.to_json()
    meta.update(extra or {})
    return manager.save(step, {"program": program}, extra=meta)


def _attach_golden(program: BinArrayProgram, extra) -> BinArrayProgram:
    """Re-attach the manifest's golden record when the restore target had
    none (`manager.restore` takes aux data from the target, not disk)."""
    if program.golden is None and isinstance(extra, dict) \
            and extra.get("golden"):
        return dataclasses.replace(
            program, golden=GoldenRecord.from_json(extra["golden"]))
    return program


class ProgramIntegrityError(ValueError):
    """A restored program failed static verification — a corrupt, truncated,
    or stale checkpoint that must not reach ``execute``.  Carries the ERROR
    :class:`~repro.analysis.verify.Finding`s as ``.findings``."""

    def __init__(self, message: str, findings=()):
        super().__init__(message)
        self.findings = tuple(findings)


def load_program(manager, step: int, like: BinArrayProgram, *,
                 verify: bool = True) -> BinArrayProgram:
    """Restore a program saved with :func:`save_program`.  ``like`` supplies
    the structure + plans — typically :func:`abstract_program` with the same
    arch/quant/input_shape (compilation is deterministic, so the treedefs
    match) or any same-shaped compiled program.

    By default the restored program is re-verified
    (``repro.analysis.verify_program``) and any ERROR finding raises
    :class:`ProgramIntegrityError` — a torn read, a truncated leaf, or a
    checkpoint from a stale layout fails loudly HERE, not as garbage logits
    (or an opaque Mosaic fault) at execute time.  ``verify=False`` opts out
    for hot loops that verify out of band (the fuzz tier compiles, verifies,
    and round-trips thousands of programs per run).
    """
    restored, extra = manager.restore(step, {"program": like})
    program = _attach_golden(restored["program"], extra)
    if verify:
        # deferred import, same reason as compile(verify=True)
        from repro.analysis.verify import verify_program

        errors = [f for f in verify_program(program)
                  if f.severity == "ERROR"]
        if errors:
            raise ProgramIntegrityError(
                f"restored program (step {step}) failed verification with "
                f"{len(errors)} ERROR finding(s):\n  "
                + "\n  ".join(str(f) for f in errors),
                findings=errors)
    return program


def load_latest_good(manager, like: BinArrayProgram, *, verify: bool = True,
                     selftest: bool = True):
    """Restore the newest checkpoint step whose program passes every gate.

    Wraps ``CheckpointManager.restore_latest_good``: the walk runs
    newest-first; any step failing digest verification, static verification
    (``verify``), or the golden self-test (``selftest``, when the saved
    program carries a :class:`GoldenRecord`) is quarantined with its reason
    and the walk continues.  Returns ``(step, program)``; raises
    :class:`~repro.checkpoint.manager.NoGoodCheckpoint` when every step is
    bad — a state the caller must handle loudly, not paper over.
    """
    def validate(restored, extra):
        program = _attach_golden(restored["program"], extra)
        if verify:
            from repro.analysis.verify import verify_program

            errors = [f for f in verify_program(program)
                      if f.severity == "ERROR"]
            if errors:
                raise ProgramIntegrityError(
                    f"restored program failed verification with "
                    f"{len(errors)} ERROR finding(s):\n  "
                    + "\n  ".join(str(f) for f in errors),
                    findings=errors)
        if selftest and program.golden is not None:
            from repro.deploy.selftest import self_test

            self_test(program)

    step, restored, extra = manager.restore_latest_good(
        {"program": like}, validate=validate)
    return step, _attach_golden(restored["program"], extra)
