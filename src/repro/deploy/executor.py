"""execute(): run a compiled BinArrayProgram — the accelerator side of §IV.

One jitted loop over the instruction stream.  Every scheduling decision
(tile plans, block sizes, padding resolution) was frozen at compile time, so
the trace contains zero auto-picks (``kernels.binary_conv.plan_pick_count``
is the proof hook) and the only per-call degrees of freedom are the input
batch and the §IV-D ``m_active`` level schedule:

  * ``m_active=None`` — every layer applies all of its packed levels;
  * ``m_active=k`` — the global runtime accuracy↔throughput switch, clamped
    per instruction to its packed M (identical numerics to the legacy
    ``QuantConfig(m_active=k)`` path);
  * ``m_active=[m0, m1, ...]`` — a per-layer schedule (one entry per
    instruction), the paper's per-layer generalization of §IV-D: early
    high-resolution layers can run fewer levels than the accuracy-critical
    back half without recompiling anything but this trace.

The schedule is static (level counts select packed buffer slices), so each
distinct schedule compiles once and is cached by ``jax.jit``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.deploy.program import (BinArrayProgram, ConvInstr, DWConvInstr,
                                  LinearInstr)
from repro.kernels import ops as kops
from repro.models.cnn import apply_pre

# Trace-entry accounting, the retrace twin of binary_conv.plan_pick_count:
# the body of _execute_jit bumps this only when jax.jit actually (re)traces,
# so repro.analysis.trace_lint can prove repeated identical traffic holds a
# bounded number of compiled variants (one per distinct m_active schedule).
_trace_entries = [0]


def trace_entry_count() -> int:
    """How many times the jitted execute body has been traced (process-wide)."""
    return _trace_entries[0]


def reset_trace_entry_count() -> None:
    _trace_entries[0] = 0


def cache_stats() -> dict:
    """Introspection for the soak/retrace harness: how many compiled
    variants the executor holds.  ``trace_entries`` is the process-wide
    trace counter above; ``jit_cache_entries`` is the live entry count of
    ``_execute_jit``'s jit cache (one entry per distinct (program treedef,
    input aval, schedule) triple) when the jax build exposes it."""
    out = {"trace_entries": _trace_entries[0]}
    try:
        out["jit_cache_entries"] = _execute_jit._cache_size()
    except Exception:  # noqa: BLE001 — private API, absent on some builds
        pass
    return out


def cache_gauges() -> dict:
    """``name -> callable`` gauges for ``repro.testing.soak`` — each must
    stay exactly flat once a soak workload has seen all its variants."""
    gauges = {"exec_trace_entries": lambda: float(_trace_entries[0])}
    if "jit_cache_entries" in cache_stats():
        gauges["exec_jit_cache_entries"] = (
            lambda: float(cache_stats()["jit_cache_entries"]))
    return gauges


def _apply(instr, y: jax.Array, m: int, interpret: bool) -> jax.Array:
    y = apply_pre(instr.pre, y)
    if isinstance(instr, ConvInstr):
        return kops.binary_conv2d(
            y, instr.B_tap_packed, instr.alpha, instr.bias,
            kh=instr.kh, kw=instr.kw, stride=instr.stride,
            padding=instr.padding, pool=instr.pool, m_active=m,
            relu=instr.relu, bd=instr.plan.bd, bu=instr.plan.bu,
            nb=instr.plan.nb, interpret=interpret)
    if isinstance(instr, DWConvInstr):
        return kops.binary_dwconv2d(
            y, instr.B_tap_packed, instr.alpha, instr.bias,
            kh=instr.kh, kw=instr.kw, stride=instr.stride, m_active=m,
            relu=instr.relu, bu=instr.plan.bu, nb=instr.plan.nb,
            interpret=interpret)
    assert isinstance(instr, LinearInstr), instr
    out = kops.binary_matmul(
        y, instr.B_packed, instr.alpha, K=instr.K,
        group_size=instr.group_size, m_active=m,
        bt=instr.plan.bt, bn=instr.plan.bn, bk=instr.plan.bk,
        interpret=interpret)
    out = out + instr.bias.astype(out.dtype)
    return jax.nn.relu(out) if instr.relu else out


@functools.partial(jax.jit, static_argnames=("m_schedule", "interpret"))
def _execute_jit(program: BinArrayProgram, x: jax.Array,
                 m_schedule: tuple[int, ...], interpret: bool) -> jax.Array:
    _trace_entries[0] += 1          # runs at trace time only, not per call
    y = x
    for instr, m in zip(program.instrs, m_schedule):
        y = _apply(instr, y, m, interpret)
    return y


def _check_input(program: BinArrayProgram, x) -> None:
    """Validate ``x`` against ``program.input_shape`` BEFORE the jitted call,
    so a mis-shaped batch is a one-line ValueError naming both shapes instead
    of an opaque Mosaic/XLA shape fault from deep inside the first kernel.

    Only ``.shape``/``.dtype`` attributes are read (tracers and
    ShapeDtypeStructs pass through — trace_lint runs execute under
    ``jax.make_jaxpr``).  The batch dim is free by contract (the kernels
    clamp and stay bit-exact across tilings); rank, the per-image dims, and
    floating dtype are not.
    """
    want = tuple(program.input_shape)
    shape = tuple(getattr(x, "shape", ()))
    if len(shape) != len(want) or shape[1:] != want[1:]:
        raise ValueError(
            f"input shape {shape} does not match program "
            f"{program.arch!r}: expected (B,{','.join(map(str, want[1:]))}) "
            f"(compiled input_shape={want}; batch dim is free)")
    dtype = getattr(x, "dtype", None)
    if dtype is not None and not jnp.issubdtype(dtype, jnp.floating):
        raise ValueError(
            f"input dtype {dtype} is not floating; program "
            f"{program.arch!r} executes fp activations (cast the batch "
            "before execute)")


def execute(program: BinArrayProgram, x: jax.Array, m_active=None, *,
            interpret: bool | None = None) -> jax.Array:
    """Run the program on a batch.  x: [B, H, W, C] -> logits.

    ``m_active``: None | int | per-instruction sequence (see module doc);
    entries are clamped to each instruction's packed M.  ``interpret``
    overrides the program's compile-time Pallas interpret default (CPU
    validation vs TPU).  Raises ValueError when ``x`` does not match
    ``program.input_shape`` (any batch size, but rank/H/W/C/floating-dtype
    must agree — see :func:`_check_input`).
    """
    _check_input(program, x)
    sched = program.resolve_schedule(m_active)
    itp = program.interpret if interpret is None else interpret
    return _execute_jit(program, x, m_schedule=sched, interpret=itp)
