"""Compile-once deployment API (paper §IV: compiler + instruction stream).

    from repro import deploy

    program = deploy.compile(params, "cnn_a", quant, input_shape=(8, 48, 48, 3))
    logits = deploy.execute(program, x)                  # all packed levels
    logits = deploy.execute(program, x, m_active=1)      # §IV-D global switch
    logits = deploy.execute(program, x, m_active=[1, 2, 2, 2, 2])  # per-layer

See docs/deploy.md for the compile → inspect → execute lifecycle.
"""
from repro.deploy.compiler import (ProgramIntegrityError, abstract_program,
                                   compile, load_program, save_program)
from repro.deploy.executor import execute
from repro.deploy.program import (BinArrayProgram, ConvInstr, DWConvInstr,
                                  LayerStats, LinearInstr, TilePlan)

__all__ = [
    "BinArrayProgram", "ConvInstr", "DWConvInstr", "LinearInstr",
    "LayerStats", "ProgramIntegrityError", "TilePlan", "abstract_program",
    "compile", "execute", "load_program", "save_program",
]
