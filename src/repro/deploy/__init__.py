"""Compile-once deployment API (paper §IV: compiler + instruction stream).

    from repro import deploy

    program = deploy.compile(params, "cnn_a", quant, input_shape=(8, 48, 48, 3))
    logits = deploy.execute(program, x)                  # all packed levels
    logits = deploy.execute(program, x, m_active=1)      # §IV-D global switch
    logits = deploy.execute(program, x, m_active=[1, 2, 2, 2, 2])  # per-layer
    deploy.self_test(program)                            # golden BIST replay

See docs/deploy.md for the compile → inspect → execute lifecycle and
docs/checkpointing.md for the integrity / recovery story.
"""
from repro.deploy.compiler import (ProgramIntegrityError, abstract_program,
                                   compile, load_latest_good, load_program,
                                   save_program)
from repro.deploy.executor import execute
from repro.deploy.program import (BinArrayProgram, ConvInstr, DWConvInstr,
                                  GoldenRecord, LayerStats, LinearInstr,
                                  TilePlan)
from repro.deploy.selftest import (SelfTestFailure, compute_golden,
                                   golden_rungs, self_test)

__all__ = [
    "BinArrayProgram", "ConvInstr", "DWConvInstr", "GoldenRecord",
    "LinearInstr", "LayerStats", "ProgramIntegrityError", "SelfTestFailure",
    "TilePlan", "abstract_program", "compile", "compute_golden", "execute",
    "golden_rungs", "load_latest_good", "load_program", "save_program",
    "self_test",
]
