"""Golden self-test: the program's built-in self-test (BIST).

The paper's accelerator executes a frozen instruction stream against packed
weight memory with *no runtime fallback* — which means a flipped bit in
``B_tap_packed`` is Mosaic-legal, passes every static check
(``analysis.verify_program``), and silently corrupts every answer.  The
defense deployed hardware uses is a BIST: a known input with a known answer,
replayed on demand.

``compute_golden`` runs a seeded canonical probe input through every §IV-D
rung of a program once (at compile time) and records the CRC32 of each
output into a :class:`~repro.deploy.program.GoldenRecord`.  ``self_test``
replays the probe through ``execute`` and compares digests — any in-memory
corruption of packed weights, alphas, or biases changes the bits of at
least the full-M output and raises :class:`SelfTestFailure` naming the
rung and both digests.

The self-test always measures the *clean* execute path: the fault
injector's wrapper (``repro.testing.faults``) marks itself with
``_clean_execute``, and :func:`_execute` unwraps it at call time — the
BIST diagnoses the program, not the harness.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.checkpoint.manager import crc32_hex
from repro.deploy.program import BinArrayProgram, GoldenRecord


def _execute(program, x, m_active):
    """The clean executor, unwrapping any live fault-injection patch."""
    from repro.deploy import executor

    fn = executor.execute
    while hasattr(fn, "_clean_execute"):
        fn = fn._clean_execute
    return fn(program, x, m_active)


class SelfTestFailure(RuntimeError):
    """A golden replay produced bytes that no longer match the record."""

    def __init__(self, message: str, *, rung: tuple[int, ...],
                 expected: str, actual: str):
        super().__init__(message)
        self.rung = rung
        self.expected = expected
        self.actual = actual


def golden_rungs(program: BinArrayProgram) -> tuple[tuple[int, ...], ...]:
    """Every §IV-D rung a served program can run at, full-M first.

    The candidate list mirrors ``serve_cnn.slo.default_ladder`` *before* its
    cost filter: the full packed schedule, then for each global m below
    ``m_max`` the front-half-at-m schedule and the global-m schedule.  The
    ladder filters this same list, so every ladder rung is guaranteed a
    recorded digest.
    """
    full = program.resolve_schedule(None)
    rungs = [full]
    n = len(program.instrs)
    half = n // 2
    for m in range(program.m_max - 1, 0, -1):
        front = tuple(min(m, s) if i < half else s
                      for i, s in enumerate(full))
        for cand in (front, program.resolve_schedule(m)):
            if cand not in rungs:
                rungs.append(cand)
    return tuple(rungs)


def golden_input(seed: int, input_shape: tuple[int, ...]) -> jax.Array:
    """The canonical probe input: seeded standard normal, batch 1."""
    return jax.random.normal(jax.random.PRNGKey(seed), tuple(input_shape),
                             dtype="float32")


def output_digest(y) -> str:
    """CRC32 of the raw output bytes — bit-exact, not allclose."""
    return crc32_hex(np.ascontiguousarray(np.asarray(y)).tobytes())


def compute_golden(program: BinArrayProgram, *, seed: int = 0,
                   rungs=None) -> GoldenRecord:
    """Execute the probe at every rung once and record the output digests."""
    if rungs is None:
        rungs = golden_rungs(program)
    shape = (1,) + tuple(program.input_shape[1:])
    x = golden_input(seed, shape)
    digests = []
    seen = set()
    for r in rungs:
        sched = program.resolve_schedule(r)
        if sched in seen:
            continue
        seen.add(sched)
        digests.append(
            (sched, output_digest(_execute(program, x, sched))))
    return GoldenRecord(seed=seed, input_shape=shape,
                        digests=tuple(digests))


def self_test(program: BinArrayProgram, *, rungs=None) -> int:
    """Replay the golden probe; raise :class:`SelfTestFailure` on any
    digest mismatch.  ``rungs=None`` checks every recorded rung; otherwise
    only the given schedules (each must be recorded).  Returns the number
    of rungs checked."""
    rec = program.golden
    if rec is None:
        raise ValueError(
            "program has no GoldenRecord — compile with golden=True (the "
            "default) or attach one via compute_golden")
    if rungs is None:
        targets = rec.schedules()
    else:
        targets = tuple(program.resolve_schedule(r) for r in rungs)
    x = golden_input(rec.seed, rec.input_shape)
    checked = 0
    for sched in targets:
        want = rec.digest_for(sched)
        if want is None:
            raise ValueError(
                f"schedule {sched} has no recorded golden digest "
                f"(recorded: {list(rec.schedules())})")
        got = output_digest(_execute(program, x, sched))
        if got != want:
            raise SelfTestFailure(
                f"golden self-test failed at rung {sched}: output digest "
                f"{got} != recorded {want} — the program's packed state "
                f"no longer produces its compile-time answers",
                rung=sched, expected=want, actual=got)
        checked += 1
    return checked
