"""Pallas TPU kernel: fused implicit-GEMM binary convolution (AGU+PA+AMU).

The im2col path (core/binconv.py) materializes a ``[B·U·V, kh·kw·C]`` patch
tensor in HBM — a kh·kw× blow-up of the activation stream — before the binary
matmul ever runs, forfeiting the memory-stream win the paper's compression
(Eq. 6) buys.  On the FPGA the AGU streams patches out of the feature buffer;
here the kernel does the same job in VMEM:

  1. AGU:  extract the patch tile for one input row-slab directly from the
     input block with kh·kw static strided slices — the im2col tensor only
     ever exists as a VMEM value, never in HBM.
  2. PE/PA: per level m, unpack the bit-packed filters to ±1, fold the
     per-(level, group) alpha in per K row, and run one MXU matmul
     (the same per-level compute order as binary_matmul.py).
  3. AMU:  bias + 2D max-pool + ReLU epilogue (paper Eq. 13, pool then ReLU
     == ReLU then pool by commutativity) before the HBM write-back, so the
     output stream is already pooled (pool² fewer bytes).

``B_tap_packed`` weight layout (byte-aligned per spatial tap)
-------------------------------------------------------------
The flat ``B_packed [M, ceil(K/8), D]`` byte stream (K = kh·kw·C row-major
over (tap_i, tap_j, c)) crosses spatial-tap boundaries whenever C % 8 != 0,
which would force the kernel to do cross-byte bit arithmetic per tap.  The
conv kernel therefore consumes a per-tap repacking

    B_tap_packed [M, kh·kw, ceil(C/8), D]   uint8

where ``B_tap_packed[m, t, c8, d]`` holds input channels ``8*c8 .. 8*c8+7``
of filter d's level-m ±1 weights at spatial tap ``t = i*kw + j`` (row-major
over the kh×kw window), **LSB-first** like the matmul kernel: bit j == 1
iff the ±1 weight for channel ``8*c8 + j`` is +1.  Each tap's C-slice is
padded to its own byte boundary with +1 bits; the kernel slices the padded
channels off right after unpacking (``w[:, :C, :]``), so their value never
matters.  Overhead: at most 7 bits per (level, tap, filter).
``pack_taps`` builds the layout from ±1 tensors, ``repack_taps`` converts a
flat ``B_packed`` (one-time upgrade — see ``binconv.ensure_tap_packed``),
and ``binconv.binarize_conv_params`` emits it directly — the tests' jnp
oracle (kernels/ref.py) consumes the *flat* layout, which is what keeps the
two packings cross-checked.

VMEM blocking: (batch, D-tile, U row-tile) grid with halo slabs
---------------------------------------------------------------
Grid: ``(B, D/BD, ceil(Uo/BU))`` where ``Uo = U // pool`` is the pooled
output height.  One program computes a ``BU × Vo × BD`` pooled output tile
(``Vo = V // pool``; the V axis is never tiled — feature maps are at most a
few hundred columns wide, and the MXU wants the full ``u_tile·V`` row
dimension anyway).  D is tiled MXU-style (BD = 128 by default, shrunk for
small D).

The input block for row-tile ``t`` is a **slab** of

    slab_rows = (BU·pool − 1)·stride + kh            rows, starting at
    row0      = t · BU·pool·stride                   (element offset)

so consecutive slabs overlap by the ``kh − stride`` halo rows the conv
window needs across the tile boundary.  Overlapping blocks cannot be
expressed in Pallas' default *Blocked* indexing (offsets are
``index·block_shape``), so the x spec uses ``pl.Unblocked`` indexing: the
index map returns element offsets directly, and the halo rows ride in via
``t·adv`` with ``adv = BU·pool·stride < slab_rows``.  The wrapper zero-pads
the row axis so every slab (including the ragged last tile when
``Uo % BU != 0``) is fully in bounds; the zero rows only ever feed output
rows that are sliced off after the call.

alpha/bias/weights are broadcast along the batch and row-tile grid dims,
x along the D grid dim; the row-tile dim is innermost so a weight tile
stays resident while the x slabs stream through it.  Per-program working
set (``tile_vmem_bytes`` computes the same quantities):

    x slab        slab_rows·Wp·C·4              (fp32 input rows + halo)
    patches       BU·pool·V·kh·kw·C·4           (implicit im2col, VMEM-only)
    weight tile   M·kh·kw·ceil(C/8)·BD          (bit-packed)
    w (1 level)   kh·kw·ceil(C/8)·8·BD·4        (unpacked ±1 as fp32)
    acc           BU·pool·V·BD·4
    out tile      BU·Vo·BD·4                    (pooled HBM write)

``pick_bu`` chooses the largest BU whose working set fits a VMEM budget
(default ``DEFAULT_VMEM_BUDGET`` = 8 MiB, half a TPU core's VMEM, leaving
room for double buffering); whole-image blocking is recovered as the
``BU == Uo`` special case and remains the pick whenever the image fits —
CNN-A never tiles, MobileNet-224's stem and early point-wise layers do.
``benchmarks/kernel_bench.py`` prints the analytic per-tile VMEM bytes and
HBM bytes for the fused vs explicit-im2col paths from these quantities.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import binarize as bz

# Per-program VMEM working-set budget for auto-picked row tiles: half a TPU
# core's ~16 MiB VMEM, leaving headroom for the pipeline's double buffering.
DEFAULT_VMEM_BUDGET = 8 * 1024 * 1024


def pack_taps(B: jax.Array, kh: int, kw: int, C: int) -> jax.Array:
    """±1 int8 [M, kh*kw*C, D] -> per-tap packed [M, kh*kw, ceil(C/8), D].

    Each spatial tap's C-slice is padded to a byte boundary with +1 bits;
    the kernel slices them off after unpacking, so their value never matters.
    """
    M, K, D = B.shape
    B = B.reshape(M, kh * kw, C, D)
    c_pad = (-C) % 8
    if c_pad:
        B = jnp.concatenate(
            [B, jnp.ones((M, kh * kw, c_pad, D), jnp.int8)], axis=2)
    Cp = C + c_pad
    return bz.pack_bits(B.reshape(M * kh * kw, Cp, D)).reshape(
        M, kh * kw, Cp // 8, D)


def repack_taps(B_packed: jax.Array, kh: int, kw: int, C: int) -> jax.Array:
    """Flat [M, ceil(K/8), D] uint8 -> per-tap [M, kh*kw, ceil(C/8), D] uint8
    (K = kh*kw*C row-major over (tap_i, tap_j, c)).

    One-time weight-layout upgrade for packed trees that predate the fused
    kernel — convert the tree once at load time via
    ``binconv.ensure_tap_packed`` (``binarize_conv_params`` emits
    B_tap_packed directly); hitting this from a traced forward re-runs the
    repack every call and warns (core/binconv.py).
    """
    M, K8, D = B_packed.shape
    K = kh * kw * C
    B = bz.unpack_bits(B_packed, K8 * 8)[:, :K, :]       # [M, K, D] ±1
    return pack_taps(B, kh, kw, C)


# ---------------------------------------------------------------------------
# Row-tile sizing (VMEM budget -> BU)
# ---------------------------------------------------------------------------

def slab_rows(bu: int, kh: int, *, stride: int = 1, pool: int = 1) -> int:
    """Input rows one program needs for ``bu`` pooled output rows (halo incl.)."""
    return (bu * pool - 1) * stride + kh


def tile_vmem_bytes(W: int, C: int, kh: int, kw: int, bd: int, *, bu: int,
                    pool: int = 1, stride: int = 1, m: int = 1) -> int:
    """Analytic per-program VMEM working set of the fused conv kernel for a
    ``bu``-pooled-row output tile (see the module docstring's table).

    ``W`` is the *padded* input width (SAME resolved upstream).  The same
    numbers drive ``pick_bu`` and benchmarks/kernel_bench.py.
    """
    V = (W - kw) // stride + 1
    u_tile = bu * pool
    slab = slab_rows(bu, kh, stride=stride, pool=pool)
    c8 = -(-C // 8)
    x_b = slab * W * C * 4
    patches = u_tile * V * kh * kw * C * 4
    w_packed = m * kh * kw * c8 * bd
    w_level = kh * kw * c8 * 8 * bd * 4      # one level's ±1 tile as fp32
    acc = u_tile * V * bd * 4
    out = bu * max(V // pool, 1) * bd * 4
    return x_b + patches + w_packed + w_level + acc + out


def pick_bu(H: int, W: int, C: int, kh: int, kw: int, bd: int,
            pool: int = 1, budget_bytes: int = DEFAULT_VMEM_BUDGET, *,
            stride: int = 1, m: int = 1) -> int:
    """Largest row-tile BU (pooled output rows per program) whose VMEM
    working set fits ``budget_bytes``.

    ``H``/``W`` are the *padded* input dims.  Returns ``Uo = U // pool``
    (whole-image blocking) whenever the image fits the budget, else the
    largest fitting BU, with a floor of 1 (a single pooled row; if even
    that exceeds the budget the kernel still runs — the budget is a target,
    not a hard VMEM limit).
    """
    U = (H - kh) // stride + 1
    uo = max(U // pool, 1)
    for bu in range(uo, 1, -1):
        if tile_vmem_bytes(W, C, kh, kw, bd, bu=bu, pool=pool, stride=stride,
                           m=m) <= budget_bytes:
            return bu
    return 1


# ---------------------------------------------------------------------------
# Kernel
# ---------------------------------------------------------------------------

def _kernel(x_ref, bp_ref, alpha_ref, bias_ref, o_ref, *,
            kh: int, kw: int, C: int, stride: int, pool: int,
            u_tile: int, V: int, group_size: int, m_active: int, relu: bool):
    """One (image, BD channels, BU rows) tile: patches + matmuls + epilogue."""
    x = x_ref[0]                                     # [slab_rows, Wp, C]
    # --- AGU: implicit im2col, tap-major to match the K layout (i, j, c) ---
    cols = []
    for i in range(kh):
        for j in range(kw):
            xs = x[i: i + (u_tile - 1) * stride + 1: stride,
                   j: j + (V - 1) * stride + 1: stride, :]
            cols.append(xs.reshape(u_tile * V, C))
    patches = jnp.concatenate(cols, axis=1).astype(jnp.float32)  # [uV, K]

    K = kh * kw * C
    G = K // group_size
    bd = o_ref.shape[-1]
    c8 = bp_ref.shape[2]
    shifts = jax.lax.broadcasted_iota(jnp.uint8, (kh * kw, c8, 8, 1), 2)
    acc = jnp.zeros((u_tile * V, bd), jnp.float32)
    for m in range(m_active):                        # static unroll over levels
        packed = bp_ref[m]                           # [kh*kw, C8, bd] uint8
        bits = (packed[:, :, None, :] >> shifts) & jnp.uint8(1)
        w = (bits.astype(jnp.int8) * 2 - 1).reshape(kh * kw, c8 * 8, bd)
        w = w[:, :C, :].reshape(K, bd).astype(jnp.float32)
        a = alpha_ref[m]                             # [G, bd]
        a_exp = jnp.broadcast_to(
            a[:, None, :], (G, group_size, bd)).reshape(K, bd)
        acc = acc + jax.lax.dot_general(
            patches, w * a_exp,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    # --- AMU epilogue: bias + 2D max-pool + ReLU, then the only HBM write ---
    y = acc + bias_ref[0][None, :]
    y = y.reshape(u_tile, V, bd)
    if pool > 1:
        y = y.reshape(u_tile // pool, pool, V // pool, pool, bd).max(
            axis=(1, 3))
    if relu:
        y = jnp.maximum(y, 0.0)
    o_ref[0] = y


@functools.partial(
    jax.jit,
    static_argnames=("kh", "kw", "stride", "pool", "group_size",
                     "m_active", "relu", "bd", "bu", "vmem_budget",
                     "interpret"),
)
def binary_conv2d_pallas(
    x: jax.Array,
    B_tap_packed: jax.Array,
    alpha: jax.Array,
    bias: jax.Array,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    pool: int = 1,
    group_size: int,
    m_active: int | None = None,
    relu: bool = True,
    bd: int = 128,
    bu: int | None = None,
    vmem_budget: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Fused binary conv + bias + 2D max-pool + ReLU.  fp32 output.

    x:            [B, Hp, Wp, C]  (already padded for SAME by the caller)
    B_tap_packed: [M, kh*kw, ceil(C/8), D] uint8  (see pack_taps)
    alpha:        [M, G, D] float  (G = kh*kw*C // group_size)
    bias:         [D] float
    returns       [B, U//pool, V//pool, D] float32 where
                  U = (Hp-kh)//stride + 1, V = (Wp-kw)//stride + 1.

    U and V must be divisible by ``pool`` (downsampling-only pooling, paper
    §III-B — binconv.relu_maxpool asserts the same).  ``bu`` fixes the row
    tile (pooled output rows per program); None auto-picks it from
    ``vmem_budget`` (default 8 MiB) via :func:`pick_bu` — whole-image
    blocking whenever the image fits.  Tiled and whole-image blocking are
    bit-identical: each output element's K-reduction and level order are
    the same in every tiling.
    """
    B, Hp, Wp, C = x.shape
    M, T, C8, D = B_tap_packed.shape
    assert T == kh * kw, (T, kh, kw)
    assert C8 * 8 >= C, (C8, C)
    m_active = min(m_active or M, M)  # can't apply more levels than packed
    U = (Hp - kh) // stride + 1
    V = (Wp - kw) // stride + 1
    assert U % pool == 0 and V % pool == 0, (U, V, pool)
    G = alpha.shape[1]
    assert G * group_size == kh * kw * C, (G, group_size, kh, kw, C)

    bd = min(bd, max(8, D))
    d_rem = (-D) % bd
    if d_rem:  # zero alpha/bias in the pad: padded channels contribute zeros
        B_tap_packed = jnp.pad(B_tap_packed, ((0, 0), (0, 0), (0, 0), (0, d_rem)))
        alpha = jnp.pad(alpha, ((0, 0), (0, 0), (0, d_rem)))
        bias = jnp.pad(bias, ((0, d_rem),))
    Dp = D + d_rem

    # --- row tiling: BU pooled output rows per program, halo slab input ---
    uo = U // pool
    if bu is None:
        bu = pick_bu(Hp, Wp, C, kh, kw, bd, pool,
                     vmem_budget or DEFAULT_VMEM_BUDGET,
                     stride=stride, m=m_active)
    bu = max(1, min(bu, uo))
    nt = -(-uo // bu)                       # row tiles (last may be ragged)
    adv = bu * pool * stride                # slab start advance per tile
    slab = slab_rows(bu, kh, stride=stride, pool=pool)
    rows_needed = (nt - 1) * adv + slab     # last slab's end, incl. halo
    if rows_needed > Hp:  # ragged last tile / halo: zero rows, sliced off
        x = jnp.pad(x, ((0, 0), (0, rows_needed - Hp), (0, 0), (0, 0)))
    u_tile = bu * pool

    B_tap_packed = B_tap_packed[:m_active]
    alpha = alpha[:m_active].astype(jnp.float32)
    bias2 = bias.astype(jnp.float32).reshape(1, Dp)

    # row-tile dim innermost: the weight tile stays resident per D-tile
    # while the x slabs stream through it.
    grid = (B, Dp // bd, nt)
    out = pl.pallas_call(
        functools.partial(
            _kernel, kh=kh, kw=kw, C=C, stride=stride, pool=pool,
            u_tile=u_tile, V=V, group_size=group_size, m_active=m_active,
            relu=relu),
        grid=grid,
        in_specs=[
            # overlapping halo slabs need element offsets -> Unblocked
            pl.BlockSpec((1, slab, Wp, C),
                         lambda b, d, t: (b, t * adv, 0, 0),
                         indexing_mode=pl.Unblocked()),
            pl.BlockSpec((m_active, T, C8, bd), lambda b, d, t: (0, 0, 0, d)),
            pl.BlockSpec((m_active, G, bd), lambda b, d, t: (0, 0, d)),
            pl.BlockSpec((1, bd), lambda b, d, t: (0, d)),
        ],
        out_specs=pl.BlockSpec((1, bu, V // pool, bd),
                               lambda b, d, t: (b, t, 0, d)),
        out_shape=jax.ShapeDtypeStruct((B, nt * bu, V // pool, Dp),
                                       jnp.float32),
        interpret=interpret,
    )(x, B_tap_packed, alpha, bias2)
    return out[:, :uo, :, :D]
