"""Pallas TPU kernel: fused implicit-GEMM binary convolution (AGU+PA+AMU).

The im2col path (core/binconv.py) materializes a ``[B·U·V, kh·kw·C]`` patch
tensor in HBM — a kh·kw× blow-up of the activation stream — before the binary
matmul ever runs, forfeiting the memory-stream win the paper's compression
(Eq. 6) buys.  On the FPGA the AGU streams patches out of the feature buffer;
here the kernel does the same job in VMEM:

  1. AGU:  extract the patch tile for one image directly from the input block
     with kh·kw static strided slices — the im2col tensor only ever exists as
     a VMEM value, never in HBM.
  2. PE/PA: per level m, unpack the bit-packed filters to ±1, fold the
     per-(level, group) alpha in per K row, and run one MXU matmul
     (the same per-level compute order as binary_matmul.py).
  3. AMU:  bias + 2D max-pool + ReLU epilogue (paper Eq. 13, pool then ReLU
     == ReLU then pool by commutativity) before the HBM write-back, so the
     output stream is already pooled (pool² fewer bytes).

``B_tap_packed`` weight layout (byte-aligned per spatial tap)
-------------------------------------------------------------
The flat ``B_packed [M, ceil(K/8), D]`` byte stream (K = kh·kw·C row-major
over (tap_i, tap_j, c)) crosses spatial-tap boundaries whenever C % 8 != 0,
which would force the kernel to do cross-byte bit arithmetic per tap.  The
conv kernel therefore consumes a per-tap repacking

    B_tap_packed [M, kh·kw, ceil(C/8), D]   uint8

where ``B_tap_packed[m, t, c8, d]`` holds input channels ``8*c8 .. 8*c8+7``
of filter d's level-m ±1 weights at spatial tap ``t = i*kw + j`` (row-major
over the kh×kw window), **LSB-first** like the matmul kernel: bit j == 1
iff the ±1 weight for channel ``8*c8 + j`` is +1.  Each tap's C-slice is
padded to its own byte boundary with +1 bits; the kernel slices the padded
channels off right after unpacking (``w[:, :C, :]``), so their value never
matters.  Overhead: at most 7 bits per (level, tap, filter).
``pack_taps`` builds the layout from ±1 tensors, ``repack_taps`` converts a
flat ``B_packed``, and ``binconv.binarize_conv_params`` emits it directly —
the tests' jnp oracle (kernels/ref.py) consumes the *flat* layout, which is
what keeps the two packings cross-checked.

VMEM blocking
-------------
Grid: (B, D/BD) — one program per (image, output-channel tile).  The spatial
extent of one image lives in VMEM whole; D is tiled MXU-style (BD = 128 by
default, shrunk for small D).  alpha/bias/weights are broadcast along the
batch grid dim, x along the D grid dim.  Per-program working set:

    x tile        Hp·Wp·C·4          (padded input image, fp32)
    patches       U·V·kh·kw·C·4      (implicit im2col, VMEM-only value)
    weight tile   M·kh·kw·ceil(C/8)·BD   (bit-packed)
    acc/out       U·V·BD·4           (epilogue shrinks the HBM write pool²)

Whole-image blocking bounds this by the feature-map size, which fits the
paper's CNN-A/MobileNet-scale layers; row-tiling the U axis for large
feature maps is a ROADMAP item.  ``benchmarks/kernel_bench.py
conv_tile_stats`` prints the analytic HBM bytes per tile for the fused vs
explicit-im2col paths from the same quantities.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import binarize as bz


def pack_taps(B: jax.Array, kh: int, kw: int, C: int) -> jax.Array:
    """±1 int8 [M, kh*kw*C, D] -> per-tap packed [M, kh*kw, ceil(C/8), D].

    Each spatial tap's C-slice is padded to a byte boundary with +1 bits;
    the kernel slices them off after unpacking, so their value never matters.
    """
    M, K, D = B.shape
    B = B.reshape(M, kh * kw, C, D)
    c_pad = (-C) % 8
    if c_pad:
        B = jnp.concatenate(
            [B, jnp.ones((M, kh * kw, c_pad, D), jnp.int8)], axis=2)
    Cp = C + c_pad
    return bz.pack_bits(B.reshape(M * kh * kw, Cp, D)).reshape(
        M, kh * kw, Cp // 8, D)


def repack_taps(B_packed: jax.Array, kh: int, kw: int, C: int) -> jax.Array:
    """Flat [M, ceil(K/8), D] uint8 -> per-tap [M, kh*kw, ceil(C/8), D] uint8
    (K = kh*kw*C row-major over (tap_i, tap_j, c)).

    Weight-layout transform for packed trees that predate the fused kernel;
    note it runs per call when hit from a traced forward — prefer converting
    the tree once (binarize_conv_params emits B_tap_packed directly).
    """
    M, K8, D = B_packed.shape
    K = kh * kw * C
    B = bz.unpack_bits(B_packed, K8 * 8)[:, :K, :]       # [M, K, D] ±1
    return pack_taps(B, kh, kw, C)


def _kernel(x_ref, bp_ref, alpha_ref, bias_ref, o_ref, *,
            kh: int, kw: int, C: int, stride: int, pool: int,
            U: int, V: int, group_size: int, m_active: int, relu: bool):
    """One (image, BD output channels) tile: patches + matmuls + AMU epilogue."""
    x = x_ref[0]                                     # [Hp, Wp, C]
    # --- AGU: implicit im2col, tap-major to match the K layout (i, j, c) ---
    cols = []
    for i in range(kh):
        for j in range(kw):
            xs = x[i: i + (U - 1) * stride + 1: stride,
                   j: j + (V - 1) * stride + 1: stride, :]
            cols.append(xs.reshape(U * V, C))
    patches = jnp.concatenate(cols, axis=1).astype(jnp.float32)  # [U*V, K]

    K = kh * kw * C
    G = K // group_size
    bd = o_ref.shape[-1]
    c8 = bp_ref.shape[2]
    shifts = jax.lax.broadcasted_iota(jnp.uint8, (kh * kw, c8, 8, 1), 2)
    acc = jnp.zeros((U * V, bd), jnp.float32)
    for m in range(m_active):                        # static unroll over levels
        packed = bp_ref[m]                           # [kh*kw, C8, bd] uint8
        bits = (packed[:, :, None, :] >> shifts) & jnp.uint8(1)
        w = (bits.astype(jnp.int8) * 2 - 1).reshape(kh * kw, c8 * 8, bd)
        w = w[:, :C, :].reshape(K, bd).astype(jnp.float32)
        a = alpha_ref[m]                             # [G, bd]
        a_exp = jnp.broadcast_to(
            a[:, None, :], (G, group_size, bd)).reshape(K, bd)
        acc = acc + jax.lax.dot_general(
            patches, w * a_exp,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    # --- AMU epilogue: bias + 2D max-pool + ReLU, then the only HBM write ---
    y = acc + bias_ref[0][None, :]
    y = y.reshape(U, V, bd)
    if pool > 1:
        y = y.reshape(U // pool, pool, V // pool, pool, bd).max(axis=(1, 3))
    if relu:
        y = jnp.maximum(y, 0.0)
    o_ref[0] = y


@functools.partial(
    jax.jit,
    static_argnames=("kh", "kw", "stride", "pool", "group_size",
                     "m_active", "relu", "bd", "interpret"),
)
def binary_conv2d_pallas(
    x: jax.Array,
    B_tap_packed: jax.Array,
    alpha: jax.Array,
    bias: jax.Array,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    pool: int = 1,
    group_size: int,
    m_active: int | None = None,
    relu: bool = True,
    bd: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Fused binary conv + bias + 2D max-pool + ReLU.  fp32 output.

    x:            [B, Hp, Wp, C]  (already padded for SAME by the caller)
    B_tap_packed: [M, kh*kw, ceil(C/8), D] uint8  (see repack_taps)
    alpha:        [M, G, D] float  (G = kh*kw*C // group_size)
    bias:         [D] float
    returns       [B, U//pool, V//pool, D] float32 where
                  U = (Hp-kh)//stride + 1, V = (Wp-kw)//stride + 1.

    U and V must be divisible by ``pool`` (downsampling-only pooling, paper
    §III-B — binconv.relu_maxpool asserts the same).
    """
    B, Hp, Wp, C = x.shape
    M, T, C8, D = B_tap_packed.shape
    assert T == kh * kw, (T, kh, kw)
    assert C8 * 8 >= C, (C8, C)
    m_active = min(m_active or M, M)  # can't apply more levels than packed
    U = (Hp - kh) // stride + 1
    V = (Wp - kw) // stride + 1
    assert U % pool == 0 and V % pool == 0, (U, V, pool)
    G = alpha.shape[1]
    assert G * group_size == kh * kw * C, (G, group_size, kh, kw, C)

    bd = min(bd, max(8, D))
    d_rem = (-D) % bd
    if d_rem:  # zero alpha/bias in the pad: padded channels contribute zeros
        B_tap_packed = jnp.pad(B_tap_packed, ((0, 0), (0, 0), (0, 0), (0, d_rem)))
        alpha = jnp.pad(alpha, ((0, 0), (0, 0), (0, d_rem)))
        bias = jnp.pad(bias, ((0, d_rem),))
    Dp = D + d_rem

    B_tap_packed = B_tap_packed[:m_active]
    alpha = alpha[:m_active].astype(jnp.float32)
    bias2 = bias.astype(jnp.float32).reshape(1, Dp)

    grid = (B, Dp // bd)
    out = pl.pallas_call(
        functools.partial(
            _kernel, kh=kh, kw=kw, C=C, stride=stride, pool=pool,
            U=U, V=V, group_size=group_size, m_active=m_active, relu=relu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Hp, Wp, C), lambda b, d: (b, 0, 0, 0)),
            pl.BlockSpec((m_active, T, C8, bd), lambda b, d: (0, 0, 0, d)),
            pl.BlockSpec((m_active, G, bd), lambda b, d: (0, 0, d)),
            pl.BlockSpec((1, bd), lambda b, d: (0, d)),
        ],
        out_specs=pl.BlockSpec((1, U // pool, V // pool, bd),
                               lambda b, d: (b, 0, 0, d)),
        out_shape=jax.ShapeDtypeStruct((B, U // pool, V // pool, Dp),
                                       jnp.float32),
        interpret=interpret,
    )(x, B_tap_packed, alpha, bias2)
    return out[..., :D]
