"""Pallas TPU kernel: fused implicit-GEMM binary convolution (AGU+PA+AMU).

The im2col path (core/binconv.py) materializes a ``[B·U·V, kh·kw·C]`` patch
tensor in HBM — a kh·kw× blow-up of the activation stream — before the binary
matmul ever runs, forfeiting the memory-stream win the paper's compression
(Eq. 6) buys.  On the FPGA the AGU streams patches out of the feature buffer;
here the kernel does the same job in VMEM:

  1. AGU:  extract the patch tile for one input row-slab directly from the
     input block with kh·kw static strided slices — the im2col tensor only
     ever exists as a VMEM value, never in HBM.
  2. PE/PA: unpack the bit-packed filters of all active levels to ±1, fold
     the per-(level, group) alpha in per K row, and run ONE level-concatenated
     MXU contraction ``[rows, m·K] @ [m·K, bd]`` (see below).
  3. AMU:  bias + 2D max-pool + ReLU epilogue (paper Eq. 13, pool then ReLU
     == ReLU then pool by commutativity) before the HBM write-back, so the
     output stream is already pooled (pool² fewer bytes).

``B_tap_packed`` weight layout (byte-aligned per spatial tap)
-------------------------------------------------------------
The flat ``B_packed [M, ceil(K/8), D]`` byte stream (K = kh·kw·C row-major
over (tap_i, tap_j, c)) crosses spatial-tap boundaries whenever C % 8 != 0,
which would force the kernel to do cross-byte bit arithmetic per tap.  The
conv kernel therefore consumes a per-tap repacking

    B_tap_packed [M, kh·kw, ceil(C/8), D]   uint8

where ``B_tap_packed[m, t, c8, d]`` holds input channels ``8*c8 .. 8*c8+7``
of filter d's level-m ±1 weights at spatial tap ``t = i*kw + j`` (row-major
over the kh×kw window), **LSB-first** like the matmul kernel: bit j == 1
iff the ±1 weight for channel ``8*c8 + j`` is +1.  Each tap's C-slice is
padded to its own byte boundary with +1 bits; the kernel slices the padded
channels off right after unpacking (``w[:, :C, :]``), so their value never
matters.  Overhead: at most 7 bits per (level, tap, filter).
``pack_taps`` builds the layout from ±1 tensors, ``repack_taps`` converts a
flat ``B_packed`` (one-time upgrade — see ``binconv.ensure_tap_packed``),
and ``binconv.binarize_conv_params`` emits it directly — the tests' jnp
oracle (kernels/ref.py) consumes the *flat* layout, which is what keeps the
two packings cross-checked.

Level-concatenated GEMM (one MXU contraction per program)
---------------------------------------------------------
The paper's Eq. 8 sum ``y = Σ_m alpha_m ⊙ (patches @ B_m)`` is linear in
the per-level products, so the kernel folds alpha into each level's ±1 tile
and stacks the levels along the contraction axis:

    W_cat [m·K, bd]   = concat_m (B_m ⊙ alpha_m)      (level-major rows)
    P_cat [rows, m·K] = concat_m patches              (m copies, VMEM-only)
    acc              = P_cat @ W_cat                  (one dot_general)

Each program issues a single big MXU contraction instead of m small ones:
the bit-unpack + alpha-fold runs once per program (not once per level-matmul
pipeline stage) and the MXU sees an m× longer reduction, which matters
exactly on the small late-layer feature maps where ``rows`` is short.
(The fully-collapsed alternative — pre-summing the alpha-folded levels into
one fp W_hat [K, bd] like the dw kernel's ``eff`` tap fold — would halve
the per-program MACs and drop the P_cat copy, but gives up the per-level
product structure of the paper's Eq. 8 inside the contraction; the
level-concat layout keeps each alpha_m·B_m product an explicit row block
of the GEMM while still amortizing the unpack.  ``tile_vmem_bytes``
charges the P_cat copy, so the (NB, BU) pick already accounts for it.)

VMEM blocking: (batch-tile, D-tile, U row-tile) grid with halo slabs
--------------------------------------------------------------------
Grid: ``(ceil(B/NB), D/BD, ceil(Uo/BU))`` where ``Uo = U // pool`` is the
pooled output height.  One program computes a ``NB × BU × Vo × BD`` pooled
output tile (``Vo = V // pool``; the V axis is never tiled — feature maps
are at most a few hundred columns wide, and the MXU wants the full row
dimension anyway).  D is tiled MXU-style (BD = 128 by default, shrunk for
small D).

**NB — batch tile.**  NB images are folded into the implicit-GEMM row
dimension: the patch tile becomes ``[NB·u_tile·V, K]`` so the MXU row dim
sees NB·u_tile·V rows instead of u_tile·V.  A 7×7 point-wise layer alone
feeds the 128-row MXU only 49 rows (38% row occupancy) and re-runs the
weight unpack for every one of the B·nt programs that share a weight tile;
folding NB images amortizes the unpack NB× and lets NB·49 approach a
multiple of 128 (NB=5 → 245/256 = 96% occupancy).  Ragged batches
(B % NB != 0) ride on zero-padded images sliced off after the call.

**BU — row tile.**  The input block for row-tile ``t`` is a **slab** of

    slab_rows = (BU·pool − 1)·stride + kh            rows, starting at
    row0      = t · BU·pool·stride                   (element offset)

so consecutive slabs overlap by the ``kh − stride`` halo rows the conv
window needs across the tile boundary.  Overlapping blocks cannot be
expressed in Pallas' default *Blocked* indexing (offsets are
``index·block_shape``), so the x spec uses ``pl.Unblocked`` indexing: the
index map returns element offsets directly, and the halo rows ride in via
``t·adv`` with ``adv = BU·pool·stride < slab_rows``.  The wrapper zero-pads
the row axis so every slab (including the ragged last tile when
``Uo % BU != 0``) is fully in bounds; the zero rows only ever feed output
rows that are sliced off after the call.

alpha/bias/weights are broadcast along the batch-tile and row-tile grid
dims, x along the D grid dim; the row-tile dim is innermost so a weight
tile stays resident while the x slabs stream through it.  Per-program
working set (``tile_vmem_bytes`` computes the same quantities):

    x slab        NB·slab_rows·Wp·C·4            (fp32 input rows + halo)
    patches       NB·u_tile·V·kh·kw·C·4·cat      (implicit im2col; cat = m+1
                                                  counts the level-concat
                                                  copy P_cat when m > 1)
    weight tile   M·kh·kw·ceil(C/8)·BD           (bit-packed)
    W_cat         2·m·kh·kw·C·BD·4               (±1 unpack + alpha-folded)
    acc           NB·u_tile·V·BD·4
    out tile      NB·BU·Vo·BD·4                  (pooled HBM write)

``pick_tile`` co-picks (NB, BU) from a VMEM budget (default
``DEFAULT_VMEM_BUDGET`` = 8 MiB, half a TPU core's VMEM, leaving room for
double buffering): big early layers keep NB=1 and row-tile BU down until
the slab fits; small late layers keep whole-image BU = Uo and pick the NB
minimizing the whole batch's padded MXU rows (ragged-batch zero images
charged) within the budget.  ``pick_bu`` is the BU-only special case (NB
fixed).  Whole-image per-image blocking is
recovered as NB=1, BU=Uo and remains bit-exact with every other tiling.
``benchmarks/kernel_bench.py`` prints the analytic per-tile VMEM bytes, HBM
bytes, and MXU row occupancy for the paper's layer shapes from these
quantities.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import binarize as bz

# Per-program VMEM working-set budget for auto-picked tiles: half a TPU
# core's ~16 MiB VMEM, leaving headroom for the pipeline's double buffering.
DEFAULT_VMEM_BUDGET = 8 * 1024 * 1024

# MXU systolic-array row dimension: the GEMM row count a program feeds is
# padded to a multiple of this, so occupancy = rows / roundup(rows, 128).
MXU_ROWS = 128

# ---------------------------------------------------------------------------
# Plan-pick accounting: every tile/block auto-pick bumps this counter, so the
# deploy tier can *prove* that a compiled BinArrayProgram runs zero scheduling
# decisions inside the jitted execute trace (repro/deploy — plans are frozen
# at compile time).  The legacy per-call paths (binconv.conv2d_relu_pool etc.)
# still auto-pick on every trace, which is exactly what the counter exposes.
# ---------------------------------------------------------------------------

_plan_picks = [0]


def _note_plan_pick() -> None:
    _plan_picks[0] += 1


def plan_pick_count() -> int:
    """Process-wide count of tile/block auto-picks (any kernel)."""
    return _plan_picks[0]


def reset_plan_pick_count() -> None:
    _plan_picks[0] = 0


def pack_taps(B: jax.Array, kh: int, kw: int, C: int) -> jax.Array:
    """±1 int8 [M, kh*kw*C, D] -> per-tap packed [M, kh*kw, ceil(C/8), D].

    Each spatial tap's C-slice is padded to a byte boundary with +1 bits;
    the kernel slices them off after unpacking, so their value never matters.
    """
    M, K, D = B.shape
    B = B.reshape(M, kh * kw, C, D)
    c_pad = (-C) % 8
    if c_pad:
        B = jnp.concatenate(
            [B, jnp.ones((M, kh * kw, c_pad, D), jnp.int8)], axis=2)
    Cp = C + c_pad
    return bz.pack_bits(B.reshape(M * kh * kw, Cp, D)).reshape(
        M, kh * kw, Cp // 8, D)


def repack_taps(B_packed: jax.Array, kh: int, kw: int, C: int) -> jax.Array:
    """Flat [M, ceil(K/8), D] uint8 -> per-tap [M, kh*kw, ceil(C/8), D] uint8
    (K = kh*kw*C row-major over (tap_i, tap_j, c)).

    One-time weight-layout upgrade for packed trees that predate the fused
    kernel — convert the tree once at load time via
    ``binconv.ensure_tap_packed`` (``binarize_conv_params`` emits
    B_tap_packed directly); hitting this from a traced forward re-runs the
    repack every call and warns (core/binconv.py).
    """
    M, K8, D = B_packed.shape
    K = kh * kw * C
    B = bz.unpack_bits(B_packed, K8 * 8)[:, :K, :]       # [M, K, D] ±1
    return pack_taps(B, kh, kw, C)


# ---------------------------------------------------------------------------
# Tile sizing (VMEM budget -> (NB, BU)) and MXU-occupancy analytics
# ---------------------------------------------------------------------------

def slab_rows(bu: int, kh: int, *, stride: int = 1, pool: int = 1) -> int:
    """Input rows one program needs for ``bu`` pooled output rows (halo incl.)."""
    return (bu * pool - 1) * stride + kh


def gemm_rows(nb: int, bu: int, V: int, *, pool: int = 1) -> int:
    """GEMM row count one program feeds the MXU: NB images × BU·pool conv
    rows × V conv columns."""
    return nb * bu * pool * V


def mxu_row_occupancy(rows: int) -> float:
    """Fraction of the MXU's padded row dimension carrying real work:
    rows / roundup(rows, MXU_ROWS)."""
    return rows / (-(-rows // MXU_ROWS) * MXU_ROWS)


def batch_padded_rows(B: int, nb: int, rows_img: int) -> int:
    """Total MXU rows a whole batch moves (zero-padding included): each of
    the ceil(B/nb) programs pads its nb·rows_img GEMM rows (the ragged last
    program's missing images ride as zero rows) up to a multiple of
    MXU_ROWS."""
    progs = -(-B // nb)
    return progs * (-(-nb * rows_img // MXU_ROWS) * MXU_ROWS)


def batch_row_utilization(B: int, nb: int, rows_img: int) -> float:
    """End-to-end fraction of the batch's padded MXU rows carrying real
    work: B·rows_img / batch_padded_rows — unlike the per-program
    ``mxu_row_occupancy`` this also charges the ragged-batch zero images."""
    return B * rows_img / batch_padded_rows(B, nb, rows_img)


def unpack_work_per_output(nb: int, bu: int, vo: int, K: int, *,
                           m: int = 1) -> float:
    """Weight-unpack element ops per pooled output element of one program.

    A program unpacks ``m·K·bd`` weight elements once and produces
    ``nb·bu·vo·bd`` pooled outputs, so folding NB images divides the
    per-output unpack work by NB — the amortization the batch tile buys.
    """
    return m * K / (nb * bu * max(vo, 1))


def tile_vmem_bytes(W: int, C: int, kh: int, kw: int, bd: int, *, bu: int,
                    pool: int = 1, stride: int = 1, m: int = 1,
                    nb: int = 1) -> int:
    """Analytic per-program VMEM working set of the fused conv kernel for an
    ``nb``-image, ``bu``-pooled-row output tile (see the module docstring's
    table).

    ``W`` is the *padded* input width (SAME resolved upstream).  The same
    numbers drive ``pick_tile``/``pick_bu`` and benchmarks/kernel_bench.py.
    """
    V = (W - kw) // stride + 1
    u_tile = bu * pool
    slab = slab_rows(bu, kh, stride=stride, pool=pool)
    c8 = -(-C // 8)
    K = kh * kw * C
    x_b = nb * slab * W * C * 4
    # base patches + the level-concatenated P_cat copy (m > 1 only)
    cat = 1 + (m if m > 1 else 0)
    patches = nb * u_tile * V * K * 4 * cat
    w_packed = m * kh * kw * c8 * bd
    w_cat = 2 * m * K * bd * 4               # ±1 unpack + alpha-folded W_cat
    acc = nb * u_tile * V * bd * 4
    out = nb * bu * max(V // pool, 1) * bd * 4
    return x_b + patches + w_packed + w_cat + acc + out


def tile_hbm_bytes(W: int, C: int, kh: int, kw: int, bd: int, *, bu: int,
                   pool: int = 1, stride: int = 1, m: int = 1, nb: int = 1,
                   H: int | None = None) -> tuple[int, int]:
    """Analytic HBM bytes one (batch-tile, D-tile, row-tile) program moves:
    ``(fused, im2col)`` for fp32 activations.

    fused: read the NB input row-slabs (halo included, clipped to the image
    height ``H`` when given) + the bit-packed per-tap weight tile, write the
    *pooled* output tile — the patch tensor lives only in VMEM.  im2col
    (core/binconv.py conv2d + relu_maxpool): additionally writes the tile's
    ``[nb·u·V, kh·kw·C]`` patch slice to HBM and reads it back for the
    matmul, then writes the unpooled conv output and re-reads it for
    pooling.  Shared by benchmarks/kernel_bench.py and the deploy compiler's
    per-layer stats so neither can drift from the BlockSpec reality.
    """
    V = (W - kw) // stride + 1
    u_tile = bu * pool
    slab = slab_rows(bu, kh, stride=stride, pool=pool)
    if H is not None:
        slab = min(slab, H)
    c8 = -(-C // 8)
    x_b = nb * slab * W * C * 4
    w_packed = m * kh * kw * c8 * bd
    out_pooled = nb * bu * (V // pool) * bd * 4
    out_unpooled = nb * u_tile * V * bd * 4
    patches = nb * u_tile * V * kh * kw * C * 4
    fused = x_b + w_packed + out_pooled
    im2col = x_b + 2 * patches + w_packed + out_unpooled * 2 + out_pooled
    return fused, im2col


def conv_block_shapes(Hp: int, Wp: int, C: int, D: int, kh: int, kw: int, *,
                      bd: int, bu: int, nb: int, pool: int = 1,
                      stride: int = 1, m: int = 1, group_size: int | None
                      = None, B: int | None = None) -> dict:
    """The exact BlockSpec geometry ``binary_conv2d_pallas`` builds for a
    (clamped) tile plan — exported so ``repro.analysis`` checks the real
    schedule instead of re-deriving its own.

    Returns ``{"blocks": {operand: (block_shape, padded_array_shape, dtype)},
    "grid": grid, "padded_rows": rows of the padded x, "slab": slab_rows,
    "adv": row advance per tile, "nt": row tiles}``.  ``Hp``/``Wp`` are the
    SAME-resolved input dims; ``B`` defaults to one batch tile.  Callers must
    pass the clamped plan (``bd <= max(8, D)``, ``bu <= Uo``, ``nb <= B``) —
    the same values the kernel would execute.
    """
    U = (Hp - kh) // stride + 1
    V = (Wp - kw) // stride + 1
    uo = max(U // pool, 1)
    T = kh * kw
    C8 = -(-C // 8)
    K = T * C
    G = K // (group_size or K)
    d_rem = (-D) % bd
    Dp = D + d_rem
    nt = -(-uo // bu)
    adv = bu * pool * stride
    slab = slab_rows(bu, kh, stride=stride, pool=pool)
    rows_needed = (nt - 1) * adv + slab
    row_pad = max(rows_needed - Hp, 0)
    b = B if B is not None else nb
    Bp = b + (-b) % nb
    blocks = {
        "x": ((nb, slab, Wp, C), (Bp, Hp + row_pad, Wp, C), "float32"),
        "B_tap_packed": ((m, T, C8, bd), (m, T, C8, Dp), "uint8"),
        "alpha": ((m, G, bd), (m, G, Dp), "float32"),
        "bias": ((1, bd), (1, Dp), "float32"),
        "out": ((nb, bu, V // pool, bd), (Bp, nt * bu, V // pool, Dp),
                "float32"),
    }
    return {"blocks": blocks, "grid": (Bp // nb, Dp // bd, nt),
            "padded_rows": Hp + row_pad, "slab": slab, "adv": adv, "nt": nt}


def pick_bu(H: int, W: int, C: int, kh: int, kw: int, bd: int,
            pool: int = 1, budget_bytes: int = DEFAULT_VMEM_BUDGET, *,
            stride: int = 1, m: int = 1, nb: int = 1) -> int:
    """Largest row-tile BU (pooled output rows per program) whose VMEM
    working set fits ``budget_bytes`` at a fixed batch tile ``nb``.

    ``H``/``W`` are the *padded* input dims.  Returns ``Uo = U // pool``
    (whole-image blocking) whenever the image fits the budget, else the
    largest fitting BU, with a floor of 1 (a single pooled row; if even
    that exceeds the budget the kernel still runs — the budget is a target,
    not a hard VMEM limit).
    """
    _note_plan_pick()
    U = (H - kh) // stride + 1
    uo = max(U // pool, 1)
    for bu in range(uo, 1, -1):
        if tile_vmem_bytes(W, C, kh, kw, bd, bu=bu, pool=pool, stride=stride,
                           m=m, nb=nb) <= budget_bytes:
            return bu
    return 1


def pick_tile(B: int, H: int, W: int, C: int, kh: int, kw: int, bd: int,
              pool: int = 1, budget_bytes: int = DEFAULT_VMEM_BUDGET, *,
              stride: int = 1, m: int = 1) -> tuple[int, int]:
    """Co-pick the (NB, BU) tile for the fused conv kernel.

    Two regimes, split by whether one whole image fits the budget:

      * big early layers (``pick_bu`` returns BU < Uo): the row slab already
        saturates the MXU row dim and VMEM is the binding constraint —
        keep NB=1 and row-tile.
      * small late layers (whole image fits): keep BU = Uo and pick the NB
        that minimizes the *whole batch's* padded MXU rows
        (``batch_padded_rows``: ceil(B/NB) programs, each rounded up to a
        multiple of MXU_ROWS — so ragged-batch zero images and per-program
        pad rows are both charged), tie-broken toward fewer programs (the
        weight unpack runs once per program).  A 7×7 point-wise map is 49
        rows/image (38% occupancy alone); at B=128 the pick lands on NB=13
        (637/640 rows = 99.5% per program), while a batch of exactly 16
        folds all 16 images into one 784-row program rather than leave a
        mostly-empty ragged program behind.

    Candidate NBs stop at the VMEM budget (or 64 images).  Every (NB, BU)
    produces bit-identical outputs — tiling is a throughput decision, never
    an accuracy one.
    """
    _note_plan_pick()
    U = (H - kh) // stride + 1
    V = (W - kw) // stride + 1
    uo = max(U // pool, 1)
    bu = pick_bu(H, W, C, kh, kw, bd, pool, budget_bytes, stride=stride, m=m)
    if bu < uo or B <= 1:
        return 1, bu
    rows1 = gemm_rows(1, uo, V, pool=pool)
    best_nb, best_key = 1, None
    for nb in range(1, min(B, 64) + 1):
        if nb > 1 and tile_vmem_bytes(W, C, kh, kw, bd, bu=uo, pool=pool,
                                      stride=stride, m=m,
                                      nb=nb) > budget_bytes:
            break
        key = (batch_padded_rows(B, nb, rows1), -(-B // nb))
        if best_key is None or key < best_key:
            best_nb, best_key = nb, key
    return best_nb, uo


# ---------------------------------------------------------------------------
# Kernel
# ---------------------------------------------------------------------------

def _kernel(x_ref, bp_ref, alpha_ref, bias_ref, o_ref, *,
            kh: int, kw: int, C: int, stride: int, pool: int, nb: int,
            u_tile: int, V: int, group_size: int, m_active: int, relu: bool):
    """One (NB images, BD channels, BU rows) tile: patches + GEMM + epilogue."""
    x = x_ref[...]                                   # [nb, slab_rows, Wp, C]
    # --- AGU: implicit im2col, tap-major to match the K layout (i, j, c) ---
    cols = []
    for i in range(kh):
        for j in range(kw):
            xs = x[:, i: i + (u_tile - 1) * stride + 1: stride,
                   j: j + (V - 1) * stride + 1: stride, :]
            cols.append(xs.reshape(nb * u_tile * V, C))
    patches = jnp.concatenate(cols, axis=1).astype(jnp.float32)  # [rows, K]

    K = kh * kw * C
    G = K // group_size
    bd = o_ref.shape[-1]
    c8 = bp_ref.shape[2]
    # --- PA: unpack every active level at once, fold alpha per level-row ---
    shifts = jax.lax.broadcasted_iota(
        jnp.uint8, (m_active, kh * kw, c8, 8, 1), 3)
    bits = (bp_ref[...][:, :, :, None, :] >> shifts) & jnp.uint8(1)
    w = (bits.astype(jnp.int8) * 2 - 1).reshape(m_active, kh * kw, c8 * 8, bd)
    w = w[:, :, :C, :].reshape(m_active, K, bd).astype(jnp.float32)
    a = alpha_ref[...]                               # [m, G, bd]
    a_exp = jnp.broadcast_to(
        a[:, :, None, :], (m_active, G, group_size, bd)).reshape(
        m_active, K, bd)
    w_cat = (w * a_exp).reshape(m_active * K, bd)    # level-major row blocks
    p_cat = (jnp.concatenate([patches] * m_active, axis=1)
             if m_active > 1 else patches)           # [rows, m·K]
    # One contraction per program, issued in fixed MXU-row-sized passes:
    # every pass is an identical-shape [MXU_ROWS, m·K] @ [m·K, bd] dot (zero
    # row padding on the ragged last pass), so each output row's reduction
    # order is invariant to the (NB, BU) tiling — the bit-exactness
    # guarantee — and matches how the MXU consumes the row dimension.  A
    # single [rows, m·K] dot would let the backend re-block the reduction
    # as a function of the row count, which differs across tilings.
    rows = nb * u_tile * V
    r_pad = (-rows) % MXU_ROWS
    if r_pad:
        p_cat = jnp.concatenate(
            [p_cat, jnp.zeros((r_pad, m_active * K), jnp.float32)], axis=0)
    acc = jax.lax.map(
        lambda pc: jax.lax.dot_general(
            pc, w_cat,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32),
        p_cat.reshape((rows + r_pad) // MXU_ROWS, MXU_ROWS, m_active * K),
    ).reshape(rows + r_pad, bd)[:rows]
    # --- AMU epilogue: bias + 2D max-pool + ReLU, then the only HBM write ---
    y = acc + bias_ref[0][None, :]
    y = y.reshape(nb, u_tile, V, bd)
    if pool > 1:
        y = y.reshape(nb, u_tile // pool, pool, V // pool, pool, bd).max(
            axis=(2, 4))
    if relu:
        y = jnp.maximum(y, 0.0)
    o_ref[...] = y


@functools.partial(
    jax.jit,
    static_argnames=("kh", "kw", "stride", "pool", "group_size",
                     "m_active", "relu", "bd", "bu", "nb", "vmem_budget",
                     "interpret"),
)
def binary_conv2d_pallas(
    x: jax.Array,
    B_tap_packed: jax.Array,
    alpha: jax.Array,
    bias: jax.Array,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    pool: int = 1,
    group_size: int,
    m_active: int | None = None,
    relu: bool = True,
    bd: int = 128,
    bu: int | None = None,
    nb: int | None = None,
    vmem_budget: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Fused binary conv + bias + 2D max-pool + ReLU.  fp32 output.

    x:            [B, Hp, Wp, C]  (already padded for SAME by the caller)
    B_tap_packed: [M, kh*kw, ceil(C/8), D] uint8  (see pack_taps)
    alpha:        [M, G, D] float  (G = kh*kw*C // group_size)
    bias:         [D] float
    returns       [B, U//pool, V//pool, D] float32 where
                  U = (Hp-kh)//stride + 1, V = (Wp-kw)//stride + 1.

    U and V must be divisible by ``pool`` (downsampling-only pooling, paper
    §III-B — binconv.relu_maxpool asserts the same).  ``nb`` fixes the batch
    tile (images folded into the GEMM row dim per program) and ``bu`` the
    row tile (pooled output rows per program); leaving both None co-picks
    them from ``vmem_budget`` (default 8 MiB) via :func:`pick_tile` —
    whole-image NB=1 blocking whenever that already saturates the MXU.
    Giving ``bu`` alone keeps per-image blocking (nb=1).  Every (nb, bu)
    tiling is bit-identical: each output element's concatenated m·K
    reduction is the same in every tiling.
    """
    B, Hp, Wp, C = x.shape
    M, T, C8, D = B_tap_packed.shape
    assert T == kh * kw, (T, kh, kw)
    assert C8 * 8 >= C, (C8, C)
    m_active = min(m_active or M, M)  # can't apply more levels than packed
    U = (Hp - kh) // stride + 1
    V = (Wp - kw) // stride + 1
    assert U % pool == 0 and V % pool == 0, (U, V, pool)
    G = alpha.shape[1]
    assert G * group_size == kh * kw * C, (G, group_size, kh, kw, C)

    bd = min(bd, max(8, D))
    d_rem = (-D) % bd
    if d_rem:  # zero alpha/bias in the pad: padded channels contribute zeros
        B_tap_packed = jnp.pad(B_tap_packed, ((0, 0), (0, 0), (0, 0), (0, d_rem)))
        alpha = jnp.pad(alpha, ((0, 0), (0, 0), (0, d_rem)))
        bias = jnp.pad(bias, ((0, d_rem),))
    Dp = D + d_rem

    # --- joint (NB, BU) tiling: batch fold + halo row slabs ---
    uo = U // pool
    budget = vmem_budget or DEFAULT_VMEM_BUDGET
    if nb is None and bu is None:
        nb, bu = pick_tile(B, Hp, Wp, C, kh, kw, bd, pool, budget,
                           stride=stride, m=m_active)
    elif nb is None:
        nb = 1  # explicit BU: per-image row tiling (the pre-batch semantics)
    elif bu is None:
        bu = pick_bu(Hp, Wp, C, kh, kw, bd, pool, budget,
                     stride=stride, m=m_active, nb=max(1, min(nb, B)))
    nb = max(1, min(nb, B))
    bu = max(1, min(bu, uo))
    nt = -(-uo // bu)                       # row tiles (last may be ragged)
    adv = bu * pool * stride                # slab start advance per tile
    slab = slab_rows(bu, kh, stride=stride, pool=pool)
    rows_needed = (nt - 1) * adv + slab     # last slab's end, incl. halo
    b_rem = (-B) % nb                       # ragged batch: zero images,
    row_pad = max(rows_needed - Hp, 0)      # ragged rows: zero rows — both
    if b_rem or row_pad:                    # sliced off after the call
        x = jnp.pad(x, ((0, b_rem), (0, row_pad), (0, 0), (0, 0)))
    Bp = B + b_rem
    u_tile = bu * pool

    B_tap_packed = B_tap_packed[:m_active]
    alpha = alpha[:m_active].astype(jnp.float32)
    bias2 = bias.astype(jnp.float32).reshape(1, Dp)

    # row-tile dim innermost: the weight tile stays resident per D-tile
    # while the x slabs stream through it.
    grid = (Bp // nb, Dp // bd, nt)
    out = pl.pallas_call(
        functools.partial(
            _kernel, kh=kh, kw=kw, C=C, stride=stride, pool=pool, nb=nb,
            u_tile=u_tile, V=V, group_size=group_size, m_active=m_active,
            relu=relu),
        grid=grid,
        in_specs=[
            # overlapping halo slabs need element offsets -> Unblocked
            pl.BlockSpec((nb, slab, Wp, C),
                         lambda b, d, t: (b * nb, t * adv, 0, 0),
                         indexing_mode=pl.Unblocked()),
            pl.BlockSpec((m_active, T, C8, bd), lambda b, d, t: (0, 0, 0, d)),
            pl.BlockSpec((m_active, G, bd), lambda b, d, t: (0, 0, d)),
            pl.BlockSpec((1, bd), lambda b, d, t: (0, d)),
        ],
        out_specs=pl.BlockSpec((nb, bu, V // pool, bd),
                               lambda b, d, t: (b, t, 0, d)),
        out_shape=jax.ShapeDtypeStruct((Bp, nt * bu, V // pool, Dp),
                                       jnp.float32),
        interpret=interpret,
    )(x, B_tap_packed, alpha, bias2)
    return out[:B, :uo, :, :D]
