"""Pallas TPU kernel: fused binary depth-wise convolution (paper §V-A3).

MobileNet's depth-wise 3×3 layers are memory-bound — each output channel
reads one input channel through a kh·kw window, so there is no reduction for
the MXU to amortize and the paper maps them to a *channel-wise* binary
approximation with D_arch = 1 (a single filter per PA).  Running them as fp
``lax.conv`` breaks the binary deployment story end to end: the activations
stream through HBM twice (conv out, then ReLU) and the weights stay fp32.
This kernel keeps the whole dw stage on-chip:

  1. unpack the bit-packed per-tap filters and fold the per-(level, channel)
     alpha into one *effective* tap weight per (tap, channel) in VMEM —
     the depth-wise conv is linear in the weights, so
     ``sum_m alpha[m,c]·B[m,t,c]`` collapses the level loop into the
     reconstruction W_hat the paper's Eq. 1 defines (HBM traffic stays the
     packed bits + alpha; m_active < M truncates the sum, §IV-D);
  2. accumulate the kh·kw strided-slice taps channel-wise on the VPU
     (no matmul — there is nothing to contract);
  3. bias + ReLU epilogue before the only HBM write-back.

``B_tap_packed`` weight layout (channel-wise, byte-aligned per tap)
-------------------------------------------------------------------
    B_tap_packed [M, kh·kw, ceil(C/8)]   uint8

``B_tap_packed[m, t, c8]`` holds channels ``8*c8 .. 8*c8+7`` of the level-m
±1 depth-wise weights at spatial tap ``t = i*kw + j``, LSB-first like the
conv kernel: bit j == 1 iff the weight for channel ``8*c8 + j`` is +1.
The C axis is padded to a byte boundary with +1 bits, sliced off after
unpacking.  ``pack_dw_taps`` builds the layout from ±1 tensors;
``binconv.binarize_dwconv_params`` emits it plus the channel-wise
``alpha [M, C]``.  The jnp oracle (kernels/ref.py binary_dwconv_relu_ref)
unpacks the same bytes and runs fp ``lax.conv`` on the reconstruction,
which is what keeps the packing and the kernel cross-checked.

VMEM blocking
-------------
Grid: ``(ceil(B/NB), ceil(U/BU))`` — joint (batch-tile, row-tile) blocking
like kernels/binary_conv.py; the channel axis stays whole (dw feature maps
are large exactly when C is small, and C·4 bytes per pixel is the whole
working set — there is no D blow-up).  NB images per program amortize the
per-program bit-unpack + alpha-fold NB× (there is no MXU row dimension to
fill here — the tap accumulation runs on the VPU — so the batch tile is
purely an unpack/dispatch amortization; ragged batches ride on zero-padded
images sliced off after the call).  Row tiles use the same halo-slab scheme
as the conv kernel: tile ``t`` reads the input rows
``[t·BU·stride, t·BU·stride + (BU-1)·stride + kh)`` via a ``pl.Unblocked``
element-offset index map, with the wrapper zero-padding the row axis so
ragged last tiles stay in bounds.  ``pick_tile_dw`` co-picks (NB, BU) from
the same 8 MiB default budget: row-tiled maps keep NB=1, whole-image maps
grow NB until the budget binds (``pick_bu_dw`` is the BU-only
special case).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import binarize as bz
from repro.kernels.binary_conv import (DEFAULT_VMEM_BUDGET, _note_plan_pick,
                                       slab_rows)


def pack_dw_taps(B: jax.Array) -> jax.Array:
    """±1 int8 [M, kh*kw, C] -> channel-packed [M, kh*kw, ceil(C/8)] uint8.

    The C axis is padded to a byte boundary with +1 bits; the kernel and the
    oracle slice them off after unpacking, so their value never matters.
    """
    M, T, C = B.shape
    c_pad = (-C) % 8
    if c_pad:
        B = jnp.concatenate([B, jnp.ones((M, T, c_pad), jnp.int8)], axis=2)
    Cp = C + c_pad
    return bz.pack_bits(B.reshape(M * T, Cp, 1)).reshape(M, T, Cp // 8)


def unpack_dw_taps(packed: jax.Array, C: int) -> jax.Array:
    """uint8 [M, kh*kw, ceil(C/8)] -> ±1 int8 [M, kh*kw, C] (inverse)."""
    M, T, c8 = packed.shape
    B = bz.unpack_bits(packed.reshape(M * T, c8, 1), c8 * 8)
    return B.reshape(M, T, c8 * 8)[:, :, :C]


def tile_vmem_bytes_dw(W: int, C: int, kh: int, kw: int, *, bu: int,
                       stride: int = 1, m: int = 1, nb: int = 1) -> int:
    """Analytic per-program VMEM working set for an ``nb``-image, ``bu``-row
    dw tile."""
    V = (W - kw) // stride + 1
    slab = slab_rows(bu, kh, stride=stride)
    c8 = -(-C // 8)
    x_b = nb * slab * W * C * 4
    w_packed = m * kh * kw * c8
    w_eff = kh * kw * c8 * 8 * 4 * (m + 1)   # unpacked levels + folded taps
    acc = nb * bu * V * C * 4
    out = nb * bu * V * C * 4
    return x_b + w_packed + w_eff + acc + out


def dw_block_shapes(Hp: int, Wp: int, C: int, kh: int, kw: int, *,
                    bu: int, nb: int, stride: int = 1, m: int = 1,
                    B: int | None = None) -> dict:
    """The exact BlockSpec geometry ``binary_dwconv2d_pallas`` builds for a
    (clamped) tile plan — same contract as
    ``binary_conv.conv_block_shapes``, consumed by ``repro.analysis``."""
    U = (Hp - kh) // stride + 1
    V = (Wp - kw) // stride + 1
    T = kh * kw
    c8 = -(-C // 8)
    nt = -(-U // bu)
    adv = bu * stride
    slab = slab_rows(bu, kh, stride=stride)
    rows_needed = (nt - 1) * adv + slab
    row_pad = max(rows_needed - Hp, 0)
    b = B if B is not None else nb
    Bp = b + (-b) % nb
    blocks = {
        "x": ((nb, slab, Wp, C), (Bp, Hp + row_pad, Wp, C), "float32"),
        "B_tap_packed": ((m, T, c8), (m, T, c8), "uint8"),
        "alpha": ((m, C), (m, C), "float32"),
        "bias": ((1, C), (1, C), "float32"),
        "out": ((nb, bu, V, C), (Bp, nt * bu, V, C), "float32"),
    }
    return {"blocks": blocks, "grid": (Bp // nb, nt),
            "padded_rows": Hp + row_pad, "slab": slab, "adv": adv, "nt": nt}


def pick_bu_dw(H: int, W: int, C: int, kh: int, kw: int,
               budget_bytes: int = DEFAULT_VMEM_BUDGET, *,
               stride: int = 1, m: int = 1, nb: int = 1) -> int:
    """Largest dw row tile (output rows per program) fitting the budget at a
    fixed batch tile ``nb``."""
    _note_plan_pick()
    U = (H - kh) // stride + 1
    for bu in range(max(U, 1), 1, -1):
        if tile_vmem_bytes_dw(W, C, kh, kw, bu=bu, stride=stride,
                              m=m, nb=nb) <= budget_bytes:
            return bu
    return 1


def pick_tile_dw(B: int, H: int, W: int, C: int, kh: int, kw: int,
                 budget_bytes: int = DEFAULT_VMEM_BUDGET, *,
                 stride: int = 1, m: int = 1,
                 nb_cap: int = 8) -> tuple[int, int]:
    """Co-pick the (NB, BU) tile for the fused dw kernel.

    Row-tiled maps (whole image over budget) keep NB=1; whole-image maps
    grow NB while the working set fits the budget, capped at ``nb_cap``
    (the VPU has no 128-row dimension to fill — past a handful of images
    the unpack/dispatch amortization has flattened out).
    """
    _note_plan_pick()
    U = (H - kh) // stride + 1
    bu = pick_bu_dw(H, W, C, kh, kw, budget_bytes, stride=stride, m=m)
    if bu < max(U, 1) or B <= 1:
        return 1, bu
    nb = 1
    while nb < min(B, nb_cap) and tile_vmem_bytes_dw(
            W, C, kh, kw, bu=bu, stride=stride, m=m,
            nb=nb + 1) <= budget_bytes:
        nb += 1
    return nb, bu


def _dw_kernel(x_ref, bp_ref, alpha_ref, bias_ref, o_ref, *,
               kh: int, kw: int, C: int, stride: int, nb: int,
               u_tile: int, V: int, m_active: int, relu: bool):
    """One (NB images, BU rows) tile: fold levels, tap-accumulate, epilogue."""
    x = x_ref[...].astype(jnp.float32)               # [nb, slab, Wp, C]
    T, c8 = bp_ref.shape[1], bp_ref.shape[2]
    # fold the level sum into one effective fp tap weight per (tap, channel):
    # W_hat[t, c] = sum_{m < m_active} alpha[m, c] * B[m, t, c]  (Eq. 1)
    shifts = jax.lax.broadcasted_iota(jnp.uint8, (1, T, c8, 8), 3)
    bits = (bp_ref[...][:, :, :, None] >> shifts) & jnp.uint8(1)
    w = (bits.astype(jnp.int8) * 2 - 1).reshape(m_active, T, c8 * 8)
    w = w[:, :, :C].astype(jnp.float32)              # [m, T, C] ±1
    eff = jnp.sum(w * alpha_ref[...][:, None, :], axis=0)     # [T, C]
    # channel-wise tap accumulation on the VPU (no contraction to feed MXU)
    acc = jnp.zeros((nb, u_tile, V, C), jnp.float32)
    for i in range(kh):
        for j in range(kw):
            xs = x[:, i: i + (u_tile - 1) * stride + 1: stride,
                   j: j + (V - 1) * stride + 1: stride, :]
            acc = acc + xs * eff[i * kw + j][None, None, None, :]
    y = acc + bias_ref[0][None, None, :]
    if relu:
        y = jnp.maximum(y, 0.0)
    o_ref[...] = y


@functools.partial(
    jax.jit,
    static_argnames=("kh", "kw", "stride", "m_active", "relu", "bu", "nb",
                     "vmem_budget", "interpret"),
)
def binary_dwconv2d_pallas(
    x: jax.Array,
    B_tap_packed: jax.Array,
    alpha: jax.Array,
    bias: jax.Array,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    m_active: int | None = None,
    relu: bool = True,
    bu: int | None = None,
    nb: int | None = None,
    vmem_budget: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Fused binary depth-wise conv + bias + ReLU.  fp32 output.

    x:            [B, Hp, Wp, C]  (already padded for SAME by the caller)
    B_tap_packed: [M, kh*kw, ceil(C/8)] uint8  (see pack_dw_taps)
    alpha:        [M, C] float   (channel-wise, paper §V-A3 / D_arch=1)
    bias:         [C] float
    returns       [B, U, V, C] float32, U = (Hp-kh)//stride + 1.

    ``nb``/``bu`` fix the batch/row tile; leaving both None co-picks them
    via :func:`pick_tile_dw` (giving ``bu`` alone keeps per-image blocking).
    Every (nb, bu) tiling is bit-identical.
    """
    B, Hp, Wp, C = x.shape
    M, T, c8 = B_tap_packed.shape
    assert T == kh * kw, (T, kh, kw)
    assert c8 * 8 >= C, (c8, C)
    assert alpha.shape == (M, C), (alpha.shape, M, C)
    m_active = min(m_active or M, M)
    U = (Hp - kh) // stride + 1
    V = (Wp - kw) // stride + 1

    budget = vmem_budget or DEFAULT_VMEM_BUDGET
    if nb is None and bu is None:
        nb, bu = pick_tile_dw(B, Hp, Wp, C, kh, kw, budget,
                              stride=stride, m=m_active)
    elif nb is None:
        nb = 1  # explicit BU: per-image row tiling (the pre-batch semantics)
    elif bu is None:
        bu = pick_bu_dw(Hp, Wp, C, kh, kw, budget, stride=stride,
                        m=m_active, nb=max(1, min(nb, B)))
    nb = max(1, min(nb, B))
    bu = max(1, min(bu, U))
    nt = -(-U // bu)
    adv = bu * stride
    slab = slab_rows(bu, kh, stride=stride)
    rows_needed = (nt - 1) * adv + slab
    b_rem = (-B) % nb                       # ragged batch / ragged last row
    row_pad = max(rows_needed - Hp, 0)      # tile: zero pad, sliced off below
    if b_rem or row_pad:
        x = jnp.pad(x, ((0, b_rem), (0, row_pad), (0, 0), (0, 0)))
    Bp = B + b_rem

    bp = B_tap_packed[:m_active]
    alpha = alpha[:m_active].astype(jnp.float32)
    bias2 = bias.astype(jnp.float32).reshape(1, C)

    grid = (Bp // nb, nt)
    out = pl.pallas_call(
        functools.partial(
            _dw_kernel, kh=kh, kw=kw, C=C, stride=stride, nb=nb,
            u_tile=bu, V=V, m_active=m_active, relu=relu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((nb, slab, Wp, C),
                         lambda b, t: (b * nb, t * adv, 0, 0),
                         indexing_mode=pl.Unblocked()),
            pl.BlockSpec((m_active, T, c8), lambda b, t: (0, 0, 0)),
            pl.BlockSpec((m_active, C), lambda b, t: (0, 0)),
            pl.BlockSpec((1, C), lambda b, t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((nb, bu, V, C), lambda b, t: (b, t, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, nt * bu, V, C), jnp.float32),
        interpret=interpret,
    )(x, bp, alpha, bias2)
    return out[:B, :U]
