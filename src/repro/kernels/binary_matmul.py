"""Pallas TPU kernel: multi-level binary matmul (the BinArray SA, re-thought).

The FPGA systolic array computes, per output channel d and level m,
    p_{d,m} = sum_i b_{i,m} * x_i            (PE: sign-change + accumulate)
    o_d     = sum_m alpha_{d,m} * p_{d,m}    (PA: one DSP, time-multiplexed)

On TPU the MXU *is* the systolic array.  What we keep from the paper is the
storage format — M× 1-bit weights + per-(level, group) scales — and the
computation order: per K-tile, unpack the packed bits to ±1 in VMEM, run one
MXU matmul per level, and apply the alpha scaling as a VPU epilogue while
accumulating in fp32 (the MULW=28 accumulator analogue, strictly wider).

Packed weight layout (``B_packed``, produced by ``core.binarize.pack_bits``)
----------------------------------------------------------------------------
``B_packed[m, k8, n]`` is a uint8 holding reduction rows ``8*k8 .. 8*k8+7``
of level m's ±1 matrix for output channel n, **LSB-first**:

    bit j of B_packed[m, k8, n]  ==  1  iff  B_m[8*k8 + j, n] == +1
    (so +1 -> bit 1, -1 -> bit 0;  row index = 8*k8 + j, j = 0..7)

K is padded up to a byte boundary *upstream* (``core.binarize.pack``/
``binlinear.binarize_params`` append +1 rows); the padding rows are
harmless because the matching x columns are zero.  Scales live separately
as ``alpha[M, G, N]`` fp32 with ``G = K / group_size`` groups along the
reduction axis (G == 1 is the paper's per-output-channel scheme).

VMEM blocking (BlockSpec, all multiples of MXU-friendly sizes)
--------------------------------------------------------------
    x        [T, K]            -> blocks [BT, BK]
    B_packed [M, K/8, N] uint8 -> blocks [m_active, BK/8, BN]
    alpha    [M, G, N]         -> blocks [m_active, 1, BN]   (G = K/group_size)
    out      [T, N] f32        -> blocks [BT, BN]

Grid: (T/BT, N/BN, K/BK) with the K dimension innermost ("arbitrary"
sequential), accumulating into the output block; alpha's group index is
derived from the K block index (requires group_size % BK == 0 or BK == K —
otherwise ops.py falls back to the single-K-block mode where the whole
padded K is one block and alpha is folded into the unpacked weights per
row).  Per-tile VMEM working set (fp32 x, defaults BT=BN=128, BK=256,
M=2): ``BT*BK*4 + M*(BK/8)*BN + BT*BN*4`` ≈ 128 KiB + 8 KiB + 64 KiB —
comfortably inside one core's ~16 MiB, leaving headroom for double
buffering; ``benchmarks/kernel_bench.py tile_stats`` prints the same
formula per candidate block shape.

The per-level unpack costs BK/8 * BN uint8 VMEM loads per (BK x BN) tile —
1/16 the bytes of a bf16 weight tile, which is exactly the paper's
compression-factor win (Eq. 6) applied to the HBM->VMEM stream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def tile_vmem_bytes_mm(bt: int, bn: int, bk: int, *, m: int = 1) -> int:
    """Analytic per-tile VMEM working set of the matmul kernel: fp32 x block
    + bit-packed weight block + fp32 accumulator (module docstring formula).
    Shared by the deploy compiler's LayerStats and repro.analysis."""
    return bt * bk * 4 + m * (bk // 8) * bn + bt * bn * 4


def matmul_block_shapes(T: int, K: int, N: int, *, bt: int, bn: int, bk: int,
                        m: int = 1, G: int = 1,
                        group_size: int | None = None) -> tuple[dict, int]:
    """The exact BlockSpec geometry ``binary_matmul_pallas`` builds for a
    block plan, plus the *effective* bk (the kernel silently overrides bk to
    the whole padded K when grouped alpha boundaries cannot align with the
    K tiles).  Returns ``({operand: (block_shape, padded_array_shape,
    dtype)}, effective_bk)`` — consumed by ``repro.analysis``."""
    K8 = -(-K // 8)
    group_size = group_size or (K // max(G, 1))
    full_groups = G > 1 and group_size % bk != 0
    if full_groups:
        bk = K8 * 8
    K_pad = K8 * 8
    Kp = K_pad + (-K_pad) % bk
    Tp = T + (-T) % bt
    Np = N + (-N) % bn
    alpha_block = (m, G, bn) if full_groups else (m, 1, bn)
    blocks = {
        "x": ((bt, bk), (Tp, Kp), "float32"),
        "B_packed": ((m, bk // 8, bn), (m, Kp // 8, Np), "uint8"),
        "alpha": (alpha_block, (m, G, Np), "float32"),
        "out": ((bt, bn), (Tp, Np), "float32"),
    }
    return blocks, bk


def _kernel(x_ref, bp_ref, alpha_ref, o_ref, *, m_active: int, n_k_blocks: int,
            full_groups_size: int = 0):
    """One (BT, BN) output tile; invoked n_k_blocks times along the K grid.

    ``full_groups_size > 0`` selects the single-K-block grouped-alpha mode:
    the whole (padded) K lives in one block and alpha arrives as [M, G, BN],
    applied per K row by folding it into the unpacked ±1 weights.  This is
    the legal path for group sizes that are not multiples of 8 (no packed
    K-tile boundary can align with the group boundaries then).
    """
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xb = x_ref[...].astype(jnp.float32)           # [BT, BK]
    acc = jnp.zeros(o_ref.shape, jnp.float32)     # [BT, BN]
    bk8 = bp_ref.shape[1]
    shifts = jax.lax.broadcasted_iota(jnp.uint8, (bk8, 8, 1), 1)
    for m in range(m_active):                     # static unroll over levels
        packed = bp_ref[m]                        # [BK/8, BN] uint8
        bits = (packed[:, None, :] >> shifts) & jnp.uint8(1)
        bpm = (bits.astype(jnp.int8) * 2 - 1).reshape(-1, packed.shape[-1])
        if full_groups_size:
            a = alpha_ref[m]                      # [G, BN]
            G, bn = a.shape
            a_exp = jnp.broadcast_to(
                a[:, None, :], (G, full_groups_size, bn)
            ).reshape(G * full_groups_size, bn)
            kp = bpm.shape[0]
            if kp > G * full_groups_size:         # 8-padding rows (x is zero)
                a_exp = jnp.pad(a_exp, ((0, kp - G * full_groups_size), (0, 0)))
            acc = acc + jax.lax.dot_general(
                xb, bpm.astype(jnp.float32) * a_exp,
                dimension_numbers=(((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
        else:
            p = jax.lax.dot_general(
                xb, bpm.astype(jnp.float32),
                dimension_numbers=(((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )                                     # [BT, BN]
            acc = acc + alpha_ref[m, 0, :][None, :] * p
    o_ref[...] = o_ref[...] + acc


@functools.partial(
    jax.jit,
    static_argnames=("K", "group_size", "m_active", "bt", "bn", "bk", "interpret"),
)
def binary_matmul_pallas(
    x: jax.Array,
    B_packed: jax.Array,
    alpha: jax.Array,
    *,
    K: int,
    group_size: int,
    m_active: int | None = None,
    bt: int = 128,
    bn: int = 128,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """y[T, N] = sum_m alpha_m ⊙ (x @ B_m) over bit-packed B.  fp32 output.

    Pads T/N/K to block multiples; K-padding is safe because padded x columns
    are zero.  Grouped alpha (G > 1) wants ``group_size % bk == 0`` (group
    boundaries align with K tiles); when group_size is not a multiple of 8
    that is impossible and the kernel switches to a single-K-block mode that
    folds alpha into the unpacked weights per row.  The ops.py wrapper picks
    a legal bk automatically.
    """
    T, Kx = x.shape
    M, K8, N = B_packed.shape
    assert Kx == K, (Kx, K)
    m_active = min(m_active or M, M)  # can't apply more levels than packed
    G = alpha.shape[1]
    assert G * group_size == K, (G, group_size, K)
    # Grouped alpha needs K-tile boundaries aligned to group boundaries; when
    # that's impossible (group_size not a multiple of bk) the whole K must fit
    # in a single block and alpha is folded in per K row inside the kernel.
    full_groups = G > 1 and group_size % bk != 0
    if full_groups:
        bk = K8 * 8                      # single K block, multiple of 8

    K_pad = K8 * 8
    # pad x's K to K_pad (packed buffer is already padded)
    if K_pad != K:
        x = jnp.pad(x, ((0, 0), (0, K_pad - K)))
    # pad K_pad to a multiple of bk
    k_rem = (-K_pad) % bk
    if k_rem:
        x = jnp.pad(x, ((0, 0), (0, k_rem)))
        B_packed = jnp.pad(B_packed, ((0, 0), (0, k_rem // 8), (0, 0)))
    Kp = K_pad + k_rem
    t_rem = (-T) % bt
    if t_rem:
        x = jnp.pad(x, ((0, t_rem), (0, 0)))
    n_rem = (-N) % bn
    if n_rem:
        B_packed = jnp.pad(B_packed, ((0, 0), (0, 0), (0, n_rem)))
        alpha = jnp.pad(alpha, ((0, 0), (0, 0), (0, n_rem)))
    Tp, Np = T + t_rem, N + n_rem

    B_packed = B_packed[:m_active]
    alpha = alpha[:m_active].astype(jnp.float32)
    n_k_blocks = Kp // bk
    grid = (Tp // bt, Np // bn, n_k_blocks)

    # group index of K-block k: (k * bk) // group_size  (static ints)
    def alpha_idx(t, n, k):
        return (0, (k * bk) // group_size if G > 1 else 0, n)

    if full_groups:
        alpha_spec = pl.BlockSpec((m_active, G, bn), lambda t, n, k: (0, 0, n))
    else:
        alpha_spec = pl.BlockSpec((m_active, 1, bn), alpha_idx)
    out = pl.pallas_call(
        functools.partial(_kernel, m_active=m_active, n_k_blocks=n_k_blocks,
                          full_groups_size=group_size if full_groups else 0),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bk), lambda t, n, k: (t, k)),
            pl.BlockSpec((m_active, bk // 8, bn), lambda t, n, k: (0, k, n)),
            alpha_spec,
        ],
        out_specs=pl.BlockSpec((bt, bn), lambda t, n, k: (t, n)),
        out_shape=jax.ShapeDtypeStruct((Tp, Np), jnp.float32),
        interpret=interpret,
    )(x, B_packed, alpha)
    return out[:T, :N]
