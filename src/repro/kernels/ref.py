"""Pure-jnp oracles for every Pallas kernel in this package.

These are the "bit-accurate Python model" of the paper's verification setup
(§V-A2, Fig. 11): the Pallas kernels must match these references to fp32
accumulation accuracy across shape/dtype sweeps (tests/test_kernels.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import binarize as bz


def binary_matmul_ref(
    x: jax.Array,
    B_packed: jax.Array,
    alpha: jax.Array,
    *,
    K: int,
    group_size: int,
    m_active: int | None = None,
) -> jax.Array:
    """y = sum_{m<m_active} alpha_m ⊙ (x @ B_m)   (paper Eq. 8, grouped alpha).

    x:        [..., K]  (any float dtype)
    B_packed: [M, K_pad//8, N] uint8   (K_pad = 8*ceil(K/8))
    alpha:    [M, G, N] float          (G = K // group_size)
    returns   [..., N] float32
    """
    M, K8, N = B_packed.shape
    m = min(m_active or M, M)  # §IV-D: can't apply more levels than packed
    K_pad = K8 * 8
    B = bz.unpack_bits(B_packed[:m], K_pad)[:, :K, :].astype(jnp.float32)
    G = K // group_size
    xf = x.astype(jnp.float32)
    lead = xf.shape[:-1]
    xg = xf.reshape(*lead, G, group_size)
    Bg = B.reshape(m, G, group_size, N)
    # per-(level, group) partial sums, then alpha-weighted reduction:
    p = jnp.einsum("...gk,mgkn->...mgn", xg, Bg)
    y = jnp.einsum("...mgn,mgn->...n", p, alpha[:m].astype(jnp.float32))
    return y


def binary_matmul_dense_equiv(
    x: jax.Array, approx: bz.BinApprox, m_active: int | None = None
) -> jax.Array:
    """Same computation via explicit W_hat reconstruction (identity check)."""
    m = m_active or approx.M
    sub = bz.BinApprox(B=approx.B[:m], alpha=approx.alpha[:m],
                       group_size=approx.group_size)
    return x.astype(jnp.float32) @ bz.reconstruct(sub)


def fused_binary_matmul_relu_pool_ref(
    x: jax.Array,
    B_packed: jax.Array,
    alpha: jax.Array,
    *,
    K: int,
    group_size: int,
    pool: int = 1,
    m_active: int | None = None,
) -> jax.Array:
    """Binary matmul + AMU epilogue (paper §III-B): max-pool over ``pool``
    consecutive rows then ReLU — using max(y, 0) over the window, which equals
    ReLU∘maxpool by commutativity (paper Eq. 13).

    x: [T, K] with T % pool == 0 -> [T//pool, N].
    """
    y = binary_matmul_ref(x, B_packed, alpha, K=K, group_size=group_size,
                          m_active=m_active)
    T, N = y.shape
    y = y.reshape(T // pool, pool, N)
    return jnp.maximum(jnp.max(y, axis=1), 0.0)


def fused_binary_conv_relu_pool_ref(
    x: jax.Array,
    B_packed: jax.Array,
    alpha: jax.Array,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    padding: str = "VALID",
    pool: int = 1,
    m_active: int | None = None,
    bias: jax.Array | None = None,
    relu: bool = True,
) -> jax.Array:
    """Conv oracle for the fused implicit-GEMM kernel: explicit im2col +
    binary matmul (Eq. 8) + bias + 2D max-pool + ReLU (AMU, Eq. 13).

    x: [B, H, W, C]; B_packed is the *flat* [M, ceil(K/8), D] layout
    (K = kh*kw*C) — the reference deliberately exercises the HBM-materialized
    path the Pallas kernel eliminates.  Returns [B, U//pool, V//pool, D] f32.
    """
    from repro.core import binconv

    patches = binconv.im2col(x, kh, kw, stride, padding)
    K = patches.shape[-1]
    group_size = K // alpha.shape[1]
    y = binary_matmul_ref(patches, B_packed, alpha, K=K,
                          group_size=group_size, m_active=m_active)
    if bias is not None:
        y = y + bias.astype(y.dtype)
    B, U, V, D = y.shape
    y = y.reshape(B, U // pool, pool, V // pool, pool, D).max(axis=(2, 4))
    return jnp.maximum(y, 0.0) if relu else y


def binary_dwconv_relu_ref(
    x: jax.Array,
    B_tap_packed: jax.Array,
    alpha: jax.Array,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    padding: str = "SAME",
    m_active: int | None = None,
    bias: jax.Array | None = None,
    relu: bool = True,
) -> jax.Array:
    """±1 oracle for the fused depth-wise kernel (kernels/binary_dwconv.py).

    Unpacks the channel-packed ``[M, kh*kw, ceil(C/8)]`` taps to ±1,
    reconstructs the effective depth-wise filter W_hat[t, c] =
    sum_{m<m_active} alpha[m, c] * B[m, t, c] (paper Eq. 1, channel-wise
    §V-A3), and runs it through fp ``lax.conv`` with feature groups — the
    exact HBM-bound path the Pallas kernel replaces.  x: [B, H, W, C] ->
    [B, U, V, C] float32.
    """
    from repro.kernels.binary_dwconv import unpack_dw_taps

    C = x.shape[-1]
    M = B_tap_packed.shape[0]
    m = min(m_active or M, M)
    B = unpack_dw_taps(B_tap_packed[:m], C).astype(jnp.float32)  # [m, T, C]
    W_hat = jnp.einsum("mtc,mc->tc", B, alpha[:m].astype(jnp.float32))
    W_hat = W_hat.reshape(kh, kw, 1, C)          # HWIO, depth-wise groups
    y = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), W_hat, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=C)
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return jnp.maximum(y, 0.0) if relu else y
