"""Public jit'd wrappers around the Pallas kernels.

``binary_matmul`` flattens leading dims, picks legal block sizes for the
actual problem shape, and routes to the Pallas kernel (TPU, or interpret=True
for CPU validation).  The dry-run / pure-XLA path uses kernels/ref.py instead
(see repro.core.binlinear).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import binary_matmul as bmk


def _pick_block(dim: int, preferred: int) -> int:
    """Largest power-of-two block <= preferred that keeps padding sane."""
    b = preferred
    while b > dim and b > 8:
        b //= 2
    return max(b, 8)


def binary_matmul(
    x: jax.Array,
    B_packed: jax.Array,
    alpha: jax.Array,
    *,
    K: int,
    group_size: int,
    m_active: int | None = None,
    interpret: bool = False,
    bt: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
) -> jax.Array:
    """y[..., N] = sum_m alpha_m ⊙ (x[..., K] @ B_m);  fp32 accumulate."""
    lead = x.shape[:-1]
    T = 1
    for d in lead:
        T *= d
    x2 = x.reshape(T, K)
    M, K8, N = B_packed.shape

    bt = bt or _pick_block(T, 128)
    bn = bn or _pick_block(N, 128)
    # bk must divide group_size (or G == 1); cap at 256 for VMEM
    if alpha.shape[1] == 1:
        bk = bk or _pick_block(K8 * 8, 256)
    else:
        bk = bk or _pick_block(group_size, 256)
        while group_size % bk and bk > 8:
            bk //= 2
    y = bmk.binary_matmul_pallas(
        x2, B_packed, alpha, K=K, group_size=group_size,
        m_active=m_active, bt=bt, bn=bn, bk=bk, interpret=interpret,
    )
    return y.reshape(*lead, N).astype(x.dtype) if x.dtype != jnp.float32 else y.reshape(*lead, N)
