"""Public jit'd wrappers around the Pallas kernels.

``binary_matmul`` flattens leading dims, picks legal block sizes for the
actual problem shape, and routes to the Pallas kernel (TPU, or interpret=True
for CPU validation).  ``binary_conv2d`` does the same for the fused
implicit-GEMM conv kernel (SAME padding resolved here, so the kernel only
ever sees pre-padded inputs).  The dry-run / pure-XLA path uses
kernels/ref.py instead (see repro.core.binlinear / repro.core.binconv).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import binary_conv as bck
from repro.kernels import binary_matmul as bmk


def _pick_block(dim: int, preferred: int) -> int:
    """Largest power-of-two block <= preferred that keeps padding sane."""
    bck._note_plan_pick()
    b = preferred
    while b > dim and b > 8:
        b //= 2
    return max(b, 8)


def pick_matmul_plan(T: int, K: int, N: int, *, G: int,
                     group_size: int) -> tuple[int, int, int]:
    """The (bt, bn, bk) block plan ``binary_matmul`` auto-picks for a
    ``[T, K] @ [K, N]`` binary matmul with G alpha groups.

    Exported so the deploy compiler (repro/deploy) can freeze the *same*
    blocks at compile time that the per-call path would pick — identical
    blocks mean an identical K-reduction order, which is what makes
    compiled-program execution bit-exact against the legacy path.
    """
    K8 = -(-K // 8)
    bt = _pick_block(T, 128)
    # Lane legality (analysis/mosaic_rules.py `mosaic-lane`): a block's last
    # dim must be a multiple of 128 lanes *or* cover the whole padded array
    # dim — Mosaic pads a lone sub-128-lane array transparently, but several
    # sub-128 blocks violate the register tiling.  So below 128 we take one
    # block over the whole 8-aligned padded dim instead of a smaller
    # power of two.  bn only re-blocks output columns (never the K reduction
    # order), so this stays bit-exact against any other bn.
    bn = _pick_block(N, 128)
    if bn < 128:
        bn = -(-N // 8) * 8
    # bk must divide group_size (or G == 1); cap at 256 for VMEM
    if G == 1:
        bk = _pick_block(K8 * 8, 256)
        if bk < 128:
            bk = K8 * 8          # whole padded K in one (legal) block
    elif group_size % 8 == 0:
        bk = _pick_block(group_size, 256)
        while group_size % bk and bk > 8:
            bk //= 2  # terminates at a legal divisor: 8 | group_size
    else:
        # group_size % 8 != 0: no multiple-of-8 K tile can align with group
        # boundaries, so take the kernel's single-block grouped-alpha path
        # (whole padded K in one block, alpha folded in per row).
        bk = K8 * 8
    return bt, bn, bk


def binary_matmul(
    x: jax.Array,
    B_packed: jax.Array,
    alpha: jax.Array,
    *,
    K: int,
    group_size: int,
    m_active: int | None = None,
    interpret: bool = False,
    bt: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
) -> jax.Array:
    """y[..., N] = sum_m alpha_m ⊙ (x[..., K] @ B_m);  fp32 accumulate."""
    lead = x.shape[:-1]
    T = 1
    for d in lead:
        T *= d
    x2 = x.reshape(T, K)
    M, K8, N = B_packed.shape

    if bt is None or bn is None or bk is None:
        pbt, pbn, pbk = pick_matmul_plan(T, K, N, G=alpha.shape[1],
                                         group_size=group_size)
        bt, bn, bk = bt or pbt, bn or pbn, bk or pbk
    y = bmk.binary_matmul_pallas(
        x2, B_packed, alpha, K=K, group_size=group_size,
        m_active=m_active, bt=bt, bn=bn, bk=bk, interpret=interpret,
    )
    return y.reshape(*lead, N).astype(x.dtype) if x.dtype != jnp.float32 else y.reshape(*lead, N)


def binary_conv2d(
    x: jax.Array,
    B_tap_packed: jax.Array,
    alpha: jax.Array,
    bias: jax.Array,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    padding: str = "VALID",
    pool: int = 1,
    m_active: int | None = None,
    relu: bool = True,
    interpret: bool = False,
    bd: int | None = None,
    bu: int | None = None,
    nb: int | None = None,
    vmem_budget: int | None = None,
) -> jax.Array:
    """Fused binary conv + bias + max-pool + ReLU via the Pallas kernel.

    x: [B, H, W, C] -> [B, U//pool, V//pool, D] in fp32.  The im2col tensor
    never touches HBM (patch extraction runs in VMEM inside the kernel).
    ``nb``/``bu`` fix the batch tile (images folded into the GEMM row dim
    per program) and the output row tile; leaving both None co-picks them
    from the VMEM budget (kernels/binary_conv.py pick_tile) — NB grows on
    small late-layer maps until the MXU row dim saturates, big maps keep
    NB=1 and row-tile.
    """
    from repro.core.binconv import same_pads

    B, H, W, C = x.shape
    if padding == "SAME":
        x = jnp.pad(x, ((0, 0), same_pads(H, kh, stride),
                        same_pads(W, kw, stride), (0, 0)))
    elif padding != "VALID":
        raise ValueError(padding)
    K = kh * kw * C
    group_size = K // alpha.shape[1]
    D = alpha.shape[-1]
    return bck.binary_conv2d_pallas(
        x, B_tap_packed, alpha, bias,
        kh=kh, kw=kw, stride=stride, pool=pool, group_size=group_size,
        m_active=m_active, relu=relu, bd=bd or _pick_block(D, 128),
        bu=bu, nb=nb, vmem_budget=vmem_budget, interpret=interpret,
    )


def binary_dwconv2d(
    x: jax.Array,
    B_tap_packed: jax.Array,
    alpha: jax.Array,
    bias: jax.Array,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    padding: str = "SAME",
    m_active: int | None = None,
    relu: bool = True,
    interpret: bool = False,
    bu: int | None = None,
    nb: int | None = None,
    vmem_budget: int | None = None,
) -> jax.Array:
    """Fused binary depth-wise conv + bias + ReLU via the Pallas kernel.

    x: [B, H, W, C] -> [B, U, V, C] fp32 (paper §V-A3: depth-wise layers are
    approximated channel-wise; D_arch = 1).  SAME padding is resolved here
    like :func:`binary_conv2d`, so the kernel only sees pre-padded inputs;
    ``nb``/``bu`` tile the batch/row dims (None = pick_tile_dw co-pick).
    """
    from repro.core.binconv import same_pads
    from repro.kernels import binary_dwconv as bdw

    B, H, W, C = x.shape
    if padding == "SAME":
        x = jnp.pad(x, ((0, 0), same_pads(H, kh, stride),
                        same_pads(W, kw, stride), (0, 0)))
    elif padding != "VALID":
        raise ValueError(padding)
    return bdw.binary_dwconv2d_pallas(
        x, B_tap_packed, alpha, bias,
        kh=kh, kw=kw, stride=stride, m_active=m_active, relu=relu,
        bu=bu, nb=nb, vmem_budget=vmem_budget, interpret=interpret,
    )
