"""Fault-tolerant checkpointing: sharded npz + manifest, atomic, verified.

Design (for 1000+ node deployments, exercised here on 1 host):
  * Each host writes only the leaves (or leaf-shards) it owns to
    ``step_<N>/host_<id>.npz``; a JSON manifest records the tree structure,
    dtypes, global shapes, per-leaf CRC32 content digests, a whole-manifest
    digest, and data-pipeline state.
  * Writes are atomic AND overwrite-safe: temp dir -> fsync -> rename-aside
    the old step -> rename the new dir in (the commit point) -> delete the
    displaced copy.  A crash at any instant leaves either the old or the new
    step intact; ``__init__`` scrubs the two orphan classes a crash can
    leave behind (``.tmp_ckpt_*`` pre-commit temps, ``.displaced_step_*``
    set-aside copies).
  * Restore VERIFIES: every leaf is re-hashed against the manifest digest
    (``ChecksumMismatch`` names the leaf, both digests, and the step), the
    manifest is re-hashed against its own recorded digest
    (``ManifestMismatch``), and loaded shape/dtype must match both the
    manifest and the restore target (``LeafMismatch`` — no silent
    ``astype``; pass ``allow_cast=True`` for an explicit conversion).
  * ``restore_latest_good`` walks steps newest-first, QUARANTINES failing
    steps (renamed to ``quarantine_step_<N>/`` with a JSON reason ledger,
    never deleted) and returns the first step that passes every check plus
    the caller's ``validate`` hook; ``NoGoodCheckpoint`` when the walk
    exhausts.
  * Restore is RESHARD-SAFE: arrays are loaded as full values and committed
    to whatever sharding the restoring job requests (jax.device_put with the
    new sharding), so a job restarted on a different mesh/device count
    (elastic scaling) restores transparently.
"""
from __future__ import annotations

import contextlib
import json
import os
import shutil
import tempfile
import zipfile
import zlib

import jax
import jax.numpy as jnp
import numpy as np

_TMP_PREFIX = ".tmp_ckpt_"
_DISPLACED_PREFIX = ".displaced_"
_QUARANTINE_PREFIX = "quarantine_"


def crc32_hex(data: bytes) -> str:
    """CRC32 of ``data`` as a fixed-width lowercase hex string."""
    return f"{zlib.crc32(data) & 0xFFFFFFFF:08x}"


def _manifest_digest(meta: dict) -> str:
    doc = {k: v for k, v in meta.items() if k != "manifest_crc32"}
    return crc32_hex(json.dumps(doc, sort_keys=True).encode())


class CheckpointCorruption(RuntimeError):
    """A checkpoint step cannot be trusted (digest, structure, or IO)."""

    def __init__(self, message: str, *, step: int | None = None):
        super().__init__(message)
        self.step = step


class ChecksumMismatch(CheckpointCorruption):
    """A leaf's bytes no longer hash to the digest recorded at save time."""

    def __init__(self, message: str, *, step: int | None, leaf: str,
                 expected: str, actual: str):
        super().__init__(message, step=step)
        self.leaf = leaf
        self.expected = expected
        self.actual = actual


class ManifestMismatch(CheckpointCorruption):
    """The manifest itself no longer hashes to its recorded digest."""

    def __init__(self, message: str, *, step: int | None, expected: str,
                 actual: str):
        super().__init__(message, step=step)
        self.expected = expected
        self.actual = actual


class LeafMismatch(CheckpointCorruption):
    """Loaded leaf shape/dtype disagrees with the manifest or the target."""

    def __init__(self, message: str, *, step: int | None, leaf: str):
        super().__init__(message, step=step)
        self.leaf = leaf


class NoGoodCheckpoint(RuntimeError):
    """``restore_latest_good`` exhausted every step without success."""


def _flatten_with_paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        # DictKey -> .key, SequenceKey -> .idx, GetAttrKey (custom pytree
        # nodes, e.g. deploy.BinArrayProgram instructions) -> .name
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        out[key] = leaf
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, host_id: int = 0,
                 n_hosts: int = 1, scrub: bool = True):
        self.dir = directory
        self.keep = keep
        self.host_id = host_id
        self.n_hosts = n_hosts
        #: (step, reason) for every step this manager quarantined.
        self.quarantined: list[tuple[int, str]] = []
        os.makedirs(directory, exist_ok=True)
        if scrub:
            self._scrub_orphans()

    def _scrub_orphans(self):
        """Clean up after crashed saves (see the commit protocol in save).

        ``.tmp_ckpt_*``: a save died before its commit rename — nothing was
        displaced, so the temp is garbage.  ``.displaced_step_*``: a save
        died *between* renaming the old step aside and committing the new
        one — the displaced dir holds the last intact copy of that step, so
        it is restored unless the commit actually landed.
        """
        for d in sorted(os.listdir(self.dir)):
            path = os.path.join(self.dir, d)
            if d.startswith(_TMP_PREFIX):
                shutil.rmtree(path, ignore_errors=True)
            elif d.startswith(_DISPLACED_PREFIX):
                orig = d[len(_DISPLACED_PREFIX):].rsplit("_", 1)[0]
                dest = os.path.join(self.dir, orig)
                if os.path.exists(os.path.join(dest, "manifest.json")):
                    shutil.rmtree(path, ignore_errors=True)  # commit landed
                else:
                    shutil.rmtree(dest, ignore_errors=True)  # partial commit
                    os.rename(path, dest)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def _manifest_name(self) -> str:
        """Single-host steps keep the historical ``manifest.json``; with
        ``n_hosts > 1`` each host owns ``manifest_host_<id>.json`` so hosts
        never write the same file."""
        if self.n_hosts <= 1:
            return "manifest.json"
        return f"manifest_host_{self.host_id}.json"

    # ------------------------------------------------------------- save ---
    def save(self, step: int, state: dict, *, extra: dict | None = None):
        """state: pytree of arrays.  extra: JSON-able (data pipeline etc.).

        Single-host saves commit the whole step dir with the
        rename-aside/rename-in protocol below.  Multi-host saves
        (``n_hosts > 1``) can't: the step dir is SHARED — each host instead
        stages its ``host_<id>.npz`` + ``manifest_host_<id>.json`` in a temp
        dir and merge-commits them with per-file atomic ``os.replace`` into
        the (possibly pre-existing) step dir, so concurrent hosts never
        displace each other's files and a crash leaves every other host's
        files intact.
        """
        flat, _ = _flatten_with_paths(state)
        step_dir = self._step_dir(step)
        tmp = tempfile.mkdtemp(dir=self.dir, prefix=_TMP_PREFIX)
        displaced = None
        try:
            arrays = {}
            meta = {"step": step, "host_id": self.host_id,
                    "n_hosts": self.n_hosts, "extra": extra or {},
                    "leaves": {}}
            for key, leaf in flat.items():
                host = np.asarray(jax.device_get(leaf))
                # ascontiguousarray promotes 0-d to (1,); keep scalar shapes
                arr = np.ascontiguousarray(host).reshape(host.shape)
                arrays[key.replace("/", "__")] = arr
                meta["leaves"][key] = {
                    "shape": list(arr.shape), "dtype": str(arr.dtype),
                    "crc32": crc32_hex(arr.tobytes())}
            meta["manifest_crc32"] = _manifest_digest(meta)
            np.savez(os.path.join(tmp, f"host_{self.host_id}.npz"), **arrays)
            with open(os.path.join(tmp, self._manifest_name()), "w") as f:
                json.dump(meta, f)
                f.flush()
                os.fsync(f.fileno())
            if self.n_hosts > 1:
                # merge commit: per-file atomic replace into the shared dir
                os.makedirs(step_dir, exist_ok=True)
                for name in sorted(os.listdir(tmp)):
                    os.replace(os.path.join(tmp, name),
                               os.path.join(step_dir, name))
                shutil.rmtree(tmp, ignore_errors=True)
            else:
                # Overwrite protocol: the old step is renamed aside (intact)
                # before the new dir is committed, so a crash between the
                # two renames loses nothing — __init__ recovers the
                # displaced copy.
                if os.path.exists(step_dir):
                    displaced = self._displaced_name(step_dir)
                    os.rename(step_dir, displaced)
                self._commit(tmp, step_dir)  # commit point
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            if displaced is not None and not os.path.exists(step_dir):
                with contextlib.suppress(OSError):
                    os.rename(displaced, step_dir)  # roll the old step back
                displaced = None
            raise
        if displaced is not None:
            shutil.rmtree(displaced, ignore_errors=True)
        if self.host_id == 0:
            self._gc()   # one host gc's; racing deletes corrupt live saves
        return step_dir

    def _displaced_name(self, step_dir: str) -> str:
        base = os.path.basename(step_dir)
        i = 0
        while True:
            cand = os.path.join(
                self.dir, f"{_DISPLACED_PREFIX}{base}_{i}")
            if not os.path.exists(cand):
                return cand
            i += 1

    def _commit(self, tmp: str, step_dir: str) -> None:
        """The commit rename, isolated so crash tests can fail it."""
        os.rename(tmp, step_dir)

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ---------------------------------------------------------- restore ---
    def all_steps(self) -> list[int]:
        """Committed steps, ascending.  Quarantined dirs are skipped (their
        names start with ``quarantine_``, not ``step_``).  A step counts as
        committed when any host's manifest landed (``manifest.json`` or
        ``manifest_host_<id>.json``)."""
        out = []
        for d in os.listdir(self.dir):
            if not d.startswith("step_"):
                continue
            path = os.path.join(self.dir, d)
            if os.path.exists(os.path.join(path, "manifest.json")) or any(
                    n.startswith("manifest_host_") and n.endswith(".json")
                    for n in os.listdir(path)):
                out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _read_step(self, step: int, *, verify: bool = True):
        """Load manifest + arrays for ``step``, verifying digests/shapes.

        Raises a typed ``CheckpointCorruption`` subclass on the first
        problem found; manifests written before digests existed (no
        ``crc32``/``manifest_crc32`` fields) are tolerated.
        """
        step_dir = self._step_dir(step)
        manifest = os.path.join(step_dir, self._manifest_name())
        if not os.path.exists(manifest) and self.n_hosts > 1:
            # a step saved single-host, restored under a multi-host manager
            manifest = os.path.join(step_dir, "manifest.json")
        if not os.path.exists(manifest):
            raise CheckpointCorruption(
                f"step {step}: {os.path.basename(manifest)} missing under "
                f"{step_dir}", step=step)
        try:
            with open(manifest) as f:
                meta = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            raise CheckpointCorruption(
                f"step {step}: unreadable manifest.json: {e}",
                step=step) from e
        npz = os.path.join(step_dir, f"host_{self.host_id}.npz")
        if not os.path.exists(npz):
            raise CheckpointCorruption(
                f"step {step}: host_{self.host_id}.npz missing under "
                f"{step_dir}", step=step)
        try:
            with np.load(npz) as z:
                data = {k: z[k] for k in z.files}
        except (OSError, ValueError, zipfile.BadZipFile) as e:
            raise CheckpointCorruption(
                f"step {step}: unreadable host_{self.host_id}.npz: {e}",
                step=step) from e
        if not verify:
            return meta, data
        recorded = meta.get("manifest_crc32")
        if recorded is not None:
            actual = _manifest_digest(meta)
            if actual != recorded:
                raise ManifestMismatch(
                    f"step {step}: manifest digest {actual} != recorded "
                    f"{recorded} (manifest tampered or torn)",
                    step=step, expected=recorded, actual=actual)
        for key, info in meta.get("leaves", {}).items():
            nkey = key.replace("/", "__")
            if nkey not in data:
                raise CheckpointCorruption(
                    f"step {step}: leaf {key!r} recorded in manifest but "
                    f"absent from npz", step=step)
            arr = data[nkey]
            if list(arr.shape) != list(info["shape"]) or \
                    str(arr.dtype) != info["dtype"]:
                raise LeafMismatch(
                    f"step {step}: leaf {key!r} loaded as "
                    f"{arr.dtype}{tuple(arr.shape)} but manifest records "
                    f"{info['dtype']}{tuple(info['shape'])}",
                    step=step, leaf=key)
            want = info.get("crc32")
            if want is not None:
                got = crc32_hex(np.ascontiguousarray(arr).tobytes())
                if got != want:
                    raise ChecksumMismatch(
                        f"step {step}: leaf {key!r} digest {got} != "
                        f"recorded {want} (bit rot or torn write)",
                        step=step, leaf=key, expected=want, actual=got)
        return meta, data

    def verify_step(self, step: int) -> list[str]:
        """Digest-check one step; [] when clean, else the problems found."""
        try:
            self._read_step(step, verify=True)
        except CheckpointCorruption as e:
            return [str(e)]
        return []

    def cross_host_digests(self, step: int) -> dict:
        """All-gather-style digest exchange over one step's host files.

        Every host's manifest + npz under the shared step dir is re-read
        and re-hashed (the filesystem walk stands in for the collective —
        each entry is exactly what host ``h`` would contribute to an
        all-gather of its per-leaf CRC32 digests).  Returns a report:

          * ``hosts``      — ``host_id -> {"problems": [...], "leaves":
            {key: crc32}}``; ``problems`` holds that host's local
            verification failures (manifest digest, missing npz, leaf
            digest/shape drift);
          * ``mismatches`` — leaves recorded by more than one host whose
            digests disagree (replicated state must hash identically on
            every host; a split here means the replicas diverged);
          * ``ok``         — no problems and no mismatches.
        """
        step_dir = self._step_dir(step)
        if not os.path.isdir(step_dir):
            raise CheckpointCorruption(
                f"step {step}: no step dir under {self.dir}", step=step)
        manifests: dict[int, str] = {}
        for name in sorted(os.listdir(step_dir)):
            if name == "manifest.json":
                manifests[0] = os.path.join(step_dir, name)
            elif name.startswith("manifest_host_") and name.endswith(".json"):
                manifests[int(name[len("manifest_host_"):-len(".json")])] = \
                    os.path.join(step_dir, name)
        report: dict = {"step": step, "hosts": {}, "mismatches": [],
                        "ok": bool(manifests)}
        by_leaf: dict[str, dict[int, str]] = {}
        for host, mpath in sorted(manifests.items()):
            problems: list[str] = []
            leaves: dict[str, str] = {}
            try:
                with open(mpath) as f:
                    meta = json.load(f)
            except (OSError, json.JSONDecodeError) as e:
                report["hosts"][host] = {
                    "problems": [f"unreadable manifest: {e}"], "leaves": {}}
                report["ok"] = False
                continue
            recorded = meta.get("manifest_crc32")
            if recorded is not None and _manifest_digest(meta) != recorded:
                problems.append(
                    f"manifest digest {_manifest_digest(meta)} != recorded "
                    f"{recorded}")
            npz = os.path.join(step_dir, f"host_{host}.npz")
            data: dict[str, np.ndarray] = {}
            if not os.path.exists(npz):
                problems.append(f"host_{host}.npz missing")
            else:
                try:
                    with np.load(npz) as z:
                        data = {k: z[k] for k in z.files}
                except (OSError, ValueError, zipfile.BadZipFile) as e:
                    problems.append(f"unreadable host_{host}.npz: {e}")
            for key, info in meta.get("leaves", {}).items():
                nkey = key.replace("/", "__")
                if nkey not in data:
                    if data:
                        problems.append(f"leaf {key!r} absent from npz")
                    continue
                got = crc32_hex(np.ascontiguousarray(data[nkey]).tobytes())
                leaves[key] = got
                want = info.get("crc32")
                if want is not None and got != want:
                    problems.append(
                        f"leaf {key!r} digest {got} != recorded {want}")
                by_leaf.setdefault(key, {})[host] = got
            report["hosts"][host] = {"problems": problems, "leaves": leaves}
            if problems:
                report["ok"] = False
        for key, per_host in sorted(by_leaf.items()):
            if len(per_host) > 1 and len(set(per_host.values())) > 1:
                report["mismatches"].append(
                    {"leaf": key, "digests": dict(sorted(per_host.items()))})
                report["ok"] = False
        return report

    def restore(self, step: int, target: dict, *, shardings=None,
                allow_cast: bool = False, verify: bool = True):
        """target: pytree of like-structured arrays/ShapeDtypeStructs.
        shardings: optional matching pytree of jax.sharding.Sharding — arrays
        are placed onto it (reshard-on-restore for elastic scaling).

        Every leaf is digest-verified against the manifest, and its loaded
        shape/dtype must match the target exactly; a dtype difference raises
        ``LeafMismatch`` unless ``allow_cast=True`` makes the conversion
        explicit.  Shape differences always raise.
        """
        meta, data = self._read_step(step, verify=verify)
        flat_t, treedef = _flatten_with_paths(target)
        flat_s, _ = (_flatten_with_paths(shardings) if shardings is not None
                     else (None, None))
        out = {}
        for key, tgt in flat_t.items():
            nkey = key.replace("/", "__")
            if nkey not in data:
                raise CheckpointCorruption(
                    f"step {step}: target leaf {key!r} absent from "
                    f"checkpoint", step=step)
            arr = data[nkey]
            want_dtype = np.dtype(tgt.dtype)
            if tuple(arr.shape) != tuple(np.shape(tgt)):
                raise LeafMismatch(
                    f"step {step}: leaf {key!r} has shape "
                    f"{tuple(arr.shape)} but target expects "
                    f"{tuple(np.shape(tgt))}", step=step, leaf=key)
            if arr.dtype != want_dtype:
                if not allow_cast:
                    raise LeafMismatch(
                        f"step {step}: leaf {key!r} stored as {arr.dtype} "
                        f"but target expects {want_dtype} (pass "
                        f"allow_cast=True for an explicit conversion)",
                        step=step, leaf=key)
                arr = arr.astype(want_dtype)
            val = jnp.asarray(arr)
            if flat_s is not None and key in flat_s and flat_s[key] is not None:
                val = jax.device_put(val, flat_s[key])
            out[key] = val
        leaves = [out[k] for k in flat_t.keys()]
        restored = jax.tree_util.tree_unflatten(treedef, leaves)
        return restored, meta["extra"]

    # ------------------------------------------------- last-known-good ---
    def quarantine_step(self, step: int, *, reason: str = "") -> str:
        """Rename a bad step aside (never deleted) with a reason ledger."""
        name = f"step_{step:010d}"
        src = os.path.join(self.dir, name)
        i = 0
        while True:
            suffix = f"_{i}" if i else ""
            dst = os.path.join(
                self.dir, f"{_QUARANTINE_PREFIX}{name}{suffix}")
            if not os.path.exists(dst):
                break
            i += 1
        os.rename(src, dst)
        with open(os.path.join(dst, "quarantine.json"), "w") as f:
            json.dump({"step": step, "reason": reason, "from": name}, f,
                      indent=1)
        self.quarantined.append((step, reason))
        return dst

    def quarantine_dirs(self) -> list[str]:
        return sorted(d for d in os.listdir(self.dir)
                      if d.startswith(_QUARANTINE_PREFIX))

    def restore_latest_good(self, target, *, shardings=None,
                            allow_cast: bool = False, validate=None):
        """Walk steps newest-first to the first one that restores cleanly.

        A step fails the walk when digest/shape/dtype verification raises
        ``CheckpointCorruption``, or when the optional ``validate(restored,
        extra)`` hook raises anything — either way the step is quarantined
        (renamed aside with its reason, never deleted) and the walk
        continues.  Returns ``(step, restored, extra)``; raises
        ``NoGoodCheckpoint`` listing every rejection when no step survives.
        """
        steps = self.all_steps()
        if not steps:
            raise NoGoodCheckpoint(f"no checkpoints under {self.dir}")
        rejected = []
        for step in reversed(steps):
            try:
                restored, extra = self.restore(
                    step, target, shardings=shardings, allow_cast=allow_cast)
                if validate is not None:
                    validate(restored, extra)
            except CheckpointCorruption as e:
                rejected.append((step, str(e)))
                self.quarantine_step(step, reason=str(e))
                continue
            except Exception as e:  # noqa: BLE001 — validate() rejections
                reason = f"{type(e).__name__}: {e}"
                rejected.append((step, reason))
                self.quarantine_step(step, reason=reason)
                continue
            return step, restored, extra
        detail = "; ".join(f"step {s}: {r}" for s, r in rejected)
        raise NoGoodCheckpoint(
            f"all {len(rejected)} checkpoint step(s) under {self.dir} "
            f"failed verification — {detail}")
