"""Fault-tolerant checkpointing: sharded npz + manifest, atomic, reshardable.

Design (for 1000+ node deployments, exercised here on 1 host):
  * Each host writes only the leaves (or leaf-shards) it owns to
    ``step_<N>/host_<id>.npz``; a JSON manifest records the tree structure,
    dtypes, global shapes and data-pipeline state.
  * Writes are atomic: temp dir -> fsync -> rename; a crashed write can
    never corrupt the latest checkpoint (rename is the commit point).
  * ``latest_step`` scans for complete checkpoints only (manifest present).
  * Restore is RESHARD-SAFE: arrays are loaded as full values and committed
    to whatever sharding the restoring job requests (jax.device_put with the
    new sharding), so a job restarted on a different mesh/device count
    (elastic scaling) restores transparently.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        # DictKey -> .key, SequenceKey -> .idx, GetAttrKey (custom pytree
        # nodes, e.g. deploy.BinArrayProgram instructions) -> .name
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        out[key] = leaf
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, host_id: int = 0,
                 n_hosts: int = 1):
        self.dir = directory
        self.keep = keep
        self.host_id = host_id
        self.n_hosts = n_hosts
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save ---
    def save(self, step: int, state: dict, *, extra: dict | None = None):
        """state: pytree of arrays.  extra: JSON-able (data pipeline etc.)."""
        flat, _ = _flatten_with_paths(state)
        step_dir = os.path.join(self.dir, f"step_{step:010d}")
        tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp_ckpt_")
        try:
            arrays = {}
            meta = {"step": step, "extra": extra or {}, "leaves": {}}
            for key, leaf in flat.items():
                arr = np.asarray(jax.device_get(leaf))
                arrays[key.replace("/", "__")] = arr
                meta["leaves"][key] = {
                    "shape": list(arr.shape), "dtype": str(arr.dtype)}
            np.savez(os.path.join(tmp, f"host_{self.host_id}.npz"), **arrays)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(meta, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(step_dir):
                shutil.rmtree(step_dir)
            os.rename(tmp, step_dir)  # commit point
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()
        return step_dir

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore ---
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, d, "manifest.json")):
                out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target: dict, *, shardings=None):
        """target: pytree of like-structured arrays/ShapeDtypeStructs.
        shardings: optional matching pytree of jax.sharding.Sharding — arrays
        are placed onto it (reshard-on-restore for elastic scaling)."""
        step_dir = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(step_dir, "manifest.json")) as f:
            meta = json.load(f)
        data = np.load(os.path.join(step_dir, f"host_{self.host_id}.npz"))
        flat_t, treedef = _flatten_with_paths(target)
        flat_s, _ = (_flatten_with_paths(shardings) if shardings is not None
                     else (None, None))
        out = {}
        for key, tgt in flat_t.items():
            arr = data[key.replace("/", "__")]
            want_dtype = tgt.dtype
            val = jnp.asarray(arr.astype(want_dtype))
            if flat_s is not None and key in flat_s and flat_s[key] is not None:
                val = jax.device_put(val, flat_s[key])
            out[key] = val
        leaves = [out[k] for k in flat_t.keys()]
        restored = jax.tree_util.tree_unflatten(treedef, leaves)
        return restored, meta["extra"]
