"""SLO controller: map latency pressure onto the §IV-D degradation ladder.

The paper's runtime accuracy↔throughput switch (§IV-D) gives the serving
tier a *graded* response to overload that an LM server doesn't have: before
shedding a request outright, the service can serve it at fewer binary
levels — less accurate, proportionally cheaper (every dropped level removes
one MXU matmul per layer).  This module turns that knob into a closed-loop
policy:

  * :func:`schedule_cost` — the §IV-E cost model of a resolved ``m_active``
    schedule: level-weighted MACs (one matmul pass per active level per
    layer), the same quantity ``benchmarks/table3`` scales throughput by.
  * :func:`default_ladder` — an ordered sequence of per-layer schedules with
    strictly decreasing cost, full-M first.  Intermediate rungs reduce the
    *front* (high-resolution, high-MAC, low-semantic) half of the network
    first — ReBNet's observation that late layers carry the accuracy — so
    early rungs trade the most MACs for the least accuracy.
  * :class:`SLOController` — windowed-quantile feedback: ``observe()``
    completion latencies, ``update()`` once per batch.  Pressure =
    p99/target; at ``degrade_at`` the controller steps one rung down the
    ladder (and starts *shedding at admission* once the ladder is
    exhausted); after ``recover_after`` consecutive calm updates it climbs
    back.  The sample window is cleared on every rung change so the next
    decision is based purely on latencies measured *at the new rung* —
    without this, pre-degradation samples keep p99 inflated and the
    controller overshoots straight to shed.

Degrade-before-shed, recover-when-clear: the ladder is the robustness
mechanism, shedding is the last rung.
"""
from __future__ import annotations

import collections
import dataclasses

from repro.deploy.program import BinArrayProgram


def schedule_cost(program: BinArrayProgram, m_active=None) -> int:
    """Level-weighted MAC cost of running ``program`` at ``m_active``.

    One binary-matmul pass per active level per layer (paper §IV-E), so the
    cost of a schedule is ``sum(layer.macs * m_layer)``.  Accepts anything
    ``resolve_schedule`` does (None | int | per-layer sequence).
    """
    sched = program.resolve_schedule(m_active)
    return sum(int(i.stats.macs) * m for i, m in zip(program.instrs, sched))


def default_ladder(program: BinArrayProgram) -> tuple[tuple[int, ...], ...]:
    """Build the degradation ladder: resolved per-layer schedules, full-M
    first, strictly decreasing :func:`schedule_cost`, no duplicates.

    Rung 0 is always the full packed schedule.  Below it, for each global
    level count m < m_max, two candidates in order: front-half layers at m
    with the back half kept full (the accuracy-gentle rung), then the global
    §IV-D switch at m.  Candidates that do not strictly reduce cost (tiny or
    already-M=1 programs) are dropped, so every program gets a valid ladder —
    possibly of length 1, in which case the controller's only move is shed.

    The candidate list is ``deploy.selftest.golden_rungs`` — the same rungs
    ``deploy.compile`` records golden digests for — so every ladder rung the
    service can run at is guaranteed a recorded BIST digest.
    """
    from repro.deploy.selftest import golden_rungs
    ladder = []
    for cand in golden_rungs(program):
        if not ladder or schedule_cost(program, cand) < schedule_cost(
                program, ladder[-1]):
            ladder.append(cand)
    return tuple(ladder)


@dataclasses.dataclass
class SLOConfig:
    """Feedback-policy knobs for :class:`SLOController`.

    ``target_ms=None`` disables the loop entirely: the controller pins its
    initial rung and never sheds (static-schedule serving — benches and
    bit-exactness tests use this).  ``degrade_at``/``recover_at`` are
    pressure thresholds (pressure = windowed p-``quantile`` latency /
    target); the gap between them plus ``recover_after`` consecutive calm
    updates is the hysteresis that stops rung flapping.
    """

    target_ms: float | None = None
    window: int = 64            # latency samples retained (deque maxlen)
    min_samples: int = 8        # no decisions until the window has this many
    degrade_at: float = 1.0     # pressure >= this -> one rung down
    recover_at: float = 0.6     # pressure <= this counts as a calm update
    recover_after: int = 3      # consecutive calm updates before climbing
    quantile: float = 0.99


class SLOController:
    """Windowed-quantile latency feedback over a degradation ladder.

    State: ``rung`` indexes ``ladder`` (0 = full-M); ``shedding`` is the
    final escalation past the last rung — the service consults it at
    admission.  ``rung_changes`` / ``shed_transitions`` are monotone
    counters for the soak progress report.
    """

    def __init__(self, ladder: tuple[tuple[int, ...], ...],
                 config: SLOConfig | None = None, *, initial_rung: int = 0):
        if not ladder:
            raise ValueError("ladder must hold at least one schedule")
        if not 0 <= initial_rung < len(ladder):
            raise ValueError(
                f"initial_rung {initial_rung} outside ladder of "
                f"{len(ladder)} rungs")
        self.ladder = tuple(ladder)
        self.config = config or SLOConfig()
        self.rung = initial_rung
        self.shedding = False
        self.rung_changes = 0
        self.shed_transitions = 0
        self._window = collections.deque(maxlen=self.config.window)
        self._calm = 0

    @property
    def schedule(self) -> tuple[int, ...]:
        """The per-layer ``m_active`` schedule of the current rung."""
        return self.ladder[self.rung]

    def observe(self, latency_s: float) -> None:
        """Record one request completion latency (seconds)."""
        self._window.append(float(latency_s))

    def pressure(self) -> float | None:
        """Windowed p-quantile latency over target, or None when the loop
        is disabled (no target) or the window is still too thin."""
        cfg = self.config
        if cfg.target_ms is None or len(self._window) < cfg.min_samples:
            return None
        lat = sorted(self._window)
        idx = min(len(lat) - 1, int(cfg.quantile * len(lat)))
        return lat[idx] / (cfg.target_ms * 1e-3)

    def update(self) -> None:
        """One control decision (call once per served batch).

        Escalation clears the sample window so the next decision measures
        the *new* rung, not a mix; de-escalation requires ``recover_after``
        consecutive calm updates and likewise resets the window.
        """
        p = self.pressure()
        if p is None:
            return
        cfg = self.config
        if p >= cfg.degrade_at:
            self._calm = 0
            if self.rung + 1 < len(self.ladder):
                self.rung += 1
                self.rung_changes += 1
                self._window.clear()
            elif not self.shedding:
                self.shedding = True
                self.shed_transitions += 1
                self._window.clear()
        elif p <= cfg.recover_at:
            self._calm += 1
            if self._calm >= cfg.recover_after:
                self._calm = 0
                if self.shedding:
                    self.shedding = False
                    self.shed_transitions += 1
                elif self.rung > 0:
                    self.rung -= 1
                    self.rung_changes += 1
                    self._window.clear()
        else:
            self._calm = 0
