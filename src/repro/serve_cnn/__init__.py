"""SLO-governed continuous-batching CNN inference over BinArrayPrograms.

The serving tier for the programs the repo is about: bounded admission,
per-request deadlines, dynamic batch assembly into ``deploy.execute``, and
the paper's §IV-D runtime accuracy↔throughput switch operated *as the
degradation policy* — under latency pressure the service serves fewer
binary levels before it sheds requests, and recovers to full-M when the
pressure clears.  See docs/serving_cnn.md.
"""
from repro.serve_cnn.service import (CNNService, ImageRequest,
                                     NonFiniteOutput, SHED_REASONS)
from repro.serve_cnn.slo import (SLOConfig, SLOController, default_ladder,
                                 schedule_cost)

__all__ = [
    "CNNService", "ImageRequest", "NonFiniteOutput", "SHED_REASONS",
    "SLOConfig", "SLOController", "default_ladder", "schedule_cost",
]
