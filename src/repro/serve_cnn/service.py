"""CNNService: continuous-batching image inference over a BinArrayProgram.

The serving loop the ROADMAP names ("a real inference *service* over
compiled programs"): a bounded request queue with per-request deadlines
feeding dynamic batch assembly into ``deploy.execute``, governed by the
§IV-D degradation ladder (:mod:`repro.serve_cnn.slo`).  The robustness
contract, enforced fault class by fault class in tests/test_serve_cnn.py:

  every fault is **retried, shed, or degraded — never a silent wrong
  answer, never a stuck queue.**

Dispositions:

  * **transient executor failures** (raised exceptions, NaN/Inf outputs
    caught by the finite screen) — bounded retry with exponential backoff;
    a batch that exhausts retries fails *loudly*: its requests return
    ``status="failed"`` with the error attached, counted in
    ``stats["exec_failed_batches"]``, and the queue keeps draining.
  * **latency pressure** — the SLO controller degrades the ``m_active``
    schedule down the ladder (cheaper batches) before anything is dropped,
    and recovers to full-M when the windowed p99 clears.
  * **overload** — explicit admission control: a full queue, an
    already-expired deadline, or controller-commanded shedding rejects the
    request *at submit* with a named reason (``stats["shed"]``), instead of
    letting the queue grow without bound.  Requests whose deadline expires
    while queued are shed at dispatch, not executed past their deadline.
  * **in-memory program corruption** — a budgeted watchdog
    (``selftest_every``) replays the compile-time golden probe
    (``deploy.self_test``) on the active rung every N batches and on every
    rung change; a digest mismatch quarantines the live program and
    hot-reloads the last-known-good checkpoint
    (``deploy.load_latest_good``), re-runs the self-test, and resumes —
    counted in ``stats`` (``selftest_runs`` / ``selftest_failures`` /
    ``reloads`` / ``quarantined_steps``) and *loud* (the original
    ``SelfTestFailure`` propagates) when no checkpoint manager was wired
    or the recovery walk exhausts.

Batches are always zero-padded to the configured ``batch_size``, so the
executor sees one input shape and compiles exactly one variant per ladder
rung — and every response is bit-exact against ``deploy.execute`` on the
same padded batch at the same schedule (``last_batch``/``last_schedule``
expose the pair for exactly that check).

Determinism hooks: ``clock``/``sleep`` are injectable (tests pass
``testing.faults.ManualClock``) and ``execute_fn`` defaults to looking up
``repro.deploy.executor.execute`` *at call time*, so the fault injector's
module patch (``testing.faults.inject_faults``) is visible without the
service opting in — while ``repro.deploy.execute`` stays the clean
reference for bit-exactness checks.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import time

import jax.numpy as jnp
import numpy as np

from repro.deploy.program import BinArrayProgram
from repro.serve_cnn.slo import SLOConfig, SLOController, default_ladder

SHED_REASONS = ("queue_full", "deadline_expired", "slo_shed")


class NonFiniteOutput(RuntimeError):
    """The executor returned NaN/Inf logits — a wrong answer that must never
    reach a client silently.  Raised by the service's finite screen and
    handled exactly like a transient executor fault (retried, then failed
    loudly)."""


@dataclasses.dataclass
class ImageRequest:
    """One inference request and its full lifecycle record.

    ``deadline_s`` is an *absolute* time on the service clock (None = no
    deadline).  ``status`` walks pending -> queued -> done | shed | failed;
    shed requests carry ``shed_reason`` (one of :data:`SHED_REASONS`),
    failed ones carry ``error``.  Completed requests carry the served
    ``logits``, the resolved ``m_schedule``/``rung`` they were computed at,
    their ``batch_index`` into the padded batch, and ``latency_s``.
    """

    image: np.ndarray
    deadline_s: float | None = None
    id: int = -1
    status: str = "pending"
    shed_reason: str | None = None
    error: str | None = None
    logits: np.ndarray | None = None
    m_schedule: tuple[int, ...] | None = None
    rung: int | None = None
    batch_index: int | None = None
    submit_t: float = 0.0
    latency_s: float | None = None


class CNNService:
    """SLO-governed continuous-batching inference over one compiled program.

    Parameters
    ----------
    program:      the compiled :class:`BinArrayProgram` to serve.
    slo:          :class:`SLOConfig`; ``target_ms=None`` (default) pins the
                  ladder at ``initial_rung`` and never sheds on pressure.
    ladder:       degradation schedules; default :func:`default_ladder`.
    batch_size:   padded device batch (one compiled variant per rung).
    max_queue:    admission bound; beyond it requests shed ``queue_full``.
    max_retries:  executor re-attempts per batch before failing loudly.
    backoff_s:    base of the exponential retry backoff.
    clock/sleep:  time sources (injectable for deterministic tests).
    execute_fn:   ``fn(program, x, m_active, *, interpret)``; default
                  late-binds ``repro.deploy.executor.execute`` so
                  fault-injection patches apply.
    interpret:    Pallas interpret override passed through to the executor.
    mesh_plan:    optional :class:`repro.distributed.MeshPlan` — batches are
                  served through ``distributed.execute_sharded`` (bit-exact
                  vs the single-device path, so every SLO/degradation
                  contract carries over unchanged).  ``batch_size`` must
                  divide evenly over the plan's data axis: the service
                  always pads to ``batch_size``, and an uneven split would
                  silently waste a device column every step.
    selftest_every: run the golden self-test (``deploy.self_test``, always
                  the *clean* execute path — the BIST diagnoses the program,
                  not the fault harness) on the active rung every this-many
                  served batches, plus once at startup and on every rung
                  change.  Requires the program to carry a GoldenRecord.
                  None (default) disables the watchdog.
    checkpoint_manager / restore_like: recovery source for the watchdog —
                  on a self-test failure the live program is quarantined
                  and ``deploy.load_latest_good(checkpoint_manager,
                  restore_like)`` hot-reloads the newest checkpoint that
                  passes digests + verification + self-test.  Without them
                  a self-test failure raises (loud, by design).
    """

    def __init__(self, program: BinArrayProgram, *,
                 slo: SLOConfig | None = None,
                 ladder=None,
                 batch_size: int = 4,
                 max_queue: int = 16,
                 max_retries: int = 2,
                 backoff_s: float = 0.01,
                 clock=time.monotonic,
                 sleep=time.sleep,
                 execute_fn=None,
                 interpret: bool | None = None,
                 mesh_plan=None,
                 initial_rung: int = 0,
                 selftest_every: int | None = None,
                 checkpoint_manager=None,
                 restore_like: BinArrayProgram | None = None):
        if batch_size < 1 or max_queue < 1:
            raise ValueError(
                f"batch_size ({batch_size}) and max_queue ({max_queue}) "
                "must be >= 1")
        if selftest_every is not None:
            if selftest_every < 1:
                raise ValueError(
                    f"selftest_every must be >= 1, got {selftest_every}")
            if program.golden is None:
                raise ValueError(
                    "selftest_every requires a program with a GoldenRecord "
                    "(deploy.compile(..., golden=True), the default)")
        self.program = program
        self.batch_size = int(batch_size)
        self.max_queue = int(max_queue)
        self.max_retries = int(max_retries)
        self.backoff_s = float(backoff_s)
        self.clock = clock
        self.sleep = sleep
        self.interpret = interpret
        if mesh_plan is not None:
            if len(mesh_plan.shards) != len(program.instrs):
                raise ValueError(
                    f"mesh_plan carries {len(mesh_plan.shards)} shard(s) "
                    f"for a {len(program.instrs)}-instruction program")
            if batch_size % mesh_plan.n_data:
                raise ValueError(
                    f"batch_size={batch_size} must divide over the mesh "
                    f"data axis (n_data={mesh_plan.n_data}): the service "
                    f"pads every batch to batch_size, so an uneven split "
                    f"wastes a device column every step")
        self.mesh_plan = mesh_plan
        self._execute_fn = execute_fn
        self.selftest_every = selftest_every
        self.checkpoint_manager = checkpoint_manager
        self.restore_like = restore_like
        self._last_selftest_batch: int | None = None
        self.last_reload_step: int | None = None
        self.quarantined_program: BinArrayProgram | None = None
        self.controller = SLOController(
            tuple(ladder) if ladder is not None else default_ladder(program),
            slo, initial_rung=initial_rung)
        self.queue: collections.deque[ImageRequest] = collections.deque()
        self._ids = itertools.count()
        self._latencies = collections.deque(maxlen=512)
        self._schedules_seen: set[tuple[int, ...]] = set()
        self.last_batch: np.ndarray | None = None
        self.last_schedule: tuple[int, ...] | None = None
        self._stats = {
            "admitted": 0, "completed": 0, "failed": 0, "batches": 0,
            "retries": 0, "exec_exceptions": 0, "nonfinite_detected": 0,
            "exec_failed_batches": 0, "shed_count": 0,
            "shed": {r: 0 for r in SHED_REASONS},
            "fault_types": {}, "rung_hist": {},
            "selftest_runs": 0, "selftest_failures": 0, "reloads": 0,
            "quarantined_steps": 0,
        }
        self._last_rung = self.controller.rung

    # ------------------------------------------------------------ admit ---
    def submit(self, image, deadline_s: float | None = None) -> ImageRequest:
        """Admit one image; returns the request (check ``status``).

        Malformed inputs raise ``ValueError`` (caller bug, not load).
        Admission sheds — full queue, dead-on-arrival deadline, controller
        shedding — set ``status="shed"`` + ``shed_reason`` and count in
        ``stats``; they are the explicit backpressure signal.
        """
        image = np.asarray(image, np.float32)
        want = tuple(self.program.input_shape[1:])
        if image.shape != want:
            raise ValueError(
                f"request image has shape {image.shape}; program "
                f"{self.program.arch!r} serves {want} "
                f"(input_shape={self.program.input_shape})")
        req = ImageRequest(image=image, deadline_s=deadline_s,
                           id=next(self._ids), submit_t=self.clock())
        if deadline_s is not None and deadline_s <= req.submit_t:
            return self._shed(req, "deadline_expired")
        if self.controller.shedding and len(self.queue) >= self.batch_size:
            # controller-commanded shedding is *backpressure*, not an
            # outage: up to one batch's worth stays admitted so the system
            # keeps serving (and keeps measuring — without fresh latency
            # samples the controller could never observe recovery and
            # shedding would latch forever); everything that would queue
            # beyond that is shed
            return self._shed(req, "slo_shed")
        if len(self.queue) >= self.max_queue:
            return self._shed(req, "queue_full")
        req.status = "queued"
        self.queue.append(req)
        self._stats["admitted"] += 1
        return req

    def _shed(self, req: ImageRequest, reason: str) -> ImageRequest:
        req.status = "shed"
        req.shed_reason = reason
        self._stats["shed"][reason] += 1
        self._stats["shed_count"] += 1
        return req

    # ------------------------------------------------------------- step ---
    def step(self) -> list[ImageRequest]:
        """Serve one batch: assemble, execute at the controller's rung with
        bounded retry, screen for non-finite outputs, record latencies, run
        one SLO update.  Returns every request that left the system this
        step (done, failed, or shed-at-dispatch).  The integrity watchdog
        (when configured) runs *before* batch assembly, so a corrupt program
        is replaced before it can answer this step's requests."""
        if self.selftest_every is not None:
            self._watchdog()
        finished: list[ImageRequest] = []
        batch: list[ImageRequest] = []
        while self.queue and len(batch) < self.batch_size:
            req = self.queue.popleft()
            if (req.deadline_s is not None
                    and req.deadline_s <= self.clock()):
                finished.append(self._shed(req, "deadline_expired"))
                continue
            batch.append(req)
        if not batch:
            return finished

        rung = self.controller.rung
        sched = self.controller.schedule
        shape = (self.batch_size,) + tuple(self.program.input_shape[1:])
        x_np = np.zeros(shape, np.float32)
        for i, req in enumerate(batch):
            x_np[i] = req.image
        x = jnp.asarray(x_np)

        out, err = None, None
        for attempt in range(self.max_retries + 1):
            try:
                y = self._execute(x, sched)
                if not bool(jnp.all(jnp.isfinite(y))):
                    self._stats["nonfinite_detected"] += 1
                    raise NonFiniteOutput(
                        f"non-finite logits at rung {rung} "
                        f"(schedule {sched})")
                out = np.asarray(y)
                break
            except Exception as e:  # noqa: BLE001 — disposition by contract
                err = e
                name = type(e).__name__
                self._stats["fault_types"][name] = (
                    self._stats["fault_types"].get(name, 0) + 1)
                if not isinstance(e, NonFiniteOutput):
                    self._stats["exec_exceptions"] += 1
                if attempt < self.max_retries:
                    self._stats["retries"] += 1
                    self.sleep(self.backoff_s * (2 ** attempt))

        self._stats["batches"] += 1
        self._stats["rung_hist"][rung] = (
            self._stats["rung_hist"].get(rung, 0) + 1)
        self._schedules_seen.add(sched)
        self.last_batch = x_np
        self.last_schedule = sched

        now = self.clock()
        if out is None:
            # loud failure: requests carry the error, queue keeps draining
            self._stats["exec_failed_batches"] += 1
            for req in batch:
                req.status = "failed"
                req.error = repr(err)
                req.rung = rung
                finished.append(req)
        else:
            for i, req in enumerate(batch):
                req.status = "done"
                req.logits = out[i]
                req.m_schedule = sched
                req.rung = rung
                req.batch_index = i
                req.latency_s = now - req.submit_t
                self.controller.observe(req.latency_s)
                self._latencies.append(req.latency_s)
                self._stats["completed"] += 1
                finished.append(req)
        self.controller.update()
        return finished

    # --------------------------------------------------------- watchdog ---
    def _watchdog(self) -> None:
        """Budgeted integrity check: golden self-test on the active rung
        every ``selftest_every`` served batches and on every rung change
        (each compiled rung variant gets re-attested when it comes live)."""
        rung = self.controller.rung
        due = (rung != self._last_rung
               or self._last_selftest_batch is None
               or (self._stats["batches"] - self._last_selftest_batch
                   >= self.selftest_every))
        self._last_rung = rung
        if not due:
            return
        self._last_selftest_batch = self._stats["batches"]
        self._selftest_rungs(self._watch_rungs(self.program))

    def _watch_rungs(self, program):
        """The active rung when the golden record covers it, else full-M
        (rung 0 of golden_rungs — always recorded)."""
        sched = program.resolve_schedule(self.controller.schedule)
        if program.golden.digest_for(sched) is not None:
            return (sched,)
        return (program.resolve_schedule(None),)

    def _selftest_rungs(self, rungs) -> None:
        from repro.deploy.selftest import SelfTestFailure, self_test

        self._stats["selftest_runs"] += 1
        try:
            self_test(self.program, rungs=rungs)
        except SelfTestFailure as e:
            self._stats["selftest_failures"] += 1
            self._recover(e)

    def _recover(self, cause) -> None:
        """Quarantine the live program and hot-reload the last-known-good
        checkpoint.  Loud when recovery is impossible: without a wired
        checkpoint manager the original failure propagates, and an
        exhausted walk raises ``NoGoodCheckpoint`` — a service that cannot
        prove its answers right anymore must not keep serving."""
        self.quarantined_program = self.program
        if self.checkpoint_manager is None or self.restore_like is None:
            raise cause
        from repro.deploy.compiler import load_latest_good
        from repro.deploy.selftest import self_test

        before = len(self.checkpoint_manager.quarantined)
        step, fresh = load_latest_good(
            self.checkpoint_manager, self.restore_like)
        self._stats["quarantined_steps"] += (
            len(self.checkpoint_manager.quarantined) - before)
        # the walk already self-tested every recorded rung; re-run on the
        # rung this service is actually serving as the explicit resume gate
        self._stats["selftest_runs"] += 1
        self_test(fresh, rungs=self._watch_rungs(fresh))
        self.program = fresh
        self._stats["reloads"] += 1
        self.last_reload_step = step

    def _execute(self, x, sched):
        if self._execute_fn is not None:
            return self._execute_fn(self.program, x, sched,
                                    interpret=self.interpret)
        if self.mesh_plan is not None:
            from repro.distributed import executor as dist_executor

            return dist_executor.execute_sharded(
                self.program, self.mesh_plan, x, m_active=sched,
                interpret=self.interpret)
        # late binding: resolve the module attribute at call time so a
        # testing.faults.inject_faults patch is seen (deploy.execute — the
        # import-time binding — stays clean for reference outputs)
        from repro.deploy import executor

        return executor.execute(self.program, x, sched,
                                interpret=self.interpret)

    def drain(self, max_steps: int = 10_000) -> list[ImageRequest]:
        """Step until the queue is empty; returns everything that finished.
        Bounded (a stuck queue raises instead of spinning forever)."""
        done: list[ImageRequest] = []
        for _ in range(max_steps):
            if not self.queue:
                return done
            done.extend(self.step())
        raise RuntimeError(
            f"queue failed to drain within {max_steps} steps "
            f"({len(self.queue)} requests left)")

    # ------------------------------------------------------------ stats ---
    @property
    def stats(self) -> dict:
        """Counters + derived latency quantiles (p50/p99 over a bounded
        window) + controller state.  ``shed`` is by-reason; ``fault_types``
        is by-exception-class; ``rung_hist`` is batches served per rung —
        the degradation histogram the acceptance criteria name."""
        out = {k: (dict(v) if isinstance(v, dict) else v)
               for k, v in self._stats.items()}
        out["queue_depth"] = len(self.queue)
        out["rung"] = self.controller.rung
        out["shedding"] = self.controller.shedding
        lat = sorted(self._latencies)
        if lat:
            out["p50_latency_s"] = lat[len(lat) // 2]
            out["p99_latency_s"] = lat[min(len(lat) - 1,
                                           int(0.99 * len(lat)))]
        return out

    def cache_gauges(self) -> dict:
        """Flat-by-contract gauges for ``repro.testing.soak``: the executor's
        compiled-variant counters plus the service's own distinct-schedule
        count (bounded by the ladder length — a growing value means the
        controller is inventing schedules)."""
        from repro.deploy import executor

        gauges = dict(executor.cache_gauges())
        gauges["svc_schedules_seen"] = (
            lambda: float(len(self._schedules_seen)))
        return gauges
