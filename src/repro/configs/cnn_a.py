"""CNN-A — the paper's own small network (§V-A1): GTSRB, 43 classes, ~9M MACs.

Not an LM ArchConfig; exposed as a simple spec consumed by models/cnn.py,
examples/train_cnn_a.py and benchmarks/table2_accuracy.py.
"""
CONFIG = dict(
    name="cnn-a",
    kind="cnn",
    input_shape=(48, 48, 3),
    n_classes=43,
    macs=9_000_000,  # paper's headline figure; exact count in cnn.cnn_a_macs()
    layers=[
        ("conv", dict(filters=5, kernel=(7, 7), in_ch=3)),
        ("pool", dict(factor=2)),
        ("conv", dict(filters=150, kernel=(4, 4), in_ch=5)),
        ("pool", dict(factor=6)),
        ("dense", dict(inp=1350, out=340)),
        ("dense", dict(inp=340, out=490)),
        ("dense", dict(inp=490, out=43)),
    ],
)
