"""mamba2-2.7b [ssm] — arXiv:2405.21060.  SSD (state-space duality),
attention-free; O(1)-state decode runs long_500k trivially."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,              # attention-free
    n_kv_heads=0,
    d_ff=0,
    vocab=50_280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_ngroups=1,
    tie_embeddings=True,
)
