"""grok-1-314b [moe] — hf:xai-org/grok-1.  8 experts, top-2, GQA kv=8."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    d_ff_expert=32768,
    vocab=131_072,
    activation="geglu",
    n_experts=8,
    top_k=2,
    logit_softcap=30.0,
)
