"""Architecture config schema + registry + input specs for every shape cell.

Every assigned architecture gets one ``configs/<id>.py`` exporting ``CONFIG``;
``get_config(name)`` resolves it, ``reduced(cfg)`` shrinks it for CPU smoke
tests, and ``input_specs(cfg, shape)`` builds the ShapeDtypeStruct stand-ins
used by the multi-pod dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.binlinear import QuantConfig

# ---------------------------------------------------------------------------
# Shape cells (assigned): seq_len x global_batch
# ---------------------------------------------------------------------------
SHAPES: dict[str, dict[str, Any]] = {
    "train_4k":    dict(seq_len=4_096,   global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32_768,  global_batch=32,  kind="prefill"),
    "decode_32k":  dict(seq_len=32_768,  global_batch=128, kind="decode"),
    "long_500k":   dict(seq_len=524_288, global_batch=1,   kind="decode"),
}

ARCH_IDS = [
    "gemma_2b", "qwen3_14b", "h2o_danube_1_8b", "codeqwen15_7b",
    "internvl2_2b", "zamba2_7b", "whisper_medium", "mamba2_2_7b",
    "grok_1_314b", "deepseek_v3_671b",
]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None      # default d_model // n_heads
    activation: str = "swiglu"       # swiglu | geglu | gelu
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: int | None = None  # SWA width; None = full attention
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    logit_softcap: float | None = None
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int | None = None
    n_dense_layers: int = 0          # leading dense layers (DeepSeek-V3: 3)
    capacity_factor: float = 1.25
    # --- MLA (DeepSeek) ---
    use_mla: bool = False
    q_lora_rank: int = 0             # 0 = no q compression
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # --- MTP (DeepSeek) ---
    mtp_depth: int = 0
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_ngroups: int = 1
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    # --- hybrid (Zamba2) ---
    hybrid_attn_every: int = 6       # one shared attn block per N ssm blocks
    # --- enc-dec (Whisper) ---
    n_encoder_layers: int = 0
    encoder_len: int = 1500          # precomputed frame embeddings (stub)
    # --- VLM (InternVL2) ---
    n_image_tokens: int = 0          # precomputed patch embeddings (stub)
    # --- numerics / quant ---
    dtype: str = "bfloat16"
    quant: QuantConfig = QuantConfig(mode="dense")
    remat: bool = True
    scan_layers: bool = True
    # --- perf knobs (EXPERIMENTS.md §Perf) ---
    attn_chunk: int | None = None    # query-chunked attention (flash-style)
    onehot_loss: bool = False        # vocab-sharded CE (no logits gather)
    serve_fsdp: bool = True          # False: TP-only params at serve time
    kv_seq_shard: bool = False       # decode cache: shard seq dim on 'model'
                                     # (vs head_dim) — kills the per-layer
                                     # partial-sum all-reduce when kv heads
                                     # don't divide the model axis

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run long_500k decode? (SSM/hybrid/SWA)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter count (for MODEL_FLOPS = 6*N*D roofline term) ----------
    def param_count(self, active_only: bool = False) -> int:
        from repro.models import api

        return api.count_params(self, active_only=active_only)


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{name.replace('-', '_')}")
    return mod.CONFIG


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests (assignment requirement)."""
    kw: dict[str, Any] = dict(
        n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        d_ff=128, vocab=512, head_dim=16,
        sliding_window=32 if cfg.sliding_window else None,
        scan_layers=False, remat=False,
    )
    if cfg.n_experts:
        kw.update(n_experts=4, top_k=min(cfg.top_k, 2), d_ff_expert=64,
                  n_dense_layers=min(cfg.n_dense_layers, 1))
    if cfg.use_mla:
        kw.update(q_lora_rank=32 if cfg.q_lora_rank else 0, kv_lora_rank=32,
                  qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
    if cfg.mtp_depth:
        kw.update(mtp_depth=1)
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16, n_layers=4)
    if cfg.family == "hybrid":
        kw.update(hybrid_attn_every=2, n_layers=4)
    if cfg.n_encoder_layers:
        kw.update(n_encoder_layers=2, encoder_len=24)
    if cfg.n_image_tokens:
        kw.update(n_image_tokens=8)
    return cfg.replace(**kw)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins — dry-run pattern)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape_name: str) -> dict[str, Any]:
    """ShapeDtypeStructs for every model input of this (arch, shape) cell.

    train/prefill: full-sequence batch. decode: one new token + KV/SSM cache
    of seq_len. Modality frontends are stubs: precomputed embeddings appear
    as inputs (assignment: ``input_specs()`` provides frame/patch embeds).
    """
    sh = SHAPES[shape_name]
    B, S = sh["global_batch"], sh["seq_len"]
    dt = cfg.jnp_dtype
    if sh["kind"] in ("train", "prefill"):
        specs: dict[str, Any] = {
            "tokens": _sds((B, S), jnp.int32),
        }
        if sh["kind"] == "train":
            specs["labels"] = _sds((B, S), jnp.int32)
        if cfg.family == "vlm":
            specs["patch_embeds"] = _sds((B, cfg.n_image_tokens, cfg.d_model), dt)
        if cfg.family == "encdec":
            specs["frame_embeds"] = _sds((B, cfg.encoder_len, cfg.d_model), dt)
        return specs
    # decode: one token in, cache of length S
    from repro.models import api

    # (vlm patch / encdec frame context lives inside the cache at decode time)
    return {
        "tokens": _sds((B, 1), jnp.int32),
        "pos": _sds((B,), jnp.int32),
        "cache": api.cache_specs(cfg, batch=B, max_len=S),
    }


def cells(cfg: ArchConfig) -> list[str]:
    """The shape cells this arch runs (long_500k only if sub-quadratic;
    skips recorded in DESIGN.md §5)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        out.append("long_500k")
    return out
