"""zamba2-7b [hybrid] — arXiv:2411.15242.  Mamba2 backbone + shared attention
block (one parameter set, applied every 6 mamba blocks on
concat(hidden, original embedding)).  Sub-quadratic family: runs long_500k."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab=32_000,
    activation="swiglu",
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_ngroups=1,
    hybrid_attn_every=6,
)
