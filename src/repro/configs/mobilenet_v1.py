"""MobileNetV1 variants — the paper's CNN-B1/B2 (§V-A1)."""
CNN_B1 = dict(
    name="cnn-b1", kind="cnn", width_mult=0.5, resolution=128,
    n_classes=1000, macs=49_000_000,
)
CNN_B2 = dict(
    name="cnn-b2", kind="cnn", width_mult=1.0, resolution=224,
    n_classes=1000, macs=569_000_000,
)
