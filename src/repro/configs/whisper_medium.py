"""whisper-medium [audio] — arXiv:2212.04356.  Enc-dec; the conv/mel frontend
is a STUB (precomputed frame embeddings [B, 1500, d] as inputs)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,            # decoder layers
    n_encoder_layers=24,
    encoder_len=1500,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=51_865,
    activation="gelu",
    tie_embeddings=True,
)
