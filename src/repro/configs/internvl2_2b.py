"""internvl2-2b [vlm] — arXiv:2404.16821.  InternViT frontend (STUB:
precomputed patch embeddings arrive as inputs) + InternLM2-1.8B backbone."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=92_553,
    activation="swiglu",
    n_image_tokens=256,
    rope_theta=1_000_000.0,
)
