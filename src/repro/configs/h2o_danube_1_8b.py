"""h2o-danube-1.8b [dense] — arXiv:2401.16818.  llama+mistral mix, SWA.

Sliding-window attention makes this arch sub-quadratic: it runs the
long_500k decode cell with a rolling window cache.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab=32_000,
    activation="swiglu",
    sliding_window=4096,
    rope_theta=10_000.0,
)
