"""Quantizable linear layer — the paper's technique as a first-class module.

Three execution modes, selected by ``QuantConfig.mode``:

  * ``dense``      — ordinary ``x @ W`` (the fp baseline the paper compares to).
  * ``fake_quant`` — QAT/retraining: forward uses the binary reconstruction
                     W_hat = sum_m alpha_m B_m with a straight-through gradient
                     to the latent fp weights (paper §V-B1 retraining).
  * ``binary``     — deployment: weights stored bit-packed (uint8), the matmul
                     is  y = sum_{m<m_active} alpha_m (x @ B_m)  (paper Eq. 8),
                     executed either by the Pallas kernel (TPU) or the jnp
                     reference path (CPU / dry-run lowering).

``m_active`` implements the paper's runtime accuracy↔throughput switch
(§IV-D): a BinArray built with M levels can serve with any m_active <= M.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import binarize as bz


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    mode: str = "dense"             # dense | fake_quant | binary
    M: int = 2                      # number of binary levels (paper M)
    algorithm: int = 2              # 1 = Guo et al., 2 = paper's Algorithm 2
    K_iters: int = 8                # Alg-2 refinement budget inside jit
    group_size: int | None = None   # None = per-output-channel (paper)
    m_active: int | None = None     # runtime levels used (<= M); None = all
    m_schedule: tuple[int, ...] | None = None  # per-layer §IV-D schedule:
                                    # entry i is m_active for decoder layer i
                                    # (models.common.layer_quant_cfg resolves
                                    # it; forces unrolled layer walks)
    use_pallas: bool = False        # route binary mode through Pallas kernel
    interpret: bool = False         # Pallas interpret mode (CPU validation)
    fuse_conv: bool = False         # binary convs: fused implicit-GEMM kernel
                                    # (patches in VMEM, AMU epilogue) instead
                                    # of HBM im2col + matmul; needs use_pallas
    conv_batch_tile: int | None = None   # fused conv kernels: images folded
                                    # per program (NB); None = auto pick_tile
                                    # co-pick with the row tile
    conv_vmem_budget: int | None = None  # per-program VMEM budget override
                                    # for the (NB, BU) pick (bytes; None =
                                    # kernels' DEFAULT_VMEM_BUDGET)

    def replace(self, **kw: Any) -> "QuantConfig":
        return dataclasses.replace(self, **kw)


DENSE = QuantConfig(mode="dense")


def init_linear(key, in_dim: int, out_dim: int, dtype=jnp.float32, scale: float | None = None):
    """LeCun-normal weight init; returns {'w': [K, N]}."""
    s = scale if scale is not None else (1.0 / jnp.sqrt(in_dim))
    return {"w": (jax.random.normal(key, (in_dim, out_dim)) * s).astype(dtype)}


def binarize_params(params: dict, qc: QuantConfig) -> dict:
    """Offline conversion: fp weights -> packed binary deployment params.

    Returns {'B_packed': uint8 [M, ceil(K/8), N], 'alpha': [M, G, N]}
    (+ bias kept).  K is padded to a multiple of 8 if needed (padded rows
    multiply zero-padded activations).  Only array leaves — the static K /
    group_size are re-derived from shapes at apply time, so the packed tree
    is jit/eval_shape/checkpoint-safe.
    """
    W = params["w"]
    K, N = W.shape
    approx, _ = bz.approximate_tensor(
        W.astype(jnp.float32), qc.M, algorithm=qc.algorithm,
        K_iters=qc.K_iters, group_size=qc.group_size,
    )
    B, alpha = approx.B, approx.alpha
    pad = (-K) % 8
    if pad:
        B = jnp.concatenate([B, jnp.ones((qc.M, pad, N), jnp.int8)], axis=1)
    packed = bz.pack_bits(B)
    out = {"B_packed": packed, "alpha": alpha}
    if "b" in params:
        out["b"] = params["b"]
    return out


def apply_linear(params: dict, x: jax.Array, qc: QuantConfig = DENSE) -> jax.Array:
    """y = quantized-linear(x).  x: [..., K] -> [..., N].

    The execution path is keyed on the params' form: packed trees
    ('B_packed' present) always take the binary path; fp trees follow
    qc.mode (dense | fake_quant).
    """
    if "B_packed" in params:
        y = _apply_binary(params, x, qc)
    elif qc.mode == "fake_quant":
        W = params["w"].astype(jnp.float32)
        W_hat = bz.fake_quant(
            W, qc.M, algorithm=qc.algorithm, K_iters=qc.K_iters,
            group_size=qc.group_size,
        )
        y = x @ W_hat.astype(x.dtype)
    else:
        y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def _apply_binary(params: dict, x: jax.Array, qc: QuantConfig) -> jax.Array:
    """Deployment path over packed weights (paper Eq. 8).  The static K and
    group_size are re-derived from shapes: K = x's trailing dim, group_size
    = K // G (binarization guarantees exact division)."""
    K = x.shape[-1]
    G = params["alpha"].shape[1]
    group_size = K // G
    m_active = qc.m_active or params["alpha"].shape[0]
    if qc.use_pallas:
        from repro.kernels import ops as kops

        return kops.binary_matmul(
            x, params["B_packed"], params["alpha"],
            K=K, group_size=group_size,
            m_active=m_active, interpret=qc.interpret,
        )
    from repro.kernels import ref as kref

    y = kref.binary_matmul_ref(
        x, params["B_packed"], params["alpha"],
        K=K, group_size=group_size, m_active=m_active,
    )
    return y.astype(x.dtype)  # fp32 accumulate, caller dtype out
