"""Binary-approximated convolution — the paper's §III mapped to JAX.

A conv with binary-approximated filters is an im2col (patch extraction, the
AGU's job on the FPGA) followed by the binary dot product (the PA's job):

    O[b, u, v, d] = sum_m alpha_{m,d} * sum_{i} patch[b, u, v, i] * B_{m,i,d}

The fused ReLU+max-pool epilogue reproduces the AMU (paper Eq. 13).  The
dense (fp) path is the baseline the paper compares against.

Two execution strategies for the binary deployment path:

  * explicit im2col (``conv2d``): materializes the [B, U, V, kh*kw*C] patch
    tensor, then runs the binary matmul — simple, but the patch tensor is a
    kh·kw× HBM blow-up of the activation stream.
  * fused implicit GEMM (``conv2d_relu_pool`` with ``QuantConfig.fuse_conv``
    and ``use_pallas``): kernels/binary_conv.py extracts patches tile-by-tile
    in VMEM, runs the per-level bit-unpack + MXU matmul, and applies the AMU
    epilogue (bias + max-pool + ReLU) before write-back — the im2col tensor
    never exists in HBM and the output stream is already pooled.

``QuantConfig.m_active`` (paper §IV-D) selects how many of the packed levels
both paths apply at runtime — the serving-time accuracy↔throughput switch.
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp

from repro.core import binarize as bz
from repro.core.binlinear import QuantConfig, DENSE


def same_pads(size: int, k: int, stride: int) -> tuple[int, int]:
    """XLA-convention SAME padding (lo, hi) for one spatial dim.

    out = ceil(size/stride); total = (out-1)*stride + k - size, split with the
    extra element on the *high* side — asymmetric for even kernels, matching
    ``jax.lax.conv_general_dilated(padding="SAME")`` (e.g. CNN-A's 4x4 conv2).
    """
    out = -(-size // stride)
    total = max((out - 1) * stride + k - size, 0)
    return total // 2, total - total // 2


def im2col(x: jax.Array, kh: int, kw: int, stride: int = 1,
           padding: str = "VALID") -> jax.Array:
    """x: [B, H, W, C] -> patches [B, U, V, kh*kw*C] (row-major, like the
    paper's feature-buffer layout)."""
    B, H, W, C = x.shape
    if padding == "SAME":
        x = jnp.pad(x, ((0, 0), same_pads(H, kh, stride),
                        same_pads(W, kw, stride), (0, 0)))
        H, W = x.shape[1], x.shape[2]
    U = (H - kh) // stride + 1
    V = (W - kw) // stride + 1
    patches = jnp.stack(
        [x[:, u0: u0 + H - kh + 1: stride, v0: v0 + W - kw + 1: stride, :]
         for u0 in range(kh) for v0 in range(kw)], axis=3,
    )  # [B, U, V, kh*kw, C]
    return patches.reshape(B, U, V, kh * kw * C)


def conv2d(params: dict, x: jax.Array, *, stride: int = 1,
           padding: str = "VALID", quant: QuantConfig = DENSE) -> jax.Array:
    """Conv via im2col + (binary|dense) matmul.  params['w']: [kh,kw,C,D]."""
    if quant.mode == "binary":
        kh, kw = params["kh"], params["kw"]
    else:
        kh, kw, C, D = params["w"].shape
    patches = im2col(x, kh, kw, stride, padding)
    B, U, V, K = patches.shape
    flat = patches.reshape(B * U * V, K)
    if quant.mode == "dense":
        y = flat @ params["w"].reshape(K, -1).astype(flat.dtype)
    elif quant.mode == "fake_quant":
        W = params["w"].reshape(K, -1).astype(jnp.float32)
        W_hat = bz.fake_quant(W, quant.M, algorithm=quant.algorithm,
                              K_iters=quant.K_iters, group_size=quant.group_size)
        y = flat @ W_hat.astype(flat.dtype)
    elif quant.mode == "binary":
        Kf = flat.shape[-1]
        gs = Kf // params["alpha"].shape[1]
        if quant.use_pallas:
            from repro.kernels import ops as kops

            y = kops.binary_matmul(flat, params["B_packed"], params["alpha"],
                                   K=Kf, group_size=gs,
                                   m_active=quant.m_active,
                                   interpret=quant.interpret)
        else:
            from repro.kernels import ref as kref

            y = kref.binary_matmul_ref(flat, params["B_packed"], params["alpha"],
                                       K=Kf, group_size=gs,
                                       m_active=quant.m_active)
    else:
        raise ValueError(quant.mode)
    D_out = y.shape[-1]
    y = y.reshape(B, U, V, D_out)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def binarize_conv_params(params: dict, quant: QuantConfig) -> dict:
    """Offline: fp conv filters -> packed binary form (per-filter alpha).

    Emits both packings: the flat ``B_packed [M, ceil(K/8), D]`` stream
    (im2col + matmul path) and the per-tap ``B_tap_packed
    [M, kh*kw, ceil(C/8), D]`` layout the fused conv kernel consumes (each
    spatial tap's C-slice byte-aligned; see kernels/binary_conv.py).
    """
    kh, kw, C, D = params["w"].shape
    K = kh * kw * C
    W = params["w"].reshape(K, D).astype(jnp.float32)
    approx, _ = bz.approximate_tensor(
        W, quant.M, algorithm=quant.algorithm, K_iters=quant.K_iters,
        group_size=quant.group_size)
    from repro.kernels import binary_conv as bck

    B = approx.B
    tap_packed = bck.pack_taps(B, kh, kw, C)
    pad = (-K) % 8
    if pad:
        B = jnp.concatenate([B, jnp.ones((quant.M, pad, D), jnp.int8)], axis=1)
    out = {"B_packed": bz.pack_bits(B), "B_tap_packed": tap_packed,
           "alpha": approx.alpha,
           "kh": kh, "kw": kw}  # kh/kw: static ints (example-path only)
    if "b" in params:
        out["b"] = params["b"]
    return out


def binarize_dwconv_params(params: dict, quant: QuantConfig) -> dict:
    """Offline: fp depth-wise filters -> packed binary form (channel-wise).

    params['w']: [kh, kw, 1, C] (HWIO depth-wise layout).  The paper (§V-A3)
    approximates depth-wise layers channel-wise with D_arch = 1: each channel
    is one "filter" of kh·kw taps, so the approximation runs on the
    [kh*kw, C] matrix with per-channel alpha.  Emits the channel-packed
    ``B_tap_packed [M, kh*kw, ceil(C/8)]`` layout the fused dw kernel
    consumes (kernels/binary_dwconv.py) plus ``alpha [M, C]``.
    """
    kh, kw, one, C = params["w"].shape
    assert one == 1, f"expected HWIO depth-wise filters [kh,kw,1,C], got {params['w'].shape}"
    W = params["w"].reshape(kh * kw, C).astype(jnp.float32)
    approx, _ = bz.approximate_tensor(
        W, quant.M, algorithm=quant.algorithm, K_iters=quant.K_iters,
        group_size=None)  # per-column == per-channel (G = 1)
    from repro.kernels import binary_dwconv as bdw

    out = {"B_tap_packed": bdw.pack_dw_taps(approx.B),
           "alpha": approx.alpha[:, 0, :],     # [M, 1, C] -> [M, C]
           "kh": kh, "kw": kw}
    if "b" in params:
        out["b"] = params["b"]
    return out


def ensure_tap_packed(params: dict, C: int) -> dict:
    """One-time weight-layout upgrade for legacy packed conv trees.

    Packed trees that predate the fused kernel carry only the flat
    ``B_packed`` stream; the fused kernel consumes the per-tap
    ``B_tap_packed`` layout.  Call this once at load time (``C`` is the
    layer's input channel count — it cannot be recovered from the packed
    bytes alone because each tap pads to a byte boundary); hitting the
    conversion inside a traced forward instead re-runs the repack every
    call and raises a ``DeprecationWarning`` each time (see
    :func:`conv2d_relu_pool`).  The deploy compiler (repro.deploy.compile)
    calls this on legacy trees so a compiled program always carries
    ``B_tap_packed``.
    """
    if "B_tap_packed" in params or "B_packed" not in params:
        return params
    from repro.kernels import binary_conv as bck

    out = dict(params)
    out["B_tap_packed"] = bck.repack_taps(
        params["B_packed"], params["kh"], params["kw"], C)
    return out


def relu_maxpool(x: jax.Array, pool: int) -> jax.Array:
    """AMU: max-pool (downsampling only, paper §III-B) then ReLU == fused."""
    B, H, W, C = x.shape
    assert H % pool == 0 and W % pool == 0, "downsampling only (paper §III-B)"
    y = x.reshape(B, H // pool, pool, W // pool, pool, C).max(axis=(2, 4))
    return jnp.maximum(y, 0.0)


def conv2d_relu_pool(params: dict, x: jax.Array, *, stride: int = 1,
                     padding: str = "VALID", pool: int = 1,
                     quant: QuantConfig = DENSE) -> jax.Array:
    """Conv + bias + max-pool + ReLU — the paper's full PE→PA→AMU pipeline.

    With packed-binary params and ``quant.fuse_conv`` + ``quant.use_pallas``,
    routes to the fused implicit-GEMM Pallas kernel (kernels/binary_conv.py):
    patches are extracted in VMEM, the AMU epilogue runs before write-back,
    and the [B·U·V, kh·kw·C] im2col tensor never exists in HBM.  Any other
    configuration (dense / fake-quant / unfused binary / pool not dividing
    the conv output) falls back to ``conv2d`` + ``relu_maxpool`` —
    numerically equivalent, just unfused.
    """
    binary = "B_packed" in params or "B_tap_packed" in params
    if binary and quant.fuse_conv and quant.use_pallas:
        kh, kw = params["kh"], params["kw"]
        B, H, W, C = x.shape
        if padding == "SAME":
            (pt, pb), (pl_, pr) = same_pads(H, kh, stride), same_pads(W, kw, stride)
            Hp, Wp = H + pt + pb, W + pl_ + pr
        else:
            Hp, Wp = H, W
        U = (Hp - kh) // stride + 1
        V = (Wp - kw) // stride + 1
        if U % pool == 0 and V % pool == 0:
            tap = params.get("B_tap_packed")
            if tap is None:  # packed trees from before the fused kernel landed
                warnings.warn(
                    "conv params carry only the flat B_packed layout; the "
                    "per-call repack_taps path is deprecated and re-runs the "
                    "repack inside the traced forward on EVERY call.  Convert "
                    "the tree once at load time with "
                    "binconv.ensure_tap_packed(params, C), or compile it into "
                    "a BinArrayProgram (repro.deploy.compile) — both emit "
                    "B_tap_packed directly.",
                    DeprecationWarning, stacklevel=2)
                from repro.kernels import binary_conv as bck

                tap = bck.repack_taps(params["B_packed"], kh, kw, C)
            D = params["alpha"].shape[-1]
            bias = params.get("b")
            if bias is None:
                bias = jnp.zeros((D,), jnp.float32)
            from repro.kernels import ops as kops

            y = kops.binary_conv2d(
                x, tap, params["alpha"], bias, kh=kh, kw=kw, stride=stride,
                padding=padding, pool=pool, m_active=quant.m_active,
                nb=quant.conv_batch_tile,
                vmem_budget=quant.conv_vmem_budget,
                interpret=quant.interpret)
            return y.astype(x.dtype)
    y = conv2d(params, x, stride=stride, padding=padding, quant=quant)
    return relu_maxpool(y, pool)


def _dwconv_fp(w: jax.Array, x: jax.Array, stride: int) -> jax.Array:
    """fp depth-wise conv, SAME padding.  w: [kh, kw, 1, C] (HWIO groups)."""
    return jax.lax.conv_general_dilated(
        x, w.astype(x.dtype), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=x.shape[-1])


def depthwise_relu(params: dict, x: jax.Array, *, stride: int = 1,
                   quant: QuantConfig = DENSE) -> jax.Array:
    """Depth-wise conv + bias + ReLU — the paper's §V-A3 channel-wise stage.

    Path selection mirrors :func:`conv2d_relu_pool`:

      * packed-binary params ('B_tap_packed' [M, kh*kw, ceil(C/8)]) with
        ``quant.fuse_conv`` + ``quant.use_pallas``: the fused Pallas kernel
        (kernels/binary_dwconv.py) — the activations make one HBM round
        trip, the weights stream bit-packed, and **no fp ``lax.conv``
        runs** (the full-binary MobileNet requirement);
      * packed-binary params otherwise: the jnp oracle
        (kernels/ref.py binary_dwconv_relu_ref) — numerically the same
        reconstruction, HBM-bound;
      * fp params in ``fake_quant`` mode: STE-binarized W_hat (channel-wise,
        group_size = whole filter) through fp conv — the retraining path
        the packed deployment must match;
      * fp params otherwise: plain dense conv (the fp baseline).

    Depth-wise layers always use SAME padding (MobileNet's only variant).
    """
    if "B_tap_packed" in params:
        kh, kw = params["kh"], params["kw"]
        C = x.shape[-1]
        bias = params.get("b")
        if bias is None:
            bias = jnp.zeros((C,), jnp.float32)
        if quant.fuse_conv and quant.use_pallas:
            from repro.kernels import ops as kops

            y = kops.binary_dwconv2d(
                x, params["B_tap_packed"], params["alpha"], bias,
                kh=kh, kw=kw, stride=stride, padding="SAME",
                m_active=quant.m_active, nb=quant.conv_batch_tile,
                vmem_budget=quant.conv_vmem_budget,
                interpret=quant.interpret)
        else:
            from repro.kernels import ref as kref

            y = kref.binary_dwconv_relu_ref(
                x, params["B_tap_packed"], params["alpha"], kh=kh, kw=kw,
                stride=stride, padding="SAME", m_active=quant.m_active,
                bias=bias)
        return y.astype(x.dtype)
    w = params["w"]
    if quant.mode == "fake_quant":
        kh, kw, one, C = w.shape
        W_hat = bz.fake_quant(
            w.reshape(kh * kw, C).astype(jnp.float32), quant.M,
            algorithm=quant.algorithm, K_iters=quant.K_iters,
            group_size=None)  # channel-wise, like binarize_dwconv_params
        w = W_hat.reshape(kh, kw, one, C).astype(x.dtype)
    y = _dwconv_fp(w, x, stride)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return jax.nn.relu(y)
