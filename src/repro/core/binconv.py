"""Binary-approximated convolution — the paper's §III mapped to JAX.

A conv with binary-approximated filters is an im2col (patch extraction, the
AGU's job on the FPGA) followed by the binary dot product (the PA's job):

    O[b, u, v, d] = sum_m alpha_{m,d} * sum_{i} patch[b, u, v, i] * B_{m,i,d}

The fused ReLU+max-pool epilogue reproduces the AMU (paper Eq. 13).  The
dense (fp) path is the baseline the paper compares against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import binarize as bz
from repro.core.binlinear import QuantConfig, DENSE


def im2col(x: jax.Array, kh: int, kw: int, stride: int = 1,
           padding: str = "VALID") -> jax.Array:
    """x: [B, H, W, C] -> patches [B, U, V, kh*kw*C] (row-major, like the
    paper's feature-buffer layout)."""
    B, H, W, C = x.shape
    if padding == "SAME":
        ph, pw = kh // 2, kw // 2
        x = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
        H, W = x.shape[1], x.shape[2]
    U = (H - kh) // stride + 1
    V = (W - kw) // stride + 1
    idx_u = jnp.arange(U) * stride
    idx_v = jnp.arange(V) * stride
    patches = jnp.stack(
        [x[:, u0: u0 + H - kh + 1: stride, v0: v0 + W - kw + 1: stride, :]
         for u0 in range(kh) for v0 in range(kw)], axis=3,
    )  # [B, U, V, kh*kw, C]
    del idx_u, idx_v
    return patches.reshape(B, U, V, kh * kw * C)


def conv2d(params: dict, x: jax.Array, *, stride: int = 1,
           padding: str = "VALID", quant: QuantConfig = DENSE) -> jax.Array:
    """Conv via im2col + (binary|dense) matmul.  params['w']: [kh,kw,C,D]."""
    if quant.mode == "binary":
        kh, kw = params["kh"], params["kw"]
    else:
        kh, kw, C, D = params["w"].shape
    patches = im2col(x, kh, kw, stride, padding)
    B, U, V, K = patches.shape
    flat = patches.reshape(B * U * V, K)
    if quant.mode == "dense":
        y = flat @ params["w"].reshape(K, -1).astype(flat.dtype)
    elif quant.mode == "fake_quant":
        W = params["w"].reshape(K, -1).astype(jnp.float32)
        W_hat = bz.fake_quant(W, quant.M, algorithm=quant.algorithm,
                              K_iters=quant.K_iters, group_size=quant.group_size)
        y = flat @ W_hat.astype(flat.dtype)
    elif quant.mode == "binary":
        Kf = flat.shape[-1]
        gs = Kf // params["alpha"].shape[1]
        if quant.use_pallas:
            from repro.kernels import ops as kops

            y = kops.binary_matmul(flat, params["B_packed"], params["alpha"],
                                   K=Kf, group_size=gs,
                                   m_active=quant.m_active,
                                   interpret=quant.interpret)
        else:
            from repro.kernels import ref as kref

            y = kref.binary_matmul_ref(flat, params["B_packed"], params["alpha"],
                                       K=Kf, group_size=gs,
                                       m_active=quant.m_active)
    else:
        raise ValueError(quant.mode)
    D_out = y.shape[-1]
    y = y.reshape(B, U, V, D_out)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def binarize_conv_params(params: dict, quant: QuantConfig) -> dict:
    """Offline: fp conv filters -> packed binary form (per-filter alpha)."""
    kh, kw, C, D = params["w"].shape
    K = kh * kw * C
    W = params["w"].reshape(K, D).astype(jnp.float32)
    approx, _ = bz.approximate_tensor(
        W, quant.M, algorithm=quant.algorithm, K_iters=quant.K_iters,
        group_size=quant.group_size)
    B = approx.B
    pad = (-K) % 8
    if pad:
        B = jnp.concatenate([B, jnp.ones((quant.M, pad, D), jnp.int8)], axis=1)
    out = {"B_packed": bz.pack_bits(B), "alpha": approx.alpha,
           "kh": kh, "kw": kw}  # kh/kw: static ints (example-path only)
    if "b" in params:
        out["b"] = params["b"]
    return out


def relu_maxpool(x: jax.Array, pool: int) -> jax.Array:
    """AMU: max-pool (downsampling only, paper §III-B) then ReLU == fused."""
    B, H, W, C = x.shape
    assert H % pool == 0 and W % pool == 0, "downsampling only (paper §III-B)"
    y = x.reshape(B, H // pool, pool, W // pool, pool, C).max(axis=(2, 4))
    return jnp.maximum(y, 0.0)
