"""Fixed-point quantization emulation (paper §III-C).

The FPGA datapath uses DW=8-bit fixed-point activations with a per-layer
binary-point position, MULW=28-bit accumulation inside the DSP cascade, and
round-to-nearest + saturation when quantizing PA outputs back to DW bits
before the AMU.  On TPU we keep fp32 accumulation (strictly wider than 28-bit
fixed point) but provide a bit-faithful emulation of the DW-bit
activation quantizer so the paper's "bit-accurate Python model" verification
(§V-A2) can be reproduced, and an int8 activation path for deployment.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

DW = 8        # activation data width (paper)
MULW = 28     # DSP accumulation width (paper; informational — we use fp32)


class FixedPointSpec(NamedTuple):
    """Per-layer fixed-point format: DW total bits, `frac` fractional bits."""

    bits: int = DW
    frac: int = 4  # binary point position; layer-dependent in the paper


def quantize_fixed(x: jax.Array, spec: FixedPointSpec) -> jax.Array:
    """Round-to-nearest, saturate — the QS block of the SA (paper Fig. 7).

    Emulates signed (bits, frac) fixed point on fp values: scale by 2^frac,
    round, clip to [-2^(bits-1), 2^(bits-1)-1], rescale.
    """
    scale = jnp.asarray(2.0**spec.frac, x.dtype)
    lo = -(2 ** (spec.bits - 1))
    hi = 2 ** (spec.bits - 1) - 1
    q = jnp.clip(jnp.round(x * scale), lo, hi)
    return q / scale


@jax.custom_vjp
def quantize_fixed_ste(x: jax.Array, scale: jax.Array, lo: float, hi: float):
    return jnp.clip(jnp.round(x * scale), lo, hi) / scale


def _qfs_fwd(x, scale, lo, hi):
    return quantize_fixed_ste(x, scale, lo, hi), None


def _qfs_bwd(_, g):
    return g, None, None, None


quantize_fixed_ste.defvjp(_qfs_fwd, _qfs_bwd)


def fake_quant_activation(x: jax.Array, spec: FixedPointSpec) -> jax.Array:
    """STE-wrapped activation quantizer for QAT with the fixed-point datapath."""
    scale = jnp.asarray(2.0**spec.frac, x.dtype)
    lo = float(-(2 ** (spec.bits - 1)))
    hi = float(2 ** (spec.bits - 1) - 1)
    return quantize_fixed_ste(x, scale, lo, hi)


def choose_frac_bits(x_absmax: float, bits: int = DW) -> int:
    """Pick the binary-point position covering |x| <= x_absmax (per layer)."""
    import math

    if x_absmax <= 0:
        return bits - 1
    int_bits = max(0, math.ceil(math.log2(x_absmax + 1e-12)) + 1)  # sign incl.
    return max(0, bits - 1 - int_bits)


# --- int8 symmetric activation quant (deployment path) ---------------------

class Int8Quant(NamedTuple):
    values: jax.Array   # int8
    scale: jax.Array    # fp32 per-tensor (or per-row) scale


def quantize_int8(x: jax.Array, axis: int | None = None) -> Int8Quant:
    absmax = (
        jnp.max(jnp.abs(x)) if axis is None else jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    )
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale), -128, 127).astype(jnp.int8)
    return Int8Quant(values=q, scale=scale.astype(jnp.float32))


def dequantize_int8(q: Int8Quant) -> jax.Array:
    return q.values.astype(jnp.float32) * q.scale
