"""BinArray analytical performance model (paper §IV-E, Eq. 14-18).

Predicts cycles/frame and fps for a BinArray[N_SA, D_arch, M_arch] given a
layer list.  Two variants:

  * ``cc_layer`` — MAC-exact: every output pixel needs W_B·H_B·C_I
    accumulations per binary level group; D_arch output channels in
    parallel; N_pass passes when D > D_arch·N_LSA (Eq. 17).  The dense-layer
    formula reproduces the paper's Table III composition exactly (the
    819.8 fps CNN-A figure decomposes into 466,668 conv + 21,270 dense cc at
    400 MHz with this dense model).
  * ``cc_layer_eq18`` — the literal Eq. 18 text (W_I·H_I·C_I·W_B·H_I·N_pass/N_T);
    kept for reference — the H_I factor where H_B is expected makes it
    inconsistent with the paper's own fps tables (documented in
    benchmarks/table3_throughput.py).

Throughput mode (paper §IV-D): M > M_arch costs ceil(M/M_arch) passes via
N_LSA (Eq. 15).
"""
from __future__ import annotations

import dataclasses
import math

CLOCK_HZ = 400e6  # paper §V-B2: timing closure at 400 MHz on XC7Z045-2


@dataclasses.dataclass(frozen=True)
class BinArrayConfig:
    N_SA: int
    D_arch: int
    M_arch: int

    def __str__(self):
        return f"BinArray[{self.N_SA},{self.D_arch},{self.M_arch}]"


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    W_I: int; H_I: int; C_I: int       # input feature dims
    W_B: int; H_B: int; D: int         # kernel dims, output channels
    stride: int = 1
    padding: int = 0
    depthwise: bool = False            # paper §V-A3: D_arch=1 for depth-wise

    @property
    def out_dims(self):
        """Eq. 14."""
        U = (self.W_I - self.W_B + 2 * self.padding) // self.stride + 1
        V = (self.H_I - self.H_B + 2 * self.padding) // self.stride + 1
        return U, V, self.D

    @property
    def macs(self) -> int:
        U, V, D = self.out_dims
        if self.depthwise:
            return U * V * D * self.W_B * self.H_B
        return U * V * D * self.W_B * self.H_B * self.C_I


@dataclasses.dataclass(frozen=True)
class DenseLayer:
    N_in: int
    N_out: int

    @property
    def macs(self) -> int:
        return self.N_in * self.N_out


def n_lsa(cfg: BinArrayConfig, M: int) -> float:
    """Eq. 15: logical SAs after folding M over M_arch passes."""
    return cfg.N_SA / math.ceil(M / cfg.M_arch)


def n_tiles(cfg: BinArrayConfig, layer: ConvLayer, M: int) -> int:
    """Eq. 16 (with the feasibility constraint W_I/N_T > 1)."""
    lsa = n_lsa(cfg, M)
    d_arch = 1 if layer.depthwise else cfg.D_arch
    nt = int(lsa // math.ceil(layer.D / d_arch))
    nt = max(nt, 1)
    while nt > 1 and (layer.W_I / nt <= 1 or layer.H_I / nt <= 1):
        nt -= 1
    return nt


def n_pass(cfg: BinArrayConfig, D: int, M: int, depthwise: bool = False) -> int:
    """Eq. 17."""
    d_arch = 1 if depthwise else cfg.D_arch
    lsa = max(n_lsa(cfg, M), 1e-9)
    return math.ceil(max(1.0, D / (d_arch * lsa)))


def cc_layer(cfg: BinArrayConfig, layer, M: int) -> float:
    """MAC-exact cycle count for one layer."""
    if isinstance(layer, DenseLayer):
        # each PE accumulates N_in inputs; D_arch·N_LSA neurons in parallel
        passes = n_pass(cfg, layer.N_out, M)
        return layer.N_in * passes
    U, V, D = layer.out_dims
    d_arch = 1 if layer.depthwise else cfg.D_arch
    passes = n_pass(cfg, D, M, layer.depthwise)
    nt = n_tiles(cfg, layer, M)
    per_pixel = layer.W_B * layer.H_B * (1 if layer.depthwise else layer.C_I)
    return U * V * per_pixel * passes / nt


def cc_layer_eq18(cfg: BinArrayConfig, layer: ConvLayer, M: int) -> float:
    """Literal paper Eq. 18 (documented inconsistency — see module doc)."""
    passes = n_pass(cfg, layer.D, M, layer.depthwise)
    nt = n_tiles(cfg, layer, M)
    return (layer.W_I * layer.H_I * layer.C_I * layer.W_B * layer.H_I
            * passes) / nt


def fps(cfg: BinArrayConfig, layers, M: int, *, clock_hz: float = CLOCK_HZ,
        exclude_final_dense: bool = False) -> float:
    """Frames/s for a network (paper offloads MobileNet's final dense+GAP to
    the CPU — exclude_final_dense reproduces that)."""
    use = list(layers)
    if exclude_final_dense:
        while use and isinstance(use[-1], DenseLayer):
            use.pop()
    total = sum(cc_layer(cfg, l, M) for l in use)
    return clock_hz / total


def total_macs(layers) -> int:
    return sum(l.macs for l in layers)


def cpu_fps(layers, *, gops: float = 1e9) -> float:
    """The paper's hypothetical 1-GOPS CPU baseline (Table III)."""
    return gops / total_macs(layers)


# ---------------------------------------------------------------------------
# Reference networks (paper §V-A1) as layer lists
# ---------------------------------------------------------------------------

def cnn_a_layers():
    return [
        ConvLayer(48, 48, 3, 7, 7, 5),
        ConvLayer(21, 21, 5, 4, 4, 150),
        DenseLayer(1350, 340),
        DenseLayer(340, 490),
        DenseLayer(490, 43),
    ]


def mobilenet_layers(*, alpha: float = 1.0, resolution: int = 224):
    """MobileNetV1 (CNN-B1: alpha=.5 res=128; CNN-B2: alpha=1 res=224)."""
    def c(ch):
        return max(8, int(ch * alpha))

    blocks = [(1, 64), (2, 128), (1, 128), (2, 256), (1, 256), (2, 512),
              (1, 512), (1, 512), (1, 512), (1, 512), (1, 512), (2, 1024),
              (1, 1024)]
    layers = []
    r = resolution // 2
    cin = c(32)
    layers.append(ConvLayer(resolution, resolution, 3, 3, 3, cin, stride=2,
                            padding=1))
    for stride, cout in blocks:
        cout = c(cout)
        layers.append(ConvLayer(r, r, cin, 3, 3, cin, stride=stride,
                                padding=1, depthwise=True))
        r = r // stride
        layers.append(ConvLayer(r, r, cin, 1, 1, cout))
        cin = cout
    layers.append(DenseLayer(cin, 1000))
    return layers
