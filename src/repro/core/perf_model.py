"""BinArray analytical performance model (paper §IV-E, Eq. 14-18).

Predicts cycles/frame and fps for a BinArray[N_SA, D_arch, M_arch] given a
layer list.  Two variants:

  * ``cc_layer`` — MAC-exact: every output pixel needs W_B·H_B·C_I
    accumulations per binary level group; D_arch output channels in
    parallel; N_pass passes when D > D_arch·N_LSA (Eq. 17).  The dense-layer
    formula reproduces the paper's Table III composition exactly (the
    819.8 fps CNN-A figure decomposes into 466,668 conv + 21,270 dense cc at
    400 MHz with this dense model).
  * ``cc_layer_eq18`` — the literal Eq. 18 text (W_I·H_I·C_I·W_B·H_I·N_pass/N_T);
    kept for reference — the H_I factor where H_B is expected makes it
    inconsistent with the paper's own fps tables (documented in
    benchmarks/table3_throughput.py).

Throughput mode (paper §IV-D): M > M_arch costs ceil(M/M_arch) passes via
N_LSA (Eq. 15).
"""
from __future__ import annotations

import dataclasses
import functools
import math

CLOCK_HZ = 400e6  # paper §V-B2: timing closure at 400 MHz on XC7Z045-2


@dataclasses.dataclass(frozen=True)
class BinArrayConfig:
    N_SA: int
    D_arch: int
    M_arch: int

    def __str__(self):
        return f"BinArray[{self.N_SA},{self.D_arch},{self.M_arch}]"


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    W_I: int; H_I: int; C_I: int       # input feature dims
    W_B: int; H_B: int; D: int         # kernel dims, output channels
    stride: int = 1
    padding: int = 0
    depthwise: bool = False            # paper §V-A3: D_arch=1 for depth-wise

    @property
    def out_dims(self):
        """Eq. 14."""
        U = (self.W_I - self.W_B + 2 * self.padding) // self.stride + 1
        V = (self.H_I - self.H_B + 2 * self.padding) // self.stride + 1
        return U, V, self.D

    @property
    def macs(self) -> int:
        U, V, D = self.out_dims
        if self.depthwise:
            return U * V * D * self.W_B * self.H_B
        return U * V * D * self.W_B * self.H_B * self.C_I


@dataclasses.dataclass(frozen=True)
class DenseLayer:
    N_in: int
    N_out: int

    @property
    def macs(self) -> int:
        return self.N_in * self.N_out


def n_lsa(cfg: BinArrayConfig, M: int) -> float:
    """Eq. 15: logical SAs after folding M over M_arch passes."""
    return cfg.N_SA / math.ceil(M / cfg.M_arch)


def n_tiles(cfg: BinArrayConfig, layer: ConvLayer, M: int) -> int:
    """Eq. 16 (with the feasibility constraint W_I/N_T > 1)."""
    lsa = n_lsa(cfg, M)
    d_arch = 1 if layer.depthwise else cfg.D_arch
    nt = int(lsa // math.ceil(layer.D / d_arch))
    nt = max(nt, 1)
    while nt > 1 and (layer.W_I / nt <= 1 or layer.H_I / nt <= 1):
        nt -= 1
    return nt


def n_pass(cfg: BinArrayConfig, D: int, M: int, depthwise: bool = False) -> int:
    """Eq. 17."""
    d_arch = 1 if depthwise else cfg.D_arch
    lsa = max(n_lsa(cfg, M), 1e-9)
    return math.ceil(max(1.0, D / (d_arch * lsa)))


def cc_layer(cfg: BinArrayConfig, layer, M: int) -> float:
    """MAC-exact cycle count for one layer."""
    if isinstance(layer, DenseLayer):
        # each PE accumulates N_in inputs; D_arch·N_LSA neurons in parallel
        passes = n_pass(cfg, layer.N_out, M)
        return layer.N_in * passes
    U, V, D = layer.out_dims
    d_arch = 1 if layer.depthwise else cfg.D_arch
    passes = n_pass(cfg, D, M, layer.depthwise)
    nt = n_tiles(cfg, layer, M)
    per_pixel = layer.W_B * layer.H_B * (1 if layer.depthwise else layer.C_I)
    return U * V * per_pixel * passes / nt


def cc_layer_eq18(cfg: BinArrayConfig, layer: ConvLayer, M: int) -> float:
    """Literal paper Eq. 18 (documented inconsistency — see module doc)."""
    passes = n_pass(cfg, layer.D, M, layer.depthwise)
    nt = n_tiles(cfg, layer, M)
    return (layer.W_I * layer.H_I * layer.C_I * layer.W_B * layer.H_I
            * passes) / nt


def fps(cfg: BinArrayConfig, layers, M: int, *, clock_hz: float = CLOCK_HZ,
        exclude_final_dense: bool = False) -> float:
    """Frames/s for a network (paper offloads MobileNet's final dense+GAP to
    the CPU — exclude_final_dense reproduces that)."""
    use = list(layers)
    if exclude_final_dense:
        while use and isinstance(use[-1], DenseLayer):
            use.pop()
    total = sum(cc_layer(cfg, lyr, M) for lyr in use)
    return clock_hz / total


def total_macs(layers) -> int:
    return sum(lyr.macs for lyr in layers)


def cpu_fps(layers, *, gops: float = 1e9) -> float:
    """The paper's hypothetical 1-GOPS CPU baseline (Table III)."""
    return gops / total_macs(layers)


# ---------------------------------------------------------------------------
# Reference networks (paper §V-A1) as layer lists — derived from the deploy
# compiler's program.layer_stats(), not hand-maintained: the LayerSpec lists
# in models/cnn.py are the single topology source of truth, and an abstract
# compile (jax.eval_shape — no weights ever computed) turns them into the
# same per-layer geometry this model consumes.
# ---------------------------------------------------------------------------

def _infer_pad(in_dim: int, k: int, stride: int, out_dim: int) -> int:
    """Symmetric padding p with (in - k + 2p)//stride + 1 == out (Eq. 14)."""
    for p in range(0, k + 1):
        if (in_dim - k + 2 * p) // stride + 1 == out_dim:
            return p
    raise ValueError(f"no symmetric pad reproduces {in_dim}->{out_dim} "
                     f"(k={k}, stride={stride})")


def layers_from_stats(stats: list[dict]) -> list:
    """program.layer_stats() -> [ConvLayer | DenseLayer] for Eq. 14-18."""
    out = []
    for s in stats:
        if s["kind"] == "linear":
            out.append(DenseLayer(s["K"], s["out_shape"][-1]))
            continue
        _, H, W, C = s["in_shape"]
        U = s["out_shape"][1] * s.get("pool", 1)   # conv rows before the AMU
        out.append(ConvLayer(
            W_I=W, H_I=H, C_I=C, W_B=s["kw"], H_B=s["kh"],
            D=s["out_shape"][-1], stride=s["stride"],
            padding=_infer_pad(H, s["kh"], s["stride"], U),
            depthwise=(s["kind"] == "dwconv")))
    return out


def layers_from_program(program) -> list:
    """A compiled (or abstract) BinArrayProgram -> perf-model layer list."""
    return layers_from_stats(program.layer_stats())


@functools.lru_cache(maxsize=None)
def _net_stats(arch: str, width_mult: float, resolution: int) -> tuple:
    from repro import deploy  # deferred: core must not hard-depend on deploy
    from repro.core.binlinear import QuantConfig

    qc = QuantConfig(mode="binary", M=2, K_iters=1)
    shape = ((1, 48, 48, 3) if arch == "cnn_a"
             else (1, resolution, resolution, 3))
    prog = deploy.abstract_program(arch, qc, shape, width_mult=width_mult)
    return tuple(prog.layer_stats())


def cnn_a_layers():
    return layers_from_stats(list(_net_stats("cnn_a", 1.0, 48)))


def mobilenet_layers(*, alpha: float = 1.0, resolution: int = 224):
    """MobileNetV1 (CNN-B1: alpha=.5 res=128; CNN-B2: alpha=1 res=224)."""
    return layers_from_stats(list(_net_stats("mobilenet", alpha, resolution)))
