"""Multi-level binary weight approximation (BinArray, §II).

Implements:
  * Algorithm 1 (Guo et al., CVPR'17 "Network Sketching", as restated in the
    paper): greedy residual binarization followed by one least-squares solve
    for the scaling factors alpha.
  * Algorithm 2 (the paper's contribution): alternate between re-deriving the
    binary tensors B from the current *optimal* alpha and re-solving the
    least-squares system, until B is stable or K iterations.
  * Group-wise approximation: the paper binarizes per filter (= per output
    channel).  We generalize to groups along the reduction axis (group_size),
    which subsumes the paper's scheme (group_size == K) and allows finer
    accuracy control ("beyond paper", DESIGN.md §7).
  * Bit-packing of the ±1 tensors into uint8 (8 weights/byte) for the
    memory-roofline win on TPU, plus unpacking.
  * Compression-factor computation (paper Eq. 6).

Conventions
-----------
Weight matrices are stored as ``W[K, N]`` (reduction dim first, like
``x @ W``).  The paper's "filter" == one output channel == one column of W.
Binary tensors are ``B[M, K, N]`` (int8, values in {-1, +1}) and scales are
``alpha[M, G, N]`` where ``G = K // group_size`` (G == 1 reproduces the paper
exactly).  All functions are jit-able and differentiable where meaningful.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class BinApprox(NamedTuple):
    """Multi-level binary approximation of a weight matrix W[K, N]."""

    B: jax.Array          # [M, K, N] int8, values in {-1, +1}
    alpha: jax.Array      # [M, G, N] float32, per-(level, group, out-channel) scale
    group_size: int       # reduction-dim group size; K // group_size == G

    @property
    def M(self) -> int:
        return self.B.shape[0]

    @property
    def K(self) -> int:
        return self.B.shape[1]

    @property
    def N(self) -> int:
        return self.B.shape[2]


def _expand_alpha(alpha: jax.Array, K: int, group_size: int) -> jax.Array:
    """alpha[M, G, N] -> per-element scale [M, K, N] by repeating over groups."""
    return jnp.repeat(alpha, group_size, axis=1, total_repeat_length=K)


def reconstruct(approx: BinApprox) -> jax.Array:
    """W_hat = sum_m alpha_m * B_m   (paper Eq. 1), float32 [K, N]."""
    a = _expand_alpha(approx.alpha, approx.K, approx.group_size)
    return jnp.sum(a * approx.B.astype(jnp.float32), axis=0)


def residual_error(W: jax.Array, approx: BinApprox) -> jax.Array:
    """||W - W_hat||^2 (paper Eq. 4 objective), scalar."""
    return jnp.sum((W.astype(jnp.float32) - reconstruct(approx)) ** 2)


# ---------------------------------------------------------------------------
# Least-squares solve for alpha given B (paper Eq. 5)
# ---------------------------------------------------------------------------

def solve_alpha(W: jax.Array, B: jax.Array, group_size: int) -> jax.Array:
    """Optimal alpha for given binary tensors (paper Eq. 5), per group & column.

    For each (group g, column n) solves the M-dim normal equations
        (B_g^T B_g) alpha = B_g^T w_g
    where B_g is the [group_size, M] slice.  Singular Gram matrices (duplicate
    binary tensors) are handled with a pseudo-inverse-style ridge.
    """
    M, K, N = B.shape
    G = K // group_size
    Bf = B.astype(jnp.float32).reshape(M, G, group_size, N)
    Wf = W.astype(jnp.float32).reshape(G, group_size, N)
    # Gram[G, N, M, M] and rhs[G, N, M]
    gram = jnp.einsum("mgkn,lgkn->gnml", Bf, Bf)
    rhs = jnp.einsum("mgkn,gkn->gnm", Bf, Wf)
    # Ridge for rank-deficient B (e.g. B_m == B_l): tiny relative jitter.
    eye = jnp.eye(M, dtype=jnp.float32)
    jitter = 1e-6 * jnp.maximum(jnp.trace(gram, axis1=-2, axis2=-1), 1.0)
    gram = gram + eye * jitter[..., None, None]
    alpha = jnp.linalg.solve(gram, rhs[..., None])[..., 0]  # [G, N, M]
    return jnp.transpose(alpha, (2, 0, 1))  # [M, G, N]


# ---------------------------------------------------------------------------
# Algorithm 1 (Guo et al. / paper Algorithm 1)
# ---------------------------------------------------------------------------

def _greedy_binarize(W: jax.Array, M: int, group_size: int) -> tuple[jax.Array, jax.Array]:
    """Steps 1-5 of Algorithm 1: greedy residual binarization.

    Returns (B[M,K,N] int8, alpha_hat[M,G,N]) where alpha_hat are the greedy
    mean-|residual| estimates (paper step 4).
    """
    K, N = W.shape
    G = K // group_size

    def body(carry, _):
        dW = carry
        Bm = jnp.where(dW >= 0, 1.0, -1.0)
        # mean(|dW|) per (group, column) — paper: mean(dW ⊙ B_m) over the filter
        a = jnp.mean(
            jnp.abs(dW).reshape(G, group_size, N), axis=1
        )  # [G, N]
        dW = dW - Bm * jnp.repeat(a, group_size, axis=0, total_repeat_length=K)
        return dW, (Bm.astype(jnp.int8), a)

    _, (B, alpha_hat) = jax.lax.scan(body, W.astype(jnp.float32), None, length=M)
    return B, alpha_hat


def algorithm1(W: jax.Array, M: int, *, group_size: int | None = None) -> BinApprox:
    """Paper Algorithm 1: greedy B, then one LS solve for alpha (Eq. 5)."""
    K, N = W.shape
    group_size = K if group_size is None else group_size
    if K % group_size:
        raise ValueError(f"group_size {group_size} must divide K={K}")
    B, _ = _greedy_binarize(W, M, group_size)
    alpha = solve_alpha(W, B, group_size)
    return BinApprox(B=B, alpha=alpha, group_size=group_size)


# ---------------------------------------------------------------------------
# Algorithm 2 (the paper's contribution)
# ---------------------------------------------------------------------------

def algorithm2(
    W: jax.Array,
    M: int,
    *,
    K_iters: int = 100,
    group_size: int | None = None,
) -> BinApprox:
    """Paper Algorithm 2: alternate B-refinement and LS alpha until stable.

    Lines 3-11 of the paper: starting from Algorithm 1's (B, alpha), re-derive
    each B_m as sign of the residual under the *optimal* alpha (not the greedy
    estimate), then re-solve Eq. 5; stop when B is unchanged or after K_iters.
    Implemented with lax.while_loop so it jit-compiles; the early-exit
    condition (B == B_old) is honored exactly.
    """
    Kdim, N = W.shape
    group_size = Kdim if group_size is None else group_size
    if Kdim % group_size:
        raise ValueError(f"group_size {group_size} must divide K={Kdim}")
    init = algorithm1(W, M, group_size=group_size)
    Wf = W.astype(jnp.float32)

    def refine_B(alpha: jax.Array) -> jax.Array:
        """Lines 6-9: greedy sign pass using the current optimal alpha."""
        def body(carry, am):
            dW = carry
            Bm = jnp.where(dW >= 0, 1.0, -1.0)
            dW = dW - Bm * jnp.repeat(
                am, group_size, axis=0, total_repeat_length=Kdim
            )
            return dW, Bm.astype(jnp.int8)

        _, B = jax.lax.scan(body, Wf, alpha)  # alpha scanned over M
        return B

    def cond(state):
        it, B, B_old, _ = state
        changed = jnp.any(B != B_old)
        return jnp.logical_and(it < K_iters, changed)

    def body(state):
        it, B, _, alpha = state
        B_new = refine_B(alpha)
        alpha_new = solve_alpha(W, B_new, group_size)
        return (it + 1, B_new, B, alpha_new)

    # Seed B_old with ~B so the loop runs at least once.
    state0 = (jnp.int32(0), init.B, -init.B, init.alpha)
    _, B, _, alpha = jax.lax.while_loop(cond, body, state0)
    return BinApprox(B=B, alpha=alpha, group_size=group_size)


# ---------------------------------------------------------------------------
# Generic tensor entry points (conv kernels, stacked layers, ...)
# ---------------------------------------------------------------------------

def approximate_tensor(
    W: jax.Array,
    M: int,
    *,
    algorithm: int = 2,
    K_iters: int = 100,
    group_size: int | None = None,
    reduce_axes: tuple[int, ...] | None = None,
) -> tuple[BinApprox, tuple[int, ...]]:
    """Binarize an arbitrary-rank weight tensor.

    ``reduce_axes`` are the contraction axes (flattened into K); the remaining
    axes are output channels (flattened into N).  Returns the approximation of
    the [K, N] matrix plus the permutation used, so callers can reshape back.
    Conv kernels HWIO use reduce_axes=(0,1,2); the paper's per-filter scheme
    falls out as group_size=None (= whole filter).
    """
    if reduce_axes is None:
        reduce_axes = tuple(range(W.ndim - 1))
    out_axes = tuple(i for i in range(W.ndim) if i not in reduce_axes)
    perm = reduce_axes + out_axes
    Wm = jnp.transpose(W, perm)
    K = int(np.prod([W.shape[i] for i in reduce_axes]))
    N = int(np.prod([W.shape[i] for i in out_axes])) if out_axes else 1
    Wm = Wm.reshape(K, N)
    fn = algorithm2 if algorithm == 2 else algorithm1
    kwargs = {"group_size": group_size}
    if algorithm == 2:
        kwargs["K_iters"] = K_iters
    return fn(Wm, M, **kwargs), perm


# ---------------------------------------------------------------------------
# Bit packing (TPU adaptation: 1-bit weights in HBM)
# ---------------------------------------------------------------------------

def pack_bits(B: jax.Array) -> jax.Array:
    """Pack ±1 int8 [M, K, N] -> uint8 [M, K//8, N]; bit j of byte k is B[8k+j].

    +1 -> bit 1, -1 -> bit 0.  K must be a multiple of 8 (pad upstream).
    """
    M, K, N = B.shape
    if K % 8:
        raise ValueError(f"K={K} must be a multiple of 8 for packing")
    bits = (B > 0).astype(jnp.uint8).reshape(M, K // 8, 8, N)
    shifts = jnp.arange(8, dtype=jnp.uint8).reshape(1, 1, 8, 1)
    return jnp.sum(bits << shifts, axis=2).astype(jnp.uint8)


def unpack_bits(packed: jax.Array, K: int) -> jax.Array:
    """uint8 [M, K//8, N] -> ±1 int8 [M, K, N] (inverse of pack_bits)."""
    M, K8, N = packed.shape
    if K8 * 8 != K:
        raise ValueError(f"packed K//8={K8} inconsistent with K={K}")
    shifts = jnp.arange(8, dtype=jnp.uint8).reshape(1, 1, 8, 1)
    bits = (packed[:, :, None, :] >> shifts) & jnp.uint8(1)
    return (bits.astype(jnp.int8) * 2 - 1).reshape(M, K, N)


class PackedBinApprox(NamedTuple):
    """Deployment form: bit-packed binary tensors + scales."""

    B_packed: jax.Array   # [M, K//8, N] uint8
    alpha: jax.Array      # [M, G, N] float32 (or bf16)
    K: int
    group_size: int


def pack(approx: BinApprox) -> PackedBinApprox:
    return PackedBinApprox(
        B_packed=pack_bits(approx.B),
        alpha=approx.alpha,
        K=approx.K,
        group_size=approx.group_size,
    )


def unpack(packed: PackedBinApprox) -> BinApprox:
    return BinApprox(
        B=unpack_bits(packed.B_packed, packed.K),
        alpha=packed.alpha,
        group_size=packed.group_size,
    )


# ---------------------------------------------------------------------------
# Compression factor (paper Eq. 6)
# ---------------------------------------------------------------------------

def compression_factor(
    N_c: int, M: int, *, bits_w: int = 32, bits_alpha: int = 8, n_bias: int = 1
) -> float:
    """(N_c + 1)·bits_w / (M·(N_c + bits_alpha))  — paper Eq. 6 exactly."""
    return ((N_c + n_bias) * bits_w) / (M * (N_c + bits_alpha))


# ---------------------------------------------------------------------------
# Straight-through estimator (paper §V-B1 retraining)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def ste_binarize(W: jax.Array, W_hat: jax.Array) -> jax.Array:
    """Forward: the binary reconstruction W_hat; backward: identity to W.

    This is the straight-through estimation of BinaryNet ([5] in the paper)
    used for the paper's one-epoch retraining: gradients flow to the latent
    real-valued weights as if the binarization were the identity.
    """
    del W
    return W_hat


def _ste_fwd(W, W_hat):
    return W_hat, None


def _ste_bwd(_, g):
    return g, jnp.zeros_like(g)


ste_binarize.defvjp(_ste_fwd, _ste_bwd)


def fake_quant(
    W: jax.Array,
    M: int,
    *,
    algorithm: int = 2,
    K_iters: int = 8,
    group_size: int | None = None,
) -> jax.Array:
    """QAT forward: W -> STE(binary reconstruction of W).  Differentiable."""
    approx = (algorithm2 if algorithm == 2 else algorithm1)(
        W, M, group_size=group_size,
        **({"K_iters": K_iters} if algorithm == 2 else {}),
    )
    # The binarization itself (incl. the Alg-2 while_loop) is not part of the
    # gradient path — STE routes dL/dW_hat straight to the latent weights.
    return ste_binarize(W, jax.lax.stop_gradient(reconstruct(approx)))
