"""Binary gradient compression with error feedback (beyond-paper feature).

The paper's multi-level binarization (Algorithm 1) applied to *gradients*
before the data-parallel all-reduce: each worker compresses its local
gradient g to M sign tensors + M scales (32/M x fewer bits on the wire),
all-reduces the compressed representation, and keeps the compression residual
locally ("error feedback", Karimireddy et al. 2019) so the bias vanishes over
steps.  With M>=2 this is a multi-level generalization of signSGD.

Implementation notes: inside jit/pjit we express the collective as a psum of
the *reconstructed* compressed gradients (mathematically identical to
all-reducing the compact form; the wire-format win is realized when paired
with the uint8 packing in binarize.pack_bits — see train.py which installs a
shard_map-based compressed all-reduce when enabled).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp



class CompressionState(NamedTuple):
    error: dict  # per-leaf residual memory (fp32)


def init_state(grads) -> CompressionState:
    return CompressionState(
        error=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads))


def _compress_leaf(g: jax.Array, M: int):
    """Greedy M-level binarization (Algorithm 1 steps 1-5, per-tensor alpha).

    Returns (reconstruction fp32, compact (B int8, alpha [M]) pair).
    Per-tensor (not per-column) alpha: gradient compression wants the
    smallest wire format; LS refinement is skipped — error feedback absorbs
    the residual bias (hypothesis validated in tests/test_compress.py).
    """
    flat = g.astype(jnp.float32).reshape(-1)

    def body(carry, _):
        r = carry
        b = jnp.where(r >= 0, 1.0, -1.0)
        a = jnp.mean(jnp.abs(r))
        return r - a * b, (b.astype(jnp.int8), a)

    resid, (B, alpha) = jax.lax.scan(body, flat, None, length=M)
    recon = jnp.sum(B.astype(jnp.float32)
                    * alpha[:, None], axis=0).reshape(g.shape)
    return recon, resid.reshape(g.shape)


def compress_grads(grads, state: CompressionState, *, M: int = 2):
    """-> (compressed-reconstructed grads, new state).  Call BEFORE psum."""
    def per_leaf(g, e):
        target = g.astype(jnp.float32) + e          # error feedback
        recon, resid = _compress_leaf(target, M)
        return recon.astype(g.dtype), resid

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(state.error)
    outs = [per_leaf(g, e) for g, e in zip(flat_g, flat_e)]
    new_grads = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_err = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return new_grads, CompressionState(error=new_err)


def wire_bytes(grads, M: int) -> tuple[int, int]:
    """(compressed, uncompressed) bytes per all-reduce — the collective-term
    win reported in EXPERIMENTS.md §Perf."""
    comp = unc = 0
    for g in jax.tree.leaves(grads):
        n = g.size
        unc += n * 4                       # fp32 wire
        comp += M * (n // 8 + 4)           # M x (1 bit/elem + fp32 alpha)
    return comp, unc
