"""Static analysis for compiled BinArrayPrograms (the offline legality
checker the paper's §IV compiler/ISA contract implies).

  * :mod:`repro.analysis.mosaic_rules` — the TPU tiling/legality rules as
    data (ids, severities, block-shape checks);
  * :mod:`repro.analysis.verify` — ``verify_program`` re-derives every
    instruction's schedule through the kernels' own exports and returns
    typed ERROR/WARN :class:`Finding`\\ s;
  * :mod:`repro.analysis.trace_lint` — jaxpr lint of ``deploy.execute``
    (zero fp convs, zero plan picks, no f64) + retrace detection.

``tools/verify_program.py`` runs the whole pass over the shipped program
set and gates CI; ``deploy.compile(..., verify=True)`` raises on ERRORs.
"""
from repro.analysis import mosaic_rules, trace_lint
from repro.analysis.verify import (Finding, ProgramVerificationError,
                                   assert_verified, summarize,
                                   verify_mesh_plan, verify_program)

__all__ = [
    "Finding", "ProgramVerificationError", "assert_verified",
    "mosaic_rules", "summarize", "trace_lint", "verify_mesh_plan",
    "verify_program",
]
