"""The TPU/Mosaic legality rules the BinArray kernels must obey — as data.

The paper's compiler (§IV) emits macro-instructions the FPGA executes
unconditionally; there is no runtime legality fallback.  Our Pallas port has
the same contract: every frozen :class:`~repro.deploy.program.TilePlan` turns
into BlockSpecs that Mosaic either accepts or rejects at lowering time, and
interpret-mode CI never exercises the reject path.  This module is the single
place those rules live — ``verify.py`` evaluates them against the kernels' own
block-shape exports (``binary_conv.conv_block_shapes`` /
``binary_dwconv.dw_block_shapes`` / ``binary_matmul.matmul_block_shapes``),
and ``docs/analysis.md`` renders the same registry as the rule table.

Tiling model (pallas guide):

  * the last ("lane") dim of every block must be a multiple of ``LANE`` = 128
    — or equal the full (padded) array dim, since Mosaic transparently pads a
    lone sub-128-lane array to one tile;
  * the second-to-last ("sublane") dim must be a multiple of the dtype's
    sublane count (f32: 8, bf16: 16, int8/uint8: 32) — or equal the full dim,
    or be 1 (degenerate row blocks relayout fine);
  * ``pl.Unblocked`` halo slabs must stay inside the zero-padded input rows;
  * packed weights are exactly ``ceil(K/8)`` / ``ceil(C/8)`` bytes wide;
  * the conv kernel feeds the MXU fixed 128-row passes (``MXU_ROWS``).
"""
from __future__ import annotations

import dataclasses

# Mosaic register tiling: (sublane, lane) = (SUBLANE_BY_DTYPE[dtype], LANE).
LANE = 128
SUBLANE_BY_DTYPE = {
    "float32": 8, "int32": 8, "uint32": 8,
    "bfloat16": 16, "float16": 16,
    "int8": 32, "uint8": 32, "bool": 32,
}

ERROR = "ERROR"
WARN = "WARN"


@dataclasses.dataclass(frozen=True)
class Rule:
    """One checkable legality/consistency rule with a stable id.

    ``severity`` is the default for findings under this rule: ERROR means the
    program is not safe to hand to a TPU (or is not the schedule that would
    actually execute); WARN means legal-but-suspicious (wasted MXU rows,
    schedules that drifted from the canonical pick, silent kernel overrides).
    """

    id: str
    severity: str
    summary: str


RULES: dict[str, Rule] = {r.id: r for r in [
    # --- Mosaic BlockSpec tiling -------------------------------------------
    Rule("mosaic-lane", ERROR,
         "block last dim must be a multiple of 128 lanes or the full padded "
         "array dim"),
    Rule("mosaic-sublane", ERROR,
         "block second-to-last dim must be a multiple of the dtype sublane "
         "(f32 8 / bf16 16 / u8 32), the full dim, or 1"),
    Rule("unblocked-bounds", ERROR,
         "pl.Unblocked halo slabs must stay inside the zero-padded input "
         "rows"),
    Rule("mxu-pass-rows", ERROR,
         "the conv kernel's fixed MXU pass height must stay 128 rows"),
    # --- packed-buffer / instruction consistency ---------------------------
    Rule("pack-width", ERROR,
         "packed weight widths must be exactly ceil(K/8) / ceil(C/8) bytes"),
    Rule("alpha-shape", ERROR,
         "alpha/bias must match the packed layout: [M, G, D] with "
         "G*group_size == K (conv/linear) or [M, C] (dw)"),
    Rule("levels-mismatch", ERROR,
         "packed buffers and the instruction must agree on the level count "
         "M"),
    Rule("shape-chain", ERROR,
         "each instruction's input (after its pre-op) must match the "
         "previous instruction's output"),
    Rule("epilogue-pool", ERROR,
         "conv output must be divisible by the AMU pool window "
         "(downsampling-only pooling, paper §III-B)"),
    Rule("epilogue-pre", ERROR,
         "pre-op must be one of none | flatten | gap"),
    # --- frozen tile plans --------------------------------------------------
    Rule("plan-missing", ERROR,
         "plan fields the kernel needs must be frozen (a None re-picks "
         "inside the trace)"),
    Rule("plan-range", ERROR,
         "frozen plan outside the kernel's legal range — the kernel would "
         "silently clamp, so the plan is not the executed schedule"),
    Rule("plan-bk-group", WARN,
         "bk incompatible with the alpha groups: the kernel silently "
         "switches to single-K-block grouped mode with a different bk"),
    Rule("plan-noncanonical", WARN,
         "plan differs from every pick_tile/pick_matmul_plan canonical "
         "choice (hand-built or stale)"),
    # --- budgets & stats ----------------------------------------------------
    Rule("vmem-budget", ERROR,
         "per-program VMEM working set exceeds the budget at full level "
         "count"),
    Rule("stats-drift", WARN,
         "LayerStats disagree with values re-derived from the kernels' own "
         "exports"),
    Rule("ragged-batch", WARN,
         "batch not divisible by NB: the last program carries zero images"),
    Rule("mxu-occupancy", WARN,
         "under half the MXU's padded GEMM rows carry real work"),
    # --- mesh shards (repro.distributed) ------------------------------------
    Rule("shard-divisibility", ERROR,
         "a bd-sharded layer's output channels must divide evenly over the "
         "model axis (and the recorded d_local must be that quotient)"),
    Rule("shard-lane", ERROR,
         "a bd shard's device-local lane tile must be a multiple of 128 or "
         "the full 8-padded per-device channel dim"),
    Rule("shard-plan", ERROR,
         "MeshPlan structure must match the program: one LayerShard per "
         "instruction, bd only on ConvInstr, with a frozen device-local "
         "plan (a None re-picks inside the sharded trace)"),
    Rule("shard-accounting", WARN,
         "LayerShard per-device weight bytes disagree with the stats "
         "re-derived split (replicated copy vs weight_bytes / n_model)"),
    Rule("shard-batch", WARN,
         "global batch not divisible by the data axis: the last device "
         "carries zero images every forward"),
    # --- trace lint ---------------------------------------------------------
    Rule("trace-fp-conv", ERROR,
         "full-binary trace contains fp conv_general_dilated primitives"),
    Rule("trace-plan-pick", ERROR,
         "tile auto-picks ran inside the traced forward (scheduling leaked "
         "past compile time)"),
    Rule("trace-f64", ERROR,
         "float64 values in the trace (accidental x64 promotion)"),
    Rule("trace-retrace", ERROR,
         "repeated identical calls re-traced: a compiled-variant cache is "
         "leaking"),
]}


def sublane(dtype: str) -> int:
    """Sublane tile for a dtype name (conservative f32 default)."""
    return SUBLANE_BY_DTYPE.get(str(dtype), 8)


def block_findings(operand: str, block: tuple, full: tuple,
                   dtype: str) -> list[tuple[str, str]]:
    """Mosaic tiling violations of one BlockSpec as (rule_id, message) pairs.

    ``block`` is the BlockSpec block shape, ``full`` the *padded* array shape
    it tiles (so ``block[i] == full[i]`` means the dim is untiled).  Rank < 2
    operands have no (sublane, lane) tiling to violate.
    """
    out: list[tuple[str, str]] = []
    if len(block) < 2 or len(full) < 2:
        return out
    lane_b, lane_f = int(block[-1]), int(full[-1])
    if lane_b % LANE and lane_b != lane_f:
        out.append(("mosaic-lane",
                    f"{operand}: last-dim block {lane_b} is neither a "
                    f"multiple of {LANE} nor the full padded dim {lane_f} "
                    f"(block {tuple(block)} over {tuple(full)})"))
    sub = sublane(dtype)
    sl_b, sl_f = int(block[-2]), int(full[-2])
    if sl_b % sub and sl_b != sl_f and sl_b != 1:
        out.append(("mosaic-sublane",
                    f"{operand}: second-to-last block dim {sl_b} is not a "
                    f"multiple of the {dtype} sublane {sub}, the full dim "
                    f"{sl_f}, or 1 (block {tuple(block)} over "
                    f"{tuple(full)})"))
    return out


def blocks_findings(prefix: str,
                    blocks: dict[str, tuple]) -> list[tuple[str, str]]:
    """Run :func:`block_findings` over a kernel's ``*_block_shapes`` export:
    a dict of ``operand -> (block_shape, padded_array_shape, dtype)``."""
    out: list[tuple[str, str]] = []
    for name, (block, full, dtype) in blocks.items():
        out.extend(block_findings(f"{prefix}.{name}", block, full, dtype))
    return out
