"""Jaxpr lint for deploy.execute (and the legacy per-call forwards).

Three contract checks, all on the *trace* rather than on outputs:

  * **trace-fp-conv** — a full-binary program's jaxpr must contain zero
    ``conv_general_dilated`` primitives: every conv went through the fused
    Pallas kernels, none fell back to fp ``lax.conv``.
  * **trace-plan-pick** — tracing the forward must run zero tile auto-picks
    (``kernels.binary_conv.plan_pick_count``, upgraded here from a test
    counter to a reusable gate): all scheduling was frozen at compile time.
  * **trace-f64** — no float64 values anywhere in the trace (accidental
    x64 promotion would silently double every VMEM estimate).

Plus a **trace-retrace** detector: the executor counts how many times its
jitted body actually (re)traces; repeated identical traffic must not grow
the count — the guard against compile-cache leaks in the per-``m_active``
variant caches (executor schedules, ``serve.Server`` prefill buckets).

Linting uses ``jax.make_jaxpr``, which accepts ShapeDtypeStruct leaves — so
abstract programs (``deploy.abstract_program``) lint without ever executing
a kernel, and MobileNet-B2 at 224² costs milliseconds, not minutes.
"""
from __future__ import annotations

import jax

from repro.analysis.verify import Finding, make_finding
from repro.kernels import binary_conv as bck


def _inner_jaxprs(params: dict):
    """Yield sub-jaxprs hiding in an equation's params (pjit / scan /
    pallas_call / custom_jvp all stash them differently)."""
    for v in params.values():
        vs = v if isinstance(v, (list, tuple)) else (v,)
        for u in vs:
            closed = getattr(u, "jaxpr", None)
            if closed is not None and hasattr(closed, "eqns"):
                yield closed            # ClosedJaxpr-like
            elif hasattr(u, "eqns"):
                yield u                 # raw Jaxpr


def iter_eqns(jaxpr):
    """DFS over every equation, including nested sub-jaxprs."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    stack = [jaxpr]
    while stack:
        j = stack.pop()
        for eqn in j.eqns:
            yield eqn
            stack.extend(_inner_jaxprs(eqn.params))


def count_primitive(jaxpr, name: str) -> int:
    """Occurrences of a primitive (by name) anywhere in the jaxpr."""
    return sum(1 for eqn in iter_eqns(jaxpr) if eqn.primitive.name == name)


def count_f64(jaxpr) -> int:
    """Equation outputs with a float64 aval anywhere in the jaxpr."""
    n = 0
    for eqn in iter_eqns(jaxpr):
        for var in eqn.outvars:
            dt = getattr(getattr(var, "aval", None), "dtype", None)
            if dt is not None and str(dt) == "float64":
                n += 1
    return n


def lint_fn(fn, args, *, full_binary: bool = True,
            label: str = "trace") -> list[Finding]:
    """Trace ``fn(*args)`` (ShapeDtypeStruct args are fine) and lint the
    jaxpr.  The plan-pick counter is snapshot/restored, so linting never
    poisons a caller's own zero-pick gate."""
    before = bck.plan_pick_count()
    try:
        jaxpr = jax.make_jaxpr(fn)(*args)
        picks = bck.plan_pick_count() - before
    finally:
        bck._plan_picks[0] = before
    findings: list[Finding] = []
    if picks:
        findings.append(make_finding(
            "trace-plan-pick", label, -1,
            f"{picks} tile auto-pick(s) ran while tracing — scheduling "
            f"leaked past compile time"))
    nconv = count_primitive(jaxpr, "conv_general_dilated")
    if full_binary and nconv:
        findings.append(make_finding(
            "trace-fp-conv", label, -1,
            f"{nconv} fp conv_general_dilated primitive(s) in a "
            f"full-binary trace"))
    n64 = count_f64(jaxpr)
    if n64:
        findings.append(make_finding(
            "trace-f64", label, -1,
            f"{n64} float64 value(s) in the trace"))
    return findings


def lint_execute(program, x=None, *, m_active=None,
                 interpret: bool | None = None,
                 label: str | None = None) -> list[Finding]:
    """Lint the jaxpr of ``deploy.execute(program, x, m_active)``.

    ``x`` defaults to an abstract batch of ``program.input_shape`` — works
    for abstract and concrete programs alike, and never runs a kernel.
    """
    from repro.deploy import executor

    if x is None:
        x = jax.ShapeDtypeStruct(tuple(program.input_shape), "float32")
    return lint_fn(
        lambda p, xx: executor.execute(p, xx, m_active, interpret=interpret),
        (program, x), full_binary=True,
        label=label or f"execute[{program.arch}]")


def retrace_findings(program, x, *, schedules=(None,), repeats: int = 3,
                     interpret: bool | None = None,
                     label: str | None = None) -> list[Finding]:
    """Run ``repeats`` rounds of the same ``m_active`` traffic and assert
    the executor traced at most once per distinct resolved schedule.

    Needs concrete arrays (this one executes).  A warm jit cache can make
    the observed trace count *lower* than the schedule count — only growth
    beyond it is a leak.
    """
    from repro.deploy import executor

    start = executor.trace_entry_count()
    for _ in range(repeats):
        for m in schedules:
            jax.block_until_ready(
                executor.execute(program, x, m, interpret=interpret))
    traced = executor.trace_entry_count() - start
    expected = len({program.resolve_schedule(m) for m in schedules})
    if traced > expected:
        return [make_finding(
            "trace-retrace", label or f"execute[{program.arch}]", -1,
            f"{traced} trace entries for {expected} distinct schedule(s) "
            f"across {repeats}x repeated traffic — a compiled-variant "
            f"cache is leaking")]
    return []
