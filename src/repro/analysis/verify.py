"""verify_program(): statically prove a BinArrayProgram is safe to execute.

The checker re-derives every instruction's geometry from the program's
``input_shape`` and static fields, evaluates the Mosaic tiling rules
(``mosaic_rules``) against the kernels' own block-shape exports
(``conv_block_shapes`` / ``dw_block_shapes`` / ``matmul_block_shapes``), and
re-runs the canonical pick functions to detect hand-built or stale plans —
so a program that passes is, instruction for instruction, the schedule the
kernels would actually execute, inside the VMEM budget, with no silent
clamps or overrides.

Works on abstract programs too (``deploy.abstract_program``): every check
reads shapes and static aux data only, never array values.  Canonical-pick
re-runs are wrapped so they do NOT bump the process-wide
``plan_pick_count`` — verification must not poison the trace-lint gate.

``Finding`` severity semantics live on the rules (``mosaic_rules.RULES``):
ERROR = not safe to hand to a TPU / not the executed schedule; WARN =
legal but suspicious.  ``assert_verified`` raises
:class:`ProgramVerificationError` on any ERROR — ``deploy.compile(...,
verify=True)`` calls it.
"""
from __future__ import annotations

import contextlib
import dataclasses

from repro.analysis import mosaic_rules
from repro.core import binconv
from repro.deploy.program import (BinArrayProgram, ConvInstr, DWConvInstr,
                                  LinearInstr)
from repro.kernels import binary_conv as bck
from repro.kernels import binary_dwconv as bdw
from repro.kernels import binary_matmul as bmk
from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class Finding:
    """One verifier result: a rule id, where it fired, and why."""

    rule: str        # id in mosaic_rules.RULES
    severity: str    # ERROR | WARN (rule default unless overridden)
    instr: str       # instruction name ("" = program/trace level)
    index: int       # instruction index (-1 = program/trace level)
    message: str

    def __str__(self) -> str:
        where = f"{self.instr}[{self.index}]" if self.index >= 0 else "trace"
        return f"{self.severity} {self.rule} @ {where}: {self.message}"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class ProgramVerificationError(ValueError):
    """Raised by :func:`assert_verified` when ERROR findings exist."""


def make_finding(rule: str, instr: str, index: int, message: str,
                 severity: str | None = None) -> Finding:
    """Build a Finding, defaulting severity from the rule registry."""
    sev = severity or mosaic_rules.RULES[rule].severity
    return Finding(rule=rule, severity=sev, instr=instr, index=index,
                   message=message)


@contextlib.contextmanager
def _no_pick_accounting():
    """Re-running pick_* for canonical-plan comparison must not count as a
    trace-time plan pick (the counter is the trace-lint gate)."""
    before = bck.plan_pick_count()
    try:
        yield
    finally:
        bck._plan_picks[0] = before


def summarize(findings: list[Finding]) -> dict:
    """JSON-able roll-up for ``benchmarks/run.py --json``'s verify section."""
    by_rule: dict[str, int] = {}
    for f in findings:
        by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
    return {
        "errors": sum(1 for f in findings if f.severity == mosaic_rules.ERROR),
        "warnings": sum(1 for f in findings
                        if f.severity == mosaic_rules.WARN),
        "by_rule": by_rule,
    }


# ---------------------------------------------------------------------------
# Per-instruction checkers.  Each returns (out_shape, findings); out_shape is
# re-derived (never trusted from stats) so shape-chain errors localize.
# ---------------------------------------------------------------------------

def _pre_shape(pre: str, shape: tuple[int, ...]) -> tuple[int, ...]:
    if pre == "flatten":
        n = 1
        for d in shape[1:]:
            n *= d
        return (shape[0], n)
    if pre == "gap":
        return (shape[0], shape[-1])
    return shape


def _check_pre(instr, idx: int, shape, fs) -> tuple[int, ...]:
    if instr.pre not in ("none", "flatten", "gap"):
        fs.append(make_finding("epilogue-pre", instr.name, idx,
                               f"unknown pre-op {instr.pre!r}"))
        return shape
    return _pre_shape(instr.pre, shape)


def _check_stats_vmem(instr, idx: int, vmem_by_m: dict[int, int], fs) -> None:
    """stats.vmem_bytes must match the kernel formula for *some* level count
    1..M (compiles may be m_active-biased)."""
    got = instr.stats.vmem_bytes
    if got and got not in vmem_by_m.values():
        fs.append(make_finding(
            "stats-drift", instr.name, idx,
            f"stats.vmem_bytes={got} matches no level count 1..{instr.M} "
            f"(kernel formula gives {sorted(set(vmem_by_m.values()))})"))


def _verify_conv(instr: ConvInstr, idx: int, shape, budget: int):
    fs: list[Finding] = []
    name = instr.name
    shape = _check_pre(instr, idx, shape, fs)
    if len(shape) != 4:
        fs.append(make_finding(
            "shape-chain", name, idx,
            f"conv needs a rank-4 [B,H,W,C] input, got {shape}"))
        return tuple(instr.stats.out_shape), fs
    B, H, W, C = shape
    M, T, C8, D = instr.B_tap_packed.shape
    kh, kw = instr.kh, instr.kw
    if T != kh * kw:
        fs.append(make_finding(
            "pack-width", name, idx,
            f"B_tap_packed has {T} taps for a {kh}x{kw} window"))
    if C8 != -(-C // 8):
        fs.append(make_finding(
            "pack-width", name, idx,
            f"B_tap_packed per-tap width {C8} != ceil(C/8) = {-(-C // 8)} "
            f"for C={C}"))
    if M != instr.M:
        fs.append(make_finding(
            "levels-mismatch", name, idx,
            f"B_tap_packed carries {M} levels, instruction says {instr.M}"))
    al = tuple(instr.alpha.shape)
    if len(al) != 3 or al[0] != M or al[2] != D:
        fs.append(make_finding(
            "alpha-shape", name, idx,
            f"alpha {al} != [M={M}, G, D={D}]"))
        G = 1
    else:
        G = al[1]
        if G * instr.group_size != kh * kw * C:
            fs.append(make_finding(
                "alpha-shape", name, idx,
                f"G={G} * group_size={instr.group_size} != K="
                f"{kh * kw * C}"))
    if tuple(instr.bias.shape) != (D,):
        fs.append(make_finding(
            "alpha-shape", name, idx,
            f"bias {tuple(instr.bias.shape)} != ({D},)"))

    # geometry (the wrapper resolves SAME before the kernel sees x)
    if instr.padding == "SAME":
        pt, pb = binconv.same_pads(H, kh, instr.stride)
        pl_, pr = binconv.same_pads(W, kw, instr.stride)
        Hp, Wp = H + pt + pb, W + pl_ + pr
    else:
        Hp, Wp = H, W
    U = (Hp - kh) // instr.stride + 1
    V = (Wp - kw) // instr.stride + 1
    if U % instr.pool or V % instr.pool:
        fs.append(make_finding(
            "epilogue-pool", name, idx,
            f"conv output {U}x{V} not divisible by AMU pool {instr.pool}"))
        return tuple(instr.stats.out_shape), fs
    uo = max(U // instr.pool, 1)
    out_shape = (B, uo, V // instr.pool, D)

    plan = instr.plan
    if plan.nb is None or plan.bu is None or plan.bd is None:
        fs.append(make_finding(
            "plan-missing", name, idx,
            f"conv plan needs (nb, bu, bd), got {plan}"))
        return out_shape, fs
    nb, bu, bd = plan.nb, plan.bu, plan.bd
    if not 1 <= nb <= B:
        fs.append(make_finding(
            "plan-range", name, idx,
            f"nb={nb} outside [1, B={B}] (kernel clamps silently)"))
    if not 1 <= bu <= uo:
        fs.append(make_finding(
            "plan-range", name, idx,
            f"bu={bu} outside [1, Uo={uo}] (kernel clamps silently)"))
    if not 1 <= bd <= max(8, D):
        fs.append(make_finding(
            "plan-range", name, idx,
            f"bd={bd} outside [1, max(8, D={D})] (kernel clamps silently)"))
    # check the schedule the kernel would actually run (clamped plan)
    nb_e = max(1, min(nb, B))
    bu_e = max(1, min(bu, uo))
    bd_e = max(1, min(bd, max(8, D)))
    geo = bck.conv_block_shapes(
        Hp, Wp, C, D, kh, kw, bd=bd_e, bu=bu_e, nb=nb_e, pool=instr.pool,
        stride=instr.stride, m=M, group_size=instr.group_size, B=B)
    for rule, msg in mosaic_rules.blocks_findings(name, geo["blocks"]):
        fs.append(make_finding(rule, name, idx, msg))
    last_slab_end = (geo["nt"] - 1) * geo["adv"] + geo["slab"]
    if geo["adv"] < 1 or geo["slab"] < kh \
            or last_slab_end > geo["padded_rows"]:
        fs.append(make_finding(
            "unblocked-bounds", name, idx,
            f"halo slabs (nt={geo['nt']}, adv={geo['adv']}, "
            f"slab={geo['slab']}) overrun the {geo['padded_rows']} padded "
            f"input rows"))

    vmem_by_m = {m: bck.tile_vmem_bytes(
        Wp, C, kh, kw, bd_e, bu=bu_e, pool=instr.pool, stride=instr.stride,
        m=m, nb=nb_e) for m in range(1, M + 1)}
    worst = vmem_by_m[M]
    if worst > budget:
        # the pick floor (nb=bu=1) may legitimately exceed the budget on
        # huge layers — the budget is a target there, not a hard limit
        floor = nb_e == 1 and bu_e == 1
        fs.append(make_finding(
            "vmem-budget", name, idx,
            f"working set {worst} B > budget {budget} B at m={M} "
            f"(nb={nb_e}, bu={bu_e}, bd={bd_e})",
            severity=mosaic_rules.WARN if floor else None))

    with _no_pick_accounting():
        canonical = set()
        for m in range(1, M + 1):
            cbd = kops._pick_block(D, 128)
            canonical.add((bck.pick_tile(
                B, Hp, Wp, C, kh, kw, cbd, instr.pool, budget,
                stride=instr.stride, m=m), cbd))
            for cnb in range(1, B + 1):
                canonical.add(((cnb, bck.pick_bu(
                    Hp, Wp, C, kh, kw, cbd, instr.pool, budget,
                    stride=instr.stride, m=m, nb=cnb)), cbd))
    if ((nb, bu), bd) not in canonical:
        fs.append(make_finding(
            "plan-noncanonical", name, idx,
            f"(nb={nb}, bu={bu}, bd={bd}) matches no pick_tile/pick_bu "
            f"choice for this layer (hand-built or stale plan)"))

    # stats drift + utilization warnings
    st = instr.stats
    if tuple(st.out_shape) and tuple(st.out_shape) != out_shape:
        fs.append(make_finding(
            "stats-drift", name, idx,
            f"stats.out_shape {tuple(st.out_shape)} != derived {out_shape}"))
    if tuple(st.padded_in) and tuple(st.padded_in) != (Hp, Wp):
        fs.append(make_finding(
            "stats-drift", name, idx,
            f"stats.padded_in {tuple(st.padded_in)} != ({Hp}, {Wp})"))
    macs = U * V * D * kh * kw * C
    if st.macs and st.macs != macs:
        fs.append(make_finding(
            "stats-drift", name, idx,
            f"stats.macs {st.macs} != derived {macs}"))
    _check_stats_vmem(instr, idx, vmem_by_m, fs)
    if B % nb_e:
        fs.append(make_finding(
            "ragged-batch", name, idx,
            f"B={B} % nb={nb_e} != 0: last program carries "
            f"{(-B) % nb_e} zero image(s)"))
    occ = bck.mxu_row_occupancy(bck.gemm_rows(nb_e, bu_e, V, pool=instr.pool))
    if occ < 0.5:
        fs.append(make_finding(
            "mxu-occupancy", name, idx,
            f"MXU row occupancy {occ:.0%} under the plan "
            f"(rows={bck.gemm_rows(nb_e, bu_e, V, pool=instr.pool)})"))
    return out_shape, fs


def _verify_dwconv(instr: DWConvInstr, idx: int, shape, budget: int):
    fs: list[Finding] = []
    name = instr.name
    shape = _check_pre(instr, idx, shape, fs)
    if len(shape) != 4:
        fs.append(make_finding(
            "shape-chain", name, idx,
            f"dwconv needs a rank-4 [B,H,W,C] input, got {shape}"))
        return tuple(instr.stats.out_shape), fs
    B, H, W, C = shape
    M, T, c8 = instr.B_tap_packed.shape
    kh, kw = instr.kh, instr.kw
    if T != kh * kw:
        fs.append(make_finding(
            "pack-width", name, idx,
            f"B_tap_packed has {T} taps for a {kh}x{kw} window"))
    if c8 != -(-C // 8):
        fs.append(make_finding(
            "pack-width", name, idx,
            f"B_tap_packed width {c8} != ceil(C/8) = {-(-C // 8)}"))
    if M != instr.M:
        fs.append(make_finding(
            "levels-mismatch", name, idx,
            f"B_tap_packed carries {M} levels, instruction says {instr.M}"))
    if tuple(instr.alpha.shape) != (M, C):
        fs.append(make_finding(
            "alpha-shape", name, idx,
            f"dw alpha {tuple(instr.alpha.shape)} != (M={M}, C={C})"))
    if tuple(instr.bias.shape) != (C,):
        fs.append(make_finding(
            "alpha-shape", name, idx,
            f"bias {tuple(instr.bias.shape)} != ({C},)"))

    pt, pb = binconv.same_pads(H, kh, instr.stride)
    pl_, pr = binconv.same_pads(W, kw, instr.stride)
    Hp, Wp = H + pt + pb, W + pl_ + pr
    U = (Hp - kh) // instr.stride + 1
    V = (Wp - kw) // instr.stride + 1
    out_shape = (B, U, V, C)

    plan = instr.plan
    if plan.nb is None or plan.bu is None:
        fs.append(make_finding(
            "plan-missing", name, idx,
            f"dw plan needs (nb, bu), got {plan}"))
        return out_shape, fs
    nb, bu = plan.nb, plan.bu
    if not 1 <= nb <= B:
        fs.append(make_finding(
            "plan-range", name, idx,
            f"nb={nb} outside [1, B={B}] (kernel clamps silently)"))
    if not 1 <= bu <= U:
        fs.append(make_finding(
            "plan-range", name, idx,
            f"bu={bu} outside [1, U={U}] (kernel clamps silently)"))
    nb_e = max(1, min(nb, B))
    bu_e = max(1, min(bu, U))
    geo = bdw.dw_block_shapes(Hp, Wp, C, kh, kw, bu=bu_e, nb=nb_e,
                              stride=instr.stride, m=M, B=B)
    for rule, msg in mosaic_rules.blocks_findings(name, geo["blocks"]):
        fs.append(make_finding(rule, name, idx, msg))
    last_slab_end = (geo["nt"] - 1) * geo["adv"] + geo["slab"]
    if geo["adv"] < 1 or geo["slab"] < kh \
            or last_slab_end > geo["padded_rows"]:
        fs.append(make_finding(
            "unblocked-bounds", name, idx,
            f"halo slabs (nt={geo['nt']}, adv={geo['adv']}, "
            f"slab={geo['slab']}) overrun the {geo['padded_rows']} padded "
            f"input rows"))

    vmem_by_m = {m: bdw.tile_vmem_bytes_dw(
        Wp, C, kh, kw, bu=bu_e, stride=instr.stride, m=m, nb=nb_e)
        for m in range(1, M + 1)}
    worst = vmem_by_m[M]
    if worst > budget:
        floor = nb_e == 1 and bu_e == 1
        fs.append(make_finding(
            "vmem-budget", name, idx,
            f"working set {worst} B > budget {budget} B at m={M} "
            f"(nb={nb_e}, bu={bu_e})",
            severity=mosaic_rules.WARN if floor else None))

    with _no_pick_accounting():
        canonical = set()
        for m in range(1, M + 1):
            canonical.add(bdw.pick_tile_dw(B, Hp, Wp, C, kh, kw, budget,
                                           stride=instr.stride, m=m))
            for cnb in range(1, B + 1):
                canonical.add((cnb, bdw.pick_bu_dw(
                    Hp, Wp, C, kh, kw, budget, stride=instr.stride, m=m,
                    nb=cnb)))
    if (nb, bu) not in canonical:
        fs.append(make_finding(
            "plan-noncanonical", name, idx,
            f"(nb={nb}, bu={bu}) matches no pick_tile_dw/pick_bu_dw choice "
            f"for this layer (hand-built or stale plan)"))

    st = instr.stats
    if tuple(st.out_shape) and tuple(st.out_shape) != out_shape:
        fs.append(make_finding(
            "stats-drift", name, idx,
            f"stats.out_shape {tuple(st.out_shape)} != derived {out_shape}"))
    if tuple(st.padded_in) and tuple(st.padded_in) != (Hp, Wp):
        fs.append(make_finding(
            "stats-drift", name, idx,
            f"stats.padded_in {tuple(st.padded_in)} != ({Hp}, {Wp})"))
    _check_stats_vmem(instr, idx, vmem_by_m, fs)
    if B % nb_e:
        fs.append(make_finding(
            "ragged-batch", name, idx,
            f"B={B} % nb={nb_e} != 0: last program carries "
            f"{(-B) % nb_e} zero image(s)"))
    return out_shape, fs


def _verify_linear(instr: LinearInstr, idx: int, shape, budget: int):
    fs: list[Finding] = []
    name = instr.name
    shape = _check_pre(instr, idx, shape, fs)
    B = shape[0]
    k_in = shape[-1] if len(shape) >= 2 else 0
    if k_in != instr.K:
        fs.append(make_finding(
            "shape-chain", name, idx,
            f"incoming features {k_in} (shape {shape} after pre="
            f"{instr.pre!r}) != instruction K={instr.K}"))
    K = instr.K
    M, K8, N = instr.B_packed.shape
    if K8 != -(-K // 8):
        fs.append(make_finding(
            "pack-width", name, idx,
            f"B_packed width {K8} != ceil(K/8) = {-(-K // 8)} for K={K}"))
    if M != instr.M:
        fs.append(make_finding(
            "levels-mismatch", name, idx,
            f"B_packed carries {M} levels, instruction says {instr.M}"))
    al = tuple(instr.alpha.shape)
    if len(al) != 3 or al[0] != M or al[2] != N:
        fs.append(make_finding(
            "alpha-shape", name, idx, f"alpha {al} != [M={M}, G, N={N}]"))
        G = 1
    else:
        G = al[1]
        if G * instr.group_size != K:
            fs.append(make_finding(
                "alpha-shape", name, idx,
                f"G={G} * group_size={instr.group_size} != K={K}"))
    if tuple(instr.bias.shape) != (N,):
        fs.append(make_finding(
            "alpha-shape", name, idx,
            f"bias {tuple(instr.bias.shape)} != ({N},)"))
    out_shape = (B, N)

    plan = instr.plan
    if plan.bt is None or plan.bn is None or plan.bk is None:
        fs.append(make_finding(
            "plan-missing", name, idx,
            f"matmul plan needs (bt, bn, bk), got {plan}"))
        return out_shape, fs
    bt, bn, bk = plan.bt, plan.bn, plan.bk
    if bt < 1 or bn < 1 or bk < 8 or bk % 8:
        fs.append(make_finding(
            "plan-range", name, idx,
            f"(bt={bt}, bn={bn}, bk={bk}) needs bt,bn >= 1 and bk a "
            f"positive multiple of 8 (bit-packed K tiles)"))
        return out_shape, fs
    blocks, eff_bk = bmk.matmul_block_shapes(
        B, K, N, bt=bt, bn=bn, bk=bk, m=M, G=G,
        group_size=instr.group_size)
    if eff_bk != bk:
        fs.append(make_finding(
            "plan-bk-group", name, idx,
            f"bk={bk} does not divide group_size={instr.group_size} "
            f"(G={G}): kernel silently overrides to single-block "
            f"bk={eff_bk}"))
    for rule, msg in mosaic_rules.blocks_findings(name, blocks):
        fs.append(make_finding(rule, name, idx, msg))

    vmem_by_m = {m: bmk.tile_vmem_bytes_mm(bt, bn, eff_bk, m=m)
                 for m in range(1, M + 1)}
    worst = vmem_by_m[M]
    if worst > budget:
        fs.append(make_finding(
            "vmem-budget", name, idx,
            f"working set {worst} B > budget {budget} B at m={M} "
            f"(bt={bt}, bn={bn}, bk={eff_bk})"))

    with _no_pick_accounting():
        canonical = kops.pick_matmul_plan(B, K, N, G=G,
                                          group_size=instr.group_size)
    if (bt, bn, bk) != canonical:
        fs.append(make_finding(
            "plan-noncanonical", name, idx,
            f"(bt={bt}, bn={bn}, bk={bk}) != pick_matmul_plan "
            f"{canonical} (hand-built or stale plan)"))

    st = instr.stats
    if tuple(st.out_shape) and tuple(st.out_shape) != out_shape:
        fs.append(make_finding(
            "stats-drift", name, idx,
            f"stats.out_shape {tuple(st.out_shape)} != derived {out_shape}"))
    if st.macs and st.macs != K * N:
        fs.append(make_finding(
            "stats-drift", name, idx,
            f"stats.macs {st.macs} != derived {K * N}"))
    _check_stats_vmem(instr, idx, vmem_by_m, fs)
    return out_shape, fs


# ---------------------------------------------------------------------------
# Program-level entry points
# ---------------------------------------------------------------------------

def verify_program(program: BinArrayProgram, *,
                   vmem_budget: int | None = None) -> list[Finding]:
    """Statically verify every instruction of a compiled (or abstract)
    program.  Returns all findings, ERRORs first; empty list == clean.

    ``vmem_budget`` defaults to the kernels' ``DEFAULT_VMEM_BUDGET`` (the
    same target the pick functions optimize against).
    """
    budget = vmem_budget or bck.DEFAULT_VMEM_BUDGET
    findings: list[Finding] = []
    if bck.MXU_ROWS != mosaic_rules.LANE:
        findings.append(make_finding(
            "mxu-pass-rows", "", -1,
            f"kernels.binary_conv.MXU_ROWS = {bck.MXU_ROWS}, expected "
            f"{mosaic_rules.LANE}"))
    shape = tuple(program.input_shape)
    for idx, instr in enumerate(program.instrs):
        if isinstance(instr, ConvInstr):
            shape, fs = _verify_conv(instr, idx, shape, budget)
        elif isinstance(instr, DWConvInstr):
            shape, fs = _verify_dwconv(instr, idx, shape, budget)
        else:
            shape, fs = _verify_linear(instr, idx, shape, budget)
        findings.extend(fs)
    findings.sort(key=lambda f: (f.severity != mosaic_rules.ERROR, f.index))
    return findings


def verify_mesh_plan(program: BinArrayProgram, plan, *,
                     vmem_budget: int | None = None) -> list[Finding]:
    """Statically verify a :class:`~repro.distributed.plan.MeshPlan` against
    its program: shard arity/kind structure (``shard-plan``), channel
    divisibility over the model axis (``shard-divisibility``), Mosaic
    lane-128 legality of each device-local bd tile (``shard-lane``),
    per-device working sets against the VMEM budget (``vmem-budget``), and
    the replication byte accounting (``shard-accounting``).  Returns all
    findings, ERRORs first; empty list == clean.  Abstract-program safe —
    shapes and static aux only, like :func:`verify_program`.
    """
    budget = vmem_budget or bck.DEFAULT_VMEM_BUDGET
    fs: list[Finding] = []
    if plan.n_data < 1 or plan.n_model < 1:
        fs.append(make_finding(
            "shard-plan", "", -1,
            f"mesh axes must be >= 1, got n_data={plan.n_data}, "
            f"n_model={plan.n_model}"))
        return fs
    if len(plan.shards) != len(program.instrs):
        fs.append(make_finding(
            "shard-plan", "", -1,
            f"MeshPlan carries {len(plan.shards)} LayerShard(s) for "
            f"{len(program.instrs)} instruction(s)"))
        return fs
    if plan.global_batch % plan.n_data:
        fs.append(make_finding(
            "shard-batch", "", -1,
            f"global_batch={plan.global_batch} % n_data={plan.n_data} != 0: "
            f"every forward pads {(-plan.global_batch) % plan.n_data} zero "
            f"image(s)"))
    for idx, (instr, s) in enumerate(zip(program.instrs, plan.shards)):
        name = instr.name
        if s.kind == "replicated":
            if (s.per_device_weight_bytes
                    and s.per_device_weight_bytes
                    != instr.stats.weight_bytes):
                fs.append(make_finding(
                    "shard-accounting", name, idx,
                    f"replicated shard records "
                    f"{s.per_device_weight_bytes} B/device, stats say the "
                    f"full copy is {instr.stats.weight_bytes} B"))
            continue
        if s.kind != "bd":
            fs.append(make_finding(
                "shard-plan", name, idx,
                f"unknown shard kind {s.kind!r} (replicated | bd)"))
            continue
        if not isinstance(instr, ConvInstr):
            fs.append(make_finding(
                "shard-plan", name, idx,
                f"bd sharding applies to ConvInstr only, got {instr.kind}"))
            continue
        D = int(instr.alpha.shape[-1])
        if D % plan.n_model:
            fs.append(make_finding(
                "shard-divisibility", name, idx,
                f"D={D} output channels do not divide over "
                f"n_model={plan.n_model}"))
            continue
        d_local = D // plan.n_model
        if s.d_local != d_local:
            fs.append(make_finding(
                "shard-divisibility", name, idx,
                f"recorded d_local={s.d_local} != D/n_model = {d_local}"))
        lp = s.plan
        if lp is None or lp.nb is None or lp.bu is None or lp.bd is None:
            fs.append(make_finding(
                "shard-plan", name, idx,
                f"bd shard needs a frozen device-local (nb, bu, bd) plan, "
                f"got {lp}"))
            continue
        d_pad = -(-d_local // 8) * 8
        if lp.bd % mosaic_rules.LANE and lp.bd != d_pad:
            fs.append(make_finding(
                "shard-lane", name, idx,
                f"device-local bd={lp.bd} is neither a multiple of "
                f"{mosaic_rules.LANE} nor the full 8-padded per-device "
                f"channel dim {d_pad} (d_local={d_local})"))
        st = instr.stats
        Hp, Wp = (tuple(st.padded_in) if st.padded_in
                  else tuple(st.in_shape[1:3]))
        C = int(st.in_shape[-1])
        local_vmem = bck.tile_vmem_bytes(
            Wp, C, instr.kh, instr.kw, min(lp.bd, d_pad),
            bu=lp.bu, pool=instr.pool, stride=instr.stride, m=instr.M,
            nb=lp.nb)
        if local_vmem > budget and not (lp.nb == 1 and lp.bu == 1):
            fs.append(make_finding(
                "vmem-budget", name, idx,
                f"device-local working set {local_vmem} B > budget "
                f"{budget} B (nb={lp.nb}, bu={lp.bu}, bd={lp.bd}, "
                f"d_local={d_local})"))
        if (s.per_device_weight_bytes
                and s.per_device_weight_bytes
                != st.weight_bytes // plan.n_model):
            fs.append(make_finding(
                "shard-accounting", name, idx,
                f"bd shard records {s.per_device_weight_bytes} B/device, "
                f"stats split gives {st.weight_bytes // plan.n_model} B "
                f"(weight_bytes={st.weight_bytes}, n_model={plan.n_model})"))
    fs.sort(key=lambda f: (f.severity != mosaic_rules.ERROR, f.index))
    return fs


def assert_verified(program: BinArrayProgram, *,
                    vmem_budget: int | None = None) -> list[Finding]:
    """Raise :class:`ProgramVerificationError` on any ERROR finding; returns
    the (WARN-only) findings otherwise."""
    findings = verify_program(program, vmem_budget=vmem_budget)
    errors = [f for f in findings if f.severity == mosaic_rules.ERROR]
    if errors:
        raise ProgramVerificationError(
            f"{len(errors)} ERROR finding(s):\n"
            + "\n".join(f"  {f}" for f in errors))
    return findings
