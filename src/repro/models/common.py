"""Shared model components: norms, RoPE, embeddings, sharded-linear glue.

All weight-bearing matmuls route through ``repro.core.binlinear`` so the
paper's multi-level binary approximation is a config switch on every layer
(DESIGN.md §5).  Activation sharding uses *logical* axis names resolved
against rules installed by the launcher (set_axis_rules); on CPU tests no
rules are installed and constraints are no-ops.
"""
from __future__ import annotations

import threading

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import binlinear as bl

_STATE = threading.local()


def set_axis_rules(rules: dict[str, tuple[str, ...] | str | None] | None,
                   axis_sizes: dict[str, int] | None = None):
    """Install logical->mesh axis rules (e.g. {'batch': ('pod','data')}).
    axis_sizes enables divisibility checks (a constraint that doesn't divide
    the dim is dropped rather than failing the partitioner)."""
    _STATE.rules = rules
    _STATE.axis_sizes = axis_sizes or {}


def get_axis_rules():
    return getattr(_STATE, "rules", None)


def _axes_size(axes, sizes: dict[str, int]) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return sizes.get(axes, 1)
    n = 1
    for a in axes:
        n *= sizes.get(a, 1)
    return n


def shard(x: jax.Array, *logical: str | None) -> jax.Array:
    """with_sharding_constraint by logical axis names; no-op without rules."""
    rules = get_axis_rules()
    if rules is None:
        return x
    sizes = getattr(_STATE, "axis_sizes", {})
    spec = []
    for i, name in enumerate(logical):
        axes = rules.get(name) if name else None
        if axes is not None and x.shape[i] % _axes_size(axes, sizes) != 0:
            axes = None  # dim not divisible -> leave unconstrained
        spec.append(axes)
    return jax.lax.with_sharding_constraint(x, P(*spec))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def rms_norm(params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def rms_norm_gated(params, x: jax.Array, z: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Mamba2's gated RMSNorm: norm(x * silu(z)) * scale."""
    xf = x.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, hd], positions: [B, S] (or [S]) -> rotated x."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                      # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, S, hd/2]
    cos = jnp.cos(angles)[..., None, :]                      # [B, S, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, dim: int) -> jax.Array:
    """Fixed sinusoidal embeddings (Whisper encoder positional stub)."""
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    inv = 1.0 / (10_000 ** (jnp.arange(0, dim, 2, jnp.float32) / dim))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Linear / embedding (quantization-aware)
# ---------------------------------------------------------------------------

def init_linear(key, in_dim: int, out_dim: int, dtype, *, bias: bool = False):
    p = bl.init_linear(key, in_dim, out_dim, dtype=dtype)
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def linear(params, x: jax.Array, quant: bl.QuantConfig = bl.DENSE) -> jax.Array:
    return bl.apply_linear(params, x, quant)


def layer_quant_cfg(cfg, idx: int):
    """Resolve a per-layer §IV-D quant schedule for decoder layer ``idx``.

    With ``cfg.quant.m_schedule`` set, returns ``cfg`` specialized to that
    layer's level count (entry ``idx``, last entry extended if the schedule
    is short); otherwise returns ``cfg`` unchanged.  ``idx`` counts global
    decoder layers — leading dense layers first, then the main stack — the
    same order ``deploy``'s per-instruction schedules use for CNNs.
    """
    sched = cfg.quant.m_schedule
    if sched is None:
        return cfg
    m = sched[idx] if idx < len(sched) else sched[-1]
    return cfg.replace(
        quant=cfg.quant.replace(m_active=int(m), m_schedule=None))


def init_embedding(key, vocab: int, dim: int, dtype):
    return {"table": (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)}


def embed(params, tokens: jax.Array) -> jax.Array:
    return params["table"][tokens]


def unembed(params, x: jax.Array) -> jax.Array:
    """Logits in fp32 (loss numerics)."""
    return x.astype(jnp.float32) @ params["table"].astype(jnp.float32).T


# ---------------------------------------------------------------------------
# Masks
# ---------------------------------------------------------------------------

def causal_mask(S: int, window: int | None = None) -> jax.Array:
    """[S, S] bool; True = attend.  window = sliding-window width."""
    q = jnp.arange(S)[:, None]
    k = jnp.arange(S)[None, :]
    m = k <= q
    if window is not None:
        m &= (q - k) < window
    return m


def softcap(logits: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)
