"""Decoder-only LM stack: dense / MoE / MLA variants, VLM prefix, MTP head.

Layers are scanned (stacked params) for compile-time efficiency at 40-80
layers; remat wraps the scan body.  Two homogeneous stacks are supported:
leading dense layers (DeepSeek-V3's 3) and the main stack (dense FFN or MoE).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import common as cm
from repro.models import ffn as ffn_mod
from repro.models import moe as moe_mod


# ---------------------------------------------------------------------------
# Single layer
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ArchConfig, *, kind: str):
    """kind: 'dense' | 'moe'."""
    k1, k2 = jax.random.split(key)
    dt = cfg.jnp_dtype
    p = {
        "ln1": cm.init_rmsnorm(cfg.d_model, dt),
        "ln2": cm.init_rmsnorm(cfg.d_model, dt),
        "attn": attn.init_mla(k1, cfg) if cfg.use_mla else attn.init_attn(k1, cfg),
    }
    if kind == "moe":
        p["moe"] = moe_mod.init_moe(k2, cfg)
    else:
        d_ff = cfg.d_ff if cfg.d_ff else (cfg.d_ff_expert or 128)
        p["ffn"] = ffn_mod.init_ffn(k2, cfg, d_ff=d_ff)
    return p


def layer_forward(params, x, cfg: ArchConfig, *, positions=None, mask=None):
    x = cm.shard(x, "batch", "seq", None)
    h = cm.rms_norm(params["ln1"], x, cfg.norm_eps)
    if cfg.use_mla:
        a = attn.mla_forward(params["attn"], h, cfg, positions=positions, mask=mask)
    else:
        a = attn.attn_forward(params["attn"], h, cfg, positions=positions, mask=mask)
    x = x + a
    h = cm.rms_norm(params["ln2"], x, cfg.norm_eps)
    aux = {}
    if "moe" in params:
        f, aux = moe_mod.moe_ffn(params["moe"], h, cfg)
    else:
        f = ffn_mod.ffn_forward(params["ffn"], h, cfg)
    return x + f, aux


def layer_decode(params, x, cfg: ArchConfig, cache, pos):
    h = cm.rms_norm(params["ln1"], x, cfg.norm_eps)
    if cfg.use_mla:
        a, cache = attn.mla_decode(params["attn"], h, cfg, cache, pos)
    else:
        a, cache = attn.attn_decode(params["attn"], h, cfg, cache, pos)
    x = x + a
    h = cm.rms_norm(params["ln2"], x, cfg.norm_eps)
    if "moe" in params:
        f, _ = moe_mod.moe_ffn(params["moe"], h, cfg)
    else:
        f = ffn_mod.ffn_forward(params["ffn"], h, cfg)
    return x + f, cache


def layer_prefill(params, x, cfg: ArchConfig, *, positions, mask, max_len):
    """Full-sequence layer pass that also emits the layer's decode cache."""
    h = cm.rms_norm(params["ln1"], x, cfg.norm_eps)
    if cfg.use_mla:
        a, kv = attn.mla_prefill(params["attn"], h, cfg, max_len=max_len,
                                 positions=positions, mask=mask)
    else:
        a, kv = attn.attn_prefill(params["attn"], h, cfg, max_len=max_len,
                                  positions=positions, mask=mask)
    x = x + a
    h = cm.rms_norm(params["ln2"], x, cfg.norm_eps)
    if "moe" in params:
        f, _ = moe_mod.moe_ffn(params["moe"], h, cfg)
    else:
        f = ffn_mod.ffn_forward(params["ffn"], h, cfg)
    return x + f, kv


# ---------------------------------------------------------------------------
# Full LM
# ---------------------------------------------------------------------------

def _stacked_init(key, cfg: ArchConfig, n: int, kind: str):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: init_layer(k, cfg, kind=kind))(keys)


def init_lm(key, cfg: ArchConfig):
    ks = jax.random.split(key, 6)
    main_kind = "moe" if cfg.n_experts else "dense"
    n_main = cfg.n_layers - cfg.n_dense_layers
    p = {
        "embed": cm.init_embedding(ks[0], cfg.vocab, cfg.d_model, cfg.jnp_dtype),
        "layers": _stacked_init(ks[1], cfg, n_main, main_kind),
        "final_norm": cm.init_rmsnorm(cfg.d_model, cfg.jnp_dtype),
    }
    if cfg.n_dense_layers:
        p["dense_layers"] = _stacked_init(ks[2], cfg, cfg.n_dense_layers, "dense")
    if not cfg.tie_embeddings:
        p["unembed"] = cm.init_embedding(ks[3], cfg.vocab, cfg.d_model, cfg.jnp_dtype)
    if cfg.mtp_depth:
        p["mtp"] = {
            "proj": cm.init_linear(ks[4], 2 * cfg.d_model, cfg.d_model, cfg.jnp_dtype),
            "layer": init_layer(ks[5], cfg, kind="dense"),
            "norm": cm.init_rmsnorm(cfg.d_model, cfg.jnp_dtype),
        }
    return p


def _run_stack(stacked, x, cfg: ArchConfig, positions, mask, *,
               layer0: int = 0):
    """Scan (or unrolled loop) over a homogeneous layer stack.

    A per-layer quant schedule (``cfg.quant.m_schedule``, §IV-D) forces the
    unrolled walk — scan requires a layer-uniform body, and the schedule
    makes each layer's level count a distinct static value.  ``layer0`` is
    the stack's global layer offset (dense_layers first, then the main
    stack), so schedule indices line up across both stacks.
    """
    per_layer = cfg.quant.m_schedule is not None

    def make_body(cfg_i):
        def body(carry, layer_params):
            y, aux = layer_forward(layer_params, carry, cfg_i,
                                   positions=positions, mask=mask)
            return y, aux.get("load_balance_loss", jnp.float32(0.0))

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        return body

    if cfg.scan_layers and not per_layer:
        x, lb = jax.lax.scan(make_body(cfg), x, stacked)
        return x, jnp.sum(lb)
    n = jax.tree.leaves(stacked)[0].shape[0]
    total = jnp.float32(0.0)
    for i in range(n):
        layer = jax.tree.map(lambda t: t[i], stacked)
        x, lb = make_body(cm.layer_quant_cfg(cfg, layer0 + i))(x, layer)
        total += lb
    return x, total


def lm_hidden(params, cfg: ArchConfig, tokens, *, prefix_embeds=None):
    """Token (+ optional prefix) embeddings -> final hidden states."""
    x = cm.embed(params["embed"], tokens).astype(cfg.jnp_dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    mask = cm.causal_mask(S, cfg.sliding_window)
    lb_total = jnp.float32(0.0)
    if "dense_layers" in params:
        x, lb = _run_stack(params["dense_layers"], x, cfg, positions, mask)
        lb_total += lb
    x, lb = _run_stack(params["layers"], x, cfg, positions, mask,
                       layer0=cfg.n_dense_layers)
    lb_total += lb
    x = cm.rms_norm(params["final_norm"], x, cfg.norm_eps)
    if prefix_embeds is not None:
        x = x[:, prefix_embeds.shape[1]:]
    return x, {"load_balance_loss": lb_total}


def lm_logits(params, cfg: ArchConfig, hidden):
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = cm.unembed(table, hidden)
    logits = cm.shard(logits, "batch", None, "vocab")
    return cm.softcap(logits, cfg.logit_softcap)


def lm_forward(params, cfg: ArchConfig, tokens, *, prefix_embeds=None):
    hidden, aux = lm_hidden(params, cfg, tokens, prefix_embeds=prefix_embeds)
    return lm_logits(params, cfg, hidden), aux


def mtp_logits(params, cfg: ArchConfig, hidden, tokens):
    """DeepSeek-V3 multi-token prediction: predict t+2 from (h_t, emb_{t+1}).

    hidden: [B, S, D] main-stack output; tokens: [B, S].  Returns logits for
    positions predicting tokens[t+2] (length S-1, caller aligns labels).
    """
    emb_next = cm.embed(params["embed"], tokens[:, 1:]).astype(hidden.dtype)
    h = jnp.concatenate([hidden[:, :-1], emb_next], axis=-1)
    h = cm.linear(params["mtp"]["proj"], h, cfg.quant)
    S = h.shape[1]
    h, _ = layer_forward(params["mtp"]["layer"], h, cfg,
                         positions=jnp.arange(S)[None, :],
                         mask=cm.causal_mask(S))
    h = cm.rms_norm(params["mtp"]["norm"], h, cfg.norm_eps)
    return lm_logits(params, cfg, h)


# --- decode -----------------------------------------------------------------

def lm_cache_specs(cfg: ArchConfig, batch: int, max_len: int):
    n_main = cfg.n_layers - cfg.n_dense_layers
    if cfg.use_mla:
        one = attn.mla_cache_specs(cfg, batch, max_len)
    else:
        one = attn.attn_cache_specs(cfg, batch, max_len)
    def stack(n):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n, *s.shape), s.dtype), one)

    spec = {"layers": stack(n_main)}
    if cfg.n_dense_layers:
        spec["dense_layers"] = stack(cfg.n_dense_layers)
    return spec


def init_lm_cache(cfg: ArchConfig, batch: int, max_len: int):
    def mk(s):
        if s.dtype == jnp.int32:
            return -jnp.ones(s.shape, s.dtype)
        return jnp.zeros(s.shape, s.dtype)

    return jax.tree.map(mk, lm_cache_specs(cfg, batch, max_len))


def _decode_stack(stacked, caches, x, cfg: ArchConfig, pos, *,
                  layer0: int = 0):
    per_layer = cfg.quant.m_schedule is not None

    def body(carry, inp, cfg_i=cfg):
        layer_params, cache = inp
        y, new_cache = layer_decode(layer_params, carry, cfg_i, cache, pos)
        return y, new_cache

    if cfg.scan_layers and not per_layer:
        return jax.lax.scan(body, x, (stacked, caches))
    n = jax.tree.leaves(stacked)[0].shape[0]
    new_caches = []
    for i in range(n):
        layer = jax.tree.map(lambda t: t[i], stacked)
        cache = jax.tree.map(lambda t: t[i], caches)
        x, nc = body(x, (layer, cache),
                     cfg_i=cm.layer_quant_cfg(cfg, layer0 + i))
        new_caches.append(nc)
    stacked_cache = jax.tree.map(lambda *ts: jnp.stack(ts), *new_caches)
    return x, stacked_cache


def _prefill_stack(stacked, x, cfg: ArchConfig, positions, mask, max_len, *,
                   layer0: int = 0):
    """Run a homogeneous layer stack over the full sequence, collecting each
    layer's decode cache (stacked [L, ...], same layout as lm_cache_specs)."""
    per_layer = cfg.quant.m_schedule is not None

    def body(carry, layer_params, cfg_i=cfg):
        return layer_prefill(layer_params, carry, cfg_i, positions=positions,
                             mask=mask, max_len=max_len)

    if cfg.scan_layers and not per_layer:
        return jax.lax.scan(body, x, stacked)
    n = jax.tree.leaves(stacked)[0].shape[0]
    caches = []
    for i in range(n):
        layer = jax.tree.map(lambda t: t[i], stacked)
        x, kv = body(x, layer, cfg_i=cm.layer_quant_cfg(cfg, layer0 + i))
        caches.append(kv)
    return x, jax.tree.map(lambda *ts: jnp.stack(ts), *caches)


def lm_prefill(params, cfg: ArchConfig, tokens, *, max_len: int):
    """Bulk prefill: one full-sequence pass -> (logits [B, S, V], cache).

    The cache matches ``lm_cache_specs(cfg, B, max_len)`` with positions
    0..S-1 populated — semantically identical to S token-wise
    ``lm_decode_step`` calls, in a single forward pass (the serving
    engine's admission path; see launch/serve.py).
    """
    x = cm.embed(params["embed"], tokens).astype(cfg.jnp_dtype)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    mask = cm.causal_mask(S, cfg.sliding_window)
    cache = {}
    if "dense_layers" in params:
        x, nc = _prefill_stack(params["dense_layers"], x, cfg, positions,
                               mask, max_len)
        cache["dense_layers"] = nc
    x, nc = _prefill_stack(params["layers"], x, cfg, positions, mask, max_len,
                           layer0=cfg.n_dense_layers)
    cache["layers"] = nc
    x = cm.rms_norm(params["final_norm"], x, cfg.norm_eps)
    return lm_logits(params, cfg, x), cache


def lm_decode_step(params, cfg: ArchConfig, tokens, pos, cache):
    """tokens: [B, 1], pos: [B] -> (logits [B, 1, V], new cache)."""
    x = cm.embed(params["embed"], tokens).astype(cfg.jnp_dtype)
    new_cache = {}
    if "dense_layers" in params:
        x, nc = _decode_stack(params["dense_layers"], cache["dense_layers"],
                              x, cfg, pos)
        new_cache["dense_layers"] = nc
    x, nc = _decode_stack(params["layers"], cache["layers"], x, cfg, pos,
                          layer0=cfg.n_dense_layers)
    new_cache["layers"] = nc
    x = cm.rms_norm(params["final_norm"], x, cfg.norm_eps)
    return lm_logits(params, cfg, x), new_cache
