"""Dense FFN blocks: SwiGLU / GeGLU / GELU-MLP."""
from __future__ import annotations

import jax

from repro.configs.base import ArchConfig
from repro.models import common as cm


def init_ffn(key, cfg: ArchConfig, d_ff: int | None = None):
    d_ff = d_ff or cfg.d_ff
    dt = cfg.jnp_dtype
    ks = jax.random.split(key, 3)
    if cfg.activation in ("swiglu", "geglu"):
        return {
            "w_gate": cm.init_linear(ks[0], cfg.d_model, d_ff, dt),
            "w_up": cm.init_linear(ks[1], cfg.d_model, d_ff, dt),
            "w_down": cm.init_linear(ks[2], d_ff, cfg.d_model, dt),
        }
    return {
        "w_up": cm.init_linear(ks[0], cfg.d_model, d_ff, dt),
        "w_down": cm.init_linear(ks[1], d_ff, cfg.d_model, dt),
    }


def ffn_forward(params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    q = cfg.quant
    if "w_gate" in params:
        act = jax.nn.silu if cfg.activation == "swiglu" else (
            lambda v: jax.nn.gelu(v, approximate=True))
        h = act(cm.linear(params["w_gate"], x, q)) * cm.linear(params["w_up"], x, q)
    else:
        h = jax.nn.gelu(cm.linear(params["w_up"], x, q), approximate=True)
    h = cm.shard(h, "batch", None, "ff")
    return cm.linear(params["w_down"], h, q)
