"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a STUB per the assignment: ``frame_embeds``
([B, T_enc, D], precomputed) arrive as inputs.  Encoder: bidirectional
self-attention with fixed sinusoidal positions.  Decoder: causal
self-attention + cross-attention to encoder output.  Decode caches the
decoder self-KV plus the (static) cross K/V.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import common as cm
from repro.models import ffn as ffn_mod


def _init_cross_attn(key, cfg: ArchConfig):
    return attn.init_attn(key, cfg)  # same projection structure


def init_encdec(key, cfg: ArchConfig):
    ks = jax.random.split(key, 8)
    dt = cfg.jnp_dtype

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": cm.init_rmsnorm(cfg.d_model, dt),
            "ln2": cm.init_rmsnorm(cfg.d_model, dt),
            "attn": attn.init_attn(k1, cfg),
            "ffn": ffn_mod.init_ffn(k2, cfg),
        }

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "ln1": cm.init_rmsnorm(cfg.d_model, dt),
            "ln_x": cm.init_rmsnorm(cfg.d_model, dt),
            "ln2": cm.init_rmsnorm(cfg.d_model, dt),
            "attn": attn.init_attn(k1, cfg),
            "xattn": _init_cross_attn(k2, cfg),
            "ffn": ffn_mod.init_ffn(k3, cfg),
        }

    enc_keys = jax.random.split(ks[0], cfg.n_encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "embed": cm.init_embedding(ks[2], cfg.vocab, cfg.d_model, dt),
        "enc_layers": jax.vmap(enc_layer)(enc_keys),
        "enc_norm": cm.init_rmsnorm(cfg.d_model, dt),
        "dec_layers": jax.vmap(dec_layer)(dec_keys),
        "final_norm": cm.init_rmsnorm(cfg.d_model, dt),
    }


def _cross_attend(params, x, enc_kv, cfg: ArchConfig):
    """x: [B, Sq, D] queries; enc_kv = (k, v): [B, Se, kv, hd]."""
    B, Sq, _ = x.shape
    hd = cfg.resolved_head_dim
    q = cm.linear(params["wq"], x, cfg.quant).reshape(B, Sq, cfg.n_heads, hd)
    k, v = enc_kv
    logits = attn._gqa_scores(q, k, cfg)
    w = jax.nn.softmax(logits, axis=-1)
    o = attn._gqa_out(w, v, cfg).astype(x.dtype)
    return cm.linear(params["wo"], o, cfg.quant)


def _enc_kv(params, enc_out, cfg: ArchConfig):
    B, Se, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = cm.linear(params["wk"], enc_out, cfg.quant).reshape(B, Se, cfg.n_kv_heads, hd)
    v = cm.linear(params["wv"], enc_out, cfg.quant).reshape(B, Se, cfg.n_kv_heads, hd)
    return k, v


def encode(params, cfg: ArchConfig, frame_embeds):
    """frame_embeds: [B, Se, D] (stub frontend output) -> encoder states."""
    B, Se, D = frame_embeds.shape
    x = frame_embeds.astype(cfg.jnp_dtype) + cm.sinusoidal_positions(
        Se, D).astype(cfg.jnp_dtype)[None]
    mask = jnp.ones((Se, Se), bool)  # bidirectional
    positions = jnp.arange(Se)[None, :]

    def body(carry, layer):
        h = cm.rms_norm(layer["ln1"], carry, cfg.norm_eps)
        carry = carry + attn.attn_forward(layer["attn"], h, cfg,
                                          positions=positions, mask=mask)
        h = cm.rms_norm(layer["ln2"], carry, cfg.norm_eps)
        carry = carry + ffn_mod.ffn_forward(layer["ffn"], h, cfg)
        return carry, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
    else:
        n = jax.tree.leaves(params["enc_layers"])[0].shape[0]
        for i in range(n):
            x, _ = body(x, jax.tree.map(lambda t: t[i], params["enc_layers"]))
    return cm.rms_norm(params["enc_norm"], x, cfg.norm_eps)


def encdec_forward(params, cfg: ArchConfig, tokens, frame_embeds):
    """Teacher-forced full-sequence forward -> logits [B, S, V]."""
    enc_out = encode(params, cfg, frame_embeds)
    B, S = tokens.shape
    x = cm.embed(params["embed"], tokens).astype(cfg.jnp_dtype)
    positions = jnp.arange(S)[None, :]
    mask = cm.causal_mask(S)

    def body(carry, layer):
        h = cm.rms_norm(layer["ln1"], carry, cfg.norm_eps)
        carry = carry + attn.attn_forward(layer["attn"], h, cfg,
                                          positions=positions, mask=mask)
        h = cm.rms_norm(layer["ln_x"], carry, cfg.norm_eps)
        carry = carry + _cross_attend(layer["xattn"], h,
                                      _enc_kv(layer["xattn"], enc_out, cfg), cfg)
        h = cm.rms_norm(layer["ln2"], carry, cfg.norm_eps)
        carry = carry + ffn_mod.ffn_forward(layer["ffn"], h, cfg)
        return carry, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["dec_layers"])
    else:
        n = jax.tree.leaves(params["dec_layers"])[0].shape[0]
        for i in range(n):
            x, _ = body(x, jax.tree.map(lambda t: t[i], params["dec_layers"]))
    x = cm.rms_norm(params["final_norm"], x, cfg.norm_eps)

    table = params["embed"]
    return cm.softcap(cm.unembed(table, x), cfg.logit_softcap)


def encdec_cache_specs(cfg: ArchConfig, batch: int, max_len: int):
    hd = cfg.resolved_head_dim
    dt = cfg.jnp_dtype
    n = cfg.n_layers
    self_spec = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n, *s.shape), s.dtype),
        attn.attn_cache_specs(cfg, batch, max_len))
    cross = jax.ShapeDtypeStruct((n, batch, cfg.encoder_len, cfg.n_kv_heads, hd), dt)
    return {"self": self_spec, "cross_k": cross, "cross_v": cross}


def init_encdec_cache(params, cfg: ArchConfig, batch: int, max_len: int,
                      frame_embeds=None):
    spec = encdec_cache_specs(cfg, batch, max_len)
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)
    if frame_embeds is not None:
        enc_out = encode(params, cfg, frame_embeds)
        n = cfg.n_layers
        ks, vs = [], []
        for i in range(n):
            layer = jax.tree.map(lambda t: t[i], params["dec_layers"])
            k, v = _enc_kv(layer["xattn"], enc_out, cfg)
            ks.append(k)
            vs.append(v)
        cache["cross_k"] = jnp.stack(ks)
        cache["cross_v"] = jnp.stack(vs)
    return cache


def encdec_decode_step(params, cfg: ArchConfig, tokens, pos, cache):
    """tokens [B,1], pos [B]; cross K/V precomputed in cache."""
    x = cm.embed(params["embed"], tokens).astype(cfg.jnp_dtype)

    def body(carry, inp):
        layer, self_cache, ck, cv = inp
        h = cm.rms_norm(layer["ln1"], carry, cfg.norm_eps)
        a, new_self = attn.attn_decode(layer["attn"], h, cfg, self_cache, pos)
        carry = carry + a
        h = cm.rms_norm(layer["ln_x"], carry, cfg.norm_eps)
        carry = carry + _cross_attend(layer["xattn"], h, (ck, cv), cfg)
        h = cm.rms_norm(layer["ln2"], carry, cfg.norm_eps)
        carry = carry + ffn_mod.ffn_forward(layer["ffn"], h, cfg)
        return carry, new_self

    if cfg.scan_layers:
        x, new_self = jax.lax.scan(
            body, x,
            (params["dec_layers"], cache["self"], cache["cross_k"], cache["cross_v"]))
    else:
        n = cfg.n_layers
        outs = []
        for i in range(n):
            inp = jax.tree.map(lambda t: t[i],
                               (params["dec_layers"], cache["self"],
                                cache["cross_k"], cache["cross_v"]))
            x, ns = body(x, inp)
            outs.append(ns)
        new_self = jax.tree.map(lambda *ts: jnp.stack(ts), *outs)
    x = cm.rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = cm.softcap(cm.unembed(params["embed"], x), cfg.logit_softcap)
    return logits, dict(cache, self=new_self)
