"""Mixture-of-Experts with gather-based dispatch and expert parallelism.

Dispatch is index-based (no [T, E, C] one-hot) and *batch-blocked*: each
batch row (sequence) dispatches its own tokens to per-expert capacity slots,
so with batch sharded over the DP axes and experts over 'model', the dispatch
gather stays local to the data shard and the combine gather is the only
cross-'model' movement (the all-to-all-equivalent of real EP).  Tokens beyond
capacity are dropped (GShard-style).

Decode (S == 1) instead dispatches globally across the (tiny) token batch so
per-expert capacity stays ~top_k·B/E instead of one slot per (row, expert)
— avoiding E/top_k x FLOP waste at decode (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common as cm


def init_moe(key, cfg: ArchConfig):
    dt = cfg.jnp_dtype
    E = cfg.n_experts
    F = cfg.d_ff_expert or cfg.d_ff
    D = cfg.d_model
    ks = jax.random.split(key, 5)
    s = 1.0 / jnp.sqrt(D)
    p = {
        "router": {"w": (jax.random.normal(ks[0], (D, E)) * s).astype(jnp.float32)},
        "w_gate": (jax.random.normal(ks[1], (E, D, F)) * s).astype(dt),
        "w_up": (jax.random.normal(ks[2], (E, D, F)) * s).astype(dt),
        "w_down": (jax.random.normal(ks[3], (E, F, D)) * (1.0 / jnp.sqrt(F))).astype(dt),
    }
    if cfg.n_shared_experts:
        from repro.models.ffn import init_ffn

        p["shared"] = init_ffn(ks[4], cfg, d_ff=F * cfg.n_shared_experts)
    return p


def _dispatch_indices(expert_ids: jax.Array, E: int, capacity: int):
    """expert_ids: [T, k] -> (dispatch [E, C] token-row indices, sentinel=T;
    slot [T, k]: position inside the expert, -1 if dropped)."""
    T, k = expert_ids.shape
    flat = expert_ids.reshape(-1)                                   # [T*k]
    onehot = jax.nn.one_hot(flat, E, dtype=jnp.int32)               # [T*k, E]
    ranks = jnp.cumsum(onehot, axis=0) - onehot
    slot = jnp.sum(ranks * onehot, axis=1)                          # [T*k]
    ok = slot < capacity
    token_row = jnp.arange(T * k, dtype=jnp.int32) // k
    dispatch = jnp.full((E, capacity), T, jnp.int32)
    dispatch = dispatch.at[flat, slot].set(
        jnp.where(ok, token_row, T), mode="drop")
    return dispatch, jnp.where(ok, slot, -1).reshape(T, k)


def _expert_weights(params, cfg: ArchConfig, dtype):
    q = cfg.quant
    if q.mode == "fake_quant":
        from repro.core import binarize as bz

        binz = jax.vmap(lambda w: bz.fake_quant(
            w.astype(jnp.float32), q.M, algorithm=q.algorithm,
            K_iters=q.K_iters, group_size=q.group_size))
        return (binz(params["w_gate"]).astype(dtype),
                binz(params["w_up"]).astype(dtype),
                binz(params["w_down"]).astype(dtype))
    return params["w_gate"], params["w_up"], params["w_down"]


def moe_ffn(params, x: jax.Array, cfg: ArchConfig):
    """x: [B, S, D] -> (y, aux metrics)."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    # group layout: per-row dispatch for sequences, global for decode
    if S == 1:
        G, Sg = 1, B
    else:
        G, Sg = B, S
    xg = x.reshape(G, Sg, D)
    # --- routing (fp32) ---
    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32),
                        params["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)                 # [G, Sg, k]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    # --- dispatch (per group) ---
    capacity = max(1, int(cfg.capacity_factor * Sg * k / E))
    dispatch, slot = jax.vmap(
        lambda ids: _dispatch_indices(ids, E, capacity))(expert_ids)
    x_pad = jnp.concatenate([xg, jnp.zeros((G, 1, D), xg.dtype)], axis=1)
    expert_in = jax.vmap(lambda xp, di: xp[di])(x_pad, dispatch)    # [G,E,C,D]
    expert_in = cm.shard(expert_in, "batch", "experts", None, None)
    # --- expert computation (grouped GEMMs, EP over 'model') ---
    w_gate, w_up, w_down = _expert_weights(params, cfg, x.dtype)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, w_gate)) \
        * jnp.einsum("gecd,edf->gecf", expert_in, w_up)
    h = cm.shard(h, "batch", "experts", None, None)
    expert_out = jnp.einsum("gecf,efd->gecd", h, w_down)            # [G,E,C,D]
    # --- combine (the all-to-all-equivalent gather) ---
    ok = slot >= 0
    gathered = jax.vmap(
        lambda eo, ids, sl: eo[ids, jnp.clip(sl, 0, capacity - 1)]
    )(expert_out, expert_ids, slot)                                 # [G,Sg,k,D]
    y = jnp.sum(
        jnp.where(ok[..., None], gathered, 0.0)
        * gate_vals[..., None].astype(gathered.dtype), axis=2)
    if cfg.n_shared_experts:
        from repro.models.ffn import ffn_forward

        y = y + ffn_forward(params["shared"], xg, cfg).astype(y.dtype)
    # --- aux: load-balance loss (Switch-style) ---
    frac_tokens = jnp.mean(
        jax.nn.one_hot(expert_ids[..., 0], E), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = {"load_balance_loss": E * jnp.sum(frac_tokens * frac_probs),
           "dropped_frac": 1.0 - jnp.mean(ok.astype(jnp.float32))}
    return y.reshape(B, S, D).astype(x.dtype), aux
