"""Mamba2 / SSD (state-space duality) blocks — arXiv:2405.21060.

Training/prefill uses the chunked SSD algorithm (quadratic within a chunk,
linear across chunks); decode is the O(1) recurrent state update.  The large
projections (in_proj/out_proj — the FLOP carriers) route through the
quantizable linear, so the paper's binary approximation applies; the SSM
dynamics parameters (A_log, D, dt_bias, conv) stay fp (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common as cm


def _dims(cfg: ArchConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_head_dim
    conv_ch = d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return d_inner, H, conv_ch


def init_mamba2(key, cfg: ArchConfig):
    d_inner, H, conv_ch = _dims(cfg)
    dt = cfg.jnp_dtype
    n = cfg.ssm_state
    g = cfg.ssm_ngroups
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_inner + 2 * g * n + H  # z, x, B, C, dt
    p = {
        "in_proj": cm.init_linear(ks[0], cfg.d_model, proj_out, dt),
        "out_proj": cm.init_linear(ks[1], d_inner, cfg.d_model, dt),
        "conv_w": (jax.random.normal(ks[2], (cfg.ssm_conv_width, conv_ch)) * 0.1
                   ).astype(jnp.float32),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": cm.init_rmsnorm(d_inner, dt),
    }
    return p


def _split_proj(cfg: ArchConfig, proj: jax.Array):
    d_inner, H, _ = _dims(cfg)
    n, g = cfg.ssm_state, cfg.ssm_ngroups
    idx = [d_inner, 2 * d_inner, 2 * d_inner + g * n, 2 * d_inner + 2 * g * n]
    z = proj[..., : idx[0]]
    xh = proj[..., idx[0]: idx[1]]
    Bm = proj[..., idx[1]: idx[2]]
    Cm = proj[..., idx[2]: idx[3]]
    dt_raw = proj[..., idx[3]:]
    return z, xh, Bm, Cm, dt_raw


def _causal_dconv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv, x: [B, L, ch], w: [width, ch] -> [B, L, ch]."""
    width = w.shape[0]
    xf = x.astype(jnp.float32)
    pad = jnp.pad(xf, ((0, 0), (width - 1, 0), (0, 0)))
    y = jnp.zeros_like(xf)
    for i in range(width):
        y = y + pad[:, i: i + x.shape[1], :] * w[i]
    return jax.nn.silu(y + b).astype(x.dtype)


def _segsum(x: jax.Array) -> jax.Array:
    """x: [..., q] -> [..., q, q]; [i, j] = sum_{j<k<=i} x_k, -inf above diag."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(xh, dt, A, Bm, Cm, D, chunk: int, *, return_state: bool = False):
    """Chunked SSD scan (Mamba2 Listing 1, jnp).

    xh: [b, l, h, p]  dt: [b, l, h]  A: [h] (negative)
    Bm, Cm: [b, l, g, n] (g groups broadcast over heads)  D: [h]
    returns y: [b, l, h, p]; with ``return_state`` also the recurrent state
    *after* the last token ([b, h, p, n] fp32 — the decode ``ssm_state``),
    which is what bulk prefill scatters into the serving cache.

    Heads are factored as h = g x e and B/C keep their group dim throughout —
    materializing the head-broadcast ([..., h, n] via jnp.repeat) cost
    zamba2 ~3x its whole-model HBM traffic (EXPERIMENTS.md §Perf, zamba2
    iteration).  Einsums accumulate in fp32.
    """
    b, l, h, p = xh.shape
    g = Bm.shape[2]
    e = h // g
    assert l % chunk == 0, (l, chunk)
    c = l // chunk
    xf = xh.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)                            # [b, l, g, n]
    Cf = Cm.astype(jnp.float32)
    dA = dtf * A[None, None, :]                            # [b, l, h]
    x_dt = xf * dtf[..., None]                             # dt-premultiplied

    def ch(t):  # [b, l, ...] -> [b, c, q, ...]
        return t.reshape(b, c, chunk, *t.shape[2:])

    xc = ch(x_dt).reshape(b, c, chunk, g, e, p)            # [b,c,q,g,e,p]
    dAc = ch(dA).reshape(b, c, chunk, g, e)                # [b,c,q,g,e]
    Bc, Cc = ch(Bf), ch(Cf)                                # [b,c,q,g,n]
    dA_cs = jnp.cumsum(dAc, axis=2)                        # [b,c,q,g,e]
    # --- intra-chunk (diagonal blocks) ---
    L = jnp.exp(_segsum(jnp.moveaxis(dAc, 2, -1)))         # [b,c,g,e,q,q]
    Y_diag = jnp.einsum("bclgn,bcsgn,bcgels,bcsgep->bclgep", Cc, Bc, L, xc)
    # --- chunk final states ---
    decay_states = jnp.exp(dA_cs[:, :, -1:] - dA_cs)       # [b,c,q,g,e]
    states = jnp.einsum("bcsgn,bcsge,bcsgep->bcgepn", Bc, decay_states, xc)
    # --- inter-chunk recurrence (scan over chunks) ---
    chunk_decay = jnp.exp(dA_cs[:, :, -1])                 # [b,c,g,e]

    def scan_fn(carry, inp):
        st_c, dec_c = inp                                  # [b,g,e,p,n], [b,g,e]
        new = carry * dec_c[..., None, None] + st_c
        return new, carry                                  # state BEFORE chunk

    init = jnp.zeros((b, g, e, p, states.shape[-1]), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)          # [b,c,g,e,p,n]
    # --- state -> output ---
    state_decay = jnp.exp(dA_cs)                           # [b,c,q,g,e]
    Y_off = jnp.einsum("bclgn,bcgepn,bclge->bclgep", Cc, prev_states,
                       state_decay)
    y = (Y_diag + Y_off).reshape(b, l, h, p)
    y = y + xf * D[None, None, :, None]
    if return_state:
        return y, final_state.reshape(b, h, p, states.shape[-1])
    return y


def cfg_state_n(states: jax.Array) -> int:
    return states.shape[-1]


def _mamba2_seq(params, x: jax.Array, cfg: ArchConfig, *, want_cache: bool):
    """Shared full-sequence core for forward (train) and prefill (serve)."""
    B, L, _ = x.shape
    d_inner, H, _ = _dims(cfg)
    n, g = cfg.ssm_state, cfg.ssm_ngroups
    proj = cm.linear(params["in_proj"], x, cfg.quant)
    z, xh, Bm, Cm, dt_raw = _split_proj(cfg, proj)
    xBC_pre = jnp.concatenate([xh, Bm, Cm], axis=-1)         # pre-conv stream
    xBC = _causal_dconv(xBC_pre, params["conv_w"], params["conv_b"])
    xh = xBC[..., :d_inner].reshape(B, L, H, cfg.ssm_head_dim)
    Bm = xBC[..., d_inner: d_inner + g * n].reshape(B, L, g, n)
    Cm = xBC[..., d_inner + g * n:].reshape(B, L, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    # largest divisor of L that fits the configured chunk — arbitrary prompt
    # lengths must work (the bulk-prefill path sees prompt_len-1, not a
    # training shape); worst case (prime L) degrades to chunk=1, still exact
    chunk = min(cfg.ssm_chunk, L)
    while L % chunk:
        chunk -= 1
    y = ssd_chunked(xh, dt, A, Bm, Cm, params["D"], chunk,
                    return_state=want_cache)                 # [B, L, H, p] f32
    cache = None
    if want_cache:
        y, final_state = y
        # conv_state holds the last (width-1) *pre-activation* xBC rows —
        # exactly what token-wise decode keeps (zero-padded when L < width-1)
        w1 = cfg.ssm_conv_width - 1
        conv_state = jnp.pad(xBC_pre, ((0, 0), (w1, 0), (0, 0)))[:, L:]
        cache = {"ssm_state": final_state,
                 "conv_state": conv_state.astype(cfg.jnp_dtype)}
    y = y.reshape(B, L, d_inner)
    y = cm.rms_norm_gated(params["norm"], y.astype(x.dtype), z, cfg.norm_eps)
    out = cm.linear(params["out_proj"], y, cfg.quant)
    return out, cache


def mamba2_forward(params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Full-sequence Mamba2 block. x: [B, L, D] -> [B, L, D]."""
    out, _ = _mamba2_seq(params, x, cfg, want_cache=False)
    return out


def mamba2_prefill(params, x: jax.Array, cfg: ArchConfig):
    """Full-sequence Mamba2 block that also returns the decode cache.

    x: [B, L, D] -> (y [B, L, D], cache as in :func:`mamba2_cache_specs`),
    with the cache holding the recurrent state *after* token L-1 — the bulk
    prefill path: one chunked-SSD pass instead of L decode steps.
    """
    return _mamba2_seq(params, x, cfg, want_cache=True)


# --- decode -----------------------------------------------------------------

def mamba2_cache_specs(cfg: ArchConfig, batch: int):
    d_inner, H, conv_ch = _dims(cfg)
    return {
        "ssm_state": jax.ShapeDtypeStruct(
            (batch, H, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
        "conv_state": jax.ShapeDtypeStruct(
            (batch, cfg.ssm_conv_width - 1, conv_ch), cfg.jnp_dtype),
    }


def init_mamba2_cache(cfg: ArchConfig, batch: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        mamba2_cache_specs(cfg, batch))


def mamba2_decode(params, x: jax.Array, cfg: ArchConfig, cache,
                  update_mask: jax.Array | None = None):
    """One-token recurrent update. x: [B, 1, D] -> (y [B, 1, D], cache).

    ``update_mask`` ([B] bool, optional) gates the *state write-back* per
    batch row: rows where it is False keep their ssm/conv state bit-exact
    (their returned y is garbage and must be ignored by the caller).  This
    is what lets a serving engine run a grouped decode (§IV-D: slots grouped
    by per-request ``m_active``) over a shared batch without pad tokens
    advancing — i.e. corrupting — the recurrent state of slots outside the
    running group.  ``None`` means update every row (train/single-group).
    """
    B = x.shape[0]
    d_inner, H, conv_ch = _dims(cfg)
    n, g = cfg.ssm_state, cfg.ssm_ngroups
    proj = cm.linear(params["in_proj"], x[:, 0], cfg.quant)     # [B, proj]
    z, xh, Bm, Cm, dt_raw = _split_proj(cfg, proj)
    xBC_new = jnp.concatenate([xh, Bm, Cm], axis=-1)            # [B, conv_ch]
    window = jnp.concatenate(
        [cache["conv_state"].astype(jnp.float32),
         xBC_new[:, None, :].astype(jnp.float32)], axis=1)      # [B, w, ch]
    conv = jnp.einsum("bwc,wc->bc", window, params["conv_w"]) + params["conv_b"]
    xBC = jax.nn.silu(conv)
    xh = xBC[:, :d_inner].reshape(B, H, cfg.ssm_head_dim)
    Bv = xBC[:, d_inner: d_inner + g * n].reshape(B, g, n)
    Cv = xBC[:, d_inner + g * n:].reshape(B, g, n)
    rep = H // g
    Bv = jnp.repeat(Bv, rep, axis=1)                            # [B, H, n]
    Cv = jnp.repeat(Cv, rep, axis=1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # [B, H]
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt * A[None, :])                               # [B, H]
    state = cache["ssm_state"] * dA[..., None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dt, xh.astype(jnp.float32), Bv)
    y = jnp.einsum("bhpn,bhn->bhp", state, Cv) + params["D"][None, :, None] * xh
    y = y.reshape(B, d_inner).astype(x.dtype)
    y = cm.rms_norm_gated(params["norm"], y, z, cfg.norm_eps)
    out = cm.linear(params["out_proj"], y, cfg.quant)[:, None, :]
    new_conv = window[:, 1:].astype(cache["conv_state"].dtype)
    if update_mask is not None:
        keep = update_mask.astype(bool)
        state = jnp.where(keep[:, None, None, None], state, cache["ssm_state"])
        new_conv = jnp.where(keep[:, None, None], new_conv, cache["conv_state"])
    new_cache = {
        "ssm_state": state,
        "conv_state": new_conv,
    }
    return out, new_cache
