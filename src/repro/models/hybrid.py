"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention block.

Faithful structure (arXiv:2411.15242): the backbone is a stack of Mamba2
blocks; every ``hybrid_attn_every`` blocks, a single shared
attention+MLP block (one set of parameters, reused at every application
point) processes concat(current hidden, original embedding) projected back
to d_model.  Each application point keeps its own KV cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import common as cm
from repro.models import ffn as ffn_mod
from repro.models import ssm as ssm_mod


def n_attn_points(cfg: ArchConfig) -> int:
    return cfg.n_layers // cfg.hybrid_attn_every


def init_hybrid(key, cfg: ArchConfig):
    ks = jax.random.split(key, 6)
    dt = cfg.jnp_dtype
    mamba_keys = jax.random.split(ks[0], cfg.n_layers)
    k1, k2 = jax.random.split(ks[1])
    return {
        "embed": cm.init_embedding(ks[2], cfg.vocab, cfg.d_model, dt),
        "mamba_layers": jax.vmap(
            lambda k: dict(norm=cm.init_rmsnorm(cfg.d_model, dt),
                           block=ssm_mod.init_mamba2(k, cfg)))(mamba_keys),
        "shared": {
            "in_proj": cm.init_linear(ks[3], 2 * cfg.d_model, cfg.d_model, dt),
            "ln1": cm.init_rmsnorm(cfg.d_model, dt),
            "ln2": cm.init_rmsnorm(cfg.d_model, dt),
            "attn": attn.init_attn(k1, cfg),
            "ffn": ffn_mod.init_ffn(k2, cfg),
        },
        "final_norm": cm.init_rmsnorm(cfg.d_model, dt),
    }


def _shared_block(shared, x, x0, cfg: ArchConfig, *, positions, mask):
    h = cm.linear(shared["in_proj"],
                  jnp.concatenate([x, x0], axis=-1), cfg.quant)
    a = attn.attn_forward(shared["attn"],
                          cm.rms_norm(shared["ln1"], h, cfg.norm_eps),
                          cfg, positions=positions, mask=mask)
    h = h + a
    f = ffn_mod.ffn_forward(shared["ffn"],
                            cm.rms_norm(shared["ln2"], h, cfg.norm_eps), cfg)
    return x + h + f


def hybrid_hidden(params, cfg: ArchConfig, tokens):
    x = cm.embed(params["embed"], tokens).astype(cfg.jnp_dtype)
    x0 = x
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    mask = cm.causal_mask(S, cfg.sliding_window)
    every = cfg.hybrid_attn_every

    # per-layer §IV-D schedules force the unrolled walk, like transformer
    per_layer = cfg.quant.m_schedule is not None

    def make_body(cfg_i):
        def body(carry, inp):
            i, layer = inp
            h = cm.rms_norm(layer["norm"], carry, cfg_i.norm_eps)
            carry = carry + ssm_mod.mamba2_forward(layer["block"], h, cfg_i)
            carry = jax.lax.cond(
                (i + 1) % every == 0,
                lambda c: _shared_block(params["shared"], c, x0, cfg_i,
                                        positions=positions, mask=mask),
                lambda c: c,
                carry,
            )
            return carry, None

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        return body

    idx = jnp.arange(cfg.n_layers)
    if cfg.scan_layers and not per_layer:
        x, _ = jax.lax.scan(make_body(cfg), x, (idx, params["mamba_layers"]))
    else:
        for i in range(cfg.n_layers):
            x, _ = make_body(cm.layer_quant_cfg(cfg, i))(
                x, (jnp.int32(i),
                    jax.tree.map(lambda t: t[i], params["mamba_layers"])))
    return cm.rms_norm(params["final_norm"], x, cfg.norm_eps)


def hybrid_forward(params, cfg: ArchConfig, tokens):
    hidden = hybrid_hidden(params, cfg, tokens)
    return cm.unembed(params["embed"], hidden)


# --- decode -----------------------------------------------------------------

def hybrid_cache_specs(cfg: ArchConfig, batch: int, max_len: int):
    n_pts = n_attn_points(cfg)
    attn_one = attn.attn_cache_specs(cfg, batch, max_len)
    mamba_one = ssm_mod.mamba2_cache_specs(cfg, batch)
    return {
        "mamba": jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((cfg.n_layers, *s.shape), s.dtype),
            mamba_one),
        "attn": jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n_pts, *s.shape), s.dtype), attn_one),
    }


def init_hybrid_cache(cfg: ArchConfig, batch: int, max_len: int):
    def mk(s):
        if s.dtype == jnp.int32:
            return -jnp.ones(s.shape, s.dtype)
        return jnp.zeros(s.shape, s.dtype)

    return jax.tree.map(mk, hybrid_cache_specs(cfg, batch, max_len))


def _shared_block_decode(shared, x, x0, cfg: ArchConfig, cache, pos):
    h = cm.linear(shared["in_proj"],
                  jnp.concatenate([x, x0], axis=-1), cfg.quant)
    a, new_cache = attn.attn_decode(
        shared["attn"], cm.rms_norm(shared["ln1"], h, cfg.norm_eps),
        cfg, cache, pos)
    h = h + a
    f = ffn_mod.ffn_forward(shared["ffn"],
                            cm.rms_norm(shared["ln2"], h, cfg.norm_eps), cfg)
    return x + h + f, new_cache


def hybrid_decode_step(params, cfg: ArchConfig, tokens, pos, cache,
                       update_mask=None):
    """One-token decode.  ``update_mask`` ([B] bool, optional) gates the
    recurrent-state write-back per batch row (see ssm.mamba2_decode); the
    positional attention caches need no mask — a non-updated row's k/v write
    lands at a position its owner has not attended past and is overwritten
    by the owner's next real decode (the transient-row invariant that
    token-wise prefill of KV caches relies on)."""
    x = cm.embed(params["embed"], tokens).astype(cfg.jnp_dtype)
    x0 = x
    every = cfg.hybrid_attn_every
    n_pts = n_attn_points(cfg)
    new_mamba = []
    attn_cache = cache["attn"]
    # unrolled decode over layers (cond-in-scan with per-point cache indexing
    # is messier than the win; n_layers is static)
    for i in range(cfg.n_layers):
        cfg_i = cm.layer_quant_cfg(cfg, i)
        layer = jax.tree.map(lambda t: t[i], params["mamba_layers"])
        mcache = jax.tree.map(lambda t: t[i], cache["mamba"])
        h = cm.rms_norm(layer["norm"], x, cfg_i.norm_eps)
        d, nm = ssm_mod.mamba2_decode(layer["block"], h, cfg_i, mcache,
                                      update_mask=update_mask)
        x = x + d
        new_mamba.append(nm)
        if (i + 1) % every == 0 and (i + 1) // every <= n_pts:
            p_idx = (i + 1) // every - 1
            acache = jax.tree.map(lambda t: t[p_idx], attn_cache)
            x, na = _shared_block_decode(params["shared"], x, x0, cfg_i,
                                         acache, pos)
            attn_cache = jax.tree.map(
                lambda full, new: full.at[p_idx].set(new), attn_cache, na)
    x = cm.rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = cm.unembed(params["embed"], x)
    new_cache = {
        "mamba": jax.tree.map(lambda *ts: jnp.stack(ts), *new_mamba),
        "attn": attn_cache,
    }
    return logits, new_cache


def _shared_block_prefill(shared, x, x0, cfg: ArchConfig, *,
                          positions, mask, max_len):
    h = cm.linear(shared["in_proj"],
                  jnp.concatenate([x, x0], axis=-1), cfg.quant)
    a, kv = attn.attn_prefill(shared["attn"],
                              cm.rms_norm(shared["ln1"], h, cfg.norm_eps),
                              cfg, max_len=max_len, positions=positions,
                              mask=mask)
    h = h + a
    f = ffn_mod.ffn_forward(shared["ffn"],
                            cm.rms_norm(shared["ln2"], h, cfg.norm_eps), cfg)
    return x + h + f, kv


def hybrid_prefill(params, cfg: ArchConfig, tokens, *, max_len: int):
    """Bulk prefill: one full-sequence pass -> (logits [B, S, V], cache).

    The cache matches ``hybrid_cache_specs(cfg, B, max_len)`` with the SSM
    state after token S-1 and each attention point's KV rows 0..S-1 —
    semantically identical to S token-wise decode steps, in one pass
    (unrolled over layers like hybrid_decode_step; n_layers is static)."""
    x = cm.embed(params["embed"], tokens).astype(cfg.jnp_dtype)
    x0 = x
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    mask = cm.causal_mask(S, cfg.sliding_window)
    every = cfg.hybrid_attn_every
    n_pts = n_attn_points(cfg)
    mamba_caches, attn_caches = [], []
    for i in range(cfg.n_layers):
        cfg_i = cm.layer_quant_cfg(cfg, i)
        layer = jax.tree.map(lambda t: t[i], params["mamba_layers"])
        h = cm.rms_norm(layer["norm"], x, cfg_i.norm_eps)
        d, mc = ssm_mod.mamba2_prefill(layer["block"], h, cfg_i)
        x = x + d
        mamba_caches.append(mc)
        if (i + 1) % every == 0 and (i + 1) // every <= n_pts:
            x, ac = _shared_block_prefill(params["shared"], x, x0, cfg_i,
                                          positions=positions, mask=mask,
                                          max_len=max_len)
            attn_caches.append(ac)
    x = cm.rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = cm.unembed(params["embed"], x)
    cache = {
        "mamba": jax.tree.map(lambda *ts: jnp.stack(ts), *mamba_caches),
        "attn": jax.tree.map(lambda *ts: jnp.stack(ts), *attn_caches),
    }
    return logits, cache
