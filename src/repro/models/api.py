"""Unified model API: init / forward / loss / decode dispatch by family.

This is the surface the launcher, dry-run, tests, and examples use:
  init_params(cfg, key)            -> params pytree
  forward(cfg, params, batch)      -> logits
  loss_fn(cfg, params, batch)      -> (loss, metrics)
  decode_step(cfg, params, batch)  -> (logits, new_cache)
  cache_specs(cfg, batch, max_len) -> ShapeDtypeStruct pytree
  count_params(cfg)                -> int (for 6·N·D roofline term)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import encdec as encdec_mod
from repro.models import hybrid as hybrid_mod
from repro.models import ssm as ssm_mod
from repro.models import transformer as tf_mod
from repro.models import common as cm


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(cfg: ArchConfig, key):
    if cfg.family in ("dense", "moe", "vlm"):
        return tf_mod.init_lm(key, cfg)
    if cfg.family == "ssm":
        return _init_ssm_lm(key, cfg)
    if cfg.family == "hybrid":
        return hybrid_mod.init_hybrid(key, cfg)
    if cfg.family == "encdec":
        return encdec_mod.init_encdec(key, cfg)
    raise ValueError(cfg.family)


def _init_ssm_lm(key, cfg: ArchConfig):
    ks = jax.random.split(key, 3)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    dt = cfg.jnp_dtype
    return {
        "embed": cm.init_embedding(ks[1], cfg.vocab, cfg.d_model, dt),
        "mamba_layers": jax.vmap(
            lambda k: dict(norm=cm.init_rmsnorm(cfg.d_model, dt),
                           block=ssm_mod.init_mamba2(k, cfg)))(layer_keys),
        "final_norm": cm.init_rmsnorm(cfg.d_model, dt),
    }


def _ssm_hidden(params, cfg: ArchConfig, tokens):
    x = cm.embed(params["embed"], tokens).astype(cfg.jnp_dtype)
    # a per-layer §IV-D schedule forces the unrolled walk (scan needs a
    # layer-uniform body); same contract as transformer._run_stack
    per_layer = cfg.quant.m_schedule is not None

    def make_body(cfg_i):
        def body(carry, layer):
            h = cm.rms_norm(layer["norm"], carry, cfg_i.norm_eps)
            return carry + ssm_mod.mamba2_forward(layer["block"], h, cfg_i), None

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        return body

    if cfg.scan_layers and not per_layer:
        x, _ = jax.lax.scan(make_body(cfg), x, params["mamba_layers"])
    else:
        for i in range(cfg.n_layers):
            x, _ = make_body(cm.layer_quant_cfg(cfg, i))(
                x, jax.tree.map(lambda t: t[i], params["mamba_layers"]))
    return cm.rms_norm(params["final_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def forward(cfg: ArchConfig, params, batch):
    """Full-sequence forward -> (logits [B, S, V], aux dict)."""
    tokens = batch["tokens"]
    if cfg.family in ("dense", "moe"):
        return tf_mod.lm_forward(params, cfg, tokens)
    if cfg.family == "vlm":
        return tf_mod.lm_forward(params, cfg, tokens,
                                 prefix_embeds=batch["patch_embeds"])
    if cfg.family == "ssm":
        hidden = _ssm_hidden(params, cfg, tokens)
        return cm.unembed(params["embed"], hidden), {}
    if cfg.family == "hybrid":
        return hybrid_mod.hybrid_forward(params, cfg, tokens), {}
    if cfg.family == "encdec":
        return encdec_mod.encdec_forward(params, cfg, tokens,
                                         batch["frame_embeds"]), {}
    raise ValueError(cfg.family)


def loss_fn(cfg: ArchConfig, params, batch):
    """Next-token CE (+ MoE load balance + MTP aux).  Returns (loss, metrics)."""
    tokens, labels = batch["tokens"], batch["labels"]
    if cfg.family in ("dense", "moe", "vlm") and cfg.mtp_depth:
        prefix = batch.get("patch_embeds") if cfg.family == "vlm" else None
        hidden, aux = tf_mod.lm_hidden(params, cfg, tokens, prefix_embeds=prefix)
        logits = tf_mod.lm_logits(params, cfg, hidden)
    else:
        logits, aux = forward(cfg, params, batch)
        hidden = None
    if cfg.onehot_loss:
        # vocab-sharded CE: logsumexp + one-hot contraction partition over
        # the vocab shards with a scalar all-reduce — no [B,S,V] gather
        lg = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(lg, axis=-1)
        onehot = jax.nn.one_hot(labels, lg.shape[-1], dtype=lg.dtype)
        lab_logit = jnp.einsum("bsv,bsv->bs", lg, onehot)
        nll = logz - lab_logit
    else:
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    metrics = {"ce_loss": loss}
    if aux.get("load_balance_loss") is not None and cfg.n_experts:
        lb = aux["load_balance_loss"] * 0.01
        loss = loss + lb
        metrics["load_balance_loss"] = lb
    if cfg.mtp_depth and hidden is not None:
        # MTP: logits at position t predict labels[t+1] (== tokens[t+2])
        mtp_lg = tf_mod.mtp_logits(params, cfg, hidden, tokens)
        mtp_labels = labels[:, 1:]
        mlp_logp = jax.nn.log_softmax(mtp_lg.astype(jnp.float32), axis=-1)
        mtp_nll = -jnp.take_along_axis(
            mlp_logp, mtp_labels[..., None], axis=-1)[..., 0]
        mtp_loss = 0.3 * jnp.mean(mtp_nll)
        loss = loss + mtp_loss
        metrics["mtp_loss"] = mtp_loss
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def cache_specs(cfg: ArchConfig, batch: int, max_len: int):
    if cfg.family in ("dense", "moe"):
        return tf_mod.lm_cache_specs(cfg, batch, max_len)
    if cfg.family == "vlm":
        return tf_mod.lm_cache_specs(cfg, batch, max_len + cfg.n_image_tokens)
    if cfg.family == "ssm":
        one = ssm_mod.mamba2_cache_specs(cfg, batch)
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((cfg.n_layers, *s.shape), s.dtype), one)
    if cfg.family == "hybrid":
        return hybrid_mod.hybrid_cache_specs(cfg, batch, max_len)
    if cfg.family == "encdec":
        return encdec_mod.encdec_cache_specs(cfg, batch, max_len)
    raise ValueError(cfg.family)


def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    def mk(s):
        if s.dtype == jnp.int32:
            return -jnp.ones(s.shape, s.dtype)
        return jnp.zeros(s.shape, s.dtype)

    return jax.tree.map(mk, cache_specs(cfg, batch, max_len))


def decode_step(cfg: ArchConfig, params, batch):
    """batch: tokens [B,1], pos [B], cache -> (logits [B,1,V], new cache).

    An optional ``batch["update_mask"]`` ([B] bool) gates *recurrent* state
    write-back per batch row for ssm/hybrid (rows outside a serving group
    keep their state bit-exact; their logits are garbage and ignored).
    Positional KV caches need no mask — see launch/serve.py's transient-row
    invariant — so the other families ignore it.
    """
    tokens, pos, cache = batch["tokens"], batch["pos"], batch["cache"]
    update_mask = batch.get("update_mask")
    if cfg.family in ("dense", "moe", "vlm"):
        return tf_mod.lm_decode_step(params, cfg, tokens, pos, cache)
    if cfg.family == "ssm":
        return _ssm_decode(params, cfg, tokens, cache, update_mask=update_mask)
    if cfg.family == "hybrid":
        return hybrid_mod.hybrid_decode_step(params, cfg, tokens, pos, cache,
                                             update_mask=update_mask)
    if cfg.family == "encdec":
        return encdec_mod.encdec_decode_step(params, cfg, tokens, pos, cache)
    raise ValueError(cfg.family)


def _ssm_decode(params, cfg: ArchConfig, tokens, cache, update_mask=None):
    x = cm.embed(params["embed"], tokens).astype(cfg.jnp_dtype)

    per_layer = cfg.quant.m_schedule is not None

    def body(carry, inp, cfg_i=cfg):
        layer, lc = inp
        h = cm.rms_norm(layer["norm"], carry, cfg_i.norm_eps)
        d, nc = ssm_mod.mamba2_decode(layer["block"], h, cfg_i, lc,
                                      update_mask=update_mask)
        return carry + d, nc

    if cfg.scan_layers and not per_layer:
        x, new_cache = jax.lax.scan(body, x, (params["mamba_layers"], cache))
    else:
        outs = []
        for i in range(cfg.n_layers):
            layer = jax.tree.map(lambda t: t[i], params["mamba_layers"])
            lc = jax.tree.map(lambda t: t[i], cache)
            x, nc = body(x, (layer, lc), cfg_i=cm.layer_quant_cfg(cfg, i))
            outs.append(nc)
        new_cache = jax.tree.map(lambda *ts: jnp.stack(ts), *outs)
    x = cm.rms_norm(params["final_norm"], x, cfg.norm_eps)
    return cm.unembed(params["embed"], x), new_cache


# ---------------------------------------------------------------------------
# bulk prefill (serving admission path)
# ---------------------------------------------------------------------------

# families with a forward() + cache-emit prefill; others (encdec/vlm carry
# side inputs the serving engine does not model yet) fall back to token-wise
BULK_PREFILL_FAMILIES = ("dense", "moe", "ssm", "hybrid")


def prefill(cfg: ArchConfig, params, tokens, *, max_len: int):
    """Bulk prefill: tokens [B, S] -> (logits [B, S, V], decode cache).

    One full-sequence forward that also emits the decode cache (shaped like
    ``cache_specs(cfg, B, max_len)``) with positions 0..S-1 populated —
    semantically equivalent to S ``decode_step`` calls but a single device
    program.  The serving engine runs this at admission with B=1 and
    scatters the result into its slot arrays (:func:`scatter_cache`), so
    admitting a request costs one forward pass instead of O(prompt_len)
    decode steps and never touches concurrent slots' state.
    """
    if cfg.family in ("dense", "moe"):
        return tf_mod.lm_prefill(params, cfg, tokens, max_len=max_len)
    if cfg.family == "ssm":
        return _ssm_prefill(params, cfg, tokens)
    if cfg.family == "hybrid":
        return hybrid_mod.hybrid_prefill(params, cfg, tokens, max_len=max_len)
    raise NotImplementedError(
        f"bulk prefill not implemented for family={cfg.family!r}")


def _ssm_prefill(params, cfg: ArchConfig, tokens):
    x = cm.embed(params["embed"], tokens).astype(cfg.jnp_dtype)

    per_layer = cfg.quant.m_schedule is not None

    def body(carry, layer, cfg_i=cfg):
        h = cm.rms_norm(layer["norm"], carry, cfg_i.norm_eps)
        d, c = ssm_mod.mamba2_prefill(layer["block"], h, cfg_i)
        return carry + d, c

    if cfg.scan_layers and not per_layer:
        x, caches = jax.lax.scan(body, x, params["mamba_layers"])
    else:
        outs = []
        for i in range(cfg.n_layers):
            layer = jax.tree.map(lambda t: t[i], params["mamba_layers"])
            x, c = body(x, layer, cfg_i=cm.layer_quant_cfg(cfg, i))
            outs.append(c)
        caches = jax.tree.map(lambda *ts: jnp.stack(ts), *outs)
    x = cm.rms_norm(params["final_norm"], x, cfg.norm_eps)
    return cm.unembed(params["embed"], x), caches


def scatter_cache(cfg: ArchConfig, cache, slot, part):
    """Write a B=1 prefill cache into batch row ``slot`` of a serving cache.

    ``cache`` leaves are [L, B, ...] (stacked layers / attention points);
    ``part`` is the matching tree from :func:`prefill` with B=1.  Only row
    ``slot`` is written — concurrent slots' rows are untouched by
    construction.
    """
    if cfg.family not in BULK_PREFILL_FAMILIES:
        raise NotImplementedError(cfg.family)
    return jax.tree.map(lambda full, p: full.at[:, slot].set(p[:, 0]),
                        cache, part)


# ---------------------------------------------------------------------------
# deployment binarization (the paper's technique, model-wide)
# ---------------------------------------------------------------------------

# routers/embeddings/SSM dynamics stay fp (DESIGN.md §5); MLA wuk/wuv stay fp
# because the absorbed decode form consumes the explicit factors (tiny mats).
BINARIZE_EXCLUDE = ("router", "embed", "unembed", "conv_", "A_log",
                    "dt_bias", "norm", "wuk", "wuv")


def binarize_model_params(cfg: ArchConfig, params, *, qc=None):
    """Convert every eligible linear's fp weights to packed-binary form.

    Eligible = dict leaves holding a 2D 'w' under a path not excluded in
    BINARIZE_EXCLUDE (DESIGN.md §5: routers/embeddings/SSM dynamics stay fp).
    Works under jit AND eval_shape (dry-run lowering of the binary serve
    path).  Stacked-layer weights ([L, K, N]) are vmapped over the stack.
    """
    from repro.core import binlinear as bl

    qc = qc or cfg.quant

    def convert(path, subtree):
        if not isinstance(subtree, dict):
            return subtree
        pstr = "/".join(str(p) for p in path)
        if any(e in pstr for e in BINARIZE_EXCLUDE):
            return {k: convert(path + (k,), v) for k, v in subtree.items()}
        w = subtree.get("w")
        if w is not None and hasattr(w, "ndim"):
            if w.ndim == 2:
                return bl.binarize_params(subtree, qc)
            if w.ndim == 3:  # stacked layers [L, K, N]
                stacked = jax.vmap(
                    lambda wi: bl.binarize_params({"w": wi}, qc))( w)
                if "b" in subtree:
                    stacked["b"] = subtree["b"]
                return stacked
        return {k: convert(path + (k,), v) for k, v in subtree.items()}

    return convert((), params)


# ---------------------------------------------------------------------------
# parameter counting (roofline MODEL_FLOPS)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _param_specs(cfg: ArchConfig):
    return jax.eval_shape(lambda k: init_params(cfg, k),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def count_params(cfg: ArchConfig, active_only: bool = False) -> int:
    specs = _param_specs(cfg)
    total = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(specs))
    if not active_only or not cfg.n_experts:
        return total
    # active = total - (inactive routed experts' weights)
    n_main = cfg.n_layers - cfg.n_dense_layers
    F = cfg.d_ff_expert or cfg.d_ff
    per_expert = 3 * cfg.d_model * F  # gate, up, down
    inactive = n_main * (cfg.n_experts - cfg.top_k) * per_expert
    return total - inactive
