"""The paper's own evaluation networks (§V-A1).

  * CNN-A: 2 conv (5@7x7x3, 150@4x4x5) + 3 dense (1350->340->490->43), GTSRB.
  * CNN-B: MobileNetV1 (depth multiplier alpha, resolution rho), ImageNet.

Both are built from the quantizable conv/linear so they run dense (fp
baseline), fake-quant (retraining), or packed-binary (deployment) — exactly
the paper's evaluation axes in Table II.  The max-pool layers use the fused
AMU epilogue.  Depth-wise layers of MobileNet are approximated channel-wise
(paper §V-A1: "a single convolution filter").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import binconv
from repro.core import binlinear as bl
from repro.core.binlinear import QuantConfig, DENSE
from repro.models import common as cm

# ---------------------------------------------------------------------------
# CNN-A (paper: 9M MACs, GTSRB 43 classes, input 48x48x3)
# ---------------------------------------------------------------------------

CNN_A_INPUT = (48, 48, 3)
CNN_A_CLASSES = 43


def init_cnn_a(key, dtype=jnp.float32):
    ks = jax.random.split(key, 5)

    def conv(k, kh, kw, cin, cout):
        s = 1.0 / jnp.sqrt(kh * kw * cin)
        return {"w": (jax.random.normal(k, (kh, kw, cin, cout)) * s).astype(dtype),
                "b": jnp.zeros((cout,), dtype)}

    return {
        "conv1": conv(ks[0], 7, 7, 3, 5),
        "conv2": conv(ks[1], 4, 4, 5, 150),
        "fc1": dict(bl.init_linear(ks[2], 1350, 340, dtype), b=jnp.zeros((340,), dtype)),
        "fc2": dict(bl.init_linear(ks[3], 340, 490, dtype), b=jnp.zeros((490,), dtype)),
        "fc3": dict(bl.init_linear(ks[4], 490, 43, dtype), b=jnp.zeros((43,), dtype)),
    }


def cnn_a_forward(params, x: jax.Array, quant: QuantConfig = DENSE) -> jax.Array:
    """x: [B, 48, 48, 3] -> logits [B, 43].

    conv1 7x7 VALID -> 42x42x5, AMU pool 2 -> 21x21x5
    conv2 4x4 VALID -> 18x18x150, AMU pool 6 -> 3x3x150 = 1350

    Each conv+pool stage goes through conv2d_relu_pool, so a binary
    deployment with quant.fuse_conv runs the fused implicit-GEMM kernel —
    conv2's small (3x3 pooled) output map is where the kernel's batch tile
    folds several images per program to fill the MXU rows
    (quant.conv_batch_tile overrides the auto pick).
    """
    y = binconv.conv2d_relu_pool(params["conv1"], x, pool=2, quant=quant)
    y = binconv.conv2d_relu_pool(params["conv2"], y, pool=6, quant=quant)
    y = y.reshape(y.shape[0], -1)
    y = jax.nn.relu(bl.apply_linear(params["fc1"], y, quant))
    y = jax.nn.relu(bl.apply_linear(params["fc2"], y, quant))
    return bl.apply_linear(params["fc3"], y, quant)


def binarize_cnn_a(params, quant: QuantConfig):
    """Offline conversion of every layer to packed-binary deployment form."""
    out = {}
    for name in ("conv1", "conv2"):
        out[name] = binconv.binarize_conv_params(params[name], quant)
    for name in ("fc1", "fc2", "fc3"):
        out[name] = bl.binarize_params(params[name], quant)
    return out


# ---------------------------------------------------------------------------
# MobileNetV1 (CNN-B1: alpha=0.5 rho=0.57 @128; CNN-B2: alpha=1 rho=1 @224)
# ---------------------------------------------------------------------------

MOBILENET_BLOCKS = [
    # (stride, out_channels) after the stem; standard MobileNetV1
    (1, 64), (2, 128), (1, 128), (2, 256), (1, 256), (2, 512),
    (1, 512), (1, 512), (1, 512), (1, 512), (1, 512), (2, 1024), (1, 1024),
]


def init_mobilenet(key, *, width_mult: float = 1.0, n_classes: int = 1000,
                   dtype=jnp.float32):
    def c(ch):
        return max(8, int(ch * width_mult))

    ks = jax.random.split(key, 2 + 2 * len(MOBILENET_BLOCKS))
    params = {"stem": {
        "w": (jax.random.normal(ks[0], (3, 3, 3, c(32))) * 0.1).astype(dtype),
        "b": jnp.zeros((c(32),), dtype)}}
    cin = c(32)
    for i, (stride, cout) in enumerate(MOBILENET_BLOCKS):
        cout = c(cout)
        kd, kp = ks[1 + 2 * i], ks[2 + 2 * i]
        params[f"dw{i}"] = {  # HWIO depth-wise layout: [kh, kw, 1, C]
            "w": (jax.random.normal(kd, (3, 3, 1, cin)) * 0.1).astype(dtype),
            "b": jnp.zeros((cin,), dtype)}
        params[f"pw{i}"] = {
            "w": (jax.random.normal(kp, (1, 1, cin, cout)) * (1.0 / jnp.sqrt(cin))
                  ).astype(dtype),
            "b": jnp.zeros((cout,), dtype)}
        cin = cout
    params["head"] = dict(
        bl.init_linear(ks[-1], cin, n_classes, dtype),
        b=jnp.zeros((n_classes,), dtype))
    return params


def mobilenet_forward(params, x: jax.Array, quant: QuantConfig = DENSE):
    """x: [B, R, R, 3] -> logits.  Point-wise convs carry the binary matmuls;
    depth-wise convs are memory-bound and approximated channel-wise (paper
    §V-A3: D_arch=1 there).  With a packed tree (``binarize_mobilenet``) and
    ``quant.fuse_conv`` + ``use_pallas`` the whole dw->pw stack runs the
    fused binary kernels — zero fp ``lax.conv`` calls end to end.  The
    back-half 14²/7² point-wise layers are where the kernels' (NB, BU)
    batch tiling folds images per program to keep the MXU rows full
    (``quant.conv_batch_tile`` / ``conv_vmem_budget`` override the auto
    pick)."""
    y = binconv.conv2d_relu_pool(params["stem"], x, stride=2, padding="SAME",
                                 pool=1, quant=quant)
    for i, (stride, _) in enumerate(MOBILENET_BLOCKS):
        y = binconv.depthwise_relu(params[f"dw{i}"], y, stride=stride,
                                   quant=quant)
        y = binconv.conv2d_relu_pool(params[f"pw{i}"], y, pool=1, quant=quant)
    y = jnp.mean(y, axis=(1, 2))  # global average pool (offloaded to CPU in paper)
    return bl.apply_linear(params["head"], y, quant)


def binarize_mobilenet(params, quant: QuantConfig):
    """Offline conversion of every MobileNet layer to packed-binary form.

    stem/point-wise convs use the grouped conv packing (B_packed +
    B_tap_packed); depth-wise layers use the channel-wise dw packing
    (paper §V-A3); the classifier head packs like any linear."""
    out = {"stem": binconv.binarize_conv_params(params["stem"], quant)}
    for i in range(len(MOBILENET_BLOCKS)):
        out[f"dw{i}"] = binconv.binarize_dwconv_params(params[f"dw{i}"], quant)
        out[f"pw{i}"] = binconv.binarize_conv_params(params[f"pw{i}"], quant)
    out["head"] = bl.binarize_params(params["head"], quant)
    return out


def cnn_a_macs() -> int:
    """Analytic MAC count — paper says ~9M for CNN-A."""
    m_conv1 = 42 * 42 * 5 * 7 * 7 * 3
    m_conv2 = 18 * 18 * 150 * 4 * 4 * 5
    m_fc = 1350 * 340 + 340 * 490 + 490 * 43
    return m_conv1 + m_conv2 + m_fc
