"""The paper's own evaluation networks (§V-A1).

  * CNN-A: 2 conv (5@7x7x3, 150@4x4x5) + 3 dense (1350->340->490->43), GTSRB.
  * CNN-B: MobileNetV1 (depth multiplier alpha, resolution rho), ImageNet.

Both are built from the quantizable conv/linear so they run dense (fp
baseline), fake-quant (retraining), or packed-binary (deployment) — exactly
the paper's evaluation axes in Table II.  The max-pool layers use the fused
AMU epilogue.  Depth-wise layers of MobileNet are approximated channel-wise
(paper §V-A1: "a single convolution filter").

Layer topology lives in ONE place: the :class:`LayerSpec` lists returned by
``cnn_a_specs()`` / ``mobilenet_specs()``.  Everything that needs the network
structure walks the same list —

  * ``cnn_a_forward`` / ``mobilenet_forward``: thin spec-driven loops over
    ``binconv.conv2d_relu_pool`` / ``binconv.depthwise_relu`` /
    ``bl.apply_linear`` (dense, fake-quant, and per-call binary paths);
  * ``binarize_cnn_a`` / ``binarize_mobilenet``: offline packing per spec;
  * the deploy compiler (``repro.deploy.compile``): turns each spec + its
    packed params into a macro-instruction with a frozen tile plan (paper
    §IV: the compiler emits one instruction per layer and the accelerator
    merely executes the stream).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import binconv
from repro.core import binlinear as bl
from repro.core.binlinear import QuantConfig, DENSE


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """Static description of one layer — the single source of truth the
    forwards, the offline binarizers, and the deploy compiler all walk.

    ``pre`` is the activation epilogue *before* this layer ("flatten" for
    conv->dense, "gap" for MobileNet's global average pool — offloaded to
    the CPU in the paper); ``pool``/``relu`` describe the AMU epilogue after
    it.  Weight shapes are carried by the params tree, not the spec, so one
    spec list serves every width multiplier.
    """

    name: str
    kind: str                 # conv | dwconv | linear
    kh: int = 1
    kw: int = 1
    stride: int = 1
    padding: str = "VALID"    # conv only; dw layers are always SAME
    pool: int = 1             # AMU max-pool window (1 = no pooling)
    pre: str = "none"         # none | flatten | gap
    relu: bool = True


def apply_pre(pre: str, y: jax.Array) -> jax.Array:
    """A spec's pre-layer activation transform (shared with the deploy
    executor so both paths stay literally the same computation)."""
    if pre == "flatten":
        return y.reshape(y.shape[0], -1)
    if pre == "gap":
        return jnp.mean(y, axis=(1, 2))
    if pre != "none":
        raise ValueError(f"unknown pre-op {pre!r}")
    return y


def _forward(specs, params, x: jax.Array, quant: QuantConfig) -> jax.Array:
    """Spec-driven forward: dense / fake-quant / per-call binary paths."""
    y = x
    for s in specs:
        y = apply_pre(s.pre, y)
        if s.kind == "conv":
            y = binconv.conv2d_relu_pool(
                params[s.name], y, stride=s.stride, padding=s.padding,
                pool=s.pool, quant=quant)
        elif s.kind == "dwconv":
            y = binconv.depthwise_relu(params[s.name], y, stride=s.stride,
                                       quant=quant)
        else:
            y = bl.apply_linear(params[s.name], y, quant)
            if s.relu:
                y = jax.nn.relu(y)
    return y


def spec_forward(specs, params, x: jax.Array,
                 quant: QuantConfig = DENSE) -> jax.Array:
    """Public spec-driven forward over an arbitrary LayerSpec list — the
    per-call reference the deploy executor must match bit-exactly for ANY
    topology, which is what the differential fuzz tier
    (tests/test_fuzz_programs.py) exercises via
    ``repro.testing.fuzz.random_network``."""
    return _forward(tuple(specs), params, x, quant)


def spec_binarize(specs, params, quant: QuantConfig) -> dict:
    """Public spec-driven offline packing for an arbitrary LayerSpec list."""
    return _binarize(tuple(specs), params, quant)


def _binarize(specs, params, quant: QuantConfig) -> dict:
    """Spec-driven offline conversion to packed-binary deployment form."""
    out = {}
    for s in specs:
        if s.kind == "conv":
            out[s.name] = binconv.binarize_conv_params(params[s.name], quant)
        elif s.kind == "dwconv":
            out[s.name] = binconv.binarize_dwconv_params(params[s.name], quant)
        else:
            out[s.name] = bl.binarize_params(params[s.name], quant)
    return out


# ---------------------------------------------------------------------------
# CNN-A (paper: 9M MACs, GTSRB 43 classes, input 48x48x3)
# ---------------------------------------------------------------------------

CNN_A_INPUT = (48, 48, 3)
CNN_A_CLASSES = 43

# conv1 7x7 VALID -> 42x42x5, AMU pool 2 -> 21x21x5
# conv2 4x4 VALID -> 18x18x150, AMU pool 6 -> 3x3x150 = 1350 -> 340 -> 490 -> 43
CNN_A_SPECS = (
    LayerSpec("conv1", "conv", kh=7, kw=7, pool=2),
    LayerSpec("conv2", "conv", kh=4, kw=4, pool=6),
    LayerSpec("fc1", "linear", pre="flatten"),
    LayerSpec("fc2", "linear"),
    LayerSpec("fc3", "linear", relu=False),
)


def cnn_a_specs() -> tuple[LayerSpec, ...]:
    return CNN_A_SPECS


def init_cnn_a(key, dtype=jnp.float32):
    ks = jax.random.split(key, 5)

    def conv(k, kh, kw, cin, cout):
        s = 1.0 / jnp.sqrt(kh * kw * cin)
        return {"w": (jax.random.normal(k, (kh, kw, cin, cout)) * s).astype(dtype),
                "b": jnp.zeros((cout,), dtype)}

    return {
        "conv1": conv(ks[0], 7, 7, 3, 5),
        "conv2": conv(ks[1], 4, 4, 5, 150),
        "fc1": dict(bl.init_linear(ks[2], 1350, 340, dtype), b=jnp.zeros((340,), dtype)),
        "fc2": dict(bl.init_linear(ks[3], 340, 490, dtype), b=jnp.zeros((490,), dtype)),
        "fc3": dict(bl.init_linear(ks[4], 490, 43, dtype), b=jnp.zeros((43,), dtype)),
    }


def cnn_a_forward(params, x: jax.Array, quant: QuantConfig = DENSE) -> jax.Array:
    """x: [B, 48, 48, 3] -> logits [B, 43], walking ``CNN_A_SPECS``.

    Each conv+pool stage goes through conv2d_relu_pool, so a binary
    deployment with quant.fuse_conv runs the fused implicit-GEMM kernel —
    conv2's small (3x3 pooled) output map is where the kernel's batch tile
    folds several images per program to fill the MXU rows.  For zero
    per-call planning, compile the packed tree into a ``BinArrayProgram``
    instead (``repro.deploy.compile``) — this wrapper stays for the
    dense/fake-quant training paths and per-call binary compatibility.
    """
    return _forward(CNN_A_SPECS, params, x, quant)


def binarize_cnn_a(params, quant: QuantConfig):
    """Offline conversion of every layer to packed-binary deployment form."""
    return _binarize(CNN_A_SPECS, params, quant)


# ---------------------------------------------------------------------------
# MobileNetV1 (CNN-B1: alpha=0.5 rho=0.57 @128; CNN-B2: alpha=1 rho=1 @224)
# ---------------------------------------------------------------------------

MOBILENET_BLOCKS = [
    # (stride, out_channels) after the stem; standard MobileNetV1
    (1, 64), (2, 128), (1, 128), (2, 256), (1, 256), (2, 512),
    (1, 512), (1, 512), (1, 512), (1, 512), (1, 512), (2, 1024), (1, 1024),
]

MOBILENET_SPECS = (
    (LayerSpec("stem", "conv", kh=3, kw=3, stride=2, padding="SAME"),)
    + tuple(
        spec
        for i, (stride, _) in enumerate(MOBILENET_BLOCKS)
        for spec in (LayerSpec(f"dw{i}", "dwconv", kh=3, kw=3, stride=stride),
                     LayerSpec(f"pw{i}", "conv", kh=1, kw=1))
    )
    + (LayerSpec("head", "linear", pre="gap", relu=False),)
)


def mobilenet_specs() -> tuple[LayerSpec, ...]:
    return MOBILENET_SPECS


def init_mobilenet(key, *, width_mult: float = 1.0, n_classes: int = 1000,
                   dtype=jnp.float32):
    def c(ch):
        return max(8, int(ch * width_mult))

    ks = jax.random.split(key, 2 + 2 * len(MOBILENET_BLOCKS))
    params = {"stem": {
        "w": (jax.random.normal(ks[0], (3, 3, 3, c(32))) * 0.1).astype(dtype),
        "b": jnp.zeros((c(32),), dtype)}}
    cin = c(32)
    for i, (stride, cout) in enumerate(MOBILENET_BLOCKS):
        cout = c(cout)
        kd, kp = ks[1 + 2 * i], ks[2 + 2 * i]
        params[f"dw{i}"] = {  # HWIO depth-wise layout: [kh, kw, 1, C]
            "w": (jax.random.normal(kd, (3, 3, 1, cin)) * 0.1).astype(dtype),
            "b": jnp.zeros((cin,), dtype)}
        params[f"pw{i}"] = {
            "w": (jax.random.normal(kp, (1, 1, cin, cout)) * (1.0 / jnp.sqrt(cin))
                  ).astype(dtype),
            "b": jnp.zeros((cout,), dtype)}
        cin = cout
    params["head"] = dict(
        bl.init_linear(ks[-1], cin, n_classes, dtype),
        b=jnp.zeros((n_classes,), dtype))
    return params


def mobilenet_forward(params, x: jax.Array, quant: QuantConfig = DENSE):
    """x: [B, R, R, 3] -> logits, walking ``MOBILENET_SPECS``.  Point-wise
    convs carry the binary matmuls; depth-wise convs are memory-bound and
    approximated channel-wise (paper §V-A3: D_arch=1 there).  With a packed
    tree (``binarize_mobilenet``) and ``quant.fuse_conv`` + ``use_pallas``
    the whole dw->pw stack runs the fused binary kernels — zero fp
    ``lax.conv`` calls end to end.  The back-half 14²/7² point-wise layers
    are where the kernels' (NB, BU) batch tiling folds images per program to
    keep the MXU rows full (``quant.conv_batch_tile`` / ``conv_vmem_budget``
    override the auto pick; ``repro.deploy.compile`` freezes the pick
    offline)."""
    return _forward(MOBILENET_SPECS, params, x, quant)


def binarize_mobilenet(params, quant: QuantConfig):
    """Offline conversion of every MobileNet layer to packed-binary form.

    stem/point-wise convs use the grouped conv packing (B_packed +
    B_tap_packed); depth-wise layers use the channel-wise dw packing
    (paper §V-A3); the classifier head packs like any linear."""
    return _binarize(MOBILENET_SPECS, params, quant)


def cnn_a_macs() -> int:
    """Analytic MAC count — paper says ~9M for CNN-A."""
    m_conv1 = 42 * 42 * 5 * 7 * 7 * 3
    m_conv2 = 18 * 18 * 150 * 4 * 4 * 5
    m_fc = 1350 * 340 + 340 * 490 + 490 * 43
    return m_conv1 + m_conv2 + m_fc
