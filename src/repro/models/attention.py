"""Attention variants: GQA/MQA (+qk-norm, sliding window, softcap), MLA.

Decode uses an explicit KV cache:
  * full attention: cache [B, S_max, kv, hd] with validity mask slot <= pos.
  * sliding window: rolling cache [B, W, kv, hd] + per-slot global positions
    (sub-quadratic long-context decode; the long_500k path for SWA archs).
  * MLA: latent cache [B, S_max, kv_lora + rope_dim] — the DeepSeek trick;
    decode uses the absorbed form (queries projected into latent space).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common as cm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# GQA / MQA
# ---------------------------------------------------------------------------

def init_attn(key, cfg: ArchConfig):
    hd = cfg.resolved_head_dim
    dt = cfg.jnp_dtype
    ks = jax.random.split(key, 4)
    p = {
        "wq": cm.init_linear(ks[0], cfg.d_model, cfg.n_heads * hd, dt, bias=cfg.qkv_bias),
        "wk": cm.init_linear(ks[1], cfg.d_model, cfg.n_kv_heads * hd, dt, bias=cfg.qkv_bias),
        "wv": cm.init_linear(ks[2], cfg.d_model, cfg.n_kv_heads * hd, dt, bias=cfg.qkv_bias),
        "wo": cm.init_linear(ks[3], cfg.n_heads * hd, cfg.d_model, dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = cm.init_rmsnorm(hd, dt)
        p["k_norm"] = cm.init_rmsnorm(hd, dt)
    return p


def _project_qkv(params, x, cfg: ArchConfig, positions):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = cm.linear(params["wq"], x, cfg.quant).reshape(B, S, cfg.n_heads, hd)
    k = cm.linear(params["wk"], x, cfg.quant).reshape(B, S, cfg.n_kv_heads, hd)
    v = cm.linear(params["wv"], x, cfg.quant).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = cm.rms_norm(params["q_norm"], q, cfg.norm_eps)
        k = cm.rms_norm(params["k_norm"], k, cfg.norm_eps)
    q = cm.apply_rope(q, positions, cfg.rope_theta)
    k = cm.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _gqa_scores(q, k, cfg: ArchConfig):
    """q [B,Sq,H,hd], k [B,Sk,kv,hd] -> logits [B, H, Sq, Sk] (fp32).

    Inputs stay in their storage dtype (bf16); the contraction accumulates
    in fp32 on the MXU (preferred_element_type).  Keeping the operands bf16
    keeps the *cotangents* bf16 too — fp32-cast inputs made every backward
    dX partial-sum all-reduce fp32 and unfusable (2x wire + HBM bytes;
    EXPERIMENTS.md §Perf cell C).
    """
    B, Sq, H, hd = q.shape
    kv = k.shape[2]
    g = H // kv
    qr = q.reshape(B, Sq, kv, g, hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qr, k,
                        preferred_element_type=jnp.float32)
    logits = logits / jnp.sqrt(hd).astype(jnp.float32)
    return logits.reshape(B, H, Sq, -1)


def _gqa_out(weights, v, cfg: ArchConfig):
    """weights [B,H,Sq,Sk] (fp32), v [B,Sk,kv,hd] -> [B,Sq,H*hd]."""
    B, H, Sq, Sk = weights.shape
    kv = v.shape[2]
    g = H // kv
    w = weights.reshape(B, kv, g, Sq, Sk).astype(v.dtype)
    o = jnp.einsum("bkgqs,bskh->bqkgh", w, v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Sq, H * v.shape[-1])


def attn_forward(params, x, cfg: ArchConfig, *, positions=None, mask=None):
    """Full-sequence (train/prefill) attention.  x: [B, S, D].

    cfg.attn_chunk: query-chunked (flash-style) evaluation — the S x S score
    tensor is never materialized; peak score memory drops by S/chunk.
    Chunks are an unrolled python loop (NOT lax.scan) so the dry-run cost
    analysis counts every chunk (see launch/dryrun._depth_pair).
    """
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(params, x, cfg, positions)
    q = cm.shard(q, "batch", None, "heads", None)
    k = cm.shard(k, "batch", None, "kv_heads", None)
    v = cm.shard(v, "batch", None, "kv_heads", None)
    if mask is None:
        mask = cm.causal_mask(S, cfg.sliding_window)
    c = cfg.attn_chunk
    if c and S > c and S % c == 0:
        outs = []
        for i in range(S // c):
            qi = q[:, i * c: (i + 1) * c]
            mi = mask[i * c: (i + 1) * c]
            # causality: keys beyond the chunk's last query never attend
            k_hi = (i + 1) * c
            logits = _gqa_scores(qi, k[:, :k_hi], cfg)
            logits = jnp.where(mi[None, None, :, :k_hi], logits, NEG_INF)
            w = jax.nn.softmax(logits, axis=-1)
            outs.append(_gqa_out(w, v[:, :k_hi], cfg))
        o = jnp.concatenate(outs, axis=1).astype(x.dtype)
    else:
        logits = _gqa_scores(q, k, cfg)
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        weights = jax.nn.softmax(logits, axis=-1)
        o = _gqa_out(weights, v, cfg).astype(x.dtype)
    return cm.linear(params["wo"], o, cfg.quant)


# --- decode ---------------------------------------------------------------

def _cache_window(cfg: ArchConfig, max_len: int) -> int:
    """Cache rows per slot: the sliding window caps the rolling cache."""
    return min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len


def attn_cache_specs(cfg: ArchConfig, batch: int, max_len: int):
    hd = cfg.resolved_head_dim
    W = _cache_window(cfg, max_len)
    dt = cfg.jnp_dtype
    spec = {
        "k": jax.ShapeDtypeStruct((batch, W, cfg.n_kv_heads, hd), dt),
        "v": jax.ShapeDtypeStruct((batch, W, cfg.n_kv_heads, hd), dt),
    }
    if cfg.sliding_window:
        spec["slot_pos"] = jax.ShapeDtypeStruct((batch, W), jnp.int32)
    return spec


def init_attn_cache(cfg: ArchConfig, batch: int, max_len: int):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype)
        if s.dtype != jnp.int32 else -jnp.ones(s.shape, jnp.int32),
        attn_cache_specs(cfg, batch, max_len),
    )


def attn_prefill(params, x, cfg: ArchConfig, *, max_len: int,
                 positions=None, mask=None):
    """Full-sequence attention that also emits the decode-cache state.

    x: [B, S, D] -> (y [B, S, D], cache leaf shaped like
    ``attn_cache_specs(cfg, B, max_len)``) with k/v for positions 0..S-1
    already written — the bulk-prefill path: one pass instead of S decode
    steps.  Requires S <= max_len (the server admits under this bound).
    For sliding-window caches only the last ``W`` tokens are written (older
    ones could never be attended to again), at their rolling slots
    ``pos % W`` with ``slot_pos`` bookkeeping matching token-wise decode.

    Scores are materialized whole ([B, H, S, S]) rather than query-chunked
    like attn_forward's ``attn_chunk`` path: admission runs at B=1 with
    S < max_len, so the score tensor is bounded by the server's max_len².
    """
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(params, x, cfg, positions)
    if mask is None:
        mask = cm.causal_mask(S, cfg.sliding_window)
    logits = _gqa_scores(q, k, cfg)
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    weights = jax.nn.softmax(logits, axis=-1)
    o = _gqa_out(weights, v, cfg).astype(x.dtype)
    y = cm.linear(params["wo"], o, cfg.quant)

    W = _cache_window(cfg, max_len)
    dt = cfg.jnp_dtype
    ck = jnp.zeros((B, W, cfg.n_kv_heads, k.shape[-1]), dt)
    cv = jnp.zeros_like(ck)
    if cfg.sliding_window:
        n = min(S, W)
        ts = jnp.arange(S - n, S)
        slots = ts % W
        cache = {
            "k": ck.at[:, slots].set(k[:, S - n:].astype(dt)),
            "v": cv.at[:, slots].set(v[:, S - n:].astype(dt)),
            "slot_pos": (-jnp.ones((B, W), jnp.int32)).at[:, slots].set(
                jnp.broadcast_to(ts.astype(jnp.int32), (B, n))),
        }
    else:
        cache = {"k": ck.at[:, :S].set(k.astype(dt)),
                 "v": cv.at[:, :S].set(v.astype(dt))}
    return y, cache


def attn_decode(params, x, cfg: ArchConfig, cache, pos):
    """One-token decode.  x: [B, 1, D], pos: [B] int32 -> (y, new_cache)."""
    B = x.shape[0]
    q, k, v = _project_qkv(params, x, cfg, pos[:, None])
    W = cache["k"].shape[1]
    slot = (pos % W) if cfg.sliding_window else pos
    bidx = jnp.arange(B)
    new_k = cache["k"].at[bidx, slot].set(k[:, 0])
    new_v = cache["v"].at[bidx, slot].set(v[:, 0])
    new_cache = dict(cache, k=new_k, v=new_v)
    if cfg.sliding_window:
        slot_pos = cache["slot_pos"].at[bidx, slot].set(pos)
        new_cache["slot_pos"] = slot_pos
        valid = (slot_pos >= 0) & (slot_pos <= pos[:, None]) & (
            pos[:, None] - slot_pos < cfg.sliding_window
        )
    else:
        valid = jnp.arange(W)[None, :] <= pos[:, None]
    logits = _gqa_scores(q, new_k, cfg)                       # [B, H, 1, W]
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    weights = jax.nn.softmax(logits, axis=-1)
    o = _gqa_out(weights, new_v, cfg).astype(x.dtype)
    return cm.linear(params["wo"], o, cfg.quant), new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ArchConfig):
    dt = cfg.jnp_dtype
    H = cfg.n_heads
    qk = cfg.qk_nope_dim
    r = cfg.qk_rope_dim
    vd = cfg.v_head_dim
    ks = jax.random.split(key, 8)
    p = {}
    if cfg.q_lora_rank:
        p["wdq"] = cm.init_linear(ks[0], cfg.d_model, cfg.q_lora_rank, dt)
        p["q_norm"] = cm.init_rmsnorm(cfg.q_lora_rank, dt)
        p["wuq"] = cm.init_linear(ks[1], cfg.q_lora_rank, H * (qk + r), dt)
    else:
        p["wq"] = cm.init_linear(ks[1], cfg.d_model, H * (qk + r), dt)
    p["wdkv"] = cm.init_linear(ks[2], cfg.d_model, cfg.kv_lora_rank + r, dt)
    p["kv_norm"] = cm.init_rmsnorm(cfg.kv_lora_rank, dt)
    p["wuk"] = cm.init_linear(ks[3], cfg.kv_lora_rank, H * qk, dt)
    p["wuv"] = cm.init_linear(ks[4], cfg.kv_lora_rank, H * vd, dt)
    p["wo"] = cm.init_linear(ks[5], H * vd, cfg.d_model, dt)
    return p


def _mla_queries(params, x, cfg: ArchConfig, positions):
    B, S, _ = x.shape
    H, qk, r = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    if cfg.q_lora_rank:
        cq = cm.rms_norm(params["q_norm"],
                         cm.linear(params["wdq"], x, cfg.quant), cfg.norm_eps)
        q = cm.linear(params["wuq"], cq, cfg.quant)
    else:
        q = cm.linear(params["wq"], x, cfg.quant)
    q = q.reshape(B, S, H, qk + r)
    q_nope, q_rope = q[..., :qk], q[..., qk:]
    q_rope = cm.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latents(params, x, cfg: ArchConfig, positions):
    """c_kv [B,S,rank] (normed), k_rope [B,S,r] (shared across heads)."""
    r = cfg.qk_rope_dim
    dkv = cm.linear(params["wdkv"], x, cfg.quant)
    c_kv = cm.rms_norm(params["kv_norm"], dkv[..., : cfg.kv_lora_rank], cfg.norm_eps)
    k_rope = cm.apply_rope(dkv[..., cfg.kv_lora_rank:][:, :, None, :],
                           positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_forward(params, x, cfg: ArchConfig, *, positions=None, mask=None):
    """Train/prefill MLA: materialize per-head k/v from the latent."""
    B, S, _ = x.shape
    H, qk, r, vd = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q_nope, q_rope = _mla_queries(params, x, cfg, positions)
    c_kv, k_rope = _mla_latents(params, x, cfg, positions)
    k_nope = cm.linear(params["wuk"], c_kv, cfg.quant).reshape(B, S, H, qk)
    v = cm.linear(params["wuv"], c_kv, cfg.quant).reshape(B, S, H, vd)
    scale = 1.0 / jnp.sqrt(qk + r).astype(jnp.float32)
    # bf16 operands, fp32 accumulation (see _gqa_scores)
    logits = (
        jnp.einsum("bqhd,bshd->bhqs", q_nope, k_nope,
                   preferred_element_type=jnp.float32)
        + jnp.einsum("bqhd,bsd->bhqs", q_rope, k_rope,
                     preferred_element_type=jnp.float32)
    ) * scale
    if mask is None:
        mask = cm.causal_mask(S)
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    o = jnp.einsum("bhqs,bshd->bqhd", w, v,
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, S, H * vd).astype(x.dtype)
    return cm.linear(params["wo"], o, cfg.quant)


def mla_cache_specs(cfg: ArchConfig, batch: int, max_len: int):
    dt = cfg.jnp_dtype
    return {
        "c_kv": jax.ShapeDtypeStruct((batch, max_len, cfg.kv_lora_rank), dt),
        "k_rope": jax.ShapeDtypeStruct((batch, max_len, cfg.qk_rope_dim), dt),
    }


def init_mla_cache(cfg: ArchConfig, batch: int, max_len: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        mla_cache_specs(cfg, batch, max_len))


def mla_prefill(params, x, cfg: ArchConfig, *, max_len: int,
                positions=None, mask=None):
    """Full-sequence MLA that also emits the latent decode cache.

    Mirrors :func:`mla_forward` (materialized per-head k/v); the cache is
    the absorbed-form decode state — per-position latents ``c_kv``/``k_rope``
    for 0..S-1, zero-padded to ``max_len``.  Requires S <= max_len.
    """
    B, S, _ = x.shape
    H, qk, r, vd = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q_nope, q_rope = _mla_queries(params, x, cfg, positions)
    c_kv, k_rope = _mla_latents(params, x, cfg, positions)
    k_nope = cm.linear(params["wuk"], c_kv, cfg.quant).reshape(B, S, H, qk)
    v = cm.linear(params["wuv"], c_kv, cfg.quant).reshape(B, S, H, vd)
    scale = 1.0 / jnp.sqrt(qk + r).astype(jnp.float32)
    logits = (
        jnp.einsum("bqhd,bshd->bhqs", q_nope, k_nope,
                   preferred_element_type=jnp.float32)
        + jnp.einsum("bqhd,bsd->bhqs", q_rope, k_rope,
                     preferred_element_type=jnp.float32)
    ) * scale
    if mask is None:
        mask = cm.causal_mask(S)
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    o = jnp.einsum("bhqs,bshd->bqhd", w, v,
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, S, H * vd).astype(x.dtype)
    y = cm.linear(params["wo"], o, cfg.quant)
    dt = cfg.jnp_dtype
    cache = {
        "c_kv": jnp.zeros((B, max_len, cfg.kv_lora_rank), dt)
                   .at[:, :S].set(c_kv.astype(dt)),
        "k_rope": jnp.zeros((B, max_len, cfg.qk_rope_dim), dt)
                     .at[:, :S].set(k_rope.astype(dt)),
    }
    return y, cache


def mla_decode(params, x, cfg: ArchConfig, cache, pos):
    """Absorbed-form decode: scores/outputs computed in latent space, so the
    per-token cache is kv_lora_rank + rope_dim floats — the MLA memory win."""
    B = x.shape[0]
    H, qk, r, vd = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    rank = cfg.kv_lora_rank
    q_nope, q_rope = _mla_queries(params, x, cfg, pos[:, None])   # [B,1,H,*]
    c_new, k_rope_new = _mla_latents(params, x, cfg, pos[:, None])
    bidx = jnp.arange(B)
    c_kv = cache["c_kv"].at[bidx, pos].set(c_new[:, 0])
    k_rope = cache["k_rope"].at[bidx, pos].set(k_rope_new[:, 0])
    # absorb W_uk into the query:  q_lat[b,h,rank] = q_nope · W_uk[rank, h, qk]
    wuk = params["wuk"]["w"].astype(jnp.float32).reshape(rank, H, qk)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32), wuk)
    scale = 1.0 / jnp.sqrt(qk + r).astype(jnp.float32)
    logits = (
        jnp.einsum("bhr,bsr->bhs", q_lat, c_kv.astype(jnp.float32))
        + jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32),
                     k_rope.astype(jnp.float32))
    ) * scale
    S = c_kv.shape[1]
    valid = jnp.arange(S)[None, :] <= pos[:, None]
    logits = jnp.where(valid[:, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", w, c_kv.astype(jnp.float32))
    wuv = params["wuv"]["w"].astype(jnp.float32).reshape(rank, H, vd)
    o = jnp.einsum("bhr,rhd->bhd", o_lat, wuv).reshape(B, 1, H * vd)
    y = cm.linear(params["wo"], o.astype(x.dtype), cfg.quant)
    return y, {"c_kv": c_kv, "k_rope": k_rope}
