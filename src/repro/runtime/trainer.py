"""Fault-tolerant training loop.

Production behaviors (exercised at laptop scale in tests/test_runtime.py):
  * checkpoint every N steps (atomic; data-pipeline state included);
  * auto-resume: on startup, restore the latest complete checkpoint and
    fast-forward the data pipeline — a killed job restarted with the same
    command continues bit-exactly;
  * straggler watchdog: per-step wall-clock deadline (EWMA * factor);
    overruns are logged with step indices (on real fleets this feeds the
    scheduler's hot-swap; here it is observable behavior under test);
  * elastic re-mesh: restore() maps checkpoints onto a different mesh /
    device count via reshard-on-restore (checkpoint/manager.py);
  * NaN/inf guard: skip the update and record it (common large-fleet guard).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 25
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    straggler_factor: float = 3.0   # deadline = EWMA * factor
    ewma_decay: float = 0.9
    log_every: int = 10


@dataclasses.dataclass
class TrainerReport:
    steps_run: int = 0
    resumed_from: int | None = None
    straggler_events: list = dataclasses.field(default_factory=list)
    nan_skips: int = 0
    losses: list = dataclasses.field(default_factory=list)


class Trainer:
    def __init__(self, step_fn: Callable, state: Any, data,
                 tcfg: TrainerConfig, *, state_shardings=None):
        self.step_fn = step_fn
        self.state = state
        self.data = data
        self.tcfg = tcfg
        self.ckpt = CheckpointManager(
            tcfg.checkpoint_dir, keep=tcfg.keep_checkpoints)
        self.report = TrainerReport()
        self.state_shardings = state_shardings

    # ------------------------------------------------------------ resume --
    def maybe_resume(self):
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        self.state, extra = self.ckpt.restore(
            latest, self.state, shardings=self.state_shardings)
        if "data_state" in extra and hasattr(self.data, "load_state_dict"):
            self.data.load_state_dict(extra["data_state"])
        self.report.resumed_from = latest
        log.info("resumed from checkpoint step %d", latest)
        return True

    # -------------------------------------------------------------- loop --
    def run(self):
        t = self.tcfg
        ewma = None
        start_step = int(jax.device_get(self.state["step"]))
        first_iter = True  # step 0 includes jit compile — excluded from EWMA
        for step in range(start_step, t.total_steps):
            batch = self.data.next_batch()
            t0 = time.monotonic()
            new_state, metrics = self.step_fn(self.state, batch)
            loss = float(jax.device_get(metrics["loss"]))
            dt = time.monotonic() - t0
            # --- NaN guard: skip the update, keep old state ---
            if not np.isfinite(loss):
                self.report.nan_skips += 1
                log.warning("step %d: non-finite loss %s — update skipped",
                            step, loss)
            else:
                self.state = new_state
                self.report.losses.append(loss)
            # --- straggler watchdog (EWMA excludes the compile step) ---
            if ewma is not None and dt > t.straggler_factor * ewma:
                self.report.straggler_events.append(
                    {"step": step, "seconds": dt, "deadline": t.straggler_factor * ewma})
                log.warning("step %d straggled: %.3fs (deadline %.3fs)",
                            step, dt, t.straggler_factor * ewma)
            if first_iter:
                first_iter = False
            else:
                ewma = dt if ewma is None else (
                    t.ewma_decay * ewma + (1 - t.ewma_decay) * dt)
            self.report.steps_run += 1
            if step % t.log_every == 0:
                log.info("step %d loss %.4f (%.0f ms)", step, loss, dt * 1e3)
            # --- checkpoint ---
            if (step + 1) % t.checkpoint_every == 0 or step + 1 == t.total_steps:
                extra = {}
                if hasattr(self.data, "state_dict"):
                    extra["data_state"] = self.data.state_dict()
                self.ckpt.save(step + 1, self.state, extra=extra)
        return self.report
