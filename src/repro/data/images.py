"""Synthetic GTSRB-like image pipeline for the paper's CNN-A experiments.

43 classes of procedurally generated "traffic signs": each class is a fixed
random template (shape blob + color) plus per-sample noise, translation and
brightness jitter.  Linearly separable enough to train CNN-A to high
accuracy in minutes on CPU, and non-trivial enough that binarization hurts
before retraining — which is what Table II measures.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


class SyntheticGTSRB:
    def __init__(self, *, n_classes: int = 43, size: int = 48, seed: int = 0):
        self.n_classes = n_classes
        self.size = size
        rng = np.random.default_rng(seed)
        # class templates: smooth random fields, distinct per class
        self.templates = rng.normal(0, 1, (n_classes, size, size, 3)).astype(np.float32)
        for c in range(n_classes):
            for ch in range(3):
                t = self.templates[c, :, :, ch]
                # cheap smoothing: separable box blur x3
                for _ in range(3):
                    t = (np.roll(t, 1, 0) + t + np.roll(t, -1, 0)) / 3
                    t = (np.roll(t, 1, 1) + t + np.roll(t, -1, 1)) / 3
                self.templates[c, :, :, ch] = t
        self.templates /= np.abs(self.templates).max(axis=(1, 2, 3), keepdims=True)

    def batch(self, batch_size: int, *, rng: np.random.Generator):
        labels = rng.integers(0, self.n_classes, batch_size)
        imgs = self.templates[labels].copy()
        # jitter: shift, brightness, noise (tuned so a trained fp32 CNN-A
        # sits around ~90% — binarization visibly hurts, retraining recovers)
        for i in range(batch_size):
            dx, dy = rng.integers(-5, 6, 2)
            imgs[i] = np.roll(imgs[i], (dx, dy), axis=(0, 1))
        imgs *= rng.uniform(0.6, 1.4, (batch_size, 1, 1, 1)).astype(np.float32)
        imgs += rng.normal(0, 0.45, imgs.shape).astype(np.float32)
        return jnp.asarray(imgs), jnp.asarray(labels.astype(np.int32))

    def eval_set(self, n: int, seed: int = 1234):
        rng = np.random.default_rng(seed)
        return self.batch(n, rng=rng)
