"""Deterministic synthetic token pipeline — host-sharded, checkpointable.

Produces a structured synthetic language (Zipfian unigrams + periodic
copy/induction patterns) so models have learnable signal for end-to-end
training examples.  State is a (step, seed) pair stored in checkpoints, so a
restarted job resumes mid-epoch with identical batches (fault tolerance).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class TokenPipelineState:
    seed: int
    step: int

    def to_dict(self):
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_dict(cls, d):
        return cls(seed=int(d["seed"]), step=int(d["step"]))


class SyntheticTokens:
    """Iterator of {tokens, labels} batches with next-token labels."""

    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 *, seed: int = 0, host_id: int = 0, n_hosts: int = 1):
        assert global_batch % n_hosts == 0
        self.vocab = vocab
        self.seq_len = seq_len
        self.local_batch = global_batch // n_hosts
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.state = TokenPipelineState(seed=seed, step=0)
        # Zipfian unigram distribution (heavy head like natural text)
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        p = 1.0 / ranks
        self._probs = (p / p.sum()).astype(np.float64)

    def _rng(self):
        # distinct stream per (seed, step, host) — deterministic resume
        return np.random.default_rng(
            (self.state.seed * 1_000_003 + self.state.step) * 65_537
            + self.host_id)

    def next_batch(self):
        rng = self._rng()
        B, S = self.local_batch, self.seq_len
        toks = rng.choice(self.vocab, size=(B, S + 1), p=self._probs)
        # induction patterns: random repeated bigrams (copy task signal)
        n_pat = max(1, S // 64)
        for b in range(B):
            for _ in range(n_pat):
                i = rng.integers(0, S - 3)
                j = rng.integers(i + 2, S - 1)
                toks[b, j: j + 2] = toks[b, i: i + 2]
        toks = toks.astype(np.int32)
        self.state.step += 1
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:])}

    # --- checkpoint integration ---
    def state_dict(self):
        return self.state.to_dict()

    def load_state_dict(self, d):
        self.state = TokenPipelineState.from_dict(d)
