"""Functional optimizers (no external deps).

AdamW — the paper's CNN-A retraining optimizer (alpha=1e-4, b1=.9, b2=.999);
SGD+momentum — the paper's CNN-B recipe (momentum .9, exp-decayed lr from
5e-4; Adam was "susceptible to exploding gradients" there, §V-B1).

Optimizer state is kept in fp32 regardless of param dtype (mixed-precision
training); state is sharded like the params (sharding/rules.py).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params, step) -> (new_params, new_state)


def _f32(tree):
    return jax.tree.map(lambda p: p.astype(jnp.float32), tree)


def clip_by_global_norm(grads, max_norm: float):
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gnorm


def adamw(lr: float | Callable, *, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.0,
          grad_clip: float | None = 1.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params):
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"mu": zeros, "nu": jax.tree.map(jnp.copy, zeros)}

    def update(grads, state, params, step):
        if grad_clip is not None:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        g32 = _f32(grads)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["mu"], g32)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["nu"], g32)
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t
        lr_t = lr_fn(step)

        def upd(p, m, v):
            step_ = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                step_ = step_ + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * step_).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, {"mu": mu, "nu": nu}

    return Optimizer(init=init, update=update)


def sgd(lr: float | Callable, *, momentum: float = 0.9,
        grad_clip: float | None = 1.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params):
        return {"vel": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params, step):
        if grad_clip is not None:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        g32 = _f32(grads)
        vel = jax.tree.map(lambda v, g: momentum * v + g, state["vel"], g32)
        lr_t = lr_fn(step)
        new_params = jax.tree.map(
            lambda p, v: (p.astype(jnp.float32) - lr_t * v).astype(p.dtype),
            params, vel)
        return new_params, {"vel": vel}

    return Optimizer(init=init, update=update)
