from repro.optim.optimizers import adamw, sgd, Optimizer, clip_by_global_norm
from repro.optim.schedule import cosine_schedule, exponential_decay, warmup_cosine
