from repro.optim.optimizers import adamw, sgd, Optimizer, clip_by_global_norm
from repro.optim.schedule import cosine_schedule, exponential_decay, warmup_cosine

__all__ = [
    "Optimizer", "adamw", "clip_by_global_norm", "cosine_schedule",
    "exponential_decay", "sgd", "warmup_cosine",
]
