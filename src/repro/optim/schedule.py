"""LR schedules (jit-compatible: step -> lr)."""
from __future__ import annotations

import jax.numpy as jnp


def exponential_decay(init_lr: float, decay_rate: float, decay_steps: int):
    """Paper §V-B1: lr initialized at 5e-4, decayed exponentially."""
    def fn(step):
        return init_lr * decay_rate ** (step.astype(jnp.float32) / decay_steps)
    return fn


def cosine_schedule(init_lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.minimum(step.astype(jnp.float32) / total_steps, 1.0)
        return init_lr * (final_frac + (1 - final_frac) * 0.5 *
                          (1 + jnp.cos(jnp.pi * t)))
    return fn


def warmup_cosine(init_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    cos = cosine_schedule(init_lr, max(total_steps - warmup_steps, 1), final_frac)
    def fn(step):
        s = step.astype(jnp.float32)
        warm = init_lr * s / jnp.maximum(warmup_steps, 1)
        return jnp.where(s < warmup_steps, warm, cos(step - warmup_steps))
    return fn
