"""execute_sharded(): run one BinArrayProgram forward across a device mesh.

The multi-device twin of ``deploy.execute``: a chain of jitted ``shard_map``
macro-instructions, bit-exact against the single-device path for every
§IV-D schedule because nothing numeric changes —

  * data parallelism splits the batch; the kernels clamp and stay bit-exact
    across batch tilings (the compile-once contract deploy relies on), so a
    device computing 1/n of the batch produces the same rows;
  * bd-sharded convs compute disjoint output-channel slices with no fp
    reduction; ``all_gather(tiled=True)`` concatenates them in channel
    order, bitwise equal to the unsharded conv;
  * replicated layers run ``deploy.executor._apply`` verbatim.

Execution granularity is one compiled module per (instruction, level,
shard) — the paper's accelerator likewise executes one macro-instruction
at a time (§IV ISA), and on the partitioned module this is what makes the
bit-exactness *provable*: fusing the whole chain into one ``shard_map``
lets XLA form fp contractions across layer boundaries whose choice depends
on the surrounding module, producing deterministic 1-ulp drift vs the
single-device executable (observed on CPU at small per-device batches even
with ``optimization_barrier`` pinning every boundary).  Per-instruction
modules compile each layer in the same isolation the golden path sees, so
every (nb, bu, bd) tiling stays bit-identical.  The per-layer functions are
cached on (mesh, shard, level, geometry), so layers sharing a schedule
share one executable and repeated forwards never retrace.

Ragged global batches are padded with zero images and sliced back exactly
like the kernels' NB path.  Scheduling stays frozen: every kernel call
passes a complete frozen plan (the instruction's own, or the LayerShard's
device-local one), so the sharded trace contains zero plan auto-picks —
``kernels.binary_conv.plan_pick_count`` proves it, same as deploy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.deploy import executor as dexec
from repro.deploy.program import BinArrayProgram, ConvInstr
from repro.distributed.plan import LayerShard, MeshPlan
from repro.kernels import ops as kops
from repro.models.cnn import apply_pre

# Trace-entry accounting, mirroring deploy.executor: bumps once per layer
# module actually (re)traced, so trace_lint/soak can prove repeated
# identical sharded traffic holds a bounded number of compiled variants.
_trace_entries = [0]


def trace_entry_count() -> int:
    """How many layer-module traces have run (process-wide)."""
    return _trace_entries[0]


def reset_trace_entry_count() -> None:
    _trace_entries[0] = 0


def cache_stats() -> dict:
    """Compiled-variant counts for the soak/retrace harness: one
    ``sharded_fns`` entry per distinct (mesh, shard, level, interpret,
    instruction-geometry) layer module."""
    return {"trace_entries": _trace_entries[0],
            "sharded_fns": _layer_fn.cache_info().currsize}


def cache_gauges() -> dict:
    """``name -> callable`` gauges for ``repro.testing.soak``."""
    return {"dist_trace_entries": lambda: float(_trace_entries[0]),
            "dist_sharded_fns": lambda: float(
                _layer_fn.cache_info().currsize)}


def _instr_specs(shard: LayerShard, axis_model: str, itd):
    """The instruction-shaped PartitionSpec pytree: three leaves (packed
    taps, alpha, bias — the registered array-field order), sharded along
    the model axis on their channel dim for bd shards, replicated
    otherwise."""
    if shard.kind == "bd":
        leaves = [P(None, None, None, axis_model),   # [M, T, C8, D]
                  P(None, None, axis_model),         # [M, G, D]
                  P(axis_model)]                     # [D]
    else:
        leaves = [P(), P(), P()]
    return jax.tree_util.tree_unflatten(itd, leaves)


@functools.lru_cache(maxsize=None)
def _layer_fn(mesh, axis_data: str, axis_model: str, shard: LayerShard,
              m: int, interpret: bool, itd):
    """Build + jit one macro-instruction ``shard_map`` module.

    ``itd`` is the instruction's treedef — it carries every static field
    (kind, geometry, frozen plan), so the cache key pins the exact
    executable while layers with identical schedules share one entry.
    The cache is bounded by (distinct layer geometries × levels served),
    the same bound as deploy's jit cache; ``cache_stats`` exposes the size
    for the soak harness.
    """

    def body(instr, y: jax.Array) -> jax.Array:
        _trace_entries[0] += 1      # runs at trace time only, not per call
        if shard.kind == "bd":
            assert isinstance(instr, ConvInstr), instr
            y = apply_pre(instr.pre, y)
            y_loc = kops.binary_conv2d(
                y, instr.B_tap_packed, instr.alpha, instr.bias,
                kh=instr.kh, kw=instr.kw, stride=instr.stride,
                padding=instr.padding, pool=instr.pool, m_active=m,
                relu=instr.relu, bd=shard.plan.bd, bu=shard.plan.bu,
                nb=shard.plan.nb, interpret=interpret)
            # disjoint channel slices -> tiled concat, no fp reduction:
            # bitwise equal to the unsharded conv output
            return jax.lax.all_gather(y_loc, axis_model,
                                      axis=y_loc.ndim - 1, tiled=True)
        return dexec._apply(instr, y, m, interpret)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(_instr_specs(shard, axis_model, itd), P(axis_data)),
        out_specs=P(axis_data),
        # replicated layers compute identically on every model column
        # (deterministic kernels, identical inputs/weights), so the output
        # is replicated along the model axis by construction
        check_rep=False)
    return jax.jit(fn)


def execute_sharded(program: BinArrayProgram, plan: MeshPlan, x: jax.Array,
                    m_active=None, *, interpret: bool | None = None,
                    mesh: jax.sharding.Mesh | None = None) -> jax.Array:
    """Run the program on a batch across the mesh.  x: [B, H, W, C] -> logits.

    ``m_active`` takes every §IV-D schedule form ``deploy.execute`` does
    (None | int | per-instruction sequence); ``interpret`` overrides the
    program's compile-time Pallas default; ``mesh`` reuses an existing mesh
    instead of building ``plan.build_mesh()`` per call (equal meshes hash
    equal, so repeated calls with equal plans still share the compiled
    layer modules).  A global batch not divisible by ``plan.n_data`` is
    padded with zero images and sliced back — exactly the kernels' ragged-NB
    treatment, bit-exact for the real rows.
    """
    dexec._check_input(program, x)
    if len(plan.shards) != len(program.instrs):
        raise ValueError(
            f"MeshPlan carries {len(plan.shards)} shard(s) for "
            f"{len(program.instrs)} instruction(s) — re-plan with "
            f"plan_mesh(program, ...)")
    sched = program.resolve_schedule(m_active)
    itp = program.interpret if interpret is None else interpret
    if mesh is None:
        mesh = plan.build_mesh()
    B = x.shape[0]
    pad = (-B) % plan.n_data
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((pad,) + tuple(x.shape[1:]), x.dtype)])
    y = x
    for instr, m, s in zip(program.instrs, sched, plan.shards):
        itd = jax.tree_util.tree_structure(instr)
        fn = _layer_fn(mesh, plan.axis_data, plan.axis_model, s, m, itp, itd)
        y = fn(instr, y)
    return y[:B] if pad else y
