"""Per-device accounting of a program under a MeshPlan.

Grows the compiler's :class:`~repro.deploy.program.LayerStats` with the
mesh dimension: how many bytes of packed weights, VMEM working set, and
gather traffic each *device* carries, split into replicated vs sharded.
``pick_tile`` co-plans with the mesh through ``plan_mesh`` (the device-local
plans are picked at the per-device batch); these numbers are what
``benchmarks/run.py --json``'s ``distributed`` section reports and
``tools/bench_diff.py`` gates — replication overhead creeping up or a
per-device working set growing past a baseline is a regression.

Everything reads shapes and static aux only (abstract-program safe).
"""
from __future__ import annotations

from repro.deploy.program import BinArrayProgram
from repro.distributed.plan import MeshPlan
from repro.kernels import binary_conv as bck


def shard_layer_stats(program: BinArrayProgram,
                      plan: MeshPlan) -> list[dict]:
    """One JSON-able dict per instruction: its placement and per-device byte
    split under ``plan``.  ``gather_bytes`` is the fp32 output traffic one
    device *receives* per forward from the bd all_gather (0 for replicated
    layers — they communicate nothing)."""
    if len(plan.shards) != len(program.instrs):
        raise ValueError(
            f"MeshPlan carries {len(plan.shards)} shard(s) for "
            f"{len(program.instrs)} instruction(s)")
    out = []
    for idx, (instr, s) in enumerate(zip(program.instrs, plan.shards)):
        st = instr.stats
        row = {
            "index": idx, "name": instr.name, "kind": instr.kind,
            "shard": s.kind,
            "devices": plan.devices,
            "weight_bytes": int(st.weight_bytes),
        }
        row.update(st.device_view(n_model=plan.n_model,
                                  sharded=s.kind == "bd"))
        if s.kind == "bd":
            Hp, Wp = (tuple(st.padded_in) if st.padded_in
                      else tuple(st.in_shape[1:3]))
            C = int(st.in_shape[-1])
            row["d_local"] = s.d_local
            row["local_plan"] = {"nb": s.plan.nb, "bu": s.plan.bu,
                                 "bd": s.plan.bd}
            # the device-local working set under the local plan (the number
            # the verifier's vmem-budget rule sees per device)
            row["per_device_vmem_bytes"] = int(bck.tile_vmem_bytes(
                Wp, C, instr.kh, instr.kw, s.plan.bd, bu=s.plan.bu,
                pool=instr.pool, stride=instr.stride, m=instr.M,
                nb=s.plan.nb))
            # fp32 output rows received from the other model-column peers
            out_img = 1
            for d in st.out_shape[1:]:
                out_img *= int(d)
            recv = (out_img * plan.local_batch * 4
                    * (plan.n_model - 1)) // max(plan.n_model, 1)
            row["gather_bytes"] = int(recv)
        else:
            row["per_device_vmem_bytes"] = int(st.vmem_bytes)
            row["gather_bytes"] = 0
        out.append(row)
    return out


def mesh_totals(program: BinArrayProgram, plan: MeshPlan) -> dict:
    """Whole-program roll-up of :func:`shard_layer_stats` — the
    ``distributed`` section's gated totals.

    ``replication_overhead`` is fleet weight bytes (every copy on every
    device) divided by one program copy: ``devices`` when everything is
    replicated, shrinking toward ``n_data`` as layers shard.
    """
    rows = shard_layer_stats(program, plan)
    single = sum(r["weight_bytes"] for r in rows)
    fleet = 0
    for r in rows:
        copies = plan.n_data if r["shard"] == "bd" else plan.devices
        fleet += r["weight_bytes"] * copies
    return {
        "devices_per_forward": plan.devices,
        "n_data": plan.n_data,
        "n_model": plan.n_model,
        "global_batch": plan.global_batch,
        "local_batch": plan.local_batch,
        "sharded_layers": sum(1 for r in rows if r["shard"] == "bd"),
        "per_device_weight_bytes": int(sum(
            r["per_device_weight_bytes"] for r in rows)),
        "replicated_weight_bytes": int(sum(
            r["weight_bytes"] for r in rows if r["shard"] != "bd")),
        "sharded_weight_bytes": int(sum(
            r["weight_bytes"] for r in rows if r["shard"] == "bd")),
        "max_per_device_vmem_bytes": int(max(
            r["per_device_vmem_bytes"] for r in rows)),
        "gather_bytes": int(sum(r["gather_bytes"] for r in rows)),
        "replication_overhead": (fleet / single) if single else 0.0,
    }
