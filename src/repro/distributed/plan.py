"""MeshPlan: map a compiled BinArrayProgram onto a device mesh.

The paper scales throughput by instantiating more Processing Arrays behind
one instruction stream (§IV) — the schedule is fixed offline, the arrays
replicate compute.  Our analog is JAX devices: a :class:`MeshPlan` is the
offline decision of *how* a :class:`~repro.deploy.program.BinArrayProgram`
spreads over a ``jax.sharding.Mesh``, frozen before any trace runs (same
compile-once contract as the tile plans):

  * **data parallelism** (the default, every layer): the global batch splits
    over the ``data`` axis, packed weights are replicated — the direct
    Processing-Array analog, bit-exact because the kernels clamp and stay
    bit-exact across any batch tiling (the PR-4 contract).
  * **output-channel (bd-dim) model parallelism** (opt-in per layer): big
    point-wise ``ConvInstr`` layers split their D output channels over the
    ``model`` axis; each device runs the conv on its channel slice with a
    device-local frozen :class:`~repro.deploy.program.TilePlan` (picked with
    the *same* exported ``pick_tile``/``_pick_block`` machinery the compiler
    uses), and an ``all_gather(tiled=True)`` concatenates the slices.
    Channel slices are computed independently — there is no fp reduction —
    so the gathered output is bitwise equal to the unsharded layer.

``plan_mesh`` is the planner; the per-layer decisions live in
:class:`LayerShard` records (one per instruction, hashable, auditable by
``analysis.verify_mesh_plan``).  Everything here is static: no devices are
touched until :func:`MeshPlan.build_mesh` / ``distributed.execute_sharded``.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.deploy.program import BinArrayProgram, ConvInstr, TilePlan
from repro.kernels import binary_conv as bck
from repro.kernels import ops as kops

DATA_AXIS = "data"
MODEL_AXIS = "model"

# Point-wise layers below this packed-weight size are not worth splitting:
# the all_gather latency outweighs the VMEM/byte relief (the planner also
# splits any layer whose working set exceeds the VMEM budget, regardless).
DEFAULT_MIN_SHARD_BYTES = 16 * 1024


@dataclasses.dataclass(frozen=True)
class LayerShard:
    """One instruction's placement under the mesh.

    ``kind`` is ``"replicated"`` (weights on every device, the default) or
    ``"bd"`` (output channels split over the model axis).  For ``bd``
    shards, ``d_local`` is the per-device channel count, ``plan`` the
    device-local tile plan (frozen — the sharded trace must pick nothing),
    and ``per_device_weight_bytes`` the accounting the verifier re-derives.
    """

    kind: str = "replicated"            # replicated | bd
    d_local: int = 0                    # per-device output channels (bd)
    plan: TilePlan | None = None        # device-local frozen plan (bd)
    per_device_weight_bytes: int = 0    # packed weight bytes on one device


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """A frozen program→mesh mapping: axis sizes + one LayerShard per
    instruction.  Hashable (jit-cache key) and device-free until
    :meth:`build_mesh`."""

    n_data: int
    n_model: int = 1
    shards: tuple[LayerShard, ...] = ()
    global_batch: int = 0               # the batch the plan was picked for
    axis_data: str = DATA_AXIS
    axis_model: str = MODEL_AXIS

    @property
    def devices(self) -> int:
        """Devices one forward occupies (the paper's Processing Array count)."""
        return self.n_data * self.n_model

    @property
    def local_batch(self) -> int:
        """Per-device batch after the ragged pad (ceil division)."""
        return -(-max(self.global_batch, 1) // self.n_data)

    def build_mesh(self) -> jax.sharding.Mesh:
        """Materialize the (n_data, n_model) device mesh.  Raises if the
        process has fewer than ``devices`` JAX devices."""
        return jax.make_mesh((self.n_data, self.n_model),
                             (self.axis_data, self.axis_model))

    def describe(self) -> list[str]:
        """One human line per shard (tools/verify_program.py --mesh)."""
        out = [f"mesh {self.n_data}x{self.n_model} "
               f"({self.axis_data},{self.axis_model}), "
               f"global_batch={self.global_batch}"]
        for i, s in enumerate(self.shards):
            if s.kind == "bd":
                out.append(f"  [{i}] bd-sharded: d_local={s.d_local}, "
                           f"plan=(nb={s.plan.nb}, bu={s.plan.bu}, "
                           f"bd={s.plan.bd}), "
                           f"{s.per_device_weight_bytes} B/device")
            else:
                out.append(f"  [{i}] replicated "
                           f"({s.per_device_weight_bytes} B/device)")
        return out


def _shardable(instr, n_model: int, *, pointwise_only: bool) -> bool:
    """Structural preconditions for bd-sharding one instruction: ConvInstr,
    point-wise (unless overridden), D divisible into >= 8-channel byte-even
    slices (so the per-device lane dim stays Mosaic-padddable)."""
    if n_model < 2 or not isinstance(instr, ConvInstr):
        return False
    if pointwise_only and not (instr.kh == 1 and instr.kw == 1):
        return False
    D = int(instr.alpha.shape[-1])
    if D % n_model:
        return False
    d_local = D // n_model
    return d_local >= 8 and d_local % 8 == 0


def plan_mesh(program: BinArrayProgram, *, n_data: int, n_model: int = 1,
              global_batch: int | None = None,
              vmem_budget: int | None = None,
              min_shard_bytes: int = DEFAULT_MIN_SHARD_BYTES,
              pointwise_only: bool = True) -> MeshPlan:
    """Plan a program onto an ``n_data`` x ``n_model`` mesh.

    Every layer is data-parallel with replicated weights by default; a
    ``ConvInstr`` is bd-sharded over the model axis when it is structurally
    shardable (:func:`_shardable`) **and** the split is justified — its
    packed weights reach ``min_shard_bytes`` or its working set exceeds the
    VMEM budget.  Device-local tile plans are co-picked with the same
    exported machinery the compiler freezes (``_pick_block`` for the local
    lane tile, ``pick_tile`` for (NB, BU) at the per-device batch), wrapped
    so planning never counts as a trace-time plan pick.

    ``global_batch`` defaults to the program's compiled batch; the plan is
    picked for ``ceil(global_batch / n_data)`` images per device but stays
    *correct* for any batch (kernels clamp, bit-exact).  Works on abstract
    programs too — only shapes and static aux data are read.
    """
    from repro.analysis.verify import _no_pick_accounting

    if n_data < 1 or n_model < 1:
        raise ValueError(f"mesh axes must be >= 1, got "
                         f"n_data={n_data}, n_model={n_model}")
    gb = int(global_batch if global_batch is not None
             else (program.input_shape[0] if program.input_shape else 1))
    if gb < 1:
        raise ValueError(f"global_batch must be >= 1, got {gb}")
    budget = vmem_budget or bck.DEFAULT_VMEM_BUDGET
    b_local = -(-gb // n_data)
    shards = []
    for instr in program.instrs:
        wb = int(instr.stats.weight_bytes)
        if not (_shardable(instr, n_model, pointwise_only=pointwise_only)
                and (wb >= min_shard_bytes
                     or instr.stats.vmem_bytes > budget)):
            shards.append(LayerShard(per_device_weight_bytes=wb))
            continue
        D = int(instr.alpha.shape[-1])
        d_local = D // n_model
        st = instr.stats
        Hp, Wp = (tuple(st.padded_in) if st.padded_in
                  else tuple(st.in_shape[1:3]))
        C = int(st.in_shape[-1])
        with _no_pick_accounting():
            bd_local = kops._pick_block(d_local, 128)
            nb_l, bu_l = bck.pick_tile(
                b_local, Hp, Wp, C, instr.kh, instr.kw, bd_local,
                instr.pool, budget, stride=instr.stride, m=instr.M)
        shards.append(LayerShard(
            kind="bd", d_local=d_local,
            plan=TilePlan(nb=nb_l, bu=bu_l, bd=bd_local),
            per_device_weight_bytes=wb // n_model))
    return MeshPlan(n_data=n_data, n_model=n_model, shards=tuple(shards),
                    global_batch=gb)
