"""Multi-device BinArrayProgram execution (paper §IV scaled to a mesh).

``plan_mesh`` freezes a :class:`MeshPlan` (data-parallel batch + optional
output-channel model parallelism per layer), ``execute_sharded`` runs one
jitted ``shard_map`` forward bit-exact against ``deploy.execute``, and
``shard_layer_stats``/``mesh_totals`` account the per-device byte splits
the benchmarks gate.  See docs/distributed.md.
"""
from repro.distributed.executor import (cache_gauges, cache_stats,
                                        execute_sharded,
                                        reset_trace_entry_count,
                                        trace_entry_count)
from repro.distributed.plan import (DATA_AXIS, DEFAULT_MIN_SHARD_BYTES,
                                    MODEL_AXIS, LayerShard, MeshPlan,
                                    plan_mesh)
from repro.distributed.stats import mesh_totals, shard_layer_stats

__all__ = [
    "DATA_AXIS", "DEFAULT_MIN_SHARD_BYTES", "MODEL_AXIS",
    "LayerShard", "MeshPlan", "plan_mesh",
    "execute_sharded", "trace_entry_count", "reset_trace_entry_count",
    "cache_stats", "cache_gauges", "shard_layer_stats", "mesh_totals",
]
